#!/usr/bin/env python
"""CI smoke for the disk-resident chunk-skipping data plane.

End to end in a tmpdir: write a planted libsvm file, build the mmap-backed
store with ``FeatureChunked.from_libsvm_cached``, run the gated screened
path, and assert that chunk-level gating actually skipped transfers
(``chunks_skipped > 0``) while matching the full-stream twin bitwise.

The instance plants an informative head block and a weak noise tail
(features past the head have tiny norms), so whole tail chunks screen out
early and stay dead — the geometry chunk gating exists for. Kept separate
from pytest so the lane exercises the real CLI-adjacent workflow (text
file on disk -> store -> path) rather than in-memory containers.
"""

import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.path import PathDriver  # noqa: E402
from repro.data import make_sparse_classification  # noqa: E402
from repro.sparse import FeatureChunked  # noqa: E402


def planted_instance():
    ds = make_sparse_classification(m=320, n=120, k_active=8, seed=7)
    X = np.array(ds.X, copy=True)
    X[64:] *= 0.05  # weak noise tail -> persistently dead tail chunks
    return X, np.asarray(ds.y)


def write_libsvm(path, X, y):
    m, n = X.shape
    with open(path, "w") as f:
        for i in range(n):
            nz = np.nonzero(X[:, i])[0]
            # 9 significant digits round-trip any float32 exactly
            feats = " ".join(f"{j + 1}:{float(X[j, i]):.9g}" for j in nz)
            f.write(f"{int(y[i]):+d} {feats}\n")


def main():
    X, y = planted_instance()
    kw = dict(rules="feature_vi", tol=1e-9, max_iters=8000)
    grid = dict(n_lambdas=8, lam_min_ratio=0.05)

    with tempfile.TemporaryDirectory() as tmp:
        text = os.path.join(tmp, "planted.svm")
        write_libsvm(text, X, y)
        fc, y_store = FeatureChunked.from_libsvm_cached(
            text, store_dir=os.path.join(tmp, "store"), chunk_m=32)
        assert fc.shape == X.shape, (fc.shape, X.shape)
        np.testing.assert_allclose(np.asarray(fc.as_dense()), X, atol=1e-6)

        res = PathDriver(chunk_skip=True, **kw).run(fc, y_store, **grid)
        st = res.extras["stream_stats"]
        assert st["chunks_skipped"] > 0, st

        fc_full = FeatureChunked.from_libsvm_cached(
            text, store_dir=os.path.join(tmp, "store"), chunk_m=32)[0]
        ref = PathDriver(chunk_skip=False, **kw).run(fc_full, y_store, **grid)
        st_full = fc_full.stats
        assert st["chunks_streamed"] < st_full["chunks_streamed"], (
            st, dict(st_full))
        np.testing.assert_array_equal(res.objectives, ref.objectives)
        np.testing.assert_array_equal(res.weights, ref.weights)

        print(f"stream smoke OK: {st['chunks_streamed']} streamed, "
              f"{st['chunks_skipped']} skipped "
              f"(full twin: {st_full['chunks_streamed']} streamed), "
              f"bytes_put {st['bytes_put']} < {st_full['bytes_put']}")


if __name__ == "__main__":
    main()
