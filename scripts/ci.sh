#!/usr/bin/env bash
# CI matrix (see ROADMAP.md and .github/workflows/ci.yml). Lanes, each
# runnable by name:
#
#   ./scripts/ci.sh              # full:    the whole tier-1 suite
#   ./scripts/ci.sh full
#   ./scripts/ci.sh fast         # fast:    tier-1 minus slow (multi-process)
#   ./scripts/ci.sh kernels      # kernels: Pallas suites, interpret mode
#                                #          forced via REPRO_PALLAS_INTERPRET=1
#                                #          (incl. the valid_m row-count paths
#                                #          the compact reduction drives)
#   ./scripts/ci.sh x64          # x64:     numerical core under
#                                #          JAX_ENABLE_X64=1 (screening bound
#                                #          math, solver, paths)
#   ./scripts/ci.sh stream       # stream:  out-of-core subsystem
#                                #          (tests/test_sparse_stream.py) with
#                                #          a small forced chunk size
#                                #          (REPRO_STREAM_CHUNK_M=48): bitwise
#                                #          chunked bound sweep, solver seam,
#                                #          BCOO, memory-shape property,
#                                #          chunk-skip twin; plus the
#                                #          disk-resident smoke
#                                #          (scripts/stream_smoke.py: libsvm ->
#                                #          mmap store -> gated path with
#                                #          chunks_skipped > 0)
#   ./scripts/ci.sh serve        # serve:   path-server suite (continuous
#                                #          batching, bucket padding, warm
#                                #          program cache) + the --serve
#                                #          launcher smoke
#   ./scripts/ci.sh rules        # rules:   the screening-rule zoo — rule
#                                #          programs on every engine
#                                #          (tests/test_rule_programs.py:
#                                #          host-vs-scan equivalence matrix,
#                                #          EDPP-tightens-VI, dvi history
#                                #          carry, composite round-trip,
#                                #          dispatch rejections) + the host
#                                #          rule-protocol suite
#   ./scripts/ci.sh bench        # bench:   engine + storage equivalence smoke
#                                #          (bench_screening --smoke): catches
#                                #          host/scan/compact/pallas/chunked,
#                                #          batched-compact, server-vs-
#                                #          sequential and sharded-scan-bitwise
#                                #          regressions in seconds
#   ./scripts/ci.sh obs          # obs:     observability layer
#                                #          (tests/test_obs.py: span recorder
#                                #          round-trip, disabled-mode no-op,
#                                #          metrics registry mirroring the
#                                #          legacy stats dicts bitwise,
#                                #          PathTrace schema across engines)
#                                #          + a train_svm --trace smoke that
#                                #          validates the exported Chrome
#                                #          trace JSON
#   ./scripts/ci.sh chaos        # chaos:   fault-injection suite
#                                #          (tests/test_faults.py via
#                                #          src/repro/testing/faults.py):
#                                #          poisoned solves keep a superset
#                                #          and recover, corrupt/truncated
#                                #          stores surface typed errors,
#                                #          flaky reads are absorbed, server
#                                #          kill+resume equals uninterrupted,
#                                #          quarantine isolates tenants;
#                                #          interpret mode forced so guard
#                                #          paths run on any backend
#   ./scripts/ci.sh all          # kernels + x64 + stream + serve + rules
#                                # + bench + chaos + obs,
#                                # then full
#
# Extra pytest args pass through after the lane name (a leading '-' arg is
# treated as pytest args for the full lane, back-compat):
#   ./scripts/ci.sh fast -k screening
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

lane="${1:-full}"
case "$lane" in
  full|fast|kernels|x64|stream|serve|rules|bench|chaos|obs|all) shift || true ;;
  -*) lane="full" ;;  # bare pytest args => full lane (legacy invocation)
  *) echo "unknown lane '$lane' (full|fast|kernels|x64|stream|serve|rules|bench|chaos|obs|all)" >&2; exit 2 ;;
esac

# suites whose numerics are dtype-parametric: the safe-screening bound
# geometry, the solver, and both path engines must hold in fp64 too
X64_SUITES="tests/test_screening.py tests/test_dual.py tests/test_solver.py \
tests/test_path.py tests/test_path_scan.py"

run_lane() {
  local name="$1"; shift
  echo "=== ci lane: $name ==="
  case "$name" in
    full)
      python -m pytest -x -q "$@"
      ;;
    fast)
      python -m pytest -x -q -m 'not slow' "$@"
      ;;
    kernels)
      REPRO_PALLAS_INTERPRET=1 python -m pytest -x -q \
        tests/test_kernels.py "$@"
      ;;
    x64)
      JAX_ENABLE_X64=1 python -m pytest -x -q $X64_SUITES "$@"
      ;;
    stream)
      # deliberately small + ragged: many chunks per instance, last chunk
      # partial — the shapes the out-of-core paths must be invariant to
      REPRO_STREAM_CHUNK_M=48 python -m pytest -x -q \
        tests/test_sparse_stream.py "$@"
      # disk-resident + chunk-skip smoke: libsvm -> mmap store in a tmpdir,
      # gated path must actually skip transfers (chunks_skipped > 0)
      python scripts/stream_smoke.py
      ;;
    serve)
      python -m pytest -x -q tests/test_path_server.py "$@"
      python -m repro.launch.train_svm --serve --serve-jobs 4 \
        --serve-slots 2 --m 120 --n 60 --reduce compact
      ;;
    rules)
      python -m pytest -x -q tests/test_rule_programs.py tests/test_rules.py "$@"
      ;;
    bench)
      python -m benchmarks.bench_screening --smoke
      ;;
    chaos)
      REPRO_PALLAS_INTERPRET=1 python -m pytest -x -q \
        tests/test_faults.py "$@"
      ;;
    obs)
      python -m pytest -x -q tests/test_obs.py "$@"
      # trace-capture smoke: the launcher must export loadable Chrome
      # trace-event JSON with per-step spans from the scan engine
      python -m repro.launch.train_svm --m 120 --n 60 --n-lambdas 4 \
        --engine scan --trace artifacts/ci_trace.json
      python - <<'EOF'
import json
doc = json.load(open("artifacts/ci_trace.json"))
evs = doc["traceEvents"]
assert any(e.get("ph") == "X" and e["name"] == "scan.step" for e in evs), \
    sorted({e["name"] for e in evs})
print(f"obs smoke: {len(evs)} trace events OK")
EOF
      ;;
  esac
}

if [ "$lane" = "all" ]; then
  # kernels (interpret-forced), x64, stream, bench smoke, then full — full
  # already includes every non-slow test, so fast here would duplicate work
  run_lane kernels "$@"
  run_lane x64 "$@"
  run_lane stream "$@"
  run_lane serve "$@"
  run_lane rules "$@"
  run_lane bench
  run_lane chaos "$@"
  run_lane obs "$@"
  run_lane full "$@"
else
  run_lane "$lane" "$@"
fi
