#!/usr/bin/env bash
# CI matrix (see ROADMAP.md). Lanes, each runnable by name:
#
#   ./scripts/ci.sh              # full:    the whole tier-1 suite
#   ./scripts/ci.sh full
#   ./scripts/ci.sh fast         # fast:    tier-1 minus slow (multi-process)
#   ./scripts/ci.sh kernels      # kernels: Pallas suites, interpret mode
#                                #          forced via REPRO_PALLAS_INTERPRET=1
#   ./scripts/ci.sh all          # kernels lane, then full (which covers fast)
#
# Extra pytest args pass through after the lane name (a leading '-' arg is
# treated as pytest args for the full lane, back-compat):
#   ./scripts/ci.sh fast -k screening
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

lane="${1:-full}"
case "$lane" in
  full|fast|kernels|all) shift || true ;;
  -*) lane="full" ;;  # bare pytest args => full lane (legacy invocation)
  *) echo "unknown lane '$lane' (full|fast|kernels|all)" >&2; exit 2 ;;
esac

run_lane() {
  local name="$1"; shift
  echo "=== ci lane: $name ==="
  case "$name" in
    full)
      python -m pytest -x -q "$@"
      ;;
    fast)
      python -m pytest -x -q -m 'not slow' "$@"
      ;;
    kernels)
      REPRO_PALLAS_INTERPRET=1 python -m pytest -x -q \
        tests/test_kernels.py "$@"
      ;;
  esac
}

if [ "$lane" = "all" ]; then
  # kernels (interpret-forced), then full — full already includes every
  # non-slow test, so running fast here would only duplicate work
  run_lane kernels "$@"
  run_lane full "$@"
else
  run_lane "$lane" "$@"
fi
