#!/usr/bin/env bash
# Minimal CI: run the tier-1 suite on CPU jax (see ROADMAP.md).
#
#   ./scripts/ci.sh            # full tier-1
#   ./scripts/ci.sh -m 'not slow'   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
