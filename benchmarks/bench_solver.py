"""Solver-side tables: warm-start effect and problem-size scaling of FISTA
(the substrate the screening accelerates — paper Sec. 6.7's training cost)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import fista_solve, lambda_max
from repro.data import make_sparse_classification


def run(log=print):
    rows = []
    log("# FISTA iterations: cold vs warm start along the path")
    ds = make_sparse_classification(m=2000, n=400, seed=17)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    prev = None
    log("lambda_ratio,cold_iters,warm_iters")
    for r in (0.8, 0.6, 0.4):
        lam = r * lmax
        cold = fista_solve(X, y, lam, max_iters=30000, tol=1e-10)
        if prev is not None:
            warm = fista_solve(X, y, lam, w0=prev.w, b0=prev.b,
                               max_iters=30000, tol=1e-10)
            log(f"{r},{int(cold.n_iters)},{int(warm.n_iters)}")
            rows.append(("fista_warm_start", 0.0,
                         f"r={r} cold={int(cold.n_iters)} warm={int(warm.n_iters)}"))
        prev = cold

    log("# solve-time scaling with kept-feature count (screening's win)")
    log("m_kept,solve_ms")
    full = np.asarray(X)
    for m_kept in (128, 512, 2000):
        Xr = jnp.asarray(full[:m_kept])
        res = fista_solve(Xr, y, 0.4 * lmax, max_iters=30000, tol=1e-10)  # warm jit
        t0 = time.perf_counter()
        res = fista_solve(Xr, y, 0.4 * lmax, max_iters=30000, tol=1e-10)
        res.w.block_until_ready()
        dt = (time.perf_counter() - t0) * 1e3
        log(f"{m_kept},{dt:.1f}")
        rows.append((f"fista_m{m_kept}", dt * 1e3, f"iters={int(res.n_iters)}"))
    return rows
