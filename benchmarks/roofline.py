"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh):
    compute    = HLO_FLOPs_per_device / PEAK_FLOPS          [s]
    memory     = HLO_bytes_per_device / HBM_BW              [s]
    collective = collective_bytes_per_device / ICI_BW       [s]

(cost_analysis on an SPMD module is per-partition, i.e. per-device, as is the
optimized-HLO collective audit.) Dominant term = the bottleneck; the roofline
fraction reported in EXPERIMENTS.md §Perf is
``compute / max(compute, memory, collective)`` — how close the cell is to
being MXU-bound, the best the workload can do on this mesh.

MODEL_FLOPS: 6·N·T for train, 2·N·T for prefill, 2·N_active·B for one decode
step (per device: divided by the mesh size). The ratio MODEL_FLOPS/HLO_FLOPs
flags remat/redundancy waste (ratio << 1 ⇒ compiled compute is mostly
overhead; > 1 ⇒ cost model undercounts fused ops).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e-class target)
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link


def model_flops_total(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    tokens = sh["batch"] * sh["seq"]
    if sh["kind"] == "train":
        return 6.0 * cfg.param_count() * tokens if not cfg.moe_num_experts \
            else 6.0 * cfg.active_param_count() * tokens
    if sh["kind"] == "prefill":
        n = cfg.active_param_count() if cfg.moe_num_experts else cfg.param_count()
        return 2.0 * n * tokens
    # decode: one token per sequence in the batch
    n = cfg.active_param_count() if cfg.moe_num_experts else cfg.param_count()
    return 2.0 * n * sh["batch"]


def analyse(rec: dict) -> dict:
    n_dev = rec["devices"]
    comp = rec["flops"] / PEAK_FLOPS
    mem = rec["bytes_accessed"] / HBM_BW
    coll = rec["collective_bytes_total"] / ICI_BW
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda t: t[1])
    mf = model_flops_total(rec["arch"], rec["shape"]) / n_dev
    frac = comp / max(comp, mem, coll) if max(comp, mem, coll) > 0 else 0.0
    return {
        **rec,
        "t_compute_s": comp,
        "t_memory_s": mem,
        "t_collective_s": coll,
        "dominant": dom[0],
        "roofline_fraction": frac,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": mf / rec["flops"] if rec["flops"] else 0.0,
    }


def render(records: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | roofline frac | useful/HLO flops | cost source |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"SKIP | — | — | {r['skipped'][:48]}… |")
            continue
        a = analyse(r)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['t_compute_s']:.2e} | {a['t_memory_s']:.2e} "
            f"| {a['t_collective_s']:.2e} | **{a['dominant']}** "
            f"| {a['roofline_fraction']:.2f} | {a['useful_flops_ratio']:.2f} "
            f"| {a.get('cost_source', '')} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16",
                    help="roofline table mesh (single-pod per the brief)")
    ap.add_argument("--out", default="artifacts/roofline.md")
    args = ap.parse_args()

    # scan-mode artifacts carry the memory/compile proof; cost-mode artifacts
    # (unrolled lowering) carry accurate flops/bytes/collectives. Merge.
    base, cost, cost_base = {}, {}, {}
    for p in sorted(Path(args.dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("mesh") != args.mesh and not rec.get("skipped"):
            continue
        key = (rec["arch"], rec["shape"])
        name = p.stem
        if name.endswith("__cost"):
            cost[key] = rec
        elif name.endswith("__cost_base"):
            cost_base[key] = rec
        elif name.endswith("__base"):
            continue  # scan-mode baseline variant: §Perf only
        elif key not in base or not base[key].get("skipped"):
            base[key] = rec
    # fall back to baseline-cost numbers where no optimized-cost cell exists
    for key, rec in cost_base.items():
        cost.setdefault(key, rec)

    uniq = []
    for key in sorted(base):
        rec = dict(base[key])
        if key in cost and not rec.get("skipped"):
            c = cost[key]
            rec.update(
                flops=c["flops"], bytes_accessed=c["bytes_accessed"],
                collectives=c["collectives"],
                collective_bytes_total=c["collective_bytes_total"],
            )
            rec["cost_source"] = "unrolled"
        else:
            rec["cost_source"] = "scan(x~L undercount)"
        uniq.append(rec)

    table = render(uniq)
    Path(args.out).write_text(table + "\n")
    print(table)
    print(f"\nwritten to {args.out}")


if __name__ == "__main__":
    main()
