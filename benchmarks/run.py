"""Benchmark harness: one module per paper table. Prints
``name,us_per_call,derived`` CSV plus human-readable sections.

PYTHONPATH=src python -m benchmarks.run [--only screening|path|kernels|solver]
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import bench_kernels, bench_path, bench_screening, bench_solver

    suites = {
        "screening": bench_screening.run,
        "path": bench_path.run,
        "kernels": bench_kernels.run,
        "solver": bench_solver.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    all_rows = []
    for name, fn in suites.items():
        print(f"\n===== {name} =====")
        all_rows.extend(fn(log=print))

    print("\n===== CSV =====")
    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
