"""Paper table: screening (rejection) rate vs lambda ratio, across designs.

Mirrors the paper's evaluation axis: how many features the rule discards as a
function of lambda2/lambda1, on dense / sparse / correlated designs, with
theta1 exact (lambda1 = lambda_max) and sequential (solved theta1).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (
    fista_solve,
    lambda_max,
    screen,
    theta_at_lambda_max,
)
from repro.core.dual import safe_theta_and_delta
from repro.data import make_sparse_classification

RATIOS = (0.95, 0.9, 0.8, 0.7, 0.5, 0.3, 0.1)


def run(log=print):
    rows = []
    datasets = {
        "dense": dict(m=4000, n=500, density=1.0, correlated=0.0),
        "sparse": dict(m=4000, n=500, density=0.1, correlated=0.0),
        "correlated": dict(m=4000, n=500, density=1.0, correlated=0.5),
    }
    log("# screening rate vs lambda ratio (lambda1 = lambda_max, theta exact)")
    log("dataset,ratio,rejected_frac,screen_us,us_per_feature")
    for name, kw in datasets.items():
        ds = make_sparse_classification(seed=7, **kw)
        X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
        m = X.shape[0]
        lmax = float(lambda_max(X, y))
        theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
        # warm up jit
        screen(X, y, lmax, 0.5 * lmax, theta1)[0].block_until_ready()
        for r in RATIOS:
            t0 = time.perf_counter()
            keep, _ = screen(X, y, lmax, r * lmax, theta1)
            keep.block_until_ready()
            dt = (time.perf_counter() - t0) * 1e6
            rej = 1.0 - float(jnp.mean(keep))
            rows.append(("screen_rate_" + name, dt, f"ratio={r} rejected={rej:.4f}"))
            log(f"{name},{r},{rej:.4f},{dt:.0f},{dt / m:.3f}")
    # sequential screening rate (theta from solved intermediate lambda)
    ds = make_sparse_classification(m=4000, n=500, seed=8)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    lam1 = 0.5 * lmax
    res = fista_solve(X, y, lam1, max_iters=20000, tol=1e-11)
    theta1, delta = safe_theta_and_delta(X, y, res.w, res.b, jnp.asarray(lam1))
    for r in (0.9, 0.7, 0.5):
        keep, _ = screen(X, y, lam1, r * lam1, theta1, delta=delta)
        rej = 1.0 - float(jnp.mean(keep))
        log(f"sequential,{r},{rej:.4f},,")
        rows.append(("screen_rate_sequential", 0.0, f"ratio={r} rejected={rej:.4f}"))
    return rows
