"""Paper table: screening (rejection) rate vs lambda ratio, across designs —
plus the rule sweep (feature / sample / composite) over a whole path.

Mirrors the paper's evaluation axis: how many units each rule discards as a
function of lambda2/lambda1, on dense / sparse / correlated designs, with
theta1 exact (lambda1 = lambda_max) and sequential (solved theta1). The rule
sweep drives :class:`repro.core.PathDriver` with each registered reduction
and records per-step kept counts and wall times into a
``BENCH_screening.json`` trajectory file so successive PRs can diff
screening power and overhead.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PathDriver,
    fista_solve,
    lambda_max,
    screen,
    theta_at_lambda_max,
)
from repro.core.dual import safe_theta_and_delta
from repro.data import make_sparse_classification

RATIOS = (0.95, 0.9, 0.8, 0.7, 0.5, 0.3, 0.1)
RULE_SPECS = ("feature_vi", "sample_vi", "composite", None)
TRAJECTORY_PATH = Path(__file__).resolve().parent.parent / "BENCH_screening.json"


def _rate_tables(rows, log):
    datasets = {
        "dense": dict(m=4000, n=500, density=1.0, correlated=0.0),
        "sparse": dict(m=4000, n=500, density=0.1, correlated=0.0),
        "correlated": dict(m=4000, n=500, density=1.0, correlated=0.5),
    }
    log("# screening rate vs lambda ratio (lambda1 = lambda_max, theta exact)")
    log("dataset,ratio,rejected_frac,screen_us,us_per_feature")
    for name, kw in datasets.items():
        ds = make_sparse_classification(seed=7, **kw)
        X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
        m = X.shape[0]
        lmax = float(lambda_max(X, y))
        theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
        # warm up jit
        screen(X, y, lmax, 0.5 * lmax, theta1)[0].block_until_ready()
        for r in RATIOS:
            t0 = time.perf_counter()
            keep, _ = screen(X, y, lmax, r * lmax, theta1)
            keep.block_until_ready()
            dt = (time.perf_counter() - t0) * 1e6
            rej = 1.0 - float(jnp.mean(keep))
            rows.append(("screen_rate_" + name, dt, f"ratio={r} rejected={rej:.4f}"))
            log(f"{name},{r},{rej:.4f},{dt:.0f},{dt / m:.3f}")
    # sequential screening rate (theta from solved intermediate lambda)
    ds = make_sparse_classification(m=4000, n=500, seed=8)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    lam1 = 0.5 * lmax
    res = fista_solve(X, y, lam1, max_iters=20000, tol=1e-11)
    theta1, delta = safe_theta_and_delta(X, y, res.w, res.b, jnp.asarray(lam1))
    for r in (0.9, 0.7, 0.5):
        keep, _ = screen(X, y, lam1, r * lam1, theta1, delta=delta)
        rej = 1.0 - float(jnp.mean(keep))
        log(f"sequential,{r},{rej:.4f},,")
        rows.append(("screen_rate_sequential", 0.0, f"ratio={r} rejected={rej:.4f}"))


def _rule_sweep(rows, log, m=2000, n=400, n_lambdas=10, lam_min_ratio=0.05):
    """Drive the path with each rule config; emit the trajectory JSON."""
    ds = make_sparse_classification(m=m, n=n, k_active=20, seed=11)
    log(f"\n# rule sweep over the path (m={m}, n={n}, {n_lambdas} lambdas)")
    log("rules,path_s,kept_features,kept_samples,verify_resolves")
    traj = {
        "bench": "screening_rule_sweep",
        "instance": {"m": m, "n": n, "n_lambdas": n_lambdas,
                     "lam_min_ratio": lam_min_ratio, "seed": 11},
        "runs": [],
    }
    for spec in RULE_SPECS:
        name = spec or "none"
        driver = PathDriver(rules=spec)
        driver.run(ds.X, ds.y, n_lambdas=n_lambdas,
                   lam_min_ratio=lam_min_ratio)  # warm jit caches
        t0 = time.perf_counter()
        r = driver.run(ds.X, ds.y, n_lambdas=n_lambdas,
                       lam_min_ratio=lam_min_ratio)
        dt = time.perf_counter() - t0
        log(f"{name},{dt:.3f},{r.kept.tolist()},{r.kept_samples.tolist()},"
            f"{int(r.verify_rounds.sum())}")
        rows.append((f"path_rules_{name}", dt * 1e6,
                     f"kept_last={int(r.kept[-1])} "
                     f"samples_last={int(r.kept_samples[-1])}"))
        traj["runs"].append({
            "rules": name,
            "path_seconds": dt,
            "lambdas": [float(v) for v in r.lambdas],
            "kept_features": [int(v) for v in r.kept],
            "kept_samples": [int(v) for v in r.kept_samples],
            "active": [int(v) for v in r.active],
            "solver_iters": [int(v) for v in r.solver_iters],
            "screen_seconds": float(r.screen_times.sum()),
            "verify_resolves": int(r.verify_rounds.sum()),
            "max_obj": float(np.max(np.abs(r.objectives))),
        })
    TRAJECTORY_PATH.write_text(json.dumps(traj, indent=2))
    log(f"wrote trajectory file: {TRAJECTORY_PATH}")


def run(log=print):
    rows = []
    _rate_tables(rows, log)
    _rule_sweep(rows, log)
    return rows
