"""Paper table: screening (rejection) rate vs lambda ratio, across designs —
plus the rule sweep (feature / sample / composite) over a whole path and the
path-engine sweep (host vs scan vs scan+pallas, batched throughput).

Mirrors the paper's evaluation axis: how many units each rule discards as a
function of lambda2/lambda1, on dense / sparse / correlated designs, with
theta1 exact (lambda1 = lambda_max) and sequential (solved theta1). The rule
sweep drives :class:`repro.core.PathDriver` with each registered reduction
and records per-step kept counts and wall times into a
``BENCH_screening.json`` trajectory file so successive PRs can diff
screening power and overhead; the engine sweep does the same for the
on-device ``lax.scan`` path engine (``core/path_scan.py``) under the
``engines`` key — including the compact (on-device active-set gather)
reduction on a screen-effective grid (``engines["compact"]``), the
shared-cap batched compact vs batched mask comparison
(``engines["batched_compact"]``), the (1,1)-mesh sharded-scan bitwise
check, and batched throughput. The continuous-batching path server gets its
own ``serve`` section (jobs/sec vs sequential ``svm_path``, slot occupancy,
warm-cache hit/miss/retrace counters, p50/p95 job latency), and the
``robustness`` section prices the fault-tolerance layer (guards-on vs
guards-off path walls — asserted < 5% overhead in ``--smoke`` — plus
recovered-vs-clean objective diffs after a poisoned mid-path step), and the
``obs`` section prices the observability layer (tracing-on vs tracing-off
path walls — asserted < 3% overhead in ``--smoke``, tracing-off bitwise
equal — plus the run's uniform ``PathTrace`` artifact). The file is
stamped with backend/device/jax-version metadata (``meta``) so trajectories
from different machines are not silently compared.

CLI:  PYTHONPATH=src python -m benchmarks.bench_screening [--smoke]
``--smoke`` runs a seconds-scale engine-equivalence check on a tiny
instance (the CI bench lane) and does not touch the trajectory file.
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    PathDriver,
    fista_solve,
    lambda_max,
    screen,
    svm_path_batched,
    svm_path_scan,
    theta_at_lambda_max,
)
from repro.core.dual import safe_theta_and_delta
from repro.data import make_sparse_classification

RATIOS = (0.95, 0.9, 0.8, 0.7, 0.5, 0.3, 0.1)
RULE_SPECS = ("feature_vi", "sample_vi", "composite", "dvi", "edpp",
              "sifs", "auto", None)
TRAJECTORY_PATH = Path(__file__).resolve().parent.parent / "BENCH_screening.json"


def _machine_meta() -> dict:
    """Backend/device/version stamp for the trajectory file.

    Wall-clock trajectories are only comparable across PRs when they ran on
    the same kind of machine — this stamp makes cross-machine diffs
    interpretable instead of silently misleading.
    """
    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }


def _rate_tables(rows, log):
    datasets = {
        "dense": dict(m=4000, n=500, density=1.0, correlated=0.0),
        "sparse": dict(m=4000, n=500, density=0.1, correlated=0.0),
        "correlated": dict(m=4000, n=500, density=1.0, correlated=0.5),
    }
    log("# screening rate vs lambda ratio (lambda1 = lambda_max, theta exact)")
    log("dataset,ratio,rejected_frac,screen_us,us_per_feature")
    for name, kw in datasets.items():
        ds = make_sparse_classification(seed=7, **kw)
        X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
        m = X.shape[0]
        lmax = float(lambda_max(X, y))
        theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
        # warm up jit
        screen(X, y, lmax, 0.5 * lmax, theta1)[0].block_until_ready()
        for r in RATIOS:
            t0 = time.perf_counter()
            keep, _ = screen(X, y, lmax, r * lmax, theta1)
            keep.block_until_ready()
            dt = (time.perf_counter() - t0) * 1e6
            rej = 1.0 - float(jnp.mean(keep))
            rows.append(("screen_rate_" + name, dt, f"ratio={r} rejected={rej:.4f}"))
            log(f"{name},{r},{rej:.4f},{dt:.0f},{dt / m:.3f}")
    # sequential screening rate (theta from solved intermediate lambda)
    ds = make_sparse_classification(m=4000, n=500, seed=8)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    lam1 = 0.5 * lmax
    res = fista_solve(X, y, lam1, max_iters=20000, tol=1e-11)
    theta1, delta = safe_theta_and_delta(X, y, res.w, res.b, jnp.asarray(lam1))
    for r in (0.9, 0.7, 0.5):
        keep, _ = screen(X, y, lam1, r * lam1, theta1, delta=delta)
        rej = 1.0 - float(jnp.mean(keep))
        log(f"sequential,{r},{rej:.4f},,")
        rows.append(("screen_rate_sequential", 0.0, f"ratio={r} rejected={rej:.4f}"))


def _rule_sweep(rows, log, m=2000, n=400, n_lambdas=10, lam_min_ratio=0.05):
    """Drive the path with each rule config; emit the trajectory JSON."""
    ds = make_sparse_classification(m=m, n=n, k_active=20, seed=11)
    log(f"\n# rule sweep over the path (m={m}, n={n}, {n_lambdas} lambdas)")
    log("rules,path_s,kept_features,kept_samples,verify_resolves")
    traj = {
        "bench": "screening_rule_sweep",
        "meta": _machine_meta(),
        "instance": {"m": m, "n": n, "n_lambdas": n_lambdas,
                     "lam_min_ratio": lam_min_ratio, "seed": 11},
        "runs": [],
    }
    for spec in RULE_SPECS:
        name = spec or "none"
        driver = PathDriver(rules=spec)
        driver.run(ds.X, ds.y, n_lambdas=n_lambdas,
                   lam_min_ratio=lam_min_ratio)  # warm jit caches
        t0 = time.perf_counter()
        r = driver.run(ds.X, ds.y, n_lambdas=n_lambdas,
                       lam_min_ratio=lam_min_ratio)
        dt = time.perf_counter() - t0
        log(f"{name},{dt:.3f},{r.kept.tolist()},{r.kept_samples.tolist()},"
            f"{int(r.verify_rounds.sum())}")
        rows.append((f"path_rules_{name}", dt * 1e6,
                     f"kept_last={int(r.kept[-1])} "
                     f"samples_last={int(r.kept_samples[-1])}"))
        traj["runs"].append({
            "rules": name,
            "path_seconds": dt,
            "lambdas": [float(v) for v in r.lambdas],
            "kept_features": [int(v) for v in r.kept],
            "kept_samples": [int(v) for v in r.kept_samples],
            "active": [int(v) for v in r.active],
            "solver_iters": [int(v) for v in r.solver_iters],
            "screen_seconds": float(r.screen_times.sum()),
            "verify_resolves": int(r.verify_rounds.sum()),
            "max_obj": float(np.max(np.abs(r.objectives))),
        })
    _rules_sweep(rows, log, traj, m=m, n=n, n_lambdas=n_lambdas)
    _dynamic_sweep(rows, log, traj, m=m, n=n, n_lambdas=n_lambdas,
                   lam_min_ratio=lam_min_ratio)
    _engine_sweep(rows, log, traj, m=m, n=n, n_lambdas=n_lambdas,
                  lam_min_ratio=lam_min_ratio)
    _storage_sweep(rows, log, traj, m=m, n=n, n_lambdas=n_lambdas,
                   lam_min_ratio=lam_min_ratio)
    _serve_sweep(rows, log, traj)
    _robustness_sweep(rows, log, traj, m=m, n=n, n_lambdas=n_lambdas,
                      lam_min_ratio=lam_min_ratio)
    _obs_sweep(rows, log, traj, m=m, n=n, n_lambdas=n_lambdas,
               lam_min_ratio=lam_min_ratio)
    TRAJECTORY_PATH.write_text(json.dumps(traj, indent=2))
    log(f"wrote trajectory file: {TRAJECTORY_PATH}")


def _rules_sweep(rows, log, traj, m=2000, n=400, n_lambdas=10,
                 lam_min_ratio=0.3, tol=1e-9, check=False):
    """Rule-*stack* sweep over the jit-threaded rule programs.

    Drives the host :class:`PathDriver` (for the per-rule telemetry and the
    screen/solve wall split) with each program-backed stack on a
    screen-effective planted instance and records ``traj["rules"]``:
    per-rule kept counts and mean bounds per step, total path wall, and the
    two headline comparisons — EDPP vs the feature VI sphere (EDPP must
    screen strictly more on this instance) and ``rules="auto"`` overhead vs
    the best single rule.  The ``["feature_vi", "edpp"]`` stack run gives a
    same-region per-step dominance check: both rules are evaluated from the
    identical anchor, so ``kept_edpp <= kept_vi`` must hold step by step.
    ``check=True`` (the ``--smoke`` CI lane) asserts equivalence and
    dominance on a tiny instance; strictness and the auto-overhead ratio
    are only meaningful on the full-size instance.
    """
    ds = make_sparse_classification(m=m, n=n, k_active=10, noise=0.1,
                                   seed=11)
    log(f"\n# rule-stack sweep (m={m}, n={n}, {n_lambdas} lambdas, "
        f"lam_min_ratio={lam_min_ratio})")
    log("rules,path_s,screen_s,total_kept")
    specs = ("none", "feature_vi", "dvi", "edpp", "auto",
             ["feature_vi", "edpp"])
    out = {"instance": {"m": m, "n": n, "n_lambdas": n_lambdas,
                        "lam_min_ratio": lam_min_ratio, "k_active": 10,
                        "seed": 11},
           "runs": {}}
    objs = {}
    for spec in specs:
        name = spec if isinstance(spec, str) else "+".join(spec)
        driver = PathDriver(rules=None if spec == "none" else spec, tol=tol)
        driver.run(ds.X, ds.y, n_lambdas=n_lambdas,
                   lam_min_ratio=lam_min_ratio)  # warm jit caches
        # Per-step wall is dominated by kept-independent work (dual-point
        # and objective evaluation), so rule-to-rule deltas are a few
        # percent -- min-of-5 keeps scheduler noise out of the ratios.
        dt = float("inf")
        for _ in range(1 if check else 5):
            t0 = time.perf_counter()
            r = driver.run(ds.X, ds.y, n_lambdas=n_lambdas,
                           lam_min_ratio=lam_min_ratio)
            dt = min(dt, time.perf_counter() - t0)
        per_rule_kept, per_rule_bound = {}, {}
        for step in r.extras.get("rule_telemetry", []):
            for rn, st in step.items():
                per_rule_kept.setdefault(rn, []).append(st["kept"])
                per_rule_bound.setdefault(rn, []).append(st["bound_mean"])
        screen_s = float(r.screen_times.sum())
        total_kept = int(r.kept[1:].sum())  # step 0 is the lam_max seed
        log(f"{name},{dt:.3f},{screen_s:.3f},{total_kept}")
        rows.append((f"rules_{name}", dt * 1e6,
                     f"kept_total={total_kept}"))
        out["runs"][name] = {
            "path_seconds": dt,
            "screen_seconds": screen_s,
            "solve_seconds": max(dt - screen_s, 0.0),
            "kept_features": [int(v) for v in r.kept],
            "total_kept": total_kept,
            "per_rule_kept": per_rule_kept,
            "per_rule_bound_mean": per_rule_bound,
            "max_obj": float(np.max(np.abs(r.objectives))),
        }
        objs[name] = np.asarray(r.objectives)

    # Safety: every stack must reach the same path objectives (screening is
    # a-priori safe -- it can only drop provably-inactive features).
    ref = objs["none"]
    scale = max(float(np.max(np.abs(ref))), 1e-12)
    for name, ob in objs.items():
        rel = float(np.max(np.abs(ob - ref))) / scale
        out["runs"][name]["relobj_vs_unscreened"] = rel
        assert rel < 1e-4, f"rules={name} diverged from unscreened: {rel}"

    # Same-region dominance: in the stacked run both rules see the same
    # anchor; EDPP is the tighter bound, so kept_edpp <= kept_vi holds
    # exactly, step by step.
    stack = out["runs"]["feature_vi+edpp"]
    vi_kept = stack["per_rule_kept"].get("feature_vi", [])
    ed_kept = stack["per_rule_kept"].get("edpp", [])
    assert len(vi_kept) == len(ed_kept) and vi_kept, "telemetry missing"
    assert all(e <= v for e, v in zip(ed_kept, vi_kept)), (
        "EDPP kept more than VI from the same anchor: "
        f"{ed_kept} vs {vi_kept}")
    out["edpp_dominates_vi_per_step"] = True

    vi_total = out["runs"]["feature_vi"]["total_kept"]
    ed_total = out["runs"]["edpp"]["total_kept"]
    out["edpp_total_kept"] = ed_total
    out["feature_vi_total_kept"] = vi_total
    out["edpp_strictly_tighter"] = ed_total < vi_total
    singles = {k: out["runs"][k]["path_seconds"]
               for k in ("feature_vi", "dvi", "edpp")}
    best = min(singles, key=singles.get)
    ratio = out["runs"]["auto"]["path_seconds"] / singles[best]
    out["auto_vs_best_single"] = {"best_single": best,
                                  "best_seconds": singles[best],
                                  "auto_seconds":
                                      out["runs"]["auto"]["path_seconds"],
                                  "ratio": ratio}
    log(f"edpp_total={ed_total} vi_total={vi_total} "
        f"auto/best({best})={ratio:.3f}")
    if not check:
        # Full-size acceptance: EDPP must screen strictly more than the VI
        # sphere on this planted instance, and the telemetry-driven auto
        # stack must stay within 10% of the best single rule.
        assert ed_total < vi_total, (
            f"EDPP did not tighten VI on the bench instance: "
            f"{ed_total} vs {vi_total}")
        assert ratio <= 1.10, (
            f"rules='auto' slower than best single rule by >10%: {ratio}")
    traj["rules"] = out
    return out


def _dynamic_sweep(rows, log, traj, m, n, n_lambdas, lam_min_ratio,
                   screen_every=25):
    """Dynamic vs sequential screening on the same instance/rule.

    The comparison the in-solver screen must win: for each path step, the
    per-segment kept-feature trajectory should drop *below* the step's
    initial (between-lambda) screen while the final objectives match the
    sequential path to 1e-6. Appends a ``dynamic`` section to the
    BENCH_screening.json trajectory file.
    """
    ds = make_sparse_classification(m=m, n=n, k_active=20, seed=11)
    log(f"\n# dynamic vs sequential (rules=feature_vi, screen_every={screen_every})")
    kw = dict(rules="feature_vi", tol=1e-10, max_iters=8000)
    seq_driver = PathDriver(**kw)
    dyn_driver = PathDriver(dynamic=True, screen_every=screen_every, **kw)
    grid = dict(n_lambdas=n_lambdas, lam_min_ratio=lam_min_ratio)
    for d in (seq_driver, dyn_driver):  # warm jit caches
        d.run(ds.X, ds.y, **grid)
    t0 = time.perf_counter()
    seq = seq_driver.run(ds.X, ds.y, **grid)
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    dyn = dyn_driver.run(ds.X, ds.y, **grid)
    t_dyn = time.perf_counter() - t0

    obj_diff = float(np.max(np.abs(seq.objectives - dyn.objectives)
                            / np.maximum(np.abs(seq.objectives), 1.0)))
    tele = dyn.extras["dynamic"]
    tightened = sum(
        1 for k, d in tele.items()
        if k > 0 and d["kept_per_segment"]
        and d["kept_per_segment"][-1] < dyn.kept[k]
    )
    log("step,initial_kept,kept_per_segment")
    for k in range(1, len(dyn.lambdas)):
        segs = tele.get(k, {}).get("kept_per_segment", [])
        log(f"{k},{int(dyn.kept[k])},{segs}")
    log(f"sequential_path_s={t_seq:.3f} dynamic_path_s={t_dyn:.3f} "
        f"max_rel_obj_diff={obj_diff:.2e} steps_tightened={tightened}")
    rows.append(("path_dynamic_feature_vi", t_dyn * 1e6,
                 f"tightened={tightened} obj_diff={obj_diff:.1e}"))
    traj["dynamic"] = {
        "rules": "feature_vi",
        "screen_every": screen_every,
        "sequential_path_seconds": t_seq,
        "dynamic_path_seconds": t_dyn,
        "max_rel_obj_diff": obj_diff,
        "steps_tightened_in_solver": tightened,
        "initial_kept": [int(v) for v in dyn.kept],
        "kept_per_segment": {
            str(k): d["kept_per_segment"] for k, d in sorted(tele.items())
        },
        "gap_per_segment": {
            str(k): d["gap_per_segment"] for k, d in sorted(tele.items())
        },
    }


def _engine_sweep(rows, log, traj, m=2000, n=400, n_lambdas=10,
                  lam_min_ratio=0.05, batch=8, tol=1e-9, max_iters=4000,
                  check=False):
    """Host driver vs the on-device scan engine, plus batched throughput.

    The comparison the scan engine must win on orchestration-bound
    instances: same grid, objectives matching to 1e-6, wall clock at least
    halved. ``scan+pallas`` is timed only where the Pallas kernels compile
    natively (TPU, unless globally disabled via ``REPRO_FISTA_PALLAS=0``);
    everywhere else they would run in interpret mode and the timing would
    measure the interpreter, not the kernel — solver equivalence under
    interpret is covered by tests/test_path_scan.py instead. Appends an
    ``engines`` section to the trajectory file.
    """
    from repro.kernels.ops import _default_interpret, fista_use_pallas

    ds = make_sparse_classification(m=m, n=n, k_active=20, seed=11)
    grid = dict(n_lambdas=n_lambdas, lam_min_ratio=lam_min_ratio)
    kw = dict(tol=tol, max_iters=max_iters)
    log(f"\n# path engines (m={m}, n={n}, {n_lambdas} lambdas, "
        f"rules=feature_vi)")

    def timed(fn, *a, **k):
        fn(*a, **k)  # warm jit caches
        t0 = time.perf_counter()
        out = fn(*a, **k)
        return out, time.perf_counter() - t0

    host_driver = PathDriver(rules="feature_vi", **kw)
    h, t_host = timed(host_driver.run, ds.X, ds.y, **grid)
    s, t_scan = timed(svm_path_scan, ds.X, ds.y, **grid, **kw)
    obj_diff = float(np.max(np.abs(h.objectives - s.objectives)
                            / np.maximum(np.abs(h.objectives), 1.0)))
    log(f"host_gather_s={t_host:.3f} scan_s={t_scan:.3f} "
        f"speedup={t_host / t_scan:.2f}x max_rel_obj_diff={obj_diff:.2e}")
    if check:
        assert obj_diff < 1e-6, f"engine mismatch: {obj_diff:.3e}"
    rows.append(("path_engine_host", t_host * 1e6, "rules=feature_vi"))
    rows.append(("path_engine_scan", t_scan * 1e6,
                 f"speedup={t_host / t_scan:.2f}x obj_diff={obj_diff:.1e}"))
    engines = {
        "instance": {"m": m, "n": n, "n_lambdas": n_lambdas,
                     "lam_min_ratio": lam_min_ratio, "seed": 11,
                     "tol": tol},
        "host_seconds": t_host,
        "scan_seconds": t_scan,
        "speedup_scan_over_host": t_host / t_scan,
        "max_rel_obj_diff": obj_diff,
        "scan_solver_iters": [int(v) for v in s.solver_iters],
        "scan_kept": [int(v) for v in s.kept],
    }

    # -- scan + pallas-fused solver (native-compile backends only) ---------
    if fista_use_pallas(None) and not _default_interpret():
        sp, t_pallas = timed(svm_path_scan, ds.X, ds.y, use_pallas=True,
                             **grid, **kw)
        pdiff = float(np.max(np.abs(sp.objectives - s.objectives)
                             / np.maximum(np.abs(s.objectives), 1.0)))
        log(f"scan_pallas_s={t_pallas:.3f} obj_diff_vs_scan={pdiff:.2e}")
        rows.append(("path_engine_scan_pallas", t_pallas * 1e6,
                     f"obj_diff={pdiff:.1e}"))
        engines["scan_pallas_seconds"] = t_pallas
        engines["scan_pallas_obj_diff"] = pdiff
    else:
        engines["scan_pallas"] = (
            "skipped: interpret-mode backend (timing would measure the "
            "Pallas interpreter); equivalence tested in tests/test_path_scan.py"
        )
        log("scan+pallas: skipped on interpret-mode backend")

    # -- compact reduction: same grid (informational) + the screen-effective
    # grid where FLOP-proportionality is the whole point --------------------
    c, t_comp = timed(svm_path_scan, ds.X, ds.y, reduce="compact", **grid,
                      **kw)
    cdiff = float(np.max(np.abs(c.objectives - h.objectives)
                         / np.maximum(np.abs(h.objectives), 1.0)))
    log(f"scan_compact_s={t_comp:.3f} speedup_vs_mask={t_scan / t_comp:.2f}x "
        f"obj_diff_vs_host={cdiff:.2e} caps={c.extras['caps'].tolist()}")
    rows.append(("path_engine_scan_compact", t_comp * 1e6,
                 f"speedup_vs_mask={t_scan / t_comp:.2f}x obj_diff={cdiff:.1e}"))
    if check:
        assert cdiff < 1e-6, f"compact/host mismatch: {cdiff:.3e}"
    engines["compact_same_grid"] = {
        "seconds": t_comp,
        "speedup_vs_mask": t_scan / t_comp,
        "max_rel_obj_diff_vs_host": cdiff,
        "caps": [int(v) for v in c.extras["caps"]],
    }
    engines["compact"] = _compact_section(rows, log, ds, m=m, n=n,
                                          n_lambdas=n_lambdas, tol=tol,
                                          max_iters=max_iters,
                                          reps=1 if check else 3)

    # -- sharded scan on a trivial mesh: the bitwise-port check ------------
    from repro.core import svm_path_scan_sharded
    from repro.core.distributed import svm_mesh

    shard = svm_path_scan_sharded(svm_mesh(1, 1), ds.X, ds.y, **grid, **kw)
    # baseline must force the XLA sweeps: the sharded engine has no Pallas
    # route, and on TPU (or REPRO_FISTA_PALLAS=1) the default-policy `s`
    # above solved with the fp32-accumulating kernels — ulp-different, which
    # would record a spurious bitwise regression
    s_xla = svm_path_scan(ds.X, ds.y, use_pallas=False, **grid, **kw)
    bitwise = bool(np.array_equal(shard.objectives, s_xla.objectives)
                   and np.array_equal(shard.extras["keep_masks"],
                                      s_xla.extras["keep_masks"]))
    log(f"scan_sharded(1,1): bitwise_vs_scan={bitwise}")
    if check:
        assert bitwise, "sharded scan (1,1 mesh) diverged from local scan"
    engines["sharded_1x1_bitwise"] = bitwise

    # -- batched throughput: B grids on one program ------------------------
    lam_max_val = h.extras["lam_max"]
    ratios = np.linspace(0.8 * lam_min_ratio, 1.2 * lam_min_ratio, batch)
    grids = np.stack([np.geomspace(lam_max_val, lam_max_val * r, n_lambdas)
                      for r in ratios])
    b_res, t_batch = timed(svm_path_batched, ds.X, ds.y, lambdas=grids, **kw)
    pps = batch / t_batch
    log(f"batched B={batch}: {t_batch:.3f}s = {pps:.2f} paths/s "
        f"(single-scan {1.0 / t_scan:.2f} paths/s)")
    rows.append(("path_engine_batched", t_batch * 1e6,
                 f"B={batch} paths_per_s={pps:.2f}"))
    engines["batched"] = {
        "batch": batch,
        "seconds": t_batch,
        "paths_per_second": pps,
        "single_scan_paths_per_second": 1.0 / t_scan,
        "note": ("vmap lowers the restart lax.cond to a select (both "
                 "branches execute) and while-loops run to the slowest "
                 "batch element — the batching win is launch/dispatch "
                 "amortization, which shows on accelerators rather than "
                 "on an already-saturated CPU"),
    }
    engines["batched_compact"] = _batched_compact_section(
        rows, log, ds, m=m, n=n, n_lambdas=n_lambdas, tol=tol,
        max_iters=max_iters, batch=2 if check else 4,
        reps=1 if check else 3, check=check)
    traj["engines"] = engines
    return engines


def _batched_compact_section(rows, log, ds, m, n, n_lambdas, tol, max_iters,
                             lam_min_ratio=0.3, batch=4, reps=3, check=False):
    """Batched compact (shared per-step capacity) vs batched mask.

    The comparison compact-under-vmap must win: on the screen-effective grid
    (early steps certify small active sets) a batch of grids solved with
    ``reduce="compact"`` shares ONE capacity per lambda step — the scalar
    batch-max keep count picks the bucket, so exactly one solver body runs
    per step instead of the run-every-branch select a per-element
    ``lax.switch`` would lower to. The shared-cap schedule is recorded
    (identical across batch elements by construction) along with the
    objective agreement against the batched mask engine.
    """
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    ratios = np.linspace(0.8 * lam_min_ratio, 1.2 * lam_min_ratio, batch)
    grids = np.stack([np.geomspace(lmax, lmax * r, n_lambdas)
                      for r in ratios])
    kw = dict(lambdas=grids, tol=tol, max_iters=max_iters)

    def med(fn, *a, **k):
        out = fn(*a, **k)  # warm jit caches
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn(*a, **k)
            ts.append(time.perf_counter() - t0)
        return out, float(np.median(ts))

    mask, t_mask = med(svm_path_batched, ds.X, ds.y, **kw)
    comp, t_comp = med(svm_path_batched, ds.X, ds.y, reduce="compact", **kw)
    obj_diff = max(
        float(np.max(np.abs(comp[i].objectives - mask[i].objectives)
                     / np.maximum(np.abs(mask[i].objectives), 1.0)))
        for i in range(batch))
    speedup = t_mask / t_comp
    caps = comp[0].extras["caps"]
    log(f"\n# batched compact vs batched mask (B={batch}, m={m}, n={n}, "
        f"lam_min_ratio={lam_min_ratio} screen-effective grid)")
    log(f"batched_mask_s={t_mask:.3f} batched_compact_s={t_comp:.3f} "
        f"speedup={speedup:.2f}x obj_diff={obj_diff:.2e} "
        f"shared_caps={caps.tolist()}")
    if check:
        # vmap lowering (GEMV -> GEMM) reassociates fp32 accumulation, so
        # the two reductions agree to solver resolution, not bitwise
        assert obj_diff < 1e-4, f"batched compact/mask mismatch: {obj_diff:.3e}"
        for r in comp[1:]:
            np.testing.assert_array_equal(caps, r.extras["caps"])
        assert int(caps[0]) < m, "screen-effective grid never compacted"
    rows.append(("path_batched_compact", t_comp * 1e6,
                 f"B={batch} speedup_vs_mask={speedup:.2f}x "
                 f"obj_diff={obj_diff:.1e}"))
    return {
        "instance": {"m": m, "n": n, "n_lambdas": n_lambdas,
                     "lam_min_ratio": lam_min_ratio, "batch": batch,
                     "tol": tol},
        "batched_mask_seconds": t_mask,
        "batched_compact_seconds": t_comp,
        "speedup_compact_over_mask": speedup,
        "max_rel_obj_diff_vs_mask": obj_diff,
        "shared_caps": [int(v) for v in caps],
        "kept": [[int(v) for v in r.kept] for r in comp],
        "note": ("the shared per-step capacity is the batch-max keep count "
                 "rounded up the bucket ladder; one overflowing element "
                 "demotes that step to mask for the whole batch — "
                 "correctness never depends on the schedule"),
    }


def _compact_section(rows, log, ds, m, n, n_lambdas, tol, max_iters,
                     lam_min_ratio=0.3, reps=5):
    """Compact vs mask where screening certifies small active sets.

    The grid is chosen so the early path steps keep a small fraction of the
    features (<=15% on the stock 2000x400 instance) — the regime the paper's
    value proposition lives in, and the one the compact reduction must win:
    per-step solver FLOPs proportional to the certified active set. Walls
    are medians over ``reps`` runs — the engine-level difference is well
    above scheduler noise, but single runs on a shared CPU are not (the
    ``meta`` stamp records where the numbers came from).
    """
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    grid = np.geomspace(lmax, lmax * lam_min_ratio, n_lambdas)
    kw = dict(lambdas=grid, tol=tol, max_iters=max_iters)

    def med(fn, *a, **k):
        out = fn(*a, **k)  # warm jit caches
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn(*a, **k)
            ts.append(time.perf_counter() - t0)
        return out, float(np.median(ts))

    host, _ = med(PathDriver(rules="feature_vi", tol=tol,
                             max_iters=max_iters).run, ds.X, ds.y,
                  lambdas=grid)
    mask, t_mask = med(svm_path_scan, ds.X, ds.y, **kw)
    comp, t_comp = med(svm_path_scan, ds.X, ds.y, reduce="compact", **kw)
    obj_diff = float(np.max(np.abs(comp.objectives - host.objectives)
                            / np.maximum(np.abs(host.objectives), 1.0)))
    speedup = t_mask / t_comp
    kept_frac_early = float(np.max(comp.kept[: n_lambdas // 2]) / m)
    log(f"\n# compact vs mask (m={m}, n={n}, {n_lambdas} lambdas, "
        f"lam_min_ratio={lam_min_ratio}: early steps keep "
        f"<= {100 * kept_frac_early:.1f}% of features)")
    log(f"mask_s={t_mask:.3f} compact_s={t_comp:.3f} speedup={speedup:.2f}x "
        f"obj_diff_vs_host={obj_diff:.2e}")
    log("step,kept,cap,iters,resurrected")
    for k in range(n_lambdas):
        log(f"{k},{int(comp.kept[k])},{int(comp.extras['caps'][k])},"
            f"{int(comp.solver_iters[k])},{int(comp.extras['resurrected'][k])}")
    rows.append(("path_compact_screen_effective", t_comp * 1e6,
                 f"speedup={speedup:.2f}x obj_diff={obj_diff:.1e}"))
    return {
        "instance": {"m": m, "n": n, "n_lambdas": n_lambdas,
                     "lam_min_ratio": lam_min_ratio, "tol": tol},
        "mask_seconds": t_mask,
        "compact_seconds": t_comp,
        "speedup_compact_over_mask": speedup,
        "max_rel_obj_diff_vs_host": obj_diff,
        "max_early_kept_fraction": kept_frac_early,
        "kept": [int(v) for v in comp.kept],
        "caps": [int(v) for v in comp.extras["caps"]],
        "solver_iters": [int(v) for v in comp.solver_iters],
        "resurrected": [int(v) for v in comp.extras["resurrected"]],
        "mask_solver_iters": [int(v) for v in mask.solver_iters],
    }


def _storage_sweep(rows, log, traj, m=2000, n=400, n_lambdas=10,
                   lam_min_ratio=0.05, density=0.05, chunk_m=None,
                   tol=1e-9, max_iters=8000, check=False):
    """Dense vs chunked vs CSR storage on a sparse (density<=5%) instance.

    The out-of-core engine's acceptance sweep: the chunked path must match
    the in-core host driver's objectives to <=1e-6 while never holding more
    than one chunk of X on the device (``max_put_rows`` is recorded as
    proof), and the CSR/BCOO route must agree to the fp32 convergence floor
    (its reductions reassociate per nnz; <=1e-5). Writes
    ``BENCH_screening.json["storage"]``.
    """
    from repro.core import PathDriver, lipschitz_estimate
    from repro.sparse import FeatureChunked, lipschitz_estimate_stream

    chunk_m = chunk_m or max(m // 8, 64)
    ds = make_sparse_classification(m=m, n=n, k_active=20, density=density,
                                    seed=13)
    # one shared Lipschitz bound for every storage engine: the bound is a
    # property of the matrix, not of its storage, and near fp32 plateau
    # ties a 1-ulp step-size difference moves the stopping point by ~2e-6
    # relative — sharing L isolates what this sweep measures (storage).
    # The self-estimated streamed L is recorded alongside as the honest
    # fully-out-of-core number.
    L = lipschitz_estimate(jnp.asarray(ds.X))
    kw = dict(rules="feature_vi", tol=tol, max_iters=max_iters, L=L)
    grid = dict(n_lambdas=n_lambdas, lam_min_ratio=lam_min_ratio)
    log(f"\n# storage engines (m={m}, n={n}, density={density}, "
        f"chunk_m={chunk_m}, {n_lambdas} lambdas)")

    def timed(fn, *a, **k):
        fn(*a, **k)  # warm jit caches
        t0 = time.perf_counter()
        out = fn(*a, **k)
        return out, time.perf_counter() - t0

    def reset_stats(fc):
        # the recorded counters must describe exactly ONE measured path run,
        # not the jit warm-up that preceded it
        fc.stats.update(puts=0, max_put_rows=0, bcoo_puts=0,
                        chunks_streamed=0, chunks_skipped=0, bytes_put=0)

    host, t_dense = timed(PathDriver(**kw).run, ds.X, ds.y, **grid)

    # chunk_skip=False keeps this row the pure full-stream storage baseline;
    # the gated lane is measured separately below on a planted instance
    fc_d = FeatureChunked.from_dense(ds.X, chunk_m=chunk_m)
    PathDriver(chunk_skip=False, **kw).run(fc_d, ds.y, **grid)  # warm jit
    reset_stats(fc_d)
    t0 = time.perf_counter()
    chunked = PathDriver(chunk_skip=False, **kw).run(fc_d, ds.y, **grid)
    t_chunk = time.perf_counter() - t0
    chunked_stats = dict(fc_d.stats)
    cdiff = float(np.max(np.abs(chunked.objectives - host.objectives)
                         / np.maximum(np.abs(host.objectives), 1.0)))

    fc_c = FeatureChunked.from_csr(ds.csr, chunk_m=chunk_m)
    PathDriver(**kw).run(fc_c, ds.y, **grid)  # warm jit caches
    reset_stats(fc_c)
    t0 = time.perf_counter()
    csr = PathDriver(**kw).run(fc_c, ds.y, **grid)
    t_csr = time.perf_counter() - t0
    csr_stats = dict(fc_c.stats)
    sdiff = float(np.max(np.abs(csr.objectives - host.objectives)
                         / np.maximum(np.abs(host.objectives), 1.0)))

    # the fully-self-contained run: streamed L estimate, no in-core input
    # (fresh container so its transfers don't pollute the recorded stats)
    fc_own = FeatureChunked.from_dense(ds.X, chunk_m=chunk_m)
    L_stream = lipschitz_estimate_stream(fc_own)
    own = PathDriver(rules="feature_vi", tol=tol, max_iters=max_iters).run(
        fc_own, ds.y, **grid)
    odiff = float(np.max(np.abs(own.objectives - host.objectives)
                         / np.maximum(np.abs(host.objectives), 1.0)))

    # -- chunk-skipping lane: planted low-density instance -----------------
    # weak noise tail (tiny feature norms past the head block) so whole
    # tail chunks screen out early and *stay* dead — the geometry chunk
    # gating is built for. Skip vs full-stream twin on identical data:
    # the path must be bitwise equal while transferring strictly fewer
    # chunks, and the per-step live set must shrink below T * n_chunks.
    Xp = np.array(ds.X, copy=True)
    head = max(chunk_m, m // 5)
    Xp[head:] *= 0.05
    Lp = lipschitz_estimate(jnp.asarray(Xp))
    kwp = dict(rules="feature_vi", tol=tol, max_iters=max_iters, L=Lp)

    fc_skip = FeatureChunked.from_dense(Xp, chunk_m=chunk_m)
    PathDriver(chunk_skip=True, **kwp).run(fc_skip, ds.y, **grid)  # warm jit
    reset_stats(fc_skip)
    t0 = time.perf_counter()
    skip = PathDriver(chunk_skip=True, **kwp).run(fc_skip, ds.y, **grid)
    t_skip = time.perf_counter() - t0
    skip_stats = dict(fc_skip.stats)

    fc_fullp = FeatureChunked.from_dense(Xp, chunk_m=chunk_m)
    PathDriver(chunk_skip=False, **kwp).run(fc_fullp, ds.y, **grid)
    reset_stats(fc_fullp)
    t0 = time.perf_counter()
    fullp = PathDriver(chunk_skip=False, **kwp).run(fc_fullp, ds.y, **grid)
    t_fullp = time.perf_counter() - t0
    fullp_stats = dict(fc_fullp.stats)
    skip_bitwise = bool(
        np.array_equal(skip.objectives, fullp.objectives)
        and np.array_equal(skip.weights, fullp.weights))
    live_total = int(np.sum(skip.extras["live_chunks"]))
    live_cap = len(skip.lambdas) * fc_skip.n_chunks

    log(f"dense_s={t_dense:.3f} chunked_s={t_chunk:.3f} csr_s={t_csr:.3f}")
    log(f"obj_diff chunked={cdiff:.2e} csr={sdiff:.2e} "
        f"self_L_chunked={odiff:.2e} "
        f"(L dense={float(L):.6g} streamed={float(L_stream):.6g})")
    log(f"max_device_rows: chunked={chunked_stats['max_put_rows']} "
        f"csr={csr_stats['max_put_rows']} (m={m}) "
        f"bcoo_transfers={csr_stats['bcoo_puts']}")
    log(f"chunk_skip (planted): streamed={skip_stats['chunks_streamed']} "
        f"skipped={skip_stats['chunks_skipped']} "
        f"vs full={fullp_stats['chunks_streamed']} "
        f"live={live_total}/{live_cap} bitwise={skip_bitwise} "
        f"({t_skip:.3f}s vs {t_fullp:.3f}s)")
    if check:
        assert cdiff < 1e-6, f"chunked/host mismatch: {cdiff:.3e}"
        assert sdiff < 1e-5, f"csr/host mismatch: {sdiff:.3e}"
        assert odiff < 1e-5, f"self-L chunked/host mismatch: {odiff:.3e}"
        assert chunked_stats["max_put_rows"] <= chunk_m
        assert skip_stats["chunks_skipped"] > 0, skip_stats
        assert (skip_stats["chunks_streamed"]
                < fullp_stats["chunks_streamed"]), (skip_stats, fullp_stats)
        assert live_total < live_cap, (live_total, live_cap)
        assert skip_bitwise, "chunk-skip diverged from its full-stream twin"
    rows.append(("path_storage_dense", t_dense * 1e6, f"density={density}"))
    rows.append(("path_storage_chunked", t_chunk * 1e6,
                 f"obj_diff={cdiff:.1e} chunk_m={chunk_m}"))
    rows.append(("path_storage_csr", t_csr * 1e6,
                 f"obj_diff={sdiff:.1e} bcoo_puts={csr_stats['bcoo_puts']}"))
    rows.append(("path_storage_chunked_skip", t_skip * 1e6,
                 f"skipped={skip_stats['chunks_skipped']} "
                 f"live={live_total}/{live_cap}"))
    traj["storage"] = {
        "instance": {"m": m, "n": n, "n_lambdas": n_lambdas,
                     "lam_min_ratio": lam_min_ratio, "density": density,
                     "chunk_m": chunk_m, "seed": 13, "tol": tol},
        "dense_seconds": t_dense,
        "chunked_seconds": t_chunk,
        "csr_seconds": t_csr,
        "max_rel_obj_diff_chunked_vs_dense": cdiff,
        "max_rel_obj_diff_csr_vs_dense": sdiff,
        "max_rel_obj_diff_chunked_self_L": odiff,
        "lipschitz_dense": float(L),
        "lipschitz_streamed": float(L_stream),
        "kept_dense": [int(v) for v in host.kept],
        "kept_chunked": [int(v) for v in chunked.kept],
        "kept_csr": [int(v) for v in csr.kept],
        "chunked_stream_stats": chunked_stats,
        "csr_stream_stats": csr_stats,
        "chunk_skip": {
            "planted_head_rows": int(head),
            "seconds": t_skip,
            "full_stream_seconds": t_fullp,
            "stream_stats": skip_stats,
            "full_stream_stats": fullp_stats,
            "live_chunks": [int(v) for v in skip.extras["live_chunks"]],
            "live_total": live_total,
            "live_cap_T_x_nchunks": live_cap,
            "bitwise_vs_full_stream": skip_bitwise,
        },
        "note": ("chunked max_put_rows == chunk_m is the out-of-core "
                 "contract: the device never held more than one feature "
                 "chunk of X (plus the gathered active set); the CSR lane "
                 "streams BCOO chunks so screening FLOPs track nnz; the "
                 "chunk_skip block is the gated lane on the planted "
                 "weak-tail instance — bitwise equal to its full-stream "
                 "twin with strictly fewer transfers"),
    }
    return traj["storage"]


def _serve_sweep(rows, log, traj, n_jobs=8, m=300, n=120, slots=4,
                 tol=1e-10, max_iters=8000, seed=17, check=False):
    """Continuous-batching path server vs sequential ``svm_path``.

    The serving acceptance sweep: a mixed-grid workload (ragged lambda-path
    lengths) drains through the warm server and must (a) reproduce every
    job's sequential ``svm_path`` objectives and (b) sustain more jobs/sec
    than sequentially looping the default (host) engine over the same jobs.
    The warm-up pass is separate and its compile cost is reported — the
    steady-state number is the one a long-running server actually sustains.
    Sequential scan-engine walls are recorded both cold (each distinct grid
    length retraces the whole-path program — the ragged-workload reality the
    server's bucket-keyed step cache avoids) and warm (tiny instances fit
    the scan engine's sweet spot; the server's win there is multi-tenancy +
    bounded compiles, not raw single-path speed). Writes
    ``BENCH_screening.json["serve"]``.
    """
    from repro.core import svm_path
    from repro.launch.path_server import PathServer, demo_jobs

    log(f"\n# path server (jobs={n_jobs}, slots={slots}, m={m}, n={n}, "
        f"ragged grids)")
    server = PathServer(slots=slots, reduce="compact", tol=tol,
                        max_iters=max_iters)
    # warm-up workload in the same shape bucket: the measured pass below
    # then reports steady-state throughput on a warm program cache
    t0 = time.perf_counter()
    server.serve(demo_jobs(max(2, slots), m=m, n=n, seed=seed + 100),
                 log=lambda *a, **k: None)
    t_warmup = time.perf_counter() - t0
    jobs = demo_jobs(n_jobs, m=m, n=n, seed=seed)
    results = server.serve(jobs, log=lambda *a, **k: None)
    serve_info = dict(server.last_serve)

    seq_kw = dict(tol=tol, max_iters=max_iters)
    svm_path(jobs[0].X, jobs[0].y, lambdas=jobs[0].lambdas, **seq_kw)  # warm
    t0 = time.perf_counter()
    seq = [svm_path(j.X, j.y, lambdas=j.lambdas, **seq_kw) for j in jobs]
    t_host = time.perf_counter() - t0

    scan_kw = dict(engine="scan", reduce="compact", **seq_kw)
    t0 = time.perf_counter()
    for j in jobs:  # cold: one whole-path compile per distinct grid length
        svm_path(j.X, j.y, lambdas=j.lambdas, **scan_kw)
    t_scan_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for j in jobs:
        svm_path(j.X, j.y, lambdas=j.lambdas, **scan_kw)
    t_scan_warm = time.perf_counter() - t0

    obj_diff = max(
        float(np.max(np.abs(r.objectives - s.objectives)
                     / np.maximum(np.abs(s.objectives), 1.0)))
        for r, s in zip(results, seq))
    st = server.cache_stats()
    jps = serve_info["jobs_per_s"]
    log(f"server_warm_jobs_per_s={jps:.2f} "
        f"sequential_host={n_jobs / t_host:.2f} "
        f"scan_cold={n_jobs / t_scan_cold:.2f} "
        f"scan_warm={n_jobs / t_scan_warm:.2f}")
    log(f"occupancy={serve_info['slot_occupancy']:.2f} "
        f"latency_p50_s={serve_info['latency_p50_s']:.3f} "
        f"p95_s={serve_info['latency_p95_s']:.3f} "
        f"cache: programs={st['programs']} hits={st['hits']} "
        f"misses={st['misses']} retraces={st['retraces']}")
    log(f"max_rel_obj_diff_vs_sequential={obj_diff:.2e} "
        f"(warmup_pass_s={t_warmup:.1f} incl. compiles)")
    if check:
        # correctness + cache discipline gate; throughput is recorded but
        # not asserted (single CI runs on shared CPUs are scheduler noise)
        assert obj_diff < 5e-6, f"server/sequential mismatch: {obj_diff:.3e}"
        assert st["retraces"] == 0, st
        assert st["hits"] > 0, st
        assert st["programs"] == st["misses"], st
        grid_lens = {len(j.lambdas) for j in jobs}
        assert len(grid_lens) > 1, "workload not ragged — sweep proves nothing"
    rows.append(("path_serve", n_jobs / jps * 1e6 if jps else 0.0,
                 f"jobs={n_jobs} jobs_per_s={jps:.2f} "
                 f"vs_host={n_jobs / t_host:.2f} obj_diff={obj_diff:.1e}"))
    traj["serve"] = {
        "instance": {"n_jobs": n_jobs, "slots": slots, "m": m, "n": n,
                     "seed": seed, "tol": tol, "max_iters": max_iters,
                     "grid_lengths": [len(j.lambdas) for j in jobs]},
        "jobs_per_s": jps,
        "slot_occupancy": serve_info["slot_occupancy"],
        "latency_p50_s": serve_info["latency_p50_s"],
        "latency_p95_s": serve_info["latency_p95_s"],
        "steps": serve_info["steps"],
        "warmup_pass_seconds": t_warmup,
        "cache": {k: st[k] for k in
                  ("programs", "hits", "misses", "retraces")},
        "sequential_host_jobs_per_s": n_jobs / t_host,
        "sequential_scan_cold_jobs_per_s": n_jobs / t_scan_cold,
        "sequential_scan_warm_jobs_per_s": n_jobs / t_scan_warm,
        "speedup_vs_sequential_host": jps * t_host / n_jobs,
        "max_rel_obj_diff_vs_sequential": obj_diff,
        "note": ("the server's win is bounded compiles on ragged grid "
                 "lengths (a handful of bucket-keyed step programs vs one "
                 "whole-path retrace per distinct length) plus "
                 "multi-tenant slot refill; a warm single-path scan on a "
                 "tiny CPU instance is faster per path — that baseline is "
                 "recorded above, not hidden"),
    }
    return traj["serve"]


def _robustness_sweep(rows, log, traj, m=2000, n=400, n_lambdas=10,
                      lam_min_ratio=0.05, tol=1e-9, max_iters=4000,
                      repeats=3, poison_step=2, check=False):
    """Health-guard cost + poison recovery. Writes
    ``BENCH_screening.json["robustness"]``.

    Two questions the robustness layer must answer with numbers: (a) what
    do the always-on solver guards cost on a clean path (guards-on vs
    ``REPRO_SOLVER_GUARDS=0`` walls, min over ``repeats`` warm runs — the
    ``--smoke`` lane asserts < 5%), and (b) how far does a recovered path
    land from a clean one after a mid-path poisoned step (per-step and
    final relative objective diffs; the poisoned step itself refuses its
    certificate and keeps everything, later steps re-converge).
    """
    import os

    from repro.core.solver import HEALTH_SCREEN_REFUSED
    from repro.testing import poison_path_step

    ds = make_sparse_classification(m=m, n=n, k_active=20, seed=11)
    kw = dict(rules="feature_vi", tol=tol, max_iters=max_iters)
    run_kw = dict(n_lambdas=n_lambdas, lam_min_ratio=lam_min_ratio)
    log(f"\n# robustness (m={m}, n={n}, {n_lambdas} lambdas, "
        f"min of {repeats} warm walls)")

    def timed_path(guards_env):
        prev = os.environ.get("REPRO_SOLVER_GUARDS")
        os.environ["REPRO_SOLVER_GUARDS"] = guards_env
        try:
            drv = PathDriver(**kw)
            drv.run(ds.X, ds.y, **run_kw)  # warm the jit caches
            walls = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                r = drv.run(ds.X, ds.y, **run_kw)
                walls.append(time.perf_counter() - t0)
        finally:
            if prev is None:
                os.environ.pop("REPRO_SOLVER_GUARDS", None)
            else:
                os.environ["REPRO_SOLVER_GUARDS"] = prev
        return min(walls), r

    t_on, r_on = timed_path("1")
    t_off, r_off = timed_path("0")
    overhead = (t_on - t_off) / t_off
    clean_equal = bool(np.allclose(np.asarray(r_on.objectives),
                                   np.asarray(r_off.objectives),
                                   rtol=0, atol=0))
    log(f"guards_on_s={t_on:.3f} guards_off_s={t_off:.3f} "
        f"overhead={overhead * 100:.2f}% bitwise_clean={clean_equal}")

    # poison recovery: corrupt one accepted step, measure how the refused
    # certificate + keep-all + sanitized warm start propagate
    clean = PathDriver(**kw).run(ds.X, ds.y, **run_kw)
    drv = PathDriver(**kw)
    inj = poison_path_step(poison_step)
    drv._fault_injector = inj
    poisoned = drv.run(ds.X, ds.y, **run_kw)
    health = np.asarray(poisoned.extras["health"])
    co = np.asarray(clean.objectives)
    po = np.asarray(poisoned.objectives)
    rel = np.abs(po - co) / np.maximum(np.abs(co), 1.0)
    refused = [int(k) for k in np.nonzero(health & HEALTH_SCREEN_REFUSED)[0]]
    superset = bool(np.all(np.asarray(poisoned.kept)
                           >= np.asarray(clean.kept)))
    log(f"poisoned_step={poison_step} refused_steps={refused} "
        f"kept_superset={superset} max_step_rel_obj_diff={rel.max():.2e} "
        f"final_rel_obj_diff={rel[-1]:.2e}")
    if check:
        assert inj.state["fired"]
        assert overhead < 0.05, (
            f"guard overhead {overhead * 100:.2f}% >= 5% "
            f"(on={t_on:.3f}s off={t_off:.3f}s)")
        assert clean_equal, "guards changed a clean path's objectives"
        assert refused, "poison never tripped a certificate refusal"
        assert superset, "poisoned run discarded more than the clean run"
        assert rel[-1] < 1e-4, f"no recovery: final diff {rel[-1]:.3e}"
    rows.append(("robustness_guards", t_on * 1e6,
                 f"overhead={overhead * 100:.2f}% "
                 f"final_poison_diff={rel[-1]:.1e}"))
    traj["robustness"] = {
        "instance": {"m": m, "n": n, "n_lambdas": n_lambdas,
                     "lam_min_ratio": lam_min_ratio, "seed": 11,
                     "tol": tol, "max_iters": max_iters,
                     "repeats": repeats},
        "guards_on_path_seconds": t_on,
        "guards_off_path_seconds": t_off,
        "guard_overhead_fraction": overhead,
        "clean_path_bitwise_equal": clean_equal,
        "poison": {
            "step": poison_step,
            "refused_steps": refused,
            "health": [int(v) for v in health],
            "kept_clean": [int(v) for v in clean.kept],
            "kept_poisoned": [int(v) for v in poisoned.kept],
            "kept_superset": superset,
            "per_step_rel_obj_diff": [float(v) for v in rel],
            "final_rel_obj_diff": float(rel[-1]),
        },
    }
    return traj["robustness"]


def _obs_sweep(rows, log, traj, m=2000, n=400, n_lambdas=10,
               lam_min_ratio=0.05, tol=1e-9, max_iters=4000,
               repeats=3, check=False):
    """Observability cost on the stock host path. Writes
    ``BENCH_screening.json["obs"]``.

    The obs layer's contract is "always-on instrumentation you never have
    to strip": with tracing *off* the span hooks must be free (the path's
    numerics are untouched either way — asserted bitwise), and with
    tracing *on* the recorder must stay under 3% of path wall (min over
    ``repeats`` warm runs; the ``--smoke`` lane asserts it). Also checks
    every run hands back the uniform ``PathTrace`` artifact.
    """
    from repro.obs import trace as obs_trace

    ds = make_sparse_classification(m=m, n=n, k_active=20, seed=11)
    kw = dict(rules="feature_vi", tol=tol, max_iters=max_iters)
    run_kw = dict(n_lambdas=n_lambdas, lam_min_ratio=lam_min_ratio)
    log(f"\n# observability (m={m}, n={n}, {n_lambdas} lambdas, "
        f"min of {repeats} warm walls)")

    was = obs_trace.enabled()
    drv = PathDriver(**kw)
    drv.run(ds.X, ds.y, **run_kw)  # warm the jit caches (tracing-neutral)

    def trial(tracing):
        (obs_trace.enable if tracing else obs_trace.disable)()
        obs_trace.get_tracer().clear()
        t0 = time.perf_counter()
        r = drv.run(ds.X, ds.y, **run_kw)
        dt = time.perf_counter() - t0
        n = len(obs_trace.get_tracer().events)
        obs_trace.get_tracer().clear()
        return dt, r, n

    # alternate off/on trials so machine drift hits both columns equally
    try:
        offs, ons = [], []
        for _ in range(repeats):
            offs.append(trial(False))
            ons.append(trial(True))
    finally:
        (obs_trace.enable if was else obs_trace.disable)()
    t_off, r_off, _ = min(offs, key=lambda x: x[0])
    t_on, r_on, n_events = min(ons, key=lambda x: x[0])
    overhead = (t_on - t_off) / t_off
    bitwise = bool(np.allclose(np.asarray(r_on.objectives),
                               np.asarray(r_off.objectives),
                               rtol=0, atol=0))
    pt = r_on.extras["path_trace"]
    log(f"trace_on_s={t_on:.3f} trace_off_s={t_off:.3f} "
        f"overhead={overhead * 100:.2f}% events_per_path={n_events} "
        f"bitwise_off_vs_on={bitwise}")
    if check:
        assert overhead < 0.03, (
            f"tracing overhead {overhead * 100:.2f}% >= 3% "
            f"(on={t_on:.3f}s off={t_off:.3f}s)")
        assert bitwise, "tracing changed the path's objectives"
        assert pt.engine == "host" and len(pt.steps) == n_lambdas
        # screen/solve/certify/step per solved step (k=0 is the analytic
        # lambda_max point — no solve, no spans)
        assert n_events >= 4 * (n_lambdas - 1)
    rows.append(("obs_tracing", t_on * 1e6,
                 f"overhead={overhead * 100:.2f}% events={n_events}"))
    traj["obs"] = {
        "instance": {"m": m, "n": n, "n_lambdas": n_lambdas,
                     "lam_min_ratio": lam_min_ratio, "seed": 11,
                     "tol": tol, "max_iters": max_iters,
                     "repeats": repeats},
        "trace_on_path_seconds": t_on,
        "trace_off_path_seconds": t_off,
        "trace_overhead_fraction": overhead,
        "trace_events_per_path": int(n_events),
        "objectives_bitwise_equal": bitwise,
        "path_trace": pt.to_dict(),
    }
    return traj["obs"]


def run(log=print, smoke=False):
    rows = []
    if smoke:
        # CI lane: seconds-scale engine + storage equivalence smoke on tiny
        # instances; never touches the trajectory file.
        _engine_sweep(rows, log, {}, m=300, n=120, n_lambdas=5,
                      lam_min_ratio=0.2, batch=2, tol=1e-10, max_iters=4000,
                      check=True)
        _storage_sweep(rows, log, {}, m=320, n=120, n_lambdas=5,
                       lam_min_ratio=0.2, density=0.05, chunk_m=64,
                       tol=1e-10, max_iters=8000, check=True)
        _serve_sweep(rows, log, {}, n_jobs=4, m=120, n=60, slots=2,
                     tol=1e-10, max_iters=8000, check=True)
        _rules_sweep(rows, log, {}, m=300, n=120, n_lambdas=5,
                     lam_min_ratio=0.2, tol=1e-10, check=True)
        _robustness_sweep(rows, log, {}, m=300, n=120, n_lambdas=5,
                          lam_min_ratio=0.2, tol=1e-10, max_iters=4000,
                          check=True)
        _obs_sweep(rows, log, {}, m=300, n=120, n_lambdas=5,
                   lam_min_ratio=0.2, tol=1e-10, max_iters=4000,
                   repeats=5, check=True)
        return rows
    _rate_tables(rows, log)
    _rule_sweep(rows, log)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-instance engine check (CI); no trajectory write")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
