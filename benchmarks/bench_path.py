"""Paper headline table: end-to-end regularization-path speedup from screening.

Times the full sequential path with screening ON vs OFF (steady-state: jit
caches warmed by a first pass) and reports per-step kept counts — the paper's
"speedup" axis.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import svm_path
from repro.data import make_sparse_classification


def run(log=print, m=6000, n=400, n_lambdas=16, ratio=0.25):
    """Fine lambda grid (the paper's regime: many close-by lambdas) so the
    sequential rule stays strong along the path."""
    ds = make_sparse_classification(m=m, n=n, k_active=20, seed=11)
    kw = dict(n_lambdas=n_lambdas, lam_min_ratio=ratio, tol=1e-9,
              max_iters=8000)
    # warm the jit caches (bucketed shapes) with a throwaway pass
    svm_path(ds.X, ds.y, screening=True, **kw)
    svm_path(ds.X, ds.y, screening=False, **kw)
    svm_path(ds.X, ds.y, rules="composite", **kw)

    t0 = time.perf_counter()
    on = svm_path(ds.X, ds.y, screening=True, **kw)
    t_on = time.perf_counter() - t0
    t0 = time.perf_counter()
    comp = svm_path(ds.X, ds.y, rules="composite", **kw)
    t_comp = time.perf_counter() - t0
    t0 = time.perf_counter()
    off = svm_path(ds.X, ds.y, screening=False, **kw)
    t_off = time.perf_counter() - t0

    obj_dev = float(np.max(np.abs(on.objectives - off.objectives)
                           / np.maximum(np.abs(off.objectives), 1e-9)))
    comp_dev = float(np.max(np.abs(comp.objectives - off.objectives)
                            / np.maximum(np.abs(off.objectives), 1e-9)))
    log(f"# path speedup (m={m}, n={n}, {n_lambdas} lambdas)")
    log(f"kept per step       : {on.kept.tolist()}")
    log(f"active per step     : {on.active.tolist()}")
    log(f"composite samples   : {comp.kept_samples.tolist()} "
        f"(verify re-solves: {int(comp.verify_rounds.sum())})")
    log(f"screen overhead     : {on.screen_times.sum() * 1e3:.1f} ms total")
    log(f"path time feat/comp/OFF: {t_on:.3f}s / {t_comp:.3f}s / {t_off:.3f}s "
        f"-> speedup x{t_off / t_on:.2f} / x{t_off / t_comp:.2f}")
    log(f"max rel obj dev     : feat {obj_dev:.2e}, composite {comp_dev:.2e} "
        f"(safety: identical solutions)")
    return [
        ("path_screened", t_on * 1e6, f"speedup=x{t_off / t_on:.2f}"),
        ("path_composite", t_comp * 1e6,
         f"speedup=x{t_off / t_comp:.2f} obj_dev={comp_dev:.2e}"),
        ("path_unscreened", t_off * 1e6, f"obj_dev={obj_dev:.2e}"),
    ]
