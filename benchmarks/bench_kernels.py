"""Screening-sweep implementations head-to-head (paper Alg. 1 cost model).

  naive      : per-feature python loop over neg_min (the paper's literal
               Algorithm 1 — O(mn) with per-feature kernel-launch overhead)
  batched    : one fused jnp sweep (our TPU adaptation; still multi-pass)
  fused-op   : the Pallas-kernel wrapper (single pass over X; on CPU this
               runs the jnp fallback — on TPU it is the Mosaic kernel; the
               win measured here is the pass-fusion, the VMEM win is
               structural and shows in the dry-run bytes term)

Reports us/feature — the paper's claim is that screening cost ~ one gradient
evaluation; these numbers substantiate it.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lambda_max, screen_bounds, theta_at_lambda_max
from repro.core.screening import (
    FeatureReductions,
    screen_bounds_from_reductions,
    shared_scalars,
)
from repro.data import make_sparse_classification


def _naive_loop(X, y, lam1, lam2, theta1, n_features=64):
    """Paper Algorithm 1: feature-at-a-time (first n_features for timing)."""
    sh = shared_scalars(y, lam1, lam2, theta1)
    outs = []
    for j in range(n_features):
        f = X[j:j + 1]
        rhs = jnp.stack([y * theta1, y, jnp.ones_like(y)], axis=1)
        d = f @ rhs
        red = FeatureReductions(d_theta=d[:, 0], d_one=d[:, 1], d_y=d[:, 2],
                                d_sq=jnp.sum(f * f, axis=1))
        outs.append(screen_bounds_from_reductions(red, sh))
    return jnp.concatenate(outs)


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps


def run(log=print):
    ds = make_sparse_classification(m=8192, n=1024, seed=13)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    m = X.shape[0]
    lmax = lambda_max(X, y)
    theta1 = theta_at_lambda_max(y, lmax)
    lam2 = 0.5 * lmax

    n_naive = 64
    t_naive = _time(lambda: _naive_loop(X, y, lmax, lam2, theta1, n_naive), reps=1)
    t_batched = _time(lambda: screen_bounds(X, y, lmax, lam2, theta1))

    # fused Pallas op: on CPU this must run in interpret mode (python-level
    # emulation — correctness path, not a perf path), so time a small slice
    # and report it as such; the TPU win is structural (1 HBM pass vs 4, see
    # EXPERIMENTS.md §Perf / svm_roofline).
    from repro.kernels.ops import screen_bounds_op
    m_f = 512
    t_fused = _time(lambda: screen_bounds_op(X[:m_f], y, lmax, lam2, theta1,
                                             block_m=256, block_n=512,
                                             interpret=True), reps=1)

    us_naive = t_naive / n_naive * 1e6
    us_batched = t_batched / m * 1e6
    us_fused = t_fused / m_f * 1e6
    log(f"# screening sweep cost (m={m}, n={X.shape[1]})")
    log(f"naive per-feature : {us_naive:10.2f} us/feature")
    log(f"batched jnp       : {us_batched:10.3f} us/feature "
        f"(x{us_naive / us_batched:.0f} vs naive)")
    log(f"fused op (interpret-mode emulation, m={m_f}): {us_fused:10.3f} us/feature")
    return [
        ("screen_naive", us_naive, "per-feature loop (paper Alg.1)"),
        ("screen_batched", us_batched, "one fused jnp sweep"),
        ("screen_fused_interp", us_fused, "Pallas interpret emulation (CPU)"),
    ]
