"""Roofline for the PAPER'S OWN workload at production scale.

Lowers the distributed screen + one distributed FISTA iteration on the
single-pod (16 model x 16 data) mesh for a web-scale sparse-SVM problem
(m = 2^21 features x n = 2^17 samples — the paper's text-classification
regime scaled to cluster size), and extracts the same three roofline terms
as the LM cells. Run in its own process (512-device flag):

    PYTHONPATH=src python -m benchmarks.svm_roofline
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json      # noqa: E402
from pathlib import Path  # noqa: E402

import jax       # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.distributed import fista_sharded, screen_sharded  # noqa: E402
from repro.launch.hlo_analysis import collective_stats  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analyse(label, compiled, n_dev, log=print):
    cost = compiled.cost_analysis()
    colls = collective_stats(compiled.as_text())
    cb = sum(c["bytes"] for c in colls.values())
    comp = cost.get("flops", 0.0) / PEAK_FLOPS
    mem = cost.get("bytes accessed", 0.0) / HBM_BW
    coll = cb / ICI_BW
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda t: t[1])[0]
    rec = {
        "cell": label, "devices": n_dev,
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": cb, "collectives": colls,
        "t_compute_s": comp, "t_memory_s": mem, "t_collective_s": coll,
        "dominant": dom,
        "roofline_fraction": comp / max(comp, mem, coll) if max(comp, mem, coll) else 0,
        "memory": {a: int(getattr(compiled.memory_analysis(), a, 0) or 0)
                   for a in ("argument_size_in_bytes", "temp_size_in_bytes",
                             "output_size_in_bytes")},
    }
    log(f"[svm-roofline] {label}: compute={comp:.2e}s memory={mem:.2e}s "
        f"collective={coll:.2e}s dominant={dom}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=1 << 21)
    ap.add_argument("--n", type=int, default=1 << 17)
    ap.add_argument("--out", default="artifacts/svm_roofline.json")
    args = ap.parse_args()

    mesh = jax.make_mesh((16, 16), ("model", "data"))
    m, n = args.m, args.n
    X = jax.ShapeDtypeStruct((m, n), jnp.float32)
    y = jax.ShapeDtypeStruct((n,), jnp.float32)
    th = jax.ShapeDtypeStruct((n,), jnp.float32)
    w = jax.ShapeDtypeStruct((m,), jnp.float32)

    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    recs = []

    # 1) the screen itself (paper Alg. 1, batched + sharded)
    fn = jax.jit(
        lambda X, y, t: screen_sharded(mesh, X, y, 100.0, 50.0, t, delta=0.0),
        in_shardings=(ns("model", "data"), ns("data"), ns("data")),
    )
    compiled = fn.lower(X, y, th).compile()
    recs.append(analyse("screen_m2e21_n2e17", compiled, mesh.size))

    # 2) one distributed FISTA solve (50-iteration budget for analysis)
    fn2 = jax.jit(
        lambda X, y, w: fista_sharded(mesh, X, y, 50.0, max_iters=50,
                                      tol=0.0, w0=w),
        in_shardings=(ns("model", "data"), ns("data"), ns("model")),
    )
    compiled2 = fn2.lower(X, y, w).compile()
    recs.append(analyse("fista50_m2e21_n2e17", compiled2, mesh.size))

    Path(args.out).write_text(json.dumps(recs, indent=2))
    print(f"written {args.out}")


if __name__ == "__main__":
    main()
