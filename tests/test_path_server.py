"""Path server: continuous batching over the batched scan step — served
results vs sequential svm_path, bucket padding invariants, and the warm
program cache (hits/misses/retraces)."""

import numpy as np
import pytest

from repro.core import svm_path
from repro.launch.path_server import PathJob, PathServer, demo_jobs

SOLVE = dict(tol=1e-10, max_iters=8000)


@pytest.fixture(scope="module")
def served():
    """One ragged 6-job workload through a 3-slot compact-mode server."""
    jobs = demo_jobs(6, m=300, n=120, seed=3)  # ragged T in [4, 10)
    server = PathServer(slots=3, reduce="compact", **SOLVE)
    results = server.serve(jobs, log=lambda *a, **k: None)
    return jobs, server, results


def test_server_matches_sequential_paths(served):
    """Every served job must reproduce its sequential svm_path solution
    (scan engine, same grid): objectives to solver resolution — the padded
    slot solves the true problem through its sample mask."""
    jobs, _, results = served
    for job, r in zip(jobs, results):
        seq = svm_path(job.X, job.y, lambdas=job.lambdas, engine="scan",
                       reduce="compact", **SOLVE)
        rel = np.max(np.abs(r.objectives - seq.objectives)
                     / np.maximum(np.abs(seq.objectives), 1.0))
        assert rel < 1e-6, (job.jid, rel)
        np.testing.assert_allclose(r.weights, seq.weights, atol=5e-3)
        assert r.extras["jid"] == job.jid
        assert r.extras["engine"] == "serve"


def test_server_results_trimmed_to_true_shape(served):
    """Bucket padding must never leak: results carry the job's true (T, m)
    shapes, padded feature rows are screened to exact zeros, and the
    reported caps never exceed the true m."""
    jobs, _, results = served
    for job, r in zip(jobs, results):
        T, m = len(job.lambdas), job.X.shape[0]
        assert r.weights.shape == (T, m)
        assert r.extras["keep_masks"].shape == (T, m)
        assert np.all(r.weights[~r.extras["keep_masks"]] == 0.0)
        assert np.all(r.extras["caps"] <= m)
        assert np.all(r.kept <= m)


def test_server_cache_warm_and_no_retrace(served):
    """The explicit program cache must actually get reused (more hits than
    misses on a multi-job workload) and never retrace a compiled program."""
    _, server, _ = served
    st = server.cache_stats()
    assert st["programs"] == st["misses"]
    assert st["hits"] > st["misses"], st
    assert st["retraces"] == 0, st


def test_server_occupancy_and_latency(served):
    """Continuous batching keeps slots busy across ragged grid lengths."""
    _, server, _ = served
    s = server.last_serve
    assert s["jobs"] == 6
    assert s["slot_occupancy"] > 0.5
    assert s["latency_p95_s"] >= s["latency_p50_s"] > 0.0
    assert s["jobs_per_s"] > 0.0


def test_server_second_workload_bounded_compiles():
    """A second same-bucket workload on a warm server compiles at most the
    remaining rungs of the cap ladder — the cache key space for one group
    is (|caps| + 1) programs, never per-job or per-grid-length."""
    from repro.core.path_scan import compact_caps

    server = PathServer(slots=2, reduce="compact", tol=1e-9, max_iters=4000)
    server.serve(demo_jobs(3, m=100, n=60, seed=1), log=lambda *a: None)
    server.serve(demo_jobs(3, m=100, n=60, seed=9), log=lambda *a: None)
    st = server.cache_stats()
    assert st["programs"] <= len(compact_caps(128)) + 1  # m_b = bucket(100)
    assert st["retraces"] == 0


def test_server_mixed_buckets_and_rules():
    """Jobs from different shape buckets and rule configs drain group by
    group through the same server, each against its own sequential path."""
    a = demo_jobs(2, m=100, n=60, seed=21)
    b = demo_jobs(2, m=40, n=24, seed=22)
    for j in b:
        j.jid += 10
    b[1].rules = "none"  # separate group: screening is in the group key
    server = PathServer(slots=2, reduce="compact", tol=1e-9, max_iters=4000)
    results = server.serve(a + b, log=lambda *a, **k: None)
    assert [r.extras["jid"] for r in results] == [0, 1, 10, 11]
    for job, r in zip(a + b, results):
        seq = svm_path(job.X, job.y, lambdas=job.lambdas, engine="scan",
                       reduce="compact", screening=job.screening,
                       tol=1e-9, max_iters=4000)
        rel = np.max(np.abs(r.objectives - seq.objectives)
                     / np.maximum(np.abs(seq.objectives), 1.0))
        assert rel < 1e-6, (job.jid, rel)
        assert r.screened == job.screening


def test_server_rejects_unknown_rules():
    job = PathJob(jid=0, X=np.eye(8, dtype=np.float32),
                  y=np.ones(8, np.float32), rules="sample_vi")
    with pytest.raises(ValueError, match="feature rule only"):
        job.group_key()
    with pytest.raises(ValueError, match="mask' or 'compact"):
        PathServer(reduce="gather")
