import numpy as np
import pytest

from repro.core import svm_path
from repro.data import make_sparse_classification


@pytest.fixture(scope="module")
def paths():
    ds = make_sparse_classification(m=400, n=150, k_active=10, seed=31)
    on = svm_path(ds.X, ds.y, n_lambdas=6, lam_min_ratio=0.2, screening=True,
                  tol=1e-10, max_iters=5000)
    off = svm_path(ds.X, ds.y, n_lambdas=6, lam_min_ratio=0.2, screening=False,
                   tol=1e-10, max_iters=5000)
    return on, off


def test_path_exactness(paths):
    on, off = paths
    np.testing.assert_allclose(on.objectives, off.objectives, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(on.weights, off.weights, atol=3e-3)


def test_screening_reduces_problem_size(paths):
    on, off = paths
    assert np.all(on.kept[1:] <= 400)
    assert on.kept[1] < 400  # near lam_max most features screened
    assert np.all(off.kept[1:] == 400)


def test_kept_superset_of_active(paths):
    on, _ = paths
    for k in range(1, len(on.lambdas)):
        assert on.active[k] <= on.kept[k]


def test_active_set_grows_roughly_monotone(paths):
    on, _ = paths
    # allow small dips (fp tolerance) but overall growth along the path
    assert on.active[-1] >= on.active[1]


def test_mask_mode_matches_gather_mode():
    ds = make_sparse_classification(m=200, n=100, seed=33)
    g = svm_path(ds.X, ds.y, n_lambdas=5, lam_min_ratio=0.3, screening=True,
                 reduce="gather", tol=1e-10, max_iters=4000)
    m = svm_path(ds.X, ds.y, n_lambdas=5, lam_min_ratio=0.3, screening=True,
                 reduce="mask", tol=1e-10, max_iters=4000)
    np.testing.assert_allclose(g.weights, m.weights, atol=3e-3)
