"""Per-architecture smoke tests (reduced configs) + block-level oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import transformer as tr
from repro.models.cache import segments_of


def _batch(cfg, B=2, S=32, seed=0, dtype=jnp.bfloat16):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        b["enc_embeds"] = jnp.asarray(
            0.1 * rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), dtype)
    if cfg.family == "vlm":
        b["prefix_embeds"] = jnp.asarray(
            0.1 * rng.standard_normal((B, cfg.num_prefix_tokens, cfg.d_model)), dtype)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    """One forward+backward on the reduced config: shapes + finite grads."""
    cfg = get_smoke_config(arch)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: tr.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), arch
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), f"{arch}: non-finite grads"
    assert float(loss) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """AR decode with cache == full-sequence forward at the same position."""
    kw = {"dtype": "float32"}
    cfg = get_smoke_config(arch)
    if cfg.moe_num_experts:
        kw["moe_capacity_factor"] = 8.0  # avoid prefill-only token drops
    cfg = cfg.replace(**kw)
    params = tr.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 32
    batch = _batch(cfg, B, S, dtype=jnp.float32)

    full, _ = tr.prefill(params, cfg, batch, max_seq=S + 8)
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, : S - 1]
    _, cache = tr.prefill(params, cfg, b2, max_seq=S + 8)
    dec, _ = tr.decode_step(params, cfg, batch["tokens"][:, S - 1 : S],
                            jnp.full((B,), S - 1, jnp.int32), cache)
    rel = float(jnp.max(jnp.abs(full - dec))) / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 5e-3, f"{arch}: decode/prefill rel diff {rel}"


def test_multi_step_decode_consistency():
    """Greedy decode 4 steps == teacher-forced forward (windowed hybrid arch)."""
    cfg = get_smoke_config("recurrentgemma-9b").replace(dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(2))
    B, S, T = 1, 24, 4
    batch = _batch(cfg, B, S + T, dtype=jnp.float32)
    toks = batch["tokens"]

    _, cache = tr.prefill(params, cfg, {"tokens": toks[:, :S]}, max_seq=S + T)
    outs = []
    for t in range(T):
        logit, cache = tr.decode_step(params, cfg, toks[:, S + t : S + t + 1],
                                      jnp.full((B,), S + t, jnp.int32), cache)
        outs.append(logit)
    full, _ = tr.prefill(params, cfg, {"tokens": toks}, max_seq=S + T)
    rel = float(jnp.max(jnp.abs(full - outs[-1]))) / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 5e-3


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step state recurrence."""
    from repro.models.ssm import ssd_chunked

    rng = np.random.default_rng(3)
    B, S, nh, P, N = 2, 64, 3, 8, 16
    xh = jnp.asarray(rng.standard_normal((B, S, nh, P)), jnp.float32)
    dt = jnp.asarray(0.5 * rng.random((B, S, nh)) + 0.1, jnp.float32)
    A = jnp.asarray(-0.5 * rng.random(nh) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)

    y, fin = ssd_chunked(xh, dt, A, Bm, Cm, chunk=16)

    state = np.zeros((B, nh, P, N))
    ys = np.zeros((B, S, nh, P))
    for t in range(S):
        a = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])          # (B,nh)
        upd = np.einsum("bh,bn,bhp->bhpn", np.asarray(dt[:, t]),
                        np.asarray(Bm[:, t]), np.asarray(xh[:, t]))
        state = state * a[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t]), state)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(fin), state, rtol=2e-3, atol=2e-3)


def test_blockwise_attention_matches_naive():
    from repro.models.attention import _sdpa_chunked

    rng = np.random.default_rng(4)
    B, S, H, G, hd = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, G, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, G, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    out = _sdpa_chunked(q, k, v, pos, pos, causal=True, window=0,
                        q_chunk=16, kv_chunk=16)

    # naive reference
    rep = H // G
    qr = q.reshape(B, S, G, rep, hd)
    s = np.einsum("bqgrd,bkgd->bgrqk", np.asarray(qr), np.asarray(k)) / np.sqrt(hd)
    mask = np.tril(np.ones((S, S), bool))
    s = np.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(jnp.asarray(s), axis=-1)
    ref = np.einsum("bgrqk,bkgd->bqgrd", np.asarray(p), np.asarray(v)).reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_local_window_attention_restricts_context():
    from repro.models.attention import _sdpa_chunked

    rng = np.random.default_rng(5)
    B, S, H, hd, W = 1, 40, 2, 8, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_w = _sdpa_chunked(q, k, v, pos, pos, causal=True, window=W,
                          q_chunk=16, kv_chunk=16)
    # reference: explicit banded mask
    s = np.einsum("bqhd,bkhd->bhqk", np.asarray(q), np.asarray(k)) / np.sqrt(hd)
    dq = np.arange(S)[:, None]; dk = np.arange(S)[None, :]
    mask = (dk <= dq) & (dq - dk < W)
    s = np.where(mask[None, None], s, -1e30)
    p = np.asarray(jax.nn.softmax(jnp.asarray(s), axis=-1))
    ref = np.einsum("bhqk,bkhd->bqhd", p, np.asarray(v))
    np.testing.assert_allclose(np.asarray(out_w), ref, rtol=2e-4, atol=2e-4)


def test_segments_decomposition():
    cfg = get_config("recurrentgemma-9b")
    segs = segments_of(cfg)
    assert segs == [(("rec", "rec", "attn"), 12), (("rec", "rec"), 1)]
    total = sum(len(p) * n for p, n in segs)
    assert total == cfg.num_layers


@pytest.mark.parametrize("arch,lo,hi", [
    ("granite-8b", 7.5e9, 9.0e9),
    ("deepseek-v2-236b", 2.2e11, 2.6e11),
    ("arctic-480b", 4.4e11, 5.1e11),
    ("mamba2-130m", 1.1e8, 1.5e8),
])
def test_param_count_matches_name(arch, lo, hi):
    n = get_config(arch).param_count()
    assert lo <= n <= hi, (arch, n)


def test_cross_entropy_ignores_vocab_padding():
    from repro.models.layers import cross_entropy

    logits = jnp.asarray(np.random.default_rng(6).standard_normal((2, 4, 16)), jnp.float32)
    tgt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    base = cross_entropy(logits, tgt, vocab_real=12)
    spiked = logits.at[..., 12:].set(100.0)  # junk in padded columns
    again = cross_entropy(spiked, tgt, vocab_real=12)
    np.testing.assert_allclose(float(base), float(again), rtol=1e-6)
