"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lambda_max, theta_at_lambda_max
from repro.data import make_sparse_classification
from repro.kernels.ops import (
    hinge_grad_op,
    hinge_margin_op,
    margin_obj_op,
    sample_surplus_op,
    screen_bounds_op,
)
from repro.kernels.ref import (
    hinge_grad_ref,
    hinge_stats_ref,
    sample_surplus_ref,
    screen_bounds_ref,
)

SHAPES = [(64, 64), (128, 256), (300, 200), (513, 130)]  # incl. non-multiples
DTYPES = [jnp.float32, jnp.bfloat16]
BLOCKS = [(64, 128), (128, 128)]


def _data(m, n, dtype, seed=0):
    ds = make_sparse_classification(m=m, n=n, seed=seed)
    X = jnp.asarray(ds.X).astype(dtype)
    y = jnp.asarray(ds.y)
    return X, y


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_screen_kernel_matches_oracle(shape, dtype):
    m, n = shape
    X, y = _data(m, n, dtype)
    lmax = lambda_max(X.astype(jnp.float32), y)
    theta1 = theta_at_lambda_max(y, lmax)
    ref = np.asarray(screen_bounds_ref(X, y, lmax, 0.5 * lmax, theta1))
    out = np.asarray(
        screen_bounds_op(X, y, lmax, 0.5 * lmax, theta1,
                         block_m=64, block_n=128, interpret=True)
    )
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol * max(1.0, np.abs(ref).max()))


def test_screen_kernel_delta_matches_oracle():
    """delta-inflated bounds flow through the packed scalars unchanged."""
    X, y = _data(256, 128, jnp.float32, seed=2)
    lmax = lambda_max(X, y)
    theta1 = theta_at_lambda_max(y, lmax)
    from repro.core import screen_bounds

    for delta in (0.0, 0.05, 0.3):
        ref = np.asarray(screen_bounds(X, y, lmax, 0.5 * lmax, theta1,
                                       delta=delta))
        out = np.asarray(screen_bounds_op(X, y, lmax, 0.5 * lmax, theta1,
                                          block_m=64, block_n=128,
                                          interpret=True, delta=delta))
        np.testing.assert_allclose(out, ref, rtol=1e-5,
                                   atol=1e-5 * max(1.0, np.abs(ref).max()))
    # inflation is monotone: a larger delta never shrinks a bound
    b0 = np.asarray(screen_bounds_op(X, y, lmax, 0.5 * lmax, theta1,
                                     block_m=64, block_n=128, interpret=True))
    b1 = np.asarray(screen_bounds_op(X, y, lmax, 0.5 * lmax, theta1,
                                     block_m=64, block_n=128, interpret=True,
                                     delta=0.1))
    assert np.all(b1 >= b0 - 1e-6)


@pytest.mark.parametrize("blocks", BLOCKS)
def test_screen_kernel_block_shape_invariance(blocks):
    bm, bn = blocks
    X, y = _data(256, 256, jnp.float32)
    lmax = lambda_max(X, y)
    theta1 = theta_at_lambda_max(y, lmax)
    ref = np.asarray(screen_bounds_ref(X, y, lmax, 0.3 * lmax, theta1))
    out = np.asarray(
        screen_bounds_op(X, y, lmax, 0.3 * lmax, theta1,
                         block_m=bm, block_n=bn, interpret=True)
    )
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5 * max(1.0, np.abs(ref).max()))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("history", [False, True])
def test_sample_kernel_matches_oracle(shape, dtype, history):
    """Transposed (sample-axis) sweep == pure-XLA margin surplus, both slacks."""
    m, n = shape
    X, y = _data(m, n, dtype, seed=5)
    rng = np.random.default_rng(9)
    w = jnp.asarray(rng.standard_normal(m) * (rng.random(m) < 0.2), jnp.float32)
    b = -0.23
    u_prev = jnp.asarray(rng.standard_normal(n), jnp.float32) if history else None
    kw = dict(dw=0.37, db=0.05, u_prev=u_prev, shrink_factor=2.0, margin_floor=1e-3)
    ref = np.asarray(sample_surplus_ref(X, y, w, b, **kw))
    out = np.asarray(sample_surplus_op(X, w, y, b, block_m=64, block_n=128,
                                       interpret=True, **kw))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol * max(1.0, np.abs(ref).max()))


def test_sample_kernel_no_trust_region_keeps_everything():
    """dw=inf and no history => every surplus is hugely negative (keep all)."""
    X, y = _data(128, 96, jnp.float32, seed=8)
    w = jnp.zeros((128,), jnp.float32)
    out = np.asarray(sample_surplus_op(X, w, y, 0.0, block_m=64, block_n=128,
                                       interpret=True))
    assert np.all(out < 0.0)
    assert np.all(np.isfinite(out))


def test_sample_kernel_padding_is_inert():
    X, y = _data(100, 90, jnp.float32, seed=6)
    w = jnp.asarray(np.random.default_rng(3).standard_normal(100), jnp.float32)
    kw = dict(dw=0.1, db=0.01, interpret=True)
    out1 = np.asarray(sample_surplus_op(X, w, y, 0.1, block_m=64, block_n=128, **kw))
    out2 = np.asarray(sample_surplus_op(X, w, y, 0.1, block_m=128, block_n=256, **kw))
    np.testing.assert_allclose(out1, out2, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_hinge_margin_kernel(shape, dtype):
    m, n = shape
    X, y = _data(m, n, dtype, seed=3)
    w = jnp.asarray(np.random.default_rng(1).standard_normal(m), dtype)
    b = 0.17
    _, xi_ref, loss_ref = hinge_stats_ref(X, y, w, b)
    xi, loss = hinge_margin_op(X, w, y, b, block_m=64, block_n=128, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(xi), np.asarray(xi_ref), rtol=tol, atol=tol)
    np.testing.assert_allclose(float(loss), float(loss_ref), rtol=tol)


@pytest.mark.parametrize("shape", SHAPES)
def test_margin_obj_kernel(shape):
    """The fused (u, xi, loss) sweep the FISTA hot loop runs — u is the raw
    X^T w (bias excluded: the solver carries it separately), padding inert."""
    m, n = shape
    X, y = _data(m, n, jnp.float32, seed=7)
    w = jnp.asarray(np.random.default_rng(5).standard_normal(m), jnp.float32)
    b = -0.31
    u_ref, xi_ref, loss_ref = hinge_stats_ref(X, y, w, b)
    u, xi, loss = margin_obj_op(X, w, y, b, block_m=64, block_n=128,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(u) + b, np.asarray(u_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(xi), np.asarray(xi_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(loss), float(loss_ref), rtol=1e-4)


@pytest.mark.parametrize("shape", SHAPES)
def test_hinge_grad_kernel(shape):
    m, n = shape
    X, y = _data(m, n, jnp.float32, seed=4)
    xi = jnp.asarray(np.random.default_rng(2).random(n), jnp.float32)
    ref = np.asarray(hinge_grad_ref(X, y, xi))
    out = np.asarray(hinge_grad_op(X, y, xi, block_m=64, block_n=128, interpret=True))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4 * max(1.0, np.abs(ref).max()))


def test_kernel_padding_is_inert():
    """Padding rows/cols must not change results for real features."""
    X, y = _data(100, 90, jnp.float32, seed=6)
    lmax = lambda_max(X, y)
    theta1 = theta_at_lambda_max(y, lmax)
    out1 = np.asarray(screen_bounds_op(X, y, lmax, 0.5 * lmax, theta1,
                                       block_m=64, block_n=128, interpret=True))
    out2 = np.asarray(screen_bounds_op(X, y, lmax, 0.5 * lmax, theta1,
                                       block_m=128, block_n=256, interpret=True))
    np.testing.assert_allclose(out1, out2, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Row-validity counts (the compact active-set seam, core/path_scan.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("valid", [1, 37, 64, 200, 300])
def test_margin_kernel_valid_count_matches_full(valid):
    """With rows >= valid zeroed, skipping their blocks must be a no-op:
    the valid-count sweep equals the full sweep on the zero-padded operand
    (which itself matches the XLA oracle — the tests above)."""
    m, n = 300, 200
    X, y = _data(m, n, jnp.float32, seed=11)
    rng = np.random.default_rng(12)
    live = (jnp.arange(m) < valid).astype(jnp.float32)
    Xz = X * live[:, None]
    w = jnp.asarray(rng.standard_normal(m), jnp.float32) * live
    b = 0.21
    kw = dict(block_m=64, block_n=128, interpret=True)
    u_f, xi_f, loss_f = margin_obj_op(Xz, w, y, b, **kw)
    u_v, xi_v, loss_v = margin_obj_op(Xz, w, y, b, valid_m=jnp.int32(valid),
                                      **kw)
    np.testing.assert_array_equal(np.asarray(u_v), np.asarray(u_f))
    np.testing.assert_array_equal(np.asarray(xi_v), np.asarray(xi_f))
    assert float(loss_v) == float(loss_f)


@pytest.mark.parametrize("valid", [1, 37, 64, 200, 300])
def test_grad_kernel_valid_count_matches_full(valid):
    m, n = 300, 200
    X, y = _data(m, n, jnp.float32, seed=13)
    live = (jnp.arange(m) < valid).astype(jnp.float32)
    Xz = X * live[:, None]
    xi = jnp.asarray(np.random.default_rng(14).random(n), jnp.float32)
    kw = dict(block_m=64, block_n=128, interpret=True)
    g_f = np.asarray(hinge_grad_op(Xz, y, xi, **kw))
    g_v = np.asarray(hinge_grad_op(Xz, y, xi, valid_m=jnp.int32(valid), **kw))
    np.testing.assert_array_equal(g_v, g_f)
    # skipped output rows are written, as zeros
    assert np.all(g_v[valid:] == 0.0)
