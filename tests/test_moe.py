"""MoE layer oracle tests: dispatch/combine einsums == per-token expert loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.moe import init_moe, moe_forward


def _naive_moe(params, x, cfg):
    """Per-token loop oracle (no capacity limits)."""
    B, S, D = x.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    logits = (x @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    out = jnp.zeros_like(x, jnp.float32)
    for e in range(E):
        h = x @ params["wi"][e]
        g = x @ params["wg"][e]
        ye = (jax.nn.silu(g) * h) @ params["wo"][e]          # (B,S,D)
        w_e = jnp.sum(jnp.where(top_i == e, top_p, 0.0), axis=-1)
        out = out + w_e[..., None] * ye.astype(jnp.float32)
    if "shared" in params:
        sp = params["shared"]
        out = out + ((jax.nn.silu(x @ sp["wg"]) * (x @ sp["wi"])) @ sp["wo"]).astype(jnp.float32)
    if "dense" in params:
        dp = params["dense"]
        out = out + ((jax.nn.silu(x @ dp["wg"]) * (x @ dp["wi"])) @ dp["wo"]).astype(jnp.float32)
    return out


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "arctic-480b"])
def test_moe_matches_naive_loop(arch):
    cfg = get_smoke_config(arch).replace(dtype="float32",
                                         moe_capacity_factor=16.0)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe_forward(params, x, cfg, act_dtype=jnp.float32)
    ref = _naive_moe(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens_not_correctness():
    """With tiny capacity some tokens drop to the residual path (out = 0 for
    their routed contribution) — outputs stay finite and bounded."""
    cfg = get_smoke_config("deepseek-v2-236b").replace(
        dtype="float32", moe_capacity_factor=0.25)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, _ = moe_forward(params, x, cfg, act_dtype=jnp.float32)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_ssd_streaming_state_handoff():
    """ssm_forward(full) == ssm_forward(half1) -> state -> ssm_forward(half2)."""
    from repro.models.ssm import init_ssm, ssm_forward

    cfg = get_smoke_config("mamba2-130m").replace(dtype="float32")
    params = init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))

    full, _ = ssm_forward(params, x, cfg, act_dtype=jnp.float32)
    h1, (conv, state) = ssm_forward(params, x[:, :32], cfg, act_dtype=jnp.float32)
    h2, _ = ssm_forward(params, x[:, 32:], cfg, conv_state=conv,
                        ssd_state=state, act_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), rtol=3e-3, atol=3e-3)
