"""Property tests for the pluggable screening-rule subsystem (core/rules).

Invariants:
  R1 (registry):       every built-in rule round-trips through the registry
                       and composite flattens to one rule per axis.
  R2 (sample safety):  zero false sample rejections — every sample screened
                       by the path driver has xi_i = 0 at the accepted
                       solution (exactly) and at an independently solved
                       full optimum (to solver tolerance).
  R3 (path equiv):     the composite path == the unscreened path within
                       solver tolerance, for reduce="gather" and "mask".
  R4 (composition):    composite keeps <= the units kept by either single
                       rule, per axis, per step.
  R5 (cap validity):   the certified a-priori slack caps upper-bound the
                       true slacks (the sample-side analogue of S2 in
                       tests/test_screening.py).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompositeRule,
    ConvexRegion,
    FeatureVIRule,
    PathDriver,
    SampleVIRule,
    available_rules,
    fista_solve,
    get_rule,
    lambda_max,
    make_rules,
    svm_path,
)
from repro.core.dual import safe_theta_and_delta, xi_from_primal
from repro.core.rules import sample_slack_caps
from repro.data import make_sparse_classification

DEEP_GRID = dict(n_lambdas=8, lam_min_ratio=0.02)


# -- R1: registry ----------------------------------------------------------

def test_registry_roundtrip():
    assert {"feature_vi", "sample_vi", "composite"} <= set(available_rules())
    assert isinstance(get_rule("feature_vi"), FeatureVIRule)
    assert isinstance(get_rule("sample_vi"), SampleVIRule)
    with pytest.raises(KeyError):
        get_rule("no_such_rule")


def test_make_rules_flattens_composite():
    rules = make_rules("composite")
    assert {r.axis for r in rules} == {"features", "samples"}
    assert make_rules(None) == []
    assert [r.name for r in make_rules(["feature_vi"])] == ["feature_vi"]
    custom = make_rules(CompositeRule([FeatureVIRule(tau=0.9)]))
    assert len(custom) == 1 and custom[0].tau == 0.9


# -- R2: zero false sample rejections --------------------------------------

@pytest.mark.parametrize("seed", [0, 3, 17])
def test_sample_screening_zero_false_rejections(seed):
    ds = make_sparse_classification(m=300, n=160, k_active=12, seed=seed)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    res = PathDriver(rules="sample_vi", tol=1e-10, max_iters=20000).run(
        ds.X, ds.y, **DEEP_GRID)
    masks = res.extras["sample_masks"]
    assert any((~m).any() for m in masks.values()), "no samples screened at all"
    for k, mask in masks.items():
        screened = ~mask
        if not screened.any():
            continue
        # exact at the accepted solution: margins were KKT-verified >= 1
        xi_acc = np.asarray(xi_from_primal(
            X, y, jnp.asarray(res.weights[k], jnp.float32),
            jnp.asarray(res.biases[k], jnp.float32)))
        assert xi_acc[screened].max() <= 1e-6, (
            f"step {k}: screened sample has xi={xi_acc[screened].max()} "
            "at the accepted solution")
        # and at an independently solved full optimum, to solver tolerance
        full = fista_solve(X, y, jnp.asarray(float(res.lambdas[k])),
                           max_iters=60000, tol=1e-13)
        xi_true = np.asarray(xi_from_primal(X, y, full.w, full.b))
        assert xi_true[screened].max() <= 1e-4, (
            f"step {k}: screened sample has true xi={xi_true[screened].max()}")


# -- R3: composite path equivalence ----------------------------------------

@pytest.mark.parametrize("reduce", ["gather", "mask"])
def test_composite_path_matches_unscreened(reduce):
    ds = make_sparse_classification(m=250, n=120, k_active=10, seed=42)
    kw = dict(tol=1e-10, max_iters=20000)
    comp = PathDriver(rules="composite", reduce=reduce, **kw).run(
        ds.X, ds.y, **DEEP_GRID)
    off = PathDriver(rules=None, reduce=reduce, **kw).run(ds.X, ds.y, **DEEP_GRID)
    np.testing.assert_allclose(comp.weights, off.weights, atol=3e-3)
    np.testing.assert_allclose(comp.biases, off.biases, atol=3e-3)
    np.testing.assert_allclose(comp.objectives, off.objectives,
                               rtol=1e-3, atol=1e-3)


# -- R4: composition keeps <= each single rule -----------------------------

def test_composite_keeps_at_most_single_rules():
    ds = make_sparse_classification(m=250, n=120, k_active=10, seed=5)
    kw = dict(tol=1e-10, max_iters=20000)
    comp = PathDriver(rules="composite", **kw).run(ds.X, ds.y, **DEEP_GRID)
    feat = PathDriver(rules="feature_vi", **kw).run(ds.X, ds.y, **DEEP_GRID)
    samp = PathDriver(rules="sample_vi", **kw).run(ds.X, ds.y, **DEEP_GRID)
    assert np.all(comp.kept <= feat.kept)
    assert np.all(comp.kept_samples <= samp.kept_samples)
    # and the single-axis drivers never reduce the other axis
    assert np.all(feat.kept_samples[1:] == 120)
    assert np.all(samp.kept[1:] == 250)


def test_svm_path_wrapper_backcompat_and_rules():
    ds = make_sparse_classification(m=120, n=80, seed=2)
    legacy = svm_path(ds.X, ds.y, n_lambdas=4, lam_min_ratio=0.3,
                      screening=True, tol=1e-9, max_iters=4000)
    assert legacy.rules == ("feature_vi",)
    off = svm_path(ds.X, ds.y, n_lambdas=4, lam_min_ratio=0.3,
                   screening=False, tol=1e-9, max_iters=4000)
    assert off.rules == () and not off.screened
    comp = svm_path(ds.X, ds.y, n_lambdas=4, lam_min_ratio=0.3,
                    rules="composite", tol=1e-9, max_iters=4000)
    assert set(comp.rules) == {"feature_vi", "sample_vi"}


# -- R5: certified a-priori caps are valid upper bounds --------------------

@pytest.mark.parametrize("seed,r1,r2", [(1, 0.5, 0.9), (9, 0.3, 0.7),
                                        (23, 0.6, 0.5)])
def test_sample_slack_caps_upper_bound_true_slack(seed, r1, r2):
    ds = make_sparse_classification(m=200, n=120, k_active=8, seed=seed)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    lam1, lam2 = r1 * lmax, r2 * r1 * lmax
    res1 = fista_solve(X, y, jnp.asarray(lam1), max_iters=50000, tol=1e-13)
    theta1, delta = safe_theta_and_delta(X, y, res1.w, res1.b, jnp.asarray(lam1))
    region = ConvexRegion.build(y, lam1, lam2, theta1, delta=delta)
    caps = np.asarray(sample_slack_caps(region))

    res2 = fista_solve(X, y, jnp.asarray(lam2), max_iters=50000, tol=1e-13)
    xi2 = np.asarray(xi_from_primal(X, y, res2.w, res2.b))
    assert np.all(caps >= xi2 - 5e-4), (
        f"cap violated by {np.max(xi2 - caps)}")
