"""Out-of-core + sparse-matrix engine tests (repro/sparse).

Invariants:
  C1 (bitwise screen):  the chunk-streamed bound sweep equals the in-core
                        sweep BITWISE, for every chunking (incl. ragged) —
                        the row-stable reduction contract.
  C2 (container):       from_dense / from_csr round-trip exactly;
                        gather_rows returns the exact rows.
  C3 (solver seam):     fista_solve(operator=FeatureChunked) matches the
                        dense solver's objective to solver tolerance.
  C4 (BCOO tolerance):  low-density CSR chunks sweep as BCOO; matvec pair
                        and bound sweep agree with dense to fp32 tolerance.
  C5 (memory shape):    no per-chunk kernel traces an intermediate of the
                        full (m, n) shape — the device never holds more
                        than one chunk of X (stats observe the transfers).
  C6 (path):            the chunked screened path matches the in-core host
                        driver (objectives <= 1e-6; bitwise with a shared
                        Lipschitz bound) for feature, sample and dynamic
                        configs; mask reduce / program-less rules / scan
                        engines rejected loudly.
  C7 (data):            sparse synthetic datasets carry an exact CSR view;
                        the libsvm loader parses indices/labels correctly
                        (gzip input, comments, dtype override).
  C8 (chunk skip):      chunk-level gating is safe (a skipped chunk's
                        stamped bounds sit below tau and agree with the
                        fresh sweep) and free (the skip path is bitwise
                        equal to the full-stream twin, transferring
                        strictly fewer chunks); the mmap store round-trips.

The CI ``stream`` lane runs this file with REPRO_STREAM_CHUNK_M forcing a
small, ragged chunk size.
"""

import gzip
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PathDriver, fista_solve, lambda_max, screen, \
    theta_at_lambda_max
from repro.core.dual import safe_theta_and_delta
from repro.data import load_libsvm, make_sparse_classification
from repro.sparse import (
    BCOO_DENSITY_THRESHOLD,
    ChunkScreenCache,
    FeatureChunked,
    fista_solve_chunked,
    lambda_max_stream,
    lipschitz_estimate_stream,
    screen_step_stream,
    screen_stream,
    stream_feature_reductions,
)

# the CI stream lane forces a small (deliberately ragged) chunk size so the
# suite exercises many-chunk paths even on the small test instances
ENV_CHUNK_M = int(os.environ.get("REPRO_STREAM_CHUNK_M", "64"))


@pytest.fixture(scope="module")
def dense_inst():
    ds = make_sparse_classification(m=300, n=130, k_active=12, seed=21)
    return ds, jnp.asarray(ds.X), jnp.asarray(ds.y)


@pytest.fixture(scope="module")
def planted_inst():
    """Informative head block + weak noise tail: features past row 64 have
    tiny norms, so whole tail chunks screen out early and *stay* dead —
    the geometry chunk-level gating is built for."""
    ds = make_sparse_classification(m=320, n=120, k_active=8, seed=7)
    X = np.array(ds.X, copy=True)
    X[64:] *= 0.05
    return X, np.asarray(ds.y)


@pytest.fixture(scope="module")
def sparse_inst():
    ds = make_sparse_classification(m=300, n=130, k_active=12, seed=23,
                                    density=0.04)
    return ds, jnp.asarray(ds.X), jnp.asarray(ds.y)


# -- C1: bitwise bound sweep --------------------------------------------------

@pytest.mark.parametrize("chunk_m", [ENV_CHUNK_M, 97, 300])
def test_stream_bounds_bitwise_vs_dense(dense_inst, chunk_m):
    ds, X, y = dense_inst
    lmax = float(lambda_max(X, y))
    theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
    keep_d, bounds_d = screen(X, y, lmax, 0.6 * lmax, theta1)

    fc = FeatureChunked.from_dense(ds.X, chunk_m=chunk_m)
    keep_s, bounds_s = screen_stream(fc, ds.y, lmax, 0.6 * lmax, theta1)
    np.testing.assert_array_equal(np.asarray(bounds_s), np.asarray(bounds_d))
    np.testing.assert_array_equal(np.asarray(keep_s), np.asarray(keep_d))


def test_stream_bounds_bitwise_with_delta(dense_inst):
    """The inexact-anchor (delta > 0) scalar path is shared too."""
    ds, X, y = dense_inst
    lmax = float(lambda_max(X, y))
    lam1 = 0.5 * lmax
    res = fista_solve(X, y, lam1, max_iters=20000, tol=1e-11)
    theta1, delta = safe_theta_and_delta(X, y, res.w, res.b, jnp.asarray(lam1))
    _, bounds_d = screen(X, y, lam1, 0.8 * lam1, theta1, delta=delta)
    fc = FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M)
    _, bounds_s = screen_stream(fc, ds.y, lam1, 0.8 * lam1, theta1,
                                delta=delta)
    np.testing.assert_array_equal(np.asarray(bounds_s), np.asarray(bounds_d))


def test_lambda_max_stream_bitwise(dense_inst):
    ds, X, y = dense_inst
    fc = FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M)
    assert float(lambda_max_stream(fc, ds.y)) == float(lambda_max(X, y))


# -- C2: container ------------------------------------------------------------

def test_container_round_trip(sparse_inst):
    ds, _, _ = sparse_inst
    fc_d = FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M)
    np.testing.assert_array_equal(fc_d.as_dense(), ds.X)
    fc_c = FeatureChunked.from_csr(ds.csr, chunk_m=ENV_CHUNK_M)
    np.testing.assert_array_equal(fc_c.as_dense(), ds.X)
    assert fc_c.shape == ds.X.shape
    assert abs(fc_c.density() - ds.csr.density) < 1e-12

    idx = np.asarray([0, 5, ENV_CHUNK_M, ds.X.shape[0] - 1])
    np.testing.assert_array_equal(fc_c.gather_rows(idx), ds.X[idx])
    np.testing.assert_array_equal(fc_d.gather_rows(idx), ds.X[idx])


def test_container_matches_scipy_csr(sparse_inst):
    """Cross-check our numpy CSR triple against scipy's (optional extra)."""
    sp = pytest.importorskip("scipy.sparse")
    ds, _, _ = sparse_inst
    ref = sp.csr_matrix(ds.X)
    np.testing.assert_array_equal(ds.csr.indptr, ref.indptr)
    np.testing.assert_array_equal(ds.csr.indices, ref.indices)
    np.testing.assert_array_equal(ds.csr.data, ref.data)
    fc = FeatureChunked.from_csr(ref, chunk_m=ENV_CHUNK_M)  # scipy accepted
    np.testing.assert_array_equal(fc.as_dense(), ds.X)


# -- C3: solver seam ----------------------------------------------------------

def test_chunked_solver_matches_dense(dense_inst):
    ds, X, y = dense_inst
    lam = 0.3 * float(lambda_max(X, y))
    ref = fista_solve(X, y, lam, max_iters=20000, tol=1e-10)
    fc = FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M)
    # the operator= seam on the standard entry point
    ch = fista_solve(None, ds.y, lam, max_iters=20000, tol=1e-10, operator=fc)
    assert abs(float(ch.obj) - float(ref.obj)) / float(ref.obj) < 1e-6
    np.testing.assert_allclose(np.asarray(ch.w), np.asarray(ref.w), atol=1e-3)
    assert bool(ch.converged)
    # u is carried like the fused in-core body's
    np.testing.assert_allclose(np.asarray(ch.u),
                               np.asarray(X.T @ ch.w), atol=1e-4)


def test_chunked_solver_warm_start_and_mask(dense_inst):
    ds, X, y = dense_inst
    n = ds.X.shape[1]
    lam = 0.35 * float(lambda_max(X, y))
    sm = np.ones((n,), np.float32)
    sm[: n // 5] = 0.0
    ref = fista_solve(X, y, lam, max_iters=20000, tol=1e-10,
                      sample_mask=jnp.asarray(sm))
    fc = FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M)
    ch = fista_solve_chunked(fc, ds.y, lam, w0=ref.w, b0=ref.b,
                             max_iters=20000, tol=1e-10,
                             sample_mask=jnp.asarray(sm))
    assert abs(float(ch.obj) - float(ref.obj)) / float(ref.obj) < 1e-6


def test_lipschitz_stream_close(dense_inst):
    ds, X, _ = dense_inst
    from repro.core.solver import lipschitz_estimate

    Ld = float(lipschitz_estimate(X))
    Ls = float(lipschitz_estimate_stream(
        FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M)))
    assert abs(Ld - Ls) / Ld < 1e-4


# -- C4: BCOO route -----------------------------------------------------------

def test_bcoo_selected_below_threshold(sparse_inst):
    ds, _, _ = sparse_inst
    fc = FeatureChunked.from_csr(ds.csr, chunk_m=ENV_CHUNK_M)
    assert ds.csr.density <= BCOO_DENSITY_THRESHOLD
    list(fc.stream())
    assert fc.stats["bcoo_puts"] > 0
    # a dense-threshold container densifies instead
    fc2 = FeatureChunked.from_csr(ds.csr, chunk_m=ENV_CHUNK_M,
                                  bcoo_threshold=0.0)
    list(fc2.stream())
    assert fc2.stats["bcoo_puts"] == 0


def test_bcoo_margin_sweep_tolerance(sparse_inst):
    """BCOO matvec pair + bound sweep vs dense, fp32 tolerance."""
    ds, X, y = sparse_inst
    fc = FeatureChunked.from_csr(ds.csr, chunk_m=ENV_CHUNK_M)
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.standard_normal(ds.X.shape[1]).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(ds.X.shape[0]).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fc.matvec(v)), np.asarray(X @ v),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fc.rmatvec(w)), np.asarray(X.T @ w),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fc.row_sq()),
                               np.asarray(jnp.sum(X * X, axis=1)),
                               rtol=2e-4, atol=2e-4)

    lmax = float(lambda_max(X, y))
    theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
    keep_d, bounds_d = screen(X, y, lmax, 0.6 * lmax, theta1)
    keep_s, bounds_s = screen_stream(fc, ds.y, lmax, 0.6 * lmax, theta1)
    np.testing.assert_allclose(np.asarray(bounds_s), np.asarray(bounds_d),
                               rtol=2e-4, atol=2e-4)
    # decisions agree away from the tau boundary (the tau margin is sized
    # to absorb exactly this class of reassociation noise)
    mism = int(np.sum(np.asarray(keep_s) != np.asarray(keep_d)))
    assert mism <= 2, mism


def test_bcoo_solver_matches_dense(sparse_inst):
    ds, X, y = sparse_inst
    lam = 0.3 * float(lambda_max(X, y))
    ref = fista_solve(X, y, lam, max_iters=20000, tol=1e-10)
    fc = FeatureChunked.from_csr(ds.csr, chunk_m=ENV_CHUNK_M)
    ch = fista_solve_chunked(fc, ds.y, lam, max_iters=20000, tol=1e-10)
    assert abs(float(ch.obj) - float(ref.obj)) / float(ref.obj) < 1e-5


# -- C5: memory-shape property ------------------------------------------------

def _walk_avals(jaxpr):
    """All intermediate avals of a (closed) jaxpr, sub-jaxprs included."""
    for eqn in jaxpr.eqns:
        for v in (*eqn.invars, *eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                yield aval
        for sub in jax.core.jaxprs_in_params(eqn.params) \
                if hasattr(jax.core, "jaxprs_in_params") else []:
            yield from _walk_avals(sub)
        for name in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
            sub = eqn.params.get(name)
            if sub is not None:
                yield from _walk_avals(getattr(sub, "jaxpr", sub))
        for sub in eqn.params.get("branches", ()) or ():
            yield from _walk_avals(getattr(sub, "jaxpr", sub))


def test_no_full_matrix_in_chunk_jaxprs(dense_inst):
    """No per-chunk kernel ever traces a (m, n)-sized intermediate."""
    ds, _, _ = dense_inst
    m, n = ds.X.shape
    chunk_m = ENV_CHUNK_M if ENV_CHUNK_M < m else 64
    from repro.core.screening import _row_stable_reductions, row_dot
    from repro.sparse.chunked import _chunk_csq, _chunk_mv, _chunk_rmv, \
        _chunk_sq

    Xc = jnp.zeros((chunk_m, n), jnp.float32)
    v = jnp.zeros((n,), jnp.float32)
    wc = jnp.zeros((chunk_m,), jnp.float32)
    traced = [
        jax.make_jaxpr(_chunk_mv)(Xc, v),
        jax.make_jaxpr(_chunk_rmv)(Xc, wc),
        jax.make_jaxpr(_chunk_sq)(Xc),
        jax.make_jaxpr(_chunk_csq)(Xc),
        jax.make_jaxpr(row_dot)(Xc, v),
        jax.make_jaxpr(_row_stable_reductions)(Xc, v, v),
    ]
    cap = chunk_m * n  # one chunk; the (m, n) matrix is m//chunk_m x larger
    for jx in traced:
        for aval in _walk_avals(jx.jaxpr):
            assert int(np.prod(aval.shape or (1,))) <= cap, (
                f"chunk kernel traced an aval of shape {aval.shape} "
                f"(> one chunk {chunk_m}x{n})"
            )
            assert tuple(aval.shape) != (m, n)


def test_stream_stats_observe_device_contract(dense_inst):
    """A chunk_m << m run never puts more than chunk_m rows at once."""
    ds, _, _ = dense_inst
    m = ds.X.shape[0]
    chunk_m = 48
    fc = FeatureChunked.from_dense(ds.X, chunk_m=chunk_m)
    stream_feature_reductions(fc, ds.y, jnp.zeros((ds.X.shape[1],)))
    fista_solve_chunked(fc, ds.y, 1.0, max_iters=5, tol=0.0)
    assert fc.stats["puts"] > 0
    assert fc.stats["max_put_rows"] == chunk_m < m


# -- C6: chunked path ---------------------------------------------------------

def test_chunked_path_matches_host(dense_inst):
    ds, X, y = dense_inst
    from repro.core.solver import lipschitz_estimate

    # shared L isolates storage (see PathDriver docstring); grids already
    # match bitwise via the row-stable lambda_max
    L = lipschitz_estimate(X)
    kw = dict(rules="feature_vi", tol=1e-10, max_iters=20000, L=L)
    grid = dict(n_lambdas=6, lam_min_ratio=0.1)
    host = PathDriver(**kw).run(ds.X, ds.y, **grid)
    fc = FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M)
    ch = PathDriver(**kw).run(fc, ds.y, **grid)
    np.testing.assert_array_equal(host.lambdas, ch.lambdas)
    rel = np.max(np.abs(host.objectives - ch.objectives)
                 / np.maximum(np.abs(host.objectives), 1.0))
    assert rel < 1e-6, rel
    np.testing.assert_allclose(ch.weights, host.weights, atol=1e-3)
    assert ch.extras["storage"] == "chunked"
    assert ch.extras["stream_stats"]["max_put_rows"] <= max(ENV_CHUNK_M, 64)


def test_chunked_path_self_contained(dense_inst):
    """No in-core inputs at all: streamed L, streamed certification."""
    ds, X, y = dense_inst
    kw = dict(rules="feature_vi", tol=1e-10, max_iters=20000)
    grid = dict(n_lambdas=5, lam_min_ratio=0.15)
    host = PathDriver(**kw).run(ds.X, ds.y, **grid)
    ch = PathDriver(**kw).run(
        FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M), ds.y, **grid)
    rel = np.max(np.abs(host.objectives - ch.objectives)
                 / np.maximum(np.abs(host.objectives), 1.0))
    assert rel < 1e-5, rel  # fp32 plateau floor (see PathDriver docstring)


def test_chunked_path_sample_rules_match_host(dense_inst):
    """sifs (EDPP feature half + verified sample half) out of core: the
    transposed sweep feeds the margins, verification rides the carried u."""
    ds, X, y = dense_inst
    from repro.core.solver import lipschitz_estimate

    L = lipschitz_estimate(X)
    kw = dict(rules="sifs", tol=1e-10, max_iters=20000, L=L)
    grid = dict(n_lambdas=10, lam_min_ratio=0.02)
    host = PathDriver(**kw).run(ds.X, ds.y, **grid)
    ch = PathDriver(**kw).run(
        FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M), ds.y, **grid)
    rel = np.max(np.abs(host.objectives - ch.objectives)
                 / np.maximum(np.abs(host.objectives), 1.0))
    assert rel < 1e-6, rel  # verification makes any sample screen exact
    assert "sample_masks" in ch.extras
    np.testing.assert_array_equal(ch.kept_samples, host.kept_samples)
    n = ds.X.shape[1]
    assert np.any(ch.kept_samples[1:] < n)  # the screen actually fires


def test_chunked_path_dynamic_matches_host(dense_inst):
    """dynamic=True routes to the segmented streamed solver; objectives
    still match the (non-dynamic) host path."""
    ds, X, y = dense_inst
    kw = dict(rules="feature_vi", tol=1e-10, max_iters=20000)
    grid = dict(n_lambdas=5, lam_min_ratio=0.15)
    host = PathDriver(**kw).run(ds.X, ds.y, **grid)
    ch = PathDriver(dynamic=True, screen_every=40, **kw).run(
        FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M), ds.y, **grid)
    rel = np.max(np.abs(host.objectives - ch.objectives)
                 / np.maximum(np.abs(host.objectives), 1.0))
    assert rel < 1e-5, rel
    assert "dynamic" in ch.extras


def test_chunked_path_rejects_unsupported_configs(dense_inst):
    ds, _, _ = dense_inst
    fc = FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M)
    with pytest.raises(ValueError, match="gather"):
        PathDriver(rules="feature_vi", reduce="mask").run(fc, ds.y)

    from repro.core.rules.base import (AXIS_FEATURES, AXIS_SAMPLES,
                                       ScreeningRule)

    class _NoProgram(ScreeningRule):
        axis = AXIS_FEATURES

        def bounds(self, X, y, region):  # pragma: no cover - never reached
            raise NotImplementedError

    with pytest.raises(ValueError, match="feature rule"):
        PathDriver(rules=[_NoProgram()]).run(fc, ds.y)

    class _OddSample(ScreeningRule):
        axis = AXIS_SAMPLES

        def bounds(self, X, y, region):  # pragma: no cover - never reached
            raise NotImplementedError

    with pytest.raises(ValueError, match="SampleVIRule"):
        PathDriver(rules=[_OddSample()]).run(fc, ds.y)
    from repro.core import svm_path

    with pytest.raises(ValueError, match="scan"):
        svm_path(fc, ds.y, engine="scan")


# -- C8: chunk-skip data plane + disk-resident store --------------------------

def test_chunk_skip_bitwise_vs_full_stream(planted_inst):
    """The gated path is the full-stream path minus transfers: identical
    gating/cache policy in both modes, so objectives, weights and kept
    counts are bitwise equal while the skip side streams strictly fewer
    chunks (and actually skips some)."""
    X, y = planted_inst
    kw = dict(rules="feature_vi", tol=1e-9, max_iters=8000)
    grid = dict(n_lambdas=8, lam_min_ratio=0.05)
    fc_skip = FeatureChunked.from_dense(X, chunk_m=32)
    r_skip = PathDriver(chunk_skip=True, **kw).run(fc_skip, y, **grid)
    fc_full = FeatureChunked.from_dense(X, chunk_m=32)
    r_full = PathDriver(chunk_skip=False, **kw).run(fc_full, y, **grid)

    np.testing.assert_array_equal(r_skip.objectives, r_full.objectives)
    np.testing.assert_array_equal(r_skip.weights, r_full.weights)
    np.testing.assert_array_equal(r_skip.kept, r_full.kept)

    st = r_skip.extras["stream_stats"]
    assert st["chunks_skipped"] > 0
    assert st["chunks_streamed"] < r_full.extras["stream_stats"][
        "chunks_streamed"]
    assert st["bytes_put"] < r_full.extras["stream_stats"]["bytes_put"]
    # gating visibly shrank the live set on some step
    assert int(np.min(r_skip.extras["live_chunks"])) < fc_skip.n_chunks
    assert r_skip.extras["chunk_skip"] and not r_full.extras["chunk_skip"]


def test_skipped_chunk_bounds_safe(planted_inst):
    """Safety property of chunk gating: every chunk the cache declares dead
    has (a) all stamped stale bounds below tau, and (b) a fresh full sweep
    from the same anchor agrees — no feature the fresh screen would keep is
    ever gated away. With identical anchors the gated and fresh sweeps
    produce the same keep decisions."""
    X, y = planted_inst
    fc = FeatureChunked.from_dense(X, chunk_m=32)
    lmax = float(lambda_max_stream(fc, y))
    theta1 = theta_at_lambda_max(jnp.asarray(y), jnp.asarray(lmax))

    cache = ChunkScreenCache(fc)
    # first gated step: empty cache, every chunk streams + refreshes
    screen_step_stream(fc, y, lmax, 0.7 * lmax, theta1, cache=cache)
    # second step re-uses the cached (lmax, theta1) anchors for gating
    keep_g, bounds_g, _, live = screen_step_stream(
        fc, y, lmax, 0.5 * lmax, theta1, cache=cache)
    assert not live.all(), "planted instance must trigger gating"
    assert live.any()

    keep_f, bounds_f = screen_stream(
        FeatureChunked.from_dense(X, chunk_m=32), y, lmax, 0.5 * lmax, theta1)
    from repro.core.screening import SAFE_TAU

    bounds_g, bounds_f = np.asarray(bounds_g), np.asarray(bounds_f)
    for i in np.nonzero(~live)[0]:
        s, e = fc.chunk_bounds(int(i))
        assert np.all(bounds_g[s:e] < SAFE_TAU)  # stamped bounds honest
        assert np.all(bounds_f[s:e] < SAFE_TAU)  # fresh sweep agrees
    np.testing.assert_array_equal(np.asarray(keep_g), np.asarray(keep_f))


def test_chunk_cache_refuses_larger_targets(planted_inst):
    """A cached region certifies only strictly smaller lambdas: gating at a
    target >= the cached anchor's lambda must declare every chunk live."""
    X, y = planted_inst
    fc = FeatureChunked.from_dense(X, chunk_m=32)
    lmax = float(lambda_max_stream(fc, y))
    theta1 = theta_at_lambda_max(jnp.asarray(y), jnp.asarray(lmax))
    cache = ChunkScreenCache(fc)
    screen_step_stream(fc, y, lmax, 0.6 * lmax, theta1, cache=cache)
    from repro.core.screening import fixed_stats
    from repro.sparse import fixed_reductions

    d_one, d_y, d_sq = fixed_reductions(fc, y)
    fixed = fixed_stats(jnp.asarray(y, fc.dtype), d_one, d_y, d_sq)
    live, _ = cache.live_mask(lmax, fixed)
    assert live.all()


def test_col_sq_matches_dense(sparse_inst):
    """The transposed reduction (CSR host scatter + dense kernel) and its
    memoization."""
    ds, X, _ = sparse_inst
    ref = np.asarray(jnp.sum(X * X, axis=0))
    fc = FeatureChunked.from_csr(ds.csr, chunk_m=ENV_CHUNK_M)
    np.testing.assert_allclose(np.asarray(fc.col_sq()), ref,
                               rtol=2e-4, atol=2e-4)
    assert fc.col_sq() is fc.col_sq()  # theta-independent: memoized
    fcd = FeatureChunked.from_dense(ds.X, chunk_m=ENV_CHUNK_M)
    np.testing.assert_allclose(np.asarray(fcd.col_sq()), ref,
                               rtol=2e-4, atol=2e-4)


_TOY_LIBSVM = (
    "+1 1:0.5 3:-2.0\n"
    "-1 2:1.25\n"
    "+1 1:3.0 4:0.125\n"
    "-1 3:0.75\n"
)


def test_memmap_store_roundtrip(tmp_path):
    p = tmp_path / "toy.svm"
    p.write_text(_TOY_LIBSVM)
    ref = load_libsvm(p)

    fc, yv = FeatureChunked.from_libsvm_cached(
        p, store_dir=tmp_path / "store", chunk_m=2)
    np.testing.assert_array_equal(np.asarray(fc.as_dense()), ref.X)
    np.testing.assert_array_equal(np.asarray(yv), ref.y)
    # second open re-uses the store (and may re-slice the chunking)
    fc2, y2 = FeatureChunked.from_libsvm_cached(
        p, store_dir=tmp_path / "store", chunk_m=3)
    np.testing.assert_array_equal(np.asarray(fc2.as_dense()), ref.X)
    np.testing.assert_array_equal(np.asarray(y2), ref.y)

    # gzip input builds the same store
    pgz = tmp_path / "toy.svm.gz"
    with gzip.open(pgz, "wt") as f:
        f.write(_TOY_LIBSVM)
    fcz, yz = FeatureChunked.from_libsvm_cached(
        pgz, store_dir=tmp_path / "gz_store", chunk_m=2)
    np.testing.assert_array_equal(np.asarray(fcz.as_dense()), ref.X)
    np.testing.assert_array_equal(np.asarray(yz), ref.y)


def test_memmap_store_runs_screened_path(tmp_path, planted_inst):
    """End to end: dense store on disk -> memmap container -> gated path."""
    X, y = planted_inst
    fc_mem = FeatureChunked.from_dense(X, chunk_m=32)
    store = tmp_path / "planted_store"
    fc_mem.save_store(store, y=y)
    fc = FeatureChunked.from_store(store, chunk_m=32)
    assert fc.labels is not None
    res = PathDriver(rules="feature_vi", tol=1e-9, max_iters=8000).run(
        fc, fc.labels, n_lambdas=6, lam_min_ratio=0.05)
    assert res.extras["stream_stats"]["chunks_skipped"] > 0
    ref = PathDriver(rules="feature_vi", tol=1e-9, max_iters=8000).run(
        FeatureChunked.from_dense(X, chunk_m=32), y,
        n_lambdas=6, lam_min_ratio=0.05)
    np.testing.assert_array_equal(res.objectives, ref.objectives)


# -- C7: data -----------------------------------------------------------------

def test_sparse_dataset_carries_exact_csr():
    ds = make_sparse_classification(m=64, n=40, density=0.3, seed=5)
    assert ds.csr is not None
    np.testing.assert_array_equal(ds.csr.to_dense(ds.X.dtype), ds.X)
    # sparsity is real (scale-only standardization keeps the zeros)
    assert 0.0 < ds.csr.density < 0.5
    dense = make_sparse_classification(m=64, n=40, seed=5)
    assert dense.csr is None


def test_libsvm_loader(tmp_path):
    p = tmp_path / "toy.svm"
    p.write_text(
        "+1 1:0.5 3:-2.0\n"
        "-1 2:1.25\n"
        "# comment line\n"
        "0 1:3.0 4:0.125  # trailing comment\n"
    )
    ds = load_libsvm(p)
    assert ds.X.shape == (4, 3)  # 4 features (max index), 3 samples
    np.testing.assert_array_equal(ds.y, [1.0, -1.0, -1.0])
    assert ds.X[0, 0] == np.float32(0.5)
    assert ds.X[2, 0] == np.float32(-2.0)
    assert ds.X[1, 1] == np.float32(1.25)
    assert ds.X[3, 2] == np.float32(0.125)
    assert ds.csr is not None and ds.csr.nnz == 5
    # n_features override + zero-based indexing
    ds2 = load_libsvm(p, n_features=6)
    assert ds2.X.shape == (6, 3)
    with pytest.raises(ValueError):
        load_libsvm(p, n_features=2)
    fc = FeatureChunked.from_csr(ds.csr, chunk_m=2)
    np.testing.assert_array_equal(fc.as_dense(), ds.X)


def test_libsvm_loader_gzip_and_dtype(tmp_path):
    p = tmp_path / "toy.svm"
    p.write_text(_TOY_LIBSVM)
    ref = load_libsvm(p)
    # gzip is detected from the magic bytes, not the extension
    pgz = tmp_path / "toy.svm.gz"
    with gzip.open(pgz, "wt") as f:
        f.write(_TOY_LIBSVM)
    dz = load_libsvm(pgz)
    np.testing.assert_array_equal(dz.X, ref.X)
    np.testing.assert_array_equal(dz.y, ref.y)
    # dtype override flows through X, y and the CSR view
    d64 = load_libsvm(p, dtype=np.float64)
    assert d64.X.dtype == np.float64
    np.testing.assert_allclose(d64.X, ref.X.astype(np.float64))
    assert d64.csr is not None and d64.csr.data.dtype == np.float64
