"""Fallback for the optional ``hypothesis`` dev dependency.

The property tests prefer real hypothesis (shrinking, example database,
adversarial generation) — install it via ``pip install -e .[dev]`` (see
pyproject.toml). When it is absent this module provides a minimal
deterministic stand-in so the safety properties still run in CI instead of
being skipped: ``@given`` draws a fixed number of pseudo-random examples per
test (seeded from the test name, so failures reproduce), and ``@settings``
honours ``max_examples`` up to a small cap to keep suite time bounded.

Usage in test modules::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                      # pragma: no cover
        from _hyp_compat import given, settings, st
"""

from __future__ import annotations

import functools
import inspect
import os
import zlib

import numpy as np

_MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_FALLBACK_MAX_EXAMPLES", "5"))


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


class _StModule:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)


st = _StModule()


def settings(max_examples: int = 10, **_ignored):
    """Records max_examples on the wrapped test (deadline etc. are no-ops)."""

    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    """Run the test once per drawn example (deterministic per test name)."""

    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = min(getattr(runner, "_hyp_max_examples",
                            getattr(fn, "_hyp_max_examples", 10)),
                    _MAX_EXAMPLES_CAP)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(max(n, 1)):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)

        # hide the drawn parameters from pytest's fixture resolution (wraps
        # copies __wrapped__, which inspect.signature would follow); keep any
        # parameters NOT supplied by strategies (real fixtures)
        orig = inspect.signature(fn)
        remaining = [p for name, p in orig.parameters.items()
                     if name not in strategies]
        runner.__signature__ = orig.replace(parameters=remaining)
        return runner

    return deco
