"""Rule-program zoo: host-vs-scan equivalence matrix, EDPP dominance,
engine error paths, and composite round-trips.

The tentpole contract under test: every a-priori-safe feature rule is ONE
implementation — a pure rule program (``core/rules/programs.py``) — whether
it runs through the host driver's OO protocol, the jitted scan/compact/
batched engines, the path server, or chunked storage. So the matrix here
asserts *objective* equality at tight tolerance across engines for every
registered program-backed rule, not just the paper's VI rule.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dual import lambda_max
from repro.core.path import PathDriver, svm_path
from repro.core.rules import (
    PROGRAMS,
    CompositeRule,
    EDPPRule,
    FeatureVIRule,
    available_rules,
    get_rule,
    make_rules,
    resolve_programs,
)
from repro.core.rules.base import AXIS_FEATURES
from repro.core.screening import anchor_stats, fixed_stats, screen_bounds

TOL = 1e-9


def _problem(m=150, n=90, seed=0, planted=0):
    rng = np.random.default_rng(seed)
    X = (rng.normal(size=(m, n)) / np.sqrt(n)).astype(np.float64)
    if planted:
        w = np.zeros(m)
        w[:planted] = rng.normal(size=planted) * 3
        y = np.sign(X.T @ w + 0.1 * rng.normal(size=n))
    else:
        y = np.sign(rng.normal(size=n))
        y[y == 0] = 1.0
    return jnp.asarray(X), jnp.asarray(y)


def _rel(a, b):
    return np.max(np.abs(np.asarray(a) - np.asarray(b))
                  / np.maximum(np.abs(np.asarray(b)), 1.0))


def _program_rule_names():
    """Every registered a-priori-safe feature rule that ships a program."""
    names = []
    for nm in available_rules():
        cls = get_rule(nm)
        if (getattr(cls, "program", None) in PROGRAMS
                and getattr(cls, "axis", None) == AXIS_FEATURES
                and not getattr(cls, "needs_verification", False)):
            names.append(nm)
    return sorted(names)


def test_program_registry_covers_the_zoo():
    names = _program_rule_names()
    assert {"feature_vi", "edpp", "dvi", "auto"} <= set(names)
    # containers and sample rules must NOT claim lowerability
    assert getattr(get_rule("sample_vi"), "program", None) not in PROGRAMS
    assert getattr(get_rule("composite"), "program", None) not in PROGRAMS
    assert getattr(get_rule("sifs"), "program", None) not in PROGRAMS


# -- the host-vs-scan equivalence matrix ---------------------------------


@pytest.mark.parametrize("rule_name", _program_rule_names())
@pytest.mark.parametrize("reduce", ["mask", "compact"])
def test_host_vs_scan_equivalence(rule_name, reduce):
    """Each program-backed rule solves the same path on host and scan
    engines (mask AND compact reductions) to matching objectives."""
    X, y = _problem(seed=3)
    host = svm_path(X, y, n_lambdas=6, lam_min_ratio=0.3, rules=rule_name,
                    engine="host", tol=TOL)
    scan = svm_path(X, y, n_lambdas=6, lam_min_ratio=0.3, rules=rule_name,
                    engine="scan", reduce=reduce, tol=TOL)
    assert _rel(scan.objectives, host.objectives) < 1e-6, rule_name
    assert scan.screened and host.screened
    # resolved program stack is reported (auto statically resolves to edpp)
    expected = resolve_programs(rule_name)
    assert tuple(scan.rules) == expected


@pytest.mark.parametrize("rule_name", _program_rule_names())
def test_batched_grids_equivalence(rule_name):
    """The batched engine (B grids, one problem) matches the single-path
    scan engine per element for every program-backed rule."""
    X, y = _problem(seed=5)
    lmax = float(lambda_max(X, y))
    grids = np.stack([np.geomspace(1.0, 0.3, 5),
                      np.geomspace(1.0, 0.5, 5)]) * lmax
    batched = svm_path(X, y, lambdas=grids, rules=rule_name,
                       engine="batched", tol=TOL)
    for i in range(2):
        seq = svm_path(X, y, lambdas=grids[i], rules=rule_name,
                       engine="scan", tol=TOL)
        assert _rel(batched[i].objectives, seq.objectives) < 1e-6, rule_name


# -- EDPP dominance -------------------------------------------------------


def test_edpp_bound_dominates_vi_same_region():
    """Unit level: on the SAME anchor, the EDPP program's bound is
    everywhere <= the VI program's (guaranteed by min-composition), so its
    keep set is a subset at any tau."""
    X, y = _problem(seed=7)
    lam1 = float(lambda_max(X, y))
    lam2 = 0.5 * lam1
    from repro.core.dual import theta_at_lambda_max
    theta1 = theta_at_lambda_max(y, jnp.asarray(lam1, X.dtype))
    d_theta = X @ (y * theta1)
    red_one = X @ y
    red_y = X @ jnp.ones_like(y)
    red_sq = jnp.sum(X * X, axis=1)
    fixed = fixed_stats(y, red_one, red_y, red_sq)
    a1 = anchor_stats(y, lam1, theta1, 0.0, d_theta)
    b_vi = PROGRAMS["feature_vi"].bounds(jnp.asarray(lam2), (a1,), fixed)
    b_edpp = PROGRAMS["edpp"].bounds(jnp.asarray(lam2), (a1,), fixed)
    assert bool(jnp.all(b_edpp <= b_vi + 1e-12))
    # and the VI program reproduces the reference bound (same math; the
    # reference route is jitted, so equality is to ulp-level tolerance)
    ref = screen_bounds(X, y, lam1, lam2, theta1, delta=0.0)
    np.testing.assert_allclose(np.asarray(b_vi), np.asarray(ref), rtol=1e-6)


def test_edpp_tightens_vi_on_path():
    """Path level: on a screen-effective instance EDPP keeps a strict
    subset of VI's keeps at every step (strictly fewer in total), while
    both paths solve to identical objectives."""
    X, y = _problem(m=600, n=200, seed=0, planted=10)
    vi = svm_path(X, y, engine="scan", n_lambdas=10, lam_min_ratio=0.3,
                  rules="feature_vi", tol=TOL)
    ed = svm_path(X, y, engine="scan", n_lambdas=10, lam_min_ratio=0.3,
                  rules="edpp", tol=TOL)
    mv = vi.extras["keep_masks"]
    me = ed.extras["keep_masks"]
    for t in range(len(vi.lambdas)):
        assert bool(np.all(me[t] <= mv[t])), f"step {t}: EDPP kept ⊄ VI kept"
    assert int(ed.kept.sum()) < int(vi.kept.sum())
    assert _rel(ed.objectives, vi.objectives) < 1e-9


def test_dvi_scan_matches_host_with_history():
    """The dvi carry (old anchor riding the scan carry) reproduces the
    host DVIRule's stateful anchor pair: same keeps, same objectives."""
    X, y = _problem(m=300, n=120, seed=11, planted=8)
    host = svm_path(X, y, n_lambdas=8, lam_min_ratio=0.3, rules="dvi",
                    engine="host", tol=TOL)
    scan = svm_path(X, y, n_lambdas=8, lam_min_ratio=0.3, rules="dvi",
                    engine="scan", tol=TOL)
    assert _rel(scan.objectives, host.objectives) < 1e-6
    np.testing.assert_array_equal(scan.kept[1:], host.kept[1:])


# -- composite round-trip (satellite: container-only bounds error) --------


def test_composite_feature_stack_roundtrips_host_and_scan():
    """A composite of *feature* rules flattens through make_rules() at
    every call site — neither engine ever calls the container's bounds —
    and the identical spec solves identically on host and scan."""
    spec = CompositeRule([FeatureVIRule(), EDPPRule()])
    assert resolve_programs(spec) == ("feature_vi", "edpp")
    X, y = _problem(seed=13)
    host = svm_path(X, y, n_lambdas=6, lam_min_ratio=0.3, rules=[spec],
                    engine="host", tol=TOL)
    scan = svm_path(X, y, n_lambdas=6, lam_min_ratio=0.3, rules=[spec],
                    engine="scan", tol=TOL)
    # the container spec and its hand-flattened list resolve to the SAME
    # static options, hence the same cached engine: bitwise identical
    flat = svm_path(X, y, n_lambdas=6, lam_min_ratio=0.3,
                    rules=["feature_vi", "edpp"], engine="scan", tol=TOL)
    np.testing.assert_array_equal(np.asarray(scan.objectives),
                                  np.asarray(flat.objectives))
    np.testing.assert_array_equal(scan.kept, flat.kept)
    # host and scan agree to (fp32 gather- vs mask-mode) solver tolerance;
    # kept counts may flip marginal features between the two float paths
    assert _rel(scan.objectives, host.objectives) < 1e-4
    assert tuple(scan.rules) == ("feature_vi", "edpp")
    assert tuple(host.rules) == ("feature_vi", "edpp")
    # the container itself still refuses direct bounds evaluation
    with pytest.raises(NotImplementedError, match="container"):
        spec.bounds(X, y, None)
    # and flattening is what both engines actually consumed
    assert [r.name for r in make_rules([spec])] == ["feature_vi", "edpp"]


# -- error paths: unsupported configs fail at dispatch --------------------


def test_scan_rejects_sample_rules_at_dispatch():
    X, y = _problem(m=40, n=24, seed=1)
    with pytest.raises(ValueError, match="feature rule only"):
        svm_path(X, y, n_lambdas=3, engine="scan", rules="sample_vi")
    with pytest.raises(ValueError, match="feature rule only"):
        svm_path(X, y, n_lambdas=3, engine="scan", rules="sifs")
    with pytest.raises(ValueError, match="feature rule only"):
        svm_path(X, y, n_lambdas=3, engine="batched",
                 lambdas=np.array([[1.0, 0.5]]), rules="composite")


def test_sharded_rejects_dynamic_at_dispatch():
    from repro.core.distributed import svm_mesh
    from repro.core.path_scan import svm_path_scan_sharded

    X, y = _problem(m=40, n=24, seed=1)
    with pytest.raises(ValueError, match="sharded"):
        svm_path_scan_sharded(svm_mesh(1, 1), X, y, n_lambdas=3,
                              dynamic=True)


def test_server_rejects_anchor_history_rules():
    from repro.launch.path_server import PathJob

    job = PathJob(jid=0, X=np.eye(8, dtype=np.float32),
                  y=np.ones(8, np.float32), rules="dvi")
    with pytest.raises(ValueError, match="anchor history"):
        job.group_key()


def test_chunked_runs_composite_sample_rules():
    # chunked storage grew a transposed streamed sweep, so sample rules
    # now run out-of-core instead of failing at dispatch: composite
    # (feature VI + verified samples) must match the dense host driver.
    # (Deeper coverage lives in tests/test_sparse_stream.py.)
    from repro.sparse import FeatureChunked

    X, y = _problem(m=60, n=40, seed=2)
    X_np, y_np = np.asarray(X), np.asarray(y)
    fc = FeatureChunked.from_dense(X_np, chunk_m=32)
    chunked = PathDriver(rules="composite", tol=TOL).run(
        fc, y_np, n_lambdas=3, lam_min_ratio=0.3)
    dense = PathDriver(rules="composite", tol=TOL).run(
        X, y, n_lambdas=3, lam_min_ratio=0.3)
    assert _rel(chunked.objectives, dense.objectives) < 1e-5
    np.testing.assert_array_equal(chunked.kept_samples, dense.kept_samples)


# -- chunked storage runs the program stacks ------------------------------


@pytest.mark.parametrize("rule_name", ["edpp", "dvi"])
def test_chunked_stack_matches_dense_host(rule_name):
    from repro.sparse import FeatureChunked

    X, y = _problem(m=120, n=80, seed=17)
    X_np, y_np = np.asarray(X), np.asarray(y)
    fc = FeatureChunked.from_dense(X_np, chunk_m=48)
    chunked = PathDriver(rules=rule_name, tol=TOL).run(
        fc, y_np, n_lambdas=5, lam_min_ratio=0.3)
    dense = PathDriver(rules=rule_name, tol=TOL).run(
        X, y, n_lambdas=5, lam_min_ratio=0.3)
    assert _rel(chunked.objectives, dense.objectives) < 1e-5
    np.testing.assert_array_equal(chunked.kept[1:], dense.kept[1:])


# -- auto rule: telemetry-driven stacks -----------------------------------


def test_auto_rule_telemetry_and_equivalence():
    """rules='auto' on the host driver records per-step telemetry, feeds
    the driver's observe hook, and solves the same path as feature_vi."""
    from repro.core.rules import AutoRule

    X, y = _problem(m=300, n=120, seed=19, planted=8)
    rule = AutoRule(probe_every=2)
    auto = PathDriver(rules=[rule], tol=TOL).run(
        X, y, n_lambdas=8, lam_min_ratio=0.3)
    ref = PathDriver(rules="feature_vi", tol=TOL).run(
        X, y, n_lambdas=8, lam_min_ratio=0.3)
    assert _rel(auto.objectives, ref.objectives) < 1e-6
    # auto's keeps are never looser than VI's (EDPP floor dominates)
    assert int(auto.kept[1:].sum()) <= int(ref.kept[1:].sum())
    # telemetry: one record per screened step, observe() fed the EMA
    assert len(rule.telemetry) == len(auto.lambdas) - 1
    assert rule._solve_per_feat is not None and rule._solve_per_feat > 0
    # the driver surfaced per-rule stats too
    tele = auto.extras["rule_telemetry"]
    assert len(tele) == len(auto.lambdas)
    assert all("auto" in t for t in tele[1:])
    assert all(t["auto"]["kept"] == int(k)
               for t, k in zip(tele[1:], auto.kept[1:]))
