"""Unified observability layer: span recorder round-trips, disabled-mode
no-op guarantees, the metrics registry mirroring the legacy stats dicts
bitwise, and the uniform PathTrace artifact across engines."""

import json

import numpy as np
import pytest

from repro.core import PathDriver, svm_path
from repro.data import make_sparse_classification
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.path_trace import PathStep, PathTrace, build_path_trace
from repro.obs.trace import NOOP_SPAN, Tracer
from repro.sparse import FeatureChunked

SOLVE = dict(tol=1e-9, max_iters=4000)


@pytest.fixture()
def tracer():
    """A private enabled tracer (does not touch the process singleton)."""
    return Tracer(enabled=True)


@pytest.fixture(autouse=True)
def _quiet_registry():
    """Reset the process registry around every test so counter equality
    checks see only this test's increments."""
    obs_metrics.reset()
    yield
    obs_metrics.reset()


@pytest.fixture(scope="module")
def ds():
    return make_sparse_classification(m=120, n=60, k_active=8, seed=0)


# -- span recorder ----------------------------------------------------------


def test_span_nesting_and_export_roundtrip(tracer, tmp_path):
    """Nested spans land as complete events whose intervals nest, attrs
    ride args, and the exported file is valid Chrome trace JSON."""
    with tracer.span("outer", step=1):
        with tracer.span("inner", phase="solve"):
            pass
        tracer.instant("marker", note="hi")
    evs = tracer.events
    names = [e["name"] for e in evs]
    assert names == ["inner", "marker", "outer"]  # exit order records
    inner = evs[0]
    outer = evs[2]
    assert outer["ph"] == "X" and inner["ph"] == "X"
    assert outer["args"] == {"step": 1}
    assert inner["args"] == {"phase": "solve"}
    # nesting: inner's interval sits inside outer's
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3

    out = tmp_path / "trace.json"
    tracer.export_chrome(out)
    doc = json.loads(out.read_text())
    assert "traceEvents" in doc
    byname = {e["name"]: e for e in doc["traceEvents"]}
    assert byname["process_name"]["ph"] == "M"
    assert byname["outer"]["args"] == {"step": 1}
    assert byname["marker"]["ph"] == "i"
    # every event is pid-stamped (Perfetto groups by pid/tid)
    assert all("pid" in e for e in doc["traceEvents"])


def test_span_set_attaches_attrs_mid_span(tracer):
    with tracer.span("solve") as sp:
        sp.set(iters=17)
    (ev,) = tracer.events
    assert ev["args"] == {"iters": 17}


def test_disabled_mode_is_noop_singleton():
    """Disabled tracing must allocate nothing on the hot path: span()
    returns the shared no-op singleton and nothing is recorded."""
    t = Tracer(enabled=False)
    assert t.span("solve", step=1) is NOOP_SPAN
    assert t.span("other") is NOOP_SPAN  # same object every call
    with t.span("solve"):
        t.instant("marker")
    t.add_complete_event("post", 0.0, 1.0)
    assert t.events == []

    # module-level fast path honors the process tracer's switch
    was = obs_trace.enabled()
    obs_trace.disable()
    try:
        assert obs_trace.span("x") is NOOP_SPAN
        n0 = len(obs_trace.get_tracer().events)
        obs_trace.complete("x", 0.0, 1.0)
        obs_trace.instant("x")
        assert len(obs_trace.get_tracer().events) == n0
    finally:
        if was:
            obs_trace.enable()


def test_thread_safety_under_concurrent_spans(tracer):
    import threading

    barrier = threading.Barrier(4)  # all four alive at once: distinct tids

    def work(i):
        barrier.wait()
        for k in range(50):
            with tracer.span("w", tid_hint=i, k=k):
                pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    evs = tracer.events
    assert len(evs) == 200
    assert len({e["tid"] for e in evs}) == 4


# -- metrics registry -------------------------------------------------------


def test_metric_kinds_and_dumps():
    c = obs_metrics.counter("t.count")
    c.inc()
    c.inc(4)
    obs_metrics.gauge("t.gauge").set_max(7)
    obs_metrics.gauge("t.gauge").set_max(3)  # keeps the max
    h = obs_metrics.histogram("t.hist")
    for v in (1.0, 3.0):
        h.observe(v)
    snap = obs_metrics.snapshot()
    assert snap["t.count"] == 5
    assert snap["t.gauge"] == 7
    assert snap["t.hist"] == {"count": 2, "sum": 4.0, "min": 1.0,
                              "max": 3.0, "mean": 2.0}
    # kind collisions are typed errors, not silent re-registration
    with pytest.raises(TypeError):
        obs_metrics.gauge("t.count")
    doc = json.loads(obs_metrics.to_json())
    assert doc["t.count"] == 5
    prom = obs_metrics.to_prometheus()
    assert "repro_t_count_total 5" in prom
    assert "repro_t_hist_count 2" in prom


def test_registry_mirrors_stream_stats_bitwise(ds):
    """The stream.* counters must equal FeatureChunked's legacy stats dict
    exactly after a chunked path run — same increments, one API."""
    fc = FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=32)
    driver = PathDriver(**SOLVE)
    driver.run(fc, ds.y, n_lambdas=4)
    snap = obs_metrics.snapshot()
    for key in ("puts", "chunks_streamed", "chunks_skipped", "bytes_put",
                "bcoo_puts"):
        # counters register lazily; never-incremented ones read 0
        assert snap.get(f"stream.{key}", 0) == fc.stats[key], key
    assert snap["stream.max_put_rows"] == fc.stats["max_put_rows"]


def test_registry_mirrors_server_stats_bitwise(ds):
    """Every serve.* counter must equal the server's legacy stats dict
    after a drain, and metrics() returns the unified snapshot with the
    cache state absorbed."""
    from repro.launch.path_server import PathServer, demo_jobs

    server = PathServer(slots=2, **SOLVE)
    jobs = demo_jobs(3, m=60, n=40, seed=1)
    results = server.serve(jobs, log=lambda *a, **k: None)
    assert all(r is not None for r in results)
    snap = server.metrics()
    for key, val in server.stats.items():
        # counters register lazily; never-incremented ones read 0
        assert snap.get(f"serve.{key}", 0) == val, key
    cs = server.cache_stats()
    for key, val in cs.items():
        assert snap[f"serve.cache.{key}"] == val, key
    assert snap["serve.latency_s"]["count"] == len(jobs)
    # the path.* counters aggregate the assembled per-job traces
    assert snap["path.steps"] == sum(len(j.lambdas) for j in jobs)


# -- PathTrace --------------------------------------------------------------


def _trace_of(r):
    pt = r.extras["path_trace"]
    assert isinstance(pt, PathTrace)
    return pt


def _assert_schema(pt, T):
    assert len(pt.steps) == T
    for k, s in enumerate(pt.steps):
        assert isinstance(s, PathStep)
        assert s.step == k
        assert s.kept >= 0 and s.iters >= 0
    assert pt.total_s >= 0.0
    d = pt.to_dict()
    json.dumps(d)  # plain data, artifact-ready


def test_path_trace_uniform_across_engines(ds):
    """host, scan, and serve runs must all attach the SAME PathTrace
    schema: one record per lambda, matching grids, engine-tagged."""
    from repro.launch.path_server import PathJob, PathServer

    T = 4
    host = svm_path(ds.X, ds.y, n_lambdas=T, engine="host", **SOLVE)
    scan = svm_path(ds.X, ds.y, n_lambdas=T, engine="scan", **SOLVE)
    server = PathServer(slots=1, **SOLVE)
    job = PathJob(jid=0, X=np.asarray(ds.X), y=np.asarray(ds.y),
                  lambdas=np.asarray(host.lambdas))
    (serve,) = server.serve([job], log=lambda *a, **k: None)

    traces = {"host": _trace_of(host), "scan": _trace_of(scan),
              "serve": _trace_of(serve)}
    for name, pt in traces.items():
        assert pt.engine == name
        _assert_schema(pt, T)
        np.testing.assert_allclose([s.lam for s in pt.steps], host.lambdas)
    # host engines measure walls; single-dispatch engines synthesize them
    assert traces["host"].walls_observed
    assert not traces["scan"].walls_observed
    assert not traces["serve"].walls_observed
    # host phase walls are real measurements that add up inside the step
    for s in traces["host"].steps:
        assert np.isfinite(s.screen_s) and np.isfinite(s.certify_s)
        assert s.screen_s + s.solve_s + s.certify_s <= s.wall_s + 1e-6
    # the server's shared latency field equals the job's extras bookkeeping
    assert traces["serve"].total_s == pytest.approx(
        serve.extras["latency_s"])
    assert traces["serve"].meta["jid"] == 0


def test_path_trace_chunked_engine(ds):
    fc = FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=32)
    r = PathDriver(**SOLVE).run(fc, ds.y, n_lambdas=4)
    pt = _trace_of(r)
    assert pt.engine == "chunked"
    _assert_schema(pt, 4)
    assert pt.walls_observed
    assert pt.meta["storage"] == "chunked"


def test_path_trace_emits_synthesized_spans(ds):
    """A single-dispatch engine's PathTrace must synthesize per-step spans
    into an enabled tracer (Chrome 'X' events tiling the dispatch wall)."""
    pt = build_path_trace(
        "scan", [1.0, 0.5], [3, 5], None, [1, 2], [10, 20],
        [0.5, 0.5], total_s=1.0, walls_observed=False)
    t = Tracer(enabled=True)
    pt.emit_to_tracer(t)
    evs = [e for e in t.events if e["name"] == "scan.step"]
    assert len(evs) == 2
    # steps tile contiguously and end at the emit time
    assert evs[0]["ts"] + evs[0]["dur"] == pytest.approx(evs[1]["ts"])
    # a disabled tracer records nothing
    t2 = Tracer(enabled=False)
    pt.emit_to_tracer(t2)
    assert t2.events == []
