import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional dev dependency (pip install -e .[dev]); the optimizer property
# tests leans hardest on hypothesis' numeric edge cases, so skip the module
# rather than run a weakened fallback (cf. tests/_hyp_compat.py used by the
# core screening/solver suites)
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    int8_compress,
    int8_decompress,
)


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 1.0, 1.0])
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(grads, state, params, lr=0.05,
                                        weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(gn), np.sqrt(800.0), rtol=1e-5)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), 1e-3, 10, 100)) for s in range(101)]
    assert lrs[0] < lrs[10]                      # warmup
    assert abs(lrs[10] - 1e-3) < 1e-6            # peak
    assert lrs[100] < lrs[50] < lrs[10]          # decay
    assert lrs[100] >= 1e-4 - 1e-9               # min ratio floor


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-3, 1e3))
def test_int8_compression_unbiased_and_bounded(seed, scale):
    key = jax.random.PRNGKey(seed)
    x = scale * jax.random.normal(key, (256,))
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), 64)
    dec = jnp.stack([int8_decompress(*int8_compress(x, k)) for k in keys])
    err = jnp.abs(jnp.mean(dec, axis=0) - x)
    step = scale * jnp.max(jnp.abs(x)) / 127.0 / scale  # one quant step
    q_step = float(jnp.max(jnp.abs(x))) / 127.0
    # stochastic rounding is unbiased: the MC mean converges to x
    assert float(jnp.max(err)) < 0.6 * q_step
    # and each sample is within one quantization step
    assert float(jnp.max(jnp.abs(dec[0] - x))) <= q_step * (1 + 1e-5)


def test_error_feedback_converges():
    """With error feedback, the accumulated compressed sum tracks the true sum."""
    from repro.optim.compression import int8_compress, int8_decompress

    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (128,)) * 0.01
    err = jnp.zeros_like(x)
    acc_c, acc_t = jnp.zeros_like(x), jnp.zeros_like(x)
    for i in range(50):
        xe = x + err
        q, s = int8_compress(xe, jax.random.fold_in(rng, i))
        dec = int8_decompress(q, s)
        err = xe - dec
        acc_c += dec
        acc_t += x
    rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.02
