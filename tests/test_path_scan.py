"""On-device path engine: scan-vs-host equivalence, compact-vs-mask
reduction equivalence (incl. the overflow fallback), the sharded scan's
bitwise port check, Pallas-vs-XLA solver equivalence (interpret mode), the
shared-Lipschitz upper-bound property, and batched-vs-single equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PathDriver,
    compact_caps,
    compact_caps_batched,
    fista_solve,
    lambda_max,
    lipschitz_estimate,
    svm_path,
    svm_path_batched,
    svm_path_scan,
    svm_path_scan_sharded,
)
from repro.core.distributed import svm_mesh
from repro.data import make_sparse_classification

GRID = dict(n_lambdas=6, lam_min_ratio=0.15)
SOLVE = dict(tol=1e-11, max_iters=20000)


@pytest.fixture(scope="module")
def ds():
    return make_sparse_classification(m=300, n=120, k_active=10, seed=41)


@pytest.fixture(scope="module")
def host_path(ds):
    return PathDriver(rules="feature_vi", **SOLVE).run(ds.X, ds.y, **GRID)


@pytest.fixture(scope="module")
def scan_path(ds):
    return svm_path_scan(ds.X, ds.y, **GRID, **SOLVE)


def test_scan_matches_host_screened(host_path, scan_path):
    """Same grid, same solutions: objectives to 1e-6 (relative), weights to
    fp32 solver resolution."""
    np.testing.assert_allclose(scan_path.lambdas, host_path.lambdas)
    rel = np.max(np.abs(host_path.objectives - scan_path.objectives)
                 / np.maximum(np.abs(host_path.objectives), 1.0))
    assert rel < 1e-6, rel
    np.testing.assert_allclose(scan_path.weights, host_path.weights, atol=1e-3)
    np.testing.assert_allclose(scan_path.biases, host_path.biases, atol=1e-3)


def test_scan_matches_host_unscreened(ds):
    h = PathDriver(rules=[], **SOLVE).run(ds.X, ds.y, **GRID)
    s = svm_path_scan(ds.X, ds.y, screening=False, **GRID, **SOLVE)
    rel = np.max(np.abs(h.objectives - s.objectives)
                 / np.maximum(np.abs(h.objectives), 1.0))
    assert rel < 1e-6, rel
    assert np.all(s.kept == ds.X.shape[0])
    assert not s.screened


def test_scan_never_screens_an_active_feature(scan_path):
    """Safety end-to-end: a screened (masked-out) feature is never active."""
    for k in range(len(scan_path.lambdas)):
        assert scan_path.active[k] <= scan_path.kept[k]
    assert scan_path.extras["converged"].all()


def test_scan_dynamic_matches_sequential(ds, scan_path):
    dyn = svm_path_scan(ds.X, ds.y, dynamic=True, screen_every=25,
                        **GRID, **SOLVE)
    rel = np.max(np.abs(dyn.objectives - scan_path.objectives)
                 / np.maximum(np.abs(scan_path.objectives), 1.0))
    assert rel < 1e-6, rel


def test_svm_path_engine_dispatch(ds, scan_path):
    via = svm_path(ds.X, ds.y, engine="scan", **GRID, **SOLVE)
    np.testing.assert_allclose(via.objectives, scan_path.objectives, rtol=1e-7)
    assert via.extras["engine"] == "scan"
    with pytest.raises(ValueError, match="engine"):
        svm_path(ds.X, ds.y, engine="warp")
    with pytest.raises(ValueError, match="feature rule"):
        svm_path(ds.X, ds.y, engine="scan", rules="composite")


def test_scan_grid_validation(ds):
    with pytest.raises(ValueError, match="decreasing"):
        svm_path_scan(ds.X, ds.y, lambdas=[0.1, 0.2])
    with pytest.raises(ValueError, match="positive"):
        svm_path_scan(ds.X, ds.y, lambdas=[0.1, -0.2])


# ---------------------------------------------------------------------------
# Compact reduction: on-device active-set gather
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def compact_path(ds):
    return svm_path_scan(ds.X, ds.y, reduce="compact", **GRID, **SOLVE)


def test_compact_matches_mask_and_host(ds, scan_path, host_path, compact_path):
    """The gathered subproblem is the masked problem with screened rows
    physically absent: same inv_L, same iteration map => objectives match to
    solver resolution (a few fp32 ulps of the objective — the gathered GEMV
    reassociates, so the two trajectories stop 1-2 ulps apart; the bench
    instance records the <=1e-7 criterion, BENCH_screening.json) and
    weights to fp32 resolution."""
    for ref in (scan_path, host_path):
        rel = np.max(np.abs(ref.objectives - compact_path.objectives)
                     / np.maximum(np.abs(ref.objectives), 1.0))
        assert rel < 5e-7, rel
    np.testing.assert_allclose(compact_path.weights, scan_path.weights,
                               atol=1e-3)
    # screened features scatter back as exact zeros
    masks = compact_path.extras["keep_masks"]
    assert np.all(compact_path.weights[~masks] == 0.0)


def test_compact_uses_small_buffers_when_screening_bites(ds, compact_path):
    """Early steps keep few features => the step must have solved in a
    bucket well below m, and resurrection telemetry tracks mask growth."""
    m = ds.X.shape[0]
    caps = compact_path.extras["caps"]
    kept = compact_path.kept
    assert caps[0] < m and caps[0] >= kept[0]
    assert np.all(caps >= kept)  # a bucket always fits the certified keeps
    # kept counts grow along this grid => some features resurrect
    assert compact_path.extras["resurrected"].sum() > 0


def test_compact_overflow_falls_back_to_mask(ds):
    """With screening off every step keeps all m features — past the largest
    bucket — so the lax.cond/switch fallback must engage (cap == m) and
    still match the mask engine."""
    s = svm_path_scan(ds.X, ds.y, screening=False, **GRID, **SOLVE)
    c = svm_path_scan(ds.X, ds.y, screening=False, reduce="compact",
                      **GRID, **SOLVE)
    assert np.all(c.extras["caps"] == ds.X.shape[0])
    rel = np.max(np.abs(s.objectives - c.objectives)
                 / np.maximum(np.abs(s.objectives), 1.0))
    assert rel < 1e-9, rel


def test_compact_dynamic_matches(ds, scan_path):
    dyn = svm_path_scan(ds.X, ds.y, reduce="compact", dynamic=True,
                        screen_every=25, **GRID, **SOLVE)
    rel = np.max(np.abs(dyn.objectives - scan_path.objectives)
                 / np.maximum(np.abs(scan_path.objectives), 1.0))
    assert rel < 1e-6, rel


def test_compact_caps_schedule():
    assert compact_caps(2000) == (64, 128, 256, 512)
    assert compact_caps(300) == (32, 64, 128)
    assert compact_caps(16) == ()  # degenerates to mask mode
    caps = compact_caps(10**6)
    assert len(caps) == 4 and all(c <= 10**6 // 2 for c in caps)


def test_reduce_validation(ds):
    with pytest.raises(ValueError, match="mask' or 'compact"):
        svm_path_scan(ds.X, ds.y, reduce="gather", **GRID)
    with pytest.raises(ValueError, match="scan engine"):
        PathDriver(reduce="compact")
    # svm_path dispatch: per-engine defaults + pass-through
    r = svm_path(ds.X, ds.y, engine="scan", reduce="compact", **GRID, **SOLVE)
    assert r.extras["options"]["reduce"] == "compact"


# ---------------------------------------------------------------------------
# Sharded scan engine: one shard_map'd program over the svm_mesh
# ---------------------------------------------------------------------------


def test_sharded_scan_bitwise_on_unit_mesh(ds, scan_path):
    """On a trivial (1, 1) CPU mesh every collective binds to the identity,
    so the shard_map'd program must reproduce the single-device scan
    BITWISE — keep masks, objectives, weights, and certificates. This is
    the port check: any drift means the sharded step diverged from the
    local step."""
    sh = svm_path_scan_sharded(svm_mesh(1, 1), ds.X, ds.y, **GRID, **SOLVE)
    assert sh.extras["engine"] == "scan_sharded"
    np.testing.assert_array_equal(sh.extras["keep_masks"],
                                  scan_path.extras["keep_masks"])
    np.testing.assert_array_equal(sh.objectives, scan_path.objectives)
    np.testing.assert_array_equal(sh.weights, scan_path.weights)
    np.testing.assert_array_equal(sh.extras["gaps"], scan_path.extras["gaps"])
    np.testing.assert_array_equal(sh.solver_iters, scan_path.solver_iters)


# ---------------------------------------------------------------------------
# Pallas-fused solver vs XLA solver (interpret mode on non-TPU backends)
# ---------------------------------------------------------------------------


def test_pallas_fista_matches_xla(ds, monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lam = 0.3 * float(lambda_max(X, y))
    ref = fista_solve(X, y, lam, max_iters=20000, tol=1e-12, use_pallas=False)
    out = fista_solve(X, y, lam, max_iters=20000, tol=1e-12, use_pallas=True)
    np.testing.assert_allclose(float(out.obj), float(ref.obj), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out.w), np.asarray(ref.w), atol=1e-3)


def test_pallas_scan_path_matches_xla(ds, scan_path, monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    p = svm_path_scan(ds.X, ds.y, use_pallas=True, **GRID, **SOLVE)
    rel = np.max(np.abs(p.objectives - scan_path.objectives)
                 / np.maximum(np.abs(scan_path.objectives), 1.0))
    assert rel < 1e-5, rel


def test_pallas_compact_path_matches_xla(ds, compact_path, monkeypatch):
    """Compact solves hand the kernels their live-row count (valid_m); the
    skipped padded blocks must not change the path."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    p = svm_path_scan(ds.X, ds.y, use_pallas=True, reduce="compact",
                      **GRID, **SOLVE)
    rel = np.max(np.abs(p.objectives - compact_path.objectives)
                 / np.maximum(np.abs(compact_path.objectives), 1.0))
    assert rel < 1e-5, rel


def test_restart_fallback_is_conditional():
    """The monotone-restart branch must sit under lax.cond — not be computed
    eagerly every iteration (the perf bug this PR fixes)."""
    X = jnp.asarray(np.random.default_rng(0).standard_normal((16, 12)),
                    jnp.float32)
    y = jnp.asarray(np.sign(np.random.default_rng(1).standard_normal(12)),
                    jnp.float32)
    jaxpr = str(jax.make_jaxpr(
        lambda X, y: fista_solve(X, y, 0.5, max_iters=7, use_pallas=False)
    )(X, y))
    assert "cond[" in jaxpr


# ---------------------------------------------------------------------------
# Shared Lipschitz bound: full X upper-bounds every masked submatrix
# ---------------------------------------------------------------------------


def test_full_lipschitz_upper_bounds_masked_submatrices():
    rng = np.random.default_rng(7)
    X = jnp.asarray(rng.standard_normal((120, 80)), jnp.float32)
    L_full = float(lipschitz_estimate(X, n_iters=120))
    for seed in range(5):
        r = np.random.default_rng(seed)
        rows = r.random(120) < r.uniform(0.3, 0.9)
        cols = r.random(80) < r.uniform(0.3, 0.9)
        rows[0] = cols[0] = True  # keep non-empty
        # mask mode: zeroed rows (samples all kept)
        L_mask = float(lipschitz_estimate(
            X * jnp.asarray(rows[:, None], jnp.float32), n_iters=120))
        # gather mode: physical submatrix on both axes
        L_sub = float(lipschitz_estimate(
            jnp.asarray(np.asarray(X)[rows][:, cols]), n_iters=120))
        assert L_mask <= L_full * 1.01 + 1e-4, (seed, L_mask, L_full)
        assert L_sub <= L_full * 1.01 + 1e-4, (seed, L_sub, L_full)


# ---------------------------------------------------------------------------
# Batched engine
# ---------------------------------------------------------------------------


def test_batched_grids_match_single(ds, host_path):
    lmax = host_path.extras["lam_max"]
    grids = np.stack([
        np.geomspace(lmax, lmax * r, 5) for r in (0.15, 0.25, 0.4)
    ])
    batched = svm_path_batched(ds.X, ds.y, lambdas=grids, **SOLVE)
    assert len(batched) == 3
    for i in range(3):
        single = svm_path_scan(ds.X, ds.y, lambdas=grids[i], **SOLVE)
        # vmap changes the XLA lowering (GEMV -> GEMM, different fp32
        # accumulation order), so near-threshold screening decisions and
        # noise-level stopping may differ — solutions agree to fp32 solver
        # resolution, not bitwise.
        rel = np.max(np.abs(batched[i].objectives - single.objectives)
                     / np.maximum(np.abs(single.objectives), 1.0))
        assert rel < 1e-4, (i, rel)
        np.testing.assert_allclose(batched[i].weights, single.weights,
                                   atol=5e-3)


def test_batched_problems_match_single():
    sets = [make_sparse_classification(m=200, n=90, k_active=8, seed=s)
            for s in (51, 52)]
    Xb = np.stack([d.X for d in sets])
    yb = np.stack([d.y for d in sets])
    batched = svm_path_batched(Xb, yb, n_lambdas=5, lam_min_ratio=0.25,
                               **SOLVE)
    assert len(batched) == 2
    for i, d in enumerate(sets):
        single = svm_path_scan(d.X, d.y, n_lambdas=5, lam_min_ratio=0.25,
                               **SOLVE)
        rel = np.max(np.abs(batched[i].objectives - single.objectives)
                     / np.maximum(np.abs(single.objectives), 1.0))
        assert rel < 1e-4, (i, rel)  # see grids test: vmap lowering differs


def test_batched_input_validation(ds):
    with pytest.raises(ValueError, match="lambdas"):
        svm_path_batched(ds.X, ds.y)  # 2-D X needs explicit grids
    with pytest.raises(ValueError, match="B, T"):
        svm_path_batched(ds.X, ds.y, lambdas=np.array([0.5, 0.1]))


# ---------------------------------------------------------------------------
# Batched compact: the shared-cap schedule under vmap
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def batch_sets():
    return [make_sparse_classification(m=200, n=90, k_active=8, seed=s)
            for s in (51, 52)]


@pytest.fixture(scope="module")
def batched_compact(batch_sets):
    Xb = np.stack([d.X for d in batch_sets])
    yb = np.stack([d.y for d in batch_sets])
    return svm_path_batched(Xb, yb, n_lambdas=6, lam_min_ratio=0.15,
                            reduce="compact", **SOLVE)


def test_compact_caps_batched_schedule():
    # no counts: the ladder itself (same as the per-problem schedule)
    assert compact_caps_batched(300) == compact_caps(300) == (32, 64, 128)
    # with counts: the smallest shared cap fitting the batch-max keep
    assert compact_caps_batched(300, [5]) == 32
    assert compact_caps_batched(300, [10, 40]) == 64
    assert compact_caps_batched(300, [10, 200]) == 300  # overflow -> mask
    assert compact_caps_batched(16, [4]) == 16  # no ladder -> mask mode


def test_batched_compact_matches_single_compact(batch_sets, batched_compact):
    """vmapped compact == per-problem compact: same screen math, same
    cumsum compaction, same solver trajectory (observed bitwise on CPU;
    asserted at fp32 solver resolution since vmap may change the XLA
    lowering) — and the certified keep masks agree exactly."""
    for i, d in enumerate(batch_sets):
        single = svm_path_scan(d.X, d.y, n_lambdas=6, lam_min_ratio=0.15,
                               reduce="compact", **SOLVE)
        rel = np.max(np.abs(batched_compact[i].objectives - single.objectives)
                     / np.maximum(np.abs(single.objectives), 1.0))
        assert rel < 1e-4, (i, rel)
        np.testing.assert_array_equal(
            batched_compact[i].extras["keep_masks"],
            single.extras["keep_masks"])
        assert batched_compact[i].extras["options"]["reduce"] == "compact"


def test_batched_compact_matches_batched_mask(batch_sets, batched_compact):
    """Compact vs mask reduction on the same batched program structure:
    objectives to solver resolution, screened features exactly zero, and
    the compact caps shared across the batch (ONE capacity per step)."""
    Xb = np.stack([d.X for d in batch_sets])
    yb = np.stack([d.y for d in batch_sets])
    masked = svm_path_batched(Xb, yb, n_lambdas=6, lam_min_ratio=0.15,
                              reduce="mask", **SOLVE)
    for i in range(2):
        rel = np.max(np.abs(batched_compact[i].objectives
                            - masked[i].objectives)
                     / np.maximum(np.abs(masked[i].objectives), 1.0))
        assert rel < 5e-6, (i, rel)
        km = batched_compact[i].extras["keep_masks"]
        assert np.all(batched_compact[i].weights[~km] == 0.0)
        caps = batched_compact[i].extras["caps"]
        kept = batched_compact[i].kept
        assert np.all(caps >= kept)  # the shared cap fits every element
        assert caps[0] < Xb.shape[1]  # early steps actually compacted
    # the schedule is batch-level: every element reports the same cap
    np.testing.assert_array_equal(batched_compact[0].extras["caps"],
                                  batched_compact[1].extras["caps"])


def test_batched_grids_compact_matches_single(ds, host_path):
    lmax = host_path.extras["lam_max"]
    grids = np.stack([
        np.geomspace(lmax, lmax * r, 5) for r in (0.15, 0.25, 0.4)
    ])
    batched = svm_path_batched(ds.X, ds.y, lambdas=grids, reduce="compact",
                               **SOLVE)
    for i in range(3):
        single = svm_path_scan(ds.X, ds.y, lambdas=grids[i],
                               reduce="compact", **SOLVE)
        rel = np.max(np.abs(batched[i].objectives - single.objectives)
                     / np.maximum(np.abs(single.objectives), 1.0))
        assert rel < 1e-4, (i, rel)


def test_batched_compact_overflow_falls_back(batch_sets):
    """Screening off keeps all m features every step — past the largest
    bucket — so the batch-level overflow branch must fire (cap == m for
    every element) and still match the batched mask engine."""
    Xb = np.stack([d.X for d in batch_sets])
    yb = np.stack([d.y for d in batch_sets])
    kw = dict(n_lambdas=4, lam_min_ratio=0.3, screening=False,
              tol=1e-9, max_iters=4000)
    c = svm_path_batched(Xb, yb, reduce="compact", **kw)
    s = svm_path_batched(Xb, yb, reduce="mask", **kw)
    for i in range(2):
        assert np.all(c[i].extras["caps"] == Xb.shape[1])
        rel = np.max(np.abs(c[i].objectives - s[i].objectives)
                     / np.maximum(np.abs(s[i].objectives), 1.0))
        assert rel < 1e-9, (i, rel)


def test_svm_path_engine_batched_dispatch(batch_sets):
    """PR-4 leftover: svm_path now dispatches engine='batched' (returns a
    list) and accepts reduce='compact' there; the engine validation names
    all three engines."""
    Xb = np.stack([d.X for d in batch_sets])
    yb = np.stack([d.y for d in batch_sets])
    rs = svm_path(Xb, yb, engine="batched", reduce="compact", n_lambdas=4,
                  lam_min_ratio=0.25, tol=1e-9, max_iters=4000)
    assert isinstance(rs, list) and len(rs) == 2
    for r in rs:
        assert r.extras["options"]["reduce"] == "compact"
        assert r.extras["batch"] == 2
    with pytest.raises(ValueError, match="'host', 'scan', or 'batched'"):
        svm_path(Xb, yb, engine="bogus")
    with pytest.raises(ValueError, match="feature rule only"):
        svm_path(Xb, yb, engine="batched", rules="sample_vi")


def test_engine_cache_no_retrace(batch_sets):
    """Same config + same shapes must hit both warm-cache layers: the
    engine dict (one jitted engine per static-opts key) and jit's own trace
    cache (no retrace on the repeat call)."""
    from repro.core.path_scan import _engine_jit, _static_opts, engine_cache_info

    # layer 1: static opts are hashable and hit the engine dict
    a = _engine_jit(_static_opts(4000, True, False, 50, None, False,
                                 "compact"), batched="problems_compact")
    b = _engine_jit(_static_opts(4000, True, False, 50, None, False,
                                 "compact"), batched="problems_compact")
    assert a is b

    # layer 2: a repeated same-shape call leaves every trace count alone
    Xb = np.stack([d.X for d in batch_sets])
    yb = np.stack([d.y for d in batch_sets])
    kw = dict(n_lambdas=4, lam_min_ratio=0.25, reduce="compact",
              tol=1e-9, max_iters=4000)
    svm_path_batched(Xb, yb, **kw)
    before = engine_cache_info()
    if any(v < 0 for v in before.values()):
        pytest.skip("running jax exposes no _cache_size probe")
    svm_path_batched(Xb, yb, **kw)
    after = engine_cache_info()
    assert after == before, (before, after)
