"""Unit tests for dry-run machinery that doesn't need 512 devices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cells, get_config, input_specs
from repro.launch.hlo_analysis import _shape_bytes, collective_stats


def test_collective_parser_counts_ops():
    hlo = """
  %add = f32[4,8]{1,0} add(f32[4,8] %a, f32[4,8] %b)
  %ar = f32[1024]{0} all-reduce(f32[1024] %x), replica_groups={}
  %ag.1 = bf16[2,4096]{1,0} all-gather(bf16[2,256] %y), dimensions={1}
  ROOT %rs = f32[128]{0} reduce-scatter(f32[2048] %z), dimensions={0}
"""
    stats = collective_stats(hlo)
    assert stats["all-reduce"]["count"] == 1
    assert stats["all-reduce"]["bytes"] == 1024 * 4
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 2 * 4096 * 2
    assert stats["reduce-scatter"]["bytes"] == 128 * 4
    assert stats["all-to-all"]["count"] == 0


def test_shape_bytes_tuple():
    assert _shape_bytes("(f32[8,2], bf16[16])") == 8 * 2 * 4 + 16 * 2
    assert _shape_bytes("pred[100]") == 100
    assert _shape_bytes("f32[]") == 4  # scalar


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_are_structs(arch, shape):
    cfg = get_config(arch)
    if shape in cfg.shape_skips():
        pytest.skip("documented skip cell")
    specs = input_specs(cfg, shape)
    for leaf in jax.tree_util.tree_leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    if SHAPES[shape]["kind"] == "decode":
        assert specs["tokens"].shape[1] == 1
        assert "cache" in specs
    else:
        assert specs["tokens"].shape == (SHAPES[shape]["batch"], SHAPES[shape]["seq"])


def test_cells_enumeration():
    cs = cells(include_skips=True)
    assert len(cs) == len(ARCHS) * len(SHAPES)
    skipped = [c for c in cs if c[2]]
    assert len(skipped) == 8  # 8 full-attention archs skip long_500k


def test_vocab_padding_divisible_by_tp():
    for arch in ARCHS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 16 == 0, arch
        assert cfg.padded_vocab >= cfg.vocab_size


def test_param_specs_shard_big_tensors():
    """On the production mesh, every >=2-D big tensor gets at least one
    sharded dimension (no accidental full replication of weights)."""
    from repro.models import transformer as tr
    from repro.models.sharding import param_specs

    mesh = jax.make_mesh((1, 1), ("data", "model"))  # sizes 1: always divides
    cfg = get_config("granite-8b")
    sds = jax.eval_shape(lambda k: tr.init_params(cfg, k), jax.random.PRNGKey(0))
    specs = param_specs(sds, mesh)
    flat = jax.tree_util.tree_leaves_with_path(specs)
    sds_flat = jax.tree_util.tree_leaves_with_path(sds)
    for (path, spec), (_, leaf) in zip(flat, sds_flat):
        n = int(np.prod(leaf.shape))
        if n >= 1 << 20:  # >=1M params must shard somewhere
            assert any(a is not None for a in spec), (path, leaf.shape, spec)
