"""Cross-validation: the paper's literal closed forms (Thm 6.5/6.7/6.9 via
Algorithm 1) == our geometric implementation, on random instances.

Two independently-derived implementations agreeing to fp tolerance is the
strongest fidelity check we can run without the authors' code; it also pins
the halfspace sign convention (the paper's Eq. 43 vs its Eq. 31 — see
module docstrings)."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep; see tests/_hyp_compat.py + pyproject
    from _hyp_compat import given, settings, st

from repro.core import (
    fista_solve,
    lambda_max,
    screen_bounds,
    theta_at_lambda_max,
)
from repro.core.dual import safe_theta_and_delta
from repro.core.paper_reference import screen_bounds_paper
from repro.data import make_sparse_classification


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), ratio=st.floats(0.1, 0.95))
def test_paper_formulas_match_geometric(seed, ratio):
    ds = make_sparse_classification(m=50, n=36, seed=seed)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))

    ours = np.asarray(screen_bounds(X, y, lmax, ratio * lmax, theta1), np.float64)
    paper = screen_bounds_paper(
        np.asarray(X, np.float64), np.asarray(y, np.float64),
        lmax, ratio * lmax, np.asarray(theta1, np.float64))
    np.testing.assert_allclose(ours, paper, rtol=2e-4, atol=2e-4)


def test_paper_formulas_match_with_solved_theta():
    """Agreement also holds off the lambda_max special case."""
    ds = make_sparse_classification(m=60, n=40, seed=77)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    lam1 = 0.6 * lmax
    res = fista_solve(X, y, lam1, max_iters=40000, tol=1e-13)
    theta1, _ = safe_theta_and_delta(X, y, res.w, res.b, jnp.asarray(lam1))

    ours = np.asarray(screen_bounds(X, y, lam1, 0.5 * lam1, theta1), np.float64)
    paper = screen_bounds_paper(
        np.asarray(X, np.float64), np.asarray(y, np.float64),
        lam1, 0.5 * lam1, np.asarray(theta1, np.float64))
    np.testing.assert_allclose(ours, paper, rtol=5e-4, atol=5e-4)
