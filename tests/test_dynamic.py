"""Dynamic (in-solver) screening, DVI rule, and path-grid safety tests.

Invariants:
  D1 (solver equiv):   fista_solve_dynamic returns the same solution as
                       fista_solve to solver tolerance, with monotonically
                       non-increasing per-segment kept counts.
  D2 (solver safety):  every dynamically screened feature is inactive at an
                       independently solved high-precision optimum.
  D3 (path safety):    PathDriver(dynamic=True) never changes the accepted
                       path beyond tol, for gather and mask reduction, and
                       its telemetry shows in-solve tightening.
  D4 (refresh hook):   a region rebuilt from a solved iterate via
                       ScreeningRule.refresh screens safely (keeps the
                       support) and at least as hard as the step's
                       sequential region.
  G1 (grid):           a custom grid starting below lambda_max matches an
                       unscreened solve (the closed form must NOT be
                       assumed); increasing / non-positive grids raise.
  V1 (dvi):            the DVI rule is registered, is never looser than
                       feature_vi, and its path matches the unscreened path.
  S1 (dtype):          sample_margin_surplus respects x64 input dtypes for
                       the w1-is-None margin vector.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DVIRule,
    FeatureVIRule,
    PathDriver,
    available_rules,
    fista_solve,
    fista_solve_dynamic,
    get_rule,
    lambda_max,
)
from repro.core.rules import ConvexRegion, sample_margin_surplus
from repro.data import make_sparse_classification


@pytest.fixture(scope="module")
def inst():
    ds = make_sparse_classification(m=400, n=160, k_active=12, seed=77)
    return ds, jnp.asarray(ds.X), jnp.asarray(ds.y)


# -- D1/D2: dynamic solver ---------------------------------------------------

def test_dynamic_solver_matches_and_tightens(inst):
    _, X, y = inst
    lam = 0.25 * float(lambda_max(X, y))
    ref = fista_solve(X, y, lam, max_iters=20000, tol=1e-11)
    dyn = fista_solve_dynamic(X, y, lam, max_iters=20000, tol=1e-11,
                              screen_every=20)
    np.testing.assert_allclose(float(dyn.obj), float(ref.obj), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dyn.w), np.asarray(ref.w), atol=1e-4)
    n_seg = int(dyn.n_segments)
    kept = np.asarray(dyn.kept_per_segment)[:n_seg]
    gaps = np.asarray(dyn.gap_per_segment)[:n_seg]
    assert n_seg >= 2
    assert np.all(np.diff(kept) <= 0), kept          # mask only shrinks
    assert kept[-1] < X.shape[0], kept               # and it does shrink
    assert np.all(np.isfinite(gaps)) and np.all(gaps >= 0.0)
    # unused telemetry slots keep their sentinels
    assert np.all(np.asarray(dyn.kept_per_segment)[n_seg:] == -1)


def test_dynamic_screened_features_truly_inactive(inst):
    _, X, y = inst
    lam = 0.3 * float(lambda_max(X, y))
    dyn = fista_solve_dynamic(X, y, lam, max_iters=20000, tol=1e-11,
                              screen_every=20)
    screened = ~np.asarray(dyn.feature_mask)
    assert screened.any()
    full = fista_solve(X, y, lam, max_iters=60000, tol=1e-13)
    assert np.abs(np.asarray(full.w))[screened].max() <= 1e-6


def test_dynamic_solver_respects_seed_mask(inst):
    _, X, y = inst
    m = X.shape[0]
    lam = 0.3 * float(lambda_max(X, y))
    seed = np.ones((m,), np.float32)
    seed[: m // 4] = 0.0  # pretend a sequential screen dropped these
    Xm = X * jnp.asarray(seed)[:, None]
    dyn = fista_solve_dynamic(Xm, y, lam, max_iters=20000, tol=1e-11,
                              screen_every=20, feature_mask=jnp.asarray(seed))
    # seeded zeros never resurrect (check magnitude: a sign-agnostic leak
    # through e.g. an unmasked prox output must fail this too)
    assert not np.asarray(dyn.feature_mask)[: m // 4].any()
    assert np.abs(np.asarray(dyn.w)[: m // 4]).max(initial=0.0) == 0.0


# -- D3: dynamic path safety -------------------------------------------------

@pytest.mark.parametrize("reduce", ["gather", "mask"])
def test_dynamic_path_matches_sequential(inst, reduce):
    ds, _, _ = inst
    kw = dict(tol=1e-10, max_iters=20000, reduce=reduce)
    grid = dict(n_lambdas=6, lam_min_ratio=0.05)
    seq = PathDriver(rules="feature_vi", **kw).run(ds.X, ds.y, **grid)
    dyn = PathDriver(rules="feature_vi", dynamic=True, screen_every=25,
                     **kw).run(ds.X, ds.y, **grid)
    np.testing.assert_allclose(dyn.objectives, seq.objectives,
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(dyn.weights, seq.weights, atol=3e-3)
    tele = dyn.extras["dynamic"]
    assert tele, "dynamic path produced no telemetry"
    # in-solve tightening: some step ends with fewer live features than its
    # between-lambda screen fed the solver
    assert any(
        d["kept_per_segment"] and d["kept_per_segment"][-1] < dyn.kept[k]
        for k, d in tele.items() if k > 0
    ), tele


# -- D4: the refresh protocol hook ------------------------------------------

def test_refresh_region_is_safe_and_tightens(inst):
    _, X, y = inst
    lmax = float(lambda_max(X, y))
    lam1, lam2 = 0.5 * lmax, 0.3 * lmax
    res1 = fista_solve(X, y, jnp.asarray(lam1), max_iters=40000, tol=1e-13)
    res2 = fista_solve(X, y, jnp.asarray(lam2), max_iters=40000, tol=1e-13)
    rule = FeatureVIRule()

    region = rule.refresh(X, y, res2.w, res2.b, lam2)
    assert region.lam1 == region.lam2 == pytest.approx(lam2)
    keep = np.asarray(rule.keep(rule.bounds(X, y, region)))
    support = np.abs(np.asarray(res2.w)) > 1e-7
    assert np.all(keep[support]), "refresh screened an active feature"
    # and it is at least as tight as the sequential lam1 -> lam2 region
    from repro.core.dual import safe_theta_and_delta

    theta1, delta1 = safe_theta_and_delta(X, y, res1.w, res1.b, jnp.asarray(lam1))
    seq_region = ConvexRegion.build(y, lam1, lam2, theta1, delta=delta1)
    keep_seq = np.asarray(rule.keep(rule.bounds(X, y, seq_region)))
    assert keep.sum() <= keep_seq.sum()


# -- G1: custom grids --------------------------------------------------------

def test_custom_grid_below_lambda_max_matches_unscreened(inst):
    ds, X, y = inst
    lmax = float(lambda_max(X, y))
    # starts strictly below lambda_max: step 0 must be SOLVED, not assumed 0
    grid = [0.55 * lmax, 0.35 * lmax, 0.2 * lmax]
    kw = dict(tol=1e-10, max_iters=20000)
    scr = PathDriver(rules="feature_vi", **kw).run(ds.X, ds.y, lambdas=grid)
    off = PathDriver(rules=None, **kw).run(ds.X, ds.y, lambdas=grid)
    np.testing.assert_allclose(scr.objectives, off.objectives,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(scr.weights, off.weights, atol=3e-3)
    # step 0 actually has support (the old closed-form assumption gave w=0)
    assert scr.active[0] > 0
    assert scr.kept[0] == ds.X.shape[0]
    # independent oracle for step 0
    ref0 = fista_solve(X, y, jnp.asarray(grid[0]), max_iters=40000, tol=1e-12)
    np.testing.assert_allclose(scr.objectives[0], float(ref0.obj), rtol=1e-5)


def test_bad_grids_raise(inst):
    ds, _, _ = inst
    driver = PathDriver(rules="feature_vi")
    with pytest.raises(ValueError, match="decreasing"):
        driver.run(ds.X, ds.y, lambdas=[1.0, 2.0, 3.0])
    with pytest.raises(ValueError, match="decreasing"):
        driver.run(ds.X, ds.y, lambdas=[2.0, 2.0])
    with pytest.raises(ValueError, match="positive"):
        driver.run(ds.X, ds.y, lambdas=[1.0, -0.5])
    with pytest.raises(ValueError):
        driver.run(ds.X, ds.y, lambdas=[])


# -- V1: DVI rule ------------------------------------------------------------

def test_dvi_registered_and_no_looser_than_feature_vi(inst):
    ds, _, _ = inst
    assert "dvi" in available_rules()
    assert isinstance(get_rule("dvi"), DVIRule)
    grid = dict(n_lambdas=6, lam_min_ratio=0.05)
    kw = dict(tol=1e-10, max_iters=20000)
    fv = PathDriver(rules="feature_vi", **kw).run(ds.X, ds.y, **grid)
    dvi = PathDriver(rules="dvi", **kw).run(ds.X, ds.y, **grid)
    off = PathDriver(rules=None, **kw).run(ds.X, ds.y, **grid)
    # exactness despite the extra anchor
    np.testing.assert_allclose(dvi.objectives, off.objectives,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dvi.weights, off.weights, atol=3e-3)
    # min of two valid bounds can only screen more
    assert np.all(dvi.kept <= fv.kept)


def test_dvi_anchor_state_resets_per_path(inst):
    ds, X, y = inst
    rule = DVIRule()
    grid = dict(n_lambdas=4, lam_min_ratio=0.2)
    r1 = PathDriver(rules=rule, tol=1e-9, max_iters=8000).run(ds.X, ds.y, **grid)
    assert rule._anchor is not None
    rule.prepare(X, y)
    assert rule._anchor is None
    r2 = PathDriver(rules=rule, tol=1e-9, max_iters=8000).run(ds.X, ds.y, **grid)
    np.testing.assert_allclose(r1.kept, r2.kept)


# -- DS: dynamic *sample* re-screen ------------------------------------------

def test_dynamic_sample_solver_screens_and_verifies(inst):
    """Solver-level: honest radii screen samples whose margins truly clear."""
    _, X, y = inst
    lam = 0.15 * float(lambda_max(X, y))
    ref = fista_solve(X, y, lam, max_iters=40000, tol=1e-12)
    # warm-start AT the optimum with (essentially) zero movement radii: the
    # margin prediction is then exact, so every screened sample must have
    # margin >= 1 at the optimum — and the objective must not move
    dyn = fista_solve_dynamic(X, y, lam, w0=ref.w, b0=ref.b,
                              max_iters=20000, tol=1e-11, screen_every=10,
                              dynamic_samples=True,
                              sample_dw=1e-4, sample_db=1e-4)
    assert dyn.sample_mask is not None
    screened = ~np.asarray(dyn.sample_mask)
    assert screened.any(), "no sample screened with zero-movement radii"
    margins = np.asarray(y * (X.T @ ref.w + ref.b))
    assert margins[screened].min() >= 1.0 - 1e-4
    np.testing.assert_allclose(float(dyn.obj), float(ref.obj), rtol=1e-5)
    n_seg = int(dyn.n_segments)
    kept_s = np.asarray(dyn.kept_samples_per_segment)[:n_seg]
    assert np.all(np.diff(kept_s) <= 0)  # sample mask only shrinks


def test_dynamic_sample_mask_default_off(inst):
    _, X, y = inst
    lam = 0.3 * float(lambda_max(X, y))
    dyn = fista_solve_dynamic(X, y, lam, max_iters=5000, tol=1e-9,
                              screen_every=25)
    assert dyn.sample_mask is None
    assert dyn.kept_samples_per_segment is None


def test_dynamic_sample_path_exact_with_verification(inst):
    """Path-level: dynamic in-solver sample drops ride the KKT verification
    loop, so the accepted path equals the sequential one."""
    ds, _, _ = inst
    grid = dict(n_lambdas=6, lam_min_ratio=0.05)
    kw = dict(tol=1e-10, max_iters=20000, reduce="mask")
    seq = PathDriver(rules="composite", **kw).run(ds.X, ds.y, **grid)
    dyn = PathDriver(rules="composite", dynamic=True, screen_every=25,
                     **kw).run(ds.X, ds.y, **grid)
    np.testing.assert_allclose(dyn.objectives, seq.objectives,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dyn.weights, seq.weights, atol=3e-3)
    tele = dyn.extras["dynamic"]
    assert any("kept_samples_per_segment" in d for d in tele.values()), tele


# -- S1: dtype ---------------------------------------------------------------

def test_sample_margin_surplus_respects_x64():
    from jax.experimental import enable_x64

    with enable_x64():
        n = 32
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.standard_normal((8, n)))
        y = jnp.asarray(np.where(rng.random(n) < 0.5, -1.0, 1.0))
        assert X.dtype == jnp.float64
        region = ConvexRegion.build(y, 2.0, 1.0,
                                    jnp.zeros((n,), jnp.float64), b1=0.25)
        surplus, u1 = sample_margin_surplus(X, y, region)
        assert u1.dtype == jnp.float64, u1.dtype
        assert surplus.dtype == jnp.float64, surplus.dtype
