import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    bias_at_lambda_max,
    duality_gap_estimate,
    fista_solve,
    first_features,
    lambda_max,
    theta_at_lambda_max,
    theta_from_primal,
)
from repro.data import make_sparse_classification


@pytest.fixture(scope="module")
def ds():
    return make_sparse_classification(m=150, n=100, k_active=6, seed=7)


def test_lambda_max_zero_solution(ds):
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = lambda_max(X, y)
    res = fista_solve(X, y, 1.02 * lmax, max_iters=3000, tol=1e-12)
    assert int(jnp.sum(jnp.abs(res.w) > 1e-6)) == 0


def test_lambda_max_is_tight(ds):
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = lambda_max(X, y)
    res = fista_solve(X, y, 0.90 * lmax, max_iters=20000, tol=1e-13)
    assert int(jnp.sum(jnp.abs(res.w) > 1e-7)) >= 1


def test_first_feature_matches_solver(ds):
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = lambda_max(X, y)
    j_pred = int(first_features(X, y))
    res = fista_solve(X, y, 0.95 * lmax, max_iters=20000, tol=1e-13)
    active = np.nonzero(np.abs(np.asarray(res.w)) > 1e-7)[0]
    assert j_pred in active.tolist()


def test_theta_at_lambda_max_feasible(ds):
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = lambda_max(X, y)
    theta = theta_at_lambda_max(y, lmax)
    assert abs(float(theta @ y)) < 1e-4
    corr = jnp.max(jnp.abs(X @ (y * theta)))
    assert float(corr) <= 1.0 + 1e-5
    np.testing.assert_allclose(float(corr), 1.0, rtol=1e-5)
    assert bool(jnp.all(theta >= 0))


def test_theta_from_primal_feasible_near_optimum(ds):
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = lambda_max(X, y)
    lam = 0.4 * lmax
    res = fista_solve(X, y, lam, max_iters=40000, tol=1e-14)
    theta = theta_from_primal(X, y, res.w, res.b, lam)
    assert abs(float(theta @ y)) < 1e-3
    assert float(jnp.max(jnp.abs(X @ (y * theta)))) <= 1.0 + 5e-3
    assert bool(jnp.all(theta >= 0))


def test_duality_gap_small_at_optimum(ds):
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = lambda_max(X, y)
    lam = 0.5 * lmax
    res = fista_solve(X, y, lam, max_iters=40000, tol=1e-14)
    gap = duality_gap_estimate(X, y, res.w, res.b, lam)
    assert float(gap.gap) >= -1e-3  # weak duality (numerical slack)
    assert float(gap.gap) / max(float(gap.primal), 1e-9) < 0.05


def test_bias_at_lambda_max(ds):
    y = jnp.asarray(ds.y)
    b = float(bias_at_lambda_max(y))
    n_pos = float(jnp.sum(y > 0))
    n_neg = float(jnp.sum(y < 0))
    np.testing.assert_allclose(b, (n_pos - n_neg) / y.shape[0], rtol=1e-6)
