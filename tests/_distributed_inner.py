"""Inner script for distributed tests — run in a subprocess with 8 host devices."""

import os
import re

# strip any inherited device-count override (last flag wins in XLA) so a
# polluted parent env can never change our device count
_flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = _flags + " --xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import fista_solve, lambda_max, screen, theta_at_lambda_max  # noqa: E402
from repro.core.distributed import fista_sharded, screen_sharded, svm_mesh  # noqa: E402
from repro.data import make_sparse_classification  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = svm_mesh(model=4, data=2)

    ds = make_sparse_classification(m=256, n=128, seed=51)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = lambda_max(X, y)
    theta1 = theta_at_lambda_max(y, lmax)
    lam2 = 0.4 * lmax

    keep_ref, bounds_ref = screen(X, y, lmax, lam2, theta1)
    keep_d, bounds_d = screen_sharded(mesh, X, y, lmax, lam2, theta1)
    np.testing.assert_allclose(
        np.asarray(bounds_d), np.asarray(bounds_ref), rtol=2e-4, atol=2e-4
    )
    mism = int(np.sum(np.asarray(keep_d) != np.asarray(keep_ref)))
    assert mism <= 2, f"keep-mask mismatch on {mism} features"  # tau-boundary jitter

    ref = fista_solve(X, y, lam2, max_iters=20000, tol=1e-12)
    dist = fista_sharded(mesh, X, y, lam2, max_iters=20000, tol=1e-12)
    np.testing.assert_allclose(float(dist.obj), float(ref.obj), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dist.w), np.asarray(ref.w), atol=5e-3)
    print("DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
