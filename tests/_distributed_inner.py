"""Inner script for distributed tests — run in a subprocess with 8 host devices."""

import os
import re

# strip any inherited device-count override (last flag wins in XLA) so a
# polluted parent env can never change our device count
_flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = _flags + " --xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    fista_solve,
    fista_solve_dynamic,
    lambda_max,
    screen,
    theta_at_lambda_max,
)
from repro.core.distributed import fista_sharded, screen_sharded, svm_mesh  # noqa: E402
from repro.core.dual import safe_theta_and_delta  # noqa: E402
from repro.data import make_sparse_classification  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = svm_mesh(model=4, data=2)

    ds = make_sparse_classification(m=256, n=128, seed=51)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = lambda_max(X, y)
    theta1 = theta_at_lambda_max(y, lmax)
    lam2 = 0.4 * lmax

    keep_ref, bounds_ref = screen(X, y, lmax, lam2, theta1)
    keep_d, bounds_d = screen_sharded(mesh, X, y, lmax, lam2, theta1,
                                      delta=0.0)  # theta1 exact at lam_max
    np.testing.assert_allclose(
        np.asarray(bounds_d), np.asarray(bounds_ref), rtol=2e-4, atol=2e-4
    )
    mism = int(np.sum(np.asarray(keep_d) != np.asarray(keep_ref)))
    assert mism <= 2, f"keep-mask mismatch on {mism} features"  # tau-boundary jitter

    ref = fista_solve(X, y, lam2, max_iters=20000, tol=1e-12)
    dist = fista_sharded(mesh, X, y, lam2, max_iters=20000, tol=1e-12)
    np.testing.assert_allclose(float(dist.obj), float(ref.obj), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dist.w), np.asarray(ref.w), atol=5e-3)

    # -- delta > 0: sequentially solved (inexact) anchor ------------------
    lam1 = 0.5 * lmax
    res1 = fista_solve(X, y, lam1, max_iters=40000, tol=1e-13)
    theta_s, delta_s = safe_theta_and_delta(X, y, res1.w, res1.b, lam1)
    assert float(delta_s) > 0.0
    lam2b = 0.9 * lam1  # ratio where the delta inflation reaches the mask
    keep_ref2, bounds_ref2 = screen(X, y, lam1, lam2b, theta_s, delta=delta_s)
    # feature-sharded-only mesh: no cross-shard reduction touches the sample
    # axis, and _shared_from_stats delegates to the oracle's own scalar code,
    # so the keep mask must match BITWISE
    mesh_col = svm_mesh(model=8, data=1)
    keep_d2, bounds_d2 = screen_sharded(mesh_col, X, y, lam1, lam2b, theta_s,
                                        delta=delta_s)
    assert np.array_equal(np.asarray(keep_d2), np.asarray(keep_ref2)), (
        "delta>0 sharded keep mask != local oracle "
        f"({int(np.sum(np.asarray(keep_d2) != np.asarray(keep_ref2)))} mismatches)"
    )
    # 2-D mesh: psum reassociation => tolerance equivalence
    keep_d3, bounds_d3 = screen_sharded(mesh, X, y, lam1, lam2b, theta_s,
                                        delta=delta_s)
    np.testing.assert_allclose(np.asarray(bounds_d3), np.asarray(bounds_ref2),
                               rtol=2e-4, atol=2e-4)
    # the delta-blind screen (the pre-fix behavior) must be STRICTLY more
    # aggressive on this instance — i.e. delta genuinely reaches the keep
    # mask, so reintroducing the delta-dropping bug would fail this check
    keep_blind, _ = screen_sharded(mesh_col, X, y, lam1, lam2b, theta_s,
                                   delta=0.0)
    assert int(np.sum(keep_blind)) < int(np.sum(keep_d2)), (
        int(np.sum(keep_blind)), int(np.sum(keep_d2)))

    # -- dynamic (in-solver) screening, sharded vs single-device ----------
    dyn = fista_sharded(mesh, X, y, lam2, max_iters=20000, tol=1e-12,
                        screen_every=25)
    np.testing.assert_allclose(float(dyn.obj), float(ref.obj), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dyn.w), np.asarray(ref.w), atol=5e-3)
    n_seg = int(dyn.n_segments)
    kept = np.asarray(dyn.kept_per_segment)[:n_seg]
    assert n_seg >= 1 and np.all(np.diff(kept) <= 0), kept
    # every screened feature is inactive at the single-device optimum
    screened = ~np.asarray(dyn.feature_mask)
    assert np.abs(np.asarray(ref.w))[screened].max(initial=0.0) <= 1e-6
    loc = fista_solve_dynamic(X, y, lam2, max_iters=20000, tol=1e-12,
                              screen_every=25)
    kept_loc = np.asarray(loc.kept_per_segment)[: int(loc.n_segments)]
    assert kept.shape == kept_loc.shape and np.max(np.abs(kept - kept_loc)) <= 2, (
        kept, kept_loc)
    print("DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
