"""Inner script for distributed tests — run in a subprocess with 8 host devices."""

import os
import re

# strip any inherited device-count override (last flag wins in XLA) so a
# polluted parent env can never change our device count
_flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = _flags + " --xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    fista_solve,
    fista_solve_dynamic,
    lambda_max,
    screen,
    svm_path_scan,
    svm_path_scan_sharded,
    theta_at_lambda_max,
)
from repro.core.distributed import (  # noqa: E402
    fista_sharded,
    sample_surplus_sharded,
    screen_sharded,
    svm_mesh,
)
from repro.core.dual import safe_theta_and_delta  # noqa: E402
from repro.core.rules.sample_vi import margin_surplus_core  # noqa: E402
from repro.data import make_sparse_classification  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = svm_mesh(model=4, data=2)

    ds = make_sparse_classification(m=256, n=128, seed=51)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = lambda_max(X, y)
    theta1 = theta_at_lambda_max(y, lmax)
    lam2 = 0.4 * lmax

    keep_ref, bounds_ref = screen(X, y, lmax, lam2, theta1)
    keep_d, bounds_d = screen_sharded(mesh, X, y, lmax, lam2, theta1,
                                      delta=0.0)  # theta1 exact at lam_max
    np.testing.assert_allclose(
        np.asarray(bounds_d), np.asarray(bounds_ref), rtol=2e-4, atol=2e-4
    )
    mism = int(np.sum(np.asarray(keep_d) != np.asarray(keep_ref)))
    assert mism <= 2, f"keep-mask mismatch on {mism} features"  # tau-boundary jitter

    ref = fista_solve(X, y, lam2, max_iters=20000, tol=1e-12)
    dist = fista_sharded(mesh, X, y, lam2, max_iters=20000, tol=1e-12)
    np.testing.assert_allclose(float(dist.obj), float(ref.obj), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dist.w), np.asarray(ref.w), atol=5e-3)

    # -- delta > 0: sequentially solved (inexact) anchor ------------------
    lam1 = 0.5 * lmax
    res1 = fista_solve(X, y, lam1, max_iters=40000, tol=1e-13)
    theta_s, delta_s = safe_theta_and_delta(X, y, res1.w, res1.b, lam1)
    assert float(delta_s) > 0.0
    lam2b = 0.9 * lam1  # ratio where the delta inflation reaches the mask
    keep_ref2, bounds_ref2 = screen(X, y, lam1, lam2b, theta_s, delta=delta_s)
    # feature-sharded-only mesh: no cross-shard reduction touches the sample
    # axis, and _shared_from_stats delegates to the oracle's own scalar code,
    # so the keep mask must match BITWISE
    mesh_col = svm_mesh(model=8, data=1)
    keep_d2, bounds_d2 = screen_sharded(mesh_col, X, y, lam1, lam2b, theta_s,
                                        delta=delta_s)
    assert np.array_equal(np.asarray(keep_d2), np.asarray(keep_ref2)), (
        "delta>0 sharded keep mask != local oracle "
        f"({int(np.sum(np.asarray(keep_d2) != np.asarray(keep_ref2)))} mismatches)"
    )
    # 2-D mesh: psum reassociation => tolerance equivalence
    keep_d3, bounds_d3 = screen_sharded(mesh, X, y, lam1, lam2b, theta_s,
                                        delta=delta_s)
    np.testing.assert_allclose(np.asarray(bounds_d3), np.asarray(bounds_ref2),
                               rtol=2e-4, atol=2e-4)
    # the delta-blind screen (the pre-fix behavior) must be STRICTLY more
    # aggressive on this instance — i.e. delta genuinely reaches the keep
    # mask, so reintroducing the delta-dropping bug would fail this check
    keep_blind, _ = screen_sharded(mesh_col, X, y, lam1, lam2b, theta_s,
                                   delta=0.0)
    assert int(np.sum(keep_blind)) < int(np.sum(keep_d2)), (
        int(np.sum(keep_blind)), int(np.sum(keep_d2)))

    # -- dynamic (in-solver) screening, sharded vs single-device ----------
    dyn = fista_sharded(mesh, X, y, lam2, max_iters=20000, tol=1e-12,
                        screen_every=25)
    np.testing.assert_allclose(float(dyn.obj), float(ref.obj), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dyn.w), np.asarray(ref.w), atol=5e-3)
    n_seg = int(dyn.n_segments)
    kept = np.asarray(dyn.kept_per_segment)[:n_seg]
    assert n_seg >= 1 and np.all(np.diff(kept) <= 0), kept
    # every screened feature is inactive at the single-device optimum
    screened = ~np.asarray(dyn.feature_mask)
    assert np.abs(np.asarray(ref.w))[screened].max(initial=0.0) <= 1e-6
    loc = fista_solve_dynamic(X, y, lam2, max_iters=20000, tol=1e-12,
                              screen_every=25)
    kept_loc = np.asarray(loc.kept_per_segment)[: int(loc.n_segments)]
    # psum reassociation perturbs objectives by ulps, and near the stopping
    # boundary that legitimately shifts WHEN convergence triggers — so the
    # sharded run may take one segment more or fewer than the local one.
    # The invariants that must hold: monotone tightening, segment counts
    # agreeing over the common prefix, and a final live set of similar size
    # (safety of the screened set vs the true optimum is asserted above).
    common = min(len(kept), len(kept_loc))
    assert abs(len(kept) - len(kept_loc)) <= 1, (kept, kept_loc)
    assert np.max(np.abs(kept[:common] - kept_loc[:common])) <= 2, (
        kept, kept_loc)
    assert abs(int(kept[-1]) - int(kept_loc[-1])) <= 2, (kept, kept_loc)

    # -- sharded scan path engine: one shard_map'd program ----------------
    # (the bitwise unit-mesh check lives in test_path_scan.py; here the real
    # 2-D mesh — psum reassociation and a reassociated L estimate mean
    # tolerance equivalence, with safety and convergence held exactly)
    grid = dict(n_lambdas=5, lam_min_ratio=0.2, tol=1e-10, max_iters=20000)
    loc_p = svm_path_scan(X, y, **grid)
    sh_p = svm_path_scan_sharded(mesh, X, y, **grid)
    rel = np.max(np.abs(sh_p.objectives - loc_p.objectives)
                 / np.maximum(np.abs(loc_p.objectives), 1.0))
    assert rel < 1e-5, rel
    np.testing.assert_allclose(sh_p.weights, loc_p.weights, atol=5e-3)
    assert np.asarray(sh_p.extras["converged"]).all()
    assert np.all(sh_p.active <= sh_p.kept)  # screened features stay inactive
    # the sharded screen is the same certificate: masks agree off the tau
    # boundary (reassociated anchors jitter a few boundary features)
    mism = int(np.sum(sh_p.extras["keep_masks"] != loc_p.extras["keep_masks"]))
    assert mism <= 0.05 * sh_p.extras["keep_masks"].size, mism

    # -- sample-rule margin sweep, sharded ---------------------------------
    rng = np.random.default_rng(3)
    w_s = jnp.asarray((rng.standard_normal(X.shape[0])
                       * (rng.random(X.shape[0]) < 0.2)).astype(np.float32))
    b_s = 0.37
    u_prev = jnp.asarray(rng.standard_normal(X.shape[1]).astype(np.float32))

    @jax.jit
    def surplus_oracle(X, y, w, up):
        u1 = X.T @ w + b_s
        return margin_surplus_core(u1, y, jnp.sum(X * X, axis=0), 0.5, 0.01,
                                   u_prev=up), u1

    ref_s, ref_u = surplus_oracle(X, y, w_s, u_prev)
    # model axis whole => reductions are the oracle's own ops: BITWISE
    s_d, u_d = sample_surplus_sharded(svm_mesh(1, 4), X, y, w_s, b_s,
                                      dw=0.5, db=0.01, u_prev=u_prev)
    assert np.array_equal(np.asarray(s_d), np.asarray(ref_s)), (
        "sample surplus on a data-sharded mesh != local oracle bitwise")
    assert np.array_equal(np.asarray(u_d), np.asarray(ref_u))
    # 2-D mesh: psum over "model" => tolerance equivalence, decisions exact
    s_2d, _ = sample_surplus_sharded(mesh, X, y, w_s, b_s, dw=0.5, db=0.01,
                                     u_prev=u_prev)
    np.testing.assert_allclose(np.asarray(s_2d), np.asarray(ref_s),
                               rtol=2e-4, atol=2e-4)
    assert np.array_equal(np.asarray(s_2d) < 0, np.asarray(ref_s) < 0)
    print("DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
