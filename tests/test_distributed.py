"""Distributed-equivalence tests (run in a subprocess with 8 fake devices so
the main pytest process keeps its single-device view)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_sharded_screen_and_solver_match_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_distributed_inner.py")],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "DISTRIBUTED_OK" in out.stdout
