"""Property-based safety tests for the screening rule (the paper's core claim).

Invariants:
  S1 (safety):       every feature active at lam2 is kept by the screen.
  S2 (bound valid):  bound_j >= |fhat_j^T theta*(lam2)| for every j.
  S3 (exactness):    solving the screened problem == solving the full one.
  S4 (monotonicity): lam2 -> lam1 keeps everything active at lam1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep; see tests/_hyp_compat.py + pyproject
    from _hyp_compat import given, settings, st

from repro.core import (
    fista_solve,
    lambda_max,
    screen,
    screen_bounds,
    theta_at_lambda_max,
    theta_from_primal,
)
from repro.core.dual import safe_theta_and_delta
from repro.data import make_sparse_classification

ACTIVE_TOL = 1e-6


def _setup(m, n, seed, correlated=0.0):
    ds = make_sparse_classification(m=m, n=n, k_active=max(2, m // 20),
                                    seed=seed, correlated=correlated)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    return X, y, lmax


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    ratio=st.floats(0.05, 0.95),
    m=st.sampled_from([60, 150, 300]),
    n=st.sampled_from([40, 100]),
    correlated=st.sampled_from([0.0, 0.5]),
)
def test_safety_from_lambda_max(seed, ratio, m, n, correlated):
    """S1 + S2 with the exact closed-form theta1 at lam1 = lam_max."""
    X, y, lmax = _setup(m, n, seed, correlated)
    theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
    lam2 = ratio * lmax

    keep, bounds = screen(X, y, lmax, lam2, theta1)
    res = fista_solve(X, y, lam2, max_iters=50000, tol=1e-14)
    w = np.asarray(res.w)
    active = np.abs(w) > ACTIVE_TOL
    keep = np.asarray(keep)

    assert not np.any(active & ~keep), (
        f"UNSAFE: active features screened out at ratio={ratio}"
    )
    theta2 = theta_from_primal(X, y, res.w, res.b, jnp.asarray(lam2))
    tv = np.abs(np.asarray(X @ (y * theta2)))
    bb = np.asarray(bounds)
    assert np.all(bb >= tv - 5e-4), f"bound violated by {np.max(tv - bb)}"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), r1=st.floats(0.5, 0.95), r2=st.floats(0.1, 0.95))
def test_safety_sequential(seed, r1, r2):
    """S1 with theta1 from a *solved* intermediate lambda (sequential use)."""
    X, y, lmax = _setup(200, 80, seed)
    lam1 = r1 * lmax
    lam2 = r2 * lam1
    res1 = fista_solve(X, y, lam1, max_iters=50000, tol=1e-14)
    # theta1 is inexact -> use the gap-certified (theta, delta) pair
    theta1, delta = safe_theta_and_delta(X, y, res1.w, res1.b, jnp.asarray(lam1))

    keep, _ = screen(X, y, lam1, lam2, theta1, delta=delta)
    res2 = fista_solve(X, y, lam2, max_iters=50000, tol=1e-14)
    active = np.abs(np.asarray(res2.w)) > ACTIVE_TOL
    assert not np.any(active & ~np.asarray(keep))


def test_exactness_of_screened_solve():
    """S3: solution of the reduced problem == full problem solution."""
    X, y, lmax = _setup(300, 120, seed=42)
    theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
    lam2 = 0.4 * lmax
    keep, _ = screen(X, y, lmax, lam2, theta1)
    keep = np.asarray(keep)

    full = fista_solve(X, y, lam2, max_iters=60000, tol=1e-14)
    idx = np.nonzero(keep)[0]
    Xr = jnp.asarray(np.asarray(X)[idx])
    red = fista_solve(Xr, y, lam2, max_iters=60000, tol=1e-14)

    w_full = np.asarray(full.w)
    w_red = np.zeros_like(w_full)
    w_red[idx] = np.asarray(red.w)
    np.testing.assert_allclose(w_red, w_full, atol=2e-4)
    np.testing.assert_allclose(float(red.obj), float(full.obj), rtol=1e-4)


def test_no_screening_when_lambdas_equal():
    """lam2 == lam1: K degenerates to {theta1}; kept set ⊇ active set at lam1."""
    X, y, lmax = _setup(150, 80, seed=9)
    lam = 0.5 * lmax
    res = fista_solve(X, y, lam, max_iters=50000, tol=1e-14)
    theta, delta = safe_theta_and_delta(X, y, res.w, res.b, jnp.asarray(lam))
    keep, bounds = screen(X, y, lam, lam, theta, delta=delta)
    active = np.abs(np.asarray(res.w)) > ACTIVE_TOL
    assert not np.any(active & ~np.asarray(keep))


def test_screening_becomes_aggressive_near_lambda_max():
    """Rejection rate should grow as lam2 -> lam_max (paper Fig./Table trend)."""
    X, y, lmax = _setup(400, 100, seed=11)
    theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
    rates = []
    for ratio in (0.95, 0.6, 0.2):
        keep, _ = screen(X, y, lmax, ratio * lmax, theta1)
        rates.append(1.0 - float(np.mean(np.asarray(keep))))
    assert rates[0] >= rates[1] >= rates[2]
    assert rates[0] > 0.5  # near lam_max almost everything screens out


def _enable_x64():
    """Compat: jax>=0.5 ``jax.enable_x64``; older jax has it in experimental."""
    if hasattr(jax, "enable_x64"):
        return jax.enable_x64(True)
    from jax.experimental import enable_x64

    return enable_x64()


def test_bounds_dtype_stability():
    """fp32 vs fp64 bounds agree to fp32 tolerance (safety under rounding)."""
    X, y, lmax = _setup(200, 100, seed=13)
    theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
    b32 = np.asarray(screen_bounds(X, y, lmax, 0.3 * lmax, theta1))
    with _enable_x64():
        X64 = jnp.asarray(np.asarray(X), jnp.float64)
        y64 = jnp.asarray(np.asarray(y), jnp.float64)
        t64 = theta_at_lambda_max(y64, jnp.asarray(lmax, jnp.float64))
        b64 = np.asarray(screen_bounds(X64, y64, lmax, 0.3 * lmax, t64))
    np.testing.assert_allclose(b32, b64, rtol=2e-3, atol=2e-3)


def test_sparse_theta_reduction_exact():
    """paper Sec 6.4: O(m*s) d_theta == dense O(m*n) when s >= nnz(theta)."""
    from repro.core.screening import d_theta_sparse, feature_reductions

    X, y, lmax = _setup(150, 80, seed=17)
    lam = 0.05 * lmax  # small lambda: strong fit => few margin violations
    res = fista_solve(X, y, lam, max_iters=60000, tol=1e-14)
    theta, _ = safe_theta_and_delta(X, y, res.w, res.b, jnp.asarray(lam))
    # the gap certificate's equality projection leaves O(|alpha^T y|/n)
    # dust on theta's zeros (~1e-9 here), so count the support above the
    # dust level, not strict positivity
    t_np = np.asarray(theta)
    nnz = int(np.sum(t_np > 1e-6 * t_np.max()))
    dense = feature_reductions(X, y, theta).d_theta
    sparse = d_theta_sparse(X, y, theta, support=max(nnz, 1))
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)
    assert nnz < 80  # sanity: theta is actually sparse at small lambda
