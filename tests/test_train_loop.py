"""Fault-tolerance behaviours of the trainer: resume, replay, loss descent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import TokenPipeline
from repro.launch.train import train


def test_loss_decreases_short_run(tmp_path):
    out = train("qwen2.5-3b", smoke=True, steps=15, batch=4, seq=64,
                ckpt_dir=str(tmp_path), ckpt_every=50, log=lambda *a: None)
    first = np.mean(out["losses"][:3])
    last = np.mean(out["losses"][-3:])
    assert last < first, (first, last)


def test_resume_is_exact(tmp_path):
    """Crash at step 10 then resume == uninterrupted 16-step run."""
    full = train("granite-8b", smoke=True, steps=16, batch=4, seq=32,
                 ckpt_dir=str(tmp_path / "full"), ckpt_every=8,
                 log=lambda *a: None)
    part = train("granite-8b", smoke=True, steps=8, batch=4, seq=32,
                 ckpt_dir=str(tmp_path / "res"), ckpt_every=8,
                 log=lambda *a: None)
    resumed = train("granite-8b", smoke=True, steps=16, batch=4, seq=32,
                    ckpt_dir=str(tmp_path / "res"), ckpt_every=8,
                    log=lambda *a: None)
    # same final losses (bitwise data replay + checkpointed optimizer state)
    np.testing.assert_allclose(full["losses"][-1], resumed["losses"][-1],
                               rtol=2e-5)
    w_full = jax.tree_util.tree_leaves(full["final_state"].params)[0]
    w_res = jax.tree_util.tree_leaves(resumed["final_state"].params)[0]
    np.testing.assert_allclose(np.asarray(w_full), np.asarray(w_res),
                               rtol=2e-4, atol=2e-5)


def test_data_pipeline_pure_replay():
    p = TokenPipeline(vocab_size=128, batch_size=4, seq_len=16, seed=3)
    a = p.batch_at(12)
    b = p.batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_nan_step_rejection():
    """A poisoned batch must not corrupt params (skip-and-continue)."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import init_train_state, make_train_step

    cfg = get_smoke_config("granite-8b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "targets": jnp.zeros((2, 16), jnp.int32)}
    s1, m1 = step(state, batch)
    # poison the params' input path via an out-of-range huge embed? instead:
    # inject NaN by scaling one param to NaN and verify skip flag + rollback
    bad_params = jax.tree_util.tree_map(lambda x: x, s1.params)
    bad_params["embed"]["tok"] = bad_params["embed"]["tok"] * jnp.nan
    s_bad = s1._replace(params=bad_params)
    s2, m2 = step(s_bad, batch)
    assert int(m2["skipped"]) == 1
    # params unchanged (rollback of the poisoned update)
    a = jax.tree_util.tree_leaves(s_bad.params)[1]
    b = jax.tree_util.tree_leaves(s2.params)[1]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s2.opt.step) == int(s_bad.opt.step)
