import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "opt": {"mu": jnp.ones((8, 4)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    s = _state()
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, s, extra={"next_step": 6})
    assert mgr.latest() == 5
    restored, manifest = mgr.restore(5, jax.tree_util.tree_map(jnp.zeros_like, s))
    assert manifest["extra"]["next_step"] == 6
    for a, b in zip(jax.tree_util.tree_leaves(s), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step))
    assert mgr.all_steps() == [3, 4]


def test_corrupt_latest_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    (tmp_path / "step_000000000002" / "manifest.json").write_text("{broken")
    assert mgr.latest() == 1


def test_elastic_restore_dtype_cast(tmp_path):
    """Restart may use a different param dtype policy (elastic/mixed)."""
    s = _state()
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, s)
    template = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.bfloat16) if x.dtype == jnp.float32 else x, s)
    restored, _ = mgr.restore(1, template)
    assert restored["params"]["w"].dtype == jnp.bfloat16


def test_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(9, _state())
    assert not list(tmp_path.glob("*.tmp"))
