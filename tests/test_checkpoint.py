import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "opt": {"mu": jnp.ones((8, 4)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    s = _state()
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, s, extra={"next_step": 6})
    assert mgr.latest() == 5
    restored, manifest = mgr.restore(5, jax.tree_util.tree_map(jnp.zeros_like, s))
    assert manifest["extra"]["next_step"] == 6
    for a, b in zip(jax.tree_util.tree_leaves(s), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step))
    assert mgr.all_steps() == [3, 4]


def test_corrupt_latest_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    (tmp_path / "step_000000000002" / "manifest.json").write_text("{broken")
    assert mgr.latest() == 1


def test_elastic_restore_dtype_cast(tmp_path):
    """Restart may use a different param dtype policy (elastic/mixed)."""
    s = _state()
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, s)
    template = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.bfloat16) if x.dtype == jnp.float32 else x, s)
    restored, _ = mgr.restore(1, template)
    assert restored["params"]["w"].dtype == jnp.bfloat16


def test_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(9, _state())
    assert not list(tmp_path.glob("*.tmp"))


def test_restore_raw_roundtrip(tmp_path):
    """restore_raw returns the exact flat arrays + manifest (the path
    server's snapshot format: no pytree template needed)."""
    flat = {
        "carry0": np.arange(12, dtype=np.float32).reshape(3, 4),
        "job0_lambdas": np.array([0.5, 0.25], dtype=np.float64),
        "act": np.array([True, False]),
    }
    extra = {"slots": [0, -1], "pending": [1, 2],
             "jobs": {"0": {"t": 2, "status": "running"}}}
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(3, flat, extra=extra)
    got, manifest = mgr.restore_raw(3)
    assert set(got) == set(flat)
    for k in flat:
        np.testing.assert_array_equal(got[k], flat[k])
        assert got[k].dtype == flat[k].dtype
    assert manifest["extra"] == json.loads(json.dumps(extra))


def _mixed_bucket_jobs():
    """Jobs spanning TWO bucket groups (different (m, n) grids), so the
    serve loop drains one group, reallocates, and drains the other."""
    from repro.launch.path_server import demo_jobs

    small = demo_jobs(2, m=64, n=32, seed=0)
    big = demo_jobs(2, m=96, n=48, seed=10)
    for i, j in enumerate(big):
        j.jid = 2 + i
    return small + big


def test_server_snapshot_resume_mixed_buckets(tmp_path):
    """Kill the server mid-drain on a TWO-bucket workload and resume: the
    snapshot must carry finished jobs from the *previous* bucket group
    (whose device state is long gone) as well as the live group's slots,
    and the resumed results must equal an uninterrupted run bitwise."""
    from repro.launch.path_server import PathServer
    from repro.testing import ServerKilled, kill_server_after

    ref = PathServer(slots=2).serve(_mixed_bucket_jobs(),
                                    log=lambda *a: None)
    assert all(r is not None for r in ref)

    sd = str(tmp_path / "snap")
    # kill late enough that the first (small) bucket has drained and the
    # group has been reallocated for the second
    total_small = sum(j.n_lambdas for j in _mixed_bucket_jobs()[:2])
    crashed = PathServer(slots=2)
    crashed._step_hook = kill_server_after(total_small + 1)
    with pytest.raises(ServerKilled):
        crashed.serve(_mixed_bucket_jobs(), log=lambda *a: None,
                      snapshot_dir=sd, snapshot_every=1)

    resumed = PathServer(slots=2).serve(
        _mixed_bucket_jobs(), log=lambda *a: None,
        snapshot_dir=sd, snapshot_every=1)
    assert all(r is not None for r in resumed)
    for ra, rb in zip(ref, resumed):
        np.testing.assert_array_equal(np.asarray(ra.lambdas),
                                      np.asarray(rb.lambdas))
        np.testing.assert_array_equal(np.asarray(ra.objectives),
                                      np.asarray(rb.objectives))
        np.testing.assert_array_equal(np.asarray(ra.weights),
                                      np.asarray(rb.weights))
        np.testing.assert_array_equal(np.asarray(ra.kept),
                                      np.asarray(rb.kept))


def test_server_snapshot_resume_early_kill_mixed_buckets(tmp_path):
    """Same workload, but killed while the FIRST bucket is still live —
    resume must re-enter mid-group and still finish both buckets."""
    from repro.launch.path_server import PathServer
    from repro.testing import ServerKilled, kill_server_after

    ref = PathServer(slots=2).serve(_mixed_bucket_jobs(),
                                    log=lambda *a: None)

    sd = str(tmp_path / "snap")
    crashed = PathServer(slots=2)
    crashed._step_hook = kill_server_after(2)
    with pytest.raises(ServerKilled):
        crashed.serve(_mixed_bucket_jobs(), log=lambda *a: None,
                      snapshot_dir=sd, snapshot_every=1)

    resumed = PathServer(slots=2).serve(
        _mixed_bucket_jobs(), log=lambda *a: None,
        snapshot_dir=sd, snapshot_every=1)
    assert all(r is not None for r in resumed)
    for ra, rb in zip(ref, resumed):
        np.testing.assert_array_equal(np.asarray(ra.objectives),
                                      np.asarray(rb.objectives))
        np.testing.assert_array_equal(np.asarray(ra.weights),
                                      np.asarray(rb.weights))
