"""Chaos tests: inject faults, assert the safety invariants hold.

The invariants (ISSUE 9):
  1. a poisoned solve at any path step screens a SUPERSET of the clean
     run's kept features at that step (fail-safe keep-all, never a wrong
     discard) and recovers to identical final objectives;
  2. killing the path server mid-drain and resuming from its snapshot
     produces results equal to an uninterrupted run;
  3. a corrupt store chunk is detected by checksum BEFORE its bytes can
     participate in any sweep or screening bound;
  4. transient read faults are absorbed by retry; persistent ones surface
     as typed StoreErrors.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.path import PathDriver
from repro.core.solver import HEALTH_SCREEN_REFUSED
from repro.data import make_sparse_classification
from repro.sparse.chunked import (
    FeatureChunked,
    StoreCorruptError,
    StoreError,
    StoreMissingError,
)
from repro.testing import faults


@pytest.fixture(scope="module")
def ds():
    return make_sparse_classification(m=80, n=48, k_active=6, seed=3)


def _driver(**kw):
    return PathDriver("feature_vi", tol=1e-8, max_iters=1500, **kw)


def _run(driver, X, y, T=5):
    return driver.run(X, y, n_lambdas=T, lam_min_ratio=0.2)


# -- invariant 1: poisoned solve -> keep-all fail-safe, then full recovery --

def test_poisoned_path_step_keeps_superset_and_recovers(ds):
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    clean = _run(_driver(), X, y)

    drv = _driver()
    drv._fault_injector = faults.poison_path_step(2)
    poisoned = _run(drv, X, y)
    assert drv._fault_injector.state["fired"]

    health = poisoned.extras["health"]
    # the step after the poison screens from a refused certificate
    assert health[3] & HEALTH_SCREEN_REFUSED
    assert not np.any(clean.extras["health"])
    # fail-safe: never fewer kept features than the clean run, and the
    # refused step keeps everything
    assert np.all(poisoned.kept >= clean.kept)
    assert poisoned.kept[3] == X.shape[0]
    # recovery: every step except the poisoned one matches the clean run
    T = len(clean.lambdas)
    for k in range(T):
        if k == 2:
            continue
        assert abs(poisoned.objectives[k] - clean.objectives[k]) < 1e-4
    assert np.allclose(poisoned.weights[-1], clean.weights[-1], atol=1e-4)


def test_poisoned_chunked_path_recovers(ds):
    y = np.asarray(ds.y)
    fc_c = FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=16)
    clean = _run(_driver(), fc_c, y)

    fc_p = FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=16)
    drv = _driver()
    drv._fault_injector = faults.poison_path_step(2)
    poisoned = _run(drv, fc_p, y)

    assert poisoned.extras["health"][3] & HEALTH_SCREEN_REFUSED
    assert np.all(poisoned.kept >= clean.kept)
    for k in range(len(clean.lambdas)):
        if k == 2:
            continue
        assert abs(poisoned.objectives[k] - clean.objectives[k]) < 1e-4


def test_stream_solver_guard_rolls_back(ds):
    from repro.sparse.solver_stream import fista_solve_chunked

    y = np.asarray(ds.y)
    fc = FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=16)
    lam = 1.0
    clean = fista_solve_chunked(fc, y, lam, max_iters=400)
    assert int(clean.health) == 0

    hook = faults.poison_stream_iterate(2)
    hooked = fista_solve_chunked(fc, y, lam, max_iters=400,
                                 iteration_hook=hook)
    assert hook.state["fired"]
    assert int(hooked.health) >= 1
    assert abs(float(hooked.obj) - float(clean.obj)) < 1e-4


def test_poisoned_warm_start_sanitized(ds):
    from repro.sparse.solver_stream import fista_solve_chunked

    y = np.asarray(ds.y)
    fc = FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=16)
    clean = fista_solve_chunked(fc, y, 1.0, max_iters=400)
    w0 = np.zeros((fc.shape[0],), np.float32)
    w0[1] = np.nan
    res = fista_solve_chunked(fc, y, 1.0, w0=w0, b0=np.nan, max_iters=400)
    assert int(res.health) >= 2
    assert abs(float(res.obj) - float(clean.obj)) < 1e-4


# -- invariant 3: corruption detected before the bytes are used -------------

def test_corrupt_chunk_detected_before_screening(tmp_path, ds):
    sd = str(tmp_path / "store")
    FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=16).save_store(
        sd, y=np.asarray(ds.y))
    # flip bytes in grid chunk 1 (rows 16..32 of the dense payload)
    faults.corrupt_store_bytes(os.path.join(sd, "X.bin"),
                               offset=17 * ds.X.shape[1] * 4)
    fc = FeatureChunked.from_store(sd)
    from repro.sparse.screen_stream import screen_step_stream

    lam_max = float(np.max(np.abs(np.asarray(ds.X) @ (
        np.asarray(ds.y) - np.mean(np.asarray(ds.y))))))
    theta = np.zeros((ds.X.shape[1],), np.float32)
    with pytest.raises(StoreCorruptError, match="chunk 1"):
        screen_step_stream(fc, np.asarray(ds.y), lam_max, 0.5 * lam_max,
                           theta)


def test_truncated_and_missing_store_typed_errors(tmp_path, ds):
    sd = str(tmp_path / "store")
    FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=16).save_store(sd)
    faults.truncate_store_file(os.path.join(sd, "X.bin"), nbytes=64)
    with pytest.raises(StoreCorruptError, match="truncated"):
        FeatureChunked.from_store(sd)
    with pytest.raises(StoreMissingError):
        FeatureChunked.from_store(str(tmp_path / "absent"))


def test_flaky_reads_absorbed_dead_reads_raise(tmp_path, ds):
    sd = str(tmp_path / "store")
    FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=16).save_store(
        sd, y=np.asarray(ds.y))
    with faults.flaky_reads(n_failures=1) as counts:
        fc = FeatureChunked.from_store(sd)
        fc.verify()
        assert counts  # at least one injected failure was retried through
    with faults.dead_reads():
        with pytest.raises(StoreError):
            FeatureChunked.from_store(sd)


def test_libsvm_rebuild_fallback(tmp_path):
    p = str(tmp_path / "toy.svm")
    with open(p, "w") as f:
        f.write("+1 1:0.5 3:1.5\n-1 2:2.0\n+1 1:1.0 4:0.25\n")
    fc, y = FeatureChunked.from_libsvm_cached(p, chunk_m=2)
    ref = fc.as_dense().copy()
    faults.corrupt_store_bytes(os.path.join(p + ".store", "data.bin"))
    fc2, y2 = FeatureChunked.from_libsvm_cached(p, chunk_m=2)
    fc2.verify()
    assert np.array_equal(fc2.as_dense(), ref)
    assert np.array_equal(y2, y)


# -- invariant 2: kill mid-drain + resume == uninterrupted -------------------

def test_server_kill_resume_equals_uninterrupted(tmp_path):
    from repro.launch.path_server import PathServer, demo_jobs

    ref = PathServer(slots=2).serve(demo_jobs(4, m=96, n=48),
                                    log=lambda *a: None)

    sd = str(tmp_path / "snap")
    crashed = PathServer(slots=2)
    crashed._step_hook = faults.kill_server_after(4)
    with pytest.raises(faults.ServerKilled):
        crashed.serve(demo_jobs(4, m=96, n=48), log=lambda *a: None,
                      snapshot_dir=sd, snapshot_every=1)

    resumed = PathServer(slots=2).serve(
        demo_jobs(4, m=96, n=48), log=lambda *a: None,
        snapshot_dir=sd, snapshot_every=1)
    assert all(r is not None for r in resumed)
    for ra, rb in zip(ref, resumed):
        assert np.array_equal(np.asarray(ra.objectives),
                              np.asarray(rb.objectives))
        assert np.array_equal(np.asarray(ra.weights),
                              np.asarray(rb.weights))
        assert np.array_equal(np.asarray(ra.extras["health"]),
                              np.asarray(rb.extras["health"]))


def test_server_quarantine_isolates_tenant(monkeypatch):
    from repro.launch.path_server import PathServer, demo_jobs

    # disable the on-device guard so the poison actually reaches the host
    # check (with guards on, the solver self-heals and no retry is needed)
    monkeypatch.setenv("REPRO_SOLVER_GUARDS", "0")
    jobs = demo_jobs(4, m=96, n=48)
    for j in jobs:
        j.max_retries = 0
    srv = PathServer(slots=2)
    state = {"hit": False}

    def poison_slot0(step):
        if not state["hit"] and srv._act[0]:
            state["hit"] = True
            b = srv._carry[1]
            srv._carry = (srv._carry[0], b.at[0].set(jnp.nan)) + srv._carry[2:]

    srv._step_hook = poison_slot0
    res = srv.serve(jobs, log=lambda *a: None)
    failed = [j for j in jobs if j.status == "failed"]
    assert len(failed) == 1
    assert "non-finite" in failed[0].error
    assert srv.stats["jobs_failed"] == 1
    assert sum(r is None for r in res) == 1
    assert sum(r is not None for r in res) == 3


def test_server_retry_recovers_transient_poison(monkeypatch):
    from repro.launch.path_server import PathServer, demo_jobs

    monkeypatch.setenv("REPRO_SOLVER_GUARDS", "0")
    ref = PathServer(slots=2).serve(demo_jobs(4, m=96, n=48),
                                    log=lambda *a: None)
    srv = PathServer(slots=2)
    state = {"hit": False}

    def poison_once(step):
        if step == 3 and not state["hit"]:
            state["hit"] = True
            b = srv._carry[1]
            srv._carry = (srv._carry[0], b.at[0].set(jnp.nan)) + srv._carry[2:]

    srv._step_hook = poison_once
    res = srv.serve(demo_jobs(4, m=96, n=48), log=lambda *a: None)
    assert srv.stats["retries"] >= 1
    assert all(r is not None for r in res)
    for ra, rb in zip(ref, res):
        assert np.max(np.abs(np.asarray(ra.objectives)
                             - np.asarray(rb.objectives))) < 1e-4


def test_server_deadline_evicts(monkeypatch):
    import time

    from repro.launch.path_server import PathServer, demo_jobs

    jobs = demo_jobs(2, m=96, n=48)
    jobs[0].deadline_s = 0.0
    jobs[0].t_start = time.perf_counter() - 1.0
    res = PathServer(slots=2).serve(jobs, log=lambda *a: None)
    assert jobs[0].status == "failed" and "deadline" in jobs[0].error
    assert res[0] is None and res[1] is not None


# -- cache guard: a poisoned anchor invalidates, streams everything ----------

def test_chunk_cache_refresh_rejects_poisoned_anchor(ds):
    from repro.core.screening import anchor_stats, fixed_stats
    from repro.sparse.screen_stream import ChunkScreenCache, fixed_reductions

    y = np.asarray(ds.y)
    fc = FeatureChunked.from_dense(np.asarray(ds.X), chunk_m=16)
    d_one, d_y, d_sq = fixed_reductions(fc, y)
    yj = jnp.asarray(y, fc.dtype)
    fixed = fixed_stats(yj, d_one, d_y, d_sq)
    theta = jnp.zeros((ds.X.shape[1],), fc.dtype)
    d_theta = jnp.zeros((fc.shape[0],), fc.dtype)

    cache = ChunkScreenCache(fc)
    good = anchor_stats(yj, 2.0, theta, 0.0, d_theta)
    cache.refresh(good)
    live, _ = cache.live_mask(1.0, fixed)
    assert not live.all()  # a zero anchor certifies plenty dead

    bad = anchor_stats(yj, 2.0, theta.at[0].set(jnp.nan), jnp.nan, d_theta)
    cache.refresh(bad)
    live2, bounds2 = cache.live_mask(1.0, fixed)
    # poisoned anchor invalidated the cache: everything streams again
    assert live2.all()
    assert np.all(np.isinf(np.asarray(bounds2)))
