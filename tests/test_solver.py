import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep; see tests/_hyp_compat.py + pyproject
    from _hyp_compat import given, settings, st

from repro.core import fista_solve, lambda_max, lipschitz_estimate, primal_objective
from repro.data import make_sparse_classification


def test_objective_monotone_convergence():
    ds = make_sparse_classification(m=120, n=90, seed=21)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lam = 0.3 * float(lambda_max(X, y))
    r1 = fista_solve(X, y, lam, max_iters=50, tol=0.0)
    r2 = fista_solve(X, y, lam, max_iters=500, tol=0.0)
    r3 = fista_solve(X, y, lam, max_iters=5000, tol=0.0)
    assert float(r1.obj) >= float(r2.obj) >= float(r3.obj) - 1e-6


def test_kkt_conditions_at_solution():
    """Subgradient optimality: |fhat_j^T alpha| <= lam, == lam sign-matched on support."""
    ds = make_sparse_classification(m=100, n=200, seed=22)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lam = 0.25 * float(lambda_max(X, y))
    res = fista_solve(X, y, lam, max_iters=80000, tol=1e-15)
    xi = jnp.maximum(0.0, 1.0 - y * (X.T @ res.w + res.b))
    corr = np.asarray(X @ (y * xi))  # = alpha^T fhat per feature
    w = np.asarray(res.w)
    # inactive: |corr| <= lam (+tol)
    assert np.all(np.abs(corr[np.abs(w) <= 1e-8]) <= lam * (1 + 5e-3) + 1e-4)
    # active: corr ~= sign(w) * lam (paper Eq. 21)
    act = np.abs(w) > 1e-6
    if act.any():
        np.testing.assert_allclose(corr[act], np.sign(w[act]) * lam, rtol=2e-2, atol=1e-3)
    # bias optimality: sum_i alpha_i y_i = 0 (paper Eq. 17)
    assert abs(float(xi @ y)) < 1e-2 * max(1.0, float(jnp.sum(xi)))


def test_warm_start_reduces_iterations():
    ds = make_sparse_classification(m=200, n=150, seed=23)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lmax = float(lambda_max(X, y))
    r1 = fista_solve(X, y, 0.5 * lmax, max_iters=30000, tol=1e-12)
    cold = fista_solve(X, y, 0.45 * lmax, max_iters=30000, tol=1e-12)
    warm = fista_solve(X, y, 0.45 * lmax, w0=r1.w, b0=r1.b, max_iters=30000, tol=1e-12)
    assert int(warm.n_iters) <= int(cold.n_iters)
    np.testing.assert_allclose(float(warm.obj), float(cold.obj), rtol=1e-5)


def test_lipschitz_upper_bounds_spectrum():
    ds = make_sparse_classification(m=80, n=60, seed=24)
    X = jnp.asarray(ds.X)
    L = float(lipschitz_estimate(X, n_iters=80))
    A = np.concatenate([np.asarray(X), np.ones((1, 60))], axis=0)
    true = np.linalg.norm(A, 2) ** 2
    np.testing.assert_allclose(L, true, rtol=1e-2)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), ratio=st.floats(0.15, 0.9))
def test_solution_agrees_with_scipy_reference(seed, ratio):
    """Cross-check against an independent scipy LBFGS solve of a smoothed dual
    formulation — here instead: verify against scipy.optimize on the primal
    with huberized L1 (tight smoothing), objective within tolerance."""
    import scipy.optimize as sopt

    ds = make_sparse_classification(m=40, n=60, seed=seed)
    X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)
    lam = ratio * float(lambda_max(X, y))
    res = fista_solve(X, y, lam, max_iters=60000, tol=1e-15)

    Xn, yn = np.asarray(X, np.float64), np.asarray(y, np.float64)

    def obj(z):
        w, b = z[:-1], z[-1]
        xi = np.maximum(0.0, 1.0 - yn * (Xn.T @ w + b))
        return 0.5 * xi @ xi + lam * np.sum(np.sqrt(w * w + 1e-12))

    z0 = np.concatenate([np.asarray(res.w, np.float64), [float(res.b)]])
    out = sopt.minimize(obj, np.zeros_like(z0), method="L-BFGS-B",
                        options={"maxiter": 5000, "ftol": 1e-14})
    ours = float(primal_objective(X, y, res.w, res.b, lam))
    assert ours <= out.fun + 1e-3 * max(1.0, abs(out.fun))
