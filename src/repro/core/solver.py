"""Jittable FISTA solver for the L1-regularized L2-loss SVM (paper Eq. 1/23).

Unconstrained composite form (paper Eq. 23):

    min_{w,b}  h(w, b) + lam ||w||_1,
    h(w, b) = 1/2 sum_i max(0, 1 - y_i (w^T x_i + b))^2

``h`` is convex with Lipschitz-continuous gradient (the squared hinge is C^1),
so accelerated proximal gradient (FISTA) applies; the prox of ``lam||.||_1``
is soft-thresholding on ``w`` only (``b`` is unpenalized).

Gradients (paper Eqs. 24-25), with xi = max(0, 1 - y*(X^T w + b)):

    grad_w = -X (y * xi),     grad_b = -y^T xi

Lipschitz constant: L <= sigma_max([X; 1^T])^2, estimated by power iteration.
Along a path the estimate for the *full* X upper-bounds the constant of any
row/column-masked (or gathered) subproblem — removing rows/columns of a
matrix never increases its largest singular value — so drivers estimate L
once per path and thread it through every reduced solve (see
``core/path.py`` / ``core/path_scan.py``; per-solve re-estimation stays
available via their ``exact_lipschitz`` opt-in).

Everything is pure ``jax.lax`` control flow: the whole solve jit-compiles to
one XLA program (and runs unchanged under shard_map — see
``core/distributed.py``).

Performance architecture — the fused hot loop
---------------------------------------------
A FISTA iteration needs margins at the momentum point z (for the gradient)
and the objective at the new iterate (for the monotone-restart test). The
naive body pays three full sweeps of X per iteration — ``X^T z`` (margins),
``X (y xi)`` (gradient), ``X^T w_new`` (objective) — plus two more when the
restart fires. This body pays **two**:

* the state carries ``u = X^T w`` and ``u_prev = X^T w_prev``; since the
  momentum point is the linear extrapolation ``z = w + beta (w - w_prev)``,
  its margins are ``u + beta (u - u_prev)`` — an O(n) axpy, no sweep;
* the sweep at the new iterate is *fused*: one pass over X produces
  ``u_new``, the slacks ``xi_new``, and the squared-hinge loss (and hence
  the objective), so the old separate ``_objective`` sweep is gone. On TPU
  this is the Pallas kernel ``kernels/hinge.py::hinge_margin`` (fp32 VMEM
  accumulation, loss partials reduced per block); elsewhere it is the same
  computation in XLA. Dispatch is per-call/env via
  ``kernels/ops.py::fista_use_pallas`` (``use_pallas=``,
  ``REPRO_FISTA_PALLAS``; interpret-mode fallback off-TPU honors
  ``REPRO_PALLAS_INTERPRET``);
* the monotone-restart fallback (a plain proximal step from ``(w, b)``) sits
  under ``lax.cond``, so its two extra sweeps are paid only on iterations
  whose extrapolated step actually increased the objective — not eagerly on
  every iteration as the pre-fusion ``tree_map(where, ...)`` body did.
  (Under ``vmap`` — the batched path engine — XLA lowers the cond to a
  select and both branches run; correctness is unaffected.)

Dynamic (in-solver) screening — ``fista_solve_dynamic``
-------------------------------------------------------
The VI region certifying ``theta*(lam)`` shrinks as the iterate converges:
with ``theta`` the gap-certified dual-feasible point at the *current*
``(w, b)`` and ``delta = O(sqrt(gap))`` its distance bound to ``theta*``,
the at-lambda region (``lam1 = lam2 = lam``) is the ball through ``theta``
cut by its own tangent halfspace — a set of diameter ``O(sqrt(R*delta))``
that collapses onto ``theta*`` as the gap goes to zero. Features whose
bound over that set stays below 1 are provably inactive at ``lam`` and can
be zeroed *mid-solve* (Liu et al.-style dynamic screening), which compounds
multiplicatively with the between-lambda sequential screen.

``fista_solve_dynamic`` therefore runs a segmented solve: an outer
``lax.while_loop`` whose body (a) runs up to ``screen_every`` plain FISTA
iterations, (b) computes the duality gap of the (possibly sample-masked)
problem, (c) rebuilds the region from the current iterate and re-evaluates
the feature bounds, and (d) ANDs the result into a live feature mask that
zeroes screened coordinates for all remaining iterations. Per-segment
kept-counts and gaps are returned as telemetry (`DynamicFistaResult`).
"""

from __future__ import annotations

import os
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .screening import (
    SAFE_TAU,
    FeatureReductions,
    screen_bounds_from_reductions,
    shared_scalars_from_stats,
)

__all__ = [
    "Collectives",
    "LOCAL",
    "FistaState",
    "FistaResult",
    "DynamicFistaResult",
    "lipschitz_estimate",
    "soft_threshold",
    "fista_solve",
    "fista_solve_dynamic",
    "fista_run",
    "gap_theta_delta",
]


class Collectives(NamedTuple):
    """Reduction seam: the four cross-shard reductions the solver math needs.

    Every O(mn) routine in this module reduces over exactly two axes — the
    feature ("model") axis for margins/L1 norms and the sample ("data") axis
    for gradients/losses — plus a replicated bias-gradient reduction and a
    max for the dual-feasibility rescale. Parameterizing the implementations
    over these four callables lets ONE body serve both execution modes:

    * :data:`LOCAL` (the default) binds all four to the identity, which is
      exactly the single-device math — same ops, same order, bitwise;
    * ``distributed.mesh_collectives`` binds them to ``lax.psum``/``pmax``
      over the ``svm_mesh`` axes, which is how the sharded path engine
      (``path_scan.svm_path_scan_sharded``) runs this module's FISTA body,
      gap certificate, and Lipschitz power iteration inside ``shard_map``
      without a forked implementation.
    """

    psum_model: "object"  # reduce over the feature axis (margins, sum|w|)
    psum_data: "object"   # reduce over the sample axis (grads, losses)
    psum_bias: "object"   # bias grad: global sum averaged over model replicas
    pmax_model: "object"  # max over the feature axis (dual feasibility)


def _identity(x):
    return x


# The local binding: every reduction is already global. Note for sharded
# bindings (distributed.mesh_collectives): a psum over a size-1 mesh axis
# must bind to this same identity, not to a degenerate all-reduce — a
# trivial all-reduce is value-preserving but changes XLA's fusion context,
# and the resulting 1-ulp objective differences flip the monotone-restart /
# stopping predicates exactly at their convergence-plateau ties, breaking
# the sharded-vs-local bitwise guarantee (tests/test_path_scan.py).
LOCAL = Collectives(_identity, _identity, _identity, _identity)

#: Cap on health-guard rollbacks per solve. Each trip halves the step size,
#: so 8 trips leave a 256x smaller step — a solve still tripping past that
#: is unrecoverable (poisoned operands), and bounding the trips keeps a
#: NaN'd problem from burning max_iters on rollback churn.
MAX_GUARD_TRIPS = 8

#: Bit set in ``health`` when a screening refresh was *refused* because the
#: gap certificate was non-finite (the fail-safe kept every feature). Low
#: bits count solver guard trips (rollbacks + sanitized warm starts).
HEALTH_SCREEN_REFUSED = 1 << 16


def _resolve_guards(flag: Optional[bool] = None) -> bool:
    """Numerical health guards default ON; ``REPRO_SOLVER_GUARDS=0``
    disables them (the bench's guard-off baseline). Resolved at dispatch so
    the flag lands in jit static args — an env read inside a trace would not
    retrace on change (cf. ``_resolve_pallas``)."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_SOLVER_GUARDS", "1").lower() not in (
        "0", "false", "off")


class FistaState(NamedTuple):
    w: jax.Array
    b: jax.Array
    w_prev: jax.Array
    b_prev: jax.Array
    u: jax.Array       # X^T w      (margins of the current point, no bias)
    u_prev: jax.Array  # X^T w_prev
    t: jax.Array
    k: jax.Array
    obj: jax.Array
    rel_change: jax.Array
    # previous iterations' rel_change: convergence requires THREE consecutive
    # sub-tol iterations. In fp32 the objective's relative ulp is ~6e-8, so
    # below that any single rel_change is an exact-tie coin flip — FISTA's
    # momentum plateaus produce such ties mid-trajectory while ``w`` is
    # still moving (observed: a one-ulp different L stops 2.4e-5 short of
    # the optimum on a plateau the other L sails through; a single
    # look-back still stranded 1.3e-6). A run of three ties at a
    # non-optimum is rare enough that engines with reassociated reductions
    # (chunked storage, sharded meshes) agree to <=1e-6.
    rel_prev: jax.Array = jnp.inf
    rel_prev2: jax.Array = jnp.inf
    # health-guard state (guards on only — see _make_fista_body): rollback
    # trip count, and the multiplicative step-size backoff the trips applied.
    # A trip means the candidate iterate was non-finite or a plain prox step
    # increased the objective — both say the current step size is invalid.
    health: jax.Array = 0
    backoff: jax.Array = 1.0


class FistaResult(NamedTuple):
    w: jax.Array
    b: jax.Array
    obj: jax.Array
    n_iters: jax.Array
    converged: jax.Array
    # margins u = X^T w at the accepted point (carried by the fused body, so
    # returning them is free); callers certifying the solution can hand them
    # to gap_theta_delta and skip its re-sweep. None from legacy paths.
    u: Optional[jax.Array] = None
    # int32 guard telemetry: low bits count rollback trips (0 = clean solve),
    # HEALTH_SCREEN_REFUSED flags a refused screening refresh. None from
    # legacy paths that never threaded guards.
    health: Optional[jax.Array] = None


class DynamicFistaResult(NamedTuple):
    """`FistaResult` plus in-solver screening telemetry.

    ``kept_per_segment[s]`` is the live-feature count after segment ``s``'s
    re-screen; ``gap_per_segment[s]`` the duality-gap estimate it certified
    the region from. Segments never run (early convergence) hold the
    sentinel ``-1`` / ``inf``.
    """

    w: jax.Array
    b: jax.Array
    obj: jax.Array
    n_iters: jax.Array
    converged: jax.Array
    feature_mask: jax.Array      # (m,) bool — final live mask
    kept_per_segment: jax.Array  # (S,) int32
    gap_per_segment: jax.Array   # (S,) float
    n_segments: jax.Array        # int32 — segments actually run
    u: Optional[jax.Array] = None  # X^T w at the accepted point (see FistaResult)
    # dynamic *sample* re-screen telemetry (``dynamic_samples=True`` only):
    # final live sample mask and per-segment live-sample counts. The sample
    # screen is margin-*predicted*, not a-priori safe — callers must verify
    # screened samples at the solution (core/path.py's verification loop
    # does) before treating the result as exact.
    sample_mask: Optional[jax.Array] = None          # (n,) bool
    kept_samples_per_segment: Optional[jax.Array] = None  # (S,) int32
    # guard telemetry, same encoding as FistaResult.health
    health: Optional[jax.Array] = None


def soft_threshold(x: jax.Array, tau: jax.Array) -> jax.Array:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - tau, 0.0)


def _rel3(s: "FistaState") -> jax.Array:
    """Worst rel_change of the last three iterations (the stop criterion —
    see ``FistaState.rel_prev``)."""
    return jnp.maximum(jnp.maximum(s.rel_change, s.rel_prev), s.rel_prev2)


def lipschitz_estimate(X: jax.Array, n_iters: int = 30, key: Optional[jax.Array] = None,
                       col: Collectives = LOCAL) -> jax.Array:
    """Power iteration for ``sigma_max([X; 1^T])^2`` (augmented bias row).

    Monotonicity along a path: any row/column submatrix of ``[X; 1^T]`` that
    keeps the bias row (which every masked/gathered subproblem does) has
    ``sigma_max`` no larger than the full matrix's, so this estimate is a
    valid step-size bound for every screened solve of the same path
    (property-tested in tests/test_path_scan.py).

    ``col`` binds the two GEMV reductions to mesh collectives when ``X`` is a
    ``shard_map`` block (under sharding every data shard seeds the same local
    key, so the implied global start vector is block-periodic — any nonzero
    start is valid for power iteration).
    """
    n = X.shape[1]
    if key is None:
        key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (n,), dtype=X.dtype)

    def norm(v):
        return jnp.sqrt(jnp.maximum(col.psum_data(jnp.sum(v * v)), 0.0))

    def body(v, _):
        v = v / jnp.maximum(norm(v), 1e-30)
        u_w = col.psum_data(X @ v)
        u_b = col.psum_data(jnp.sum(v))
        v = col.psum_model(X.T @ u_w) + u_b
        return v, None

    v, _ = jax.lax.scan(body, v, None, length=n_iters)
    return norm(v)  # ||A^T A v|| / ||v|| with ||v||=1 pre-normalized


def _objective(X, y, w, b, lam, sample_mask=None):
    xi = jnp.maximum(0.0, 1.0 - y * (X.T @ w + b))
    if sample_mask is not None:
        xi = xi * sample_mask
    return 0.5 * jnp.sum(xi * xi) + lam * jnp.sum(jnp.abs(w))


def _margin_obj_sweep(X, y, lam, w, b, sm, use_pallas, col=LOCAL, valid_m=None):
    """One fused pass over X: ``(u = X^T w, objective(w, b))``.

    The Pallas route also folds the loss partials into the sweep; with a
    sample mask the (cheap, O(n)) masked loss is recomputed from the
    returned slacks, so no second pass over X is ever needed. ``valid_m``
    (dynamic scalar, Pallas route only) marks rows past the compacted active
    set so the kernel can skip their blocks. The Pallas route needs the full
    margins locally (xi is finalized in-kernel), so it is single-device only
    — sharded callers (``col`` non-local) take the XLA path.
    """
    if use_pallas and col is LOCAL:
        from repro.kernels.ops import margin_obj_op  # lazy: no import cycle

        u, xi, loss = margin_obj_op(X, w, y, b, valid_m=valid_m)
        u = u.astype(X.dtype)
        if sm is not None:
            xi = xi.astype(X.dtype) * sm
            loss = 0.5 * jnp.sum(xi * xi)
        loss = jnp.asarray(loss, X.dtype)
    else:
        u = col.psum_model(X.T @ w)
        xi = jnp.maximum(0.0, 1.0 - y * (u + b))
        if sm is not None:
            xi = xi * sm
        loss = col.psum_data(0.5 * jnp.sum(xi * xi))
    return u, loss + lam * col.psum_model(jnp.sum(jnp.abs(w)))


def _grad_sweep(X, y, xi, use_pallas, col=LOCAL, valid_m=None):
    """``grad_w = -X (y * xi)`` — the transposed pass over X."""
    if use_pallas and col is LOCAL:
        from repro.kernels.ops import hinge_grad_op  # lazy: no import cycle

        return hinge_grad_op(X, y, xi, valid_m=valid_m).astype(X.dtype)
    return col.psum_data(-(X @ (y * xi)))


def _init_state(X, y, lam, w0, b0, sm, use_pallas, col=LOCAL,
                valid_m=None, guards=False) -> FistaState:
    trips = jnp.asarray(0, jnp.int32)
    if guards:
        # sanitize the warm start: a poisoned w0/b0 (NaN/inf from a faulted
        # previous path step) would poison every later iterate through the
        # carried margins; zeroing the bad coordinates is always feasible
        # (w = 0 is in the domain) and counts one trip.
        bad0 = (~jnp.all(jnp.isfinite(w0))) | (~jnp.isfinite(b0))
        # mesh-consistent verdict: w0 is a shard block under shard_map, so
        # every shard must agree on the trip (divergent health would split
        # the while-loop conds and deadlock the body's psums). Identity
        # under LOCAL.
        bad0 = col.pmax_model(bad0.astype(X.dtype)) > 0.5
        w0 = jnp.where(jnp.isfinite(w0), w0, jnp.zeros_like(w0))
        b0 = jnp.where(jnp.isfinite(b0), b0, jnp.zeros_like(b0))
        trips = bad0.astype(jnp.int32)
    u0, obj0 = _margin_obj_sweep(X, y, lam, w0, b0, sm, use_pallas, col,
                                 valid_m)
    return FistaState(
        w=w0, b=b0, w_prev=w0, b_prev=b0, u=u0, u_prev=u0,
        t=jnp.asarray(1.0, X.dtype), k=jnp.asarray(0, jnp.int32),
        obj=obj0, rel_change=jnp.asarray(jnp.inf, X.dtype),
        rel_prev=jnp.asarray(jnp.inf, X.dtype),
        rel_prev2=jnp.asarray(jnp.inf, X.dtype),
        health=trips, backoff=jnp.asarray(1.0, X.dtype),
    )


def _make_fista_body(X, y, lam, inv_L, sm, fmask=None, use_pallas=False,
                     col=LOCAL, valid_m=None, guards=False):
    """One FISTA iteration ``FistaState -> FistaState`` as a closure.

    ``fmask`` (0/1 over features, optional) freezes screened coordinates at
    zero: the prox output is masked, so a coordinate once zeroed stays zero
    — this is exactly the problem with those feature rows removed (the rows
    contribute nothing to the margins either, since ``w_j = 0``). Shared by
    :func:`fista_solve` and the dynamic solver's inner segments.

    Cost: 2 fused sweeps of X per iteration (gradient at the momentum point,
    margins+objective at the new point); +2 under ``lax.cond`` when the
    monotone restart fires. See the module docstring for the architecture.

    ``guards`` adds the on-device numerical health guard: a non-finite
    candidate iterate, or a *plain* prox step that still increased the
    objective (a valid ``inv_L <= 1/L`` makes that step monotone, so an
    increase beyond rounding noise means the step size is invalid), rolls
    the iterate back to the last accepted finite point, halves the step via
    ``FistaState.backoff``, and counts a trip in ``FistaState.health``. A
    genuine momentum restart is NOT a trip — only its fallback step failing
    is.
    """

    def mask_w(w):
        return w if fmask is None else w * fmask

    def prox_from(w_a, b_a, u_a, inv_Le):
        """One proximal-gradient step anchored at ``(w_a, b_a)`` whose
        margins ``u_a = X^T w_a`` are already known. 2 sweeps of X."""
        xi = jnp.maximum(0.0, 1.0 - y * (u_a + b_a))
        if sm is not None:
            xi = xi * sm
        gw = _grad_sweep(X, y, xi, use_pallas, col, valid_m)
        gb = col.psum_bias(-jnp.sum(y * xi))
        w_new = mask_w(soft_threshold(w_a - inv_Le * gw, lam * inv_Le))
        b_new = b_a - inv_Le * gb
        u_new, obj_new = _margin_obj_sweep(X, y, lam, w_new, b_new, sm,
                                           use_pallas, col, valid_m)
        return w_new, b_new, u_new, obj_new

    def body(s: FistaState) -> FistaState:
        inv_Le = inv_L * s.backoff if guards else inv_L
        # momentum extrapolation — margins included (u is linear in w, so
        # the momentum point's margins need no sweep)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * s.t * s.t))
        beta = (s.t - 1.0) / t_next
        zw = s.w + beta * (s.w - s.w_prev)
        zb = s.b + beta * (s.b - s.b_prev)
        uz = s.u + beta * (s.u - s.u_prev)

        w_new, b_new, u_new, obj_new = prox_from(zw, zb, uz, inv_Le)

        # monotone restart: if the extrapolated step increased the objective,
        # fall back to a plain proximal step from (w, b) — under lax.cond so
        # its two sweeps are paid only when the restart actually fires.
        # (A NaN obj_new compares False here and falls through to the guard.)
        restarted = obj_new > s.obj

        def restart(_):
            w_p, b_p, u_p, obj_p = prox_from(s.w, s.b, s.u, inv_Le)
            return w_p, b_p, u_p, obj_p, jnp.asarray(1.0, X.dtype)

        def accept(_):
            return w_new, b_new, u_new, obj_new, t_next

        w_new, b_new, u_new, obj_new, t_next = jax.lax.cond(
            restarted, restart, accept, None
        )

        # a restart iteration is not convergence evidence: the fallback step
        # from (w, b) moves little by construction, so counting its tiny
        # objective change as rel_change stops the solve at a momentum
        # overshoot instead of the optimum (observed: ulp-level L
        # differences flip a restart tie and strand the objective 2e-5 off).
        # Force one more (plain, t=1) iteration after every restart.
        rel = jnp.where(
            restarted, jnp.asarray(jnp.inf, X.dtype),
            jnp.abs(s.obj - obj_new) / jnp.maximum(jnp.abs(s.obj), 1e-30),
        )
        health, backoff = s.health, s.backoff
        if guards:
            eps = jnp.finfo(X.dtype).eps
            finite = (jnp.all(jnp.isfinite(w_new)) & jnp.isfinite(b_new)
                      & jnp.isfinite(obj_new))
            # post-restart increase beyond rounding noise: the plain step is
            # monotone under a valid step size, so this is a blowup, not a
            # momentum artifact. 256 eps relative keeps fp32 plateau ties
            # from tripping the guard at convergence.
            blowup = restarted & (obj_new > s.obj + 256.0 * eps
                                  * jnp.maximum(jnp.abs(s.obj), 1.0))
            bad = (~finite) | blowup
            # shard-consistent verdict (see _init_state): all shards must
            # agree or the guarded while-loop conds diverge across the mesh
            bad = col.pmax_model(bad.astype(X.dtype)) > 0.5
            w_new = jnp.where(bad, s.w, w_new)
            b_new = jnp.where(bad, s.b, b_new)
            u_new = jnp.where(bad, s.u, u_new)
            obj_new = jnp.where(bad, s.obj, obj_new)
            t_next = jnp.where(bad, jnp.asarray(1.0, X.dtype), t_next)
            rel = jnp.where(bad, jnp.asarray(jnp.inf, X.dtype), rel)
            health = s.health + bad.astype(jnp.int32)
            backoff = jnp.where(bad, s.backoff * 0.5, s.backoff)
        return FistaState(
            w=w_new, b=b_new, w_prev=s.w, b_prev=s.b, u=u_new, u_prev=s.u,
            t=t_next, k=s.k + 1, obj=obj_new, rel_change=rel,
            rel_prev=s.rel_change, rel_prev2=s.rel_prev,
            health=health, backoff=backoff,
        )

    return body


def fista_run(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    w0: jax.Array,
    b0: jax.Array,
    inv_L: jax.Array,
    sample_mask: Optional[jax.Array],
    feature_mask: Optional[jax.Array],
    max_iters: int,
    tol: float,
    use_pallas: bool = False,
    col: Collectives = LOCAL,
    valid_m: Optional[jax.Array] = None,
    guards: bool = False,
) -> FistaResult:
    """The raw (unjitted) FISTA loop — trace-safe building block.

    Callers own the defaults, the Lipschitz constant, and the jit boundary:
    :func:`fista_solve` wraps this for standalone solves, and the on-device
    path engine (``core/path_scan.py``) inlines it into each ``lax.scan``
    step so the whole regularization path stays one XLA program.
    ``feature_mask`` (0/1, optional) freezes screened rows at zero — the
    mask-mode reduction. ``w0`` must already respect it. ``col`` binds the
    body's reductions to mesh collectives when the operands are ``shard_map``
    blocks (the sharded path engine); ``valid_m`` is the live-row count of a
    compacted active set (Pallas sweeps skip blocks past it). ``guards``
    enables the numerical health guard (warm-start sanitization, on-device
    rollback with step-size backoff, trip-bounded loop — see
    :func:`_make_fista_body`); the trip count is returned as
    ``FistaResult.health``.
    """
    init = _init_state(X, y, lam, w0, jnp.asarray(b0, X.dtype), sample_mask,
                       use_pallas, col, valid_m, guards=guards)

    def cond(s: FistaState):
        # three consecutive sub-tol iterations (see FistaState.rel_prev)
        go = (s.k < max_iters) & (_rel3(s) > tol)
        if guards:
            go = go & (s.health < MAX_GUARD_TRIPS)
        return go

    body = _make_fista_body(X, y, lam, inv_L, sample_mask, feature_mask,
                            use_pallas, col, valid_m, guards=guards)
    out = jax.lax.while_loop(cond, body, init)
    return FistaResult(
        w=out.w, b=out.b, obj=out.obj, n_iters=out.k,
        converged=_rel3(out) <= tol, u=out.u, health=out.health,
    )


def _resolve_pallas(flag: Optional[bool]) -> bool:
    from repro.kernels.ops import fista_use_pallas  # lazy: no import cycle

    return fista_use_pallas(flag)


@partial(jax.jit, static_argnames=("max_iters", "use_pallas", "guards"))
def _fista_solve_jit(X, y, lam, w0, b0, max_iters, tol, L, sample_mask,
                     use_pallas, guards):
    m = X.shape[0]
    lam = jnp.asarray(lam, X.dtype)
    if w0 is None:
        w0 = jnp.zeros((m,), X.dtype)
    if b0 is None:
        b0 = jnp.mean(y)
    if L is None:
        L = lipschitz_estimate(X)
    L = jnp.maximum(L * 1.01, 1e-12)  # small safety factor
    return fista_run(X, y, lam, w0, b0, 1.0 / L, sample_mask, None,
                     max_iters, tol, use_pallas, guards=guards)


def fista_solve(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    w0: Optional[jax.Array] = None,
    b0: Optional[jax.Array] = None,
    max_iters: int = 2000,
    tol: float = 1e-9,
    L: Optional[jax.Array] = None,
    sample_mask: Optional[jax.Array] = None,
    use_pallas: Optional[bool] = None,
    operator=None,
    guards: Optional[bool] = None,
) -> FistaResult:
    """Solve the primal to relative-objective tolerance ``tol``.

    ``X``: (m, n) features x samples. Warm starts via ``w0``/``b0``.
    ``sample_mask`` (0/1 over samples) drops columns from the loss without
    changing shapes — with a binary mask, masking ``xi`` is exactly the
    problem with those samples removed (screened samples and gather-mode
    padding columns both use this; see core/path.py).

    ``L`` (optional): a known upper bound on the Lipschitz constant — path
    drivers pass the full-X estimate so reduced solves skip the 30-iteration
    power sweep. ``use_pallas`` routes the two O(mn) sweeps per iteration
    through the fused Pallas kernels (None = the
    ``kernels/ops.py::fista_use_pallas`` policy: env override, else TPU).

    ``operator`` (optional): the design-matrix seam. Accepts either a dense
    array (identical to passing it as ``X``) or a
    ``repro.sparse.FeatureChunked`` — the latter routes the solve through
    the streamed chunk-accumulated GEMV pair
    (``sparse/solver_stream.fista_solve_chunked``: host-orchestrated, one
    chunk on device at a time), so in-core call sites run unchanged on data
    that does not fit on the device. Chunked solves ignore ``use_pallas``
    (the streamed sweeps are XLA/BCOO per chunk). Passing a chunked
    container *as* ``X`` dispatches the same way.
    """
    A = operator if operator is not None else X
    if hasattr(A, "stream") and hasattr(A, "rmatvec"):  # FeatureChunked
        from repro.sparse.solver_stream import fista_solve_chunked  # lazy

        return fista_solve_chunked(A, y, lam, w0=w0, b0=b0,
                                   max_iters=max_iters, tol=tol, L=L,
                                   sample_mask=sample_mask,
                                   guards=_resolve_guards(guards))
    return _fista_solve_jit(A, y, lam, w0, b0, max_iters, float(tol), L,
                            sample_mask, _resolve_pallas(use_pallas),
                            _resolve_guards(guards))


def gap_theta_delta(
    X: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b: jax.Array,
    lam: jax.Array,
    sample_mask: Optional[jax.Array] = None,
    n_feas_iters: int = 4,
    col: Collectives = LOCAL,
    u: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Gap-certified ``(theta1, delta, gap)`` at the current iterate.

    The sample-masked generalization of ``dual.safe_theta_and_delta`` (same
    alternating feasibility projection, same 1-strong-concavity radius):
    with a 0/1 ``sample_mask`` the problem being certified is the one with
    masked-out columns removed, so the projection keeps their dual
    coordinates pinned at zero and the equality projection uses the live
    sample count. Pure ``jnp`` — callable from inside a jitted solve loop.

    ``u`` (optional): precomputed margins ``X^T w`` — the fused solver body
    already carries them for its accepted point, so certifying a just-solved
    iterate saves one full sweep of X. ``col`` binds the reductions to mesh
    collectives for ``shard_map`` blocks (see :class:`Collectives`).
    """
    sm = sample_mask
    if u is None:
        u = col.psum_model(X.T @ w)
    xi = jnp.maximum(0.0, 1.0 - y * (u + b))
    if sm is not None:
        xi = xi * sm
    alpha = xi
    p_obj = col.psum_data(0.5 * jnp.sum(alpha * alpha)) + lam * col.psum_model(
        jnp.sum(jnp.abs(w)))
    if sm is not None:
        n_eff = col.psum_data(jnp.sum(sm))
    else:
        n_eff = col.psum_data(jnp.asarray(float(y.shape[0]), X.dtype))

    def corr_scale(alpha):
        corr = col.psum_data(X @ (y * alpha))  # fhat_j^T alpha for all j
        mx = col.pmax_model(jnp.max(jnp.abs(corr)))
        return jnp.minimum(1.0, lam / jnp.maximum(mx, 1e-30))

    def body(alpha, _):
        alpha = alpha * corr_scale(alpha)
        alpha = jnp.maximum(0.0, alpha - col.psum_data(alpha @ y) / n_eff * y)
        if sm is not None:
            alpha = alpha * sm
        return alpha, None

    alpha, _ = jax.lax.scan(body, alpha, None, length=n_feas_iters)
    # final rescale so the inequality constraints hold for sure
    alpha = alpha * corr_scale(alpha)
    d_obj = col.psum_data(jnp.sum(alpha)) - 0.5 * col.psum_data(
        jnp.sum(alpha * alpha))
    gap = jnp.maximum(p_obj - d_obj, 0.0)
    # the gap is a difference of two O(p_obj) reductions: floor it at a few
    # ulps of p_obj so cancellation noise can never *under*-inflate delta
    # (an underestimated delta is the unsafe direction)
    gap = jnp.maximum(gap, 4.0 * jnp.finfo(X.dtype).eps * jnp.abs(p_obj))
    eq_resid = jnp.abs(col.psum_data(alpha @ y)) / jnp.sqrt(n_eff)
    delta = (jnp.sqrt(2.0 * gap) + 2.0 * eq_resid) / lam
    theta = alpha / lam
    # fail-safe: a non-finite certificate must never feed screening. A NaN
    # theta with a *finite* delta is the dangerous combination (bounds come
    # out NaN and `bounds >= tau` silently discards), so collapse delta and
    # gap to inf whenever any component is non-finite — every screening
    # consumer gates on isfinite(delta) / the NaN-safe keep comparison.
    cert_ok = (jnp.isfinite(gap) & jnp.isfinite(delta)
               & jnp.all(jnp.isfinite(theta)))
    inf = jnp.asarray(jnp.inf, X.dtype)
    return theta, jnp.where(cert_ok, delta, inf), jnp.where(cert_ok, gap, inf)


def _dynamic_run(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    w0: jax.Array,
    b0: jax.Array,
    inv_L: jax.Array,
    sample_mask: Optional[jax.Array],
    fmask0: jax.Array,
    max_iters: int,
    tol: float,
    screen_every: int,
    tau: float,
    n_feas_iters: int,
    use_pallas: bool,
    valid_m: Optional[jax.Array] = None,
    dynamic_samples: bool = False,
    sample_dw=None,
    sample_db=None,
    sample_u_prev: Optional[jax.Array] = None,
    sample_shrink: float = 2.0,
    sample_floor: float = 1e-3,
    guards: bool = False,
) -> DynamicFistaResult:
    """Raw segmented dynamic solve (see :func:`fista_solve_dynamic`).

    Trace-safe like :func:`fista_run`; the scan path engine calls this
    directly with the path-shared ``inv_L``, the step's sequential screen
    as ``fmask0``, and (compact reduction) the live-row count ``valid_m``
    for the Pallas sweeps. ``dynamic_samples`` additionally re-checks the
    margin surplus of every live sample at each refresh (the carried
    margins make it O(n)) and ANDs it into a live *sample* mask — see
    :func:`fista_solve_dynamic` for the safety contract.
    """
    sm = sample_mask
    screen_every = max(int(screen_every), 1)
    n_seg = -(-max_iters // screen_every)  # ceil; static

    sm_vec = jnp.ones_like(y) if sm is None else sm
    if dynamic_samples:
        from .rules.sample_vi import margin_surplus_core  # lazy: no cycle

        # per-sample column norms over the (already feature-masked) matrix:
        # valid for the trust-region slack — the weight movement it bounds is
        # supported on live feature rows only — and theta-independent, so one
        # sweep serves every refresh
        x_sq_cols = jnp.sum(X * X, axis=0)

    def bound_statics(smv):
        """theta-independent bound reductions over the live samples."""
        return (X @ (y * smv), X @ smv, (X * X) @ smv,
                jnp.sum(y * smv), jnp.sum(smv))

    # one sweep hoisted out of the loop; with dynamic_samples the values are
    # carried and re-swept only after a refresh that actually dropped
    # samples (the sm_dirty flag) — a stabilized sample mask costs nothing
    statics0 = bound_statics(sm_vec)

    s0 = _init_state(X, y, lam, w0, jnp.asarray(b0, X.dtype), sm, use_pallas,
                     valid_m=valid_m, guards=guards)
    kept0 = jnp.full((n_seg,), -1, jnp.int32)
    gaps0 = jnp.full((n_seg,), jnp.inf, X.dtype)
    kept_s0 = jnp.full((n_seg,), -1, jnp.int32)

    def _trips(s):
        # the trip bound looks at the low (rollback) bits only — refused
        # screening refreshes (HEALTH_SCREEN_REFUSED) don't stop the solve
        return s.health & (HEALTH_SCREEN_REFUSED - 1)

    def outer_cond(carry):
        s, *_ = carry
        go = (s.k < max_iters) & (_rel3(s) > tol)
        if guards:
            go = go & (_trips(s) < MAX_GUARD_TRIPS)
        return go

    def outer_body(carry):
        s, fmask, smask, statics, sm_dirty, kept, gaps, kept_s, seg = carry
        seg_sm = smask if dynamic_samples else sm

        # -- segment: up to screen_every FISTA steps on the live mask ------
        body = _make_fista_body(X, y, lam, inv_L, seg_sm, fmask, use_pallas,
                                valid_m=valid_m, guards=guards)
        k_stop = jnp.minimum(s.k + screen_every, max_iters)

        def inner_cond(st):
            go = (st.k < k_stop) & (_rel3(st) > tol)
            if guards:
                go = go & (_trips(st) < MAX_GUARD_TRIPS)
            return go

        s = jax.lax.while_loop(inner_cond, body, s)

        # -- refresh: gap-certified region at the current iterate ----------
        # the carried margins s.u are X^T w at the current point, so the
        # certificate skips its own margin sweep
        theta, delta, gap = gap_theta_delta(
            X, y, s.w, s.b, lam, seg_sm, n_feas_iters=n_feas_iters, u=s.u
        )
        if dynamic_samples:
            # re-sweep the statics only if the previous refresh shrank the
            # sample mask (this refresh's feature screen must see the mask
            # the segment just ran with — exactly the carried smask)
            statics = jax.lax.cond(
                sm_dirty, lambda _: bound_statics(smask), lambda _: statics,
                None,
            )
        d_one_c, d_y_c, d_sq_c, one_y_c, n_tot_c = statics
        sh = shared_scalars_from_stats(
            lam, lam, one_y=one_y_c,
            theta_dot_one=jnp.sum(theta), theta_dot_y=theta @ y,
            theta_sq=theta @ theta, n_tot=n_tot_c, delta=delta,
        )
        red = FeatureReductions(
            d_theta=X @ (y * theta), d_one=d_one_c, d_y=d_y_c, d_sq=d_sq_c
        )
        # two independent certificates, elementwise min (each is a valid
        # upper bound on |fhat_j^T theta*|): the at-lambda VI cap, and the
        # GAP-sphere bound |fhat^T theta| + ||fhat|| * delta — linear in
        # delta, so it is the one that bites as the solve converges.
        bounds = jnp.minimum(
            screen_bounds_from_reductions(red, sh),
            jnp.abs(red.d_theta) + jnp.sqrt(jnp.maximum(d_sq_c, 0.0)) * delta,
        )
        # fail-safe keep: ~(b < tau) keeps NaN/inf bounds (a poisoned
        # certificate degrades to "no screening this segment", never to a
        # wrong discard), and the explicit cert gate records the refusal
        cert_ok = jnp.isfinite(delta)
        keep = (~(bounds < tau)) | (~cert_ok)
        new_mask = fmask * keep.astype(X.dtype)

        # -- dynamic sample re-screen: margin surplus at the carried
        # margins (O(n) — no sweep). Samples whose surplus clears the slack
        # budget are *predicted* inactive and dropped from the loss for the
        # rest of the solve; the driver's KKT verification re-admits any
        # violator, so exactness is restored at acceptance.
        if dynamic_samples:
            surplus = margin_surplus_core(
                s.u + s.b, y, x_sq_cols, sample_dw, sample_db,
                u_prev=sample_u_prev, shrink_factor=sample_shrink,
                margin_floor=sample_floor,
            )
            # NaN-safe drop test: a non-finite surplus keeps the sample
            # (~(s >= 0) is True for NaN), so a poisoned margin can only
            # cost speed, never silently drop loss terms
            new_sm = smask * (~(surplus >= 0.0)).astype(X.dtype)
            sm_dirty = jnp.sum(smask - new_sm) > 0.0  # statics stale now
        else:
            new_sm = smask

        # zero the dropped coordinates; restart momentum only when the mask
        # change actually moved the problem (a moved iterate / shrunk loss
        # is a fresh point — stale momentum and a stale rel_change would
        # otherwise terminate the solve early; dropping already-zero
        # coordinates is free). The carried margins are re-swept for the
        # masked point — one fused pass per segment, amortized over
        # screen_every iterations.
        w_m = s.w * new_mask
        changed = jnp.sum((s.w - w_m) * (s.w - w_m)) > 0.0
        if dynamic_samples:
            changed = changed | (jnp.sum(smask - new_sm) > 0.0)
        u_m, obj_m = _margin_obj_sweep(
            X, y, lam, w_m, s.b, new_sm if dynamic_samples else sm,
            use_pallas, valid_m=valid_m)
        s_masked = FistaState(
            w=w_m, b=s.b, w_prev=w_m, b_prev=s.b, u=u_m, u_prev=u_m,
            t=jnp.asarray(1.0, X.dtype), k=s.k,
            obj=obj_m,
            rel_change=jnp.asarray(jnp.inf, X.dtype),
            rel_prev=jnp.asarray(jnp.inf, X.dtype),
            rel_prev2=jnp.asarray(jnp.inf, X.dtype),
            health=s.health, backoff=s.backoff,
        )
        s = jax.tree_util.tree_map(
            lambda a, b_: jnp.where(changed, a, b_), s_masked, s
        )
        # a refused refresh is health telemetry, not a solver trip: set the
        # flag bit once (idempotent under repeated refusals via bitwise or)
        s = s._replace(health=s.health | jnp.where(
            cert_ok, 0, HEALTH_SCREEN_REFUSED).astype(jnp.int32))

        # a segment may consume fewer than screen_every iterations (inner
        # convergence followed by a mask change restarts iteration), so more
        # than n_seg refreshes are possible — clamp into the last telemetry
        # slot instead of silently dropping the scatter out of bounds
        slot = jnp.minimum(seg, n_seg - 1)
        kept = kept.at[slot].set(jnp.sum(new_mask).astype(jnp.int32))
        gaps = gaps.at[slot].set(gap)
        kept_s = kept_s.at[slot].set(jnp.sum(new_sm).astype(jnp.int32))
        return (s, new_mask, new_sm, statics, sm_dirty, kept, gaps, kept_s,
                jnp.minimum(seg + 1, n_seg))

    out, fmask, smask, _, _, kept, gaps, kept_s, seg = jax.lax.while_loop(
        outer_cond, outer_body,
        (s0, fmask0, sm_vec, statics0, jnp.asarray(False), kept0, gaps0,
         kept_s0, jnp.asarray(0, jnp.int32))
    )
    return DynamicFistaResult(
        w=out.w, b=out.b, obj=out.obj, n_iters=out.k,
        converged=_rel3(out) <= tol,
        feature_mask=fmask > 0.5, kept_per_segment=kept,
        gap_per_segment=gaps, n_segments=seg, u=out.u,
        sample_mask=(smask > 0.5) if dynamic_samples else None,
        kept_samples_per_segment=kept_s if dynamic_samples else None,
        health=out.health,
    )


@partial(jax.jit, static_argnames=("max_iters", "screen_every", "n_feas_iters",
                                   "use_pallas", "dynamic_samples", "guards"))
def _fista_solve_dynamic_jit(X, y, lam, w0, b0, max_iters, tol, L,
                             sample_mask, feature_mask, screen_every, tau,
                             n_feas_iters, use_pallas, dynamic_samples,
                             sample_dw, sample_db, sample_u_prev,
                             sample_shrink, sample_floor, guards):
    m = X.shape[0]
    lam = jnp.asarray(lam, X.dtype)
    if w0 is None:
        w0 = jnp.zeros((m,), X.dtype)
    if b0 is None:
        b0 = jnp.mean(y)
    if L is None:
        L = lipschitz_estimate(X)
    L = jnp.maximum(L * 1.01, 1e-12)

    fmask0 = (
        jnp.ones((m,), X.dtype) if feature_mask is None
        else jnp.asarray(feature_mask, X.dtype)
    )
    w0 = w0 * fmask0
    return _dynamic_run(X, y, lam, w0, b0, 1.0 / L, sample_mask, fmask0,
                        max_iters, tol, screen_every, tau, n_feas_iters,
                        use_pallas, dynamic_samples=dynamic_samples,
                        sample_dw=sample_dw, sample_db=sample_db,
                        sample_u_prev=sample_u_prev,
                        sample_shrink=sample_shrink,
                        sample_floor=sample_floor, guards=guards)


def fista_solve_dynamic(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    w0: Optional[jax.Array] = None,
    b0: Optional[jax.Array] = None,
    max_iters: int = 2000,
    tol: float = 1e-9,
    L: Optional[jax.Array] = None,
    sample_mask: Optional[jax.Array] = None,
    feature_mask: Optional[jax.Array] = None,
    screen_every: int = 50,
    tau: float = SAFE_TAU,
    n_feas_iters: int = 4,
    use_pallas: Optional[bool] = None,
    dynamic_samples: bool = False,
    sample_dw: float = float("inf"),
    sample_db: float = float("inf"),
    sample_u_prev: Optional[jax.Array] = None,
    sample_shrink_factor: float = 2.0,
    sample_margin_floor: float = 1e-3,
    guards: Optional[bool] = None,
) -> DynamicFistaResult:
    """Segmented FISTA with gap-driven dynamic feature screening.

    Solves the same problem as :func:`fista_solve`, but every
    ``screen_every`` iterations it (a) computes the duality gap at the
    current iterate, (b) rebuilds the at-lambda VI region from the
    gap-certified dual point (``lam1 = lam2 = lam``; the region collapses
    onto ``theta*`` as the gap shrinks), (c) re-evaluates the feature
    bounds, and (d) ANDs the keep mask into a live ``feature_mask`` that
    zeroes screened coordinates for the rest of the solve. Screened
    features are *provably* inactive at the optimum of the (sample-masked)
    problem, so the accepted solution is unchanged beyond solver tolerance.

    ``feature_mask`` (0/1 over rows, optional) seeds the live mask — e.g.
    the path driver's between-lambda sequential screen; refreshes only ever
    shrink it. ``L``/``use_pallas`` as in :func:`fista_solve`. Returns
    :class:`DynamicFistaResult` with per-segment kept-counts and gaps
    (sentinels ``-1`` / ``inf`` for segments not run).

    Dynamic *sample* re-screen (``dynamic_samples=True``): each refresh
    additionally evaluates every live sample's margin surplus at the
    carried margins (``rules/sample_vi.margin_surplus_core`` — O(n), no
    extra sweep) against the trust-region radii ``sample_dw``/``sample_db``
    and the secant model anchored at ``sample_u_prev``, and ANDs
    ``surplus < 0`` into a live *sample* mask: samples predicted to satisfy
    their margin stop contributing to gradients and to the gap certificate
    for the rest of the solve. Unlike the feature screen this is
    margin-*predicted*, not a-priori safe — the returned
    ``DynamicFistaResult.sample_mask`` must be KKT-verified at the solution
    (the path driver's verification loop re-admits violators and re-solves),
    after which screened samples provably have ``xi_i = 0`` and the accepted
    solution is exact.
    """
    return _fista_solve_dynamic_jit(
        X, y, lam, w0, b0, max_iters, float(tol), L, sample_mask,
        feature_mask, max(int(screen_every), 1), float(tau),
        int(n_feas_iters), _resolve_pallas(use_pallas),
        bool(dynamic_samples),
        jnp.asarray(min(float(sample_dw), 1e30)),
        jnp.asarray(min(float(sample_db), 1e30)),
        sample_u_prev,
        jnp.asarray(float(sample_shrink_factor)),
        jnp.asarray(float(sample_margin_floor)),
        _resolve_guards(guards),
    )
