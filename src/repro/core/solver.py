"""Jittable FISTA solver for the L1-regularized L2-loss SVM (paper Eq. 1/23).

Unconstrained composite form (paper Eq. 23):

    min_{w,b}  h(w, b) + lam ||w||_1,
    h(w, b) = 1/2 sum_i max(0, 1 - y_i (w^T x_i + b))^2

``h`` is convex with Lipschitz-continuous gradient (the squared hinge is C^1),
so accelerated proximal gradient (FISTA) applies; the prox of ``lam||.||_1``
is soft-thresholding on ``w`` only (``b`` is unpenalized).

Gradients (paper Eqs. 24-25), with xi = max(0, 1 - y*(X^T w + b)):

    grad_w = -X (y * xi),     grad_b = -y^T xi

Lipschitz constant: L <= sigma_max([X; 1^T])^2, estimated by power iteration.

Everything is pure ``jax.lax`` control flow: the whole solve jit-compiles to
one XLA program (and runs unchanged under shard_map — see
``core/distributed.py``).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["FistaState", "FistaResult", "lipschitz_estimate", "soft_threshold", "fista_solve"]


class FistaState(NamedTuple):
    w: jax.Array
    b: jax.Array
    w_prev: jax.Array
    b_prev: jax.Array
    t: jax.Array
    k: jax.Array
    obj: jax.Array
    rel_change: jax.Array


class FistaResult(NamedTuple):
    w: jax.Array
    b: jax.Array
    obj: jax.Array
    n_iters: jax.Array
    converged: jax.Array


def soft_threshold(x: jax.Array, tau: jax.Array) -> jax.Array:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - tau, 0.0)


def lipschitz_estimate(X: jax.Array, n_iters: int = 30, key: Optional[jax.Array] = None) -> jax.Array:
    """Power iteration for ``sigma_max([X; 1^T])^2`` (augmented bias row)."""
    n = X.shape[1]
    if key is None:
        key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (n,), dtype=X.dtype)

    def body(v, _):
        v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)
        u_w = X @ v
        u_b = jnp.sum(v)
        v = X.T @ u_w + u_b
        return v, None

    v, _ = jax.lax.scan(body, v, None, length=n_iters)
    return jnp.linalg.norm(v)  # ||A^T A v|| / ||v|| with ||v||=1 pre-normalized


def _objective(X, y, w, b, lam, sample_mask=None):
    xi = jnp.maximum(0.0, 1.0 - y * (X.T @ w + b))
    if sample_mask is not None:
        xi = xi * sample_mask
    return 0.5 * jnp.sum(xi * xi) + lam * jnp.sum(jnp.abs(w))


@partial(jax.jit, static_argnames=("max_iters",))
def fista_solve(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    w0: Optional[jax.Array] = None,
    b0: Optional[jax.Array] = None,
    max_iters: int = 2000,
    tol: float = 1e-9,
    L: Optional[jax.Array] = None,
    sample_mask: Optional[jax.Array] = None,
) -> FistaResult:
    """Solve the primal to relative-objective tolerance ``tol``.

    ``X``: (m, n) features x samples. Warm starts via ``w0``/``b0``.
    ``sample_mask`` (0/1 over samples) drops columns from the loss without
    changing shapes — with a binary mask, masking ``xi`` is exactly the
    problem with those samples removed (screened samples and gather-mode
    padding columns both use this; see core/path.py).
    """
    m = X.shape[0]
    lam = jnp.asarray(lam, X.dtype)
    if w0 is None:
        w0 = jnp.zeros((m,), X.dtype)
    if b0 is None:
        b0 = jnp.mean(y)
    if L is None:
        L = lipschitz_estimate(X)
    L = jnp.maximum(L * 1.01, 1e-12)  # small safety factor
    inv_L = 1.0 / L

    sm = sample_mask
    obj0 = _objective(X, y, w0, b0, lam, sm)
    init = FistaState(
        w=w0, b=jnp.asarray(b0, X.dtype), w_prev=w0, b_prev=jnp.asarray(b0, X.dtype),
        t=jnp.asarray(1.0, X.dtype), k=jnp.asarray(0, jnp.int32),
        obj=obj0, rel_change=jnp.asarray(jnp.inf, X.dtype),
    )

    def cond(s: FistaState):
        return (s.k < max_iters) & (s.rel_change > tol)

    def body(s: FistaState) -> FistaState:
        # momentum extrapolation
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * s.t * s.t))
        beta = (s.t - 1.0) / t_next
        zw = s.w + beta * (s.w - s.w_prev)
        zb = s.b + beta * (s.b - s.b_prev)

        xi = jnp.maximum(0.0, 1.0 - y * (X.T @ zw + zb))
        if sm is not None:
            xi = xi * sm
        gw = -(X @ (y * xi))
        gb = -jnp.sum(y * xi)

        w_new = soft_threshold(zw - inv_L * gw, lam * inv_L)
        b_new = zb - inv_L * gb

        obj_new = _objective(X, y, w_new, b_new, lam, sm)
        # monotone restart: if the extrapolated step increased the objective,
        # fall back to a plain proximal step from (w, b).
        def plain_step():
            xi_p = jnp.maximum(0.0, 1.0 - y * (X.T @ s.w + s.b))
            if sm is not None:
                xi_p = xi_p * sm
            gw_p = -(X @ (y * xi_p))
            gb_p = -jnp.sum(y * xi_p)
            w_p = soft_threshold(s.w - inv_L * gw_p, lam * inv_L)
            b_p = s.b - inv_L * gb_p
            return w_p, b_p, _objective(X, y, w_p, b_p, lam, sm), jnp.asarray(1.0, X.dtype)

        bad = obj_new > s.obj
        w_new, b_new, obj_new, t_next = jax.tree_util.tree_map(
            lambda a, b_: jnp.where(bad, a, b_), plain_step(), (w_new, b_new, obj_new, t_next)
        )

        rel = jnp.abs(s.obj - obj_new) / jnp.maximum(jnp.abs(s.obj), 1e-30)
        return FistaState(
            w=w_new, b=b_new, w_prev=s.w, b_prev=s.b,
            t=t_next, k=s.k + 1, obj=obj_new, rel_change=rel,
        )

    out = jax.lax.while_loop(cond, body, init)
    return FistaResult(
        w=out.w, b=out.b, obj=out.obj, n_iters=out.k, converged=out.rel_change <= tol
    )
