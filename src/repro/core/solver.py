"""Jittable FISTA solver for the L1-regularized L2-loss SVM (paper Eq. 1/23).

Unconstrained composite form (paper Eq. 23):

    min_{w,b}  h(w, b) + lam ||w||_1,
    h(w, b) = 1/2 sum_i max(0, 1 - y_i (w^T x_i + b))^2

``h`` is convex with Lipschitz-continuous gradient (the squared hinge is C^1),
so accelerated proximal gradient (FISTA) applies; the prox of ``lam||.||_1``
is soft-thresholding on ``w`` only (``b`` is unpenalized).

Gradients (paper Eqs. 24-25), with xi = max(0, 1 - y*(X^T w + b)):

    grad_w = -X (y * xi),     grad_b = -y^T xi

Lipschitz constant: L <= sigma_max([X; 1^T])^2, estimated by power iteration.

Everything is pure ``jax.lax`` control flow: the whole solve jit-compiles to
one XLA program (and runs unchanged under shard_map — see
``core/distributed.py``).

Dynamic (in-solver) screening — ``fista_solve_dynamic``
-------------------------------------------------------
The VI region certifying ``theta*(lam)`` shrinks as the iterate converges:
with ``theta`` the gap-certified dual-feasible point at the *current*
``(w, b)`` and ``delta = O(sqrt(gap))`` its distance bound to ``theta*``,
the at-lambda region (``lam1 = lam2 = lam``) is the ball through ``theta``
cut by its own tangent halfspace — a set of diameter ``O(sqrt(R*delta))``
that collapses onto ``theta*`` as the gap goes to zero. Features whose
bound over that set stays below 1 are provably inactive at ``lam`` and can
be zeroed *mid-solve* (Liu et al.-style dynamic screening), which compounds
multiplicatively with the between-lambda sequential screen.

``fista_solve_dynamic`` therefore runs a segmented solve: an outer
``lax.while_loop`` whose body (a) runs up to ``screen_every`` plain FISTA
iterations, (b) computes the duality gap of the (possibly sample-masked)
problem, (c) rebuilds the region from the current iterate and re-evaluates
the feature bounds, and (d) ANDs the result into a live feature mask that
zeroes screened coordinates for all remaining iterations. Per-segment
kept-counts and gaps are returned as telemetry (`DynamicFistaResult`).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .screening import (
    SAFE_TAU,
    FeatureReductions,
    screen_bounds_from_reductions,
    shared_scalars_from_stats,
)

__all__ = [
    "FistaState",
    "FistaResult",
    "DynamicFistaResult",
    "lipschitz_estimate",
    "soft_threshold",
    "fista_solve",
    "fista_solve_dynamic",
    "gap_theta_delta",
]


class FistaState(NamedTuple):
    w: jax.Array
    b: jax.Array
    w_prev: jax.Array
    b_prev: jax.Array
    t: jax.Array
    k: jax.Array
    obj: jax.Array
    rel_change: jax.Array


class FistaResult(NamedTuple):
    w: jax.Array
    b: jax.Array
    obj: jax.Array
    n_iters: jax.Array
    converged: jax.Array


class DynamicFistaResult(NamedTuple):
    """`FistaResult` plus in-solver screening telemetry.

    ``kept_per_segment[s]`` is the live-feature count after segment ``s``'s
    re-screen; ``gap_per_segment[s]`` the duality-gap estimate it certified
    the region from. Segments never run (early convergence) hold the
    sentinel ``-1`` / ``inf``.
    """

    w: jax.Array
    b: jax.Array
    obj: jax.Array
    n_iters: jax.Array
    converged: jax.Array
    feature_mask: jax.Array      # (m,) bool — final live mask
    kept_per_segment: jax.Array  # (S,) int32
    gap_per_segment: jax.Array   # (S,) float
    n_segments: jax.Array        # int32 — segments actually run


def soft_threshold(x: jax.Array, tau: jax.Array) -> jax.Array:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - tau, 0.0)


def lipschitz_estimate(X: jax.Array, n_iters: int = 30, key: Optional[jax.Array] = None) -> jax.Array:
    """Power iteration for ``sigma_max([X; 1^T])^2`` (augmented bias row)."""
    n = X.shape[1]
    if key is None:
        key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (n,), dtype=X.dtype)

    def body(v, _):
        v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)
        u_w = X @ v
        u_b = jnp.sum(v)
        v = X.T @ u_w + u_b
        return v, None

    v, _ = jax.lax.scan(body, v, None, length=n_iters)
    return jnp.linalg.norm(v)  # ||A^T A v|| / ||v|| with ||v||=1 pre-normalized


def _objective(X, y, w, b, lam, sample_mask=None):
    xi = jnp.maximum(0.0, 1.0 - y * (X.T @ w + b))
    if sample_mask is not None:
        xi = xi * sample_mask
    return 0.5 * jnp.sum(xi * xi) + lam * jnp.sum(jnp.abs(w))


def _make_fista_body(X, y, lam, inv_L, sm, fmask=None):
    """One FISTA iteration ``FistaState -> FistaState`` as a closure.

    ``fmask`` (0/1 over features, optional) freezes screened coordinates at
    zero: the gradient and the prox output are both masked, so a coordinate
    once zeroed stays zero — this is exactly the problem with those feature
    rows removed (the rows contribute nothing to the margins either, since
    ``w_j = 0``). Shared by :func:`fista_solve` (``fmask=None``: bit-for-bit
    the original body) and the dynamic solver's inner segments.
    """

    def mask_w(w):
        return w if fmask is None else w * fmask

    def body(s: FistaState) -> FistaState:
        # momentum extrapolation
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * s.t * s.t))
        beta = (s.t - 1.0) / t_next
        zw = s.w + beta * (s.w - s.w_prev)
        zb = s.b + beta * (s.b - s.b_prev)

        xi = jnp.maximum(0.0, 1.0 - y * (X.T @ zw + zb))
        if sm is not None:
            xi = xi * sm
        gw = -(X @ (y * xi))
        gb = -jnp.sum(y * xi)

        w_new = mask_w(soft_threshold(zw - inv_L * gw, lam * inv_L))
        b_new = zb - inv_L * gb

        obj_new = _objective(X, y, w_new, b_new, lam, sm)
        # monotone restart: if the extrapolated step increased the objective,
        # fall back to a plain proximal step from (w, b).
        def plain_step():
            xi_p = jnp.maximum(0.0, 1.0 - y * (X.T @ s.w + s.b))
            if sm is not None:
                xi_p = xi_p * sm
            gw_p = -(X @ (y * xi_p))
            gb_p = -jnp.sum(y * xi_p)
            w_p = mask_w(soft_threshold(s.w - inv_L * gw_p, lam * inv_L))
            b_p = s.b - inv_L * gb_p
            return w_p, b_p, _objective(X, y, w_p, b_p, lam, sm), jnp.asarray(1.0, X.dtype)

        bad = obj_new > s.obj
        w_new, b_new, obj_new, t_next = jax.tree_util.tree_map(
            lambda a, b_: jnp.where(bad, a, b_), plain_step(), (w_new, b_new, obj_new, t_next)
        )

        rel = jnp.abs(s.obj - obj_new) / jnp.maximum(jnp.abs(s.obj), 1e-30)
        return FistaState(
            w=w_new, b=b_new, w_prev=s.w, b_prev=s.b,
            t=t_next, k=s.k + 1, obj=obj_new, rel_change=rel,
        )

    return body


@partial(jax.jit, static_argnames=("max_iters",))
def fista_solve(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    w0: Optional[jax.Array] = None,
    b0: Optional[jax.Array] = None,
    max_iters: int = 2000,
    tol: float = 1e-9,
    L: Optional[jax.Array] = None,
    sample_mask: Optional[jax.Array] = None,
) -> FistaResult:
    """Solve the primal to relative-objective tolerance ``tol``.

    ``X``: (m, n) features x samples. Warm starts via ``w0``/``b0``.
    ``sample_mask`` (0/1 over samples) drops columns from the loss without
    changing shapes — with a binary mask, masking ``xi`` is exactly the
    problem with those samples removed (screened samples and gather-mode
    padding columns both use this; see core/path.py).
    """
    m = X.shape[0]
    lam = jnp.asarray(lam, X.dtype)
    if w0 is None:
        w0 = jnp.zeros((m,), X.dtype)
    if b0 is None:
        b0 = jnp.mean(y)
    if L is None:
        L = lipschitz_estimate(X)
    L = jnp.maximum(L * 1.01, 1e-12)  # small safety factor
    inv_L = 1.0 / L

    sm = sample_mask
    obj0 = _objective(X, y, w0, b0, lam, sm)
    init = FistaState(
        w=w0, b=jnp.asarray(b0, X.dtype), w_prev=w0, b_prev=jnp.asarray(b0, X.dtype),
        t=jnp.asarray(1.0, X.dtype), k=jnp.asarray(0, jnp.int32),
        obj=obj0, rel_change=jnp.asarray(jnp.inf, X.dtype),
    )

    def cond(s: FistaState):
        return (s.k < max_iters) & (s.rel_change > tol)

    body = _make_fista_body(X, y, lam, inv_L, sm)
    out = jax.lax.while_loop(cond, body, init)
    return FistaResult(
        w=out.w, b=out.b, obj=out.obj, n_iters=out.k, converged=out.rel_change <= tol
    )


def gap_theta_delta(
    X: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b: jax.Array,
    lam: jax.Array,
    sample_mask: Optional[jax.Array] = None,
    n_feas_iters: int = 4,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Gap-certified ``(theta1, delta, gap)`` at the current iterate.

    The sample-masked generalization of ``dual.safe_theta_and_delta`` (same
    alternating feasibility projection, same 1-strong-concavity radius):
    with a 0/1 ``sample_mask`` the problem being certified is the one with
    masked-out columns removed, so the projection keeps their dual
    coordinates pinned at zero and the equality projection uses the live
    sample count. Pure ``jnp`` — callable from inside a jitted solve loop.
    """
    sm = sample_mask
    xi = jnp.maximum(0.0, 1.0 - y * (X.T @ w + b))
    if sm is not None:
        xi = xi * sm
    alpha = xi
    p_obj = 0.5 * jnp.sum(alpha * alpha) + lam * jnp.sum(jnp.abs(w))
    n_eff = jnp.sum(sm) if sm is not None else jnp.asarray(float(y.shape[0]), X.dtype)

    def body(alpha, _):
        corr = X @ (y * alpha)  # fhat_j^T alpha for all j
        scale = jnp.minimum(1.0, lam / jnp.maximum(jnp.max(jnp.abs(corr)), 1e-30))
        alpha = alpha * scale
        alpha = jnp.maximum(0.0, alpha - (alpha @ y) / n_eff * y)
        if sm is not None:
            alpha = alpha * sm
        return alpha, None

    alpha, _ = jax.lax.scan(body, alpha, None, length=n_feas_iters)
    # final rescale so the inequality constraints hold for sure
    corr = X @ (y * alpha)
    scale = jnp.minimum(1.0, lam / jnp.maximum(jnp.max(jnp.abs(corr)), 1e-30))
    alpha = alpha * scale
    d_obj = jnp.sum(alpha) - 0.5 * jnp.sum(alpha * alpha)
    gap = jnp.maximum(p_obj - d_obj, 0.0)
    # the gap is a difference of two O(p_obj) reductions: floor it at a few
    # ulps of p_obj so cancellation noise can never *under*-inflate delta
    # (an underestimated delta is the unsafe direction)
    gap = jnp.maximum(gap, 4.0 * jnp.finfo(X.dtype).eps * jnp.abs(p_obj))
    eq_resid = jnp.abs(alpha @ y) / jnp.sqrt(n_eff)
    delta = (jnp.sqrt(2.0 * gap) + 2.0 * eq_resid) / lam
    return alpha / lam, delta, gap


@partial(jax.jit, static_argnames=("max_iters", "screen_every", "n_feas_iters"))
def fista_solve_dynamic(
    X: jax.Array,
    y: jax.Array,
    lam: jax.Array,
    w0: Optional[jax.Array] = None,
    b0: Optional[jax.Array] = None,
    max_iters: int = 2000,
    tol: float = 1e-9,
    L: Optional[jax.Array] = None,
    sample_mask: Optional[jax.Array] = None,
    feature_mask: Optional[jax.Array] = None,
    screen_every: int = 50,
    tau: float = SAFE_TAU,
    n_feas_iters: int = 4,
) -> DynamicFistaResult:
    """Segmented FISTA with gap-driven dynamic feature screening.

    Solves the same problem as :func:`fista_solve`, but every
    ``screen_every`` iterations it (a) computes the duality gap at the
    current iterate, (b) rebuilds the at-lambda VI region from the
    gap-certified dual point (``lam1 = lam2 = lam``; the region collapses
    onto ``theta*`` as the gap shrinks), (c) re-evaluates the feature
    bounds, and (d) ANDs the keep mask into a live ``feature_mask`` that
    zeroes screened coordinates for the rest of the solve. Screened
    features are *provably* inactive at the optimum of the (sample-masked)
    problem, so the accepted solution is unchanged beyond solver tolerance.

    ``feature_mask`` (0/1 over rows, optional) seeds the live mask — e.g.
    the path driver's between-lambda sequential screen; refreshes only ever
    shrink it. Returns :class:`DynamicFistaResult` with per-segment
    kept-counts and gaps (sentinels ``-1`` / ``inf`` for segments not run).
    """
    m = X.shape[0]
    lam = jnp.asarray(lam, X.dtype)
    if w0 is None:
        w0 = jnp.zeros((m,), X.dtype)
    if b0 is None:
        b0 = jnp.mean(y)
    if L is None:
        L = lipschitz_estimate(X)
    L = jnp.maximum(L * 1.01, 1e-12)
    inv_L = 1.0 / L
    sm = sample_mask

    fmask0 = (
        jnp.ones((m,), X.dtype) if feature_mask is None
        else jnp.asarray(feature_mask, X.dtype)
    )
    w0 = w0 * fmask0
    screen_every = max(int(screen_every), 1)
    n_seg = -(-max_iters // screen_every)  # ceil; static

    # theta-independent bound reductions of the (masked) problem, one sweep
    sm_vec = jnp.ones_like(y) if sm is None else sm
    d_one = X @ (y * sm_vec)      # fhat_j^T 1 over live samples
    d_y = X @ sm_vec              # fhat_j^T y over live samples
    d_sq = (X * X) @ sm_vec       # ||fhat_j||^2 over live samples
    one_y = jnp.sum(y * sm_vec)
    n_tot = jnp.sum(sm_vec)

    obj0 = _objective(X, y, w0, b0, lam, sm)
    b0 = jnp.asarray(b0, X.dtype)
    s0 = FistaState(
        w=w0, b=b0, w_prev=w0, b_prev=b0,
        t=jnp.asarray(1.0, X.dtype), k=jnp.asarray(0, jnp.int32),
        obj=obj0, rel_change=jnp.asarray(jnp.inf, X.dtype),
    )
    kept0 = jnp.full((n_seg,), -1, jnp.int32)
    gaps0 = jnp.full((n_seg,), jnp.inf, X.dtype)

    def outer_cond(carry):
        s, *_ = carry
        return (s.k < max_iters) & (s.rel_change > tol)

    def outer_body(carry):
        s, fmask, kept, gaps, seg = carry

        # -- segment: up to screen_every FISTA steps on the live mask ------
        body = _make_fista_body(X, y, lam, inv_L, sm, fmask)
        k_stop = jnp.minimum(s.k + screen_every, max_iters)

        def inner_cond(st):
            return (st.k < k_stop) & (st.rel_change > tol)

        s = jax.lax.while_loop(inner_cond, body, s)

        # -- refresh: gap-certified region at the current iterate ----------
        theta, delta, gap = gap_theta_delta(
            X, y, s.w, s.b, lam, sm, n_feas_iters=n_feas_iters
        )
        sh = shared_scalars_from_stats(
            lam, lam, one_y=one_y,
            theta_dot_one=jnp.sum(theta), theta_dot_y=theta @ y,
            theta_sq=theta @ theta, n_tot=n_tot, delta=delta,
        )
        red = FeatureReductions(
            d_theta=X @ (y * theta), d_one=d_one, d_y=d_y, d_sq=d_sq
        )
        # two independent certificates, elementwise min (each is a valid
        # upper bound on |fhat_j^T theta*|): the at-lambda VI cap, and the
        # GAP-sphere bound |fhat^T theta| + ||fhat|| * delta — linear in
        # delta, so it is the one that bites as the solve converges.
        bounds = jnp.minimum(
            screen_bounds_from_reductions(red, sh),
            jnp.abs(red.d_theta) + jnp.sqrt(jnp.maximum(d_sq, 0.0)) * delta,
        )
        new_mask = fmask * (bounds >= tau).astype(X.dtype)

        # zero the dropped coordinates; restart momentum only when zeroing
        # actually moved the iterate (a moved iterate is a fresh point —
        # stale momentum and a stale rel_change would otherwise terminate
        # the solve early; dropping already-zero coordinates is free).
        w_m = s.w * new_mask
        changed = jnp.sum((s.w - w_m) * (s.w - w_m)) > 0.0
        s_masked = FistaState(
            w=w_m, b=s.b, w_prev=w_m, b_prev=s.b,
            t=jnp.asarray(1.0, X.dtype), k=s.k,
            obj=_objective(X, y, w_m, s.b, lam, sm),
            rel_change=jnp.asarray(jnp.inf, X.dtype),
        )
        s = jax.tree_util.tree_map(
            lambda a, b_: jnp.where(changed, a, b_), s_masked, s
        )

        # a segment may consume fewer than screen_every iterations (inner
        # convergence followed by a mask change restarts iteration), so more
        # than n_seg refreshes are possible — clamp into the last telemetry
        # slot instead of silently dropping the scatter out of bounds
        slot = jnp.minimum(seg, n_seg - 1)
        kept = kept.at[slot].set(jnp.sum(new_mask).astype(jnp.int32))
        gaps = gaps.at[slot].set(gap)
        return (s, new_mask, kept, gaps, jnp.minimum(seg + 1, n_seg))

    out, fmask, kept, gaps, seg = jax.lax.while_loop(
        outer_cond, outer_body, (s0, fmask0, kept0, gaps0, jnp.asarray(0, jnp.int32))
    )
    return DynamicFistaResult(
        w=out.w, b=out.b, obj=out.obj, n_iters=out.k,
        converged=out.rel_change <= tol,
        feature_mask=fmask > 0.5, kept_per_segment=kept,
        gap_per_segment=gaps, n_segments=seg,
    )
