"""Safe feature screening for the L1-regularized L2-loss SVM (paper Sec. 6).

Given the dual optimum ``theta1`` at ``lam1`` and a target ``lam2 < lam1``,
the unknown optimum ``theta2`` lies in the closed convex set (paper Eq. 43)

    K = Ball(c, R)  ∩  Halfspace  ∩  Hyperplane
      = {theta : ||theta - c|| <= R}
        ∩ {theta : a^T (theta - theta1) >= 0}
        ∩ {theta : y^T theta = 0}

    c = (1/lam2 + theta1) / 2          (vector; 1/lam2 means (1/lam2)*ones)
    R = || 1/lam2 - theta1 ||_2 / 2
    a = (theta1 - 1/lam1) / || theta1 - 1/lam1 ||_2

(The paper's Eq. 43 writes the halfspace as ``a^T(b+r) <= 0``; the
variational inequality Eq. 31 it is derived from gives
``(theta1 - 1/lam1)^T (theta2 - theta1) >= 0`` and ``b + r = theta2 -
theta1``, so we implement the ``>= 0`` orientation. Safety is verified
empirically by property tests.)

A feature ``f`` can be active at ``lam2`` only if ``|fhat^T theta2| = 1``
(paper Eq. 22), so any feature with ``max_{theta in K} |fhat^T theta| < 1``
is *safely* discarded.

Closed form for ``T(v) := max_{theta in K} v^T theta`` (our derivation; it
reproduces the paper's Theorems 6.5/6.7/6.9 — by Thm 6.3 the paper's
switch to the minimal ball ``B_t`` in the alpha>0 case computes the max over
the *same* sphere∩plane set, so the two forms agree):

  Work inside the hyperplane H = {y^T theta = 0}. With
  Q u := u - (u^T y / ||y||^2) y  (projection onto H's direction space),

    c_H  = Q c,   R_H^2 = R^2 - (y^T c)^2 / ||y||^2      (ball ∩ H)
    g0   = a^T (c_H - theta1)                            (halfspace offset)

  T(v) = v^T c_H + max_{||s|| <= R_H, (Qa)^T s >= -g0} (Qv)^T s:

    case A ("alpha=0", Thm 6.7): the ball max  s* = R_H Qv/||Qv||  already
      satisfies the halfspace  =>  T = v^T c_H + R_H ||Qv||.
    case B ("alpha>0", Thm 6.9): max on sphere ∩ {(Qa)^T s = -g0}:
      mu    = (Qv)^T Qa / ||Qa||^2
      vperp = Qv - mu Qa ;  rho^2 = max(0, R_H^2 - g0^2/||Qa||^2)
      T = v^T c_H - mu g0 + rho ||vperp||.
    case "beta=0" (Thm 6.5) is the ||vperp|| -> 0 limit of case B and needs
      no special handling in floating point (guarded divisions).

Everything reduces to four per-feature reductions over samples

    d_theta_j = fhat_j^T theta1,  d_1_j = fhat_j^T 1,
    d_y_j     = fhat_j^T y,       d_sq_j = ||fhat_j||^2

(i.e. ``X @ (y*theta1)``, ``X @ y``, ``X @ 1``, ``(X*X) @ 1`` in unsigned
coordinates) plus O(1) shared scalars — the paper's O(mn) bound, realized as
one GEMM-shaped sweep (see kernels/screen.py for the fused TPU kernel).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "FeatureReductions",
    "ScreenShared",
    "feature_reductions",
    "row_dot",
    "shared_scalars",
    "shared_scalars_from_stats",
    "screen_bounds_from_reductions",
    "screen_bounds",
    "screen",
    "SAFE_TAU",
    "anchor_slice",
    "fixed_slice",
    "finalize_from_anchor_jit",
]

# Keep a feature unless its bound is provably below 1; the tau margin absorbs
# floating-point accumulation error so rounding can never cause an *unsafe*
# rejection (it can only make screening slightly conservative). Sized from
# measurement: fp32 bound evaluation deviates from fp64 by up to ~2e-3 on
# adversarial instances (tests/test_screening.py::test_bounds_dtype_stability;
# a hypothesis-found case showed a 1.1e-4 violation at 1e-6 margin), so the
# default margin is 2e-3 with the rejection-power cost measured at <1%
# (benchmarks). Callers with fp64 inputs may tighten.
SAFE_TAU = 1.0 - 2e-3

_EPS = 1e-30


class FeatureReductions(NamedTuple):
    """Per-feature sample-axis reductions (all shape ``(m,)``)."""

    d_theta: jax.Array  # fhat_j^T theta1 = f_j^T (y * theta1)
    d_one: jax.Array    # fhat_j^T 1     = f_j^T y
    d_y: jax.Array      # fhat_j^T y     = f_j^T 1
    d_sq: jax.Array     # ||fhat_j||^2   = ||f_j||^2


class ScreenShared(NamedTuple):
    """Feature-independent scalars (paper Sec. 6.4 'precompute & share')."""

    inv_lam1: jax.Array
    inv_lam2: jax.Array
    yc: jax.Array          # y^T c
    ysq: jax.Array         # ||y||^2
    r_h_sq: jax.Array      # R_H^2 (ball radius^2 inside the hyperplane)
    g0: jax.Array          # a^T (c_H - theta1)
    qa_theta: jax.Array    # (Qa)^T (Q theta1)  [for v^T terms via reductions]
    qa_sq: jax.Array       # ||Qa||^2
    a_norm: jax.Array      # ||theta1 - 1/lam1||
    a_dot_one: jax.Array   # a^T 1
    a_dot_y: jax.Array     # a^T y
    theta_dot_one: jax.Array
    theta_dot_y: jax.Array  # == 0 for an exactly feasible theta1
    halfspace_valid: jax.Array  # bool: ||theta1 - 1/lam1|| > 0


@jax.jit
def row_dot(X: jax.Array, v: jax.Array) -> jax.Array:
    """``X @ v`` as an explicit multiply + last-axis reduction.

    Row-stable formulation: each output row reduces over its own samples
    only, and XLA lowers ``sum(X * v, axis=1)`` identically for any leading
    row count — so concatenating the results of row *chunks* reproduces the
    full-matrix result **bitwise** (a matmul/matvec does not: its tiling
    depends on the row count). This is the contract the out-of-core streamed
    screen (``repro/sparse/screen_stream.py``) is built on: the in-core and
    chunk-accumulated bound sweeps share this kernel and agree exactly.
    """
    return jnp.sum(X * v[None, :], axis=1)


@jax.jit
def _row_stable_reductions(X, y_theta, y):
    d_theta = jnp.sum(X * y_theta[None, :], axis=1)
    d_one = jnp.sum(X * y[None, :], axis=1)
    d_y = jnp.sum(X, axis=1)
    d_sq = jnp.sum(X * X, axis=1)
    return d_theta, d_one, d_y, d_sq


def feature_reductions(X: jax.Array, y: jax.Array, theta1: jax.Array) -> FeatureReductions:
    """The four O(mn) reductions, batched over all features.

    ``X``: (m, n) features-major. This is the only data-touching step; the
    Pallas kernel in ``repro/kernels`` fuses the four passes into one.
    Computed in the row-stable formulation (see :func:`row_dot`) so the
    streamed per-chunk sweep concatenates to these values bitwise.
    """
    d_theta, d_one, d_y, d_sq = _row_stable_reductions(X, y * theta1, y)
    return FeatureReductions(d_theta=d_theta, d_one=d_one, d_y=d_y, d_sq=d_sq)


def d_theta_sparse(X: jax.Array, y: jax.Array, theta1: jax.Array,
                   support: int) -> jax.Array:
    """``fhat_j^T theta1`` exploiting theta1's sparsity (paper Sec. 6.4).

    Along a path the other three reductions are theta-independent and
    precomputed once; this is the only O(mn) term per lambda. theta1 has at
    most #support-vectors nonzeros (samples with positive hinge), so a
    static-size gather of its ``support`` largest entries turns the sweep
    into O(m * support). ``support`` must upper-bound nnz(theta1) for
    exactness (a static shape, so jit-stable); entries beyond nnz are zero
    and contribute nothing.
    """
    support = min(support, theta1.shape[0])
    vals, idx = jax.lax.top_k(jnp.abs(theta1), support)
    coef = (y * theta1)[idx]                       # signed, true values
    return X[:, idx] @ coef


def shared_scalars(
    y: jax.Array, lam1: jax.Array, lam2: jax.Array, theta1: jax.Array,
    delta: jax.Array | float = 0.0,
) -> ScreenShared:
    """Scalars shared by every feature's bound (computed once, O(n)).

    ``delta`` is an upper bound on ``||theta1 - theta1*||_2`` when theta1 is
    only approximately optimal (the paper assumes it exact). With
    ``||theta1 - theta*|| <= delta`` the exact-theta ball
    Ball(c*, R*) is contained in Ball(c, R + delta) and the halfspace
    ``a*^T (theta2 - theta1*) >= 0`` relaxes to
    ``a^T (theta2 - theta1) >= -delta (2R + 3 delta + ||u||)/||u||``
    (u = theta1 - 1/lam1), so safety is preserved under inexact solves.
    ``delta = sqrt(2 * duality_gap) / lam1`` by 1-strong convexity of the
    dual objective (see dual.duality_gap_estimate). This robustification is
    a beyond-paper addition (in the spirit of later GAP-sphere rules).
    """
    dtype = theta1.dtype
    n = y.shape[0]
    return shared_scalars_from_stats(
        jnp.asarray(lam1, dtype),
        jnp.asarray(lam2, dtype),
        one_y=jnp.sum(y),
        theta_dot_one=jnp.sum(theta1),
        theta_dot_y=theta1 @ y,
        theta_sq=theta1 @ theta1,
        n_tot=jnp.asarray(float(n), dtype),  # ||y||^2 = n for +-1 labels
        delta=jnp.asarray(delta, dtype),
    )


def shared_scalars_from_stats(
    lam1: jax.Array,
    lam2: jax.Array,
    one_y: jax.Array,
    theta_dot_one: jax.Array,
    theta_dot_y: jax.Array,
    theta_sq: jax.Array,
    n_tot: jax.Array,
    delta: jax.Array | float = 0.0,
) -> ScreenShared:
    """:class:`ScreenShared` from global scalar statistics of ``(y, theta1)``.

    The stats-based entry point exists so every execution path — local
    (:func:`shared_scalars`), sharded (``distributed.screen_sharded`` psums
    per-shard partial sums into the same five scalars), and the in-solver
    dynamic refresh on a sample-masked problem (``solver.fista_solve_dynamic``
    computes masked stats) — runs the *identical* scalar arithmetic,
    including the inexact-theta ``delta`` inflation. Inputs:

        one_y = y^T 1,  theta_dot_one = theta1^T 1,  theta_dot_y = theta1^T y,
        theta_sq = ||theta1||^2,  n_tot = ||y||^2 (= #live samples).
    """
    inv1, inv2 = 1.0 / lam1, 1.0 / lam2
    ysq = n_tot

    # ball: c = (inv2*1 + theta1)/2 ; R^2 = ||inv2*1 - theta1||^2 / 4
    yc = 0.5 * (inv2 * one_y + theta_dot_y)
    r_sq = 0.25 * (inv2 * inv2 * n_tot - 2.0 * inv2 * theta_dot_one + theta_sq)
    r_base = jnp.sqrt(jnp.maximum(r_sq, 0.0))
    r_infl = r_base + delta          # inexact-theta1 inflation (no-op at 0)
    r_h_sq = r_infl * r_infl - yc * yc / ysq

    # halfspace normal a = (theta1 - inv1*1)/||.||
    diff_sq = theta_sq - 2.0 * inv1 * theta_dot_one + inv1 * inv1 * n_tot
    a_norm = jnp.sqrt(jnp.maximum(diff_sq, 0.0))
    # RELATIVE validity: when theta1 == 1/lam1 analytically (balanced classes
    # at lam_max), a is pure rounding noise — a random halfspace direction
    # would cut the ball unsafely. Compare against theta1's own scale.
    scale = jnp.sqrt(theta_sq + inv1 * inv1 * n_tot)
    halfspace_valid = a_norm > 1e-6 * scale
    safe_norm = jnp.maximum(a_norm, _EPS)
    a_dot_one = (theta_dot_one - inv1 * n_tot) / safe_norm
    a_dot_y = (theta_dot_y - inv1 * one_y) / safe_norm
    a_dot_theta = (theta_sq - inv1 * theta_dot_one) / safe_norm

    # c_H = c - (yc/ysq) y ;  g0 = a^T c_H - a^T theta1 (relaxed by delta slack)
    a_dot_c = 0.5 * (inv2 * a_dot_one + a_dot_theta)
    g0 = a_dot_c - (yc / ysq) * a_dot_y - a_dot_theta
    g0 = g0 + delta * (2.0 * r_base + 3.0 * delta + a_norm) / safe_norm
    qa_sq = jnp.maximum(1.0 - a_dot_y * a_dot_y / ysq, 0.0)  # ||a||=1

    return ScreenShared(
        inv_lam1=inv1,
        inv_lam2=inv2,
        yc=yc,
        ysq=ysq,
        r_h_sq=r_h_sq,
        g0=g0,
        qa_theta=a_dot_theta - a_dot_y * theta_dot_y / ysq,
        qa_sq=qa_sq,
        a_norm=a_norm,
        a_dot_one=a_dot_one,
        a_dot_y=a_dot_y,
        theta_dot_one=theta_dot_one,
        theta_dot_y=theta_dot_y,
        halfspace_valid=halfspace_valid,
    )


def _t_max(
    v_ch: jax.Array,
    qv_qa: jax.Array,
    qv_sq: jax.Array,
    sh: ScreenShared,
) -> jax.Array:
    """``max_{theta in K} v^T theta`` given hyperplane-projected stats of v.

    v_ch  : v^T c_H            (m,)
    qv_qa : (Qv)^T (Qa)        (m,)
    qv_sq : ||Qv||^2           (m,)
    """
    r_h = jnp.sqrt(jnp.maximum(sh.r_h_sq, 0.0))
    qv_norm = jnp.sqrt(jnp.maximum(qv_sq, 0.0))

    # case A: ball max satisfies the halfspace. The halfspace is only
    # informative when a has a component INSIDE the hyperplane: at
    # lam1 = lam_max with unbalanced classes a ∝ y exactly, ||Qa|| = 0 and
    # the constraint is vacuous there (found by the paper-reference
    # cross-check; both case conditions are 0/0 noise in that geometry).
    ball_val = v_ch + r_h * qv_norm
    at_ball = sh.g0 + r_h * qv_qa / jnp.maximum(qv_norm, _EPS)
    halfspace_informative = sh.halfspace_valid & (sh.qa_sq > 1e-9)
    use_ball = (at_ball >= 0.0) | (~halfspace_informative) | (qv_norm <= _EPS)

    # case B: sphere ∩ halfspace-boundary
    qa_sq = jnp.maximum(sh.qa_sq, _EPS)
    mu = qv_qa / qa_sq
    vperp_sq = jnp.maximum(qv_sq - mu * mu * qa_sq, 0.0)
    rho_sq = jnp.maximum(sh.r_h_sq - sh.g0 * sh.g0 / qa_sq, 0.0)
    cut_val = v_ch - mu * sh.g0 + jnp.sqrt(rho_sq) * jnp.sqrt(vperp_sq)

    return jnp.where(use_ball, ball_val, cut_val)


def screen_bounds_from_reductions(
    red: FeatureReductions, sh: ScreenShared
) -> jax.Array:
    """Upper bound on ``|fhat_j^T theta2|`` per feature, from reductions only."""
    # v = fhat: project the per-feature stats into the hyperplane.
    v_y = red.d_y
    v_c = 0.5 * (sh.inv_lam2 * red.d_one + red.d_theta)
    v_ch = v_c - (sh.yc / sh.ysq) * v_y
    qv_sq = red.d_sq - v_y * v_y / sh.ysq

    # (Qv)^T (Qa) = v^T a - (v^T y)(a^T y)/||y||^2, with
    # a = (theta1 - 1/lam1)/||.||  =>  v^T a = (v^T theta1 - v^T 1/lam1)/||.||
    safe_norm = jnp.maximum(sh.a_norm, _EPS)
    v_a = (red.d_theta - sh.inv_lam1 * red.d_one) / safe_norm
    qv_qa = v_a - v_y * sh.a_dot_y / sh.ysq

    m_pos = _t_max(v_ch, qv_qa, qv_sq, sh)            # max  fhat^T theta
    m_neg = _t_max(-v_ch, -qv_qa, qv_sq, sh)          # max -fhat^T theta
    return jnp.maximum(m_pos, m_neg)


# jitted separately from the reduction sweep (not one fused program): the
# streamed screen computes the reductions chunk-by-chunk and must finalize
# through the *same* compiled function to preserve the bitwise contract —
# a single whole-program jit would fuse reduction and finalizer into a
# different lowering than the chunked path can reproduce.
_finalize_bounds = jax.jit(screen_bounds_from_reductions)


class AnchorStats(NamedTuple):
    """A dual anchor ``theta1`` at ``lam``, as the scalars + one reduction
    every screening-rule program consumes (the anchor half of a rule's
    *region pytree*).

    Engines own the sweeps that produce these (psum-reduced on a mesh,
    chunk-accumulated out of core, plain reductions in core), so a rule
    program evaluated on :class:`AnchorStats` is pure and collective-free —
    the property that makes it lowerable into ``lax.scan``/``vmap``/
    ``shard_map`` bodies unchanged.
    """

    lam: jax.Array            # anchor regularization (lam1)
    delta: jax.Array          # ||theta1 - theta*(lam)|| inexactness radius
    theta_dot_one: jax.Array  # theta1^T 1
    theta_dot_y: jax.Array    # theta1^T y
    theta_sq: jax.Array       # ||theta1||^2
    d_theta: jax.Array        # (m,) fhat_j^T theta1 = f_j^T (y * theta1)


class FixedStats(NamedTuple):
    """Theta-independent statics shared by every anchor and every rule
    (the fixed half of the region pytree; hoisted once per path)."""

    d_one: jax.Array   # (m,) fhat_j^T 1
    d_y: jax.Array     # (m,) fhat_j^T y
    d_sq: jax.Array    # (m,) ||fhat_j||^2
    one_y: jax.Array   # y^T 1
    n_tot: jax.Array   # ||y||^2 = #live samples


def anchor_stats(y: jax.Array, lam, theta1: jax.Array, delta,
                 d_theta: jax.Array) -> AnchorStats:
    """Build :class:`AnchorStats` from an in-core anchor (caller supplies the
    one O(mn) reduction ``d_theta``). Scalar arithmetic matches
    :func:`shared_scalars` exactly so anchor-based and legacy entry points
    produce bitwise-identical :class:`ScreenShared` values."""
    dtype = theta1.dtype
    return AnchorStats(
        lam=jnp.asarray(lam, dtype),
        delta=jnp.asarray(delta, dtype),
        theta_dot_one=jnp.sum(theta1),
        theta_dot_y=theta1 @ y,
        theta_sq=theta1 @ theta1,
        d_theta=d_theta,
    )


def fixed_stats(y: jax.Array, d_one: jax.Array, d_y: jax.Array,
                d_sq: jax.Array) -> FixedStats:
    """Build :class:`FixedStats` from an in-core ``y`` and the three hoisted
    per-feature reductions."""
    n = y.shape[0]
    return FixedStats(d_one=d_one, d_y=d_y, d_sq=d_sq, one_y=jnp.sum(y),
                      n_tot=jnp.asarray(float(n), y.dtype))


def shared_scalars_from_anchor(anchor: AnchorStats, lam2,
                               fixed: FixedStats) -> ScreenShared:
    """:class:`ScreenShared` for the VI set anchored at ``anchor``,
    targeting ``lam2`` — the region-pytree face of
    :func:`shared_scalars_from_stats`."""
    return shared_scalars_from_stats(
        anchor.lam, lam2, one_y=fixed.one_y,
        theta_dot_one=anchor.theta_dot_one, theta_dot_y=anchor.theta_dot_y,
        theta_sq=anchor.theta_sq, n_tot=fixed.n_tot, delta=anchor.delta,
    )


def finalize_from_anchor(anchor: AnchorStats, lam2,
                         fixed: FixedStats) -> jax.Array:
    """The VI bound finalizer over the region pytree: per-feature upper
    bounds on ``|fhat_j^T theta*(lam2)|`` from one anchor's stats. Inlines
    :func:`screen_bounds_from_reductions` (no nested jit) so engine traces
    that embed it lower exactly as the pre-pytree code did."""
    sh = shared_scalars_from_anchor(anchor, lam2, fixed)
    red = FeatureReductions(d_theta=anchor.d_theta, d_one=fixed.d_one,
                            d_y=fixed.d_y, d_sq=fixed.d_sq)
    return screen_bounds_from_reductions(red, sh)


#: Jitted :func:`finalize_from_anchor` for host-driven callers evaluating
#: many small region pytrees eagerly (one compile per d_theta shape). The
#: chunk-skip plane leans on a property of the VI region worth stating once:
#: an anchor certified at ``lam1`` yields a *valid* safe region for ANY
#: target ``lam2 < lam1`` — a stale anchor's bounds are merely looser, never
#: unsafe. That is what lets a chunk's features be certified dead from the
#: reductions cached at the chunk's last stream, without re-streaming it.
finalize_from_anchor_jit = jax.jit(finalize_from_anchor)


def anchor_slice(anchor: AnchorStats, lo: int, hi: int) -> AnchorStats:
    """Restrict an anchor's per-feature reduction to rows ``[lo, hi)`` (the
    scalars are feature-independent and pass through) — the region pytree a
    single chunk's bound evaluation consumes."""
    return anchor._replace(d_theta=anchor.d_theta[lo:hi])


def fixed_slice(fixed: FixedStats, lo: int, hi: int) -> FixedStats:
    """Restrict the fixed statics to feature rows ``[lo, hi)``."""
    return fixed._replace(d_one=fixed.d_one[lo:hi], d_y=fixed.d_y[lo:hi],
                          d_sq=fixed.d_sq[lo:hi])


def screen_bounds(
    X: jax.Array,
    y: jax.Array,
    lam1: jax.Array,
    lam2: jax.Array,
    theta1: jax.Array,
    red: Optional[FeatureReductions] = None,
    delta: jax.Array | float = 0.0,
) -> jax.Array:
    """Upper bound on ``|fhat_j^T theta*(lam2)|`` for every feature j."""
    if red is None:
        red = feature_reductions(X, y, theta1)
    sh = shared_scalars(y, lam1, lam2, theta1, delta=delta)
    return _finalize_bounds(red, sh)


def screen(
    X: jax.Array,
    y: jax.Array,
    lam1: jax.Array,
    lam2: jax.Array,
    theta1: jax.Array,
    tau: float = SAFE_TAU,
    red: Optional[FeatureReductions] = None,
    delta: jax.Array | float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Safe screening (paper Algorithm 1), batched over all m features.

    Returns ``(keep_mask, bounds)``; ``keep_mask[j] = bounds[j] >= tau``.
    Discarded features are guaranteed inactive at ``lam2`` (given an exact
    ``theta1``, or ``||theta1 - theta*|| <= delta``); kept features *may* be
    active.

    The comparison is NaN-safe in the keep direction: a non-finite bound
    (poisoned anchor, overflowed reduction) certifies nothing, so the
    feature is KEPT — discarding is the only unsafe failure mode.
    """
    bounds = screen_bounds(X, y, lam1, lam2, theta1, red=red, delta=delta)
    return ~(bounds < tau), bounds
