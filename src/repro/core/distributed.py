"""2-D sharded screening + solver via shard_map (features x samples mesh).

Layout: ``X`` is sharded ``P("model", "data")`` — feature rows over the
"model" axis, sample columns over the "data" axis. Sample-space vectors
(``y``, ``theta``) shard over "data"; feature-space vectors (``w``, bounds,
keep masks) shard over "model".

Communication pattern (maps the paper's O(mn) screen onto the mesh):

* the four per-feature reductions are computed locally over each shard's
  sample columns, then ``psum`` over the "data" axis → 4 scalars per local
  feature, i.e. 4·(m/P_model) floats per device — the only screen traffic;
* bound evaluation is local to the "model" shard (zero communication);
* FISTA: margins need ``psum`` over "model" (features), gradients need
  ``psum`` over "data" (samples) — the classic 2-D GEMV pattern.

On a multi-pod mesh the "pod" axis is folded into the data axis for the SVM
workload (samples shard over ("pod", "data")) so inter-pod traffic is only
the 4-scalar psum and the margin psum, both tiny and DCN-tolerant.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

def shard_map(f, mesh, in_specs, out_specs, check_rep=False):
    """Thin compat wrapper: jax>=0.8 ``jax.shard_map`` (check_vma keyword) or
    the older ``jax.experimental.shard_map`` (check_rep keyword)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_rep)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_rep)

from .screening import (
    SAFE_TAU,
    FeatureReductions,
    screen_bounds_from_reductions,
    shared_scalars,
)
from .solver import FistaResult, soft_threshold

__all__ = ["screen_sharded", "fista_sharded", "svm_mesh"]


def svm_mesh(model: int, data: int, devices=None) -> Mesh:
    """Build a (model x data) mesh for the SVM workload."""
    devices = devices if devices is not None else jax.devices()
    assert len(devices) >= model * data, (len(devices), model, data)
    import numpy as np

    arr = np.asarray(devices[: model * data]).reshape(model, data)
    return Mesh(arr, ("model", "data"))


def screen_sharded(
    mesh: Mesh,
    X: jax.Array,
    y: jax.Array,
    lam1,
    lam2,
    theta1: jax.Array,
    tau: float = SAFE_TAU,
    data_axes=("data",),
):
    """Distributed safe screening. Returns (keep_mask, bounds), sharded on "model".

    ``X``: (m, n) sharded P("model", data_axes); ``y``/``theta1``: (n,)
    sharded P(data_axes).
    """
    lam1 = jnp.asarray(lam1, jnp.float32)
    lam2 = jnp.asarray(lam2, jnp.float32)

    def local(x_blk, y_blk, th_blk):
        # local partial reductions over this shard's sample columns
        rhs = jnp.stack([y_blk * th_blk, y_blk, jnp.ones_like(y_blk)], axis=1)
        d = x_blk @ rhs                       # (m_loc, 3)
        d_sq = jnp.sum(x_blk * x_blk, axis=1)  # (m_loc,)
        packed = jnp.concatenate([d, d_sq[:, None]], axis=1)
        packed = jax.lax.psum(packed, data_axes)

        # shared scalars need full-sample reductions of y/theta1: psum too
        n_loc = y_blk.shape[0]
        stats = jnp.stack(
            [
                jnp.sum(y_blk),
                jnp.sum(th_blk),
                th_blk @ y_blk,
                th_blk @ th_blk,
                jnp.asarray(n_loc, jnp.float32),
            ]
        )
        stats = jax.lax.psum(stats, data_axes)
        one_y, th_one, th_y, th_sq, n_tot = stats

        sh = _shared_from_stats(lam1, lam2, one_y, th_one, th_y, th_sq, n_tot)
        red = FeatureReductions(
            d_theta=packed[:, 0], d_one=packed[:, 1], d_y=packed[:, 2], d_sq=packed[:, 3]
        )
        bounds = screen_bounds_from_reductions(red, sh)
        return bounds >= tau, bounds

    specs_in = (
        P("model", *data_axes),
        P(*data_axes),
        P(*data_axes),
    )
    fn = shard_map(
        local, mesh=mesh, in_specs=specs_in, out_specs=(P("model"), P("model")),
        check_rep=False,
    )
    return fn(X, y, theta1)


def _shared_from_stats(lam1, lam2, one_y, th_one, th_y, th_sq, n_tot):
    """ScreenShared from global scalar statistics (mirrors shared_scalars)."""
    from .screening import ScreenShared, _EPS

    inv1, inv2 = 1.0 / lam1, 1.0 / lam2
    ysq = n_tot
    yc = 0.5 * (inv2 * one_y + th_y)
    r_sq = 0.25 * (inv2 * inv2 * n_tot - 2.0 * inv2 * th_one + th_sq)
    r_h_sq = r_sq - yc * yc / ysq

    diff_sq = th_sq - 2.0 * inv1 * th_one + inv1 * inv1 * n_tot
    a_norm = jnp.sqrt(jnp.maximum(diff_sq, 0.0))
    # relative validity threshold — see screening.shared_scalars
    halfspace_valid = a_norm > 1e-6 * jnp.sqrt(th_sq + inv1 * inv1 * n_tot)
    safe_norm = jnp.maximum(a_norm, _EPS)
    a_dot_one = (th_one - inv1 * n_tot) / safe_norm
    a_dot_y = (th_y - inv1 * one_y) / safe_norm
    a_dot_theta = (th_sq - inv1 * th_one) / safe_norm

    a_dot_c = 0.5 * (inv2 * a_dot_one + a_dot_theta)
    g0 = a_dot_c - (yc / ysq) * a_dot_y - a_dot_theta
    qa_sq = jnp.maximum(1.0 - a_dot_y * a_dot_y / ysq, 0.0)

    return ScreenShared(
        inv_lam1=inv1, inv_lam2=inv2, yc=yc, ysq=ysq, r_h_sq=r_h_sq, g0=g0,
        qa_theta=a_dot_theta - a_dot_y * th_y / ysq, qa_sq=qa_sq, a_norm=a_norm,
        a_dot_one=a_dot_one, a_dot_y=a_dot_y, theta_dot_one=th_one,
        theta_dot_y=th_y, halfspace_valid=halfspace_valid,
    )


def fista_sharded(
    mesh: Mesh,
    X: jax.Array,
    y: jax.Array,
    lam,
    max_iters: int = 2000,
    tol: float = 1e-9,
    w0: Optional[jax.Array] = None,
    b0: Optional[jax.Array] = None,
    data_axes=("data",),
    sample_mask: Optional[jax.Array] = None,
) -> FistaResult:
    """Distributed FISTA on 2-D sharded X. Same math as solver.fista_solve.

    ``sample_mask`` (0/1 over samples, sharded like ``y``) drops screened
    samples from the loss without reshaping the sharded operands — the
    mask-mode counterpart of the sample-screening rules (core/rules).
    """
    lam = jnp.asarray(lam, jnp.float32)
    m, n = X.shape
    if sample_mask is None:
        sample_mask = jnp.ones_like(y)

    def local(x_blk, y_blk, sm_blk, w_blk, b_scalar):
        def margins(w):
            part = x_blk.T @ w  # (n_loc,)
            return jax.lax.psum(part, "model")

        def grad(w, b):
            u = margins(w) + b
            xi = sm_blk * jnp.maximum(0.0, 1.0 - y_blk * u)
            gw = -(x_blk @ (y_blk * xi))
            gw = jax.lax.psum(gw, data_axes)
            gb = -jnp.sum(y_blk * xi)
            gb = jax.lax.psum(gb, (*data_axes, "model")) / jax.lax.psum(
                1.0, "model"
            )  # each model row computed same xi; average the replicas
            loss = 0.5 * jnp.sum(xi * xi)
            loss = jax.lax.psum(loss, data_axes)
            return gw, gb, loss

        def objective(w, b):
            u = margins(w) + b
            xi = sm_blk * jnp.maximum(0.0, 1.0 - y_blk * u)
            loss = 0.5 * jnp.sum(xi * xi)
            loss = jax.lax.psum(loss, data_axes)
            l1 = jax.lax.psum(jnp.sum(jnp.abs(w)), "model")
            return loss + lam * l1

        # power iteration for L (sharded)
        def pow_body(v, _):
            nrm = jnp.sqrt(jax.lax.psum(v @ v, data_axes))
            v = v / jnp.maximum(nrm, 1e-30)
            u_w = jax.lax.psum(x_blk @ v, data_axes)  # wait: X@v reduces over data
            u_b = jax.lax.psum(jnp.sum(v), data_axes)
            vn = x_blk.T @ u_w
            vn = jax.lax.psum(vn, "model") + u_b
            return vn, None

        v0 = jnp.cos(jnp.arange(y_blk.shape[0], dtype=jnp.float32) + 1.0)
        v, _ = jax.lax.scan(pow_body, v0, None, length=30)
        L = jnp.sqrt(jax.lax.psum(v @ v, data_axes))
        L = jnp.maximum(L * 1.01, 1e-12)
        inv_L = 1.0 / L

        obj0 = objective(w_blk, b_scalar)

        def cond(st):
            w, b, wp, bp, t, k, obj, rel = st
            return (k < max_iters) & (rel > tol)

        def body(st):
            w, b, wp, bp, t, k, obj, rel = st
            t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
            beta = (t - 1.0) / t_next
            zw = w + beta * (w - wp)
            zb = b + beta * (b - bp)
            gw, gb, _ = grad(zw, zb)
            w_new = soft_threshold(zw - inv_L * gw, lam * inv_L)
            b_new = zb - inv_L * gb
            obj_new = objective(w_new, b_new)

            gw_p, gb_p, _ = grad(w, b)
            w_pl = soft_threshold(w - inv_L * gw_p, lam * inv_L)
            b_pl = b - inv_L * gb_p
            obj_pl = objective(w_pl, b_pl)

            bad = obj_new > obj
            w_new = jnp.where(bad, w_pl, w_new)
            b_new = jnp.where(bad, b_pl, b_new)
            obj_new = jnp.where(bad, obj_pl, obj_new)
            t_next = jnp.where(bad, 1.0, t_next)

            rel = jnp.abs(obj - obj_new) / jnp.maximum(jnp.abs(obj), 1e-30)
            return (w_new, b_new, w, b, t_next, k + 1, obj_new, rel)

        st0 = (w_blk, b_scalar, w_blk, b_scalar, jnp.float32(1.0),
               jnp.int32(0), obj0, jnp.float32(jnp.inf))
        w, b, _, _, _, k, obj, rel = jax.lax.while_loop(cond, body, st0)
        return w, b, obj, k, rel <= tol

    if w0 is None:
        w0 = jnp.zeros((m,), jnp.float32)
    if b0 is None:
        b0 = jnp.mean(y)
    b0 = jnp.asarray(b0, jnp.float32)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P("model", *data_axes), P(*data_axes), P(*data_axes),
                  P("model"), P()),
        out_specs=(P("model"), P(), P(), P(), P()),
        check_rep=False,
    )
    w, b, obj, k, conv = fn(X, y, jnp.asarray(sample_mask, jnp.float32), w0, b0)
    return FistaResult(w=w, b=b, obj=obj, n_iters=k, converged=conv)
