"""2-D sharded screening + solver via shard_map (features x samples mesh).

Layout: ``X`` is sharded ``P("model", "data")`` — feature rows over the
"model" axis, sample columns over the "data" axis. Sample-space vectors
(``y``, ``theta``) shard over "data"; feature-space vectors (``w``, bounds,
keep masks) shard over "model".

Communication pattern (maps the paper's O(mn) screen onto the mesh):

* the four per-feature reductions are computed locally over each shard's
  sample columns, then ``psum`` over the "data" axis → 4 scalars per local
  feature, i.e. 4·(m/P_model) floats per device — the only screen traffic;
* bound evaluation is local to the "model" shard (zero communication);
* FISTA: margins need ``psum`` over "model" (features), gradients need
  ``psum`` over "data" (samples) — the classic 2-D GEMV pattern.

On a multi-pod mesh the "pod" axis is folded into the data axis for the SVM
workload (samples shard over ("pod", "data")) so inter-pod traffic is only
the 4-scalar psum and the margin psum, both tiny and DCN-tolerant.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

def shard_map(f, mesh, in_specs, out_specs, check_rep=False):
    """Thin compat wrapper: jax>=0.8 ``jax.shard_map`` (check_vma keyword) or
    the older ``jax.experimental.shard_map`` (check_rep keyword)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_rep)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_rep)

from .screening import (
    SAFE_TAU,
    FeatureReductions,
    screen_bounds_from_reductions,
    shared_scalars,
    shared_scalars_from_stats,
)
from .solver import Collectives, DynamicFistaResult, FistaResult, soft_threshold

__all__ = [
    "screen_sharded",
    "fista_sharded",
    "svm_mesh",
    "mesh_collectives",
    "sample_surplus_sharded",
]


def svm_mesh(model: int, data: int, devices=None) -> Mesh:
    """Build a (model x data) mesh for the SVM workload."""
    devices = devices if devices is not None else jax.devices()
    assert len(devices) >= model * data, (len(devices), model, data)
    import numpy as np

    arr = np.asarray(devices[: model * data]).reshape(model, data)
    return Mesh(arr, ("model", "data"))


def mesh_collectives(mesh: Mesh, data_axes=("data",),
                     model_axis: str = "model") -> Collectives:
    """``solver.Collectives`` bound to the ``svm_mesh`` 2-D psum pattern.

    This is the plumbing that lets the *local* solver bodies (fused FISTA,
    gap certificate, Lipschitz power iteration — ``core/solver.py``) and the
    on-device path engine (``core/path_scan.py``) run unchanged inside a
    ``shard_map``: margins and L1 norms reduce over the feature ("model")
    axis, gradients and losses over the sample ("data") axes, the bias
    gradient over both (averaged over the model replicas that each computed
    the same xi), and the dual-feasibility rescale takes a pmax over
    features. Same communication pattern as :func:`fista_sharded`.

    Axes of size 1 bind to the identity, not to a degenerate all-reduce:
    a trivial psum is value-preserving but still restructures XLA's fusion,
    and the resulting 1-ulp objective noise flips the solver's restart /
    stopping predicates at their convergence-plateau ties. Pruning trivial
    axes keeps a 1-D mesh free of no-op collectives (e.g. a pure
    data-parallel ``svm_mesh(1, N)`` issues zero "model" psums) and makes
    the ``svm_mesh(1, 1)`` sharded engine bit-identical to the local one.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d_axes = tuple(a for a in data_axes if sizes.get(a, 1) > 1)
    have_model = sizes.get(model_axis, 1) > 1

    def psum_model(x):
        return jax.lax.psum(x, model_axis) if have_model else x

    def psum_data(x):
        return jax.lax.psum(x, d_axes) if d_axes else x

    def psum_bias(x):
        axes = (*d_axes, *((model_axis,) if have_model else ()))
        if not axes:
            return x
        out = jax.lax.psum(x, axes)
        if have_model:
            out = out / jax.lax.psum(1.0, model_axis)
        return out

    def pmax_model(x):
        return jax.lax.pmax(x, model_axis) if have_model else x

    return Collectives(psum_model, psum_data, psum_bias, pmax_model)


def sample_surplus_sharded(
    mesh: Mesh,
    X: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b,
    dw=float("inf"),
    db=float("inf"),
    u_prev: Optional[jax.Array] = None,
    shrink_factor: float = 2.0,
    margin_floor: float = 1e-3,
    data_axes=("data",),
):
    """Distributed sample-rule margin sweep. Returns ``(surplus, u1)``.

    The sharded mirror of ``rules/sample_vi.sample_margin_surplus``: the two
    feature-axis reductions it needs — the margins ``u1 = X^T w + b`` and
    the column norms ``||x_i||^2`` — are computed locally over each shard's
    feature rows and ``psum``-reduced over the "model" axis (one fused
    2-row stack, mirroring :func:`screen_sharded`'s packed reduction), then
    finalized with the *identical* slack arithmetic as the local rule
    (``rules/sample_vi.margin_surplus_core``), so on a mesh that keeps the
    feature axis whole the result matches the local oracle bitwise.

    ``X``: (m, n) sharded ``P("model", data_axes)``; ``y``/``u_prev``: (n,)
    sharded ``P(data_axes)``; ``w``: (m,) sharded ``P("model")``. Outputs
    shard over ``P(data_axes)``. ``dw``/``db`` are the host trust-region
    radii (python floats; ``inf`` = no movement history, never screens).
    """
    from .rules.sample_vi import margin_surplus_core  # lazy: no import cycle

    # match the data dtype (not a hardcoded float32): the bitwise-oracle
    # contract must hold under JAX_ENABLE_X64 too
    b = jnp.asarray(b, X.dtype)
    has_history = u_prev is not None
    have_model = dict(zip(mesh.axis_names, mesh.devices.shape)).get(
        "model", 1) > 1

    def local(x_blk, y_blk, w_blk, up_blk):
        if have_model:
            # fused 2-row reduction over this shard's feature rows, one psum
            part = jnp.stack([x_blk.T @ w_blk, jnp.sum(x_blk * x_blk, axis=0)])
            part = jax.lax.psum(part, "model")
            u1, x_sq = part[0] + b, part[1]
        else:
            # feature axis whole on this shard: identical arithmetic to the
            # local oracle (no stack/psum detour), so the bitwise-equality
            # contract of margin_surplus_core extends to the reductions too
            u1 = x_blk.T @ w_blk + b
            x_sq = jnp.sum(x_blk * x_blk, axis=0)
        surplus = margin_surplus_core(
            u1, y_blk, x_sq, dw, db,
            u_prev=up_blk if has_history else None,
            shrink_factor=shrink_factor, margin_floor=margin_floor,
        )
        return surplus, u1

    up = u_prev if has_history else jnp.zeros_like(y)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P("model", *data_axes), P(*data_axes), P("model"),
                  P(*data_axes)),
        out_specs=(P(*data_axes), P(*data_axes)),
        check_rep=False,
    )
    return fn(X, y, w, up)


def screen_sharded(
    mesh: Mesh,
    X: jax.Array,
    y: jax.Array,
    lam1,
    lam2,
    theta1: jax.Array,
    tau: float = SAFE_TAU,
    data_axes=("data",),
    *,
    delta,
):
    """Distributed safe screening. Returns (keep_mask, bounds), sharded on "model".

    ``X``: (m, n) sharded P("model", data_axes); ``y``/``theta1``: (n,)
    sharded P(data_axes). ``delta`` is the inexact-theta1 radius bound
    (``||theta1 - theta*(lam1)|| <= delta``, see ``dual.safe_theta_and_delta``):
    it inflates the ball and relaxes the halfspace exactly like
    ``screening.shared_scalars``. It is deliberately a *required* keyword —
    a sharded screen that silently assumed theta1 exact could unsafely
    reject features for any iteratively solved anchor; callers with a
    truly exact theta1 (closed form at lambda_max) state ``delta=0.0``.
    """
    lam1 = jnp.asarray(lam1, jnp.float32)
    lam2 = jnp.asarray(lam2, jnp.float32)
    delta = jnp.asarray(delta, jnp.float32)

    def local(x_blk, y_blk, th_blk):
        # local partial reductions over this shard's sample columns
        rhs = jnp.stack([y_blk * th_blk, y_blk, jnp.ones_like(y_blk)], axis=1)
        d = x_blk @ rhs                       # (m_loc, 3)
        d_sq = jnp.sum(x_blk * x_blk, axis=1)  # (m_loc,)
        packed = jnp.concatenate([d, d_sq[:, None]], axis=1)
        packed = jax.lax.psum(packed, data_axes)

        # shared scalars need full-sample reductions of y/theta1: psum too
        n_loc = y_blk.shape[0]
        stats = jnp.stack(
            [
                jnp.sum(y_blk),
                jnp.sum(th_blk),
                th_blk @ y_blk,
                th_blk @ th_blk,
                jnp.asarray(n_loc, jnp.float32),
            ]
        )
        stats = jax.lax.psum(stats, data_axes)
        one_y, th_one, th_y, th_sq, n_tot = stats

        sh = _shared_from_stats(lam1, lam2, one_y, th_one, th_y, th_sq, n_tot,
                                delta=delta)
        red = FeatureReductions(
            d_theta=packed[:, 0], d_one=packed[:, 1], d_y=packed[:, 2], d_sq=packed[:, 3]
        )
        bounds = screen_bounds_from_reductions(red, sh)
        return bounds >= tau, bounds

    specs_in = (
        P("model", *data_axes),
        P(*data_axes),
        P(*data_axes),
    )
    fn = shard_map(
        local, mesh=mesh, in_specs=specs_in, out_specs=(P("model"), P("model")),
        check_rep=False,
    )
    return fn(X, y, theta1)


def _shared_from_stats(lam1, lam2, one_y, th_one, th_y, th_sq, n_tot, delta=0.0):
    """ScreenShared from global scalar statistics, delta-inflated.

    Delegates to ``screening.shared_scalars_from_stats`` so the sharded
    screen runs the *identical* scalar arithmetic as the local oracle —
    including the inexact-theta ball inflation (``r_base + delta``) and the
    ``g0`` halfspace relaxation. (The pre-delta version of this function
    re-derived the scalars locally and dropped ``delta`` entirely, which
    made the sharded screen unsafe for sequentially-solved theta1.)
    """
    return shared_scalars_from_stats(
        lam1, lam2, one_y=one_y, theta_dot_one=th_one, theta_dot_y=th_y,
        theta_sq=th_sq, n_tot=n_tot, delta=delta,
    )


def fista_sharded(
    mesh: Mesh,
    X: jax.Array,
    y: jax.Array,
    lam,
    max_iters: int = 2000,
    tol: float = 1e-9,
    w0: Optional[jax.Array] = None,
    b0: Optional[jax.Array] = None,
    data_axes=("data",),
    sample_mask: Optional[jax.Array] = None,
    feature_mask: Optional[jax.Array] = None,
    screen_every: Optional[int] = None,
    tau: float = SAFE_TAU,
    n_feas_iters: int = 4,
    L: Optional[jax.Array] = None,
):
    """Distributed FISTA on 2-D sharded X. Same math as solver.fista_solve.

    ``L`` (optional): a known Lipschitz upper bound — the path launcher
    passes the full-X estimate once per path so every sharded solve skips
    the 30-iteration distributed power sweep (masked subproblems never
    have a larger ``sigma_max``; see ``solver.lipschitz_estimate``).

    ``sample_mask`` (0/1 over samples, sharded like ``y``) drops screened
    samples from the loss without reshaping the sharded operands — the
    mask-mode counterpart of the sample-screening rules (core/rules).

    ``screen_every`` (optional) turns on in-solver *dynamic* screening —
    the sharded mirror of ``solver.fista_solve_dynamic``: every
    ``screen_every`` iterations the local function computes the duality gap
    (margin psum over "model", correlation psum over the data axes),
    rebuilds the at-lambda VI region from the gap-certified dual point, and
    re-evaluates the feature bounds with the same psum sweep as
    :func:`screen_sharded`, ANDing the result into a live feature mask
    sharded over "model" (``feature_mask`` seeds it; without
    ``screen_every`` the mask is honored statically — seeded zeros stay
    zero — just never refreshed). Returns ``solver.DynamicFistaResult``
    (with per-segment kept/gap telemetry) when ``screen_every`` is set,
    plain ``FistaResult`` otherwise.
    """
    lam = jnp.asarray(lam, jnp.float32)
    m, n = X.shape
    if sample_mask is None:
        sample_mask = jnp.ones_like(y)
    dynamic = screen_every is not None and int(screen_every) > 0
    if dynamic:
        screen_every = int(screen_every)
        n_seg = -(-max_iters // screen_every)  # ceil; static
    if feature_mask is None:
        feature_mask = jnp.ones((m,), jnp.float32)

    have_L = L is not None

    def local(x_blk, y_blk, sm_blk, fm_blk, w_blk, b_scalar, L_in):
        def margins(w):
            part = x_blk.T @ w  # (n_loc,)
            return jax.lax.psum(part, "model")

        def grad(w, b):
            u = margins(w) + b
            xi = sm_blk * jnp.maximum(0.0, 1.0 - y_blk * u)
            gw = -(x_blk @ (y_blk * xi))
            gw = jax.lax.psum(gw, data_axes)
            gb = -jnp.sum(y_blk * xi)
            gb = jax.lax.psum(gb, (*data_axes, "model")) / jax.lax.psum(
                1.0, "model"
            )  # each model row computed same xi; average the replicas
            loss = 0.5 * jnp.sum(xi * xi)
            loss = jax.lax.psum(loss, data_axes)
            return gw, gb, loss

        def objective(w, b):
            u = margins(w) + b
            xi = sm_blk * jnp.maximum(0.0, 1.0 - y_blk * u)
            loss = 0.5 * jnp.sum(xi * xi)
            loss = jax.lax.psum(loss, data_axes)
            l1 = jax.lax.psum(jnp.sum(jnp.abs(w)), "model")
            return loss + lam * l1

        if have_L:
            # path-shared upper bound: skip the distributed power sweep
            Lc = L_in
        else:
            # power iteration for L (sharded)
            def pow_body(v, _):
                nrm = jnp.sqrt(jax.lax.psum(v @ v, data_axes))
                v = v / jnp.maximum(nrm, 1e-30)
                u_w = jax.lax.psum(x_blk @ v, data_axes)  # X@v reduces over data
                u_b = jax.lax.psum(jnp.sum(v), data_axes)
                vn = x_blk.T @ u_w
                vn = jax.lax.psum(vn, "model") + u_b
                return vn, None

            v0 = jnp.cos(jnp.arange(y_blk.shape[0], dtype=jnp.float32) + 1.0)
            v, _ = jax.lax.scan(pow_body, v0, None, length=30)
            Lc = jnp.sqrt(jax.lax.psum(v @ v, data_axes))
        Lc = jnp.maximum(Lc * 1.01, 1e-12)
        inv_L = 1.0 / Lc

        def make_body(fm):
            def prox_step(w_a, b_a):
                """Proximal-gradient step anchored at (w_a, b_a)."""
                gw, gb, _ = grad(w_a, b_a)
                w_s = soft_threshold(w_a - inv_L * gw, lam * inv_L)
                b_s = b_a - inv_L * gb
                if fm is not None:
                    w_s = w_s * fm
                return w_s, b_s, objective(w_s, b_s)

            def body(st):
                w, b, wp, bp, t, k, obj, rel, rel_prev, rel_prev2 = st
                t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
                beta = (t - 1.0) / t_next
                zw = w + beta * (w - wp)
                zb = b + beta * (b - bp)
                w_new, b_new, obj_new = prox_step(zw, zb)

                # monotone restart under lax.cond: the plain step's three
                # psum sweeps are paid only when the extrapolated step
                # actually increased the (replicated) objective — the
                # predicate is identical on every device, so all shards
                # take the same branch and the collectives stay matched.
                restarted = obj_new > obj

                def restart(_):
                    w_pl, b_pl, obj_pl = prox_step(w, b)
                    return w_pl, b_pl, obj_pl, jnp.float32(1.0)

                def accept(_):
                    return w_new, b_new, obj_new, t_next

                w_new, b_new, obj_new, t_next = jax.lax.cond(
                    restarted, restart, accept, None
                )

                # restart iterations don't count as convergence evidence
                # (cf. solver._make_fista_body: the fallback step's tiny
                # objective change is a momentum artifact, not a plateau)
                rel_new = jnp.where(
                    restarted, jnp.float32(jnp.inf),
                    jnp.abs(obj - obj_new) / jnp.maximum(jnp.abs(obj), 1e-30),
                )
                return (w_new, b_new, w, b, t_next, k + 1, obj_new, rel_new,
                        rel, rel_prev)

            return body

        if not dynamic:
            # honor a static feature_mask here too (same contract as the
            # dynamic path, just never refreshed): seeded zeros stay zero
            w_init = w_blk * fm_blk
            obj0 = objective(w_init, b_scalar)

            def cond(st):
                w, b, wp, bp, t, k, obj, rel, rel_prev, rel_prev2 = st
                # three consecutive sub-tol iterations (see solver.FistaState)
                return (k < max_iters) & (
                    jnp.maximum(jnp.maximum(rel, rel_prev), rel_prev2) > tol)

            st0 = (w_init, b_scalar, w_init, b_scalar, jnp.float32(1.0),
                   jnp.int32(0), obj0, jnp.float32(jnp.inf),
                   jnp.float32(jnp.inf), jnp.float32(jnp.inf))
            w, b, _, _, _, k, obj, rel, rel_p, rel_p2 = jax.lax.while_loop(
                cond, make_body(fm_blk), st0)
            return (w, b, obj, k,
                    jnp.maximum(jnp.maximum(rel, rel_p), rel_p2) <= tol)

        # ---- dynamic: segmented solve with in-loop gap screening ---------
        # theta-independent bound reductions over live samples (one sweep +
        # one 3-scalar psum, shared by every refresh — cf. screen_sharded)
        stat = jnp.stack([y_blk * sm_blk, sm_blk], axis=1)       # (n_loc, 2)
        dd = x_blk @ stat                                         # (m_loc, 2)
        d_sq = (x_blk * x_blk) @ sm_blk
        dd = jax.lax.psum(jnp.concatenate([dd, d_sq[:, None]], axis=1), data_axes)
        d_one_blk, d_y_blk, d_sq_blk = dd[:, 0], dd[:, 1], dd[:, 2]
        sums = jax.lax.psum(
            jnp.stack([jnp.sum(y_blk * sm_blk), jnp.sum(sm_blk)]), data_axes
        )
        one_y, n_tot = sums[0], sums[1]

        def gap_certificate(w, b):
            """(theta_blk, delta, gap) — sharded gap_theta_delta."""
            u = margins(w) + b
            xi = sm_blk * jnp.maximum(0.0, 1.0 - y_blk * u)
            p_obj = jax.lax.psum(0.5 * jnp.sum(xi * xi), data_axes) + (
                lam * jax.lax.psum(jnp.sum(jnp.abs(w)), "model")
            )

            def feas_body(alpha, _):
                corr = jax.lax.psum(x_blk @ (y_blk * alpha), data_axes)
                mx = jax.lax.pmax(jnp.max(jnp.abs(corr)), "model")
                alpha = alpha * jnp.minimum(1.0, lam / jnp.maximum(mx, 1e-30))
                ay = jax.lax.psum(alpha @ y_blk, data_axes)
                return sm_blk * jnp.maximum(0.0, alpha - ay / n_tot * y_blk), None

            alpha, _ = jax.lax.scan(feas_body, xi, None, length=n_feas_iters)
            corr = jax.lax.psum(x_blk @ (y_blk * alpha), data_axes)
            mx = jax.lax.pmax(jnp.max(jnp.abs(corr)), "model")
            alpha = alpha * jnp.minimum(1.0, lam / jnp.maximum(mx, 1e-30))
            stats = jax.lax.psum(
                jnp.stack([jnp.sum(alpha), jnp.sum(alpha * alpha), alpha @ y_blk]),
                data_axes,
            )
            gap = jnp.maximum(p_obj - (stats[0] - 0.5 * stats[1]), 0.0)
            # few-ulp floor against cancellation noise (see gap_theta_delta)
            gap = jnp.maximum(gap, 4.0 * jnp.finfo(jnp.float32).eps * jnp.abs(p_obj))
            eq_resid = jnp.abs(stats[2]) / jnp.sqrt(n_tot)
            delta = (jnp.sqrt(2.0 * gap) + 2.0 * eq_resid) / lam
            return alpha / lam, delta, gap

        def outer_cond(carry):
            st, *_ = carry
            return (st[5] < max_iters) & (
                jnp.maximum(jnp.maximum(st[7], st[8]), st[9]) > tol)

        def outer_body(carry):
            st, fm, kept, gaps, seg = carry
            k_stop = jnp.minimum(st[5] + screen_every, max_iters)

            def inner_cond(s_):
                return (s_[5] < k_stop) & (
                    jnp.maximum(jnp.maximum(s_[7], s_[8]), s_[9]) > tol)

            st = jax.lax.while_loop(inner_cond, make_body(fm), st)
            w, b = st[0], st[1]

            # refresh: certify the region at the current iterate, re-screen
            theta, delta, gap = gap_certificate(w, b)
            th_stats = jax.lax.psum(
                jnp.stack([jnp.sum(theta), theta @ y_blk, theta @ theta]),
                data_axes,
            )
            sh = _shared_from_stats(lam, lam, one_y, th_stats[0], th_stats[1],
                                    th_stats[2], n_tot, delta=delta)
            d_theta_blk = jax.lax.psum(x_blk @ (y_blk * theta), data_axes)
            red = FeatureReductions(d_theta=d_theta_blk, d_one=d_one_blk,
                                    d_y=d_y_blk, d_sq=d_sq_blk)
            # min of the VI cap and the GAP-sphere bound — see
            # solver.fista_solve_dynamic for the derivation
            bounds = jnp.minimum(
                screen_bounds_from_reductions(red, sh),
                jnp.abs(d_theta_blk)
                + jnp.sqrt(jnp.maximum(d_sq_blk, 0.0)) * delta,
            )
            new_fm = fm * (bounds >= tau).astype(jnp.float32)
            n_live = jax.lax.psum(jnp.sum(new_fm), "model")

            # zero dropped coords + momentum restart only when zeroing moved
            # the iterate (cf. fista_solve_dynamic)
            w_m = w * new_fm
            changed = jax.lax.psum(jnp.sum((w - w_m) * (w - w_m)), "model") > 0.0
            obj_m = objective(w_m, b)
            st_masked = (w_m, b, w_m, b, jnp.float32(1.0), st[5], obj_m,
                         jnp.float32(jnp.inf), jnp.float32(jnp.inf),
                         jnp.float32(jnp.inf))
            st = jax.tree_util.tree_map(
                lambda a_, b_: jnp.where(changed, a_, b_), st_masked, st
            )

            # clamp into the last slot: > n_seg refreshes are possible when
            # segments end early (see fista_solve_dynamic)
            slot = jnp.minimum(seg, n_seg - 1)
            kept = kept.at[slot].set(n_live.astype(jnp.int32))
            gaps = gaps.at[slot].set(gap)
            return (st, new_fm, kept, gaps, jnp.minimum(seg + 1, n_seg))

        obj0 = objective(w_blk * fm_blk, b_scalar)
        st0 = (w_blk * fm_blk, b_scalar, w_blk * fm_blk, b_scalar,
               jnp.float32(1.0), jnp.int32(0), obj0, jnp.float32(jnp.inf),
               jnp.float32(jnp.inf), jnp.float32(jnp.inf))
        carry0 = (st0, fm_blk, jnp.full((n_seg,), -1, jnp.int32),
                  jnp.full((n_seg,), jnp.inf, jnp.float32),
                  jnp.int32(0))
        st, fm, kept, gaps, seg = jax.lax.while_loop(outer_cond, outer_body, carry0)
        w, b, _, _, _, k, obj, rel, rel_p, rel_p2 = st
        return (w, b, obj, k,
                jnp.maximum(jnp.maximum(rel, rel_p), rel_p2) <= tol,
                fm > 0.5, kept, gaps, seg)

    if w0 is None:
        w0 = jnp.zeros((m,), jnp.float32)
    if b0 is None:
        b0 = jnp.mean(y)
    b0 = jnp.asarray(b0, jnp.float32)

    scalar_out = (P(), P(), P(), P())
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P("model", *data_axes), P(*data_axes), P(*data_axes),
                  P("model"), P("model"), P(), P()),
        out_specs=(P("model"), *scalar_out)
        if not dynamic
        else (P("model"), *scalar_out, P("model"), P(), P(), P()),
        check_rep=False,
    )
    out = fn(X, y, jnp.asarray(sample_mask, jnp.float32),
             jnp.asarray(feature_mask, jnp.float32), w0, b0,
             jnp.asarray(L if have_L else 0.0, jnp.float32))
    if not dynamic:
        w, b, obj, k, conv = out
        return FistaResult(w=w, b=b, obj=obj, n_iters=k, converged=conv)
    w, b, obj, k, conv, fm, kept, gaps, seg = out
    return DynamicFistaResult(
        w=w, b=b, obj=obj, n_iters=k, converged=conv, feature_mask=fm,
        kept_per_segment=kept, gap_per_segment=gaps, n_segments=seg,
    )
