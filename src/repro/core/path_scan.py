"""On-device regularization-path engine: the whole path as one XLA program.

Why a second engine
-------------------
``core/path.py::PathDriver`` (``engine="host"``) orchestrates the path from
Python: per step it screens, gathers the kept rows/columns into a bucketed
submatrix, solves, verifies sample rules at the solution, and certifies the
next region — paying a device↔host round trip, a dispatch, and (in gather
mode) a possible re-trace at every step. That is the right engine when the
*FLOPs* dominate (gather mode physically shrinks the solve to
``kept_features x kept_samples``) or when verified sample rules are in play
(the KKT re-admission loop is inherently host-side control flow).

On the bench-scale instances the opposite regime holds: solves converge in
tens of iterations and the path is *orchestration*-bound — profiles show the
host engine spending most of its wall clock blocked on transfers, eager
re-compiles of the per-step certificate, and per-solve Lipschitz power
iterations. This module is the engine for that regime (``engine="scan"``):

* the lambda grid is walked by a single jitted ``lax.scan`` whose carry is
  ``(w, b, theta, delta, lam_prev)`` — XLA aliases the carry buffers in
  place (donated, no copies), and nothing syncs to the host until the final
  stacked ``PathResult`` is pulled once at the end;
* each scan step rebuilds the paper's VI region from the carried anchor
  (``screening.shared_scalars_from_stats``), evaluates the feature bounds
  with the theta-independent reductions hoisted out of the loop (one sweep
  per step, paper Sec. 6.4), mask-mode solves with the fused two-sweep FISTA
  body (``solver.fista_run``, optionally Pallas-backed and/or dynamic), and
  gap-certifies the solution (``solver.gap_theta_delta``) to anchor the next
  step;
* the Lipschitz constant is estimated once for the full ``X`` and reused by
  every step — valid because masking rows/columns never increases
  ``sigma_max`` (see ``solver.lipschitz_estimate``); per-step re-estimation
  is available via ``exact_lipschitz=True``;
* :func:`svm_path_batched` is ``vmap`` of the same step over a batch of
  problems or lambda grids — one program solving B paths at once
  (hyperparameter sweeps, multi-tenant serving). Under ``vmap`` the
  solver's restart ``lax.cond`` lowers to a select (both branches run) and
  the while loops run until the *slowest* batch element converges; the
  throughput win is that every launch, sweep, and reduction is batched.

Trade-off in one line: gather mode shrinks FLOPs, scan mode kills
orchestration overhead — measure with ``benchmarks/bench_screening.py``
(the ``engines`` section of ``BENCH_screening.json``).

The scan engine deliberately supports the *feature*-axis reduction only
(the paper's a-priori-safe rule, plus the in-solver dynamic refresh).
Sample rules need the a-posteriori verification loop, which is host
control flow — use ``engine="host"`` for those.
"""

from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .dual import bias_at_lambda_max, lambda_max, theta_at_lambda_max
from .path import PathResult, default_lambda_grid
from .screening import (
    SAFE_TAU,
    FeatureReductions,
    screen_bounds_from_reductions,
    shared_scalars_from_stats,
)
from .solver import (
    _dynamic_run,
    _resolve_pallas,
    fista_run,
    gap_theta_delta,
    lipschitz_estimate,
)

__all__ = ["svm_path_scan", "svm_path_batched", "ScanPathOutputs"]


class ScanPathOutputs(NamedTuple):
    """Stacked device-side per-step outputs of the scan engine (leading T)."""

    w: jax.Array          # (T, m)
    b: jax.Array          # (T,)
    obj: jax.Array        # (T,)
    kept: jax.Array       # (T,) int32 — live features fed to the solver
    active: jax.Array     # (T,) int32 — nnz(w) at the solution
    n_iters: jax.Array    # (T,) int32
    converged: jax.Array  # (T,) bool
    gap: jax.Array        # (T,) duality gap certified at the accepted point
    delta: jax.Array      # (T,) theta-radius anchoring the next step


def _path_scan_program(
    X: jax.Array,
    y: jax.Array,
    lambdas: jax.Array,
    w0: jax.Array,
    b0: jax.Array,
    theta0: jax.Array,
    delta0: jax.Array,
    lam0: jax.Array,
    L: Optional[jax.Array],
    tau,
    tol,
    *,
    max_iters: int,
    screening: bool,
    dynamic: bool,
    screen_every: int,
    use_pallas: bool,
    exact_lipschitz: bool,
    n_feas_iters: int = 8,
) -> ScanPathOutputs:
    """The traced whole-path program (one ``lax.scan`` over the grid).

    Pure function of device values — jitted (and optionally vmapped) by the
    public wrappers. ``(w0, b0, theta0, delta0)`` seed the carry: an anchor
    primal/dual pair at ``lam0`` with ``||theta0 - theta*(lam0)|| <= delta0``
    (the closed form at ``lambda_max`` in the standard entry points).
    """
    m, n = X.shape
    dt = X.dtype
    tau = jnp.asarray(tau, dt)
    lambdas = jnp.asarray(lambdas, dt)

    if L is None:
        L = lipschitz_estimate(X)
    L = jnp.maximum(L * 1.01, 1e-12)
    inv_L = 1.0 / L

    # theta-independent screen reductions, hoisted out of the scan: per step
    # only the O(mn) ``X @ (y * theta)`` sweep remains (paper Sec. 6.4).
    ones = jnp.ones((n,), dt)
    d_one = X @ y          # fhat_j^T 1
    d_y = X @ ones         # fhat_j^T y
    d_sq = jnp.sum(X * X, axis=1)
    one_y = jnp.sum(y)
    n_tot = jnp.asarray(float(n), dt)

    def step(carry, lam):
        w, b, theta, delta, lam_prev = carry

        # -- sequential screen from the carried anchor ---------------------
        if screening:
            sh = shared_scalars_from_stats(
                lam_prev, lam, one_y=one_y,
                theta_dot_one=jnp.sum(theta), theta_dot_y=theta @ y,
                theta_sq=theta @ theta, n_tot=n_tot, delta=delta,
            )
            red = FeatureReductions(
                d_theta=X @ (y * theta), d_one=d_one, d_y=d_y, d_sq=d_sq
            )
            bounds = screen_bounds_from_reductions(red, sh)
            fmask = (bounds >= tau).astype(dt)
        else:
            fmask = jnp.ones((m,), dt)

        # -- mask-mode solve on the live features --------------------------
        w_init = w * fmask
        if exact_lipschitz:
            L_k = jnp.maximum(
                lipschitz_estimate(X * fmask[:, None]) * 1.01, 1e-12
            )
            inv_Lk = 1.0 / L_k
        else:
            inv_Lk = inv_L
        if dynamic:
            res = _dynamic_run(
                X, y, lam, w_init, b, inv_Lk, None, fmask,
                max_iters, tol, screen_every, tau, 4, use_pallas,
            )
        else:
            res = fista_run(
                X, y, lam, w_init, b, inv_Lk, None, fmask,
                max_iters, tol, use_pallas,
            )

        # -- gap-certify the accepted point: anchor for the next step ------
        theta2, delta2, gap = gap_theta_delta(
            X, y, res.w, res.b, lam, None, n_feas_iters=n_feas_iters
        )

        out = ScanPathOutputs(
            w=res.w, b=res.b, obj=res.obj,
            kept=jnp.sum(fmask).astype(jnp.int32),
            active=jnp.sum(jnp.abs(res.w) > 1e-10).astype(jnp.int32),
            n_iters=jnp.asarray(res.n_iters, jnp.int32),
            converged=res.converged,
            gap=gap, delta=delta2,
        )
        return (res.w, res.b, theta2, delta2, lam), out

    carry0 = (w0, jnp.asarray(b0, dt), theta0, jnp.asarray(delta0, dt),
              jnp.asarray(lam0, dt))
    _, outs = jax.lax.scan(step, carry0, lambdas)
    return outs


def _engine_jit(static_kw: tuple, batched: Optional[str] = None):
    """Build (and cache) the jitted single/vmapped engine for static opts.

    ``batched``: None (single path), ``"grids"`` (shared problem, batched
    lambda grids — X/y/anchors broadcast by vmap, not materialized), or
    ``"problems"`` (independent problems, everything batched). The anchor
    carry (``w0/b0/theta0/delta0``) is donated in the single-path engine so
    XLA may alias it straight into the scan carry — skipped on backends
    without donation support (CPU) to avoid spurious warnings.
    """
    key = (static_kw, batched)
    fn = _ENGINE_CACHE.get(key)
    if fn is not None:
        return fn
    raw = partial(_path_scan_program, **dict(static_kw))
    # arg order: (X, y, lambdas, w0, b0, theta0, delta0, lam0, L, tau, tol)
    if batched == "grids":
        raw = jax.vmap(raw, in_axes=(None, None, 0, None, None, None, None,
                                     None, None, None, None))
    elif batched == "problems":
        raw = jax.vmap(raw, in_axes=(0, 0, 0, 0, 0, 0, None, 0, None, None,
                                     None))
    donate = ()
    if batched is None and jax.default_backend() != "cpu":
        donate = (3, 4, 5, 6)
    fn = jax.jit(raw, donate_argnums=donate)
    _ENGINE_CACHE[key] = fn
    return fn


_ENGINE_CACHE: dict = {}


def _validate_grid(lambdas) -> np.ndarray:
    lambdas = np.asarray(lambdas, dtype=np.float64)
    if lambdas.size == 0:
        raise ValueError("empty lambda grid")
    if not np.all(np.isfinite(lambdas)) or np.any(lambdas <= 0):
        raise ValueError(f"lambda grid must be finite and positive: {lambdas}")
    if np.any(np.diff(lambdas) >= 0):
        raise ValueError(
            "lambda grid must be strictly decreasing (screening regions "
            f"certify theta*(lam2) only along a decreasing path): {lambdas}"
        )
    return lambdas


def _static_opts(max_iters, screening, dynamic, screen_every, use_pallas,
                 exact_lipschitz) -> tuple:
    return (
        ("max_iters", int(max_iters)),
        ("screening", bool(screening)),
        ("dynamic", bool(dynamic)),
        ("screen_every", max(int(screen_every), 1)),
        ("use_pallas", _resolve_pallas(use_pallas)),
        ("exact_lipschitz", bool(exact_lipschitz)),
    )


def _to_path_result(lambdas, outs: ScanPathOutputs, lam_max_val, wall_s,
                    screening, static_kw) -> PathResult:
    T = len(lambdas)
    per_step = np.full((T,), wall_s / max(T, 1), dtype=np.float64)
    return PathResult(
        lambdas=np.asarray(lambdas, np.float64),
        weights=np.asarray(outs.w, np.float64),
        biases=np.asarray(outs.b, np.float64),
        objectives=np.asarray(outs.obj, np.float64),
        kept=np.asarray(outs.kept, np.int64),
        active=np.asarray(outs.active, np.int64),
        solver_iters=np.asarray(outs.n_iters, np.int64),
        # the engine never syncs mid-path, so per-step walls are not
        # observable — report the uniform share of the (blocked) total and
        # keep the exact total in extras.
        wall_times=per_step,
        screen_times=np.zeros((T,), np.float64),
        screened=bool(screening),
        kept_samples=np.zeros((T,), np.int64),
        verify_rounds=np.zeros((T,), np.int64),
        rules=("feature_vi",) if screening else (),
        extras={
            "engine": "scan",
            "lam_max": float(lam_max_val),
            "total_seconds": float(wall_s),
            "gaps": np.asarray(outs.gap, np.float64),
            "deltas": np.asarray(outs.delta, np.float64),
            "converged": np.asarray(outs.converged, bool),
            "options": dict(static_kw),
        },
    )


def svm_path_scan(
    X: jax.Array,
    y: jax.Array,
    lambdas: Optional[Sequence[float]] = None,
    n_lambdas: int = 10,
    lam_min_ratio: float = 0.1,
    *,
    screening: bool = True,
    tau: float = SAFE_TAU,
    tol: float = 1e-9,
    max_iters: int = 4000,
    dynamic: bool = False,
    screen_every: int = 50,
    use_pallas: Optional[bool] = None,
    exact_lipschitz: bool = False,
) -> PathResult:
    """Solve the feature-screened path as ONE jitted XLA program.

    Semantics match ``svm_path(..., reduce="mask", rules="feature_vi")``:
    every step screens against the previous step's gap-certified anchor,
    solves under the live mask to ``tol``, and certifies its own anchor —
    but with zero host involvement between the first dispatch and the final
    transfer. See the module docstring for when to prefer which engine.

    ``use_pallas`` routes the FISTA hot-loop sweeps through the fused Pallas
    kernels (None = env/backend policy, ``kernels/ops.fista_use_pallas``);
    ``dynamic=True`` swaps each step's solve for the segmented
    ``screen_every``-interval in-solver re-screen; ``exact_lipschitz=True``
    re-runs the power iteration per step on the masked matrix instead of
    reusing the full-X upper bound.
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    m, n = X.shape

    lam_max_val = float(lambda_max(X, y))
    if lambdas is None:
        lambdas = default_lambda_grid(lam_max_val, n_lambdas, lam_min_ratio)
    lambdas = _validate_grid(lambdas)

    # anchor at lambda_max: closed form is exact => delta = 0 (core/dual.py)
    w0 = jnp.zeros((m,), X.dtype)
    b0 = bias_at_lambda_max(y)
    theta0 = theta_at_lambda_max(y, jnp.asarray(lam_max_val, X.dtype))
    delta0 = jnp.asarray(0.0, X.dtype)

    static_kw = _static_opts(max_iters, screening, dynamic, screen_every,
                             use_pallas, exact_lipschitz)
    engine = _engine_jit(static_kw, batched=None)
    t0 = time.perf_counter()
    outs = engine(X, y, jnp.asarray(lambdas, X.dtype), w0, b0, theta0,
                  delta0, jnp.asarray(lam_max_val, X.dtype), None,
                  float(tau), float(tol))
    outs = jax.block_until_ready(outs)
    wall_s = time.perf_counter() - t0
    return _to_path_result(lambdas, outs, lam_max_val, wall_s, screening,
                           static_kw)


def svm_path_batched(
    X: jax.Array,
    y: jax.Array,
    lambdas: Optional[np.ndarray] = None,
    n_lambdas: int = 10,
    lam_min_ratio: float = 0.1,
    *,
    screening: bool = True,
    tau: float = SAFE_TAU,
    tol: float = 1e-9,
    max_iters: int = 4000,
    dynamic: bool = False,
    screen_every: int = 50,
    use_pallas: Optional[bool] = None,
    exact_lipschitz: bool = False,
) -> list[PathResult]:
    """``vmap`` of the scan engine over a batch of problems or grids.

    Two batching modes, selected by the rank of ``X``:

    * ``X (m, n)``, ``lambdas (B, T)`` — one dataset, B lambda grids
      (hyperparameter sweep / cross-validation over grids);
    * ``X (B, m, n)``, ``y (B, n)`` — B independent problems
      (multi-tenant serving), each on its own grid (``lambdas (B, T)``) or
      on its own default geometric grid anchored at its own
      ``lambda_max`` when ``lambdas`` is None.

    Executes as ONE jitted program: every sweep, reduction, and solver
    launch is batched, so B paths cost roughly one path's worth of
    launches. The usual vmap caveats apply — the while loops run until the
    slowest batch element converges and the restart ``lax.cond`` becomes a
    select — so wall clock per path is bounded by the hardest problem in
    the batch. The program is shard-transparent: inputs placed on a mesh
    (e.g. batch-sharded ``X``) keep their sharding through jit, which is
    how the sharded-solver mesh serves batched paths.

    Returns one :class:`~repro.core.path.PathResult` per batch element
    (shared total wall clock in ``extras["total_seconds"]``, batch size in
    ``extras["batch"]``).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    static_kw = _static_opts(max_iters, screening, dynamic, screen_every,
                             use_pallas, exact_lipschitz)
    if X.ndim == 2:
        # one problem, B grids — X/y/anchors stay unbatched (vmap broadcasts)
        if lambdas is None:
            raise ValueError(
                "grid-batched mode (2-D X) needs an explicit (B, T) lambdas"
            )
        grids = np.asarray(lambdas, np.float64)
        if grids.ndim != 2:
            raise ValueError(f"lambdas must be (B, T), got {grids.shape}")
        B = grids.shape[0]
        for g in grids:
            _validate_grid(g)
        m = X.shape[0]
        lam_max_val = float(lambda_max(X, y))
        lam_maxs = np.full((B,), lam_max_val)
        engine = _engine_jit(static_kw, batched="grids")
        args = (
            X, y, jnp.asarray(grids, X.dtype), jnp.zeros((m,), X.dtype),
            bias_at_lambda_max(y),
            theta_at_lambda_max(y, jnp.asarray(lam_max_val, X.dtype)),
            jnp.asarray(0.0, X.dtype), jnp.asarray(lam_max_val, X.dtype),
        )
    elif X.ndim == 3:
        B, m, _ = X.shape
        if y.ndim != 2 or y.shape[0] != B:
            raise ValueError(f"y must be (B, n) for 3-D X, got {y.shape}")
        lam_maxs = np.asarray(jax.vmap(lambda_max)(X, y), np.float64)
        if lambdas is None:
            ratios = np.geomspace(1.0, lam_min_ratio, n_lambdas)
            grids = lam_maxs[:, None] * ratios[None, :]
        else:
            grids = np.asarray(lambdas, np.float64)
            if grids.ndim == 1:
                grids = np.broadcast_to(grids, (B, grids.shape[0])).copy()
        for g in grids:
            _validate_grid(g)
        lam_maxs_j = jnp.asarray(lam_maxs, X.dtype)
        engine = _engine_jit(static_kw, batched="problems")
        args = (
            X, y, jnp.asarray(grids, X.dtype), jnp.zeros((B, m), X.dtype),
            jax.vmap(bias_at_lambda_max)(y),
            jax.vmap(theta_at_lambda_max)(y, lam_maxs_j),
            jnp.asarray(0.0, X.dtype), lam_maxs_j,
        )
    else:
        raise ValueError(f"X must be (m, n) or (B, m, n), got {X.shape}")

    t0 = time.perf_counter()
    outs = engine(*args, None, float(tau), float(tol))
    outs = jax.block_until_ready(outs)
    wall_s = time.perf_counter() - t0

    results = []
    for i in range(B):
        sub = ScanPathOutputs(*(np.asarray(v)[i] for v in outs))
        r = _to_path_result(grids[i], sub, float(lam_maxs[i]), wall_s / B,
                            screening, static_kw)
        r.extras["total_seconds"] = float(wall_s)
        r.extras["batch"] = B
        r.extras["batch_index"] = i
        results.append(r)
    return results
