"""On-device regularization-path engine: the whole path as one XLA program.

Why a second engine
-------------------
``core/path.py::PathDriver`` (``engine="host"``) orchestrates the path from
Python: per step it screens, gathers the kept rows/columns into a bucketed
submatrix, solves, verifies sample rules at the solution, and certifies the
next region — paying a device↔host round trip, a dispatch, and (in gather
mode) a possible re-trace at every step. That is the right engine when
verified sample rules are in play (the KKT re-admission loop is inherently
host-side control flow) or when the matrix is too large for a single device.

On the bench-scale instances the opposite regime holds: solves converge in
tens of iterations and the path is *orchestration*-bound — profiles show the
host engine spending most of its wall clock blocked on transfers, eager
re-compiles of the per-step certificate, and per-solve Lipschitz power
iterations. This module is the engine for that regime (``engine="scan"``):

* the lambda grid is walked by a single jitted ``lax.scan`` whose carry is
  ``(w, b, theta, delta, lam_prev, keep_mask)`` — XLA aliases the carry
  buffers in place (donated, no copies), and nothing syncs to the host until
  the final stacked ``PathResult`` is pulled once at the end;
* each scan step rebuilds the rule stack's screening region(s) from the
  carried anchor (``screening.AnchorStats`` + the pure rule programs of
  ``rules/programs.py``), evaluates the feature bounds with the
  theta-independent reductions hoisted out of the loop (one sweep
  per step, paper Sec. 6.4), solves with the fused two-sweep FISTA body
  (``solver.fista_run``, optionally Pallas-backed and/or dynamic), and
  gap-certifies the solution (``solver.gap_theta_delta``, reusing the
  solver's carried margins) to anchor the next step;
* the Lipschitz constant is estimated once for the full ``X`` and reused by
  every step — valid because masking rows/columns never increases
  ``sigma_max`` (see ``solver.lipschitz_estimate``); per-step re-estimation
  is available via ``exact_lipschitz=True``;
* :func:`svm_path_batched` is ``vmap`` of the same step over a batch of
  problems or lambda grids — one program solving B paths at once;
* :func:`svm_path_scan_sharded` wraps the *same* program in ``shard_map`` on
  the ``svm_mesh`` (features x samples), so the whole path also runs as one
  sharded XLA program — the solver/certificate reductions bind to mesh
  collectives through ``solver.Collectives``
  (``distributed.mesh_collectives``), not a forked implementation.

Reductions inside the scan step (``reduce=``)
---------------------------------------------
``"mask"``     solves the full-shape problem with screened feature rows
               frozen at zero: static shapes, zero data movement, but every
               FISTA sweep still pays O(m·n) FLOPs no matter how many
               features screening removed.
``"compact"``  physically gathers the live features into a fixed-capacity
               padded buffer *inside* the jitted step: the keep mask is
               compacted with a ``jnp.cumsum`` scatter into a static
               ``(cap, n)`` submatrix, the fused FISTA body runs on it, and
               the solution is scattered back before the anchor is
               certified — so a step that keeps ``k`` of ``m`` features
               sweeps ``O(cap·n)``, ``cap`` the smallest bucket holding
               ``k``. The capacity comes from a small static bucket schedule
               (à la ``path.py::_bucket``; one ``lax.switch`` branch per
               bucket, so jit compiles a handful of solver bodies, not one
               per kept-count), and a kept-count overflowing the largest
               bucket falls back to the mask-mode branch — never wrong,
               only less reduced. The carry holds each step's certified
               keep mask (resurrection tracking): features re-entering the
               keep set are counted per step (``extras["resurrected"]``),
               and the buffer is sized to the certified keeps — which by
               construction contain every feature allowed to be nonzero at
               the step's lambda, warm-start support included.

Rule of thumb across the three reductions (host ``gather`` + scan
``mask``/``compact``): **gather** wins when sample rules shrink the n-axis
too or a verified-exact reduced problem is wanted (host round trips buy
multiplicative kept_m x kept_n FLOPs); **mask** wins when screening is weak
(kept ~ m, compaction would only add gather traffic) or when sharded
(compaction needs local row indices); **compact** wins whenever screening
certifies a small active set — the paper's whole value proposition —
keeping the path single-program *and* FLOP-proportional to what screening
certifies. Compaction composes with batching too: batched paths share ONE
capacity per step, picked by the scalar batch-max kept count, so the bucket
switch stays a real switch under ``vmap`` instead of lowering to a
run-every-branch select (``_batched_path_scan_program``; one overflowing
element demotes that step to mask for the whole sub-batch). Measure with
``benchmarks/bench_screening.py`` (``BENCH_screening.json["engines"]``).

Rule stacks inside the jitted step (``rules=``)
-----------------------------------------------
The scan engines accept any stack of a-priori-safe *feature* rules that
ship a jittable :class:`~repro.core.rules.programs.RuleProgram` —
``"feature_vi"`` (the paper's rule), ``"edpp"`` (projection-enhanced,
strictly tighter at equal sweep cost), ``"dvi"`` (two-anchor min
composition; the scan carry grows the step-before-last anchor), or a list
of them (bounds AND-ed elementwise inside the step). The spec is resolved
at dispatch (``rules/programs.resolve_programs``) so unlowerable specs
fail loudly before tracing. Sample rules need the a-posteriori
verification loop, which is host control flow — use ``engine="host"`` for
those (including ``"sifs"``).
"""

from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace
from repro.obs.path_trace import build_path_trace

from .dual import bias_at_lambda_max, lambda_max, theta_at_lambda_max
# _validate_grid shared with the host driver: a grid-validation change
# applied to one engine must never leave the other accepting what the
# first rejects
from .path import PathDriver, PathResult, _validate_grid, default_lambda_grid
from .rules.programs import (
    PROGRAMS,
    resolve_programs,
    stack_bounds,
    stack_needs_history,
)
from .screening import (
    SAFE_TAU,
    AnchorStats,
    FixedStats,
)
from .solver import (
    HEALTH_SCREEN_REFUSED,
    LOCAL,
    Collectives,
    _dynamic_run,
    _resolve_guards,
    _resolve_pallas,
    fista_run,
    gap_theta_delta,
    lipschitz_estimate,
)

__all__ = [
    "svm_path_scan",
    "svm_path_batched",
    "svm_path_scan_sharded",
    "ScanPathOutputs",
    "compact_caps",
    "compact_caps_batched",
    "engine_cache_info",
]


class ScanPathOutputs(NamedTuple):
    """Stacked device-side per-step outputs of the scan engine (leading T)."""

    w: jax.Array           # (T, m)
    b: jax.Array           # (T,)
    obj: jax.Array         # (T,)
    kept: jax.Array        # (T,) int32 — live features fed to the solver
    active: jax.Array      # (T,) int32 — nnz(w) at the solution
    n_iters: jax.Array     # (T,) int32
    converged: jax.Array   # (T,) bool
    gap: jax.Array         # (T,) duality gap certified at the accepted point
    delta: jax.Array       # (T,) theta-radius anchoring the next step
    fmask: jax.Array       # (T, m) bool — the certified keep mask per step
    cap: jax.Array         # (T,) int32 — compact buffer capacity (m = mask)
    resurrected: jax.Array  # (T,) int32 — keeps the previous mask had dropped
    # (T,) int32 guard telemetry: low bits = solver rollback trips,
    # HEALTH_SCREEN_REFUSED flags a step that screened from a refused
    # (non-finite) certificate and fail-safed to keep-all. 0 = clean.
    health: jax.Array


def compact_caps(m: int, max_buckets: int = 4, min_cap: int = 32) -> tuple:
    """Static bucket schedule for the compacted active-set buffer.

    Powers of two up to ``m // 2`` (beyond that the gather/scatter overhead
    cancels the FLOP win — the mask fallback is cheaper), keeping the
    largest ``max_buckets`` so the jitted step compiles a bounded number of
    ``lax.switch`` branches. Empty for small ``m`` — compact mode then
    degenerates to mask mode.
    """
    caps = []
    c = min_cap
    while c <= m // 2:
        caps.append(c)
        c *= 2
    return tuple(caps[-max_buckets:])


def compact_caps_batched(m: int, kept_counts=None, max_buckets: int = 4,
                         min_cap: int = 32):
    """Shared-cap schedule for a *batch* of compacting path elements.

    Under ``vmap`` the per-element bucket ``lax.switch`` degenerates to a
    select (a batched predicate runs every branch), so batched compaction
    shares ONE capacity per step across the whole sub-batch: the ladder is
    the same as :func:`compact_caps`, but the branch index is a *scalar* —
    the batch-max kept count over live elements — so exactly one branch
    executes. With ``kept_counts`` given (observed or predicted per-element
    keeps), returns the shared cap that sub-batch would select (``m`` means
    the mask-mode overflow branch); with ``kept_counts=None``, returns the
    ladder itself. The path server uses the ``kept_counts`` form to pick the
    ``cap_bucket`` component of its program-cache key.
    """
    caps = compact_caps(m, max_buckets=max_buckets, min_cap=min_cap)
    if kept_counts is None:
        return caps
    ks = np.asarray(kept_counts)
    k = int(ks.max()) if ks.size else 0
    for c in caps:
        if k <= c:
            return int(c)
    return int(m)


def _batched_statics(X, y, sm, shared_x: bool):
    """Theta-independent screen reductions, per batch element.

    The sample-masked generalization of the hoisted reductions in
    ``_path_scan_program``: with a 0/1 ``sm`` the reductions are those of
    the problem with masked-out columns removed (padded columns of a
    zero-padded ``X`` contribute nothing), and ``n_tot`` is the live-sample
    count — never the padded width.
    """
    def one(Xe, ye, sme):
        d_one = Xe @ ye
        d_y = Xe @ sme
        d_sq = (Xe * Xe) @ sme
        return (d_one, d_y, d_sq, jnp.sum(ye * sme), jnp.sum(sme))

    return one(X, y, sm) if shared_x else jax.vmap(one)(X, y, sm)


def _batched_path_step(
    X, y, sm, statics, inv_L, tau, tol, carry, lam, act,
    *,
    caps: tuple,
    shared_x: bool,
    max_iters: int,
    screening: bool,
    dynamic: bool,
    screen_every: int,
    use_pallas: bool,
    exact_lipschitz: bool,
    rules: tuple = ("feature_vi",),
    n_feas_iters: int = 8,
    guards: bool = False,
):
    """One batched lambda step: screen -> shared-cap solve -> certify.

    The batched counterpart of ``_path_scan_program.step`` — same screen /
    solve / certify math per element (vmapped), but the compact bucket
    schedule is lifted to the batch level: the ``lax.switch`` index is the
    scalar batch-max kept count over live elements (``act``), so every
    element of the sub-batch compacts into the same static ``(cap, n)``
    buffer and exactly one branch runs. One element overflowing the largest
    bucket demotes the whole step to the mask branch — the price of
    shared-cap composition; never wrong, only less reduced.

    Shapes: ``lam``/``act``/``inv_L`` are ``(B,)``; carry leaves lead with
    B; ``X``/``y``/``sm``/``statics`` are shared (``shared_x=True``) or lead
    with B. ``sm`` is a 0/1 sample mask (live columns) so padded elements
    solve their true, unpadded problem. Returns ``(carry', out)`` with every
    ``ScanPathOutputs`` leaf leading with B — usable directly as a scan body
    (the full-path program below) or as a standalone jitted step (the path
    server).
    """
    m, n = X.shape[-2], X.shape[-1]
    dt = X.dtype
    B = lam.shape[0]
    ax = None if shared_x else 0
    progs = tuple(PROGRAMS[nm] for nm in rules) if screening else ()
    needs_hist = stack_needs_history(progs)
    if needs_hist:
        (w, b, theta, delta, lam_prev, fmask_prev,
         lam_old, theta_old, delta_old) = carry
    else:
        w, b, theta, delta, lam_prev, fmask_prev = carry

    def screen_one(Xe, ye, st, th, de, lp, la, *hist):
        d_one, d_y, d_sq, one_y, n_tot = st
        fixed = FixedStats(d_one=d_one, d_y=d_y, d_sq=d_sq, one_y=one_y,
                           n_tot=n_tot)

        def anchor(lam_a, th_a, de_a):
            return AnchorStats(
                lam=lam_a, delta=de_a, theta_dot_one=jnp.sum(th_a),
                theta_dot_y=th_a @ ye, theta_sq=th_a @ th_a,
                d_theta=Xe @ (ye * th_a),
            )

        anchors = (anchor(lp, th, de),)
        if hist:
            l0, th0, de0 = hist
            anchors = (anchor(l0, th0, de0),) + anchors
        return stack_bounds(progs, la, anchors, fixed)

    # fail-safe screening: a carry anchored by a refused certificate
    # (gap_theta_delta collapses delta to inf when any component is
    # non-finite) must keep EVERY feature this step — screening degrades to
    # "no speedup", never to a wrong discard. The keep comparison is
    # NaN-safe too (~(b < tau) keeps non-finite bounds), and the refusal is
    # recorded in the step's health word below.
    anchor_ok = jnp.isfinite(delta)
    if needs_hist:
        anchor_ok = anchor_ok & jnp.isfinite(delta_old)
    with jax.named_scope("svm_path_batched/screen"):
        if screening and needs_hist:
            bounds = jax.vmap(
                screen_one, in_axes=(ax, ax, ax, 0, 0, 0, 0, 0, 0, 0))(
                X, y, statics, theta, delta, lam_prev, lam,
                lam_old, theta_old, delta_old)
            keep = (~(bounds < tau)) | (~anchor_ok)[:, None]
        elif screening:
            bounds = jax.vmap(screen_one, in_axes=(ax, ax, ax, 0, 0, 0, 0))(
                X, y, statics, theta, delta, lam_prev, lam)
            keep = (~(bounds < tau)) | (~anchor_ok)[:, None]
        else:
            keep = jnp.ones((B, m), bool)
        fmask = keep.astype(dt)
    resurrected = jnp.sum(keep & (fmask_prev < 0.5), axis=1).astype(jnp.int32)
    kept_ct = jnp.sum(fmask, axis=1).astype(jnp.int32)

    def solve(Xs, ye, sme, la, ws, bs, fms, inv_Ls, vm):
        if dynamic:
            return _dynamic_run(
                Xs, ye, la, ws, bs, inv_Ls, sme, fms,
                max_iters, tol, screen_every, tau, 4, use_pallas,
                valid_m=vm, guards=guards,
            )
        return fista_run(
            Xs, ye, la, ws, bs, inv_Ls, sme, fms,
            max_iters, tol, use_pallas, valid_m=vm, guards=guards,
        )

    def inv_L_for(Xs, inv_Ls):
        if exact_lipschitz:
            return 1.0 / jnp.maximum(lipschitz_estimate(Xs) * 1.01, 1e-12)
        return inv_Ls

    def mask_one(Xe, ye, sme, la, inv_Ls, w_, b_, fmask_):
        res = solve(Xe, ye, sme, la, w_ * fmask_, b_, fmask_,
                    inv_L_for(Xe * fmask_[:, None], inv_Ls), None)
        return (res.w, res.b, res.obj, jnp.asarray(res.n_iters, jnp.int32),
                res.converged, res.u, jnp.asarray(res.health, jnp.int32))

    def make_compact_one(cap):
        def one(Xe, ye, sme, la, inv_Ls, w_, b_, fmask_):
            # same cumsum compaction as the single-path compact branch
            pos = jnp.cumsum(fmask_.astype(jnp.int32)) - 1
            slot = jnp.where(fmask_ > 0.5, pos, cap)
            sel = jnp.full((cap,), m, jnp.int32).at[slot].set(
                jnp.arange(m, dtype=jnp.int32), mode="drop")
            validf = (sel < m).astype(dt)
            selc = jnp.minimum(sel, m - 1)
            Xc = jnp.take(Xe, selc, axis=0) * validf[:, None]
            w0_c = jnp.take(w_, selc) * validf
            vcount = jnp.sum(fmask_).astype(jnp.int32)
            res = solve(Xc, ye, sme, la, w0_c, b_, validf,
                        inv_L_for(Xc, inv_Ls), vcount)
            w_full = jnp.zeros((m,), dt).at[selc].add(res.w * validf)
            return (w_full, res.b, res.obj,
                    jnp.asarray(res.n_iters, jnp.int32), res.converged,
                    res.u, jnp.asarray(res.health, jnp.int32))
        return one

    def batch_branch(elem):
        f = jax.vmap(elem, in_axes=(ax, ax, ax, 0, 0, 0, 0, 0))
        return lambda args: f(X, y, sm, lam, inv_L, *args)

    with jax.named_scope("svm_path_batched/solve"):
        if caps:
            caps_arr = jnp.asarray(caps, jnp.int32)
            # the switch index is a SCALAR (batch-max keeps over live
            # elements) — a batched predicate would lower the switch to a
            # select and run every branch, forfeiting the compact win
            max_kept = jnp.max(jnp.where(act, kept_ct, 0))
            idx = jnp.sum(max_kept > caps_arr)
            branches = [batch_branch(make_compact_one(c)) for c in caps]
            branches.append(batch_branch(mask_one))  # shared overflow
            w2, b2, obj, n_it, conv, u_fin, health = jax.lax.switch(
                idx, branches, (w, b, fmask))
            cap_used = jnp.full(
                (B,), jnp.asarray((*caps, m), jnp.int32)[idx])
        else:
            w2, b2, obj, n_it, conv, u_fin, health = batch_branch(mask_one)(
                (w, b, fmask))
            cap_used = jnp.full((B,), m, jnp.int32)

    def certify_one(Xe, ye, sme, w_, b_, la, u_):
        return gap_theta_delta(Xe, ye, w_, b_, la, sme,
                               n_feas_iters=n_feas_iters, u=u_)

    with jax.named_scope("svm_path_batched/certify"):
        theta2, delta2, gap = jax.vmap(
            certify_one, in_axes=(ax, ax, ax, 0, 0, 0, 0))(
            X, y, sm, w2, b2, lam, u_fin)

    out = ScanPathOutputs(
        w=w2, b=b2, obj=obj, kept=kept_ct,
        active=jnp.sum(jnp.abs(w2) > 1e-10, axis=1).astype(jnp.int32),
        n_iters=n_it, converged=conv, gap=gap, delta=delta2,
        fmask=keep, cap=cap_used, resurrected=resurrected,
        health=health | jnp.where(
            anchor_ok, 0, HEALTH_SCREEN_REFUSED).astype(jnp.int32),
    )
    new_carry = (w2, b2, theta2, delta2, lam, fmask)
    if needs_hist:
        # two-anchor programs (dvi) carry the step-before-last anchor too
        new_carry = new_carry + (lam_prev, theta, delta)
    return new_carry, out


def _batched_path_scan_program(
    X: jax.Array,
    y: jax.Array,
    sm: Optional[jax.Array],
    lambdas: jax.Array,
    w0: jax.Array,
    b0: jax.Array,
    theta0: jax.Array,
    delta0: jax.Array,
    lam0: jax.Array,
    L: Optional[jax.Array],
    tau,
    tol,
    *,
    max_iters: int,
    screening: bool,
    dynamic: bool,
    screen_every: int,
    use_pallas: bool,
    exact_lipschitz: bool,
    reduce: str = "compact",
    rules: tuple = ("feature_vi",),
    shared_x: bool = False,
    n_feas_iters: int = 8,
    guards: bool = False,
) -> ScanPathOutputs:
    """B whole paths as one program, compaction composed with batching.

    Structure matters here: ``vmap(_path_scan_program)`` batches the bucket
    switch's predicate, which lowers the switch to a select — every branch
    executes and compact mode pays mask-mode FLOPs plus gather traffic.
    This program inverts the nesting: ``lax.scan`` over the T grid steps
    stays OUTER, the per-element work is vmapped INNER, and each step picks
    one shared compact capacity from the scalar batch-max kept count
    (:func:`_batched_path_step`). Grids must share T (ragged grids are the
    path server's job, which drives the same step one lambda at a time).

    ``shared_x``: one dataset, B grids (``X (m, n)``) vs B problems
    (``X (B, m, n)``). Anchors broadcast to B if given unbatched. ``sm`` is
    an optional 0/1 live-column mask per element — zero-padded problems
    solve their true geometry. Outputs lead with ``(B, T)``.
    """
    m, n = X.shape[-2], X.shape[-1]
    dt = X.dtype
    lambdas = jnp.asarray(lambdas, dt)
    B, _ = lambdas.shape
    tau = jnp.asarray(tau, dt)
    caps = compact_caps(m) if reduce == "compact" else ()

    if sm is None:
        sm = jnp.ones((n,), dt) if shared_x else jnp.ones((B, n), dt)
    if L is None:
        L = lipschitz_estimate(X) if shared_x else jax.vmap(
            lipschitz_estimate)(X)
    inv_L = 1.0 / jnp.maximum(
        jnp.broadcast_to(jnp.asarray(L, dt), (B,)) * 1.01, 1e-12)

    statics = _batched_statics(X, y, sm, shared_x)
    act = jnp.ones((B,), bool)
    step_kw = dict(
        caps=caps, shared_x=shared_x, max_iters=max_iters,
        screening=screening, dynamic=dynamic, screen_every=screen_every,
        use_pallas=use_pallas, exact_lipschitz=exact_lipschitz,
        rules=rules, n_feas_iters=n_feas_iters, guards=guards,
    )

    def step(carry, lam):
        return _batched_path_step(X, y, sm, statics, inv_L, tau, tol,
                                  carry, lam, act, **step_kw)

    carry0 = (
        jnp.broadcast_to(jnp.asarray(w0, dt), (B, m)),
        jnp.broadcast_to(jnp.asarray(b0, dt), (B,)),
        jnp.broadcast_to(jnp.asarray(theta0, dt), (B, n)),
        jnp.broadcast_to(jnp.asarray(delta0, dt), (B,)),
        jnp.broadcast_to(jnp.asarray(lam0, dt), (B,)),
        jnp.ones((B, m), dt),
    )
    progs = tuple(PROGRAMS[nm] for nm in rules) if screening else ()
    if stack_needs_history(progs):
        # old anchor seeded as a copy of the initial anchor: step 1's
        # two-anchor bound degenerates to the single-anchor bound, matching
        # the host DVIRule which starts with no stored anchor
        carry0 = carry0 + (carry0[4], carry0[2], carry0[3])
    _, outs = jax.lax.scan(step, carry0, jnp.swapaxes(lambdas, 0, 1))
    # scan stacks along T; callers want per-element (B, T, ...) blocks
    return jax.tree_util.tree_map(lambda a: jnp.swapaxes(a, 0, 1), outs)


def _path_scan_program(
    X: jax.Array,
    y: jax.Array,
    lambdas: jax.Array,
    w0: jax.Array,
    b0: jax.Array,
    theta0: jax.Array,
    delta0: jax.Array,
    lam0: jax.Array,
    L: Optional[jax.Array],
    tau,
    tol,
    *,
    max_iters: int,
    screening: bool,
    dynamic: bool,
    screen_every: int,
    use_pallas: bool,
    exact_lipschitz: bool,
    reduce: str = "mask",
    rules: tuple = ("feature_vi",),
    col: Collectives = LOCAL,
    n_feas_iters: int = 8,
    guards: bool = False,
) -> ScanPathOutputs:
    """The traced whole-path program (one ``lax.scan`` over the grid).

    Pure function of device values — jitted (and optionally vmapped or
    shard_mapped) by the public wrappers. ``(w0, b0, theta0, delta0)`` seed
    the carry: an anchor primal/dual pair at ``lam0`` with
    ``||theta0 - theta*(lam0)|| <= delta0`` (the closed form at
    ``lambda_max`` in the standard entry points). Under ``shard_map`` the
    shapes here are the per-device blocks and ``col`` binds the reductions
    to the mesh (compact reduction requires global row indices, so it is
    local-only — wrappers enforce ``reduce="mask"`` when sharded).
    """
    m, n = X.shape
    dt = X.dtype
    tau = jnp.asarray(tau, dt)
    lambdas = jnp.asarray(lambdas, dt)
    caps = compact_caps(m) if reduce == "compact" else ()
    if dynamic and col is not LOCAL:
        # _dynamic_run has no collectives seam: on shard blocks it would
        # silently compute unreduced partial sums — fail loudly instead
        raise NotImplementedError(
            "dynamic in-solver screening is not plumbed through the "
            "sharded collectives seam yet; use dynamic=False when sharded"
        )

    if L is None:
        L = lipschitz_estimate(X, col=col)
    L = jnp.maximum(L * 1.01, 1e-12)
    inv_L = 1.0 / L

    # theta-independent screen reductions, hoisted out of the scan: per step
    # only the O(mn) ``X @ (y * theta)`` sweep remains (paper Sec. 6.4).
    ones = jnp.ones((n,), dt)
    d_one = col.psum_data(X @ y)          # fhat_j^T 1
    d_y = col.psum_data(X @ ones)         # fhat_j^T y
    d_sq = col.psum_data(jnp.sum(X * X, axis=1))
    one_y = col.psum_data(jnp.sum(y))
    n_tot = col.psum_data(jnp.asarray(float(n), dt))
    m_tot = col.psum_model(jnp.asarray(float(m), dt)).astype(jnp.int32)

    progs = tuple(PROGRAMS[nm] for nm in rules) if screening else ()
    needs_hist = stack_needs_history(progs)
    fixed = FixedStats(d_one=d_one, d_y=d_y, d_sq=d_sq, one_y=one_y,
                       n_tot=n_tot)

    def anchor_from(lam_a, theta_a, delta_a):
        # psummed anchor scalars + the per-step O(mn) sweep — every program
        # in the stack shares these; a two-anchor stack pays one extra sweep
        return AnchorStats(
            lam=lam_a, delta=delta_a,
            theta_dot_one=col.psum_data(jnp.sum(theta_a)),
            theta_dot_y=col.psum_data(theta_a @ y),
            theta_sq=col.psum_data(theta_a @ theta_a),
            d_theta=col.psum_data(X @ (y * theta_a)),
        )

    def step(carry, lam):
        if needs_hist:
            (w, b, theta, delta, lam_prev, fmask_prev,
             lam_old, theta_old, delta_old) = carry
        else:
            w, b, theta, delta, lam_prev, fmask_prev = carry

        def solve(Xs, ws, bs, fms, inv_Ls, vm):
            """Fused-FISTA (or dynamic segmented) solve on one reduction."""
            if dynamic:
                return _dynamic_run(
                    Xs, y, lam, ws, bs, inv_Ls, None, fms,
                    max_iters, tol, screen_every, tau, 4, use_pallas,
                    valid_m=vm, guards=guards,
                )
            return fista_run(
                Xs, y, lam, ws, bs, inv_Ls, None, fms,
                max_iters, tol, use_pallas, col=col, valid_m=vm,
                guards=guards,
            )

        # -- sequential screen from the carried anchor(s) ------------------
        # fail-safe: a refused certificate in the carry (delta collapsed to
        # inf by gap_theta_delta) keeps EVERY feature this step, and the
        # keep test itself is NaN-safe (~(b < tau) keeps non-finite bounds)
        # — an unhealthy anchor can cost speed, never a wrong discard.
        anchor_ok = jnp.isfinite(delta)
        if needs_hist:
            anchor_ok = anchor_ok & jnp.isfinite(delta_old)
        with jax.named_scope("svm_path/screen"):
            if screening:
                anchors = (anchor_from(lam_prev, theta, delta),)
                if needs_hist:
                    anchors = (anchor_from(lam_old, theta_old, delta_old),
                               ) + anchors
                bounds = stack_bounds(progs, lam, anchors, fixed)
                keep = (~(bounds < tau)) | (~anchor_ok)
            else:
                keep = jnp.ones((m,), bool)
            fmask = keep.astype(dt)

        # resurrection tracking: the carried mask records what the previous
        # step certified, so features re-entering the keep set are counted
        # per step. The buffer is sized to the certified keeps alone — they
        # already contain every feature allowed to be nonzero at this
        # lambda (a union with the carried support was considered and
        # rejected: carried-but-uncertified features are provably zero, so
        # buffering them frozen-at-zero only inflates the bucket).
        resurrected = col.psum_model(
            jnp.sum(keep & (fmask_prev < 0.5))).astype(jnp.int32)

        # -- solve on the reduced problem ----------------------------------
        def inv_L_for(Xs):
            if exact_lipschitz:
                return 1.0 / jnp.maximum(
                    lipschitz_estimate(Xs, col=col) * 1.01, 1e-12)
            return inv_L

        def mask_branch(args):
            w_, b_, fmask_ = args
            # inv_L_for ignores its operand unless exact_lipschitz (the
            # masked multiply is DCE'd then), mirroring the compact branch
            res = solve(X, w_ * fmask_, b_, fmask_,
                        inv_L_for(X * fmask_[:, None]), None)
            return (res.w, res.b, res.obj, jnp.asarray(res.n_iters, jnp.int32),
                    res.converged, res.u, jnp.asarray(res.health, jnp.int32))

        def make_compact_branch(cap):
            def branch(args):
                w_, b_, fmask_ = args
                # cumsum compaction: kept row j lands in slot rank(j);
                # screened rows scatter to the dropped sentinel slot
                pos = jnp.cumsum(fmask_.astype(jnp.int32)) - 1
                slot = jnp.where(fmask_ > 0.5, pos, cap)
                sel = jnp.full((cap,), m, jnp.int32).at[slot].set(
                    jnp.arange(m, dtype=jnp.int32), mode="drop")
                validf = (sel < m).astype(dt)
                selc = jnp.minimum(sel, m - 1)
                Xc = jnp.take(X, selc, axis=0) * validf[:, None]
                # every gathered row is a certified keep, so the buffer's
                # live mask IS the validity mask; w already respects fmask
                # on gathered rows (screened rows are not in the buffer)
                w0_c = jnp.take(w_, selc) * validf
                vcount = jnp.sum(fmask_).astype(jnp.int32)
                res = solve(Xc, w0_c, b_, validf, inv_L_for(Xc), vcount)
                w_full = jnp.zeros((m,), dt).at[selc].add(res.w * validf)
                return (w_full, res.b, res.obj,
                        jnp.asarray(res.n_iters, jnp.int32), res.converged,
                        res.u, jnp.asarray(res.health, jnp.int32))
            return branch

        with jax.named_scope("svm_path/solve"):
            if caps:
                caps_arr = jnp.asarray(caps, jnp.int32)
                kept_ct = jnp.sum(fmask).astype(jnp.int32)
                idx = jnp.sum(kept_ct > caps_arr)  # first bucket that fits
                branches = [make_compact_branch(c) for c in caps]
                branches.append(mask_branch)  # overflow: mask-mode fallback
                w2, b2, obj, n_it, conv, u_fin, health = jax.lax.switch(
                    idx, branches, (w, b, fmask))
                cap_used = jnp.asarray((*caps, m), jnp.int32)[idx]
            else:
                w2, b2, obj, n_it, conv, u_fin, health = mask_branch(
                    (w, b, fmask))
                cap_used = m_tot

        # -- gap-certify the accepted point: anchor for the next step ------
        # (full-X certificate — the dual feasibility max runs over every
        # feature — but the margin sweep rides the solver's carried u)
        with jax.named_scope("svm_path/certify"):
            theta2, delta2, gap = gap_theta_delta(
                X, y, w2, b2, lam, None, n_feas_iters=n_feas_iters, col=col,
                u=u_fin,
            )

        out = ScanPathOutputs(
            w=w2, b=b2, obj=obj,
            kept=col.psum_model(jnp.sum(fmask)).astype(jnp.int32),
            active=col.psum_model(jnp.sum(jnp.abs(w2) > 1e-10)).astype(
                jnp.int32),
            n_iters=n_it,
            converged=conv,
            gap=gap, delta=delta2,
            fmask=keep, cap=cap_used, resurrected=resurrected,
            health=health | jnp.where(
                anchor_ok, 0, HEALTH_SCREEN_REFUSED).astype(jnp.int32),
        )
        new_carry = (w2, b2, theta2, delta2, lam, fmask)
        if needs_hist:
            # two-anchor programs (dvi) also carry the previous anchor
            new_carry = new_carry + (lam_prev, theta, delta)
        return new_carry, out

    carry0 = (w0, jnp.asarray(b0, dt), theta0, jnp.asarray(delta0, dt),
              jnp.asarray(lam0, dt), jnp.ones((m,), dt))
    if needs_hist:
        # seed the old anchor with the initial anchor: step 1's two-anchor
        # bound degenerates to the single-anchor bound, matching the host
        # DVIRule which starts with no stored anchor
        carry0 = carry0 + (jnp.asarray(lam0, dt), theta0,
                           jnp.asarray(delta0, dt))
    _, outs = jax.lax.scan(step, carry0, lambdas)
    return outs


def _engine_jit(static_kw: tuple, batched: Optional[str] = None):
    """Build (and cache) the jitted single/vmapped engine for static opts.

    ``batched``: None (single path), ``"grids"`` (shared problem, batched
    lambda grids — X/y/anchors broadcast by vmap, not materialized), or
    ``"problems"`` (independent problems, everything batched). The anchor
    carry (``w0/b0/theta0/delta0``) is donated in the single-path engine so
    XLA may alias it straight into the scan carry — skipped on backends
    without donation support (CPU) to avoid spurious warnings.
    ``"grids_compact"``/``"problems_compact"`` route to the scan-outer /
    vmap-inner :func:`_batched_path_scan_program` (shared-cap compaction —
    the plain vmapped program would run every switch branch); note the extra
    ``sm`` argument in that program's signature.

    Cache hygiene contract (regression-tested): ``static_kw`` is a tuple of
    ``(name, value)`` pairs of hashable primitives, so the engine dict hits
    on repeated configs, and every jitted engine takes only arrays (or None)
    as runtime arguments, so repeated same-shape calls hit jit's own cache
    without retracing — :func:`engine_cache_info` exposes both layers.
    """
    key = (static_kw, batched)
    fn = _ENGINE_CACHE.get(key)
    if fn is not None:
        return fn
    if batched in ("grids_compact", "problems_compact"):
        raw = partial(_batched_path_scan_program,
                      shared_x=(batched == "grids_compact"),
                      **dict(static_kw))
        fn = jax.jit(raw)
        _ENGINE_CACHE[key] = fn
        return fn
    raw = partial(_path_scan_program, **dict(static_kw))
    # arg order: (X, y, lambdas, w0, b0, theta0, delta0, lam0, L, tau, tol)
    if batched == "grids":
        raw = jax.vmap(raw, in_axes=(None, None, 0, None, None, None, None,
                                     None, None, None, None))
    elif batched == "problems":
        raw = jax.vmap(raw, in_axes=(0, 0, 0, 0, 0, 0, None, 0, None, None,
                                     None))
    donate = ()
    if batched is None and jax.default_backend() != "cpu":
        donate = (3, 4, 5, 6)
    fn = jax.jit(raw, donate_argnums=donate)
    _ENGINE_CACHE[key] = fn
    return fn


_ENGINE_CACHE: dict = {}


def engine_cache_info() -> dict:
    """Both warm-cache layers of the scan engines, for retrace accounting.

    Returns ``{(batched, static_opts): n_traces}`` — one entry per engine
    variant built by :func:`_engine_jit`, with ``n_traces`` the number of
    distinct traces jit holds for it (one per argument-shape signature; a
    same-config same-shape call that bumps this number is a retrace
    regression). ``-1`` when the running jax has no ``_cache_size`` probe.
    """
    info = {}
    for (static_kw, batched), fn in _ENGINE_CACHE.items():
        probe = getattr(fn, "_cache_size", None)
        info[(batched, static_kw)] = int(probe()) if probe else -1
    return info


def _validate_reduce(reduce: str) -> str:
    if reduce not in ("mask", "compact"):
        raise ValueError(
            "scan-engine reduce must be 'mask' or 'compact' (gather needs "
            f"the host engine's per-step re-trace), got {reduce!r}"
        )
    return reduce


def _static_opts(max_iters, screening, dynamic, screen_every, use_pallas,
                 exact_lipschitz, reduce="mask", rules=None,
                 guards=None) -> tuple:
    # the rule spec is resolved HERE — at dispatch, not inside the trace —
    # so unlowerable specs (sample rules, containers holding them) fail
    # with resolve_programs' error before any engine is built, and the
    # resolved program tuple becomes part of the engine-cache key. The
    # screening flag is re-derived from the resolved stack: rules="none"
    # turns screening off, rules=None keeps the legacy screening=bool.
    progs = resolve_programs(rules, screening=bool(screening))
    return (
        ("max_iters", int(max_iters)),
        ("screening", bool(progs)),
        ("dynamic", bool(dynamic)),
        ("screen_every", max(int(screen_every), 1)),
        ("use_pallas", _resolve_pallas(use_pallas)),
        ("exact_lipschitz", bool(exact_lipschitz)),
        ("reduce", _validate_reduce(reduce)),
        ("rules", progs),
        # numerical health guards (core/solver.py): None resolves the
        # REPRO_SOLVER_GUARDS env default at dispatch, and the resolved bool
        # is part of the engine-cache key like every other static
        ("guards", _resolve_guards(guards)),
    )


def _to_path_result(lambdas, outs: ScanPathOutputs, lam_max_val, wall_s,
                    screening, static_kw, engine: str = "scan") -> PathResult:
    T = len(lambdas)
    opts = dict(static_kw)
    screened = bool(opts.get("screening", screening))
    per_step = np.full((T,), wall_s / max(T, 1), dtype=np.float64)
    # the uniform PathTrace artifact, synthesized post-hoc from the scan
    # carry's streamed telemetry (kept/iters/gap/delta/health ride the
    # device outputs; per-step walls are the uniform share of the blocked
    # dispatch — walls_observed=False says so)
    path_trace = build_path_trace(
        engine, lambdas, np.asarray(outs.kept, np.int64), None,
        np.asarray(outs.active, np.int64),
        np.asarray(outs.n_iters, np.int64), per_step,
        gaps=np.asarray(outs.gap, np.float64),
        deltas=np.asarray(outs.delta, np.float64),
        health=np.asarray(outs.health, np.int64),
        total_s=float(wall_s), walls_observed=False,
        meta={"reduce": opts.get("reduce"), "lam_max": float(lam_max_val)},
    )
    # same registry counters the host driver feeds (steps / guard trips /
    # kept histogram), so every engine's runs aggregate in one place
    PathDriver._observe_run(engine, np.asarray(outs.kept, np.int64),
                            np.asarray(outs.health, np.int64))
    return PathResult(
        lambdas=np.asarray(lambdas, np.float64),
        weights=np.asarray(outs.w, np.float64),
        biases=np.asarray(outs.b, np.float64),
        objectives=np.asarray(outs.obj, np.float64),
        kept=np.asarray(outs.kept, np.int64),
        active=np.asarray(outs.active, np.int64),
        solver_iters=np.asarray(outs.n_iters, np.int64),
        # the engine never syncs mid-path, so per-step walls are not
        # observable — report the uniform share of the (blocked) total and
        # keep the exact total in extras.
        wall_times=per_step,
        screen_times=np.zeros((T,), np.float64),
        screened=screened,
        kept_samples=np.zeros((T,), np.int64),
        verify_rounds=np.zeros((T,), np.int64),
        rules=opts.get("rules", ("feature_vi",) if screened else ()),
        extras={
            "engine": engine,
            "path_trace": path_trace,
            "lam_max": float(lam_max_val),
            "total_seconds": float(wall_s),
            "gaps": np.asarray(outs.gap, np.float64),
            "deltas": np.asarray(outs.delta, np.float64),
            "converged": np.asarray(outs.converged, bool),
            "keep_masks": np.asarray(outs.fmask, bool),
            "caps": np.asarray(outs.cap, np.int64),
            "resurrected": np.asarray(outs.resurrected, np.int64),
            # per-step guard telemetry (solver.HEALTH_SCREEN_REFUSED flags a
            # fail-safe keep-all step; low bits count solver rollbacks)
            "health": np.asarray(outs.health, np.int64),
            "options": dict(static_kw),
        },
    )


def svm_path_scan(
    X: jax.Array,
    y: jax.Array,
    lambdas: Optional[Sequence[float]] = None,
    n_lambdas: int = 10,
    lam_min_ratio: float = 0.1,
    *,
    screening: bool = True,
    tau: float = SAFE_TAU,
    tol: float = 1e-9,
    max_iters: int = 4000,
    dynamic: bool = False,
    screen_every: int = 50,
    use_pallas: Optional[bool] = None,
    exact_lipschitz: bool = False,
    reduce: str = "mask",
    rules=None,
    guards: Optional[bool] = None,
) -> PathResult:
    """Solve the feature-screened path as ONE jitted XLA program.

    Semantics match ``svm_path(..., rules="feature_vi")``: every step
    screens against the previous step's gap-certified anchor, solves under
    the certified keep set to ``tol``, and certifies its own anchor — but
    with zero host involvement between the first dispatch and the final
    transfer. See the module docstring for when to prefer which engine.

    ``rules`` picks the screening-rule stack evaluated inside the jitted
    step: any spec of a-priori-safe feature rules that ship a
    :class:`~repro.core.rules.programs.RuleProgram` (``"feature_vi"``,
    ``"edpp"``, ``"dvi"``, ``"auto"``, or a list of them — the bounds are
    AND-ed elementwise). ``None`` keeps the legacy default
    (``feature_vi`` when ``screening=True``); ``"none"`` disables
    screening. Sample rules and verification-needing specs raise at
    dispatch — use ``engine="host"`` for those.

    ``reduce="compact"`` turns the keep mask into a physically gathered
    fixed-capacity active set inside the step (``jnp.cumsum`` compaction,
    static bucket schedule, mask-mode overflow fallback — module docstring),
    making per-step solver FLOPs proportional to the surviving features;
    ``reduce="mask"`` (default) keeps the full-shape zero-frozen solve.
    ``use_pallas`` routes the FISTA hot-loop sweeps through the fused Pallas
    kernels (None = env/backend policy, ``kernels/ops.fista_use_pallas``;
    compacted solves pass their live-row count so the kernels skip padded
    blocks); ``dynamic=True`` swaps each step's solve for the segmented
    ``screen_every``-interval in-solver re-screen; ``exact_lipschitz=True``
    re-runs the power iteration per step on the reduced matrix instead of
    reusing the full-X upper bound.
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    m, n = X.shape

    lam_max_val = float(lambda_max(X, y))
    if lambdas is None:
        lambdas = default_lambda_grid(lam_max_val, n_lambdas, lam_min_ratio)
    lambdas = _validate_grid(lambdas)

    # anchor at lambda_max: closed form is exact => delta = 0 (core/dual.py)
    w0 = jnp.zeros((m,), X.dtype)
    b0 = bias_at_lambda_max(y)
    theta0 = theta_at_lambda_max(y, jnp.asarray(lam_max_val, X.dtype))
    delta0 = jnp.asarray(0.0, X.dtype)

    static_kw = _static_opts(max_iters, screening, dynamic, screen_every,
                             use_pallas, exact_lipschitz, reduce, rules,
                             guards)
    engine = _engine_jit(static_kw, batched=None)
    t0 = time.perf_counter()
    outs = engine(X, y, jnp.asarray(lambdas, X.dtype), w0, b0, theta0,
                  delta0, jnp.asarray(lam_max_val, X.dtype), None,
                  float(tau), float(tol))
    outs = jax.block_until_ready(outs)
    t1 = time.perf_counter()
    wall_s = t1 - t0
    obs_trace.complete("scan.dispatch", t0, t1, steps=len(lambdas),
                       reduce=dict(static_kw)["reduce"])
    r = _to_path_result(lambdas, outs, lam_max_val, wall_s, screening,
                        static_kw)
    r.extras["path_trace"].emit_to_tracer()
    return r


def svm_path_scan_sharded(
    mesh,
    X: jax.Array,
    y: jax.Array,
    lambdas: Optional[Sequence[float]] = None,
    n_lambdas: int = 10,
    lam_min_ratio: float = 0.1,
    *,
    screening: bool = True,
    tau: float = SAFE_TAU,
    tol: float = 1e-9,
    max_iters: int = 4000,
    dynamic: bool = False,
    exact_lipschitz: bool = False,
    rules=None,
    guards: Optional[bool] = None,
    data_axes=("data",),
) -> PathResult:
    """The scan engine as ONE ``shard_map``'d program on the ``svm_mesh``.

    The exact step program of :func:`svm_path_scan` runs on the per-device
    blocks of a 2-D (features x samples) mesh: the screen reductions, the
    fused FISTA sweeps, the Lipschitz power iteration, and the gap
    certificate all bind their reductions to ``lax.psum``/``pmax`` over the
    mesh axes via ``distributed.mesh_collectives`` — same communication
    pattern as ``distributed.fista_sharded`` (4-scalar + per-shard-vector
    psums; margins over "model", gradients over "data"). On a trivial
    ``svm_mesh(1, 1)`` every collective is an identity, so the outputs match
    the single-device engine bitwise (tested in tests/test_path_scan.py).

    Mask reduction only (compaction needs global row indices inside the
    step — sharding the feature axis already divides the sweep); XLA sweeps
    only (the fused Pallas margin kernel finalizes xi in-kernel, which needs
    the un-psummed full margins); the dynamic in-solver re-screen is not
    yet plumbed through the collectives seam.

    For an ``X`` too large for any single device, pass ``X``/``y`` already
    placed on the mesh (``jax.device_put`` with a ``NamedSharding`` matching
    the in-specs): the setup reductions here (``lambda_max``, anchors) then
    run SPMD on the sharded global array instead of materializing ``X`` on
    device 0.
    """
    from .distributed import mesh_collectives, shard_map  # lazy: no cycle
    from jax.sharding import PartitionSpec as P

    if dynamic:
        # validate at dispatch — previously this only surfaced as a
        # NotImplementedError from deep inside the traced program
        raise ValueError(
            "dynamic in-solver screening is not supported on the sharded "
            "scan engine: _dynamic_run has no collectives seam, so shard "
            "blocks would compute unreduced partial sums. Use "
            "svm_path_scan(dynamic=True) on a single device, or the host "
            "engine (svm_path(engine='host', dynamic=True))."
        )

    X = jnp.asarray(X)
    y = jnp.asarray(y)
    m, n = X.shape

    lam_max_val = float(lambda_max(X, y))
    if lambdas is None:
        lambdas = default_lambda_grid(lam_max_val, n_lambdas, lam_min_ratio)
    lambdas = _validate_grid(lambdas)

    w0 = jnp.zeros((m,), X.dtype)
    b0 = bias_at_lambda_max(y)
    theta0 = theta_at_lambda_max(y, jnp.asarray(lam_max_val, X.dtype))
    delta0 = jnp.asarray(0.0, X.dtype)

    static_kw = _static_opts(max_iters, screening, False, 1, False,
                             exact_lipschitz, "mask", rules, guards)
    col = mesh_collectives(mesh, data_axes)

    def local_fn(Xb, yb, lams, w0b, b0b, th0b, d0b, lam0b, taub, tolb):
        return _path_scan_program(
            Xb, yb, lams, w0b, b0b, th0b, d0b, lam0b, None, taub, tolb,
            col=col, **dict(static_kw),
        )

    in_specs = (P("model", *data_axes), P(*data_axes), P(), P("model"), P(),
                P(*data_axes), P(), P(), P(), P())
    out_specs = ScanPathOutputs(
        w=P(None, "model"), b=P(), obj=P(), kept=P(), active=P(),
        n_iters=P(), converged=P(), gap=P(), delta=P(),
        fmask=P(None, "model"), cap=P(), resurrected=P(),
        # replicated: the guard's trip verdict is pmax'd over the model axis
        # inside the body (solver._make_fista_body), so shards agree
        health=P(),
    )
    fn = jax.jit(shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False))
    t0 = time.perf_counter()
    outs = fn(X, y, jnp.asarray(lambdas, X.dtype), w0, b0, theta0, delta0,
              jnp.asarray(lam_max_val, X.dtype),
              jnp.asarray(float(tau), X.dtype),
              jnp.asarray(float(tol), X.dtype))
    outs = jax.block_until_ready(outs)
    t1 = time.perf_counter()
    wall_s = t1 - t0
    obs_trace.complete("scan_sharded.dispatch", t0, t1, steps=len(lambdas))
    r = _to_path_result(lambdas, outs, lam_max_val, wall_s, screening,
                        static_kw, engine="scan_sharded")
    r.extras["engine"] = "scan_sharded"
    r.extras["mesh"] = dict(zip(mesh.axis_names, mesh.devices.shape))
    r.extras["path_trace"].emit_to_tracer()
    return r


def svm_path_batched(
    X: jax.Array,
    y: jax.Array,
    lambdas: Optional[np.ndarray] = None,
    n_lambdas: int = 10,
    lam_min_ratio: float = 0.1,
    *,
    screening: bool = True,
    tau: float = SAFE_TAU,
    tol: float = 1e-9,
    max_iters: int = 4000,
    dynamic: bool = False,
    screen_every: int = 50,
    use_pallas: Optional[bool] = None,
    exact_lipschitz: bool = False,
    reduce: str = "mask",
    rules=None,
    guards: Optional[bool] = None,
) -> list[PathResult]:
    """``vmap`` of the scan engine over a batch of problems or grids.

    Two batching modes, selected by the rank of ``X``:

    * ``X (m, n)``, ``lambdas (B, T)`` — one dataset, B lambda grids
      (hyperparameter sweep / cross-validation over grids);
    * ``X (B, m, n)``, ``y (B, n)`` — B independent problems
      (multi-tenant serving), each on its own grid (``lambdas (B, T)``) or
      on its own default geometric grid anchored at its own
      ``lambda_max`` when ``lambdas`` is None.

    Executes as ONE jitted program: every sweep, reduction, and solver
    launch is batched, so B paths cost roughly one path's worth of
    launches. The usual vmap caveats apply — the while loops run until the
    slowest batch element converges and the restart ``lax.cond`` becomes a
    select — so wall clock per path is bounded by the hardest problem in
    the batch.

    ``reduce="compact"`` composes with batching through the shared-cap
    schedule (:func:`_batched_path_scan_program`): the scan over the grid
    stays outer, the per-element work is vmapped inner, and each step's
    compact capacity is picked by the *scalar* batch-max kept count — so
    one switch branch runs, FLOPs track what screening certifies, and one
    overflowing element demotes only that step to mask mode. Same rule of
    thumb as the single-path engine: compact when screening certifies a
    small active set, mask (default) when screening is weak and compaction
    would only add gather traffic. The mask-mode program is
    shard-transparent: inputs placed on a mesh (e.g. batch-sharded ``X``)
    keep their sharding through jit, which is how the sharded-solver mesh
    serves batched paths (compact mode needs local row indices — keep it
    single-device).

    Returns one :class:`~repro.core.path.PathResult` per batch element
    (shared total wall clock in ``extras["total_seconds"]``, batch size in
    ``extras["batch"]``).
    """
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    static_kw = _static_opts(max_iters, screening, dynamic, screen_every,
                             use_pallas, exact_lipschitz, reduce, rules,
                             guards)
    compact = dict(static_kw)["reduce"] == "compact"
    if X.ndim == 2:
        # one problem, B grids — X/y/anchors stay unbatched (vmap broadcasts)
        if lambdas is None:
            raise ValueError(
                "grid-batched mode (2-D X) needs an explicit (B, T) lambdas"
            )
        grids = np.asarray(lambdas, np.float64)
        if grids.ndim != 2:
            raise ValueError(f"lambdas must be (B, T), got {grids.shape}")
        B = grids.shape[0]
        for g in grids:
            _validate_grid(g)
        m = X.shape[0]
        lam_max_val = float(lambda_max(X, y))
        lam_maxs = np.full((B,), lam_max_val)
        engine = _engine_jit(
            static_kw, batched="grids_compact" if compact else "grids")
        args = (
            X, y, jnp.asarray(grids, X.dtype), jnp.zeros((m,), X.dtype),
            bias_at_lambda_max(y),
            theta_at_lambda_max(y, jnp.asarray(lam_max_val, X.dtype)),
            jnp.asarray(0.0, X.dtype), jnp.asarray(lam_max_val, X.dtype),
        )
    elif X.ndim == 3:
        B, m, _ = X.shape
        if y.ndim != 2 or y.shape[0] != B:
            raise ValueError(f"y must be (B, n) for 3-D X, got {y.shape}")
        lam_maxs = np.asarray(jax.vmap(lambda_max)(X, y), np.float64)
        if lambdas is None:
            ratios = np.geomspace(1.0, lam_min_ratio, n_lambdas)
            grids = lam_maxs[:, None] * ratios[None, :]
        else:
            grids = np.asarray(lambdas, np.float64)
            if grids.ndim == 1:
                grids = np.broadcast_to(grids, (B, grids.shape[0])).copy()
        for g in grids:
            _validate_grid(g)
        lam_maxs_j = jnp.asarray(lam_maxs, X.dtype)
        engine = _engine_jit(
            static_kw, batched="problems_compact" if compact else "problems")
        args = (
            X, y, jnp.asarray(grids, X.dtype), jnp.zeros((B, m), X.dtype),
            jax.vmap(bias_at_lambda_max)(y),
            jax.vmap(theta_at_lambda_max)(y, lam_maxs_j),
            jnp.asarray(0.0, X.dtype), lam_maxs_j,
        )
    else:
        raise ValueError(f"X must be (m, n) or (B, m, n), got {X.shape}")

    if compact:
        # the batched-compact program takes an optional per-element sample
        # mask right after (X, y) — unpadded callers pass None
        args = args[:2] + (None,) + args[2:]
    t0 = time.perf_counter()
    outs = engine(*args, None, float(tau), float(tol))
    outs = jax.block_until_ready(outs)
    t1 = time.perf_counter()
    wall_s = t1 - t0
    obs_trace.complete("batched.dispatch", t0, t1, batch=B)

    results = []
    for i in range(B):
        sub = ScanPathOutputs(*(np.asarray(v)[i] for v in outs))
        r = _to_path_result(grids[i], sub, float(lam_maxs[i]), wall_s / B,
                            screening, static_kw, engine="batched")
        r.extras["total_seconds"] = float(wall_s)
        r.extras["batch"] = B
        r.extras["batch_index"] = i
        r.extras["path_trace"].meta["batch_index"] = i
        r.extras["path_trace"].emit_to_tracer()
        results.append(r)
    return results
