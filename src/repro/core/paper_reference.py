"""The paper's closed forms (Theorems 6.5/6.7/6.9, Algorithm 1) implemented
*literally*, as an independent cross-check of core/screening.py.

Our production implementation derives the bound geometrically (hyperplane
projection first); this module follows the paper's own algebra:

    neg_min(fhat) = -min_{theta in K} theta^T fhat          (Algorithm 1)
    bound          = max(neg_min(fhat), neg_min(-fhat))

Cases (paper numbering):
  * Cor. 6.8  (beta>0, alpha=0): ball-interior solution,
        neg_min = ||P_y(b)|| ||P_y(f)|| - P_y(b)^T P_y(f) - f^T theta1
  * Cor. 6.10 (beta>0, alpha>0): sphere∩plane via the Thm-6.2 minimal ball,
        neg_min = 1/2 (1/l2 - 1/l1) (||u_f|| ||u_1|| - u_1^T u_f) - f^T theta1
        with u_x = P_{P_a(y)}(P_a(x))
  * Thm. 6.5  (beta=0): colinear degenerate case — measure-zero in floats;
    handled by the tolerance in the case-selection condition.

Sign convention: the paper's Eq. (43) writes the halfspace as
``a^T(b+r) <= 0`` although the variational inequality (Eq. 31) it comes from
gives ``a^T(theta2-theta1) >= 0`` with b + r = theta2 - theta1. The
case-selection condition below uses the VI-consistent orientation (matching
our geometric implementation and verified empirically by
tests/test_paper_reference.py: the two independent implementations agree to
fp tolerance on random instances, and safety holds).

This module is intentionally NOT vectorized (feature-at-a-time, like the
paper's Algorithm 1) — it is a reference, not a fast path.
"""

from __future__ import annotations

import numpy as np

_EPS = 1e-12


def _proj_out(v: np.ndarray, u: np.ndarray) -> np.ndarray:
    """P_u(v): project v onto the null space of u (paper Eq. 39)."""
    uu = float(u @ u)
    if uu < _EPS:
        return v.copy()
    return v - (float(v @ u) / uu) * u


def neg_min(fhat: np.ndarray, y: np.ndarray, lam1: float, lam2: float,
            theta1: np.ndarray) -> float:
    """-min_{theta in K} theta^T fhat, paper Algorithm 1 lines 12-23."""
    n = len(y)
    ones = np.ones(n)
    a_raw = theta1 - ones / lam1
    a_norm = float(np.linalg.norm(a_raw))
    b = 0.5 * (ones / lam2 - theta1)

    py_f = _proj_out(fhat, y)
    py_b = _proj_out(b, y)

    scale = float(np.sqrt(theta1 @ theta1 + n / lam1 ** 2))
    if a_norm < 1e-6 * scale:
        # no halfspace information (theta1 == 1/lam1 up to rounding — e.g.
        # balanced classes at lam_max): ball ∩ hyperplane only
        return float(np.linalg.norm(py_b) * np.linalg.norm(py_f)
                     - py_b @ py_f - fhat @ theta1)

    a = a_raw / a_norm
    py_a = _proj_out(a, y)

    if float(py_a @ py_a) < 1e-9:
        # a ∝ y: the halfspace is vacuous inside {y^T theta = 0} (happens
        # exactly at lam1 = lam_max with unbalanced classes) — ball-only.
        return float(np.linalg.norm(py_b) * np.linalg.norm(py_f)
                     - py_b @ py_f - fhat @ theta1)

    # Thm 6.5 colinearity (beta = 0) — degenerate, fold into the tolerance of
    # the halfspace condition below (cos == -1 lands in the alpha=0 branch).

    # Algorithm 1 line 17 condition. Orientation note: the paper's Eq. (43)
    # writes the halfspace with its own sign convention (see module
    # docstring); transcribing the condition with a_VI = (theta1 - 1/lam1)
    # mis-selects cases (verified against an SLSQP ground-truth maximizer:
    # the VI orientation sent ball-max instances into the Cor-6.10 branch,
    # 3x loose). The paper's convention corresponds to -a_VI here:
    nb = max(float(np.linalg.norm(py_b)), _EPS)
    nf = max(float(np.linalg.norm(py_f)), _EPS)
    cond = float(-py_a @ (py_b / nb - py_f / nf))
    if cond <= 0.0:
        # Cor. 6.8: beta > 0, alpha = 0
        return float(nb * nf - py_b @ py_f - fhat @ theta1)

    # Cor. 6.10: beta > 0, alpha > 0 — switch to the Thm-6.2 minimal ball
    pa_y = _proj_out(y, a)
    pa_f = _proj_out(fhat, a)
    pa_1 = _proj_out(ones, a)
    u_f = _proj_out(pa_f, pa_y)
    u_1 = _proj_out(pa_1, pa_y)
    factor = 0.5 * (1.0 / lam2 - 1.0 / lam1)
    return float(factor * (np.linalg.norm(u_f) * np.linalg.norm(u_1) - u_1 @ u_f)
                 - fhat @ theta1)


def screen_bounds_paper(X: np.ndarray, y: np.ndarray, lam1: float,
                        lam2: float, theta1: np.ndarray) -> np.ndarray:
    """Per-feature bound on |fhat^T theta2| via the paper's Algorithm 1."""
    m = X.shape[0]
    out = np.zeros(m)
    for j in range(m):
        fhat = y * X[j]
        out[j] = max(neg_min(fhat, y, lam1, lam2, theta1),
                     neg_min(-fhat, y, lam1, lam2, theta1))
    return out
