"""The paper's variational-inequality feature rule, as a pluggable rule.

This is a port of the original hard-wired screen (``core/screening.py``,
paper Sec. 6) into the :class:`~repro.core.rules.base.ScreeningRule`
protocol. The math stays in ``core/screening.py`` — shared with the Pallas
kernel and the sharded screen — this class owns the *policy*: per-feature
bound, keep threshold, and the theta-independent reduction cache that makes
the per-lambda cost one ``X @ (y * theta1)`` sweep (paper Sec. 6.4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..screening import (
    SAFE_TAU,
    FeatureReductions,
    _finalize_bounds,
    feature_reductions,
    row_dot,
)
from .base import AXIS_FEATURES, ConvexRegion, ScreeningRule, register_rule

__all__ = ["FeatureVIRule"]


@register_rule("feature_vi")
class FeatureVIRule(ScreeningRule):
    """Safe feature screening: discard feature ``j`` when
    ``max_{theta in K} |fhat_j^T theta| < tau`` (paper Algorithm 1).

    A-priori safe: a discarded feature provably has ``w_j*(lam2) = 0`` (given
    ``||theta1 - theta*(lam1)|| <= region.delta``), so no verification pass is
    needed.

    ``program`` links this class to its jittable functional twin
    (``rules/programs.py``): the fast engines evaluate
    ``PROGRAMS["feature_vi"]`` over engine-computed anchor stats; this class
    is the host-driver wrapper around the same ``core/screening.py`` math.
    """

    axis = AXIS_FEATURES
    needs_verification = False
    program = "feature_vi"

    def __init__(self, tau: float = SAFE_TAU):
        self.tau = float(tau)
        self._static: Optional[tuple[jax.Array, jax.Array, jax.Array]] = None

    def prepare(self, X: jax.Array, y: jax.Array) -> None:
        """Cache the three theta-independent reductions for a whole path.

        Row-stable formulation (``screening.row_dot``) so the cached values
        — and hence the whole bound sweep — match the chunk-streamed screen
        (``repro/sparse/screen_stream.py``) bitwise.
        """
        red = feature_reductions(X, y, jnp.ones_like(y))
        self._static = (red.d_one, red.d_y, red.d_sq)

    def bounds(self, X: jax.Array, y: jax.Array, region: ConvexRegion) -> jax.Array:
        d_theta = row_dot(X, y * region.theta1)
        if self._static is not None:
            d_one, d_y, d_sq = self._static
            red = FeatureReductions(d_theta=d_theta, d_one=d_one, d_y=d_y, d_sq=d_sq)
        else:
            red = feature_reductions(X, y, region.theta1)._replace(d_theta=d_theta)
        return _finalize_bounds(red, region.shared)

    def keep(self, bounds: jax.Array) -> jax.Array:
        return bounds >= self.tau
