"""SIFS rule: simultaneous feature + sample reduction, alternating per step.

Zhang et al. ("Scaling Up Sparse SVM by Simultaneous Feature and Sample
Reduction") interleave an *inactive feature* screen with an *inactive
sample* screen, each round tightening the other's region, until neither
shrinks. This repo's transplant of that scheme to the squared-hinge + pure
L1 dual keeps the shape but swaps the halves for what is provable here:

* feature half — the EDPP projection region (:mod:`.edpp`), the strongest
  a-priori-safe feature rule in the zoo;
* sample half — the margin-certified sample screen with a-posteriori KKT
  verification (:mod:`.sample_vi`). A-priori-safe sample screening is
  provably impossible for this loss (every sample's subgradient support is
  unbounded — see the honest derivation in ``sample_vi.py``), so the
  alternating refinement happens through the driver's existing
  ``solve_with_verification`` loop: feature mask -> sample mask -> reduced
  solve -> KKT check re-admits violators -> re-solve. Each verification
  round *is* one SIFS alternation, with the certificate exact at
  termination instead of a-priori.

Like :class:`~repro.core.rules.composite.CompositeRule` this is a container:
``make_rules("sifs")`` flattens it to ``[EDPPRule, SampleVIRule]`` and the
driver applies one per axis. Runs on the host engine with in-core *or*
chunked storage — out of core the feature half streams through its rule
program and the sample half rides the transposed sweep
(``sparse.stream_sample_stats`` inputs) with verification from the
solver's carried margins. The jitted scan engines still can't host the
verification loop; there use ``rules="edpp"`` for the feature half alone.
"""

from __future__ import annotations

from typing import Optional, Sequence

from ..screening import SAFE_TAU
from .base import ScreeningRule, register_rule
from .edpp import EDPPRule
from .sample_vi import SampleVIRule

__all__ = ["SIFSRule"]


@register_rule("sifs")
class SIFSRule(ScreeningRule):
    """Container: EDPP feature screen + verified sample screen, alternated
    through the driver's verification loop."""

    axis = "both"

    def __init__(self, tau: float = SAFE_TAU,
                 rules: Optional[Sequence[ScreeningRule]] = None):
        self.rules: list[ScreeningRule] = (
            list(rules) if rules is not None
            else [EDPPRule(tau=tau), SampleVIRule()]
        )

    def subrules(self) -> list[ScreeningRule]:
        return list(self.rules)

    def bounds(self, X, y, region):  # pragma: no cover - container only
        raise NotImplementedError(
            "SIFSRule is a container; flatten with make_rules() and apply "
            "each constituent per axis"
        )
