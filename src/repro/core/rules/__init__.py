"""Pluggable safe-screening rules for the sparse SVM path.

The paper's variational-inequality feature screen is one member of a family
of reduction rules; this package makes the family a first-class subsystem so
new rules plug into the same path driver, kernels, and benchmarks instead of
forking the stack.

Architecture
------------
* :mod:`.base` — the :class:`ScreeningRule` protocol (``axis``, ``bounds``,
  ``keep``, optional ``verify``), the shared :class:`ConvexRegion` built once
  per path step (VI set scalars + dual anchor ``(theta1, delta)`` + primal
  anchor ``(w1, b1, dw, db)``), and the string registry
  (``register_rule`` / ``get_rule`` / ``available_rules`` / ``make_rules``).
* :mod:`.feature_vi` — the paper's rule (Sec. 6): discard feature ``j`` when
  ``max_{theta in K} |fhat_j^T theta| < tau``. A-priori safe.
* :mod:`.sample_vi` — margin-certified sample screening with a-posteriori
  KKT verification (exact at termination), plus the certified-but-loose
  a-priori slack caps ``sample_slack_caps`` with an honest derivation of why
  a-priori sample screening cannot work for this loss.
* :mod:`.composite` — simultaneous feature + sample reduction; the two axes
  multiply (``kept_m * kept_n`` solver cost).
* :mod:`.dvi` — feature screening from the elementwise-min of the latest and
  step-before-last anchors' VI bounds (Liu et al.-style DVI composition).
* :mod:`.edpp` — Wang et al.'s enhanced-DPP projection region (the dual is
  a polytope projection, so the normal-cone direction at the previous
  anchor shrinks the certificate ball). Same sweep cost as ``feature_vi``,
  strictly tighter keeps.
* :mod:`.sifs` — Zhang et al.-style simultaneous feature + sample
  reduction: EDPP feature half + verified sample half, alternated through
  the driver's verification loop.
* :mod:`.auto` — telemetry-driven stack selection: EDPP always (free), the
  DVI old-anchor sweep only while its measured payoff covers its cost.
* :mod:`.programs` — the jittable functional core: every a-priori-safe
  feature rule above also ships a pure :class:`~.programs.RuleProgram`
  (region pytree -> bounds) that the fast engines (``scan`` / ``compact`` /
  ``batched`` / ``sharded`` / streamed) AND together inside their jitted
  steps. See the :mod:`.base` docstring for the lowerability contract.

Registered rules: ``"feature_vi"``, ``"sample_vi"``, ``"composite"``,
``"dvi"``, ``"edpp"``, ``"sifs"``, ``"auto"``.

Dynamic screening: every rule additionally exposes ``refresh(X, y, w, b,
lam)`` — rebuild its region from the current solver iterate (gap-certified);
``PathDriver(dynamic=True)`` fuses the equivalent refresh into the FISTA
loop itself. See the :mod:`.base` module docstring.

Usage
-----
>>> from repro.core.path import PathDriver
>>> PathDriver(rules="composite").run(X, y, n_lambdas=10)       # both axes
>>> PathDriver(rules=["feature_vi"]).run(X, y)                  # paper rule
>>> PathDriver(rules=[]).run(X, y)                              # no screening

Adding a rule: subclass :class:`ScreeningRule`, decorate with
``@register_rule("my_rule")``, implement ``bounds``/``keep`` (and ``verify``
if not a-priori safe) — the driver, ``svm_path``, ``launch/train_svm.py``,
and ``benchmarks/bench_screening.py`` pick it up by name. Planned next
rules (see ROADMAP): DVI (dual VI at the previous-previous step), EDPP-style
projection rules, and dynamic (in-solver) gap screening.
"""

from .base import (  # noqa: F401
    AXIS_FEATURES,
    AXIS_SAMPLES,
    ConvexRegion,
    ScreeningRule,
    available_rules,
    get_rule,
    make_rules,
    register_rule,
)
from .feature_vi import FeatureVIRule  # noqa: F401
from .sample_vi import SampleVIRule, sample_margin_surplus, sample_slack_caps  # noqa: F401
from .composite import CompositeRule  # noqa: F401
from .dvi import DVIRule  # noqa: F401
from .edpp import EDPPRule  # noqa: F401
from .sifs import SIFSRule  # noqa: F401
from .auto import AutoRule  # noqa: F401
from .programs import (  # noqa: F401
    PROGRAMS,
    RuleProgram,
    resolve_programs,
    stack_bounds,
    stack_needs_history,
)

__all__ = [
    "AXIS_FEATURES",
    "AXIS_SAMPLES",
    "ConvexRegion",
    "ScreeningRule",
    "FeatureVIRule",
    "SampleVIRule",
    "CompositeRule",
    "DVIRule",
    "EDPPRule",
    "SIFSRule",
    "AutoRule",
    "PROGRAMS",
    "RuleProgram",
    "available_rules",
    "get_rule",
    "make_rules",
    "register_rule",
    "resolve_programs",
    "sample_margin_surplus",
    "sample_slack_caps",
    "stack_bounds",
    "stack_needs_history",
]
