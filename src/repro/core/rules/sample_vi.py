"""Sample screening for the L1-regularized L2-loss SVM.

A sample ``i`` drops out of every solver GEMV iff its slack vanishes at the
target optimum: ``xi_i*(lam2) = max(0, 1 - y_i (w*^T x_i + b*)) = 0``, i.e.
its margin is satisfied. Equivalently ``theta_i*(lam2) = 0`` (paper Eq. 20).

Why this rule is *verified*-safe rather than a-priori safe
----------------------------------------------------------
For the squared hinge the dual coordinate ``theta_i = xi_i / lam`` is a
*continuous* function of the data (support vectors leave the active set
smoothly), so no bounded region ``K ∋ theta*`` can certify the closed
condition ``theta_i* = 0``: ``max_{theta in K} e_i^T theta >= theta_i* >= 0``
with equality only in degenerate geometry. This is the structural reason the
safe-sample-screening literature targets the *hinge* loss (discrete dual,
Ogawa et al., "Safe Sample Screening for Support Vector Machines") or adds
primal strong convexity (elastic net, Zhang et al., "Scaling Up Sparse SVMs
by Simultaneous Feature and Sample Reduction"). Pure L1 + squared hinge has
neither. ``sample_slack_caps`` below computes the best certified a-priori
bound (the VI-region coordinate maximum); it is valid — and provably too
loose to screen (the region's coordinate extent is O(ball radius), not
O(xi); measured on the bench instances its minimum is ~1 when true slacks
are 0).

The practical rule therefore splits the guarantee in two:

1. **Margin prediction** (this class). Screen sample ``i`` when its margin
   surplus at the previous solution clears a per-sample slack budget:

       y_i u1_i - 1  >=  slack_i,      u1 = X^T w1 + b1,

   with two slack models, tightest applicable wins:

   * *secant* (needs one step of history): ``slack_i =
     shrink_factor * |u1_i - u0_i| + margin_floor`` where ``u0`` is the
     margin at the previous-previous path anchor — first-order continuation
     of each sample's margin trajectory along the (geometric) lambda grid;
   * *trust region* (certificate if the radii hold): ``slack_i =
     ||x_i||_2 * dw + db`` bounds the margin change via Cauchy-Schwarz
     whenever ``||w* - w1|| <= dw`` and ``|b* - b1| <= db``. With the
     driver's default ``dw = inf`` before any movement history exists, the
     first screened step keeps every sample — correct anyway, since near
     ``lam_max`` nearly every sample is a support vector.

2. **KKT verification** (``verify``): at the solved reduced point every
   screened sample's margin is re-checked; violators are re-admitted and the
   step re-solved (warm-started, so re-solves are cheap). On acceptance all
   screened samples have ``xi_i = 0`` *at the returned solution*, so the
   reduced and full problems share that optimum: zero false rejections at
   termination, regardless of the quality of the slack model.

This is the screening-rule formalization of solver "shrinking"
(LIBLINEAR-style), upgraded with an explicit certificate at both ends. The
per-sample inputs (``u1`` and ``||x_i||^2``) are exactly the two
feature-axis reductions the fused sample-axis Pallas kernel computes in one
transposed sweep of X (kernels/screen.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..screening import _EPS, _t_max
from .base import AXIS_SAMPLES, ConvexRegion, ScreeningRule, register_rule

__all__ = ["SampleVIRule", "sample_slack_caps", "sample_margin_surplus",
           "margin_surplus_core", "violators_from_margins"]

# stands in for the driver's "no movement bound yet" dw/db = inf inside the
# arithmetic: inf would produce 0 * inf = NaN for zero-norm sample columns,
# and NaN fails every keep comparison (silently screening the sample with a
# vacuous certificate). Matches the kernel's clamp (kernels/screen.py _BIG).
_BIG = 1e30


def sample_slack_caps(region: ConvexRegion) -> jax.Array:
    """Certified per-sample cap: ``xi_i*(lam2) <= lam2 * max_{theta in K} theta_i``.

    The stats of ``v = e_i`` against the VI set are closed-form — no data
    sweep: ``e_i^T theta1 = theta1_i``, ``e_i^T 1 = 1``, ``e_i^T y = y_i``,
    ``||e_i||^2 = 1``. Valid upper bound on the true slack (property-tested),
    but loose: the region's coordinate extent is O(ball radius), so these
    caps certify screening only for ``lam2/lam1 -> 1``. Exposed as a
    diagnostic and as the honest a-priori baseline the margin rule beats.
    """
    sh = region.shared
    y = region.y
    theta1 = region.theta1
    v_ch = 0.5 * (sh.inv_lam2 + theta1) - (sh.yc / sh.ysq) * y
    qv_sq = jnp.maximum(1.0 - y * y / sh.ysq, 0.0)
    v_a = (theta1 - sh.inv_lam1) / jnp.maximum(sh.a_norm, _EPS)
    qv_qa = v_a - y * sh.a_dot_y / sh.ysq
    t_i = _t_max(v_ch, qv_qa, qv_sq, sh)
    return region.lam2 * jnp.maximum(t_i, 0.0)


def margin_surplus_core(
    u1: jax.Array,
    y: jax.Array,
    x_sq: jax.Array,
    dw: float,
    db: float,
    u_prev: Optional[jax.Array] = None,
    shrink_factor: float = 2.0,
    margin_floor: float = 1e-3,
) -> jax.Array:
    """Surplus from precomputed margins + column norms (the slack arithmetic).

    Factored out so the local rule (:func:`sample_margin_surplus`), the
    sharded sweep (``distributed.sample_surplus_sharded`` — which psums the
    same two feature-axis reductions over the mesh), and the in-solver
    dynamic sample re-screen (``solver.fista_solve_dynamic`` with
    ``dynamic_samples=True``) finalize with *bitwise identical* scalar math;
    keep the reduction producers in sync with this signature rather than
    re-deriving the slack models. ``dw``/``db`` may be python floats or
    traced scalars (the in-solver path passes tracers), hence the jnp clamp.
    """
    dw = jnp.minimum(jnp.asarray(dw), _BIG)
    db = jnp.minimum(jnp.asarray(db), _BIG)
    slack = jnp.sqrt(x_sq) * dw + db  # huge (never screens) until history
    if u_prev is not None:
        secant = shrink_factor * jnp.abs(u1 - u_prev) + margin_floor
        slack = jnp.minimum(slack, secant)
    return y * u1 - 1.0 - slack


def violators_from_margins(y, margins, screened_idx):
    """KKT check from precomputed margins: screened samples with slack > 0.

    ``margins`` holds ``x_i^T w + b`` for the *screened* samples only
    (``margins[j]`` belongs to sample ``screened_idx[j]``). This is the
    verification arithmetic shared by :meth:`SampleVIRule.verify` (which
    computes the margins from in-core X) and the chunked path driver (which
    reads them off the solver's carried ``u = X^T w`` — zero extra
    streams). Works on numpy and jax arrays alike.
    """
    return screened_idx[y[screened_idx] * margins < 1.0]


def sample_margin_surplus(
    X: jax.Array,
    y: jax.Array,
    region: ConvexRegion,
    u_prev: Optional[jax.Array] = None,
    shrink_factor: float = 2.0,
    margin_floor: float = 1e-3,
    x_sq: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-sample screening score and the margins it was computed from.

    Returns ``(surplus, u1)`` with ``surplus_i = y_i u1_i - 1 - slack_i``;
    ``surplus_i >= 0`` predicts ``xi_i*(lam2) = 0`` (to be verified). The
    slack is the minimum of the secant model (when ``u_prev`` is given) and
    the Cauchy-Schwarz trust-region model (when ``region.dw`` is finite).
    ``x_sq`` optionally supplies the theta-independent column norms
    ``sum(X*X, axis=0)`` (cached once per path by the rule's ``prepare``).
    """
    if region.w1 is None:
        # match the data dtype (not a hardcoded float32) so x64 paths stay
        # in double precision end to end
        u1 = jnp.full(y.shape, region.b1, jnp.result_type(X.dtype, y.dtype))
    else:
        u1 = X.T @ region.w1 + region.b1
    if x_sq is None:
        x_sq = jnp.sum(X * X, axis=0)
    surplus = margin_surplus_core(
        u1, y, x_sq, region.dw, region.db, u_prev=u_prev,
        shrink_factor=shrink_factor, margin_floor=margin_floor,
    )
    return surplus, u1


@register_rule("sample_vi")
class SampleVIRule(ScreeningRule):
    """Margin-predicted sample screening with a-posteriori KKT verification.

    ``bounds`` returns the margin surplus minus the per-sample slack budget;
    ``keep`` keeps every sample whose score is negative (slack not certified
    zero). ``verify`` re-checks screened samples at the solved point — the
    driver must re-admit returned violators and re-solve before accepting.

    Stateful across path steps: the rule remembers the previous anchor's
    margins for the secant slack model; ``prepare`` (called once per path)
    resets the history.
    """

    axis = AXIS_SAMPLES
    needs_verification = True

    def __init__(self, shrink_factor: float = 2.0, margin_floor: float = 1e-3):
        self.shrink_factor = float(shrink_factor)
        self.margin_floor = float(margin_floor)
        self._u_prev: Optional[jax.Array] = None
        self._x_sq: Optional[jax.Array] = None

    def prepare(self, X: jax.Array, y: jax.Array) -> None:
        self._u_prev = None
        self._x_sq = jnp.sum(X * X, axis=0)  # theta-independent, shared

    def bounds(self, X: jax.Array, y: jax.Array, region: ConvexRegion) -> jax.Array:
        surplus, u1 = sample_margin_surplus(
            X, y, region, u_prev=self._u_prev,
            shrink_factor=self.shrink_factor, margin_floor=self.margin_floor,
            x_sq=self._x_sq,
        )
        self._u_prev = u1
        return surplus

    def keep(self, bounds: jax.Array) -> jax.Array:
        return bounds < 0.0

    def verify(self, X, y, w, b, screened_idx) -> jax.Array:
        """Screened samples whose margin at ``(w, b)`` is actually < 1."""
        u = X[:, screened_idx].T @ w + b
        return violators_from_margins(y, u, screened_idx)
