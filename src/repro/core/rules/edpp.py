"""EDPP rule: Wang et al.'s enhanced-DPP projection region for the SVM dual.

The squared-hinge L1-SVM dual is a projection problem:
``theta*(lam) = P_Theta((1/lam) 1)`` with ``Theta`` the feasible polytope
(see ``core/dual.py``). That is exactly the structure EDPP ("Scaling SVM and
Least Absolute Deviations via Exact Data Reduction", and the lasso original
"Lasso Screening Rules via Dual Polytope Projection") exploits: with
``o_k = (1/lam_k) 1``,

    v1 = o1 - theta*(lam1)        in the normal cone N_Theta(theta*(lam1)),
    v2 = o2 - theta*(lam1),
    v2perp = v2 - (<v1, v2>/||v1||^2) v1,

the firm-nonexpansiveness of projections pins ``theta*(lam2)`` inside

    Ball(theta*(lam1) + v2perp/2,  ||v2perp|| / 2).

The plain DPP ball (``v2perp -> v2``) is the paper's VI ball; projecting out
the known normal-cone direction shrinks the radius — on geometric grids
substantially — so EDPP screens strictly more in practice at *identical*
sweep cost (the bound needs the same four per-feature reductions the VI
sweep already computes; see ``rules/programs.py`` for the full bound math,
the inexact-anchor inflation, and the degenerate-``v1`` fallback).

This class is the thin host-driver wrapper over ``PROGRAMS["edpp"]``; the
program min-composes with the VI bound from the same anchor, so EDPP keeps
are provably a subset of VI keeps at equal anchors (the safe-intersection
relaxation, same principle as the DVI composition).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..screening import (
    SAFE_TAU,
    anchor_stats,
    feature_reductions,
    fixed_stats,
    row_dot,
)
from .base import ConvexRegion, register_rule
from .feature_vi import FeatureVIRule
from .programs import stack_bounds_jit

__all__ = ["EDPPRule"]


@register_rule("edpp")
class EDPPRule(FeatureVIRule):
    """A-priori-safe feature screening from the EDPP projection region
    (min-composed with the VI bound). Drop-in wherever ``feature_vi`` runs:
    host driver, every scan engine, the path server, and chunked storage."""

    program = "edpp"

    def bounds(self, X: jax.Array, y: jax.Array, region: ConvexRegion) -> jax.Array:
        d_theta = row_dot(X, y * region.theta1)
        if self._static is not None:
            d_one, d_y, d_sq = self._static
        else:
            red = feature_reductions(X, y, region.theta1)
            d_one, d_y, d_sq = red.d_one, red.d_y, red.d_sq
        fixed = fixed_stats(y, d_one, d_y, d_sq)
        a1 = anchor_stats(y, region.lam1, region.theta1, region.delta, d_theta)
        return stack_bounds_jit(("edpp",),
                                jnp.asarray(region.lam2, d_theta.dtype),
                                (a1,), fixed)
