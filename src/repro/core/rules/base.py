"""Screening-rule protocol, shared region geometry, and the rule registry.

A *screening rule* inspects the optimality region of the next path step and
certifies that some problem units (feature rows or sample columns of ``X``)
cannot influence the solution at ``lam2``, so the solver can drop them.
Every rule answers three questions:

* ``axis``    — which axis of ``X`` it reduces (``"features"`` or
  ``"samples"``);
* ``bounds``  — a per-unit scalar score derived from the region (an upper
  bound on the dual correlation for features, a lower bound on the margin
  for samples);
* ``keep``    — which units survive, given those scores.

Rules that cannot certify safety *a priori* (see
:class:`~repro.core.rules.sample_vi.SampleVIRule`) additionally implement
``verify`` so the path driver can check the screened units at the solved
point and re-admit violators before accepting a step — exact at
termination.

The :class:`ConvexRegion` bundles everything a rule may consume: the paper's
VI set ``K = Ball ∩ Halfspace ∩ Hyperplane`` for ``theta*(lam2)`` (via the
precomputed :class:`~repro.core.screening.ScreenShared` scalars), the dual
anchor ``theta1`` with its inexactness radius ``delta``, and the primal
anchor ``(w1, b1)`` with the driver's trust-region movement estimates
``(dw, db)``. Feature rules read the dual part; sample rules read the
primal part; both are built once per path step and shared across rules.

Registry: implementations self-register under a short name
(``@register_rule("feature_vi")``) so drivers, launchers, and benchmarks can
be configured with strings — ``make_rules("composite")`` — without importing
concrete classes.

The functional rule-program contract (scan lowerability)
--------------------------------------------------------
The OO protocol above is host-side: ``bounds`` may allocate, branch on
Python state, or keep history on ``self`` — none of which can run inside
the jitted engines (``svm_path(engine="scan"|"batched")``, the sharded scan,
the path server's batched step, the chunk-streamed screen). A rule becomes
engine-generic by *also* shipping a pure functional twin, a
:class:`~repro.core.rules.programs.RuleProgram`, and linking to it via the
class attribute ``program = "<name>"``. The program must provide:

* ``n_anchors`` — how much certified-anchor history the bound consumes
  (1 = the latest anchor; 2 = latest + step-before-last, which the scan
  engines then carry through the ``lax.scan`` carry);
* ``bounds(lam2, anchors, fixed)`` — a pure, collective-free, traceable
  function from the region pytree
  (:class:`~repro.core.screening.AnchorStats` anchors, oldest-to-latest,
  plus the hoisted :class:`~repro.core.screening.FixedStats`) to per-feature
  upper bounds on ``|fhat_j^T theta*(lam2)|``. Every cross-sample reduction
  must already be inside those stats — the *engine* computes them with its
  own collectives (psum on a mesh, chunk accumulation out of core), so one
  program serves local, sharded, batched, and streamed execution unchanged.

Only a-priori-safe *feature* rules qualify (``axis == "features"``,
``needs_verification == False``): sample rules need the a-posteriori KKT
loop, which is inherently host-side. ``programs.resolve_programs`` turns
any user spec into a static program-name stack and raises for rules that
don't satisfy this contract; host-only rules (``program = None``) keep
working through :class:`~repro.core.path.PathDriver` exactly as before.

Dynamic (in-solver) screening
-----------------------------
A :class:`ConvexRegion` built *between* lambda steps is frozen for the whole
solve, but the region certifying ``theta*(lam2)`` keeps shrinking while
FISTA converges: the duality gap at the current iterate certifies a
dual-feasible point within ``delta = O(sqrt(gap))`` of ``theta*(lam2)``, and
the at-lambda VI set (``lam1 = lam2``) built from it is the ball through
that point cut by its own tangent halfspace — it collapses onto
``theta*(lam2)`` as the gap goes to zero. The protocol seam is
:meth:`ScreeningRule.refresh`: rebuild the region from the current primal
iterate via ``dual.safe_theta_and_delta``. The hot path does not call the
Python hook per segment — ``solver.fista_solve_dynamic`` and
``distributed.fista_sharded(screen_every=...)`` fuse the identical refresh
(gap certificate → ``shared_scalars_from_stats`` → bound sweep) into their
jitted outer loop, ANDing each re-screen into a live feature mask;
``refresh`` is the reference implementation those solvers are property-tested
against and the entry point for driver-level (unfused) dynamic passes.
Enabled end to end via ``PathDriver(dynamic=True, screen_every=...)`` and
``launch/train_svm.py --dynamic``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..screening import SAFE_TAU, ScreenShared, shared_scalars

__all__ = [
    "ConvexRegion",
    "ScreeningRule",
    "register_rule",
    "get_rule",
    "available_rules",
    "make_rules",
    "dynamic_tau",
    "solve_with_verification",
    "AXIS_FEATURES",
    "AXIS_SAMPLES",
]

AXIS_FEATURES = "features"
AXIS_SAMPLES = "samples"


@dataclass(frozen=True)
class ConvexRegion:
    """Everything the rules may know about ``theta*(lam2)`` / ``(w*, b*)(lam2)``.

    Dual side (always present): ``theta1`` is a (near-)optimal dual point at
    ``lam1`` with ``||theta1 - theta*(lam1)|| <= delta``; ``shared`` holds the
    VI-set scalars of paper Sec. 6.4, delta-inflated so the set still contains
    ``theta*(lam2)`` under inexact solves.

    Primal side (optional): ``(w1, b1)`` is the primal anchor matching
    ``theta1`` and ``(dw, db)`` are trust-region radii — estimates of
    ``||w*(lam2) - w1||_2`` and ``|b*(lam2) - b1|`` supplied by the path
    driver from observed path movement. ``dw = inf`` (the default) makes every
    margin bound vacuous, i.e. sample rules keep everything.
    """

    y: jax.Array
    lam1: float
    lam2: float
    theta1: jax.Array
    delta: float = 0.0
    shared: Optional[ScreenShared] = None
    w1: Optional[jax.Array] = None
    b1: float = 0.0
    dw: float = float("inf")
    db: float = float("inf")
    extras: dict = field(default_factory=dict)

    @classmethod
    def build(
        cls,
        y: jax.Array,
        lam1,
        lam2,
        theta1: jax.Array,
        delta=0.0,
        w1: Optional[jax.Array] = None,
        b1=0.0,
        dw: float = float("inf"),
        db: float = float("inf"),
    ) -> "ConvexRegion":
        sh = shared_scalars(y, jnp.asarray(lam1), jnp.asarray(lam2), theta1,
                            delta=delta)
        return cls(y=y, lam1=float(lam1), lam2=float(lam2), theta1=theta1,
                   delta=delta, shared=sh, w1=w1, b1=float(b1),
                   dw=float(dw), db=float(db))

    def with_primal(self, w1, b1, dw, db) -> "ConvexRegion":
        return replace(self, w1=w1, b1=float(b1), dw=float(dw), db=float(db))


class ScreeningRule:
    """Base class / protocol for screening rules.

    Subclasses set ``name`` and ``axis`` and implement ``bounds`` + ``keep``.
    ``prepare`` is an optional once-per-path hook for theta-independent
    precomputation (paper Sec. 6.4 "precompute & share"); ``verify`` is only
    meaningful when ``needs_verification`` is True.
    """

    name: str = "base"
    axis: str = AXIS_FEATURES
    #: a-priori safe rules never reject a unit that matters; rules with
    #: ``needs_verification=True`` must be checked via :meth:`verify` at the
    #: solved point before the step is accepted.
    needs_verification: bool = False
    #: name of this rule's jittable functional twin in
    #: ``rules/programs.PROGRAMS`` (the scan-lowerable "rule program"), or
    #: ``None`` for host-only rules. See the module docstring for the
    #: contract a program must satisfy.
    program: Optional[str] = None

    # -- region -----------------------------------------------------------
    @staticmethod
    def region(y, lam1, lam2, theta1, delta=0.0, **primal) -> ConvexRegion:
        """Build the shared region (drivers usually call ConvexRegion.build)."""
        return ConvexRegion.build(y, lam1, lam2, theta1, delta=delta, **primal)

    def refresh(self, X, y, w, b, lam, sample_mask=None) -> ConvexRegion:
        """Rebuild the region from the *current iterate* mid-solve.

        Dynamic screening: ``(w, b)`` is any primal point during the solve at
        ``lam``; the duality gap there certifies a dual-feasible ``theta``
        with ``||theta - theta*(lam)|| <= delta``, and the at-lambda region
        (``lam1 = lam2 = lam``) built from it tightens monotonically (in
        delta) as the solver converges. Safe for any rule that is safe on a
        sequential region — it is the same geometry with a coincident grid
        point. ``sample_mask`` restricts the certificate to the live
        (unscreened) samples of a reduced problem.
        """
        from ..solver import gap_theta_delta  # local import: no cycle at load

        theta, delta, _gap = gap_theta_delta(X, y, w, b, jnp.asarray(lam),
                                             sample_mask=sample_mask)
        return ConvexRegion.build(y, lam, lam, theta, delta=delta,
                                  w1=w, b1=float(b))

    # -- per-unit scores --------------------------------------------------
    def prepare(self, X: jax.Array, y: jax.Array) -> None:
        """Optional once-per-path precomputation hook (default: no-op)."""

    def bounds(self, X: jax.Array, y: jax.Array, region: ConvexRegion) -> jax.Array:
        raise NotImplementedError

    def keep(self, bounds: jax.Array) -> jax.Array:
        raise NotImplementedError

    def screen(self, X, y, region) -> tuple[jax.Array, jax.Array]:
        b = self.bounds(X, y, region)
        return self.keep(b), b

    # -- a posteriori check (verified rules only) -------------------------
    def verify(self, X, y, w, b, screened_idx) -> jax.Array:
        """Indices (into ``screened_idx``) violating the certificate at (w, b)."""
        raise NotImplementedError(f"rule {self.name!r} is a-priori safe")

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{type(self).__name__}(name={self.name!r}, axis={self.axis!r})"


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_RULES: dict[str, type] = {}


def register_rule(name: str):
    """Class decorator: register a ScreeningRule under ``name``."""

    def deco(cls):
        cls.name = name
        _RULES[name] = cls
        return cls

    return deco


def available_rules() -> tuple[str, ...]:
    return tuple(sorted(_RULES))


def get_rule(name: str, **kwargs) -> ScreeningRule:
    try:
        cls = _RULES[name]
    except KeyError:
        raise KeyError(
            f"unknown screening rule {name!r}; available: {available_rules()}"
        ) from None
    return cls(**kwargs)


def solve_with_verification(
    solve: Callable[[np.ndarray], tuple],
    sample_rules: Sequence[ScreeningRule],
    X_np: np.ndarray,
    y_np: np.ndarray,
    s_mask: np.ndarray,
    max_rounds: int = 3,
):
    """The verified-sample-screening solve protocol, shared by every driver.

    ``solve(s_mask) -> (result, w_full, b)`` solves the reduced problem with
    the given sample keep-mask (warm-starting is the closure's business).
    Screened samples are then margin-checked at the solution by each
    verifying rule; violators are re-admitted and the solve repeated. After
    ``max_rounds`` re-solves the mask is reset entirely (exact full-sample
    solve), so termination is guaranteed and the accepted solution always
    satisfies every screened sample's ``xi_i = 0`` certificate.

    Mutates ``s_mask`` in place; returns ``(result, w_full, b, rounds)``.
    """
    rounds = 0
    while True:
        res, w_full, b = solve(s_mask)
        if s_mask.all() or not sample_rules:
            return res, w_full, b, rounds
        scr_idx = np.nonzero(~s_mask)[0]
        viols = [
            np.asarray(rule.verify(X_np, y_np, w_full, b, scr_idx))
            for rule in sample_rules if rule.needs_verification
        ]
        viol = np.concatenate(viols) if viols else np.zeros((0,), np.int64)
        if len(viol) == 0:
            return res, w_full, b, rounds
        rounds += 1
        if rounds >= max_rounds:
            s_mask[:] = True  # give up screening this step: exact solve
        else:
            s_mask[np.unique(viol).astype(np.int64)] = True


def dynamic_tau(rules: Sequence[ScreeningRule]) -> float:
    """The in-solver (dynamic) screen's keep threshold for a rule mix.

    The most conservative configured feature-rule tau — ``min`` because
    ``keep = bounds >= tau``, so a smaller tau keeps more — falling back to
    ``SAFE_TAU`` when no feature rule carries one. The single source of this
    policy for both the local ``PathDriver`` and the sharded launcher.
    """
    taus = [float(r.tau) for r in rules
            if r.axis == AXIS_FEATURES and hasattr(r, "tau")]
    return min(taus) if taus else SAFE_TAU


RuleSpec = Union[None, str, ScreeningRule, Sequence[Union[str, ScreeningRule]]]


def make_rules(spec: RuleSpec) -> list[ScreeningRule]:
    """Normalize a rule spec into a flat list of rule instances.

    Accepts ``None`` / ``[]`` (no screening), a registry name, a rule
    instance, or a sequence of either. Composite rules are flattened into
    their constituents so drivers see one rule per axis pass.
    """
    if spec is None:
        return []
    if isinstance(spec, (str, ScreeningRule)):
        spec = [spec]
    rules: list[ScreeningRule] = []
    for item in spec:
        rule = get_rule(item) if isinstance(item, str) else item
        sub = getattr(rule, "subrules", None)
        if sub is not None:
            rules.extend(sub())
        else:
            rules.append(rule)
    return rules
