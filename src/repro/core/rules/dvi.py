"""DVI rule: dual variational-inequality screening from a *pair* of anchors.

The sequential feature rule certifies ``theta*(lam2)`` from the most recent
anchor ``theta(lam1)`` only. But along a path every previously solved dual
point is a valid anchor: the VI set built from the step-before-last point
``theta(lam0)`` (with its own inexactness radius ``delta0``) also contains
``theta*(lam2)`` whenever ``lam0 > lam2``. Intersecting the two sets can
only shrink the certificate, and the cheap relaxation of the intersection is
the elementwise minimum of the two per-feature bounds — each is a valid
upper bound on ``|fhat_j^T theta*(lam2)|``, so their min is too (this is the
"DVI" composition of Liu et al., "Safe Screening with Variational
Inequalities and Its Application to Lasso", transplanted to the paper's
squared-hinge dual geometry).

When it helps: near a kink of the path the latest anchor's halfspace can be
nearly uninformative (``theta(lam1) - 1/lam1`` almost parallel to ``y``)
while the older anchor still cuts the ball; and for a *coarse* grid the
older region's smaller ``1/lam0`` offset occasionally dominates. Cost: one
extra ``X @ (y * theta0)`` sweep per step — the three theta-independent
reductions are shared with the primary bound via the cached statics.

Stateful like the sample rule: ``bounds`` remembers the incoming region's
anchor for the next step; ``prepare`` resets the history (so the first
screened step, having one anchor only, degenerates exactly to feature_vi).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..screening import (
    SAFE_TAU,
    FeatureReductions,
    screen_bounds_from_reductions,
    shared_scalars,
)
from .base import ConvexRegion, register_rule
from .feature_vi import FeatureVIRule

__all__ = ["DVIRule"]


@register_rule("dvi")
class DVIRule(FeatureVIRule):
    """Feature screening from the min of the last and step-before-last
    anchors' VI bounds. A-priori safe (each constituent bound is).

    Scan-lowerable via ``PROGRAMS["dvi"]`` (``n_anchors = 2``): the scan
    engines carry the step-before-last anchor in the scan carry instead of
    on this object, seeding it with a copy of the initial anchor so step 1
    degenerates to plain VI exactly like the host path does.
    """

    program = "dvi"

    def __init__(self, tau: float = SAFE_TAU):
        super().__init__(tau=tau)
        self._anchor: Optional[tuple] = None  # (lam0, theta0, delta0)

    def prepare(self, X: jax.Array, y: jax.Array) -> None:
        super().prepare(X, y)
        self._anchor = None

    def bounds(self, X: jax.Array, y: jax.Array, region: ConvexRegion) -> jax.Array:
        b = super().bounds(X, y, region)
        anchor = self._anchor
        # the old anchor certifies theta*(lam2) only when screening downward
        # from it (lam0 > lam2); a replayed/non-monotone step invalidates it
        if anchor is not None and anchor[0] > region.lam2:
            lam0, theta0, delta0 = anchor
            sh0 = shared_scalars(y, jnp.asarray(lam0), jnp.asarray(region.lam2),
                                 theta0, delta=delta0)
            d_theta0 = X @ (y * theta0)
            if self._static is not None:
                d_one, d_y, d_sq = self._static
                red0 = FeatureReductions(d_theta=d_theta0, d_one=d_one,
                                         d_y=d_y, d_sq=d_sq)
            else:
                ones = jnp.ones((X.shape[1],), X.dtype)
                red0 = FeatureReductions(d_theta=d_theta0, d_one=X @ y,
                                         d_y=X @ ones, d_sq=jnp.sum(X * X, axis=1))
            b = jnp.minimum(b, screen_bounds_from_reductions(red0, sh0))
        self._anchor = (region.lam1, region.theta1, region.delta)
        return b
