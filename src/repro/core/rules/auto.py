"""``rules="auto"``: telemetry-driven per-step rule-stack selection.

The ROADMAP's closing-the-loop item: bounds are cheap relative to solves,
so running *several* rules and intersecting pays exactly when the predicted
solver-FLOP saving exceeds the extra sweep's cost. This rule implements
that policy with the telemetry the driver already observes per step (kept
counts, screen/solve wall split):

* The EDPP bound is always evaluated — it shares every reduction with the
  VI sweep (zero extra data passes) and its region is min-composed with
  VI's, so it dominates ``feature_vi`` at identical cost. This is the
  "free" floor of the stack.
* The one genuinely *optional* sweep in the zoo is the DVI old-anchor bound
  (one extra ``X @ (y * theta0)`` pass). Its payoff is measured, not
  assumed: every ``probe_every`` steps the sweep runs and we record how
  many extra features it screened and what it cost; between probes it keeps
  running only while

      (extra features screened) x (EMA solve-seconds per kept feature)
          > (EMA sweep seconds)

  i.e. while the predicted solve saving pays for the sweep. The driver
  feeds solve walls in through :meth:`observe` after each step.

Safety is unconditional — every candidate bound is individually safe, so
any intersection is; the policy only decides *spend*, never correctness.

On one-shot engines (``engine="scan"`` etc.) there is no per-step host in
the loop to observe telemetry, so ``"auto"`` resolves statically to the
dominant free stack ``("edpp",)`` via ``program = "edpp"``.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..screening import (
    SAFE_TAU,
    anchor_stats,
    feature_reductions,
    fixed_stats,
    row_dot,
)
from .base import ConvexRegion, register_rule
from .feature_vi import FeatureVIRule
from .programs import stack_bounds_jit

__all__ = ["AutoRule"]


@register_rule("auto")
class AutoRule(FeatureVIRule):
    """Auto-tuned feature-rule stack: EDPP always, the DVI old-anchor sweep
    when its measured payoff covers its measured cost."""

    program = "edpp"  # static resolution on engines with no host in the loop

    def __init__(self, tau: float = SAFE_TAU, probe_every: int = 3):
        super().__init__(tau=tau)
        self.probe_every = int(probe_every)
        self._anchor: Optional[tuple] = None   # (lam0, theta0, delta0)
        self._solve_per_feat: Optional[float] = None  # EMA sec / kept feature
        self._use_extra = False
        self._since_probe = 0
        self.telemetry: list[dict] = []

    def prepare(self, X: jax.Array, y: jax.Array) -> None:
        super().prepare(X, y)
        self._anchor = None
        self._use_extra = False
        self._since_probe = 0
        self.telemetry = []

    # -- the driver's per-step telemetry hook ------------------------------
    def observe(self, *, solve_seconds: float, kept: int, **_) -> None:
        """Fold one step's solve wall into the cost model (EMA)."""
        per = float(solve_seconds) / max(int(kept), 1)
        self._solve_per_feat = (per if self._solve_per_feat is None
                                else 0.5 * self._solve_per_feat + 0.5 * per)

    # -- bounds ------------------------------------------------------------
    def _stats(self, X, y, region):
        d_theta = row_dot(X, y * region.theta1)
        if self._static is not None:
            d_one, d_y, d_sq = self._static
        else:
            red = feature_reductions(X, y, region.theta1)
            d_one, d_y, d_sq = red.d_one, red.d_y, red.d_sq
        fixed = fixed_stats(y, d_one, d_y, d_sq)
        a1 = anchor_stats(y, region.lam1, region.theta1, region.delta, d_theta)
        return fixed, a1

    def bounds(self, X: jax.Array, y: jax.Array, region: ConvexRegion) -> jax.Array:
        fixed, a1 = self._stats(X, y, region)
        lam2 = jnp.asarray(region.lam2, a1.d_theta.dtype)
        b = stack_bounds_jit(("edpp",), lam2, (a1,), fixed)

        anchor = self._anchor
        probe = self._since_probe >= self.probe_every
        step_info = dict(extra_swept=False, extra_screened=0, sweep_s=0.0)
        if anchor is not None and anchor[0] > region.lam2 and (
                self._use_extra or probe):
            lam0, theta0, delta0 = anchor
            t0 = time.perf_counter()
            a0 = anchor_stats(y, lam0, theta0, delta0,
                              row_dot(X, y * theta0))
            b0 = stack_bounds_jit(("feature_vi",), lam2, (a0,), fixed)
            b_np = np.asarray(b)
            b0_np = np.asarray(b0)  # forces the sweep; honest wall
            sweep_s = time.perf_counter() - t0
            extra = int(np.sum(b_np >= self.tau)
                        - np.sum(np.minimum(b_np, b0_np) >= self.tau))
            saving = extra * (self._solve_per_feat or 0.0)
            self._use_extra = saving > sweep_s
            self._since_probe = 0
            b = jnp.minimum(b, b0)
            step_info = dict(extra_swept=True, extra_screened=extra,
                             sweep_s=sweep_s)
        else:
            self._since_probe += 1
        self._anchor = (region.lam1, region.theta1, region.delta)
        self.telemetry.append(dict(lam2=float(region.lam2),
                                   use_extra=self._use_extra, **step_info))
        return b
