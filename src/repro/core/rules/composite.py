"""Simultaneous feature + sample reduction (Zhang et al.-style composition).

The two axes compose multiplicatively: feature screening shrinks the m-axis
of the solver GEMMs, sample screening the n-axis, so the reduced problem
costs ``kept_m * kept_n`` instead of ``m * n``. Both rules read the same
:class:`~repro.core.rules.base.ConvexRegion`, so the composite costs one
region build plus one bound sweep per axis per path step; the driver applies
them in sequence (feature mask, then sample mask) and runs the sample rule's
verification loop on the combined reduction.
"""

from __future__ import annotations

from typing import Optional, Sequence

from .base import ScreeningRule, register_rule
from .feature_vi import FeatureVIRule
from .sample_vi import SampleVIRule

__all__ = ["CompositeRule"]


@register_rule("composite")
class CompositeRule(ScreeningRule):
    """Container rule: alternates every constituent rule at each path step.

    ``make_rules`` flattens it, so ``rules="composite"`` is equivalent to
    ``rules=["feature_vi", "sample_vi"]``; custom mixtures can be composed by
    passing instances: ``CompositeRule([FeatureVIRule(tau=...), ...])``.
    """

    axis = "both"

    def __init__(self, rules: Optional[Sequence[ScreeningRule]] = None):
        self.rules: list[ScreeningRule] = (
            list(rules) if rules is not None else [FeatureVIRule(), SampleVIRule()]
        )

    def subrules(self) -> list[ScreeningRule]:
        return list(self.rules)

    def bounds(self, X, y, region):  # pragma: no cover - container only
        raise NotImplementedError(
            "CompositeRule is a container; flatten with make_rules() and "
            "apply each constituent per axis"
        )
