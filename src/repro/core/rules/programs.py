"""Jittable functional core of the screening-rule zoo: *rule programs*.

The OO :class:`~repro.core.rules.base.ScreeningRule` protocol is the host
driver's configuration surface; the fast engines (``scan`` / ``compact`` /
``batched`` / ``sharded`` / streamed) cannot call host objects from inside a
jitted step. This module is the seam between the two worlds: each
a-priori-safe *feature* rule is lowered to a :class:`RuleProgram` — a pure
function from a region pytree (:class:`~repro.core.screening.AnchorStats`
anchors + :class:`~repro.core.screening.FixedStats` statics) to per-feature
bound scores — and the engines evaluate a static *stack* of programs by
ANDing their keeps (equivalently: taking the elementwise min of their
bounds) inside the step.

Contract (what makes a rule scan-lowerable)
-------------------------------------------
* ``n_anchors`` declares how much anchor history the program consumes: 1 =
  the latest certified anchor only; 2 = latest plus the step-before-last
  (the scan engines extend their carry with the older anchor exactly when
  some program in the stack asks for it).
* ``bounds(lam2, anchors, fixed)`` must be pure, collective-free, and
  traceable — every cross-sample reduction it needs must already be inside
  the :class:`AnchorStats`/:class:`FixedStats` inputs, which the *engine*
  computes with its own collectives (psum on a mesh, chunk accumulation out
  of core). ``anchors`` is oldest-to-latest, length ``n_anchors``.
* The score convention is the VI rule's: an upper bound on
  ``|fhat_j^T theta*(lam2)|``; features with ``bounds < tau`` are safely
  dropped. Programs for regions that are not supersets of the VI set must
  still return a *valid* upper bound (min-composition with other programs
  is then automatically safe).

Programs
--------
``feature_vi``
    The paper's VI region (Ball ∩ Halfspace ∩ Hyperplane), one anchor.
``dvi``
    Elementwise min of the latest and step-before-last anchors' VI bounds
    (Liu et al.-style composition), two anchors. Degenerates to plain VI
    when the older anchor duplicates the latest (how scan seeds step 1).
``edpp``
    Wang et al.'s enhanced-DPP projection region, one anchor. The dual path
    optimum is the projection ``theta*(lam) = P_Theta((1/lam) 1)``, so
    ``v1 = o1 - theta1`` (with ``o1 = (1/lam1) 1``) lies in the normal cone
    at ``theta1`` and Wang et al.'s Thm. 19 confines ``theta*(lam2)`` to

        || theta2 - (theta1 + v2perp/2) || <= ||v2perp|| / 2,
        v2 = o2 - theta1,  v2perp = v2 - (<v1,v2>/||v1||^2) v1.

    All scalar geometry falls out of the same four reductions the VI sweep
    already computes — EDPP costs *zero extra data passes*. The ball is
    intersected with the ``y^T theta = 0`` hyperplane (dual feasibility)
    and, for the subset guarantee the engines advertise, min-composed with
    the VI bound from the same anchor: EDPP keeps are provably a subset of
    VI keeps at equal anchors. Inexact anchors (``delta > 0``) inflate the
    projection radius by the normal-cone perturbation bound (see
    ``_edpp_bounds``); near-degenerate ``v1`` (balanced classes at
    ``lam_max``, or ``||v1|| ~ delta``) falls back to the plain DPP ball =
    the VI ball.

Stacks
------
:func:`resolve_programs` normalizes any user-facing rules spec (string
name, iterable, rule instances, composite containers — everything
:func:`~repro.core.rules.base.make_rules` accepts) into a static tuple of
program names, raising ``ValueError`` for rules that cannot be lowered
(sample rules need verification; the engines support the a-priori-safe
feature rule only specs). ``"auto"`` resolves to ``("edpp",)`` on one-shot
engines: EDPP dominates VI at identical sweep cost, and the telemetry that
could justify extra sweeps only exists on the host driver (see
``core/rules/auto.py``).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..screening import (
    _EPS,
    AnchorStats,
    FixedStats,
    finalize_from_anchor,
)

__all__ = [
    "RuleProgram",
    "PROGRAMS",
    "resolve_programs",
    "stack_bounds",
    "stack_bounds_jit",
    "stack_needs_history",
    "max_anchors",
]


class RuleProgram(NamedTuple):
    """A scan-lowerable screening rule: pure bounds over precomputed stats."""

    name: str
    n_anchors: int
    bounds: Callable[..., jax.Array]  # (lam2, anchors, fixed) -> (m,)


def _vi_bounds(lam2, anchors: Tuple[AnchorStats, ...],
               fixed: FixedStats) -> jax.Array:
    """Paper VI region from the latest anchor (identical arithmetic to the
    pre-refactor engine code paths)."""
    return finalize_from_anchor(anchors[-1], lam2, fixed)


def _dvi_bounds(lam2, anchors: Tuple[AnchorStats, ...],
                fixed: FixedStats) -> jax.Array:
    """Min of latest and step-before-last VI bounds. The older anchor only
    contributes while its ``lam`` still exceeds ``lam2`` (always true on a
    decreasing grid, but cheap to guard for custom grids)."""
    b = finalize_from_anchor(anchors[-1], lam2, fixed)
    if len(anchors) >= 2:
        a0 = anchors[0]
        b0 = finalize_from_anchor(a0, lam2, fixed)
        b = jnp.where(a0.lam > jnp.asarray(lam2, b.dtype), jnp.minimum(b, b0), b)
    return b


def _edpp_bounds(lam2, anchors: Tuple[AnchorStats, ...],
                 fixed: FixedStats) -> jax.Array:
    """EDPP projection ball ∩ hyperplane, min-composed with the VI bound.

    Geometry (everything from the anchor's scalars; o_k = (1/lam_k) 1):

        v1 = o1 - theta1          (normal-cone direction at theta1)
        v2 = o2 - theta1          (DPP ball diameter; ||v2||/2 = VI radius)
        v2perp = v2 - mu v1,  mu = <v1, v2>/||v1||^2
        theta2 in Ball(theta1 + v2perp/2, ||v2perp||/2)

    Inexact anchor (||theta1 - theta1*|| <= delta): v1 and v2 each move by
    at most delta, and the rank-1 projector along v1 moves by at most
    2 delta / (||v1|| - delta), so the true ball sits inside ours after
    inflating the radius by  2 delta + 2 delta (||v2|| + delta) /
    max(||v1|| - delta, eps). When ||v1|| is itself at noise scale the
    projection direction is meaningless: fall back to mu = 0, which is the
    plain DPP ball = the VI ball with the standard delta inflation.
    """
    a = anchors[-1]
    lam2 = jnp.asarray(lam2, a.d_theta.dtype)
    inv1 = 1.0 / a.lam
    inv2 = 1.0 / lam2
    ysq = fixed.n_tot

    # scalar geometry of v1, v2
    v1_sq = a.theta_sq - 2.0 * inv1 * a.theta_dot_one + inv1 * inv1 * fixed.n_tot
    v2_sq = a.theta_sq - 2.0 * inv2 * a.theta_dot_one + inv2 * inv2 * fixed.n_tot
    v1v2 = (inv1 * inv2 * fixed.n_tot
            - (inv1 + inv2) * a.theta_dot_one + a.theta_sq)
    v1_norm = jnp.sqrt(jnp.maximum(v1_sq, 0.0))
    v2_norm = jnp.sqrt(jnp.maximum(v2_sq, 0.0))

    # degenerate normal-cone direction: theta1 ~ o1 analytically (balanced
    # classes at lam_max) or ||v1|| drowned by the inexactness radius
    scale = jnp.sqrt(a.theta_sq + inv1 * inv1 * fixed.n_tot)
    degenerate = v1_norm <= jnp.maximum(10.0 * a.delta, 1e-6 * scale)
    mu = jnp.where(degenerate, 0.0, v1v2 / jnp.maximum(v1_sq, _EPS))

    # projection ball: center theta1 + v2perp/2, radius ||v2perp||/2
    vperp_sq = jnp.maximum(v2_sq - 2.0 * mu * v1v2 + mu * mu * v1_sq, 0.0)
    r = 0.5 * jnp.sqrt(vperp_sq)
    infl = jnp.where(
        degenerate, a.delta,
        2.0 * a.delta + 2.0 * a.delta * (v2_norm + a.delta)
        / jnp.maximum(v1_norm - a.delta, _EPS))
    r_infl = r + infl

    # intersect with the dual-feasibility hyperplane y^T theta = 0
    y_v1 = inv1 * fixed.one_y - a.theta_dot_y
    y_v2 = inv2 * fixed.one_y - a.theta_dot_y
    yc = a.theta_dot_y + 0.5 * (y_v2 - mu * y_v1)     # y^T center
    r_h_sq = r_infl * r_infl - yc * yc / ysq

    # per-feature terms, v = fhat_j
    v_v1 = inv1 * fixed.d_one - a.d_theta
    v_v2 = inv2 * fixed.d_one - a.d_theta
    v_c = a.d_theta + 0.5 * (v_v2 - mu * v_v1)        # fhat^T center
    v_ch = v_c - (yc / ysq) * fixed.d_y
    qv_sq = jnp.maximum(fixed.d_sq - fixed.d_y * fixed.d_y / ysq, 0.0)
    ball = (jnp.abs(v_ch)
            + jnp.sqrt(jnp.maximum(r_h_sq, 0.0)) * jnp.sqrt(qv_sq))

    # min-compose with the VI bound from the same anchor: valid (both
    # regions contain theta2, so the min of the maxes is an upper bound on
    # the max over their intersection) and it guarantees EDPP keeps are a
    # subset of VI keeps at equal anchors.
    return jnp.minimum(ball, _vi_bounds(lam2, anchors, fixed))


PROGRAMS = {
    "feature_vi": RuleProgram("feature_vi", 1, _vi_bounds),
    "dvi": RuleProgram("dvi", 2, _dvi_bounds),
    "edpp": RuleProgram("edpp", 1, _edpp_bounds),
}


def max_anchors(programs: Sequence[RuleProgram]) -> int:
    return max((p.n_anchors for p in programs), default=1)


def stack_needs_history(programs: Sequence[RuleProgram]) -> bool:
    """Does this stack need the step-before-last anchor carried?"""
    return max_anchors(programs) > 1


def stack_bounds(programs: Sequence[RuleProgram], lam2,
                 anchors: Tuple[AnchorStats, ...],
                 fixed: FixedStats) -> jax.Array:
    """Elementwise-min bound of a rule stack (AND of the keeps).

    ``anchors`` is oldest-to-latest; each program sees the most recent
    ``n_anchors`` of them. Valid because every program's bound is an upper
    bound on the same quantity — intersection of safe regions is safe.
    """
    b = None
    for p in programs:
        pb = p.bounds(lam2, anchors[-p.n_anchors:], fixed)
        b = pb if b is None else jnp.minimum(b, pb)
    return b


@partial(jax.jit, static_argnames=("names",))
def stack_bounds_jit(names: tuple, lam2, anchors: Tuple[AnchorStats, ...],
                     fixed: FixedStats) -> jax.Array:
    """Jitted :func:`stack_bounds`, keyed by program *names* (static).

    The host-driver rule wrappers (EDPP, auto) go through this instead of
    the eager composition: a stack bound is dozens of small elementwise
    ops, and per-step eager dispatch costs more than the sweep itself on
    mid-size instances. One compile per stack shape; anchors/fixed are
    pytrees so the cache keys only on names + dtypes/shapes.
    """
    return stack_bounds(tuple(PROGRAMS[nm] for nm in names), lam2, anchors,
                        fixed)


def resolve_programs(spec, screening: bool = True) -> tuple:
    """Normalize a user rules spec into a static tuple of program names.

    ``None`` defers to the legacy ``screening`` flag (the VI rule, or no
    screening); ``"none"``/empty specs disable screening. Anything else is
    flattened through :func:`~repro.core.rules.base.make_rules` (so strings,
    instances, composites, and mixes all work) and each flattened rule must
    link to a registered :class:`RuleProgram` via its ``program`` attribute.
    Raises ``ValueError`` naming the offending rules otherwise — on-device
    engines must reject, not silently ignore, specs they can't lower.
    """
    if spec is None:
        return ("feature_vi",) if screening else ()
    if isinstance(spec, str) and spec.lower() in ("none", ""):
        return ()
    from .base import AXIS_FEATURES, make_rules

    rules = make_rules(spec)
    if not rules:
        return ()
    names, bad = [], []
    for r in rules:
        prog = getattr(r, "program", None)
        if (prog is None or prog not in PROGRAMS
                or r.axis != AXIS_FEATURES or r.needs_verification):
            bad.append(r.name)
        else:
            names.append(prog)
    if bad:
        raise ValueError(
            "on-device engines support a-priori-safe feature rule only "
            f"specs (scan-lowerable programs: {tuple(sorted(PROGRAMS))}); "
            f"cannot lower rule(s) {bad!r} — use engine='host' for rules "
            "that need verification or the sample axis"
        )
    # dedupe preserving order: evaluating a program twice is pure waste
    return tuple(dict.fromkeys(names))
