"""Paper core: safe screening for the L1-regularized L2-loss SVM."""

from .dual import (  # noqa: F401
    bias_at_lambda_max,
    duality_gap_estimate,
    first_features,
    lambda_max,
    primal_objective,
    theta_at_lambda_max,
    theta_from_primal,
    xi_from_primal,
)
from .screening import (  # noqa: F401
    SAFE_TAU,
    AnchorStats,
    FeatureReductions,
    FixedStats,
    ScreenShared,
    anchor_stats,
    feature_reductions,
    finalize_from_anchor,
    fixed_stats,
    screen,
    screen_bounds,
    screen_bounds_from_reductions,
    shared_scalars,
    shared_scalars_from_anchor,
    shared_scalars_from_stats,
)
from .solver import (  # noqa: F401
    DynamicFistaResult,
    FistaResult,
    fista_run,
    fista_solve,
    fista_solve_dynamic,
    gap_theta_delta,
    lipschitz_estimate,
    soft_threshold,
)
from .path import PathDriver, PathResult, default_lambda_grid, svm_path  # noqa: F401
from .path_scan import (  # noqa: F401
    ScanPathOutputs,
    compact_caps,
    compact_caps_batched,
    engine_cache_info,
    svm_path_batched,
    svm_path_scan,
    svm_path_scan_sharded,
)
from .rules import (  # noqa: F401
    PROGRAMS,
    AutoRule,
    CompositeRule,
    ConvexRegion,
    DVIRule,
    EDPPRule,
    FeatureVIRule,
    RuleProgram,
    SampleVIRule,
    ScreeningRule,
    SIFSRule,
    available_rules,
    get_rule,
    make_rules,
    resolve_programs,
)
