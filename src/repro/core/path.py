"""Rule-agnostic regularization-path driver with pluggable screening.

Walks a decreasing grid ``lam_max = lam_0 > lam_1 > ... > lam_{T-1}``. At each
step the previous primal/dual pair parameterizes a
:class:`~repro.core.rules.base.ConvexRegion`; every configured
:class:`~repro.core.rules.base.ScreeningRule` then contributes a keep-mask on
its axis (feature rows and/or sample columns of ``X``), the reduced problem
is solved with a warm-started FISTA, and the solution is scattered back to
full coordinates. Rules that are not a-priori safe (``needs_verification``)
are checked at the solved point and violators re-admitted before the step is
accepted — so the accepted solution is exact regardless of screening.

Two execution modes, applied on *both* axes:

* ``reduce="gather"`` — physically gathers kept rows/columns (padded to a
  power-of-two bucket so jit re-traces at most O(log) times). Solver cost
  scales with ``kept_features x kept_samples`` — the multiplicative payoff of
  simultaneous reduction.
* ``reduce="mask"``   — static shapes; screened features are zeroed rows,
  screened samples are dropped from the loss via the solver's
  ``sample_mask`` (zeroing columns would *not* be equivalent: an all-zero
  column still contributes ``max(0, 1 - y_i b)^2`` to the loss).

Trust-region movement estimates for the sample rule come from observed path
movement: after each accepted step the driver records
``||w_k - w_{k-1}||_2`` and ``|b_k - b_{k-1}|`` and predicts the next step's
movement as ``shrink_factor`` times that (first-order continuation on a
geometric grid). The first screened step has no history and keeps all
samples — correct anyway, since near ``lam_max`` nearly every sample is a
support vector.

Exactness: feature rules are safe given ``||theta1 - theta*|| <= delta``
(gap-certified, see dual.safe_theta_and_delta); sample rules are exact at
termination via the verification loop. Property tests cover both
(tests/test_screening.py, tests/test_rules.py).

Engines: this host-orchestrated driver (``engine="host"``) is one of two
path engines — ``core/path_scan.py`` runs the same feature-screened path as
a single jitted ``lax.scan`` program (``engine="scan"``), trading the
gather-mode FLOP reduction and the sample-verification loop for zero
per-step host round trips. Rule of thumb: gather mode shrinks FLOPs, scan
mode kills orchestration overhead. ``svm_path(engine=...)`` selects.

The Lipschitz constant is estimated once per path on the full ``X`` and
reused by every reduced solve — masking/gathering rows or columns never
increases ``sigma_max``, so the full-matrix estimate stays a valid step
bound (and saves the 30-iteration power sweep per solve, per verification
round). ``exact_lipschitz=True`` restores the per-solve estimate.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .dual import (
    bias_at_lambda_max,
    lambda_max,
    safe_theta_and_delta,
    theta_at_lambda_max,
)
from .rules import (
    AXIS_FEATURES,
    AXIS_SAMPLES,
    ConvexRegion,
    FeatureVIRule,
    SampleVIRule,
    make_rules,
)
from .rules.base import dynamic_tau, solve_with_verification
from .screening import SAFE_TAU
from .solver import (
    DynamicFistaResult,
    fista_solve,
    fista_solve_dynamic,
    lipschitz_estimate,
)


def _is_chunked(X) -> bool:
    """Duck-typed ``repro.sparse.FeatureChunked`` check (no import cycle)."""
    return hasattr(X, "stream") and hasattr(X, "gather_rows")


def _validate_grid(lambdas) -> np.ndarray:
    lambdas = np.asarray(lambdas, dtype=np.float64)
    if lambdas.size == 0:
        raise ValueError("empty lambda grid")
    if not np.all(np.isfinite(lambdas)) or np.any(lambdas <= 0):
        raise ValueError(f"lambda grid must be finite and positive: {lambdas}")
    if np.any(np.diff(lambdas) >= 0):
        raise ValueError(
            "lambda grid must be strictly decreasing (screening regions "
            f"certify theta*(lam2) only for lam2 < lam1): {lambdas}"
        )
    return lambdas

__all__ = ["PathResult", "PathDriver", "svm_path", "default_lambda_grid"]


@dataclass
class PathResult:
    lambdas: np.ndarray            # (T,)
    weights: np.ndarray            # (T, m)
    biases: np.ndarray             # (T,)
    objectives: np.ndarray         # (T,)
    kept: np.ndarray               # (T,) kept feature count fed to the solver
    active: np.ndarray             # (T,) nnz(w) in the solution
    solver_iters: np.ndarray       # (T,)
    wall_times: np.ndarray         # (T,) seconds per step (solve + screen)
    screen_times: np.ndarray       # (T,) seconds spent screening
    screened: bool = True
    kept_samples: np.ndarray = None  # (T,) samples fed to the solver
    verify_rounds: np.ndarray = None  # (T,) sample-verification re-solves
    rules: tuple = ()
    extras: dict = field(default_factory=dict)


def default_lambda_grid(lam_max_val: float, n_lambdas: int = 10, lam_min_ratio: float = 0.1) -> np.ndarray:
    return np.geomspace(lam_max_val, lam_max_val * lam_min_ratio, n_lambdas)


def _bucket(n: int) -> int:
    """Round up to the next power of two (min 8) to bound retracing."""
    b = 8
    while b < n:
        b *= 2
    return b


def _dynamic_telemetry(res: DynamicFistaResult) -> dict:
    """Host-side view of one dynamic solve's per-segment screening trace."""
    s = int(res.n_segments)
    out = {
        "segments": s,
        "kept_per_segment": [int(v) for v in np.asarray(res.kept_per_segment)[:s]],
        "gap_per_segment": [float(v) for v in np.asarray(res.gap_per_segment)[:s]],
    }
    if res.kept_samples_per_segment is not None:
        out["kept_samples_per_segment"] = [
            int(v) for v in np.asarray(res.kept_samples_per_segment)[:s]
        ]
    return out


class PathDriver:
    """Applies an arbitrary list of screening rules along the lambda path.

    ``rules`` accepts anything :func:`~repro.core.rules.base.make_rules`
    does: ``"feature_vi"``, ``"sample_vi"``, ``"composite"``, a list of
    names, or rule instances. An empty list solves the unscreened path.
    """

    def __init__(
        self,
        rules="feature_vi",
        *,
        reduce: str = "gather",
        tol: float = 1e-9,
        max_iters: int = 4000,
        shrink_factor: float = 1.5,
        max_verify_rounds: int = 3,
        dynamic: bool = False,
        screen_every: int = 50,
        exact_lipschitz: bool = False,
        use_pallas: Optional[bool] = None,
        L=None,
    ):
        """``dynamic=True`` swaps every solve for the segmented
        ``solver.fista_solve_dynamic``: the step's sequential screen seeds a
        live feature mask that the solver keeps tightening every
        ``screen_every`` iterations from the gap-certified at-lambda region.
        Per-step, per-segment kept-counts/gaps land in
        ``PathResult.extras["dynamic"]``. Safe with any rule mix (the
        in-solver screen is a-priori safe on its own certificate).

        ``exact_lipschitz=True`` re-estimates L per reduced solve instead of
        reusing the full-X upper bound computed once per path (see module
        docstring); ``use_pallas`` routes the FISTA hot-loop sweeps through
        the fused Pallas kernels (None = env/backend policy).

        ``L`` (optional): a known upper bound on the Lipschitz constant of
        ``[X; 1^T]`` — skips the per-path power iteration entirely. The
        bound is a property of the matrix, not of how it is stored, so
        passing one value to several storage engines (dense / chunked /
        CSR) gives them floating-point-identical step sizes and keeps
        their trajectories comparable to solver tolerance (the streamed
        estimator reassociates its reductions, and near fp32 plateau ties
        even 1-ulp step-size differences move the stopping point)."""
        if reduce not in ("gather", "mask"):
            raise ValueError(
                f"host-driver reduce must be 'gather' or 'mask', got "
                f"{reduce!r} ('compact' is the scan engine's on-device "
                "gather — use svm_path(engine='scan', reduce='compact'))"
            )
        self.rules = make_rules(rules)
        self.reduce = reduce
        self.tol = float(tol)
        self.max_iters = int(max_iters)
        self.shrink_factor = float(shrink_factor)
        self.max_verify_rounds = int(max_verify_rounds)
        self.dynamic = bool(dynamic)
        self.screen_every = int(screen_every)
        self.exact_lipschitz = bool(exact_lipschitz)
        self.use_pallas = use_pallas
        if L is not None and exact_lipschitz:
            raise ValueError("pass either L= (a known bound) or "
                             "exact_lipschitz=True (per-solve estimates), "
                             "not both")
        self.L = L

    # -- reduction helpers -------------------------------------------------

    def _feature_select(self, X_np, f_idx, m):
        """Bucket-padded gather of kept feature rows (zeroed padding)."""
        pad = min(_bucket(max(len(f_idx), 1)), m)
        sel = np.zeros((pad,), dtype=np.int64)
        sel[: len(f_idx)] = f_idx
        valid = np.arange(pad) < len(f_idx)
        return sel, valid

    def _solve(self, Xr, yr, lam, w0, b0, sample_mask, feature_mask=None,
               L=None, sample_screen_kw=None):
        if self.dynamic:
            return fista_solve_dynamic(
                Xr, yr, jnp.asarray(lam), w0=w0, b0=b0,
                max_iters=self.max_iters, tol=self.tol, L=L,
                sample_mask=sample_mask,
                feature_mask=feature_mask,
                screen_every=self.screen_every, tau=dynamic_tau(self.rules),
                use_pallas=self.use_pallas,
                **(sample_screen_kw or {}),
            )
        return fista_solve(
            Xr, yr, jnp.asarray(lam), w0=w0, b0=b0,
            max_iters=self.max_iters, tol=self.tol, L=L,
            sample_mask=sample_mask, use_pallas=self.use_pallas,
        )

    # -- main loop ---------------------------------------------------------

    def run(
        self,
        X: jax.Array,
        y: jax.Array,
        lambdas: Optional[Sequence[float]] = None,
        n_lambdas: int = 10,
        lam_min_ratio: float = 0.1,
    ) -> PathResult:
        """``X`` may be a dense ``(m, n)`` array or a
        ``repro.sparse.FeatureChunked`` container — the latter runs the
        out-of-core lane (:meth:`_run_chunked`): screening streams chunk by
        chunk and the solver sees only the gathered surviving rows."""
        if _is_chunked(X):
            return self._run_chunked(X, y, lambdas=lambdas,
                                     n_lambdas=n_lambdas,
                                     lam_min_ratio=lam_min_ratio)
        X = jnp.asarray(X)
        y = jnp.asarray(y)
        m, n = X.shape
        X_np = np.asarray(X)
        y_np = np.asarray(y)

        feature_rules = [r for r in self.rules if r.axis == AXIS_FEATURES]
        sample_rules = [r for r in self.rules if r.axis == AXIS_SAMPLES]
        for rule in self.rules:
            rule.prepare(X, y)

        # one Lipschitz estimate serves every solve of the path (including
        # verification re-solves): sigma_max of a masked/gathered subproblem
        # never exceeds the full X's. Opt out via exact_lipschitz=True.
        if self.L is not None:
            L_path = jnp.asarray(self.L, X.dtype)
        else:
            L_path = None if self.exact_lipschitz else lipschitz_estimate(X)

        lam_max_val = float(lambda_max(X, y))
        if lambdas is None:
            lambdas = default_lambda_grid(lam_max_val, n_lambdas, lam_min_ratio)
        lambdas = _validate_grid(lambdas)
        T = len(lambdas)

        weights = np.zeros((T, m), dtype=np.float64)
        biases = np.zeros((T,), dtype=np.float64)
        objectives = np.zeros((T,), dtype=np.float64)
        kept = np.zeros((T,), dtype=np.int64)
        kept_s = np.zeros((T,), dtype=np.int64)
        vrounds = np.zeros((T,), dtype=np.int64)
        active = np.zeros((T,), dtype=np.int64)
        iters = np.zeros((T,), dtype=np.int64)
        wall = np.zeros((T,), dtype=np.float64)
        s_times = np.zeros((T,), dtype=np.float64)
        sample_masks: dict[int, np.ndarray] = {}  # accepted per-step masks

        dyn_log: dict[int, dict] = {}  # per-step in-solver screening telemetry
        # per-step, per-feature-rule screen telemetry: kept count and bound
        # spread for every rule *individually* (the masks are intersected,
        # so per-rule keeps are not recoverable from the final mask). Feeds
        # extras["rule_telemetry"], the bench rules sweep, and AutoRule's
        # cost model. Entry 0 is the unscreened closed-form/cold step.
        rule_log: list[dict[str, dict]] = [{}]
        lam_prev = float(lambdas[0])
        w_host = np.zeros((m,), dtype=np.float64)
        if lambdas[0] >= lam_max_val * (1.0 - 1e-9):
            # step 0 at (or above) lam_max: closed form (w = 0, b = mean y)
            # is *exact*, so delta = 0 and theta is the true dual optimum
            b0 = float(bias_at_lambda_max(y))
            theta_prev = theta_at_lambda_max(y, jnp.asarray(lambdas[0]))
            delta_prev = jnp.asarray(0.0, X.dtype)
            biases[0] = b0
            xi0 = np.maximum(0.0, 1.0 - y_np * b0)
            objectives[0] = 0.5 * float(np.sum(xi0 * xi0))
            b_host = b0
        else:
            # custom grid starting below lambda_max: the closed form does NOT
            # hold (w*(lambdas[0]) != 0). Solve step 0 with FISTA — no anchor
            # exists yet, so it is unscreened — and certify theta via the gap
            # bound instead of assuming exactness.
            t0 = time.perf_counter()
            res0 = self._solve(
                X, y, float(lambdas[0]),
                jnp.zeros((m,), X.dtype), jnp.mean(y), None, L=L_path,
            )
            jax.block_until_ready(res0)  # stamp *finished* device work
            wall[0] = time.perf_counter() - t0
            w_host = np.asarray(res0.w, dtype=np.float64)
            b_host = float(res0.b)
            weights[0] = w_host
            biases[0] = b_host
            objectives[0] = float(res0.obj)
            kept[0] = m
            active[0] = int(np.sum(np.abs(w_host) > 1e-10))
            iters[0] = int(res0.n_iters)
            if isinstance(res0, DynamicFistaResult):
                dyn_log[0] = _dynamic_telemetry(res0)
            theta_prev, delta_prev = safe_theta_and_delta(
                X, y, jnp.asarray(w_host, X.dtype), jnp.asarray(b_host, X.dtype),
                jnp.asarray(float(lambdas[0])),
            )
        # trust-region movement state (inf until one step of history exists)
        dw_pred = float("inf")
        db_pred = float("inf")

        # dynamic *sample* re-screen: with dynamic=True, a sample rule, and
        # mask-mode reduction (static shapes — the in-solver mask indexes
        # global samples), the segmented solver also re-checks margins
        # in-loop, using the rule's slack model. Gather mode keeps the
        # driver-level (between-lambda) sample screen only.
        dyn_sample_rule = None
        if self.dynamic and self.reduce == "mask":
            dyn_sample_rule = next(
                (r for r in sample_rules if isinstance(r, SampleVIRule)), None)

        for k in range(1, T):
            lam = float(lambdas[k])
            t0 = time.perf_counter()

            # -- screening: one region, every rule --------------------------
            st0 = time.perf_counter()
            f_mask = np.ones((m,), dtype=bool)
            s_mask = np.ones((n,), dtype=bool)
            step_rules: dict[str, dict] = {}
            if self.rules:
                region = ConvexRegion.build(
                    y, lam_prev, lam, theta_prev, delta=delta_prev,
                    w1=jnp.asarray(w_host, X.dtype), b1=b_host,
                    dw=dw_pred, db=db_pred,
                )
                for rule in feature_rules:
                    rb = rule.bounds(X, y, region)
                    rk = np.asarray(rule.keep(rb))
                    f_mask &= rk
                    rb_np = np.asarray(rb, np.float64)
                    step_rules[rule.name] = {
                        "kept": int(rk.sum()),
                        "bound_mean": float(rb_np.mean()) if rb_np.size else 0.0,
                    }
                for rule in sample_rules:
                    s_mask &= np.asarray(rule.keep(rule.bounds(X, y, region)))
            s_times[k] = time.perf_counter() - st0
            rule_log.append(step_rules)

            f_idx = np.nonzero(f_mask)[0]
            kept[k] = len(f_idx)

            # -- solve + verification loop ----------------------------------
            warm = {"w": w_host, "b": b_host, "rounds": 0}

            skw = None
            if dyn_sample_rule is not None:
                # the in-solver sample screen uses the same slack model the
                # rule screens with between lambdas: the driver's trust
                # radii plus the secant anchored at this step's margins
                # (rule.bounds above just updated _u_prev to them)
                skw = dict(
                    dynamic_samples=True,
                    sample_dw=dw_pred, sample_db=db_pred,
                    sample_u_prev=dyn_sample_rule._u_prev,
                    sample_shrink_factor=dyn_sample_rule.shrink_factor,
                    sample_margin_floor=dyn_sample_rule.margin_floor,
                )

            def solve(mask):
                s_idx = np.nonzero(mask)[0]
                # in-solver sample screening only on the first round: a
                # verification re-solve must not re-drop the violators it
                # was asked to re-admit
                res, w_full = self._solve_reduced(
                    X, y, X_np, lam, f_mask, f_idx, mask, s_idx,
                    warm["w"], warm["b"], L_path,
                    sample_screen_kw=skw if warm["rounds"] == 0 else None,
                )
                warm["w"], warm["b"] = w_full, float(res.b)
                warm["rounds"] += 1
                if getattr(res, "sample_mask", None) is not None:
                    # fold the in-solver drops into the step's screened set
                    # so the verification pass below covers them too
                    mask &= np.asarray(res.sample_mask)
                return res, w_full, float(res.b)

            res, w_full, b_new, rounds = solve_with_verification(
                solve, sample_rules, X_np, y_np, s_mask,
                max_rounds=self.max_verify_rounds,
            )

            kept_s[k] = int(s_mask.sum())
            vrounds[k] = rounds
            if sample_rules:
                sample_masks[k] = s_mask.copy()
            if isinstance(res, DynamicFistaResult):
                dyn_log[k] = _dynamic_telemetry(res)

            # -- movement estimates for the next step's trust region --------
            # (weights[k-1]/biases[k-1] hold the previous accepted solution;
            # at k=1 that is the closed form w=0, b=b* at lam_max)
            dw_pred = self.shrink_factor * float(np.linalg.norm(w_full - weights[k - 1]))
            db_pred = self.shrink_factor * abs(b_new - biases[k - 1])

            b_host = b_new
            w_host = w_full.copy()

            theta_prev, delta_prev = safe_theta_and_delta(
                X, y, jnp.asarray(w_full, X.dtype), jnp.asarray(b_host, X.dtype),
                jnp.asarray(lam),
            )
            lam_prev = lam

            weights[k] = w_full
            biases[k] = b_host
            objectives[k] = float(res.obj)
            active[k] = int(np.sum(np.abs(w_full) > 1e-10))
            iters[k] = int(res.n_iters)
            # the certificate dispatch above is async — block so the step's
            # wall time covers all device work it caused, not just what the
            # host happened to wait for
            jax.block_until_ready((theta_prev, delta_prev))
            wall[k] = time.perf_counter() - t0

            # telemetry hand-back: rules exposing ``observe`` (AutoRule's
            # cost model) learn this step's solve wall per kept feature
            solve_s = max(wall[k] - s_times[k], 0.0)
            for rule in feature_rules:
                obs = getattr(rule, "observe", None)
                if obs is not None:
                    obs(solve_seconds=solve_s, kept=int(kept[k]))

        kept_s[0] = 0
        return PathResult(
            lambdas=lambdas, weights=weights, biases=biases, objectives=objectives,
            kept=kept, active=active, solver_iters=iters, wall_times=wall,
            screen_times=s_times, screened=bool(self.rules),
            kept_samples=kept_s, verify_rounds=vrounds,
            rules=tuple(r.name for r in self.rules),
            extras={"lam_max": lam_max_val, "sample_masks": sample_masks,
                    "dynamic": dyn_log, "rule_telemetry": rule_log},
        )

    # -- one reduced solve -------------------------------------------------

    def _solve_reduced(self, X, y, X_np, lam, f_mask, f_idx, s_mask, s_idx,
                       w_host, b_host, L=None, sample_screen_kw=None):
        """Reduce X on both axes per self.reduce, solve, scatter w back.

        ``L``: the path-shared Lipschitz upper bound (valid for any
        reduction of X; None re-estimates on the reduced matrix).
        ``sample_screen_kw``: in-solver dynamic sample re-screen options
        (mask mode only — gathered sample axes reindex the mask)."""
        m, n = X.shape
        screening_f = len(f_idx) < m
        screening_s = len(s_idx) < n
        dtype = X_np.dtype

        if self.reduce == "gather" and (screening_f or screening_s):
            sel_f, valid_f = self._feature_select(X_np, f_idx, m)
            pad_n = min(_bucket(max(len(s_idx), 1)), n) if screening_s else n
            sel_s = np.zeros((pad_n,), dtype=np.int64)
            sel_s[: len(s_idx)] = s_idx if screening_s else np.arange(n)
            valid_s = np.arange(pad_n) < (len(s_idx) if screening_s else n)

            Xr = X_np[np.ix_(sel_f, sel_s)]
            # zero padded rows AND columns: padding must not distort the
            # Lipschitz estimate (duplicate columns inflate sigma_max badly)
            Xr = Xr * valid_f[:, None].astype(dtype)
            Xr = Xr * valid_s[None, :].astype(dtype)
            yr = jnp.asarray((np.asarray(y)[sel_s] * valid_s).astype(dtype))
            w0 = jnp.asarray((w_host[sel_f] * valid_f).astype(dtype))
            smask = jnp.asarray(valid_s.astype(dtype)) if screening_s else None
            res = self._solve(jnp.asarray(Xr), yr, lam, w0,
                              jnp.asarray(b_host, X.dtype), smask,
                              feature_mask=jnp.asarray(valid_f.astype(dtype)),
                              L=L)
            w_full = np.zeros((m,), dtype=np.float64)
            w_full[sel_f[: len(f_idx)]] = np.asarray(res.w, np.float64)[: len(f_idx)]
        else:
            Xr = X * jnp.asarray(f_mask[:, None], X.dtype)
            w0 = jnp.asarray((w_host * f_mask).astype(dtype))
            smask = jnp.asarray(s_mask.astype(dtype)) if screening_s else None
            res = self._solve(Xr, y, lam, w0, jnp.asarray(b_host, X.dtype), smask,
                              feature_mask=jnp.asarray(f_mask.astype(dtype)),
                              L=L, sample_screen_kw=sample_screen_kw)
            w_full = np.asarray(res.w, dtype=np.float64) * f_mask

        return res, w_full

    # -- out-of-core lane --------------------------------------------------

    def _run_chunked(self, fc, y, lambdas=None, n_lambdas: int = 10,
                     lam_min_ratio: float = 0.1) -> PathResult:
        """The screened path over ``repro.sparse.FeatureChunked`` storage.

        Same sequential-screening recurrence as :meth:`run`, restructured
        around the device-memory contract: the bound sweep streams X chunk
        by chunk (``sparse.screen_stream`` — bitwise the in-core sweep on
        dense chunks), gather-mode reduction materializes only the rows
        that survive screening (``O(chunk + kept)`` peak device memory),
        and anchor certification streams the correlation sweeps
        (``sparse.gap_theta_delta_stream``). Supports a-priori-safe
        feature rules only — any program-backed stack (``feature_vi``,
        ``edpp``, ``dvi``, ``auto``): sample rules and the in-solver
        dynamic screen need in-core X; use ``reduce='gather'``, the
        storage's whole point. The pure-VI stack routes through the legacy
        :func:`~repro.sparse.screen_stream` sweep (bitwise vs the in-core
        bound, Pallas chunk kernel eligible); every other stack evaluates
        via :func:`~repro.sparse.screen_stack_stream` (XLA route, same
        T + 1 streams of X per path).
        """
        from repro.sparse import (  # lazy: repro.sparse imports core.solver
            fista_solve_chunked,
            gap_theta_delta_stream,
            lambda_max_stream,
            lipschitz_estimate_stream,
            screen_stack_stream,
            screen_stream,
            stream_anchor_stats,
        )
        from .rules.programs import PROGRAMS

        if self.reduce != "gather":
            raise ValueError(
                "chunked storage implies gather-mode reduction (mask mode "
                f"would build the full (m, n) device matrix), got "
                f"reduce={self.reduce!r}"
            )
        if self.dynamic:
            raise ValueError(
                "dynamic in-solver screening needs in-core X; run chunked "
                "paths with dynamic=False"
            )
        bad = [r.name for r in self.rules
               if getattr(r, "program", None) not in PROGRAMS]
        if bad:
            raise ValueError(
                f"chunked storage supports a-priori-safe feature rule only "
                f"specs (program-backed: {tuple(sorted(PROGRAMS))}; sample "
                f"rules sweep the transposed axis in-core), got {bad}"
            )
        progs = tuple(dict.fromkeys(r.program for r in self.rules))
        needs_hist = any(PROGRAMS[p].n_anchors > 1 for p in progs)
        anchor_old = None  # streamed AnchorStats of the step-before-last

        y = jnp.asarray(y)
        y_np = np.asarray(y)
        m, n = fc.shape
        tau = min((r.tau for r in self.rules), default=SAFE_TAU)

        if self.L is not None:
            L_path = jnp.asarray(self.L, fc.dtype)
        else:
            L_path = (None if self.exact_lipschitz
                      else lipschitz_estimate_stream(fc))
        lam_max_val = float(lambda_max_stream(fc, y))
        if lambdas is None:
            lambdas = default_lambda_grid(lam_max_val, n_lambdas, lam_min_ratio)
        lambdas = _validate_grid(lambdas)
        T = len(lambdas)

        weights = np.zeros((T, m), dtype=np.float64)
        biases = np.zeros((T,), dtype=np.float64)
        objectives = np.zeros((T,), dtype=np.float64)
        kept = np.zeros((T,), dtype=np.int64)
        active = np.zeros((T,), dtype=np.int64)
        iters = np.zeros((T,), dtype=np.int64)
        wall = np.zeros((T,), dtype=np.float64)
        s_times = np.zeros((T,), dtype=np.float64)

        lam_prev = float(lambdas[0])
        w_host = np.zeros((m,), dtype=np.float64)
        if lambdas[0] >= lam_max_val * (1.0 - 1e-9):
            b_host = float(bias_at_lambda_max(y))
            theta_prev = theta_at_lambda_max(y, jnp.asarray(lambdas[0]))
            delta_prev = jnp.asarray(0.0, jnp.asarray(y).dtype)
            biases[0] = b_host
            xi0 = np.maximum(0.0, 1.0 - y_np * b_host)
            objectives[0] = 0.5 * float(np.sum(xi0 * xi0))
        else:
            # grid starts below lambda_max: streamed unscreened solve, then
            # gap-certify (the closed form does not hold — cf. run())
            t0 = time.perf_counter()
            res0 = fista_solve_chunked(
                fc, y, float(lambdas[0]), max_iters=self.max_iters,
                tol=self.tol, L=L_path,
            )
            jax.block_until_ready(res0.w)
            wall[0] = time.perf_counter() - t0
            w_host = np.asarray(res0.w, dtype=np.float64)
            b_host = float(res0.b)
            weights[0] = w_host
            biases[0] = b_host
            objectives[0] = float(res0.obj)
            kept[0] = m
            active[0] = int(np.sum(np.abs(w_host) > 1e-10))
            iters[0] = int(res0.n_iters)
            theta_prev, delta_prev = gap_theta_delta_stream(
                fc, y, jnp.asarray(w_host, fc.dtype), res0.b,
                jnp.asarray(float(lambdas[0])), u=res0.u,
            )

        for k in range(1, T):
            lam = float(lambdas[k])
            t0 = time.perf_counter()

            st0 = time.perf_counter()
            if self.rules and progs == ("feature_vi",):
                # pure-VI fast path: the legacy streamed sweep is bitwise
                # the in-core bound on dense chunks and Pallas-eligible
                keep_m, _ = screen_stream(
                    fc, y, lam_prev, lam, theta_prev, tau=tau,
                    delta=delta_prev, use_pallas=self.use_pallas,
                )
                f_mask = np.asarray(keep_m)
            elif self.rules:
                a1 = stream_anchor_stats(fc, y, lam_prev, theta_prev,
                                         delta=delta_prev)
                anchors = (a1,)
                if needs_hist:
                    # last step's a1 is this step's old anchor — free
                    anchors = (anchor_old if anchor_old is not None
                               else a1,) + anchors
                    anchor_old = a1
                keep_m, _ = screen_stack_stream(fc, y, lam, anchors, progs,
                                                tau=tau)
                f_mask = np.asarray(keep_m)
            else:
                f_mask = np.ones((m,), dtype=bool)
            s_times[k] = time.perf_counter() - st0

            f_idx = np.nonzero(f_mask)[0]
            kept[k] = len(f_idx)

            # gather ONLY the surviving rows (bucket-padded): the device
            # holds a (kept_padded, n) block, never the full matrix
            sel_f, valid_f = self._feature_select(None, f_idx, m)
            Xr = jnp.asarray(fc.gather_rows(sel_f)
                             * valid_f[:, None].astype(fc.dtype))
            w0 = jnp.asarray((w_host[sel_f] * valid_f).astype(fc.dtype))
            res = fista_solve(
                Xr, y, jnp.asarray(lam), w0=w0,
                b0=jnp.asarray(b_host, fc.dtype),
                max_iters=self.max_iters, tol=self.tol, L=L_path,
                use_pallas=self.use_pallas,
            )
            w_full = np.zeros((m,), dtype=np.float64)
            w_full[sel_f[: len(f_idx)]] = np.asarray(res.w, np.float64)[: len(f_idx)]
            b_host = float(res.b)
            w_host = w_full

            # certify the accepted point as the next anchor. The margin
            # sweep rides the solver's carried u (exact: padding rows are
            # zero); only the correlation sweeps stream.
            theta_prev, delta_prev = gap_theta_delta_stream(
                fc, y, jnp.asarray(w_full, fc.dtype), res.b,
                jnp.asarray(lam), u=res.u,
            )
            lam_prev = lam

            weights[k] = w_full
            biases[k] = b_host
            objectives[k] = float(res.obj)
            active[k] = int(np.sum(np.abs(w_full) > 1e-10))
            iters[k] = int(res.n_iters)
            jax.block_until_ready((theta_prev, delta_prev))
            wall[k] = time.perf_counter() - t0

        # no sample screening on chunked storage: every solved step feeds
        # all n samples (step 0's closed form feeds none — cf. run())
        kept_samples = np.full((T,), n, dtype=np.int64)
        kept_samples[0] = 0
        return PathResult(
            lambdas=lambdas, weights=weights, biases=biases,
            objectives=objectives, kept=kept, active=active,
            solver_iters=iters, wall_times=wall, screen_times=s_times,
            screened=bool(self.rules),
            kept_samples=kept_samples,
            verify_rounds=np.zeros((T,), dtype=np.int64),
            rules=tuple(r.name for r in self.rules),
            extras={"lam_max": lam_max_val, "storage": "chunked",
                    "n_chunks": fc.n_chunks, "stream_stats": dict(fc.stats)},
        )


def svm_path(
    X: jax.Array,
    y: jax.Array,
    lambdas: Optional[Sequence[float]] = None,
    n_lambdas: int = 10,
    lam_min_ratio: float = 0.1,
    screening: bool = True,
    reduce: Optional[str] = None,
    tol: float = 1e-9,
    max_iters: int = 4000,
    tau: float = SAFE_TAU,
    rules=None,
    dynamic: bool = False,
    screen_every: int = 50,
    engine: str = "host",
    exact_lipschitz: bool = False,
    use_pallas: Optional[bool] = None,
) -> PathResult:
    """Solve the L1-L2-SVM path with configurable screening rules.

    Back-compatible wrapper over :class:`PathDriver`: ``screening=True``
    defaults to the paper's feature rule (with ``tau``); pass ``rules=``
    (``"sample_vi"``, ``"composite"``, a list, or instances) to choose
    other reductions. ``screening=False`` (or ``rules=[]``) disables all.
    ``dynamic=True`` additionally re-screens inside each FISTA solve every
    ``screen_every`` iterations (see :class:`PathDriver`).

    ``engine`` selects the execution strategy:

    * ``"host"`` — this driver: per-step host orchestration, gather/mask
      reduction on both axes, any rule mix, sample-rule verification;
    * ``"scan"`` — ``core/path_scan.py``: the whole path as one jitted
      ``lax.scan`` program (a-priori-safe feature rules only — any
      program-backed stack such as ``"feature_vi"``, ``"edpp"``, ``"dvi"``
      or a list of them; mask or compact reduction, zero host round
      trips). Sample rules raise at dispatch. See that module for the
      trade-off discussion.
    * ``"batched"`` — ``path_scan.svm_path_batched``: B paths as one
      program (``X (B, m, n)`` independent problems, or ``X (m, n)`` with
      ``lambdas (B, T)`` grids). Same program-backed feature-rule stacks
      as ``"scan"``; returns a *list* of ``PathResult``. Compact reduction
      composes with batching through the shared-cap schedule. For ragged
      many-job workloads prefer ``launch/path_server.py`` (continuous
      batching over these programs).

    ``reduce`` defaults per engine (host: ``"gather"``, scan/batched:
    ``"mask"``). Rule of thumb — **gather** (host) for multiplicative
    feature x sample reduction and verified sample rules; **mask**
    (any engine) when screening is weak, so compaction would only add
    gather traffic; **compact** (scan/batched) when screening certifies a
    small active set and the solve should cost FLOPs proportional to it
    (see ``path_scan.py``'s module docstring for the batched shared-cap
    composition).
    """
    if engine == "scan":
        from .path_scan import svm_path_scan  # deferred: path_scan imports us

        if _is_chunked(X):
            raise ValueError(
                "engine='scan' jit-compiles over an in-core X; chunked "
                "storage runs on the host engine (engine='host', the "
                "default when X is a FeatureChunked)"
            )
        # rule-spec lowerability is validated at dispatch by
        # path_scan._static_opts -> rules/programs.resolve_programs:
        # sample rules / verification-needing specs raise there
        return svm_path_scan(
            X, y, lambdas=lambdas, n_lambdas=n_lambdas,
            lam_min_ratio=lam_min_ratio, screening=screening, tau=tau,
            tol=tol, max_iters=max_iters, dynamic=dynamic,
            screen_every=screen_every, use_pallas=use_pallas,
            exact_lipschitz=exact_lipschitz,
            reduce="mask" if reduce is None else reduce,
            rules=rules,
        )
    if engine == "batched":
        from .path_scan import svm_path_batched  # deferred: imports us

        if _is_chunked(X):
            raise ValueError(
                "engine='batched' jit-compiles over in-core arrays; chunked "
                "storage runs on the host engine"
            )
        return svm_path_batched(
            X, y, lambdas=lambdas, n_lambdas=n_lambdas,
            lam_min_ratio=lam_min_ratio, screening=screening, tau=tau,
            tol=tol, max_iters=max_iters, dynamic=dynamic,
            screen_every=screen_every, use_pallas=use_pallas,
            exact_lipschitz=exact_lipschitz,
            reduce="mask" if reduce is None else reduce,
            rules=rules,
        )
    if engine != "host":
        raise ValueError(
            f"engine must be 'host', 'scan', or 'batched', got {engine!r}")
    if rules is None:
        rules = [FeatureVIRule(tau=tau)] if screening else []
    driver = PathDriver(rules=rules,
                        reduce="gather" if reduce is None else reduce,
                        tol=tol, max_iters=max_iters,
                        dynamic=dynamic, screen_every=screen_every,
                        exact_lipschitz=exact_lipschitz, use_pallas=use_pallas)
    return driver.run(X, y, lambdas=lambdas, n_lambdas=n_lambdas,
                      lam_min_ratio=lam_min_ratio)
