"""Rule-agnostic regularization-path driver with pluggable screening.

Walks a decreasing grid ``lam_max = lam_0 > lam_1 > ... > lam_{T-1}``. At each
step the previous primal/dual pair parameterizes a
:class:`~repro.core.rules.base.ConvexRegion`; every configured
:class:`~repro.core.rules.base.ScreeningRule` then contributes a keep-mask on
its axis (feature rows and/or sample columns of ``X``), the reduced problem
is solved with a warm-started FISTA, and the solution is scattered back to
full coordinates. Rules that are not a-priori safe (``needs_verification``)
are checked at the solved point and violators re-admitted before the step is
accepted — so the accepted solution is exact regardless of screening.

Two execution modes, applied on *both* axes:

* ``reduce="gather"`` — physically gathers kept rows/columns (padded to a
  power-of-two bucket so jit re-traces at most O(log) times). Solver cost
  scales with ``kept_features x kept_samples`` — the multiplicative payoff of
  simultaneous reduction.
* ``reduce="mask"``   — static shapes; screened features are zeroed rows,
  screened samples are dropped from the loss via the solver's
  ``sample_mask`` (zeroing columns would *not* be equivalent: an all-zero
  column still contributes ``max(0, 1 - y_i b)^2`` to the loss).

Trust-region movement estimates for the sample rule come from observed path
movement: after each accepted step the driver records
``||w_k - w_{k-1}||_2`` and ``|b_k - b_{k-1}|`` and predicts the next step's
movement as ``shrink_factor`` times that (first-order continuation on a
geometric grid). The first screened step has no history and keeps all
samples — correct anyway, since near ``lam_max`` nearly every sample is a
support vector.

Exactness: feature rules are safe given ``||theta1 - theta*|| <= delta``
(gap-certified, see dual.safe_theta_and_delta); sample rules are exact at
termination via the verification loop. Property tests cover both
(tests/test_screening.py, tests/test_rules.py).

Engines: this host-orchestrated driver (``engine="host"``) is one of two
path engines — ``core/path_scan.py`` runs the same feature-screened path as
a single jitted ``lax.scan`` program (``engine="scan"``), trading the
gather-mode FLOP reduction and the sample-verification loop for zero
per-step host round trips. Rule of thumb: gather mode shrinks FLOPs, scan
mode kills orchestration overhead. ``svm_path(engine=...)`` selects.

The Lipschitz constant is estimated once per path on the full ``X`` and
reused by every reduced solve — masking/gathering rows or columns never
increases ``sigma_max``, so the full-matrix estimate stays a valid step
bound (and saves the 30-iteration power sweep per solve, per verification
round). ``exact_lipschitz=True`` restores the per-solve estimate.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.path_trace import build_path_trace

from .dual import (
    bias_at_lambda_max,
    lambda_max,
    safe_theta_and_delta,
    theta_at_lambda_max,
)
from .rules import (
    AXIS_FEATURES,
    AXIS_SAMPLES,
    ConvexRegion,
    FeatureVIRule,
    SampleVIRule,
    make_rules,
)
from .rules.base import dynamic_tau, solve_with_verification
from .screening import SAFE_TAU, anchor_stats
from .solver import (
    HEALTH_SCREEN_REFUSED,
    DynamicFistaResult,
    fista_solve,
    fista_solve_dynamic,
    lipschitz_estimate,
)


def _anchor_ok(theta, delta) -> bool:
    """Host-side certificate gate: screening regions may only be built from
    a finite anchor. A poisoned ``(theta, delta)`` (NaN'd solve, inf'd gap)
    must fail-safe to keep-all for the next step — host rule bounds compare
    ``bounds >= tau``, where a NaN silently discards."""
    return bool(np.isfinite(float(delta))
                and np.all(np.isfinite(np.asarray(theta))))


def _is_chunked(X) -> bool:
    """Duck-typed ``repro.sparse.FeatureChunked`` check (no import cycle)."""
    return hasattr(X, "stream") and hasattr(X, "gather_rows")


def _validate_grid(lambdas) -> np.ndarray:
    lambdas = np.asarray(lambdas, dtype=np.float64)
    if lambdas.size == 0:
        raise ValueError("empty lambda grid")
    if not np.all(np.isfinite(lambdas)) or np.any(lambdas <= 0):
        raise ValueError(f"lambda grid must be finite and positive: {lambdas}")
    if np.any(np.diff(lambdas) >= 0):
        raise ValueError(
            "lambda grid must be strictly decreasing (screening regions "
            f"certify theta*(lam2) only for lam2 < lam1): {lambdas}"
        )
    return lambdas

__all__ = ["PathResult", "PathDriver", "svm_path", "default_lambda_grid"]


@dataclass
class PathResult:
    lambdas: np.ndarray            # (T,)
    weights: np.ndarray            # (T, m)
    biases: np.ndarray             # (T,)
    objectives: np.ndarray         # (T,)
    kept: np.ndarray               # (T,) kept feature count fed to the solver
    active: np.ndarray             # (T,) nnz(w) in the solution
    solver_iters: np.ndarray       # (T,)
    wall_times: np.ndarray         # (T,) seconds per step (solve + screen)
    screen_times: np.ndarray       # (T,) seconds spent screening
    screened: bool = True
    kept_samples: np.ndarray = None  # (T,) samples fed to the solver
    verify_rounds: np.ndarray = None  # (T,) sample-verification re-solves
    rules: tuple = ()
    extras: dict = field(default_factory=dict)


def default_lambda_grid(lam_max_val: float, n_lambdas: int = 10, lam_min_ratio: float = 0.1) -> np.ndarray:
    return np.geomspace(lam_max_val, lam_max_val * lam_min_ratio, n_lambdas)


def _bucket(n: int) -> int:
    """Round up to the next power of two (min 8) to bound retracing."""
    b = 8
    while b < n:
        b *= 2
    return b


def _dynamic_telemetry(res: DynamicFistaResult) -> dict:
    """Host-side view of one dynamic solve's per-segment screening trace."""
    s = int(res.n_segments)
    out = {
        "segments": s,
        "kept_per_segment": [int(v) for v in np.asarray(res.kept_per_segment)[:s]],
        "gap_per_segment": [float(v) for v in np.asarray(res.gap_per_segment)[:s]],
    }
    if res.kept_samples_per_segment is not None:
        out["kept_samples_per_segment"] = [
            int(v) for v in np.asarray(res.kept_samples_per_segment)[:s]
        ]
    return out


class PathDriver:
    """Applies an arbitrary list of screening rules along the lambda path.

    ``rules`` accepts anything :func:`~repro.core.rules.base.make_rules`
    does: ``"feature_vi"``, ``"sample_vi"``, ``"composite"``, a list of
    names, or rule instances. An empty list solves the unscreened path.
    """

    def __init__(
        self,
        rules="feature_vi",
        *,
        reduce: str = "gather",
        tol: float = 1e-9,
        max_iters: int = 4000,
        shrink_factor: float = 1.5,
        max_verify_rounds: int = 3,
        dynamic: bool = False,
        screen_every: int = 50,
        exact_lipschitz: bool = False,
        use_pallas: Optional[bool] = None,
        L=None,
        chunk_skip: bool = True,
        guards: Optional[bool] = None,
    ):
        """``dynamic=True`` swaps every solve for the segmented
        ``solver.fista_solve_dynamic``: the step's sequential screen seeds a
        live feature mask that the solver keeps tightening every
        ``screen_every`` iterations from the gap-certified at-lambda region.
        Per-step, per-segment kept-counts/gaps land in
        ``PathResult.extras["dynamic"]``. Safe with any rule mix (the
        in-solver screen is a-priori safe on its own certificate).

        ``exact_lipschitz=True`` re-estimates L per reduced solve instead of
        reusing the full-X upper bound computed once per path (see module
        docstring); ``use_pallas`` routes the FISTA hot-loop sweeps through
        the fused Pallas kernels (None = env/backend policy).

        ``L`` (optional): a known upper bound on the Lipschitz constant of
        ``[X; 1^T]`` — skips the per-path power iteration entirely. The
        bound is a property of the matrix, not of how it is stored, so
        passing one value to several storage engines (dense / chunked /
        CSR) gives them floating-point-identical step sizes and keeps
        their trajectories comparable to solver tolerance (the streamed
        estimator reassociates its reductions, and near fp32 plateau ties
        even 1-ulp step-size differences move the stopping point).

        ``chunk_skip`` (chunked storage only): certify whole feature-row
        chunks dead from their cached stale-anchor bounds *before* the
        ``device_put`` and skip their transfers entirely (see
        ``sparse/screen_stream.ChunkScreenCache``). ``False`` runs the
        full-stream twin — identical screening decisions and path, every
        chunk transferred — the equivalence/bench baseline. No effect on
        in-core storage."""
        if reduce not in ("gather", "mask"):
            raise ValueError(
                f"host-driver reduce must be 'gather' or 'mask', got "
                f"{reduce!r} ('compact' is the scan engine's on-device "
                "gather — use svm_path(engine='scan', reduce='compact'))"
            )
        self.rules = make_rules(rules)
        self.reduce = reduce
        self.tol = float(tol)
        self.max_iters = int(max_iters)
        self.shrink_factor = float(shrink_factor)
        self.max_verify_rounds = int(max_verify_rounds)
        self.dynamic = bool(dynamic)
        self.screen_every = int(screen_every)
        self.exact_lipschitz = bool(exact_lipschitz)
        self.use_pallas = use_pallas
        if L is not None and exact_lipschitz:
            raise ValueError("pass either L= (a known bound) or "
                             "exact_lipschitz=True (per-solve estimates), "
                             "not both")
        self.L = L
        self.chunk_skip = bool(chunk_skip)
        # numerical health guards (core/solver.py): None resolves the
        # REPRO_SOLVER_GUARDS env default at each solve dispatch
        self.guards = guards
        # fault-injection seam (testing/faults.py): called as
        # ``injector(k, w_full, b_new) -> (w_full, b_new)`` on the accepted
        # solution of step k, BEFORE it is recorded, certified, and warm-
        # starts step k+1 — a poisoned return exercises the whole recovery
        # chain (refused certificate -> keep-all -> sanitized warm start).
        self._fault_injector = None

    # -- reduction helpers -------------------------------------------------

    def _feature_select(self, X_np, f_idx, m):
        """Bucket-padded gather of kept feature rows (zeroed padding)."""
        pad = min(_bucket(max(len(f_idx), 1)), m)
        sel = np.zeros((pad,), dtype=np.int64)
        sel[: len(f_idx)] = f_idx
        valid = np.arange(pad) < len(f_idx)
        return sel, valid

    def _solve(self, Xr, yr, lam, w0, b0, sample_mask, feature_mask=None,
               L=None, sample_screen_kw=None):
        if self.dynamic:
            return fista_solve_dynamic(
                Xr, yr, jnp.asarray(lam), w0=w0, b0=b0,
                max_iters=self.max_iters, tol=self.tol, L=L,
                sample_mask=sample_mask,
                feature_mask=feature_mask,
                screen_every=self.screen_every, tau=dynamic_tau(self.rules),
                use_pallas=self.use_pallas, guards=self.guards,
                **(sample_screen_kw or {}),
            )
        return fista_solve(
            Xr, yr, jnp.asarray(lam), w0=w0, b0=b0,
            max_iters=self.max_iters, tol=self.tol, L=L,
            sample_mask=sample_mask, use_pallas=self.use_pallas,
            guards=self.guards,
        )

    # -- main loop ---------------------------------------------------------

    def run(
        self,
        X: jax.Array,
        y: jax.Array,
        lambdas: Optional[Sequence[float]] = None,
        n_lambdas: int = 10,
        lam_min_ratio: float = 0.1,
    ) -> PathResult:
        """``X`` may be a dense ``(m, n)`` array or a
        ``repro.sparse.FeatureChunked`` container — the latter runs the
        out-of-core lane (:meth:`_run_chunked`): screening streams chunk by
        chunk and the solver sees only the gathered surviving rows."""
        if _is_chunked(X):
            return self._run_chunked(X, y, lambdas=lambdas,
                                     n_lambdas=n_lambdas,
                                     lam_min_ratio=lam_min_ratio)
        X = jnp.asarray(X)
        y = jnp.asarray(y)
        m, n = X.shape
        X_np = np.asarray(X)
        y_np = np.asarray(y)

        feature_rules = [r for r in self.rules if r.axis == AXIS_FEATURES]
        sample_rules = [r for r in self.rules if r.axis == AXIS_SAMPLES]
        for rule in self.rules:
            rule.prepare(X, y)

        # one Lipschitz estimate serves every solve of the path (including
        # verification re-solves): sigma_max of a masked/gathered subproblem
        # never exceeds the full X's. Opt out via exact_lipschitz=True.
        if self.L is not None:
            L_path = jnp.asarray(self.L, X.dtype)
        else:
            L_path = None if self.exact_lipschitz else lipschitz_estimate(X)

        lam_max_val = float(lambda_max(X, y))
        if lambdas is None:
            lambdas = default_lambda_grid(lam_max_val, n_lambdas, lam_min_ratio)
        lambdas = _validate_grid(lambdas)
        T = len(lambdas)

        weights = np.zeros((T, m), dtype=np.float64)
        biases = np.zeros((T,), dtype=np.float64)
        objectives = np.zeros((T,), dtype=np.float64)
        kept = np.zeros((T,), dtype=np.int64)
        kept_s = np.zeros((T,), dtype=np.int64)
        vrounds = np.zeros((T,), dtype=np.int64)
        active = np.zeros((T,), dtype=np.int64)
        iters = np.zeros((T,), dtype=np.int64)
        wall = np.zeros((T,), dtype=np.float64)
        s_times = np.zeros((T,), dtype=np.float64)
        c_times = np.zeros((T,), dtype=np.float64)  # certification walls
        deltas_log = np.full((T,), np.nan, dtype=np.float64)
        health = np.zeros((T,), dtype=np.int64)  # guard telemetry per step
        sample_masks: dict[int, np.ndarray] = {}  # accepted per-step masks

        dyn_log: dict[int, dict] = {}  # per-step in-solver screening telemetry
        # per-step, per-feature-rule screen telemetry: kept count and bound
        # spread for every rule *individually* (the masks are intersected,
        # so per-rule keeps are not recoverable from the final mask). Feeds
        # extras["rule_telemetry"], the bench rules sweep, and AutoRule's
        # cost model. Entry 0 is the unscreened closed-form/cold step.
        rule_log: list[dict[str, dict]] = [{}]
        lam_prev = float(lambdas[0])
        w_host = np.zeros((m,), dtype=np.float64)
        if lambdas[0] >= lam_max_val * (1.0 - 1e-9):
            # step 0 at (or above) lam_max: closed form (w = 0, b = mean y)
            # is *exact*, so delta = 0 and theta is the true dual optimum
            b0 = float(bias_at_lambda_max(y))
            theta_prev = theta_at_lambda_max(y, jnp.asarray(lambdas[0]))
            delta_prev = jnp.asarray(0.0, X.dtype)
            biases[0] = b0
            xi0 = np.maximum(0.0, 1.0 - y_np * b0)
            objectives[0] = 0.5 * float(np.sum(xi0 * xi0))
            b_host = b0
        else:
            # custom grid starting below lambda_max: the closed form does NOT
            # hold (w*(lambdas[0]) != 0). Solve step 0 with FISTA — no anchor
            # exists yet, so it is unscreened — and certify theta via the gap
            # bound instead of assuming exactness.
            t0 = time.perf_counter()
            res0 = self._solve(
                X, y, float(lambdas[0]),
                jnp.zeros((m,), X.dtype), jnp.mean(y), None, L=L_path,
            )
            jax.block_until_ready(res0)  # stamp *finished* device work
            wall[0] = time.perf_counter() - t0
            w_host = np.asarray(res0.w, dtype=np.float64)
            b_host = float(res0.b)
            weights[0] = w_host
            biases[0] = b_host
            objectives[0] = float(res0.obj)
            kept[0] = m
            active[0] = int(np.sum(np.abs(w_host) > 1e-10))
            iters[0] = int(res0.n_iters)
            if isinstance(res0, DynamicFistaResult):
                dyn_log[0] = _dynamic_telemetry(res0)
            if res0.health is not None:
                health[0] |= int(res0.health)
            theta_prev, delta_prev = safe_theta_and_delta(
                X, y, jnp.asarray(w_host, X.dtype), jnp.asarray(b_host, X.dtype),
                jnp.asarray(float(lambdas[0])),
            )
        anchor_ok = _anchor_ok(theta_prev, delta_prev)
        deltas_log[0] = float(delta_prev)
        # trust-region movement state (inf until one step of history exists)
        dw_pred = float("inf")
        db_pred = float("inf")

        # dynamic *sample* re-screen: with dynamic=True, a sample rule, and
        # mask-mode reduction (static shapes — the in-solver mask indexes
        # global samples), the segmented solver also re-checks margins
        # in-loop, using the rule's slack model. Gather mode keeps the
        # driver-level (between-lambda) sample screen only.
        dyn_sample_rule = None
        if self.dynamic and self.reduce == "mask":
            dyn_sample_rule = next(
                (r for r in sample_rules if isinstance(r, SampleVIRule)), None)

        for k in range(1, T):
            lam = float(lambdas[k])
            t0 = time.perf_counter()

            # -- screening: one region, every rule --------------------------
            st0 = time.perf_counter()
            f_mask = np.ones((m,), dtype=bool)
            s_mask = np.ones((n,), dtype=bool)
            step_rules: dict[str, dict] = {}
            if self.rules and not anchor_ok:
                # fail-safe: the previous step's certificate was non-finite,
                # so no region exists — keep every feature and sample this
                # step (screening degrades to "no speedup", never to a wrong
                # discard) and record the refusal
                health[k] |= HEALTH_SCREEN_REFUSED
            elif self.rules:
                region = ConvexRegion.build(
                    y, lam_prev, lam, theta_prev, delta=delta_prev,
                    w1=jnp.asarray(w_host, X.dtype), b1=b_host,
                    dw=dw_pred, db=db_pred,
                )
                for rule in feature_rules:
                    rb = rule.bounds(X, y, region)
                    rk = np.asarray(rule.keep(rb))
                    f_mask &= rk
                    rb_np = np.asarray(rb, np.float64)
                    step_rules[rule.name] = {
                        "kept": int(rk.sum()),
                        "bound_mean": float(rb_np.mean()) if rb_np.size else 0.0,
                    }
                for rule in sample_rules:
                    s_mask &= np.asarray(rule.keep(rule.bounds(X, y, region)))
            s_times[k] = time.perf_counter() - st0
            rule_log.append(step_rules)

            f_idx = np.nonzero(f_mask)[0]
            kept[k] = len(f_idx)

            # -- solve + verification loop ----------------------------------
            warm = {"w": w_host, "b": b_host, "rounds": 0}

            skw = None
            if dyn_sample_rule is not None:
                # the in-solver sample screen uses the same slack model the
                # rule screens with between lambdas: the driver's trust
                # radii plus the secant anchored at this step's margins
                # (rule.bounds above just updated _u_prev to them)
                skw = dict(
                    dynamic_samples=True,
                    sample_dw=dw_pred, sample_db=db_pred,
                    sample_u_prev=dyn_sample_rule._u_prev,
                    sample_shrink_factor=dyn_sample_rule.shrink_factor,
                    sample_margin_floor=dyn_sample_rule.margin_floor,
                )

            def solve(mask):
                s_idx = np.nonzero(mask)[0]
                # in-solver sample screening only on the first round: a
                # verification re-solve must not re-drop the violators it
                # was asked to re-admit
                res, w_full = self._solve_reduced(
                    X, y, X_np, lam, f_mask, f_idx, mask, s_idx,
                    warm["w"], warm["b"], L_path,
                    sample_screen_kw=skw if warm["rounds"] == 0 else None,
                )
                warm["w"], warm["b"] = w_full, float(res.b)
                warm["rounds"] += 1
                if getattr(res, "sample_mask", None) is not None:
                    # fold the in-solver drops into the step's screened set
                    # so the verification pass below covers them too
                    mask &= np.asarray(res.sample_mask)
                return res, w_full, float(res.b)

            res, w_full, b_new, rounds = solve_with_verification(
                solve, sample_rules, X_np, y_np, s_mask,
                max_rounds=self.max_verify_rounds,
            )

            kept_s[k] = int(s_mask.sum())
            vrounds[k] = rounds
            if sample_rules:
                sample_masks[k] = s_mask.copy()
            if isinstance(res, DynamicFistaResult):
                dyn_log[k] = _dynamic_telemetry(res)
            if getattr(res, "health", None) is not None:
                health[k] |= int(res.health)

            if self._fault_injector is not None:
                w_full, b_new = self._fault_injector(k, w_full, b_new)

            # -- movement estimates for the next step's trust region --------
            # (weights[k-1]/biases[k-1] hold the previous accepted solution;
            # at k=1 that is the closed form w=0, b=b* at lam_max)
            dw_pred = self.shrink_factor * float(np.linalg.norm(w_full - weights[k - 1]))
            db_pred = self.shrink_factor * abs(b_new - biases[k - 1])

            b_host = b_new
            w_host = w_full.copy()

            ct0 = time.perf_counter()
            theta_prev, delta_prev = safe_theta_and_delta(
                X, y, jnp.asarray(w_full, X.dtype), jnp.asarray(b_host, X.dtype),
                jnp.asarray(lam),
            )
            anchor_ok = _anchor_ok(theta_prev, delta_prev)
            deltas_log[k] = float(delta_prev)
            lam_prev = lam

            weights[k] = w_full
            biases[k] = b_host
            objectives[k] = float(res.obj)
            active[k] = int(np.sum(np.abs(w_full) > 1e-10))
            iters[k] = int(res.n_iters)
            # the certificate dispatch above is async — block so the step's
            # wall time covers all device work it caused, not just what the
            # host happened to wait for
            jax.block_until_ready((theta_prev, delta_prev))
            c_times[k] = time.perf_counter() - ct0
            wall[k] = time.perf_counter() - t0
            if obs_trace.enabled():
                st1 = st0 + s_times[k]
                obs_trace.complete("path.screen", st0, st1, step=k,
                                   kept=int(kept[k]))
                obs_trace.complete("path.solve", st1, ct0, step=k,
                                   iters=int(iters[k]))
                obs_trace.complete("path.certify", ct0, ct0 + c_times[k],
                                   step=k)
                obs_trace.complete("path.step", t0, t0 + wall[k], step=k,
                                   lam=lam, kept=int(kept[k]),
                                   active=int(active[k]))

            # telemetry hand-back: rules exposing ``observe`` (AutoRule's
            # cost model) learn this step's solve wall per kept feature
            solve_s = max(wall[k] - s_times[k], 0.0)
            for rule in feature_rules:
                obs = getattr(rule, "observe", None)
                if obs is not None:
                    obs(solve_seconds=solve_s, kept=int(kept[k]))

        kept_s[0] = 0
        self._observe_run("host", kept, health)
        path_trace = build_path_trace(
            "host", lambdas, kept, kept_s, active, iters, wall,
            deltas=deltas_log, health=health, screen_s=s_times,
            solve_s=np.maximum(wall - s_times - c_times, 0.0),
            certify_s=c_times, walls_observed=True,
            meta={"reduce": self.reduce, "lam_max": lam_max_val},
        )
        return PathResult(
            lambdas=lambdas, weights=weights, biases=biases, objectives=objectives,
            kept=kept, active=active, solver_iters=iters, wall_times=wall,
            screen_times=s_times, screened=bool(self.rules),
            kept_samples=kept_s, verify_rounds=vrounds,
            rules=tuple(r.name for r in self.rules),
            extras={"lam_max": lam_max_val, "sample_masks": sample_masks,
                    "dynamic": dyn_log, "rule_telemetry": rule_log,
                    "health": health, "path_trace": path_trace},
        )

    @staticmethod
    def _observe_run(engine: str, kept, health):
        """Fold one run's per-step telemetry into the process metrics
        registry (``repro.obs.metrics``): step counts, guard-tripped
        steps, and the kept-per-step distribution."""
        obs_metrics.counter("path.steps").inc(int(len(kept)))
        obs_metrics.counter("path.guard_trips").inc(
            int(np.count_nonzero(np.asarray(health))))
        h = obs_metrics.histogram("path.kept")
        for v in np.asarray(kept):
            h.observe(float(v))

    # -- one reduced solve -------------------------------------------------

    def _solve_reduced(self, X, y, X_np, lam, f_mask, f_idx, s_mask, s_idx,
                       w_host, b_host, L=None, sample_screen_kw=None):
        """Reduce X on both axes per self.reduce, solve, scatter w back.

        ``L``: the path-shared Lipschitz upper bound (valid for any
        reduction of X; None re-estimates on the reduced matrix).
        ``sample_screen_kw``: in-solver dynamic sample re-screen options
        (mask mode only — gathered sample axes reindex the mask)."""
        m, n = X.shape
        screening_f = len(f_idx) < m
        screening_s = len(s_idx) < n
        dtype = X_np.dtype

        if self.reduce == "gather" and (screening_f or screening_s):
            sel_f, valid_f = self._feature_select(X_np, f_idx, m)
            pad_n = min(_bucket(max(len(s_idx), 1)), n) if screening_s else n
            sel_s = np.zeros((pad_n,), dtype=np.int64)
            sel_s[: len(s_idx)] = s_idx if screening_s else np.arange(n)
            valid_s = np.arange(pad_n) < (len(s_idx) if screening_s else n)

            Xr = X_np[np.ix_(sel_f, sel_s)]
            # zero padded rows AND columns: padding must not distort the
            # Lipschitz estimate (duplicate columns inflate sigma_max badly)
            Xr = Xr * valid_f[:, None].astype(dtype)
            Xr = Xr * valid_s[None, :].astype(dtype)
            yr = jnp.asarray((np.asarray(y)[sel_s] * valid_s).astype(dtype))
            w0 = jnp.asarray((w_host[sel_f] * valid_f).astype(dtype))
            smask = jnp.asarray(valid_s.astype(dtype)) if screening_s else None
            res = self._solve(jnp.asarray(Xr), yr, lam, w0,
                              jnp.asarray(b_host, X.dtype), smask,
                              feature_mask=jnp.asarray(valid_f.astype(dtype)),
                              L=L)
            w_full = np.zeros((m,), dtype=np.float64)
            w_full[sel_f[: len(f_idx)]] = np.asarray(res.w, np.float64)[: len(f_idx)]
        else:
            Xr = X * jnp.asarray(f_mask[:, None], X.dtype)
            w0 = jnp.asarray((w_host * f_mask).astype(dtype))
            smask = jnp.asarray(s_mask.astype(dtype)) if screening_s else None
            res = self._solve(Xr, y, lam, w0, jnp.asarray(b_host, X.dtype), smask,
                              feature_mask=jnp.asarray(f_mask.astype(dtype)),
                              L=L, sample_screen_kw=sample_screen_kw)
            w_full = np.asarray(res.w, dtype=np.float64) * f_mask

        return res, w_full

    # -- out-of-core lane --------------------------------------------------

    def _run_chunked(self, fc, y, lambdas=None, n_lambdas: int = 10,
                     lam_min_ratio: float = 0.1) -> PathResult:
        """The screened path over ``repro.sparse.FeatureChunked`` storage.

        Same sequential-screening recurrence as :meth:`run`, restructured
        around the device-memory contract: the bound sweep streams X chunk
        by chunk, gather-mode reduction materializes only the rows that
        survive screening (``O(chunk + kept)`` peak device memory), and
        anchor certification streams the correlation sweeps
        (``sparse.gap_theta_delta_stream``).

        Feature screening goes through
        :func:`~repro.sparse.screen_step_stream`: chunk-level gating skips
        the transfer of chunks whose cached stale-anchor bounds certify
        every feature dead (``chunk_skip=True``, the default — see
        :class:`~repro.sparse.ChunkScreenCache`), the pure-VI stack rides
        the bitwise/Pallas-eligible sweep, and any other program-backed
        stack (``edpp``, ``dvi``, ``auto``) evaluates from the same
        streamed anchors (dvi carries history and disables the skip).
        Feature rules without a rule program cannot be streamed and raise.

        Sample rules (:class:`~repro.core.rules.sample_vi.SampleVIRule`,
        alone or inside ``composite``/``sifs`` stacks) run out-of-core via
        the transposed sweep: the margins ``u1 = X^T w1 + b1`` are the
        previous accepted solve's carried ``res.u`` (exact — padded gather
        rows are zero) and ``||x_i||^2`` is the memoized
        :meth:`~repro.sparse.FeatureChunked.col_sq`, so screening costs
        zero extra streams; KKT verification re-checks screened samples
        from the reduced solve's own carried margins (again no stream) and
        re-admits violators exactly like :meth:`run`. The sample axis is
        mask-reduced on the gathered solve (gathering it too would force a
        re-gather per verification round).

        ``dynamic=True`` swaps the gathered in-core solve for the streamed
        segmented :func:`~repro.sparse.fista_solve_chunked`: the step's
        screen seeds the live feature/chunk masks and the solver keeps
        shrinking both every ``screen_every`` iterations from the live
        duality gap — mid-solve transfer volume tracks the certified
        support. Per-step telemetry lands in ``extras["dynamic"]``.
        """
        from repro.sparse import (  # lazy: repro.sparse imports core.solver
            ChunkScreenCache,
            fista_solve_chunked,
            gap_theta_delta_stream,
            lambda_max_stream,
            lipschitz_estimate_stream,
            screen_step_stream,
        )
        from .rules.programs import PROGRAMS
        from .rules.sample_vi import margin_surplus_core, violators_from_margins

        if self.reduce != "gather":
            raise ValueError(
                "chunked storage implies gather-mode reduction (mask mode "
                f"would build the full (m, n) device matrix), got "
                f"reduce={self.reduce!r}"
            )
        feature_rules = [r for r in self.rules if r.axis == AXIS_FEATURES]
        sample_rules = [r for r in self.rules if r.axis == AXIS_SAMPLES]
        bad = [r.name for r in feature_rules
               if getattr(r, "program", None) not in PROGRAMS]
        if bad:
            raise ValueError(
                f"chunked storage streams program-backed feature rule "
                f"bounds only ({tuple(sorted(PROGRAMS))}); feature rule(s) "
                f"{bad} have no rule program — use in-core storage"
            )
        bad_s = [r.name for r in sample_rules
                 if not isinstance(r, SampleVIRule)]
        if bad_s:
            raise ValueError(
                f"chunked storage verifies sample rules from the solver's "
                f"carried margins; only SampleVIRule(-derived) rules "
                f"qualify, got {bad_s}"
            )
        progs = tuple(dict.fromkeys(r.program for r in feature_rules))
        needs_hist = any(PROGRAMS[p].n_anchors > 1 for p in progs)
        anchor_old = None  # streamed AnchorStats of the step-before-last
        cache = ChunkScreenCache(fc)

        y = jnp.asarray(y)
        y_np = np.asarray(y)
        yd = jnp.asarray(y, fc.dtype)
        m, n = fc.shape
        tau = min((r.tau for r in feature_rules if hasattr(r, "tau")),
                  default=SAFE_TAU)
        dyn_kw = (dict(screen_every=self.screen_every,
                       screen_tau=dynamic_tau(self.rules))
                  if self.dynamic else {})

        if self.L is not None:
            L_path = jnp.asarray(self.L, fc.dtype)
        else:
            L_path = (None if self.exact_lipschitz
                      else lipschitz_estimate_stream(fc))
        lam_max_val = float(lambda_max_stream(fc, y))
        if lambdas is None:
            lambdas = default_lambda_grid(lam_max_val, n_lambdas, lam_min_ratio)
        lambdas = _validate_grid(lambdas)
        T = len(lambdas)

        weights = np.zeros((T, m), dtype=np.float64)
        biases = np.zeros((T,), dtype=np.float64)
        objectives = np.zeros((T,), dtype=np.float64)
        kept = np.zeros((T,), dtype=np.int64)
        kept_s = np.zeros((T,), dtype=np.int64)
        vrounds = np.zeros((T,), dtype=np.int64)
        active = np.zeros((T,), dtype=np.int64)
        iters = np.zeros((T,), dtype=np.int64)
        wall = np.zeros((T,), dtype=np.float64)
        s_times = np.zeros((T,), dtype=np.float64)
        c_times = np.zeros((T,), dtype=np.float64)  # certification walls
        deltas_log = np.full((T,), np.nan, dtype=np.float64)
        health = np.zeros((T,), dtype=np.int64)  # guard telemetry per step
        live_log = np.full((T,), fc.n_chunks, dtype=np.int64)
        sample_masks: dict[int, np.ndarray] = {}
        dyn_log: dict[int, dict] = {}

        if sample_rules:
            x_sq = fc.col_sq()  # transposed sweep, once per container
            for rule in sample_rules:
                rule._u_prev = None  # prepare() needs in-core X; reset here
        # trust-region movement state + carried margins of the accepted
        # solution (X^T w, bias excluded) for the sample rules' u1
        dw_pred = float("inf")
        db_pred = float("inf")
        u_carry = np.zeros((n,), dtype=np.float64)

        lam_prev = float(lambdas[0])
        w_host = np.zeros((m,), dtype=np.float64)
        if lambdas[0] >= lam_max_val * (1.0 - 1e-9):
            b_host = float(bias_at_lambda_max(y))
            theta_prev = theta_at_lambda_max(y, jnp.asarray(lambdas[0]))
            delta_prev = jnp.asarray(0.0, jnp.asarray(y).dtype)
            biases[0] = b_host
            xi0 = np.maximum(0.0, 1.0 - y_np * b_host)
            objectives[0] = 0.5 * float(np.sum(xi0 * xi0))
        else:
            # grid starts below lambda_max: streamed unscreened solve, then
            # gap-certify (the closed form does not hold — cf. run())
            t0 = time.perf_counter()
            rep0: dict = {}
            res0 = fista_solve_chunked(
                fc, y, float(lambdas[0]), max_iters=self.max_iters,
                tol=self.tol, L=L_path, guards=self.guards,
                report=rep0 if self.dynamic else None, **dyn_kw,
            )
            jax.block_until_ready(res0.w)
            wall[0] = time.perf_counter() - t0
            w_host = np.asarray(res0.w, dtype=np.float64)
            b_host = float(res0.b)
            weights[0] = w_host
            biases[0] = b_host
            objectives[0] = float(res0.obj)
            kept[0] = m
            active[0] = int(np.sum(np.abs(w_host) > 1e-10))
            iters[0] = int(res0.n_iters)
            u_carry = np.asarray(res0.u, dtype=np.float64)
            if self.dynamic:
                dyn_log[0] = rep0
            if getattr(res0, "health", None) is not None:
                health[0] |= int(res0.health)
            theta_prev, delta_prev, d_th0 = gap_theta_delta_stream(
                fc, y, jnp.asarray(w_host, fc.dtype), res0.b,
                jnp.asarray(float(lambdas[0])), u=res0.u, want_corr=True,
            )
            if feature_rules:
                cache.refresh(anchor_stats(
                    yd, float(lambdas[0]), theta_prev, delta_prev, d_th0))
        anchor_ok = _anchor_ok(theta_prev, delta_prev)
        deltas_log[0] = float(delta_prev)

        for k in range(1, T):
            lam = float(lambdas[k])
            t0 = time.perf_counter()

            st0 = time.perf_counter()
            s_mask = np.ones((n,), dtype=bool)
            live = np.ones((fc.n_chunks,), dtype=bool)
            if feature_rules and not anchor_ok:
                # fail-safe: no finite certificate to screen from — keep
                # every feature and stream every chunk this step (cf. run())
                health[k] |= HEALTH_SCREEN_REFUSED
                f_mask = np.ones((m,), dtype=bool)
            elif feature_rules:
                keep_m, _, anchor, live = screen_step_stream(
                    fc, y, lam_prev, lam, theta_prev, delta=delta_prev,
                    rules=progs, tau=tau, cache=cache,
                    anchor_old=anchor_old, skip=self.chunk_skip,
                    use_pallas=self.use_pallas,
                )
                if needs_hist:
                    # last step's fresh anchor is this step's old — free
                    anchor_old = anchor
                f_mask = np.asarray(keep_m)
                live_log[k] = int(live.sum())
            else:
                f_mask = np.ones((m,), dtype=bool)
            if sample_rules:
                # transposed sweep: margins + column norms, zero streams
                u1 = (jnp.asarray(u_carry, fc.dtype)
                      + jnp.asarray(b_host, fc.dtype))
                for rule in sample_rules:
                    surplus = margin_surplus_core(
                        u1, yd, x_sq, dw_pred, db_pred,
                        u_prev=rule._u_prev,
                        shrink_factor=rule.shrink_factor,
                        margin_floor=rule.margin_floor,
                    )
                    rule._u_prev = u1  # secant anchor for the next step
                    # NaN-safe drop test (cf. solver._dynamic_run): a
                    # non-finite surplus keeps the sample — a poisoned
                    # margin costs verification rounds, never loss terms
                    s_mask &= np.asarray(~(surplus >= 0.0))
            s_times[k] = time.perf_counter() - st0

            f_idx = np.nonzero(f_mask)[0]
            kept[k] = len(f_idx)

            # -- solve + sample verification (cf. solve_with_verification):
            # the feature mask is a-priori safe and fixed for the step, so
            # the gather happens once; only the sample mask changes per
            # verification round, and the margin re-check rides the
            # solve's own carried u — no extra stream either way.
            if not self.dynamic:
                sel_f, valid_f = self._feature_select(None, f_idx, m)
                Xr = jnp.asarray(fc.gather_rows(sel_f)
                                 * valid_f[:, None].astype(fc.dtype))
            warm_w, warm_b = w_host, b_host
            rounds = 0
            while True:
                smask_dev = (None if s_mask.all()
                             else jnp.asarray(s_mask.astype(fc.dtype)))
                if self.dynamic:
                    rep: dict = {}
                    res = fista_solve_chunked(
                        fc, y, lam,
                        w0=jnp.asarray((warm_w * f_mask).astype(fc.dtype)),
                        b0=jnp.asarray(warm_b, fc.dtype),
                        max_iters=self.max_iters, tol=self.tol, L=L_path,
                        sample_mask=smask_dev, feature_mask=f_mask,
                        report=rep, guards=self.guards, **dyn_kw,
                    )
                    w_full = np.asarray(res.w, dtype=np.float64)
                    dyn_log[k] = rep
                else:
                    w0 = jnp.asarray((warm_w[sel_f] * valid_f).astype(fc.dtype))
                    res = fista_solve(
                        Xr, y, jnp.asarray(lam), w0=w0,
                        b0=jnp.asarray(warm_b, fc.dtype),
                        max_iters=self.max_iters, tol=self.tol, L=L_path,
                        sample_mask=smask_dev, use_pallas=self.use_pallas,
                        guards=self.guards,
                    )
                    w_full = np.zeros((m,), dtype=np.float64)
                    w_full[sel_f[: len(f_idx)]] = (
                        np.asarray(res.w, np.float64)[: len(f_idx)])
                b_new = float(res.b)
                warm_w, warm_b = w_full, b_new
                if s_mask.all() or not sample_rules:
                    break
                scr = np.nonzero(~s_mask)[0]
                u_np = np.asarray(res.u, dtype=np.float64)
                viol = np.asarray(violators_from_margins(
                    y_np, u_np[scr] + b_new, scr))
                if len(viol) == 0:
                    break
                rounds += 1
                if rounds >= self.max_verify_rounds:
                    s_mask[:] = True  # give up screening: exact solve
                else:
                    s_mask[viol] = True

            kept_s[k] = int(s_mask.sum())
            vrounds[k] = rounds
            if sample_rules:
                sample_masks[k] = s_mask.copy()
            if getattr(res, "health", None) is not None:
                health[k] |= int(res.health)

            if self._fault_injector is not None:
                w_full, b_new = self._fault_injector(k, w_full, b_new)

            # movement estimates for the next step's trust region
            dw_pred = self.shrink_factor * float(
                np.linalg.norm(w_full - weights[k - 1]))
            db_pred = self.shrink_factor * abs(b_new - biases[k - 1])
            b_host = b_new
            w_host = w_full
            u_carry = np.asarray(res.u, dtype=np.float64)

            # certify the accepted point as the next anchor. The margin
            # sweep rides the solver's carried u (exact: padding rows are
            # zero); the correlation sweeps stream only the gating-live
            # chunks — every kept feature lives in one (dead chunks'
            # stamped bounds are all < tau), so the reduced-problem
            # feasibility max is exact — and the final sweep doubles as
            # the fresh d_theta that re-anchors every live chunk's cache
            # entry: next step's gating is exactly as sharp as its screen,
            # at zero extra streams.
            live_arg = None if live.all() else live
            fm_cert = (None if f_mask.all()
                       else jnp.asarray(f_mask.astype(fc.dtype)))
            ct0 = time.perf_counter()
            theta_prev, delta_prev, d_th = gap_theta_delta_stream(
                fc, y, jnp.asarray(w_full, fc.dtype), res.b,
                jnp.asarray(lam), u=res.u, live_chunks=live_arg,
                feature_mask=fm_cert, want_corr=True,
            )
            anchor_ok = _anchor_ok(theta_prev, delta_prev)
            deltas_log[k] = float(delta_prev)
            if feature_rules:
                # a poisoned anchor is safe to hand over: refresh() guards
                # non-finite stats by *invalidating* the touched entries, so
                # gating treats those chunks as never-streamed (always live)
                cache.refresh(
                    anchor_stats(yd, lam, theta_prev, delta_prev, d_th),
                    live=set(int(ci) for ci in np.nonzero(live)[0]),
                )
            lam_prev = lam

            weights[k] = w_full
            biases[k] = b_host
            objectives[k] = float(res.obj)
            active[k] = int(np.sum(np.abs(w_full) > 1e-10))
            iters[k] = int(res.n_iters)
            jax.block_until_ready((theta_prev, delta_prev))
            c_times[k] = time.perf_counter() - ct0
            wall[k] = time.perf_counter() - t0
            if obs_trace.enabled():
                st1 = st0 + s_times[k]
                obs_trace.complete("path.screen", st0, st1, step=k,
                                   kept=int(kept[k]),
                                   live_chunks=int(live_log[k]))
                obs_trace.complete("path.solve", st1, ct0, step=k,
                                   iters=int(iters[k]))
                obs_trace.complete("path.certify", ct0, ct0 + c_times[k],
                                   step=k)
                obs_trace.complete("path.step", t0, t0 + wall[k], step=k,
                                   lam=lam, kept=int(kept[k]),
                                   active=int(active[k]))

        kept_s[0] = 0
        self._observe_run("chunked", kept, health)
        path_trace = build_path_trace(
            "chunked", lambdas, kept, kept_s, active, iters, wall,
            deltas=deltas_log, health=health, screen_s=s_times,
            solve_s=np.maximum(wall - s_times - c_times, 0.0),
            certify_s=c_times, walls_observed=True,
            meta={"storage": "chunked", "n_chunks": fc.n_chunks,
                  "chunk_skip": self.chunk_skip, "lam_max": lam_max_val,
                  "stream_stats": dict(fc.stats)},
        )
        extras = {"lam_max": lam_max_val, "storage": "chunked",
                  "n_chunks": fc.n_chunks, "chunk_skip": self.chunk_skip,
                  "live_chunks": live_log,
                  "health": health,
                  "path_trace": path_trace,
                  "stream_stats": dict(fc.stats)}
        if sample_rules:
            extras["sample_masks"] = sample_masks
        if self.dynamic:
            extras["dynamic"] = dyn_log
        return PathResult(
            lambdas=lambdas, weights=weights, biases=biases,
            objectives=objectives, kept=kept, active=active,
            solver_iters=iters, wall_times=wall, screen_times=s_times,
            screened=bool(self.rules),
            kept_samples=kept_s,
            verify_rounds=vrounds,
            rules=tuple(r.name for r in self.rules),
            extras=extras,
        )


def svm_path(
    X: jax.Array,
    y: jax.Array,
    lambdas: Optional[Sequence[float]] = None,
    n_lambdas: int = 10,
    lam_min_ratio: float = 0.1,
    screening: bool = True,
    reduce: Optional[str] = None,
    tol: float = 1e-9,
    max_iters: int = 4000,
    tau: float = SAFE_TAU,
    rules=None,
    dynamic: bool = False,
    screen_every: int = 50,
    engine: str = "host",
    exact_lipschitz: bool = False,
    use_pallas: Optional[bool] = None,
    chunk_skip: bool = True,
    guards: Optional[bool] = None,
) -> PathResult:
    """Solve the L1-L2-SVM path with configurable screening rules.

    Back-compatible wrapper over :class:`PathDriver`: ``screening=True``
    defaults to the paper's feature rule (with ``tau``); pass ``rules=``
    (``"sample_vi"``, ``"composite"``, a list, or instances) to choose
    other reductions. ``screening=False`` (or ``rules=[]``) disables all.
    ``dynamic=True`` additionally re-screens inside each FISTA solve every
    ``screen_every`` iterations (see :class:`PathDriver`). ``chunk_skip``
    (chunked storage only) gates whole feature-row chunks off the stream
    from their cached stale-anchor bounds (see :class:`PathDriver`).

    ``engine`` selects the execution strategy:

    * ``"host"`` — this driver: per-step host orchestration, gather/mask
      reduction on both axes, any rule mix, sample-rule verification;
    * ``"scan"`` — ``core/path_scan.py``: the whole path as one jitted
      ``lax.scan`` program (a-priori-safe feature rules only — any
      program-backed stack such as ``"feature_vi"``, ``"edpp"``, ``"dvi"``
      or a list of them; mask or compact reduction, zero host round
      trips). Sample rules raise at dispatch. See that module for the
      trade-off discussion.
    * ``"batched"`` — ``path_scan.svm_path_batched``: B paths as one
      program (``X (B, m, n)`` independent problems, or ``X (m, n)`` with
      ``lambdas (B, T)`` grids). Same program-backed feature-rule stacks
      as ``"scan"``; returns a *list* of ``PathResult``. Compact reduction
      composes with batching through the shared-cap schedule. For ragged
      many-job workloads prefer ``launch/path_server.py`` (continuous
      batching over these programs).

    ``reduce`` defaults per engine (host: ``"gather"``, scan/batched:
    ``"mask"``). Rule of thumb — **gather** (host) for multiplicative
    feature x sample reduction and verified sample rules; **mask**
    (any engine) when screening is weak, so compaction would only add
    gather traffic; **compact** (scan/batched) when screening certifies a
    small active set and the solve should cost FLOPs proportional to it
    (see ``path_scan.py``'s module docstring for the batched shared-cap
    composition).
    """
    if engine == "scan":
        from .path_scan import svm_path_scan  # deferred: path_scan imports us

        if _is_chunked(X):
            raise ValueError(
                "engine='scan' jit-compiles over an in-core X; chunked "
                "storage runs on the host engine (engine='host', the "
                "default when X is a FeatureChunked)"
            )
        # rule-spec lowerability is validated at dispatch by
        # path_scan._static_opts -> rules/programs.resolve_programs:
        # sample rules / verification-needing specs raise there
        return svm_path_scan(
            X, y, lambdas=lambdas, n_lambdas=n_lambdas,
            lam_min_ratio=lam_min_ratio, screening=screening, tau=tau,
            tol=tol, max_iters=max_iters, dynamic=dynamic,
            screen_every=screen_every, use_pallas=use_pallas,
            exact_lipschitz=exact_lipschitz,
            reduce="mask" if reduce is None else reduce,
            rules=rules, guards=guards,
        )
    if engine == "batched":
        from .path_scan import svm_path_batched  # deferred: imports us

        if _is_chunked(X):
            raise ValueError(
                "engine='batched' jit-compiles over in-core arrays; chunked "
                "storage runs on the host engine"
            )
        return svm_path_batched(
            X, y, lambdas=lambdas, n_lambdas=n_lambdas,
            lam_min_ratio=lam_min_ratio, screening=screening, tau=tau,
            tol=tol, max_iters=max_iters, dynamic=dynamic,
            screen_every=screen_every, use_pallas=use_pallas,
            exact_lipschitz=exact_lipschitz,
            reduce="mask" if reduce is None else reduce,
            rules=rules, guards=guards,
        )
    if engine != "host":
        raise ValueError(
            f"engine must be 'host', 'scan', or 'batched', got {engine!r}")
    if rules is None:
        rules = [FeatureVIRule(tau=tau)] if screening else []
    driver = PathDriver(rules=rules,
                        reduce="gather" if reduce is None else reduce,
                        tol=tol, max_iters=max_iters,
                        dynamic=dynamic, screen_every=screen_every,
                        exact_lipschitz=exact_lipschitz, use_pallas=use_pallas,
                        chunk_skip=chunk_skip, guards=guards)
    return driver.run(X, y, lambdas=lambdas, n_lambdas=n_lambdas,
                      lam_min_ratio=lam_min_ratio)
