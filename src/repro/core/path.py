"""Regularization-path driver with sequential safe screening (paper Sec. 6.7).

Walks a decreasing grid ``lam_max = lam_0 > lam_1 > ... > lam_{T-1}``. At each
step the known dual point ``theta(lam_{k})`` screens features for
``lam_{k+1}``; the reduced problem is solved with a warm-started FISTA and the
solution is scattered back to full coordinates.

Two execution modes:

* ``reduce="gather"`` — physically gathers the kept rows of X (padded to a
  power-of-two bucket so jit re-traces at most O(log m) times). This realizes
  the paper's speedup: solver cost scales with the *kept* feature count.
* ``reduce="mask"``   — multiplies screened rows by 0 and keeps static shapes
  (useful inside fully-jitted pipelines / for exactness tests).

Exactness note: the rule is *safe* given an exact ``theta1``. We compute
``theta1`` from a finite-precision primal solve (paper Eq. 20), so the path
solves to a tight tolerance and screens with the ``SAFE_TAU`` margin;
property tests (tests/test_screening.py) verify zero false rejections across
random instances.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .dual import (
    bias_at_lambda_max,
    lambda_max,
    safe_theta_and_delta,
    theta_at_lambda_max,
)
from .screening import (
    SAFE_TAU,
    FeatureReductions,
    screen_bounds_from_reductions,
    shared_scalars,
)
from .solver import fista_solve

__all__ = ["PathResult", "svm_path", "default_lambda_grid"]


@dataclass
class PathResult:
    lambdas: np.ndarray            # (T,)
    weights: np.ndarray            # (T, m)
    biases: np.ndarray             # (T,)
    objectives: np.ndarray         # (T,)
    kept: np.ndarray               # (T,) kept feature count fed to the solver
    active: np.ndarray             # (T,) nnz(w) in the solution
    solver_iters: np.ndarray       # (T,)
    wall_times: np.ndarray         # (T,) seconds per step (solve + screen)
    screen_times: np.ndarray       # (T,) seconds spent screening
    screened: bool = True
    extras: dict = field(default_factory=dict)


def default_lambda_grid(lam_max_val: float, n_lambdas: int = 10, lam_min_ratio: float = 0.1) -> np.ndarray:
    return np.geomspace(lam_max_val, lam_max_val * lam_min_ratio, n_lambdas)


def _bucket(n: int) -> int:
    """Round up to the next power of two (min 8) to bound retracing."""
    b = 8
    while b < n:
        b *= 2
    return b


def svm_path(
    X: jax.Array,
    y: jax.Array,
    lambdas: Optional[Sequence[float]] = None,
    n_lambdas: int = 10,
    lam_min_ratio: float = 0.1,
    screening: bool = True,
    reduce: str = "gather",
    tol: float = 1e-9,
    max_iters: int = 4000,
    tau: float = SAFE_TAU,
) -> PathResult:
    """Solve the L1-L2-SVM path, optionally with sequential safe screening."""
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    m, n = X.shape

    lam_max_val = float(lambda_max(X, y))
    if lambdas is None:
        lambdas = default_lambda_grid(lam_max_val, n_lambdas, lam_min_ratio)
    lambdas = np.asarray(lambdas, dtype=np.float64)
    T = len(lambdas)

    # theta-independent reductions, shared across the whole path (paper 6.4)
    d_one = np.asarray(X @ y)           # fhat^T 1
    d_y = np.asarray(X @ jnp.ones((n,), X.dtype))  # fhat^T y
    d_sq = np.asarray(jnp.sum(X * X, axis=1))

    weights = np.zeros((T, m), dtype=np.float64)
    biases = np.zeros((T,), dtype=np.float64)
    objectives = np.zeros((T,), dtype=np.float64)
    kept = np.zeros((T,), dtype=np.int64)
    active = np.zeros((T,), dtype=np.int64)
    iters = np.zeros((T,), dtype=np.int64)
    wall = np.zeros((T,), dtype=np.float64)
    s_times = np.zeros((T,), dtype=np.float64)

    # step 0: closed form at lam_max (w = 0); delta = 0 (theta exact here)
    b0 = float(bias_at_lambda_max(y))
    theta_prev = theta_at_lambda_max(y, jnp.asarray(lambdas[0]))
    delta_prev = jnp.asarray(0.0, X.dtype)
    lam_prev = float(lambdas[0])
    w_full = np.zeros((m,), dtype=np.float64)
    biases[0] = b0
    xi0 = np.maximum(0.0, 1.0 - np.asarray(y) * b0)
    objectives[0] = 0.5 * float(np.sum(xi0 * xi0))
    kept[0] = 0

    w_host = np.zeros((m,), dtype=np.float64)
    b_host = b0

    for k in range(1, T):
        lam = float(lambdas[k])
        t0 = time.perf_counter()

        if screening:
            st0 = time.perf_counter()
            d_theta = np.asarray(X @ (y * theta_prev))
            red = FeatureReductions(
                d_theta=jnp.asarray(d_theta, jnp.float32),
                d_one=jnp.asarray(d_one, jnp.float32),
                d_y=jnp.asarray(d_y, jnp.float32),
                d_sq=jnp.asarray(d_sq, jnp.float32),
            )
            sh = shared_scalars(y, jnp.asarray(lam_prev), jnp.asarray(lam),
                                theta_prev, delta=delta_prev)
            bounds = np.asarray(screen_bounds_from_reductions(red, sh))
            mask = bounds >= tau
            s_times[k] = time.perf_counter() - st0
        else:
            mask = np.ones((m,), dtype=bool)

        idx = np.nonzero(mask)[0]
        kept[k] = len(idx)

        if reduce == "gather" and screening:
            pad = min(_bucket(max(len(idx), 1)), m)  # never exceed m rows
            sel = np.zeros((pad,), dtype=np.int64)
            sel[: len(idx)] = idx
            Xr = jnp.asarray(np.asarray(X)[sel])
            if len(idx) < pad:  # zero out padding rows (duplicate of idx[0])
                padmask = np.zeros((pad, 1), dtype=np.asarray(X).dtype)
                padmask[: len(idx)] = 1.0
                Xr = Xr * jnp.asarray(padmask)
            w0 = jnp.asarray(w_host[sel] * (np.arange(pad) < len(idx)))
        else:
            Xr = X * jnp.asarray(mask[:, None], X.dtype)
            sel = np.arange(m)
            w0 = jnp.asarray(w_host * mask)

        res = fista_solve(Xr, y, jnp.asarray(lam), w0=w0.astype(X.dtype),
                          b0=jnp.asarray(b_host, X.dtype), max_iters=max_iters, tol=tol)
        res_w = np.asarray(res.w, dtype=np.float64)

        w_full[:] = 0.0
        if reduce == "gather" and screening:
            w_full[sel[: len(idx)]] = res_w[: len(idx)]
        else:
            w_full = res_w

        b_host = float(res.b)
        w_host = w_full.copy()

        theta_prev, delta_prev = safe_theta_and_delta(
            X, y, jnp.asarray(w_full, X.dtype), jnp.asarray(b_host, X.dtype),
            jnp.asarray(lam),
        )
        lam_prev = lam

        weights[k] = w_full
        biases[k] = b_host
        objectives[k] = float(res.obj)
        active[k] = int(np.sum(np.abs(w_full) > 1e-10))
        iters[k] = int(res.n_iters)
        wall[k] = time.perf_counter() - t0

    return PathResult(
        lambdas=lambdas, weights=weights, biases=biases, objectives=objectives,
        kept=kept, active=active, solver_iters=iters, wall_times=wall,
        screen_times=s_times, screened=screening,
        extras={"lam_max": lam_max_val},
    )
