"""Dual-side quantities for the L1-regularized L2-loss (squared hinge) SVM.

Primal (paper Eq. 1):

    min_{w,b}  1/2 sum_i max(0, 1 - y_i (w^T x_i + b))^2 + lam * ||w||_1

Data layout follows the paper: ``X`` has shape ``(m, n)`` = (features,
samples); ``y in {-1,+1}^n``.

Scaled dual variable ``theta = alpha / lam`` (paper Eq. 19):

    min_theta ||theta - 1/lam||_2^2
    s.t.      |fhat_j^T theta| <= 1  for all features j
              theta^T y = 0,   theta >= 0

with ``fhat_j = y * X[j]`` (elementwise label signing).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "safe_theta_and_delta",
    "bias_at_lambda_max",
    "lambda_max",
    "first_features",
    "theta_at_lambda_max",
    "xi_from_primal",
    "theta_from_primal",
    "primal_objective",
    "dual_objective",
    "duality_gap_estimate",
]


def bias_at_lambda_max(y: jax.Array) -> jax.Array:
    """Optimal bias when ``w = 0``: ``b* = (n+ - n-)/n`` (paper Sec. 4)."""
    return jnp.mean(y)


def lambda_max(X: jax.Array, y: jax.Array) -> jax.Array:
    """Smallest ``lam`` such that ``w*(lam) = 0`` (paper Eq. 26).

    ``lambda_max = || sum_i (y_i - b*) x_i ||_inf = || X (y - b*) ||_inf``.
    Computed in the row-stable formulation (``screening.row_dot``) so the
    out-of-core ``sparse.lambda_max_stream`` — a max of per-chunk maxima —
    reproduces this value bitwise and both storages walk the *same*
    default lambda grid.
    """
    from .screening import row_dot  # local: keep dual.py dependency-light

    b_star = bias_at_lambda_max(y)
    moment = row_dot(X, y - b_star)
    return jnp.max(jnp.abs(moment))


def first_features(X: jax.Array, y: jax.Array) -> jax.Array:
    """Index of the first feature to enter the model (paper Sec. 5)."""
    b_star = bias_at_lambda_max(y)
    moment = X @ (y - b_star)
    return jnp.argmax(jnp.abs(moment))


def theta_at_lambda_max(y: jax.Array, lam_max: jax.Array) -> jax.Array:
    """Closed-form dual point at ``lam_max`` (paper Eq. 20 with w=0).

    ``theta_i = max(0, 1 - y_i b*) / lam_max``; with ``b* in [-1, 1]`` the max
    is inactive, so ``theta_i = (1 - y_i b*) / lam_max`` and ``theta^T y = 0``
    holds exactly.
    """
    b_star = bias_at_lambda_max(y)
    return (1.0 - y * b_star) / lam_max


def xi_from_primal(X: jax.Array, y: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Hinge slack ``xi_i = max(0, 1 - y_i (w^T x_i + b))`` (paper Eq. 20)."""
    margins = y * (X.T @ w + b)
    return jnp.maximum(0.0, 1.0 - margins)


def theta_from_primal(
    X: jax.Array, y: jax.Array, w: jax.Array, b: jax.Array, lam: jax.Array
) -> jax.Array:
    """``theta = xi / lam`` (paper Eq. 20)."""
    return xi_from_primal(X, y, w, b) / lam


def primal_objective(
    X: jax.Array, y: jax.Array, w: jax.Array, b: jax.Array, lam: jax.Array
) -> jax.Array:
    xi = xi_from_primal(X, y, w, b)
    return 0.5 * jnp.sum(xi * xi) + lam * jnp.sum(jnp.abs(w))


def dual_objective(alpha: jax.Array) -> jax.Array:
    """``D(alpha) = sum_i alpha_i - 1/2 sum_i alpha_i^2`` (from paper Eq. 13/16).

    The dual problem is ``max_alpha D(alpha)`` subject to
    ``|fhat_j^T alpha| <= lam``, ``alpha^T y = 0``, ``alpha >= 0``.
    """
    return jnp.sum(alpha) - 0.5 * jnp.sum(alpha * alpha)


class GapEstimate(NamedTuple):
    gap: jax.Array
    primal: jax.Array
    dual: jax.Array
    alpha: jax.Array  # the dual-feasible point achieving ``dual``

    @property
    def theta_radius(self):
        """``||theta_feas - theta*|| <= sqrt(2 gap)/lam`` by 1-strong concavity
        of D(alpha); divide by lam at the call site (theta = alpha/lam)."""
        return jnp.sqrt(2.0 * jnp.maximum(self.gap, 0.0))


def duality_gap_estimate(
    X: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b: jax.Array,
    lam: jax.Array,
    n_feas_iters: int = 2,
) -> GapEstimate:
    """Approximate duality gap via feasibility projection of ``alpha = xi``.

    ``alpha = xi(w, b)`` satisfies the box/equality constraints only at the
    optimum; we alternate (a) rescale so ``max_j |fhat_j^T alpha| <= lam`` and
    (b) clip the ``alpha^T y = 0`` projection to stay nonnegative. The result
    is dual-feasible up to the equality residual; good enough as a stopping
    heuristic and reported as an *estimate*.
    """
    alpha = xi_from_primal(X, y, w, b)
    p_obj = 0.5 * jnp.sum(alpha * alpha) + lam * jnp.sum(jnp.abs(w))
    n = y.shape[0]

    def body(alpha, _):
        corr = X @ (y * alpha)  # fhat_j^T alpha for all j
        scale = jnp.minimum(1.0, lam / jnp.maximum(jnp.max(jnp.abs(corr)), 1e-30))
        alpha = alpha * scale
        alpha = jnp.maximum(0.0, alpha - (alpha @ y) / n * y)
        return alpha, None

    alpha, _ = jax.lax.scan(body, alpha, None, length=n_feas_iters)
    # final rescale so the inequality constraints hold for sure
    corr = X @ (y * alpha)
    scale = jnp.minimum(1.0, lam / jnp.maximum(jnp.max(jnp.abs(corr)), 1e-30))
    alpha = alpha * scale
    d_obj = dual_objective(alpha)
    return GapEstimate(gap=p_obj - d_obj, primal=p_obj, dual=d_obj, alpha=alpha)


def safe_theta_and_delta(
    X: jax.Array, y: jax.Array, w: jax.Array, b: jax.Array, lam: jax.Array,
    n_feas_iters: int = 8,
) -> tuple[jax.Array, jax.Array]:
    """(theta1, delta) for screening from an *approximate* primal solution.

    theta1 is a (near-)dual-feasible point; delta upper-bounds
    ``||theta1 - theta*||`` via 1-strong concavity of the dual plus a slack
    for the residual of the ``alpha^T y = 0`` equality after the alternating
    projection. Feed both into ``screening.screen(..., delta=delta)``.
    """
    est = duality_gap_estimate(X, y, w, b, lam, n_feas_iters=n_feas_iters)
    n = y.shape[0]
    eq_resid = jnp.abs(est.alpha @ y) / jnp.sqrt(jnp.asarray(float(n), y.dtype))
    delta = (est.theta_radius + 2.0 * eq_resid) / lam
    return est.alpha / lam, delta
