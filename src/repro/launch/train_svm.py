"""Distributed sparse-SVM path trainer — the paper's workload as a launcher.

Runs the sequential-screening regularization path with the 2-D sharded
(model x data) screen + FISTA from core/distributed.py, with checkpointing of
the path state ((lambda_k, w, b, theta) per step) so a preempted path job
resumes at the last completed lambda.

CPU smoke: PYTHONPATH=src python -m repro.launch.train_svm --m 2000 --n 400
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import (
    default_lambda_grid,
    lambda_max,
    theta_at_lambda_max,
)
from repro.core.distributed import fista_sharded, screen_sharded, svm_mesh
from repro.core.dual import safe_theta_and_delta
from repro.data import make_sparse_classification


def run_path(
    X: np.ndarray, y: np.ndarray,
    n_lambdas: int = 10, lam_min_ratio: float = 0.1,
    model: int = 1, data: int = 1,
    tol: float = 1e-9, max_iters: int = 4000,
    ckpt_dir: str = "artifacts/svm_ckpt", log=print,
):
    mesh = svm_mesh(model=model, data=data)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    m, n = Xj.shape

    lmax = float(lambda_max(Xj, yj))
    lambdas = default_lambda_grid(lmax, n_lambdas, lam_min_ratio)
    mgr = CheckpointManager(ckpt_dir, keep=2)

    state = {
        "w": jnp.zeros((m,), jnp.float32),
        "b": jnp.asarray(float(jnp.mean(yj)), jnp.float32),
        "theta": theta_at_lambda_max(yj, jnp.asarray(lmax)),
        "delta": jnp.asarray(0.0, jnp.float32),
        "k": jnp.asarray(0, jnp.int32),
    }
    start_k = 1
    latest = mgr.latest()
    if latest is not None:
        state, manifest = mgr.restore(latest, state)
        start_k = int(manifest["extra"]["next_k"])
        log(f"[svm] resumed path at lambda index {start_k}")

    results = []
    for k in range(start_k, len(lambdas)):
        lam1, lam2 = float(lambdas[k - 1]), float(lambdas[k])
        t0 = time.perf_counter()
        keep, bounds = screen_sharded(mesh, Xj, yj, lam1, lam2, state["theta"])
        kept = int(jnp.sum(keep))
        # mask-mode reduction keeps static shapes across the sharded solve
        Xr = Xj * keep[:, None].astype(Xj.dtype)
        res = fista_sharded(mesh, Xr, yj, lam2, max_iters=max_iters, tol=tol,
                            w0=state["w"] * keep, b0=state["b"])
        theta, delta = safe_theta_and_delta(Xj, yj, res.w, res.b,
                                            jnp.asarray(lam2))
        state = {"w": res.w, "b": res.b, "theta": theta, "delta": delta,
                 "k": jnp.asarray(k, jnp.int32)}
        dt = time.perf_counter() - t0
        nnz = int(jnp.sum(jnp.abs(res.w) > 1e-8))
        results.append({"lam": lam2, "kept": kept, "nnz": nnz,
                        "obj": float(res.obj), "iters": int(res.n_iters),
                        "wall_s": dt})
        log(f"[svm] k={k} lam={lam2:.4f} kept={kept}/{m} nnz={nnz} "
            f"obj={float(res.obj):.5f} ({dt:.2f}s)")
        mgr.save(k, state, extra={"next_k": k + 1, "lambdas": list(map(float, lambdas))})
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2000)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--n-lambdas", type=int, default=8)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/svm_ckpt")
    args = ap.parse_args()

    ds = make_sparse_classification(m=args.m, n=args.n, seed=0)
    results = run_path(ds.X, ds.y, n_lambdas=args.n_lambdas,
                       model=args.model, data=args.data,
                       ckpt_dir=args.ckpt_dir)
    Path("artifacts").mkdir(exist_ok=True)
    Path("artifacts/svm_path.json").write_text(json.dumps(results, indent=2))


if __name__ == "__main__":
    main()
