"""Distributed sparse-SVM path trainer — the paper's workload as a launcher.

Runs the sequential-screening regularization path with the 2-D sharded
(model x data) screen + FISTA from core/distributed.py, with checkpointing of
the path state ((lambda_k, w, b, theta) per step) so a preempted path job
resumes at the last completed lambda.

Screening is configured through the rule registry (core/rules):
``--rules feature_vi|sample_vi|composite|dvi|none``. The feature rule
dispatches to the sharded bound sweep (``screen_sharded`` — same math,
psum-reduced, delta-inflated for the sequentially-solved anchor); sample
rules run their margin test on the replicated sample axis and mask the loss
inside ``fista_sharded`` (static shapes, shard-friendly), with the rule's
KKT verification loop re-admitting violators before a step commits.
``--dynamic`` additionally re-screens *inside* the sharded FISTA loop every
``--screen-every`` iterations from the gap-certified region at the current
iterate, ANDing into a live "model"-sharded feature mask (per-segment kept
counts land in the results JSON).

``--engine scan`` swaps the host loop for the on-device path engine
(``core/path_scan.py``): the whole path runs as ONE jitted program — as a
single ``shard_map``'d program on the (model x data) mesh when the mesh has
more than one device, locally otherwise. ``--reduce compact`` (single-device
scan) additionally gathers each step's certified active set into a
fixed-capacity buffer so solver FLOPs track what screening keeps. The scan
engine is feature-rule-only and runs start-to-finish in one dispatch, so
``--rules``/``--dynamic`` and per-step checkpoint/resume stay host-engine
features.

``--serve`` switches the launcher into multi-tenant mode: a synthetic
mixed-grid job queue drains through ``launch/path_server.py`` (continuous
batching of the batched scan step, ``--reduce`` selecting mask vs
shared-cap compact solves) and the throughput/cache stats land in
``artifacts/svm_serve.json``.

CPU smoke: PYTHONPATH=src python -m repro.launch.train_svm --m 2000 --n 400
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace
from repro.obs.log import get_logger, setup as log_setup

_LOG = get_logger("launch.train_svm")

from repro.checkpoint import CheckpointManager
from repro.core import (
    default_lambda_grid,
    lambda_max,
    lipschitz_estimate,
    theta_at_lambda_max,
)
from repro.core.distributed import (
    fista_sharded,
    sample_surplus_sharded,
    screen_sharded,
    svm_mesh,
)
from repro.core.dual import safe_theta_and_delta
from repro.core.rules import (
    AXIS_FEATURES,
    AXIS_SAMPLES,
    ConvexRegion,
    FeatureVIRule,
    SampleVIRule,
    make_rules,
)
from repro.core.rules.base import dynamic_tau, solve_with_verification
from repro.data import load_libsvm, make_sparse_classification


def run_path_scan(
    X: np.ndarray, y: np.ndarray,
    n_lambdas: int = 10, lam_min_ratio: float = 0.1,
    model: int = 1, data: int = 1,
    tol: float = 1e-9, max_iters: int = 4000,
    reduce: str = "mask",
    rules: str = "feature_vi",
    dynamic: bool = False,
    screen_every: int = 50,
    exact_lipschitz: bool = False,
    log=None,
):
    """The launcher's scan-engine lane: one program, no per-step host loop.

    Multi-device meshes run ``svm_path_scan_sharded`` (mask reduction — the
    feature axis is already divided by sharding — and no in-solver dynamic
    re-screen yet); a single-device mesh runs ``svm_path_scan`` and honors
    ``--reduce compact`` and ``--dynamic/--screen-every``. Unsupported flag
    combinations raise rather than silently running a different experiment.
    """
    from repro.core import svm_path_scan, svm_path_scan_sharded

    if log is None:
        log = _LOG.info
    # lowerability of the rule spec is validated by the engines at dispatch
    # (rules/programs.resolve_programs): any a-priori-safe feature-rule
    # stack (feature_vi / edpp / dvi / auto / lists) runs in the jitted
    # step; sample rules and sifs raise with a pointer to --engine host
    screening = rules != "none"
    if model * data > 1:
        if reduce == "compact":
            raise ValueError(
                "--reduce compact needs the single-device scan engine "
                "(compaction indexes global feature rows); on a mesh the "
                "feature axis is already divided by sharding — use "
                "--reduce mask"
            )
        if dynamic:
            raise ValueError(
                "--dynamic is not plumbed through the sharded scan engine "
                "yet; use --engine host or a single-device mesh"
            )
        mesh = svm_mesh(model=model, data=data)
        r = svm_path_scan_sharded(mesh, X, y, n_lambdas=n_lambdas,
                                  lam_min_ratio=lam_min_ratio, tol=tol,
                                  max_iters=max_iters, screening=screening,
                                  exact_lipschitz=exact_lipschitz,
                                  rules=rules)
    else:
        r = svm_path_scan(X, y, n_lambdas=n_lambdas,
                          lam_min_ratio=lam_min_ratio, tol=tol,
                          max_iters=max_iters, reduce=reduce,
                          screening=screening, dynamic=dynamic,
                          screen_every=screen_every,
                          exact_lipschitz=exact_lipschitz,
                          rules=rules)
    m = X.shape[0]
    results = []
    for k in range(len(r.lambdas)):
        row = {"lam": float(r.lambdas[k]), "kept": int(r.kept[k]),
               "nnz": int(r.active[k]), "obj": float(r.objectives[k]),
               "iters": int(r.solver_iters[k]),
               "cap": int(r.extras["caps"][k]),
               "resurrected": int(r.extras["resurrected"][k])}
        results.append(row)
        log(f"[svm] k={k} lam={row['lam']:.4f} kept={row['kept']}/{m} "
            f"cap={row['cap']} nnz={row['nnz']} obj={row['obj']:.5f}")
    log(f"[svm] engine={r.extras['engine']} reduce={reduce} "
        f"total={r.extras['total_seconds']:.2f}s (single dispatch, "
        "per-step walls not observable)")
    return results


def run_path(
    X: np.ndarray, y: np.ndarray,
    n_lambdas: int = 10, lam_min_ratio: float = 0.1,
    model: int = 1, data: int = 1,
    tol: float = 1e-9, max_iters: int = 4000,
    ckpt_dir: str = "artifacts/svm_ckpt", log=None,
    rules: str = "feature_vi",
    shrink_factor: float = 1.5,
    max_verify_rounds: int = 3,
    dynamic: bool = False,
    screen_every: int = 50,
    exact_lipschitz: bool = False,
):
    if log is None:
        log = _LOG.info
    mesh = svm_mesh(model=model, data=data)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    m, n = Xj.shape
    X_np, y_np = np.asarray(X), np.asarray(y)

    # one Lipschitz estimate serves the whole path: every per-step solve is
    # a masked reduction of X, whose sigma_max never exceeds the full
    # matrix's (see solver.lipschitz_estimate) — saves the 30-iteration
    # distributed power sweep per solve and per verification round.
    L_path = None if exact_lipschitz else lipschitz_estimate(Xj)

    rule_list = make_rules(None if rules in (None, "none") else rules)
    feature_rules = [r for r in rule_list if r.axis == AXIS_FEATURES]
    sample_rules = [r for r in rule_list if r.axis == AXIS_SAMPLES]
    # the stock rules dispatch to their sharded psum sweeps (same bounds /
    # surpluses, mesh-parallel): feature_vi -> screen_sharded, sample_vi ->
    # sample_surplus_sharded; other rules go through their generic
    # bounds/keep. Only the generic-path rules need their prepare() caches
    # (the sharded sample sweep keeps its secant history on the rule object).
    sharded_feature = [r for r in feature_rules if type(r) is FeatureVIRule]
    generic_feature = [r for r in feature_rules if type(r) is not FeatureVIRule]
    sharded_sample = [r for r in sample_rules if type(r) is SampleVIRule]
    generic_sample = [r for r in sample_rules if type(r) is not SampleVIRule]
    for rule in (*generic_feature, *sample_rules):
        rule.prepare(Xj, yj)

    lmax = float(lambda_max(Xj, yj))
    lambdas = default_lambda_grid(lmax, n_lambdas, lam_min_ratio)
    mgr = CheckpointManager(ckpt_dir, keep=2)

    state = {
        "w": jnp.zeros((m,), jnp.float32),
        "b": jnp.asarray(float(jnp.mean(yj)), jnp.float32),
        "theta": theta_at_lambda_max(yj, jnp.asarray(lmax)),
        "delta": jnp.asarray(0.0, jnp.float32),
        "dw": jnp.asarray(jnp.inf, jnp.float32),
        "db": jnp.asarray(jnp.inf, jnp.float32),
        "k": jnp.asarray(0, jnp.int32),
    }
    start_k = 1
    latest = mgr.latest()
    if latest is not None:
        # strict=False: checkpoints written before the dw/db trust-region
        # fields existed restore with those fields at their defaults
        state, manifest = mgr.restore(latest, state, strict=False)
        start_k = int(manifest["extra"]["next_k"])
        log(f"[svm] resumed path at lambda index {start_k}")

    results = []
    for k in range(start_k, len(lambdas)):
        lam1, lam2 = float(lambdas[k - 1]), float(lambdas[k])
        t0 = time.perf_counter()

        region = ConvexRegion.build(
            yj, lam1, lam2, state["theta"], delta=state["delta"],
            w1=state["w"], b1=float(state["b"]),
            dw=float(state["dw"]), db=float(state["db"]),
        )
        keep = jnp.ones((m,), bool)
        for rule in sharded_feature:
            # state["delta"] bounds ||theta - theta*(lam1)|| for the
            # sequentially-solved anchor; without it the sharded screen
            # would assume theta exact and could unsafely reject features
            k_mask, _ = screen_sharded(mesh, Xj, yj, lam1, lam2,
                                       state["theta"], tau=rule.tau,
                                       delta=state["delta"])
            keep = keep & k_mask
        for rule in generic_feature:
            keep = keep & jnp.asarray(rule.keep(rule.bounds(Xj, yj, region)))
        s_mask = np.ones((n,), dtype=bool)
        for rule in sharded_sample:
            # the mesh-parallel margin sweep (ROADMAP: queued since PR 4):
            # same two feature-axis reductions, psum over "model", and the
            # rule's own slack arithmetic — bitwise the local oracle on
            # meshes that keep the feature axis whole. The secant history
            # lives on the rule object, exactly as in the local path.
            surplus, u1 = sample_surplus_sharded(
                mesh, Xj, yj, state["w"], float(state["b"]),
                dw=float(state["dw"]), db=float(state["db"]),
                u_prev=rule._u_prev, shrink_factor=rule.shrink_factor,
                margin_floor=rule.margin_floor,
            )
            rule._u_prev = u1
            s_mask &= np.asarray(surplus < 0.0)
        for rule in generic_sample:
            s_mask &= np.asarray(rule.keep(rule.bounds(Xj, yj, region)))

        kept = int(jnp.sum(keep))
        # mask-mode reduction keeps static shapes across the sharded solve
        Xr = Xj * keep[:, None].astype(Xj.dtype)
        warm = {"w": state["w"] * keep, "b": state["b"]}

        def solve(mask):
            # the dynamic segmented solve keeps tightening the feature mask
            # in-loop, seeded from the between-lambda sequential screen
            r = fista_sharded(
                mesh, Xr, yj, lam2, max_iters=max_iters, tol=tol,
                w0=warm["w"], b0=warm["b"],
                sample_mask=jnp.asarray(mask, jnp.float32),
                feature_mask=keep.astype(jnp.float32),
                screen_every=screen_every if dynamic else None,
                tau=dynamic_tau(feature_rules),
                L=L_path,
            )
            warm["w"], warm["b"] = r.w, r.b
            return r, np.asarray(r.w, np.float64), float(r.b)

        res, _, _, rounds = solve_with_verification(
            solve, sample_rules, X_np, y_np, s_mask,
            max_rounds=max_verify_rounds,
        )

        dw_obs = float(jnp.linalg.norm(res.w - state["w"]))
        db_obs = abs(float(res.b) - float(state["b"]))
        theta, delta = safe_theta_and_delta(Xj, yj, res.w, res.b,
                                            jnp.asarray(lam2))
        state = {"w": res.w, "b": res.b, "theta": theta, "delta": delta,
                 "dw": jnp.asarray(shrink_factor * dw_obs, jnp.float32),
                 "db": jnp.asarray(shrink_factor * db_obs, jnp.float32),
                 "k": jnp.asarray(k, jnp.int32)}
        dt = time.perf_counter() - t0
        obs_trace.complete("path.step", t0, t0 + dt, step=k, lam=lam2,
                           kept=kept, iters=int(res.n_iters))
        nnz = int(jnp.sum(jnp.abs(res.w) > 1e-8))
        kept_n = int(s_mask.sum())
        row = {"lam": lam2, "kept": kept, "kept_samples": kept_n,
               "nnz": nnz, "obj": float(res.obj),
               "iters": int(res.n_iters), "verify_rounds": rounds,
               "wall_s": dt}
        dyn_note = ""
        if hasattr(res, "kept_per_segment"):
            n_seg = int(res.n_segments)
            segs = [int(v) for v in np.asarray(res.kept_per_segment)[:n_seg]]
            row["dynamic_kept_per_segment"] = segs
            row["kept_final"] = int(np.asarray(res.feature_mask).sum())
            dyn_note = f" dyn={segs}"
        results.append(row)
        log(f"[svm] k={k} lam={lam2:.4f} kept={kept}/{m} "
            f"samples={kept_n}/{n} nnz={nnz} obj={float(res.obj):.5f} "
            f"({dt:.2f}s){dyn_note}")
        mgr.save(k, state, extra={"next_k": k + 1, "lambdas": list(map(float, lambdas))})
    return results


def run_path_chunked(
    X, y, csr=None,
    n_lambdas: int = 10, lam_min_ratio: float = 0.1,
    tol: float = 1e-9, max_iters: int = 4000,
    rules: str = "feature_vi",
    storage: str = "chunked", chunk_m: int = 512,
    exact_lipschitz: bool = False,
    chunk_skip: bool = True,
    dynamic: bool = False,
    screen_every: int = 50,
    libsvm_path=None,
    store_dir=None,
    log=None,
):
    """The launcher's out-of-core lane: stream the screened path over
    ``repro.sparse.FeatureChunked`` storage (``--storage chunked|csr``).

    ``csr`` (a ``repro.data.CsrData``, e.g. from a sparse synthetic design
    or the libsvm loader) backs ``--storage csr``; low-density chunks sweep
    as BCOO so screening FLOPs track nnz. ``store_dir`` (with a libsvm
    input) keeps the chunks disk-resident: the file is converted once into
    an mmap-backed chunk store (``FeatureChunked.from_libsvm_cached``) and
    subsequent runs open the store without re-parsing — host RAM holds no
    copy of X either. Single-host by construction — the whole point is
    that only one chunk (plus the screened active set) ever sits on the
    device; ``chunk_skip`` additionally skips the *transfer* of chunks the
    stale-anchor cache certifies dead (see ``PathDriver``).
    """
    from repro.core import PathDriver
    from repro.sparse import FeatureChunked

    if log is None:
        log = _LOG.info
    # program-backed feature stacks stream (feature_vi / edpp / dvi /
    # auto); sample rules (sample_vi / composite / sifs) ride the
    # transposed sweep + carried-margin verification; the driver lane
    # validates the spec itself
    rule_spec = [] if rules in (None, "none") else rules
    if storage == "mmap" or store_dir is not None:
        if libsvm_path is not None:
            fc, y = FeatureChunked.from_libsvm_cached(
                libsvm_path, store_dir=store_dir, chunk_m=chunk_m)
        elif store_dir is not None:
            # open an existing store directly: a missing directory raises
            # StoreMissingError, checksum/size damage StoreCorruptError —
            # both reach the CLI as a typed message + nonzero exit
            fc = FeatureChunked.from_store(store_dir, chunk_m=chunk_m)
            fc.verify()
            if fc.labels is None:
                raise ValueError(
                    f"store {store_dir} has no labels (y.bin); rebuild it "
                    "from the source text with --libsvm FILE")
            y = fc.labels
        else:
            raise ValueError("--storage mmap needs --libsvm FILE (to build "
                             "the store) or --store-dir DIR (to open an "
                             "existing one)")
    elif storage == "csr":
        if csr is None:
            raise ValueError(
                "--storage csr needs a CSR-backed dataset: generate with "
                "--density < 1 or load one with --libsvm"
            )
        fc = FeatureChunked.from_csr(csr, chunk_m=chunk_m)
    else:
        fc = FeatureChunked.from_dense(X, chunk_m=chunk_m)
    driver = PathDriver(rules=rule_spec, tol=tol, max_iters=max_iters,
                        exact_lipschitz=exact_lipschitz,
                        chunk_skip=chunk_skip, dynamic=dynamic,
                        screen_every=screen_every)
    r = driver.run(fc, y, n_lambdas=n_lambdas, lam_min_ratio=lam_min_ratio)
    m, n = fc.shape
    results = []
    for k in range(len(r.lambdas)):
        row = {"lam": float(r.lambdas[k]), "kept": int(r.kept[k]),
               "kept_samples": int(r.kept_samples[k]),
               "live_chunks": int(r.extras["live_chunks"][k]),
               "nnz": int(r.active[k]), "obj": float(r.objectives[k]),
               "iters": int(r.solver_iters[k]),
               "wall_s": float(r.wall_times[k])}
        results.append(row)
        log(f"[svm] k={k} lam={row['lam']:.4f} kept={row['kept']}/{m} "
            f"samples={row['kept_samples']}/{n} "
            f"chunks={row['live_chunks']}/{r.extras['n_chunks']} "
            f"nnz={row['nnz']} obj={row['obj']:.5f} ({row['wall_s']:.2f}s)")
    st = r.extras["stream_stats"]
    log(f"[svm] storage={storage} chunks={r.extras['n_chunks']} "
        f"chunk_m={chunk_m} max_device_rows={st['max_put_rows']} "
        f"transfers={st['puts']} bcoo_transfers={st['bcoo_puts']} "
        f"streamed={st['chunks_streamed']} skipped={st['chunks_skipped']} "
        f"bytes_put={st['bytes_put']}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2000)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--n-lambdas", type=int, default=8)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--density", type=float, default=1.0,
                    help="synthetic X density; < 1 also builds a true CSR "
                         "representation (feeds --storage csr)")
    ap.add_argument("--libsvm", default=None, metavar="FILE",
                    help="load a libsvm/svmlight text file instead of "
                         "generating synthetic data")
    ap.add_argument("--storage", choices=("dense", "chunked", "csr", "mmap"),
                    default="dense",
                    help="dense: in-core (m, n) device matrix; chunked: "
                         "host-resident feature chunks streamed to device "
                         "(out-of-core); csr: chunked CSR, low-density "
                         "chunks swept as BCOO; mmap: disk-resident chunk "
                         "store built once from --libsvm (nothing in host "
                         "RAM either)")
    ap.add_argument("--chunk-m", type=int, default=512,
                    help="feature rows per chunk for --storage "
                         "chunked|csr|mmap")
    ap.add_argument("--store-dir", default=None, metavar="DIR",
                    help="mmap chunk-store directory for --storage mmap "
                         "(default: <libsvm file>.store)")
    ap.add_argument("--no-chunk-skip", dest="chunk_skip",
                    action="store_false",
                    help="chunked storage: stream every chunk every step "
                         "instead of skipping chunks certified dead by the "
                         "stale-anchor cache (the full-stream baseline)")
    ap.add_argument("--rules", default="feature_vi",
                    help="screening rules: feature_vi|sample_vi|composite|"
                         "dvi|edpp|sifs|auto|none (comma-separated for a "
                         "custom mix; the scan engine takes a-priori-safe "
                         "feature-rule stacks only, chunked storage adds "
                         "verified sample rules via the transposed sweep)")
    ap.add_argument("--engine", choices=("host", "scan"), default="host",
                    help="host: per-step sharded loop with checkpointing; "
                         "scan: the whole path as one (shard_map'd) XLA "
                         "program (a-priori-safe feature-rule stacks only)")
    ap.add_argument("--reduce", choices=("mask", "compact"), default="mask",
                    help="scan engine: mask-mode solve vs on-device "
                         "active-set compaction (single-device mesh only)")
    ap.add_argument("--dynamic", action="store_true",
                    help="re-screen inside the sharded FISTA loop every "
                         "--screen-every iterations (gap-certified)")
    ap.add_argument("--screen-every", type=int, default=50)
    ap.add_argument("--exact-lipschitz", action="store_true",
                    help="re-estimate L per solve instead of reusing the "
                         "full-X upper bound computed once per path")
    ap.add_argument("--ckpt-dir", default="artifacts/svm_ckpt")
    ap.add_argument("--serve", action="store_true",
                    help="multi-tenant mode: drain a synthetic job mix "
                         "through launch/path_server.py (continuous "
                         "batching of the batched scan step) instead of "
                         "solving one path")
    ap.add_argument("--serve-jobs", type=int, default=8)
    ap.add_argument("--serve-slots", type=int, default=4)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record obs spans around the path and export "
                         "Chrome trace-event JSON here (open in Perfetto / "
                         "chrome://tracing); equivalent to REPRO_TRACE=1 "
                         "plus an export")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace of the path "
                         "into DIR (view with TensorBoard / Perfetto; the "
                         "engines' named_scope annotations label the "
                         "regions)")
    args = ap.parse_args()

    log_setup()
    _obs_begin(args)
    try:
        _run(args, ap)
    finally:
        _obs_end(args)


def _obs_begin(args):
    """Arm the observability capture selected on the command line: the obs
    span recorder (``--trace``) and/or the jax device profiler
    (``--profile``), both spanning the whole path dispatch."""
    if args.trace:
        obs_trace.enable()
    if args.profile:
        jax.profiler.start_trace(args.profile)


def _obs_end(args):
    if args.profile:
        jax.profiler.stop_trace()
        _LOG.info("profiler trace captured to %s", args.profile)
    if args.trace:
        path = obs_trace.export_chrome(args.trace)
        _LOG.info("chrome trace written to %s (load in Perfetto)", path)


def _run(args, ap):
    if args.serve:
        from repro.launch.path_server import PathServer, demo_jobs

        if args.engine != ap.get_default("engine") or args.storage != "dense":
            raise SystemExit(
                "--serve runs the batched scan step through the path "
                "server; --engine/--storage do not apply"
            )
        server = PathServer(slots=args.serve_slots, reduce=args.reduce)
        jobs = demo_jobs(args.serve_jobs, m=args.m, n=args.n)
        server.serve(jobs)
        Path("artifacts").mkdir(exist_ok=True)
        Path("artifacts/svm_serve.json").write_text(
            json.dumps(server.last_serve, indent=2))
        Path("artifacts/svm_serve_metrics.json").write_text(
            json.dumps(server.metrics(), indent=2, default=str))
        return

    rules = args.rules if "," not in args.rules else args.rules.split(",")
    if args.storage == "mmap":
        # the mmap store is built straight from the file (or opened from
        # --store-dir) by the chunked lane — never materialize X in host
        # RAM here
        from repro.data import SvmDataset

        ds = SvmDataset(X=None, y=None, w_true=None, csr=None)
    elif args.libsvm:
        ds = load_libsvm(args.libsvm)
    else:
        ds = make_sparse_classification(m=args.m, n=args.n, seed=0,
                                        density=args.density)
    if args.engine == "host" and args.reduce != "mask":
        raise SystemExit(
            f"--reduce {args.reduce} is a scan-engine option; the host "
            "engine reduces via its rule drivers (gather/mask). Add "
            "--engine scan."
        )
    if args.engine == "scan" and args.ckpt_dir != ap.get_default("ckpt_dir"):
        raise SystemExit(
            "--ckpt-dir has no effect with --engine scan: the whole path is "
            "one dispatch, so there is no per-step state to checkpoint or "
            "resume. Use --engine host for checkpointed paths."
        )
    if args.storage != "dense":
        if args.engine == "scan":
            raise SystemExit(
                "--storage chunked|csr runs on the host engine (the scan "
                "engine jit-compiles over an in-core X); drop --engine scan"
            )
        if args.model * args.data > 1:
            raise SystemExit(
                "--storage chunked|csr|mmap is single-host streaming (one "
                "chunk on one device); use --storage dense for sharded "
                "meshes"
            )
        from repro.sparse import StoreError

        try:
            results = run_path_chunked(
                ds.X, ds.y, csr=ds.csr, n_lambdas=args.n_lambdas,
                rules=args.rules, storage=args.storage, chunk_m=args.chunk_m,
                exact_lipschitz=args.exact_lipschitz,
                chunk_skip=args.chunk_skip, dynamic=args.dynamic,
                screen_every=args.screen_every,
                libsvm_path=args.libsvm, store_dir=args.store_dir)
        except StoreError as e:
            # typed storage failure (missing store, checksum mismatch,
            # exhausted read retries) — a clean message and a nonzero
            # exit, not a traceback
            _LOG.error("%s: %s", type(e).__name__, e)
            raise SystemExit(2)
        Path("artifacts").mkdir(exist_ok=True)
        Path("artifacts/svm_path.json").write_text(json.dumps(results, indent=2))
        return
    if args.engine == "scan":
        results = run_path_scan(ds.X, ds.y, n_lambdas=args.n_lambdas,
                                model=args.model, data=args.data,
                                reduce=args.reduce, rules=args.rules,
                                dynamic=args.dynamic,
                                screen_every=args.screen_every,
                                exact_lipschitz=args.exact_lipschitz)
    else:
        results = run_path(ds.X, ds.y, n_lambdas=args.n_lambdas,
                           model=args.model, data=args.data,
                           ckpt_dir=args.ckpt_dir, rules=rules,
                           dynamic=args.dynamic,
                           screen_every=args.screen_every,
                           exact_lipschitz=args.exact_lipschitz)
    Path("artifacts").mkdir(exist_ok=True)
    Path("artifacts/svm_path.json").write_text(json.dumps(results, indent=2))


if __name__ == "__main__":
    main()
