"""jit-able train / serve step builders shared by the trainer and the dry-run.

``make_train_step(cfg)`` returns ``step(state, batch) -> (state, metrics)``
with gradient-accumulation microbatching (compute/comm overlap: the DP
all-reduce of each microbatch's gradient is emitted inside the accumulation
scan, letting the XLA latency-hiding scheduler overlap it with the next
microbatch's compute).

``make_serve_step(cfg)`` returns the single-token decode step used by the
serving loop and the decode-shape dry-run cells.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.optim import adamw_update, cosine_schedule
from repro.optim.adamw import AdamWState, adamw_init


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


def init_train_state(cfg, key, moment_dtype=jnp.float32) -> TrainState:
    params = tr.init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params, moment_dtype))


def make_train_step(
    cfg,
    base_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    microbatches: int = 1,
    weight_decay: float = 0.1,
):
    def loss(params, batch):
        l, metrics = tr.loss_fn(params, cfg, batch)
        return l, metrics

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def step(state: TrainState, batch):
        if microbatches > 1:
            B = batch["tokens"].shape[0]
            mb = B // microbatches
            resh = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches, mb, *x.shape[1:]), batch)

            def acc(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, mbatch)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, lsum), _ = jax.lax.scan(acc, (zeros, 0.0), resh)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss_val = lsum / microbatches
            metrics = {}
        else:
            (loss_val, metrics), grads = grad_fn(state.params, batch)

        lr = cosine_schedule(state.opt.step, base_lr, warmup_steps, total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, lr, weight_decay=weight_decay)

        # NaN/Inf step rejection: a poisoned step is skipped wholesale (the
        # fault-tolerance contract — a bad node's overflow must not corrupt
        # the run; the trainer logs and continues).
        bad = ~jnp.isfinite(loss_val)
        gn = opt_metrics["grad_norm"]
        bad = bad | ~jnp.isfinite(gn)
        keep = lambda new, old: jax.tree_util.tree_map(
            lambda a, b: jnp.where(bad, b, a), new, old)
        new_params = keep(new_params, state.params)
        new_opt = AdamWState(
            step=jnp.where(bad, state.opt.step, new_opt.step),
            mu=keep(new_opt.mu, state.opt.mu),
            nu=keep(new_opt.nu, state.opt.nu),
        )
        out_metrics = {
            "loss": loss_val, "lr": lr, "grad_norm": gn,
            "skipped": bad.astype(jnp.int32),
        }
        out_metrics.update({k: v for k, v in metrics.items()})
        return TrainState(new_params, new_opt), out_metrics

    return step


def make_serve_step(cfg):
    """decode: (params, cache, tokens, positions) -> (logits, cache)."""
    def step(params, cache, tokens, positions):
        return tr.decode_step(params, cfg, tokens, positions, cache)
    return step


def make_prefill(cfg, max_seq: Optional[int] = None):
    def run(params, batch):
        return tr.prefill(params, cfg, batch, max_seq=max_seq)
    return run
