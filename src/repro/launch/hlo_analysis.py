"""Optimized-HLO analysis helpers (collective audit for the roofline).

Import-safe: no jax device-state side effects (unlike launch.dryrun, whose
module-level XLA_FLAGS override is required to precede jax init in its own
process and must never be imported for utilities).
"""

import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)", line)
        if not m:
            continue
        shape_txt, op = m.groups()
        # '-start' variants match the base name followed by '-start('
        stats[op]["count"] += 1
        stats[op]["bytes"] += _shape_bytes(shape_txt)
    return stats


