"""Fault-tolerant LM trainer.

Production behaviours exercised here (and tested in tests/test_train_loop.py):
  * auto-resume from the newest valid checkpoint (atomic, keep-k),
  * exact data replay after restart (pipeline is pure f(seed, step)),
  * NaN/Inf step rejection (in the jitted step; skipped steps logged),
  * heartbeat file + bounded step deadline for an external watchdog
    (straggler / hang mitigation at the launcher level),
  * graceful preemption: SIGTERM triggers a final checkpoint flush.

Usage (CPU smoke):  PYTHONPATH=src python -m repro.launch.train \
    --arch qwen2.5-3b --smoke --steps 20 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import json
import signal
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import TrainState, init_train_state, make_train_step


def train(
    arch: str,
    smoke: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    ckpt_dir: str = "artifacts/ckpt",
    ckpt_every: int = 10,
    seed: int = 0,
    step_deadline_s: float = 600.0,
    microbatches: int = 1,
    log=print,
):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    pipeline = TokenPipeline(vocab_size=cfg.vocab_size, batch_size=batch,
                             seq_len=seq, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps,
                                      microbatches=microbatches))

    mgr = CheckpointManager(ckpt_dir, keep=3)
    state = init_train_state(cfg, jax.random.PRNGKey(seed))

    start_step = 0
    latest = mgr.latest()
    if latest is not None:
        state, manifest = mgr.restore(latest, state)
        start_step = int(manifest["extra"].get("next_step", latest))
        log(f"[train] resumed from checkpoint step={latest} "
            f"(continuing at {start_step})")

    stop = {"flag": False}

    def _sigterm(_sig, _frm):  # preemption: flush and exit cleanly
        stop["flag"] = True

    try:
        signal.signal(signal.SIGTERM, _sigterm)
    except ValueError:
        pass  # not main thread (tests)

    hb_path = Path(ckpt_dir) / "heartbeat.json"
    losses = []
    skipped_total = 0
    for step in range(start_step, steps):
        t0 = time.perf_counter()
        batch_np = pipeline.batch_at(step)
        state, metrics = step_fn(state, jax.tree_util.tree_map(jnp.asarray, batch_np))
        loss = float(metrics["loss"])
        skipped_total += int(metrics["skipped"])
        dt = time.perf_counter() - t0
        losses.append(loss)

        # heartbeat for the external watchdog (hang/straggler detection)
        hb_path.write_text(json.dumps(
            {"step": step, "time": time.time(), "loss": loss,
             "deadline_s": step_deadline_s}))
        if dt > step_deadline_s:
            log(f"[train] WARNING step {step} exceeded deadline "
                f"({dt:.1f}s > {step_deadline_s}s)")

        if (step + 1) % ckpt_every == 0 or step == steps - 1 or stop["flag"]:
            mgr.save(step, state, extra={"next_step": step + 1,
                                         "arch": arch, "seed": seed})
        if stop["flag"]:
            log(f"[train] preempted at step {step}; checkpoint flushed")
            break
        if step % 5 == 0:
            log(f"[train] step={step} loss={loss:.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")

    return {"losses": losses, "final_state": state, "skipped": skipped_total,
            "last_step": step}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
                seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                seed=args.seed, microbatches=args.microbatches)
    print(f"[train] done. loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"({out['skipped']} skipped steps)")


if __name__ == "__main__":
    main()
