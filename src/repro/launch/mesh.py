"""Production mesh construction.

Single pod : (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh for CPU tests / examples (uses whatever devices exist)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"))
