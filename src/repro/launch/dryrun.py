"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on 512
placeholder host devices, and extract the roofline inputs.

MUST be run as its own process (``python -m repro.launch.dryrun ...``): the
device-count override below has to land before jax initializes.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config, input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import init_train_state, make_serve_step, make_train_step  # noqa: E402
from repro.launch.hlo_analysis import _shape_bytes, collective_stats  # noqa: E402,F401
from repro.models import transformer as tr  # noqa: E402
from repro.models.sharding import input_sharding_specs, param_specs  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

def build_cell(cfg, shape_name, mesh):
    """Returns (fn, args_SDS, in_shardings) for a cell."""
    kind = SHAPES[shape_name]["kind"]
    specs = input_specs(cfg, shape_name)
    in_specs = input_sharding_specs(cfg, specs, mesh)

    if kind == "train":
        state_sds = jax.eval_shape(
            lambda k: init_train_state(cfg, k), jax.random.PRNGKey(0))
        pspecs = param_specs(state_sds.params, mesh)
        state_specs = jax.tree_util.tree_map(
            lambda l: P(), state_sds, is_leaf=lambda l: isinstance(l, jax.ShapeDtypeStruct))
        state_specs = state_specs._replace(
            params=pspecs,
            opt=state_sds.opt._replace(
                step=P(),
                mu=param_specs(state_sds.opt.mu, mesh),
                nu=param_specs(state_sds.opt.nu, mesh),
            ),
        )
        step = make_train_step(cfg)
        args = (state_sds, specs)
        shard = (state_specs, in_specs)
        return step, args, shard

    params_sds = jax.eval_shape(
        lambda k: tr.init_params(cfg, k), jax.random.PRNGKey(0))
    pspecs = param_specs(params_sds, mesh)

    if kind == "prefill":
        def fn(params, batch):
            return tr.prefill(params, cfg, batch,
                              max_seq=SHAPES[shape_name]["seq"])
        return fn, (params_sds, specs), (pspecs, in_specs)

    # decode
    serve = make_serve_step(cfg)
    cache_sds = specs["cache"]
    args = (params_sds, cache_sds, specs["tokens"], specs["positions"])
    shard = (pspecs, in_specs["cache"], in_specs["tokens"], in_specs["positions"])
    return serve, args, shard


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False, cost_mode: bool = False,
             baseline: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = ("__cost_base" if baseline else "__cost") if cost_mode else (
        "__base" if baseline else "")
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if baseline:
        # §Perf "before" configuration: grouped GQA layout, monolithic CE,
        # fp32 MoE combine, 2048-token dispatch groups
        kw = dict(gqa_grouped=True, loss_chunk=0, moe_combine_f32=True)
        if cfg.moe_num_experts:
            kw["moe_group_size"] = 2048
        cfg = cfg.replace(**kw)
    else:
        # production defaults: H-space GQA (config default) + chunked CE +
        # dots-saveable remat policy (§Perf iter 5).
        # (attn_probs_bf16 measured flat on the byte model — §Perf iter 4
        # refuted — so it stays opt-in.)
        cfg = cfg.replace(loss_chunk=512, remat="dots")
    if cost_mode:
        # unrolled layers + single-chunk attention: XLA costs every layer and
        # the full attention, instead of counting loop bodies once. Flop-
        # equivalent to the production scan program (chunking never changes
        # flops); used ONLY for cost/collective extraction, never for the
        # memory/compile proof.
        kw = dict(unroll_segments=True, blockwise_q=8192, blockwise_kv=8192)
        if cfg.ssm_state:
            # cap unrolled SSD chunk count at 8: intra-chunk flops grow with
            # the chunk (∝ Q), so this *overcounts* SSM compute slightly —
            # conservative for the roofline (noted in EXPERIMENTS.md).
            seq = SHAPES[shape_name]["seq"]
            kw["ssm_chunk"] = max(cfg.ssm_chunk, seq // 8)
        cfg = cfg.replace(**kw)
    skips = cfg.shape_skips()
    if shape_name in skips:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": skips[shape_name]}
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, shard = build_cell(cfg, shape_name, mesh)
    to_ns = lambda spec_tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
    in_shardings = to_ns(shard)

    import contextlib
    jitted = jax.jit(fn, in_shardings=in_shardings)
    # ambient mesh activates logical_constraint placements (disabled in the
    # §Perf baseline configuration, which predates them)
    ctx = contextlib.nullcontext() if baseline else jax.set_mesh(mesh)
    with ctx:
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0

        t1 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = collective_stats(hlo)

    n_dev = mesh.size
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        mem_rec[attr] = int(getattr(mem, attr, 0) or 0)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "cost_mode": cost_mode,
        "baseline": baseline,
        "devices": n_dev,
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": mem_rec,
        "collectives": colls,
        "collective_bytes_total": int(sum(c["bytes"] for c in colls.values())),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "lower_s": t_lower,
        "compile_s": t_compile,
        "hlo_ops": hlo.count("\n"),
    }
    out_path.write_text(json.dumps(rec, indent=2))
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
          f"flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
          f"coll={rec['collective_bytes_total']:.3e} "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    print(f"  memory_analysis: {mem_rec}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--cost-mode", action="store_true",
                    help="unrolled lowering for accurate cost analysis")
    ap.add_argument("--baseline", action="store_true",
                    help="pre-optimization configuration (§Perf 'before')")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, out_dir, force=args.force,
                             cost_mode=args.cost_mode, baseline=args.baseline)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
