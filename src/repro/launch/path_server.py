"""Multi-tenant sparse-SVM path server: continuous batching of screened paths.

The paper's pitch is throughput — screening makes many solves (a lambda
path per tenant, a hyperparameter sweep, one model per dataset) cost far
less than their naive FLOPs. This module is the serving front end for that
claim: a queue of :class:`PathJob` requests drains through a fixed number
of batch *slots*, every lambda step of every resident job executes inside
ONE jitted program (``core/path_scan._batched_path_step`` — the shared-cap
batched screen/solve/certify step), and a slot refills the moment its job's
grid is exhausted (continuous batching, the loop shape of
``launch/serve.py::BatchedServer``). Results stream back per lambda step;
a finished job's :class:`~repro.core.path.PathResult` is assembled from its
streamed steps, so no job waits on the batch.

Bucket / padding policy
-----------------------
Jobs are padded into power-of-two shape buckets (``core/path.py::_bucket``,
min 8): a job with true shape ``(m, n)`` occupies a ``(m_b, n_b)`` slot
with ``m_b = bucket(m)``, ``n_b = bucket(n)``. Padding is *safe by
construction*, not cosmetic:

* padded **feature rows** are zero, so their screen bound is 0 < tau and
  sequential screening certifiably drops them at every step — under
  ``reduce="compact"`` they cost nothing in the solve;
* padded **sample columns** carry a 0/1 ``sample_mask`` threaded through
  the solver, the certificate, and the hoisted screen reductions
  (``n_tot`` is the live count), so each slot solves its *true, unpadded*
  problem to solver resolution.

Slots in one batch share a bucket, so a serve group is keyed by
``(m_b, n_b, rule_stack, dynamic)`` — ``rule_stack`` the job's rule spec
resolved to a scan-lowerable program tuple (any single-anchor stack:
``feature_vi``, ``edpp``, ``auto``, lists; ``()`` = screening off; ``dvi``
is rejected because anchor *history* cannot ride a slot carry that jobs
splice in and out of); the queue drains group by group
(a job from a different bucket waits for the current group's slots to
empty rather than forcing a recompile mid-group).

Program-cache key anatomy
-------------------------
Compiled step programs live in an explicit warm cache keyed by::

    (m_bucket, n_bucket, cap_bucket, B, engine_config)

``m_bucket``/``n_bucket``  padded slot shape (above);
``cap_bucket``             the shared compact capacity for this step —
                           predicted per sub-batch from the jobs' observed
                           keep counts via ``compact_caps_batched`` (equal
                           to ``m_bucket`` for mask-mode steps, so mask and
                           compact steps are distinct programs);
``B``                      the slot count (batch width of the program);
``engine_config``          the hashable ``(name, value)`` static-option
                           tuple (max_iters, screening, dynamic, ...).

A cache hit dispatches with zero tracing; misses compile once per key
(a handful per bucket ladder); ``cache_stats()`` exposes hits / misses /
retraces (a retrace = jit holding more than one trace for a cached
program — a same-key same-shape dispatch that retraced is a regression).
Under-predicting the capacity never breaks correctness: the step program's
scalar overflow check demotes that step to its mask branch on device.

Fault tolerance
---------------
Each :class:`PathJob` carries an optional wall-clock ``deadline_s`` and a
``max_retries`` budget. After every batched step the server host-checks each
active slot's outputs for finiteness: a poisoned slot (NaN/inf objective or
weights) is rolled back to its pre-step carry — sanitized, so a poisoned
certificate re-enters as a *refusing* one (``delta = inf`` → the step
fail-safes to keep-all) — and retried with backoff; a slot that exhausts its
retries (or its deadline) is quarantined: masked out of the batch, evicted
with ``status="failed"``, its slot state zeroed, while the other tenants'
slots are untouched. ``serve(..., snapshot_dir=...)`` additionally
checkpoints the whole serve state (device slot buffers, per-job step
streams, queue order) every ``snapshot_every`` steps through
:class:`~repro.checkpoint.manager.CheckpointManager`; re-serving the same
job list with the same ``snapshot_dir`` after a crash resumes mid-path and
produces results equal to an uninterrupted run.

CPU smoke: PYTHONPATH=src python -m repro.launch.path_server --jobs 6
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass, field
from functools import partial
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.dual import bias_at_lambda_max, lambda_max, theta_at_lambda_max
from repro.core.path import PathResult, _bucket, _validate_grid, default_lambda_grid
from repro.core.path_scan import (
    ScanPathOutputs,
    _batched_path_step,
    _static_opts,
    _to_path_result,
    compact_caps_batched,
    engine_cache_info,
)
from repro.core.rules.programs import PROGRAMS, resolve_programs
from repro.core.screening import SAFE_TAU
from repro.core.solver import lipschitz_estimate
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.log import get_logger, setup as log_setup

_LOG = get_logger("launch.path_server")


@dataclass
class PathJob:
    """One tenant's path request: a dataset handle, a grid, and rules."""

    jid: int
    X: np.ndarray                       # (m, n) feature-major design
    y: np.ndarray                       # (n,) ±1 labels
    lambdas: Optional[np.ndarray] = None  # explicit decreasing grid, else:
    n_lambdas: int = 10
    lam_min_ratio: float = 0.1
    rules: str = "feature_vi"           # any single-anchor program stack
    dynamic: bool = False               # in-solver re-screen segments
    deadline_s: Optional[float] = None  # wall budget from first insert
    max_retries: int = 1                # poisoned-step retry budget

    # -- server-owned runtime state (streamed results) ---------------------
    t: int = field(default=0, repr=False)
    steps: list = field(default_factory=list, repr=False)
    result: Optional[PathResult] = field(default=None, repr=False)
    lam_max: float = field(default=0.0, repr=False)
    t_submit: float = field(default=0.0, repr=False)
    t_done: float = field(default=0.0, repr=False)
    t_start: float = field(default=0.0, repr=False)  # deadline epoch
    retries: int = field(default=0, repr=False)
    status: str = field(default="queued", repr=False)  # running/done/failed
    error: Optional[str] = field(default=None, repr=False)

    @property
    def rule_stack(self) -> tuple:
        """The job's rule spec resolved to a scan-lowerable program tuple.

        Raises for sample rules / verification-needing specs (the server
        runs the batched scan step — same lowerability contract as
        ``engine="scan"``) and for two-anchor programs like ``dvi``: the
        slot carry holds exactly one anchor, and jobs splice in and out of
        slots mid-path, so anchor *history* cannot ride the batch carry.
        """
        progs = resolve_programs(self.rules, screening=True)
        deep = [nm for nm in progs if PROGRAMS[nm].n_anchors > 1]
        if deep:
            raise ValueError(
                f"the path server's slot carry holds a single anchor; "
                f"rules needing anchor history {deep} are not servable — "
                f"run {self.rules!r} through engine='scan' or the host "
                f"driver instead"
            )
        return progs

    @property
    def screening(self) -> bool:
        return bool(self.rule_stack)

    def group_key(self) -> tuple:
        """Jobs sharing this key can occupy slots of the same batch."""
        m, n = self.X.shape
        return (_bucket(m), _bucket(n), self.rule_stack, bool(self.dynamic))


class PathServer:
    """Continuous-batching front end over the batched scan-engine step.

    ``slots`` is the batch width B of every compiled step program; see the
    module docstring for the bucket policy and cache-key anatomy.
    ``reduce="compact"`` (default) predicts a shared compact capacity per
    step from observed keep counts; ``reduce="mask"`` always solves
    full-bucket-width.
    """

    def __init__(self, slots: int = 4, *, reduce: str = "compact",
                 tau: float = SAFE_TAU, tol: float = 1e-9,
                 max_iters: int = 4000, screen_every: int = 50,
                 use_pallas: Optional[bool] = None,
                 cap_growth: float = 1.5, dtype=np.float32):
        if reduce not in ("mask", "compact"):
            raise ValueError(f"reduce must be 'mask' or 'compact', got {reduce!r}")
        self.slots = int(slots)
        self.reduce = reduce
        self.tau = float(tau)
        self.tol = float(tol)
        self.max_iters = int(max_iters)
        self.screen_every = int(screen_every)
        self.use_pallas = use_pallas
        self.cap_growth = float(cap_growth)
        self.dtype = np.dtype(dtype)

        self._programs: dict = {}
        self.stats = dict(hits=0, misses=0, steps=0, occupied_slots=0,
                          jobs_done=0, mask_fallback_steps=0,
                          retries=0, jobs_failed=0)
        self._group: Optional[tuple] = None
        self._act = np.zeros((self.slots,), bool)
        self._slot_jobs: list[Optional[PathJob]] = [None] * self.slots
        # testing seam: called as hook(step_count) after every serve-loop
        # step (post-snapshot); raising simulates a server crash mid-drain
        self._step_hook = None
        self._retry_backoff_s = 0.01
        # jobs already finished (done/failed) this serve — snapshots must
        # carry their streams too, or a resume would lose their results
        self._tracked_done: list[PathJob] = []

    def _bump(self, key: str, n: int = 1):
        """Increment a legacy ``stats`` counter and mirror it into the
        process-wide metrics registry under ``serve.<key>``."""
        self.stats[key] += n
        obs_metrics.counter("serve." + key).inc(n)

    # -- program cache -----------------------------------------------------

    def _program(self, m_b: int, n_b: int, cap_b: int, cfg: tuple):
        key = (m_b, n_b, cap_b, self.slots, cfg)
        fn = self._programs.get(key)
        if fn is not None:
            self._bump("hits")
            return fn
        self._bump("misses")
        caps = () if cap_b >= m_b else (cap_b,)
        fn = jax.jit(partial(_batched_path_step, caps=caps, shared_x=False,
                             **dict(cfg)))
        self._programs[key] = fn
        return fn

    def cache_stats(self) -> dict:
        """Warm-cache health: compiled programs, hits/misses, retraces."""
        retraces = 0
        for fn in self._programs.values():
            probe = getattr(fn, "_cache_size", None)
            if probe:
                retraces += max(0, int(probe()) - 1)
        return dict(programs=len(self._programs), hits=self.stats["hits"],
                    misses=self.stats["misses"], retraces=retraces)

    def metrics(self) -> dict:
        """Unified observability snapshot (the ISSUE's one-stop view):
        the process-wide :mod:`repro.obs.metrics` registry — which the
        server's counters mirror into live — with the step-program cache
        health (:meth:`cache_stats`) and the scan-engine warm-cache layers
        (``engine_cache_info``) absorbed as gauges."""
        obs_metrics.absorb("serve.cache", self.cache_stats())
        info = engine_cache_info()
        obs_metrics.gauge("engine.cache.programs").set(len(info))
        obs_metrics.gauge("engine.cache.retraces").set(
            sum(max(0, v - 1) for v in info.values() if v > 0))
        return obs_metrics.snapshot()

    # -- group (bucket) state ----------------------------------------------

    def _alloc_group(self, group: tuple):
        """(Re)allocate device slot state for a new bucket group."""
        m_b, n_b, rule_stack, dynamic = group
        B, dt = self.slots, self.dtype
        self._group = group
        # the resolved program tuple re-resolves identically (names are
        # canonical), so it feeds _static_opts as the rules spec directly
        self._cfg = _static_opts(self.max_iters, bool(rule_stack), dynamic,
                                 self.screen_every, self.use_pallas,
                                 False, self.reduce,
                                 list(rule_stack) if rule_stack else "none")
        # _batched_path_step takes the option subset without `reduce` —
        # the reduction is carried by the caps tuple in the program key
        self._step_cfg = tuple(kv for kv in self._cfg if kv[0] != "reduce")
        z = lambda *s: jnp.zeros(s, dt)
        self._X = z(B, m_b, n_b)
        self._y = z(B, n_b)
        self._sm = z(B, n_b)
        self._statics = (z(B, m_b), z(B, m_b), z(B, m_b), z(B), z(B))
        self._inv_L = jnp.ones((B,), dt)
        self._carry = (z(B, m_b), z(B), z(B, n_b), z(B),
                       jnp.ones((B,), dt), jnp.ones((B, m_b), dt))
        self._lam_host = np.ones((B,), np.float64)
        self._last_kept = np.zeros((B,), np.int64)
        self._act[:] = False
        self._slot_jobs = [None] * B

    def _insert(self, slot: int, job: PathJob):
        """Pad the job into its bucket and splice it into device state."""
        m_b, n_b, _, _ = self._group
        m, n = job.X.shape
        dt = self.dtype
        Xp = np.zeros((m_b, n_b), dt)
        Xp[:m, :n] = job.X
        yp = np.zeros((n_b,), dt)
        yp[:n] = job.y
        smp = np.zeros((n_b,), dt)
        smp[:n] = 1.0

        # anchors on the TRUE arrays with the repo's closed forms (eager,
        # device dtype — matching what the scan engines compute)
        Xj = jnp.asarray(job.X.astype(dt))
        yj = jnp.asarray(job.y.astype(dt))
        job.lam_max = float(lambda_max(Xj, yj))
        if job.lambdas is None:
            job.lambdas = default_lambda_grid(job.lam_max, job.n_lambdas,
                                              job.lam_min_ratio)
        job.lambdas = _validate_grid(job.lambdas)
        b0 = bias_at_lambda_max(yj)
        th0 = np.zeros((n_b,), dt)
        th0[:n] = np.asarray(
            theta_at_lambda_max(yj, jnp.asarray(job.lam_max, dt)))

        Xpj = jnp.asarray(Xp)
        ypj = jnp.asarray(yp)
        smj = jnp.asarray(smp)
        # padded rows/cols are zero, so sigma_max is the true problem's
        L = jnp.maximum(lipschitz_estimate(Xpj) * 1.01, 1e-12)
        # hoisted screen reductions (path_scan._batched_statics, per slot)
        d_one = Xpj @ ypj
        d_y = Xpj @ smj
        d_sq = (Xpj * Xpj) @ smj
        one_y = jnp.sum(ypj * smj)
        n_tot = jnp.sum(smj)

        at = lambda a, v: a.at[slot].set(v)
        self._X = at(self._X, Xpj)
        self._y = at(self._y, ypj)
        self._sm = at(self._sm, smj)
        s = self._statics
        self._statics = (at(s[0], d_one), at(s[1], d_y), at(s[2], d_sq),
                         at(s[3], one_y), at(s[4], n_tot))
        self._inv_L = at(self._inv_L, 1.0 / L)
        c = self._carry
        self._carry = (
            at(c[0], jnp.zeros((m_b,), dt)),
            at(c[1], jnp.asarray(b0, dt)),
            at(c[2], jnp.asarray(th0)),
            at(c[3], jnp.asarray(0.0, dt)),
            at(c[4], jnp.asarray(job.lam_max, dt)),
            at(c[5], jnp.ones((m_b,), dt)),
        )
        self._lam_host[slot] = job.lam_max
        self._last_kept[slot] = 0
        self._act[slot] = True
        self._slot_jobs[slot] = job
        job.status = "running"
        if job.t_start == 0.0:
            job.t_start = time.perf_counter()

    # -- one batched lambda step -------------------------------------------

    def _predict_cap(self, m_b: int) -> int:
        """Shared capacity for the next step from observed keep counts.

        Keep counts grow as lambda decreases, so the last observed count
        times ``cap_growth`` headroom feeds the shared-cap schedule. A
        fresh job (no observation yet) predicts the smallest bucket — its
        first step past lambda_max keeps almost nothing. Wrong predictions
        cost speed, never correctness (on-device overflow fallback).
        """
        if self.reduce != "compact":
            return m_b
        pred = [max(1, int(np.ceil(self._last_kept[s] * self.cap_growth)))
                for s in range(self.slots) if self._act[s]]
        return int(compact_caps_batched(m_b, pred or [1]))

    def step(self):
        m_b, n_b, _, _ = self._group
        now = time.perf_counter()
        for s in range(self.slots):
            job = self._slot_jobs[s]
            if not self._act[s]:
                continue
            if (job.deadline_s is not None
                    and now - job.t_start > job.deadline_s):
                self._evict_failed(
                    s, f"deadline {job.deadline_s}s exceeded at "
                       f"lambda index {job.t}")
                continue
            self._lam_host[s] = float(job.lambdas[job.t])
        if not self._act.any():
            return
        cap_b = self._predict_cap(m_b)
        fn = self._program(m_b, n_b, cap_b, self._step_cfg)
        lam = jnp.asarray(self._lam_host, self.dtype)
        act = jnp.asarray(self._act)
        tau = jnp.asarray(self.tau, self.dtype)
        carry_prev = self._carry  # functional updates: free pre-step copy
        self._carry, out = fn(self._X, self._y, self._sm, self._statics,
                              self._inv_L, tau, self.tol, carry_prev,
                              lam, act)
        host = {k: np.asarray(v) for k, v in out._asdict().items()}
        self._bump("steps")
        self._bump("occupied_slots", int(self._act.sum()))
        if self.reduce == "compact" and int(host["cap"][0]) >= m_b:
            self._bump("mask_fallback_steps")
        for s in range(self.slots):
            if not self._act[s]:
                continue
            job = self._slot_jobs[s]
            poisoned = not (np.isfinite(host["obj"][s])
                            and np.all(np.isfinite(host["w"][s])))
            if poisoned:
                # fault isolation: THIS slot rolls back to its pre-step
                # carry (sanitized — a poisoned certificate re-enters
                # refusing, delta = inf) and the step is not recorded; the
                # other tenants' outputs are committed normally
                if job.retries < job.max_retries:
                    job.retries += 1
                    self._bump("retries")
                    time.sleep(self._retry_backoff_s * (2 ** (job.retries - 1)))
                    self._carry = self._restore_slot_carry(carry_prev, s)
                    continue
                self._evict_failed(
                    s, f"non-finite step output at lambda index {job.t} "
                       f"after {job.retries} retries")
                continue
            job.steps.append({k: v[s] for k, v in host.items()})
            self._last_kept[s] = int(host["kept"][s])
            job.t += 1
            if job.t >= len(job.lambdas):
                self._finish(s)

    def _restore_slot_carry(self, carry_prev, s: int):
        """Splice slot ``s``'s pre-step carry back in, sanitized: non-finite
        weights/bias/theta become zeros (always feasible), a non-finite
        ``delta`` becomes ``+inf`` (a *refusing* certificate — the retried
        step screens keep-all instead of trusting poison), and a non-finite
        keep flag re-enters live."""
        w, b, th, dl, lp, km = self._carry
        pw, pb, pth, pdl, plp, pkm = carry_prev
        fin = lambda a: jnp.where(jnp.isfinite(a), a, jnp.zeros_like(a))
        return (
            w.at[s].set(fin(pw[s])),
            b.at[s].set(fin(pb[s])),
            th.at[s].set(fin(pth[s])),
            dl.at[s].set(jnp.where(jnp.isfinite(pdl[s]), pdl[s],
                                   jnp.asarray(jnp.inf, dl.dtype))),
            lp.at[s].set(jnp.where(jnp.isfinite(plp[s]), plp[s],
                                   jnp.asarray(self._lam_host[s], lp.dtype))),
            km.at[s].set(jnp.where(jnp.isfinite(pkm[s]), pkm[s],
                                   jnp.ones_like(pkm[s]))),
        )

    def _evict_failed(self, slot: int, msg: str):
        """Quarantine a poisoned/overdue job: mask its slot out of the
        batch, zero the slot state (no NaN residue for the next tenant),
        evict with ``status="failed"`` — results stay 1:1 with jobs (the
        failed job's ``result`` is None, its ``error`` says why)."""
        job = self._slot_jobs[slot]
        job.status = "failed"
        job.error = msg
        job.t_done = time.perf_counter()
        job.result = None
        self._bump("jobs_failed")
        obs_metrics.histogram("serve.latency_s").observe(
            float(job.t_done - job.t_submit))
        self._tracked_done.append(job)
        self._act[slot] = False
        self._slot_jobs[slot] = None
        self._carry = tuple(c.at[slot].set(jnp.zeros_like(c[slot]))
                            for c in self._carry)

    def _assemble(self, job: PathJob) -> PathResult:
        """Build the job's PathResult from its streamed per-step outputs
        (also the resume path's way to re-materialize finished jobs)."""
        m = job.X.shape[0]
        stacked = {k: np.stack([st[k] for st in job.steps])
                   for k in ScanPathOutputs._fields}
        stacked["w"] = stacked["w"][:, :m]
        stacked["fmask"] = stacked["fmask"][:, :m]
        # mask-fallback steps report the bucket width; clamp to the true m
        stacked["cap"] = np.minimum(stacked["cap"], m)
        outs = ScanPathOutputs(**stacked)
        latency = job.t_done - job.t_submit
        r = _to_path_result(job.lambdas, outs, job.lam_max,
                            latency, job.screening,
                            self._cfg, engine="serve")
        r.extras["engine"] = "serve"
        r.extras["jid"] = job.jid
        r.extras["latency_s"] = latency
        # the shared PathTrace latency field: the job's queue-to-done wall
        # lands in total_s, same slot the host driver's summed step walls
        # use — one bookkeeping scheme across engines
        pt = r.extras["path_trace"]
        pt.meta["jid"] = job.jid
        pt.meta["latency_s"] = float(latency)
        pt.emit_to_tracer()
        job.result = r
        return r

    def _finish(self, slot: int):
        job = self._slot_jobs[slot]
        job.t_done = time.perf_counter()
        self._assemble(job)
        job.status = "done"
        self._bump("jobs_done")
        obs_metrics.histogram("serve.latency_s").observe(
            float(job.t_done - job.t_submit))
        self._tracked_done.append(job)
        self._act[slot] = False
        self._slot_jobs[slot] = None

    # -- snapshot / resume -------------------------------------------------

    def _snapshot(self, mgr: CheckpointManager, pending: list):
        """Checkpoint the complete serve state at the current step count.

        Arrays (device slot buffers + each job's stacked step stream +
        grids) go in the npz; everything discrete (group key, slot->jid
        map, queue order, per-job progress/status) rides the JSON manifest.
        The write is atomic (tmp + rename), so a crash mid-snapshot leaves
        the previous one valid.
        """
        now = time.perf_counter()
        flat = {
            "X": self._X, "y": self._y, "sm": self._sm,
            "inv_L": self._inv_L, "lam_host": self._lam_host,
            "last_kept": self._last_kept, "act": self._act,
        }
        for i, a in enumerate(self._statics):
            flat[f"statics{i}"] = a
        for i, a in enumerate(self._carry):
            flat[f"carry{i}"] = a
        jobs_meta = {}
        tracked = [j for j in self._slot_jobs if j is not None]
        tracked += list(pending) + list(self._tracked_done)
        for job in tracked:
            jid = int(job.jid)
            jobs_meta[str(jid)] = {
                "t": int(job.t), "retries": int(job.retries),
                "status": job.status, "error": job.error,
                "lam_max": float(job.lam_max),
                "elapsed": float(now - job.t_submit),
                "started": float(now - job.t_start) if job.t_start else -1.0,
                "n_steps": len(job.steps),
            }
            if job.lambdas is not None:
                flat[f"job{jid}_lambdas"] = np.asarray(job.lambdas)
            for f in ScanPathOutputs._fields:
                if job.steps:
                    flat[f"job{jid}_{f}"] = np.stack(
                        [np.asarray(st[f]) for st in job.steps])
        m_b, n_b, rule_stack, dynamic = self._group
        extra = {
            "group": [int(m_b), int(n_b), list(rule_stack), bool(dynamic)],
            "slots": [int(j.jid) if j is not None else -1
                      for j in self._slot_jobs],
            "pending": [int(j.jid) for j in pending],
            "jobs": jobs_meta,
            "stats": {k: int(v) for k, v in self.stats.items()},
        }
        mgr.save(self.stats["steps"], flat, extra=extra)

    def _restore_serve(self, mgr: CheckpointManager,
                       jobs: list) -> Optional[list]:
        """Resume from the latest snapshot: rebuild device slot state,
        splice each job's recorded progress back (matched by ``jid``), and
        return the restored pending queue — or None when there is no valid
        snapshot (fresh serve)."""
        step = mgr.latest()
        if step is None:
            return None
        flat, manifest = mgr.restore_raw(step)
        ex = manifest["extra"]
        by_jid = {int(j.jid): j for j in jobs}
        g = ex["group"]
        self._alloc_group((int(g[0]), int(g[1]), tuple(g[2]), bool(g[3])))
        self._X = jnp.asarray(flat["X"])
        self._y = jnp.asarray(flat["y"])
        self._sm = jnp.asarray(flat["sm"])
        self._inv_L = jnp.asarray(flat["inv_L"])
        self._statics = tuple(jnp.asarray(flat[f"statics{i}"])
                              for i in range(5))
        self._carry = tuple(jnp.asarray(flat[f"carry{i}"])
                            for i in range(6))
        self._lam_host = np.asarray(flat["lam_host"], np.float64).copy()
        self._last_kept = np.asarray(flat["last_kept"], np.int64).copy()
        self._act = np.asarray(flat["act"], bool).copy()
        now = time.perf_counter()
        self._tracked_done = []
        for jid_s, jm in ex["jobs"].items():
            job = by_jid.get(int(jid_s))
            if job is None:
                raise ValueError(
                    f"snapshot references job {jid_s} missing from the "
                    f"resubmitted job list")
            job.t = int(jm["t"])
            job.retries = int(jm["retries"])
            job.status = jm["status"]
            job.error = jm["error"]
            job.lam_max = float(jm["lam_max"])
            job.t_submit = now - float(jm["elapsed"])
            job.t_start = (now - float(jm["started"])
                           if jm["started"] >= 0 else 0.0)
            key = f"job{int(jid_s)}_lambdas"
            if key in flat:
                job.lambdas = np.asarray(flat[key])
            n_steps = int(jm["n_steps"])
            if n_steps:
                stacks = {f: flat[f"job{int(jid_s)}_{f}"]
                          for f in ScanPathOutputs._fields}
                job.steps = [{f: stacks[f][k] for f in stacks}
                             for k in range(n_steps)]
            if job.status == "done":
                job.t_done = job.t_submit + float(jm["elapsed"])
                self._assemble(job)
                self._tracked_done.append(job)
                self._bump("jobs_done")
            elif job.status == "failed":
                job.t_done = job.t_submit + float(jm["elapsed"])
                self._tracked_done.append(job)
                self._bump("jobs_failed")
        self._slot_jobs = [by_jid[j] if j >= 0 else None
                           for j in ex["slots"]]
        # restore is an assignment in the legacy dict; mirror it into the
        # monotone registry counter as the delta so both stay equal
        restored = int(ex["stats"].get("steps", manifest["step"]))
        self._bump("steps", restored - self.stats["steps"])
        return [by_jid[j] for j in ex["pending"]]

    # -- the serve loop ----------------------------------------------------

    def serve(self, jobs: list[PathJob], log=None,
              snapshot_dir=None, snapshot_every: int = 0,
              ) -> list[Optional[PathResult]]:
        """Drain a job queue; returns results in submission order (a failed
        job's entry is None — see its ``.error``).

        Continuous batching: empty slots refill from the queue (same bucket
        group) before every step, so ragged grid lengths keep the device
        program saturated instead of waiting on the longest path.

        ``snapshot_dir`` enables crash recovery: serve state (device slot
        buffers, per-job step streams, queue order, progress) is
        checkpointed there every ``snapshot_every`` steps (atomically, via
        :class:`CheckpointManager`). Calling ``serve`` again with the same
        ``jobs`` list (matched by ``jid``) and the same ``snapshot_dir``
        resumes from the latest snapshot instead of starting over, and the
        resumed run's results equal an uninterrupted run's.
        """
        if log is None:
            log = _LOG.info
        pending = list(jobs)
        t0 = time.perf_counter()
        for j in pending:
            j.t_submit = t0
        mgr = (CheckpointManager(snapshot_dir, keep=2)
               if snapshot_dir is not None else None)
        resumed = self._restore_serve(mgr, jobs) if mgr is not None else None
        if resumed is not None:
            pending = resumed
        else:
            self._tracked_done = []
        while pending or self._act.any():
            if not self._act.any():
                nxt_group = pending[0].group_key()
                if self._group != nxt_group:
                    self._alloc_group(nxt_group)
            with obs_trace.span("serve.refill", pending=len(pending)):
                for s in range(self.slots):
                    if not self._act[s]:
                        nxt = next((j for j in pending
                                    if j.group_key() == self._group), None)
                        if nxt is None:
                            break
                        pending.remove(nxt)
                        self._insert(s, nxt)
            with obs_trace.span("serve.step", step=self.stats["steps"],
                                occupied=int(self._act.sum())):
                self.step()
            if (mgr is not None and snapshot_every
                    and self.stats["steps"] % int(snapshot_every) == 0):
                with obs_trace.span("serve.checkpoint",
                                    step=self.stats["steps"]):
                    self._snapshot(mgr, pending)
            if self._step_hook is not None:
                self._step_hook(self.stats["steps"])
        wall = time.perf_counter() - t0
        lat = np.array([j.t_done - j.t_submit for j in jobs])
        occ = (self.stats["occupied_slots"]
               / max(1, self.stats["steps"] * self.slots))
        self.last_serve = dict(
            jobs=len(jobs), wall_s=float(wall),
            jobs_per_s=len(jobs) / wall, steps=self.stats["steps"],
            slot_occupancy=float(occ),
            latency_p50_s=float(np.percentile(lat, 50)),
            latency_p95_s=float(np.percentile(lat, 95)),
            **self.cache_stats(),
        )
        obs_metrics.gauge("serve.slot_occupancy").set(float(occ))
        log(f"[serve] {len(jobs)} jobs in {wall:.2f}s "
            f"({self.last_serve['jobs_per_s']:.2f} jobs/s), "
            f"occupancy={occ:.2f}, cache={self.cache_stats()}")
        return [j.result for j in jobs]


def demo_jobs(n_jobs: int = 8, m: int = 300, n: int = 120,
              seed: int = 0, ragged: bool = True) -> list[PathJob]:
    """A mixed-grid job workload over independent synthetic problems."""
    from repro.data import make_sparse_classification

    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(n_jobs):
        ds = make_sparse_classification(m=m, n=n, k_active=10, seed=seed + i)
        T = int(rng.integers(4, 10)) if ragged else 8
        jobs.append(PathJob(jid=i, X=np.asarray(ds.X), y=np.asarray(ds.y),
                            n_lambdas=T,
                            lam_min_ratio=float(rng.uniform(0.1, 0.3))))
    return jobs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--m", type=int, default=300)
    ap.add_argument("--n", type=int, default=120)
    ap.add_argument("--reduce", choices=("mask", "compact"),
                    default="compact")
    ap.add_argument("--tol", type=float, default=1e-9)
    args = ap.parse_args()

    log_setup()
    server = PathServer(slots=args.slots, reduce=args.reduce, tol=args.tol)
    jobs = demo_jobs(args.jobs, m=args.m, n=args.n)
    results = server.serve(jobs)
    for r in results:
        _LOG.info(
            "job %d: T=%d final nnz=%d obj=%.5f latency=%.2fs",
            r.extras["jid"], len(r.lambdas), int(r.active[-1]),
            float(r.objectives[-1]), r.extras["latency_s"])
    Path("artifacts").mkdir(exist_ok=True)
    Path("artifacts/svm_serve.json").write_text(
        json.dumps(server.last_serve, indent=2))
    Path("artifacts/svm_serve_metrics.json").write_text(
        json.dumps(server.metrics(), indent=2, default=str))


if __name__ == "__main__":
    main()
