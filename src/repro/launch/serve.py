"""Batched serving loop: continuous-batching decode driver.

A minimal production-shaped server: a request queue feeds fixed slots of a
decode batch; finished/empty slots are refilled between steps (continuous
batching), each step is one jitted ``decode_step`` over the whole batch.
Prefill for an incoming request runs at batch 1 and its cache rows are
spliced into the live batch cache (slot insertion).

CPU smoke: PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config, get_config
from repro.launch.steps import make_serve_step
from repro.models import transformer as tr
from repro.models.cache import init_cache


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class BatchedServer:
    def __init__(self, cfg, params, batch_slots: int = 4, max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.cache = init_cache(cfg, batch=batch_slots, max_seq=max_seq)
        self.positions = np.zeros((batch_slots,), np.int32)
        self.last_tok = np.zeros((batch_slots,), np.int32)
        self.active: list[Optional[Request]] = [None] * batch_slots
        self.step_fn = jax.jit(make_serve_step(cfg))
        self.prefill_fn = jax.jit(
            lambda p, b: tr.prefill(p, cfg, b, max_seq=max_seq))

    def _insert(self, slot: int, req: Request):
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        logits, cache1 = self.prefill_fn(self.params, batch)
        # splice the single-row cache into slot `slot`
        self.cache = jax.tree_util.tree_map(
            lambda full, one: full.at[:, slot:slot + 1].set(one.astype(full.dtype))
            if full.ndim >= 2 else full,
            self.cache, cache1)
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self.active[slot] = req
        self.positions[slot] = len(req.prompt)
        self.last_tok[slot] = tok

    def step(self):
        toks = jnp.asarray(self.last_tok[:, None], jnp.int32)
        pos = jnp.asarray(self.positions, jnp.int32)
        logits, self.cache = self.step_fn(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for s, req in enumerate(self.active):
            if req is None or req.done:
                continue
            req.out.append(int(nxt[s]))
            self.positions[s] += 1
            self.last_tok[s] = nxt[s]
            if len(req.out) >= req.max_new or self.positions[s] >= self.max_seq - 1:
                req.done = True
                self.active[s] = None

    def serve(self, requests: list[Request], log=print):
        queue = list(requests)
        t0 = time.perf_counter()
        n_steps = 0
        while queue or any(r is not None for r in self.active):
            for s in range(self.slots):
                if self.active[s] is None and queue:
                    self._insert(s, queue.pop(0))
            self.step()
            n_steps += 1
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in requests)
        log(f"[serve] {len(requests)} requests, {toks} tokens, "
            f"{n_steps} steps, {toks / dt:.1f} tok/s")
        return requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    server = BatchedServer(cfg, params, batch_slots=args.slots, max_seq=128)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 24)).astype(np.int32),
                    max_new=8)
            for i in range(args.requests)]
    server.serve(reqs)
    for r in reqs:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
