"""Low-overhead span recorder exporting Chrome trace-event JSON.

One process-wide :class:`Tracer` (disabled by default) records *spans* —
named, attributed intervals — and *instant events*. The recorder is built
for the repo's host loops (``PathDriver.run``, the streamed solver,
``PathServer``'s drain loop): when disabled, :func:`span` returns a shared
no-op singleton and records nothing (no event allocation, no lock, no
clock read beyond the enabled check), so instrumentation can stay in the
hot path permanently. When enabled, every span costs two
``perf_counter`` reads and one locked list append — thread-safe, so the
server drain loop and any worker threads interleave correctly (events
carry the recording thread's id).

Export is the Chrome trace-event format (``{"traceEvents": [...]}``),
loadable in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``:
spans become complete events (``ph="X"``, microsecond ``ts``/``dur``),
instants become ``ph="i"``, and span attributes ride ``args``.

Enable programmatically (:func:`enable`) or via ``REPRO_TRACE=1`` in the
environment; ``train_svm --trace out.json`` wires both ends together.

Single-dispatch engines (scan/batched/sharded/serve) cannot record live
per-step spans — their steps run inside one jitted program. They
synthesize spans post-hoc from device telemetry instead: see
``repro.obs.path_trace.PathTrace.emit_to_tracer``.
"""

from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path

__all__ = [
    "Tracer",
    "span",
    "instant",
    "complete",
    "enable",
    "disable",
    "enabled",
    "get_tracer",
    "export_chrome",
]


class _NoopSpan:
    """Shared do-nothing span: the disabled-mode fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NOOP_SPAN = _NoopSpan()


class _Span:
    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs):
        """Attach/overwrite attributes mid-span (e.g. iteration counts
        known only at the end of the timed region)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tracer._record(self.name, self._t0, t1 - self._t0, self.attrs)
        return False


class Tracer:
    """Thread-safe span/event recorder with Chrome trace-event export.

    All timestamps are relative to the tracer's epoch (construction or the
    most recent :meth:`clear`), in seconds; export converts to the
    microseconds the trace-event format wants.
    """

    def __init__(self, enabled: bool = False, process_name: str = "repro"):
        self._enabled = bool(enabled)
        self.process_name = process_name
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._epoch = time.perf_counter()

    # -- state -------------------------------------------------------------

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self):
        self._enabled = True

    def disable(self):
        self._enabled = False

    def clear(self):
        with self._lock:
            self._events = []
            self._epoch = time.perf_counter()

    def now(self) -> float:
        """Seconds since the tracer epoch."""
        return time.perf_counter() - self._epoch

    @property
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    # -- recording ---------------------------------------------------------

    def span(self, name: str, **attrs):
        """Context manager timing a named interval; no-op when disabled."""
        if not self._enabled:
            return NOOP_SPAN
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs):
        """Record a zero-duration marker event; no-op when disabled."""
        if not self._enabled:
            return
        self._append({
            "name": name, "ph": "i", "s": "t",
            "ts": self.now() * 1e6,
            "tid": threading.get_ident(),
            "args": attrs,
        })

    def _record(self, name, t0, dur_s, attrs):
        self._append({
            "name": name, "ph": "X",
            "ts": (t0 - self._epoch) * 1e6,
            "dur": dur_s * 1e6,
            "tid": threading.get_ident(),
            "args": attrs,
        })

    def add_complete_event(self, name: str, start_s: float, dur_s: float,
                           tid: int = 0, **attrs):
        """Append a complete ('X') event with explicit relative timing —
        the post-hoc synthesis path for single-dispatch engines (timestamps
        in seconds since the tracer epoch)."""
        if not self._enabled:
            return
        self._append({
            "name": name, "ph": "X",
            "ts": start_s * 1e6, "dur": dur_s * 1e6,
            "tid": tid, "args": attrs,
        })

    def _append(self, ev: dict):
        with self._lock:
            self._events.append(ev)

    # -- export ------------------------------------------------------------

    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON object (Perfetto-loadable)."""
        pid = os.getpid()
        with self._lock:
            events = [dict(ev, pid=pid) for ev in self._events]
        meta = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": self.process_name},
        }]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export_chrome(self, path) -> str:
        """Write the Chrome trace-event JSON to ``path``; returns the path."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome()))
        return str(path)


# -- process-wide tracer ---------------------------------------------------

_TRACER = Tracer(enabled=os.environ.get("REPRO_TRACE", "0") not in
                 ("", "0", "false", "False"))


def get_tracer() -> Tracer:
    return _TRACER


def enabled() -> bool:
    return _TRACER._enabled


def enable():
    _TRACER.enable()


def disable():
    _TRACER.disable()


def span(name: str, **attrs):
    """Module-level ``with span("solve", step=k): ...`` on the process
    tracer — the form the engines thread through their hot loops."""
    if not _TRACER._enabled:
        return NOOP_SPAN
    return _Span(_TRACER, name, attrs)


def instant(name: str, **attrs):
    _TRACER.instant(name, **attrs)


def complete(name: str, t0: float, t1: float, **attrs):
    """Record a complete span from absolute ``perf_counter`` stamps the
    caller already took for its own bookkeeping (the host path loops stamp
    screen/solve/certify walls regardless of tracing) — zero extra clock
    reads, no-op when disabled."""
    if not _TRACER._enabled:
        return
    _TRACER._append({
        "name": name, "ph": "X",
        "ts": (t0 - _TRACER._epoch) * 1e6,
        "dur": (t1 - t0) * 1e6,
        "tid": threading.get_ident(),
        "args": attrs,
    })


def export_chrome(path) -> str:
    return _TRACER.export_chrome(path)
