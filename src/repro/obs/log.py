"""Structured logging setup for the repro package.

Module-level loggers everywhere (``log = get_logger(__name__)``), one
idempotent handler configured on the ``repro`` root by :func:`setup` —
called by the launchers' ``main()``, never at import time, so library
users keep full control of logging config. The level is env-tunable via
``REPRO_LOG_LEVEL`` (default ``INFO``), matching the repo's other env
toggles (``REPRO_SOLVER_GUARDS``, ``REPRO_TRACE``, ...).

Launch-loop call sites keep their ``log=`` parameter for injection
(benchmarks pass ``print``; tests capture); the default is now the
module logger's ``info`` instead of a bare ``print``.
"""

from __future__ import annotations

import logging
import os
import sys

__all__ = ["get_logger", "setup"]

_CONFIGURED = False


def get_logger(name: str = "") -> logging.Logger:
    """A logger under the ``repro`` hierarchy (dotted names pass through)."""
    if not name:
        return logging.getLogger("repro")
    if name == "repro" or name.startswith("repro."):
        return logging.getLogger(name)
    return logging.getLogger(f"repro.{name}")


def setup(level=None, stream=None, force: bool = False) -> logging.Logger:
    """Attach one stream handler to the ``repro`` root logger.

    ``level``: explicit level (name or number); defaults to the
    ``REPRO_LOG_LEVEL`` environment variable, then ``INFO``. Idempotent —
    repeated calls only adjust the level unless ``force=True`` replaces
    the handler (tests redirecting ``stream``).
    """
    global _CONFIGURED
    root = logging.getLogger("repro")
    if level is None:
        level = os.environ.get("REPRO_LOG_LEVEL", "INFO")
    if isinstance(level, str):
        level = level.upper()
    root.setLevel(level)
    if _CONFIGURED and not force:
        return root
    if force:
        for h in list(root.handlers):
            root.removeHandler(h)
    handler = logging.StreamHandler(stream if stream is not None else sys.stderr)
    handler.setFormatter(logging.Formatter(
        "%(asctime)s %(levelname)s %(name)s: %(message)s", "%H:%M:%S"))
    root.addHandler(handler)
    root.propagate = False
    _CONFIGURED = True
    return root
