"""Unified path observability: trace spans, metrics, logging, PathTrace.

The repo runs the screened SVM path through six engines (host, scan,
batched, sharded, server, chunked/mmap); this package is the one
instrumentation layer they all report through:

- :mod:`repro.obs.trace` — a low-overhead span recorder
  (``span("solve", step=k)`` context manager + instant events, no-op when
  disabled, thread-safe for the server drain loop) exporting Chrome
  trace-event JSON loadable in Perfetto. Threaded through
  ``PathDriver.run``, the streamed solver's host loop,
  ``screen_step_stream``, and the ``PathServer`` drain/refill/checkpoint
  cycle. Enable with ``REPRO_TRACE=1`` or ``train_svm --trace out.json``.
- :mod:`repro.obs.metrics` — a process-wide registry of counters /
  gauges / histograms absorbing the previously scattered telemetry
  (engine-cache hit/miss/retrace, ``chunks_streamed`` /
  ``chunks_skipped`` / ``bytes_put``, guard trips, kept-per-step, job
  latency) with JSON and Prometheus-text dumps; ``PathServer.metrics()``
  returns its snapshot.
- :mod:`repro.obs.log` — structured-logging setup (module-level loggers,
  one handler on the ``repro`` root, ``REPRO_LOG_LEVEL`` env-tunable).
- :mod:`repro.obs.path_trace` — the uniform ``PathTrace`` artifact every
  engine attaches at ``PathResult.extras["path_trace"]``.

PathTrace field reference (per step; ``nan`` where an engine cannot
observe the quantity):

====================  ====================================================
field                 meaning
====================  ====================================================
``step``              lambda-grid index ``k``
``lam``               regularization value solved at this step
``kept``              features fed to the solver after screening
``kept_samples``      samples fed to the solver (0 = axis unused)
``active``            nnz(w) at the accepted solution
``iters``             FISTA iterations spent
``gap``               duality gap certified at the accepted point
``delta``             certified theta-radius anchoring the next screen
``health``            guard word (``HEALTH_SCREEN_REFUSED`` = keep-all)
``wall_s``            step wall seconds (measured, or uniform share of a
                      single-dispatch total — ``walls_observed`` says
                      which)
``screen_s``          host-measured screening wall (host engines)
``solve_s``           host-measured solve wall (host engines)
``certify_s``         host-measured certification wall (host engines)
====================  ====================================================

Run-level: ``engine`` (host / scan / batched / scan_sharded / serve /
chunked), ``total_s`` (the shared latency field — the server's per-job
``latency_s`` and the host driver's summed step walls land here),
``walls_observed``, and free-form ``meta`` (jid, stream stats, ...).
"""

from .log import get_logger, setup
from .metrics import (
    REGISTRY,
    MetricsRegistry,
    absorb,
    counter,
    gauge,
    histogram,
    snapshot,
    to_json,
    to_prometheus,
)
from .path_trace import PathStep, PathTrace, build_path_trace
from .trace import (
    Tracer,
    complete,
    enable,
    enabled,
    disable,
    export_chrome,
    get_tracer,
    instant,
    span,
)

__all__ = [
    "get_logger",
    "setup",
    "REGISTRY",
    "MetricsRegistry",
    "absorb",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    "to_json",
    "to_prometheus",
    "PathStep",
    "PathTrace",
    "build_path_trace",
    "Tracer",
    "complete",
    "enable",
    "enabled",
    "disable",
    "export_chrome",
    "get_tracer",
    "instant",
    "span",
]
