"""``PathTrace`` — the uniform per-step observability artifact.

Every path engine (host / scan / batched / sharded / serve / chunked)
attaches one ``PathTrace`` to its result (``PathResult.extras
["path_trace"]``): the same schema of per-step records regardless of how
the engine executes, so bench comparisons, the trace exporter, and the
profiler lane read ONE shape instead of five engine-specific dicts.

Host-orchestrated engines fill the records live (each step's walls are
measured on the host); single-dispatch engines (scan/batched/sharded and
the server's batched step) synthesize them post-hoc from the device
telemetry their scan carry already streams out (``ScanPathOutputs``:
kept, n_iters, gap, delta, health per step) — per-step *walls* are not
observable there, so they carry the uniform share of the blocked total
and ``walls_observed`` is False.

See :class:`PathStep` for the field reference (also reproduced in the
``repro.obs`` package docstring).
"""

from __future__ import annotations

import math
from dataclasses import asdict, dataclass, field

import numpy as np

from . import trace as _trace

__all__ = ["PathStep", "PathTrace", "build_path_trace"]

NAN = float("nan")


@dataclass
class PathStep:
    """One lambda step of a screened path, engine-agnostic.

    Fields (``nan``/0 where an engine cannot observe the quantity):

    - ``step``: lambda-grid index ``k``.
    - ``lam``: the regularization value solved at this step.
    - ``kept``: feature count fed to the solver after screening.
    - ``kept_samples``: sample count fed to the solver (0 = axis unused).
    - ``active``: nnz(w) at the accepted solution.
    - ``iters``: FISTA iterations spent.
    - ``gap``: duality gap certified at the accepted point (``nan`` on the
      host engine, which certifies via the theta-radius only).
    - ``delta``: certified ``||theta1 - theta*||`` radius anchoring the
      next step's screen (``nan`` where not carried).
    - ``health``: guard-telemetry word (``HEALTH_SCREEN_REFUSED`` flags a
      fail-safe keep-all step; low bits count solver rollbacks).
    - ``wall_s``: total step wall seconds (host-measured, or the uniform
      share of a single-dispatch total — see ``PathTrace.walls_observed``).
    - ``screen_s`` / ``solve_s`` / ``certify_s``: the step's phase walls
      (host engines only; ``nan`` when unobservable).
    """

    step: int
    lam: float
    kept: int
    kept_samples: int
    active: int
    iters: int
    gap: float
    delta: float
    health: int
    wall_s: float
    screen_s: float = NAN
    solve_s: float = NAN
    certify_s: float = NAN


@dataclass
class PathTrace:
    """Per-run schema: engine tag, per-step records, and run totals.

    ``total_s`` is the one latency field every engine populates — the
    host driver sums its measured step walls, the server stamps the job's
    submit-to-done latency (previously only ``extras["latency_s"]``), and
    the single-dispatch engines use the blocked dispatch wall — so
    cross-engine latency comparisons read one field.
    """

    engine: str
    steps: list
    total_s: float
    walls_observed: bool
    meta: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "engine": self.engine,
            "total_s": self.total_s,
            "walls_observed": self.walls_observed,
            "meta": dict(self.meta),
            "steps": [asdict(s) for s in self.steps],
        }

    # -- trace synthesis ---------------------------------------------------

    def to_chrome_events(self, end_s: float, tid: int = 0) -> list:
        """Complete ('X') trace events laying the steps out on a timeline
        ending at ``end_s`` (seconds relative to the consumer's epoch) —
        the post-hoc span synthesis for engines with no live host loop.
        Phase walls, when observed, become child events nested inside each
        step's interval."""
        walls = [s.wall_s for s in self.steps]
        start = end_s - sum(walls)
        events = []
        t = start
        for s in self.steps:
            args = {"lam": s.lam, "kept": s.kept, "active": s.active,
                    "iters": s.iters, "health": s.health}
            if not math.isnan(s.gap):
                args["gap"] = s.gap
            events.append({
                "name": f"{self.engine}.step", "ph": "X",
                "ts": t * 1e6, "dur": s.wall_s * 1e6,
                "tid": tid, "args": args,
            })
            tp = t
            for phase in ("screen", "solve", "certify"):
                dur = getattr(s, f"{phase}_s")
                if not math.isnan(dur):
                    events.append({
                        "name": f"{self.engine}.{phase}", "ph": "X",
                        "ts": tp * 1e6, "dur": dur * 1e6,
                        "tid": tid, "args": {"step": s.step},
                    })
                    tp += dur
            t += s.wall_s
        return events

    def emit_to_tracer(self, tracer=None):
        """Append this trace's synthesized spans to the (enabled) process
        tracer so ``--trace out.json`` exports contain per-step spans from
        every engine, live-recorded or not."""
        tracer = tracer or _trace.get_tracer()
        if not tracer.enabled:
            return
        for ev in self.to_chrome_events(end_s=tracer.now()):
            tracer._append(ev)


def _col(x, k, default=NAN):
    if x is None:
        return default
    v = x[k]
    return float(v) if isinstance(default, float) else int(v)


def build_path_trace(
    engine: str,
    lambdas,
    kept,
    kept_samples,
    active,
    iters,
    wall,
    *,
    gaps=None,
    deltas=None,
    health=None,
    screen_s=None,
    solve_s=None,
    certify_s=None,
    total_s=None,
    walls_observed: bool = True,
    meta: dict | None = None,
) -> PathTrace:
    """Assemble a :class:`PathTrace` from per-step arrays (host-measured
    or device-streamed — the one constructor all engines share)."""
    lambdas = np.asarray(lambdas)
    T = len(lambdas)
    steps = [
        PathStep(
            step=k,
            lam=float(lambdas[k]),
            kept=_col(kept, k, 0),
            kept_samples=_col(kept_samples, k, 0),
            active=_col(active, k, 0),
            iters=_col(iters, k, 0),
            gap=_col(gaps, k),
            delta=_col(deltas, k),
            health=_col(health, k, 0),
            wall_s=_col(wall, k),
            screen_s=_col(screen_s, k),
            solve_s=_col(solve_s, k),
            certify_s=_col(certify_s, k),
        )
        for k in range(T)
    ]
    if total_s is None:
        total_s = float(np.sum(np.asarray(wall, np.float64)))
    return PathTrace(engine=engine, steps=steps, total_s=float(total_s),
                     walls_observed=bool(walls_observed),
                     meta=dict(meta or {}))
