"""Process-wide metrics registry: counters, gauges, histograms.

Absorbs the repo's previously scattered telemetry counters — the path
server's ``stats`` dict (cache hits/misses, steps, retries), the chunk
store's ``FeatureChunked.stats`` (``chunks_streamed`` / ``chunks_skipped``
/ ``bytes_put``), engine-cache retrace probes, guard trips, kept-per-step,
job latency — behind one API. The legacy dicts keep working (call sites
mirror their increments here), so existing tests and bench consumers are
untouched; the registry adds the unified view: ``snapshot()`` for
structured readers, :func:`to_json` and :func:`to_prometheus` (text
exposition format) for dumps, ``PathServer.metrics()`` for the serving
front end.

Conventions: dotted lowercase names namespaced by subsystem —
``serve.hits``, ``stream.chunks_skipped``, ``path.guard_trips``,
``engine.cache.retraces`` — with counters for monotonic totals, gauges for
last-observed values, histograms for per-event distributions
(``serve.latency_s``, ``path.kept``). Prometheus output maps dots to
underscores (``repro_serve_hits_total``).

Thread-safe: metric creation and increments take the registry/metric lock
(the server drain loop may be concurrent with worker threads); reads are
snapshots, not live views.
"""

from __future__ import annotations

import json
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "absorb",
    "snapshot",
    "reset",
    "to_json",
    "to_prometheus",
]


class Counter:
    """Monotonically increasing integer/float total."""

    kind = "counter"
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n=1):
        with self._lock:
            self.value += n

    def get(self):
        return self.value

    def reset(self):
        with self._lock:
            self.value = 0


class Gauge:
    """Last-observed value (e.g. occupancy, cache size, a dict snapshot)."""

    kind = "gauge"
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self._lock = threading.Lock()

    def set(self, v):
        with self._lock:
            self.value = v

    def set_max(self, v):
        """Keep the running maximum (mirrors ``stats["max_put_rows"]``)."""
        with self._lock:
            if v > self.value:
                self.value = v

    def get(self):
        return self.value

    def reset(self):
        with self._lock:
            self.value = 0


class Histogram:
    """Streaming distribution summary: count / sum / min / max.

    Deliberately bucket-free — the consumers here (bench deltas, serve
    latency percentiles over small job counts) keep the raw observations
    when they need quantiles; the registry's job is the cheap always-on
    aggregate.
    """

    kind = "histogram"
    __slots__ = ("name", "count", "total", "min", "max", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v):
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def get(self) -> dict:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "min": None, "max": None,
                        "mean": None}
            return {"count": self.count, "sum": self.total,
                    "min": self.min, "max": self.max,
                    "mean": self.total / self.count}

    def reset(self):
        with self._lock:
            self.count = 0
            self.total = 0.0
            self.min = float("inf")
            self.max = float("-inf")


class MetricsRegistry:
    """Name -> metric map with typed get-or-create accessors."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def absorb(self, prefix: str, mapping: dict):
        """Set one gauge per key of a legacy stats dict (``prefix.key``) —
        the adapter for dict-shaped telemetry produced elsewhere
        (``engine_cache_info()``, ``PathServer.cache_stats()``)."""
        for k, v in mapping.items():
            self.gauge(f"{prefix}.{k}").set(v)

    def snapshot(self) -> dict:
        """``{name: value}`` for every registered metric (histograms give
        their summary dicts). A plain-data copy — safe to json-dump."""
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.get() for name, m in sorted(items)}

    def reset(self):
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            m.reset()

    # -- dumps -------------------------------------------------------------

    def to_json(self, indent=None) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one family per metric)."""
        lines = []
        with self._lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            base = "repro_" + name.replace(".", "_").replace("-", "_")
            if m.kind == "counter":
                lines.append(f"# TYPE {base}_total counter")
                lines.append(f"{base}_total {m.get()}")
            elif m.kind == "gauge":
                v = m.get()
                if isinstance(v, (int, float)):
                    lines.append(f"# TYPE {base} gauge")
                    lines.append(f"{base} {v}")
            else:  # histogram summary
                s = m.get()
                lines.append(f"# TYPE {base} summary")
                lines.append(f"{base}_count {s['count']}")
                lines.append(f"{base}_sum {s['sum']}")
        return "\n".join(lines) + "\n"


# -- process-wide registry -------------------------------------------------

REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def absorb(prefix: str, mapping: dict):
    REGISTRY.absorb(prefix, mapping)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset():
    REGISTRY.reset()


def to_json(indent=None) -> str:
    return REGISTRY.to_json(indent=indent)


def to_prometheus() -> str:
    return REGISTRY.to_prometheus()
