"""Out-of-core feature-chunked storage for the ``(m, n)`` design matrix.

The paper's headline regime — high-dimensional text-like data with
``m >> n`` and mostly-zero ``X`` — is exactly where the design matrix stops
fitting on one device while every *working set* (a feature chunk, the
screened active set, every ``(n,)``/``(m,)`` vector) still does. This module
provides the storage container the rest of the pipeline streams over:

* :class:`FeatureChunked` holds ``X`` as a sequence of fixed-size
  **feature-block chunks** (row blocks in the paper's features-major
  layout). Each chunk lives on the *host*, either dense (``np.ndarray``) or
  CSR (:class:`CsrChunk` — indptr/indices/data over the chunk's rows), and
  is shipped to the device only while it is being swept.
* :meth:`FeatureChunked.stream` is the single device-transfer point:
  it double-buffers ``jax.device_put`` (chunk ``i+1`` is dispatched while
  chunk ``i`` computes — transfers are async, so host→device copy overlaps
  device compute), and converts low-density CSR chunks to
  ``jax.experimental.sparse.BCOO`` so the hot sweeps cost FLOPs
  proportional to ``nnz`` rather than ``chunk_m * n``.
* :meth:`matvec` / :meth:`rmatvec` are the chunk-accumulated GEMV pair the
  streamed FISTA solver is built on (``grad = X r`` concatenates per-chunk
  rows; ``u = X^T w`` accumulates per-chunk partials), and
  :meth:`gather_rows` is the host-side gather the chunked
  :class:`~repro.core.path.PathDriver` uses to materialize only the rows
  that *survive screening* — peak device memory is ``O(chunk + kept)``,
  never ``O(m * n)``.

Chunk skipping (the chunk-level screening data plane): every streaming
entry point takes ``live_chunks=`` — a boolean mask (or index list) over
chunks — and chunks marked dead are never ``device_put`` at all.
:meth:`matvec` fills their output rows with zeros (their weights are
certified zero) and :meth:`rmatvec` simply omits their partials, so solver
sweeps cost transfers proportional to the *live* data. The safe-bound
machinery that certifies chunks dead lives in ``screen_stream.py``
(:class:`~repro.sparse.screen_stream.ChunkScreenCache`).

Disk residency: :meth:`save_store` / :meth:`from_store` round-trip the
container through an ``np.memmap``-backed directory (one flat binary per
array; chunks are memmap *views*, so host RSS stays O(touched pages), and
the OS page cache is the disk→host stage of the double buffer), and
:meth:`from_libsvm_cached` builds that store once from libsvm text in two
streaming passes — the full ``(m, n)`` matrix is never host-RAM-resident.

Device-memory contract: no method of this class ever places more than one
chunk (plus ``O(m + n)`` vectors) on the device at a time; the property test
in ``tests/test_sparse_stream.py`` walks the jaxprs of every per-chunk
kernel and asserts no ``(m, n)``-sized intermediate exists. ``as_dense()``
is the explicit escape hatch for in-core use and small tests.

``stats`` counts transfers (``puts`` — and ``chunks_streamed`` /
``chunks_skipped`` / ``bytes_put`` for the skip plane) and the largest row
block ever put on device (``max_put_rows``) so benchmarks and tests can
observe the contract instead of trusting it.

Store integrity: ``save_store`` (and the ``from_libsvm_cached`` build)
records a crc32 per store-grid chunk in ``meta.json``; ``from_store``
validates file presence/sizes up front (typed :class:`StoreMissingError` /
:class:`StoreCorruptError`) and verifies each grid chunk's checksum lazily,
the first time any of its rows is about to reach the device — so a corrupt
chunk is detected *before* its bytes can participate in a sweep or a
screening bound. Transient read faults retry with backoff
(:func:`_read_with_retry`; ``_read_fault_hook`` is the fault-injection
seam), and ``from_libsvm_cached`` rebuilds the store from the libsvm text
when opening it fails with a :class:`StoreError`.
"""

from __future__ import annotations

import json
import os
import time
import zlib
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics

__all__ = ["CsrChunk", "FeatureChunked", "BCOO_DENSITY_THRESHOLD",
           "StoreError", "StoreMissingError", "StoreCorruptError"]


class StoreError(RuntimeError):
    """Base error for on-disk store problems (missing, corrupt, unreadable)."""


class StoreMissingError(StoreError):
    """The store directory or one of its files does not exist."""


class StoreCorruptError(StoreError):
    """The store exists but fails validation (truncated file, bad meta,
    checksum mismatch)."""


#: Testing seam: when set, called as ``hook(tag, attempt)`` before every
#: guarded store read; raising ``OSError`` simulates a transient I/O fault
#: (``testing/faults.py`` installs finite- and infinite-fault versions).
_read_fault_hook = None
_READ_RETRIES = 3
_READ_BACKOFF_S = 0.02


def _read_with_retry(fn, tag: str):
    """Run a store read, retrying transient ``OSError`` with backoff.

    mmap-backed reads surface disk faults as ``OSError``/``BusError`` at
    page-touch time; NFS and flaky disks produce transient ones. Bounded
    retries with exponential backoff absorb those; persistent failure
    surfaces as a typed :class:`StoreError` naming the read.
    """
    last = None
    for attempt in range(_READ_RETRIES):
        try:
            if _read_fault_hook is not None:
                _read_fault_hook(tag, attempt)
            return fn()
        except OSError as e:
            last = e
            if attempt + 1 < _READ_RETRIES:
                time.sleep(_READ_BACKOFF_S * (2 ** attempt))
    raise StoreError(
        f"store read failed after {_READ_RETRIES} attempts: {tag}") from last


def _grid_chunk_crc(fmt: str, arrays, s: int, e: int) -> int:
    """crc32 of store-grid rows ``[s, e)`` — the payload bytes a sweep of
    those rows would consume (CSR: data + indices + the indptr slice)."""
    if fmt == "csr":
        data, indices, indptr = arrays
        lo, hi = int(indptr[s]), int(indptr[e])
        c = zlib.crc32(np.ascontiguousarray(data[lo:hi]).tobytes())
        c = zlib.crc32(np.ascontiguousarray(indices[lo:hi]).tobytes(), c)
        return zlib.crc32(np.ascontiguousarray(indptr[s:e + 1]).tobytes(), c)
    (X,) = arrays
    return zlib.crc32(np.ascontiguousarray(X[s:e]).tobytes())


def _store_grid_checksums(store_dir, meta: dict) -> dict:
    """Compute the ``meta["checksums"]`` block by re-reading the written
    binaries on the store's uniform chunk grid (verification's frame of
    reference, independent of any runtime re-chunking)."""
    m = int(meta["m"])
    cm = int(meta["chunk_m"])
    dt = np.dtype(meta["dtype"])
    if meta["format"] == "csr":
        indptr = np.memmap(os.path.join(store_dir, "indptr.bin"),
                           dtype=np.int64, mode="r", shape=(m + 1,))
        nnz = max(int(indptr[-1]), 1)
        arrays = (
            np.memmap(os.path.join(store_dir, "data.bin"), dtype=dt,
                      mode="r", shape=(nnz,)),
            np.memmap(os.path.join(store_dir, "indices.bin"),
                      dtype=np.int32, mode="r", shape=(nnz,)),
            indptr,
        )
    else:
        arrays = (np.memmap(os.path.join(store_dir, "X.bin"), dtype=dt,
                            mode="r", shape=(m, int(meta["n"]))),)
    crcs = [_grid_chunk_crc(meta["format"], arrays, s, min(s + cm, m))
            for s in range(0, m, cm)]
    out = {"algo": "crc32", "chunks": crcs}
    if meta.get("has_y"):
        y_path = os.path.join(store_dir, "y.bin")
        with open(y_path, "rb") as fy:
            out["y"] = zlib.crc32(fy.read())
    return out


def _require_store_file(store_dir, name: str,
                        nbytes: Optional[int] = None) -> str:
    p = os.path.join(store_dir, name)
    if not os.path.exists(p):
        raise StoreMissingError(f"store {store_dir} is missing {name}")
    if nbytes is not None and os.path.getsize(p) < nbytes:
        raise StoreCorruptError(
            f"{p} is truncated: {os.path.getsize(p)} bytes, "
            f"expected at least {nbytes}")
    return p

#: CSR chunks at or below this density are swept as BCOO on device (FLOPs
#: scale with nnz); denser CSR chunks are densified per transfer (the dense
#: sweep's bandwidth wins once a third of the entries are nonzero anyway).
BCOO_DENSITY_THRESHOLD = 0.05


class CsrChunk(NamedTuple):
    """Host CSR block over a contiguous range of feature rows."""

    data: np.ndarray     # (nnz,)
    indices: np.ndarray  # (nnz,) int32 column (sample) indices
    indptr: np.ndarray   # (rows + 1,) int64
    n_cols: int

    @property
    def rows(self) -> int:
        return len(self.indptr) - 1

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def density(self) -> float:
        denom = max(self.rows * self.n_cols, 1)
        return self.nnz / denom

    def to_dense(self, dtype=None) -> np.ndarray:
        out = np.zeros((self.rows, self.n_cols),
                       dtype=dtype or self.data.dtype)
        rows = np.repeat(np.arange(self.rows), np.diff(self.indptr))
        out[rows, self.indices] = self.data
        return out

    def row_sq(self) -> np.ndarray:
        """``||f_j||^2`` per chunk row, from the CSR data (no densify)."""
        sq = self.data.astype(self.data.dtype) ** 2
        out = np.zeros((self.rows,), dtype=self.data.dtype)
        if len(sq):
            rows = np.repeat(np.arange(self.rows), np.diff(self.indptr))
            np.add.at(out, rows, sq)
        return out


def _as_csr_parts(csr) -> tuple[np.ndarray, np.ndarray, np.ndarray, tuple]:
    """Duck-typed CSR unpack: scipy.sparse.csr_matrix, data/svm.CsrData, or
    a plain ``(data, indices, indptr, shape)`` tuple."""
    if hasattr(csr, "indptr") and hasattr(csr, "shape"):
        return (np.asarray(csr.data), np.asarray(csr.indices),
                np.asarray(csr.indptr), tuple(csr.shape))
    data, indices, indptr, shape = csr
    return np.asarray(data), np.asarray(indices), np.asarray(indptr), tuple(shape)


class FeatureChunked:
    """``X`` as host-resident feature-row chunks, streamed to device on use.

    Build with :meth:`from_dense` or :meth:`from_csr`; the constructor takes
    an explicit chunk list (each ``np.ndarray`` of shape ``(rows_i, n)`` or
    :class:`CsrChunk`) for callers assembling chunks from external storage.
    """

    def __init__(self, chunks: Sequence[Union[np.ndarray, CsrChunk]], n: int,
                 dtype=np.float32,
                 bcoo_threshold: float = BCOO_DENSITY_THRESHOLD):
        if not chunks:
            raise ValueError("FeatureChunked needs at least one chunk")
        self.chunks = list(chunks)
        self.n = int(n)
        self.dtype = np.dtype(dtype)
        self.bcoo_threshold = float(bcoo_threshold)
        rows = []
        for c in self.chunks:
            if isinstance(c, CsrChunk):
                if c.n_cols != self.n:
                    raise ValueError(f"chunk n_cols {c.n_cols} != {self.n}")
                rows.append(c.rows)
            else:
                if c.ndim != 2 or c.shape[1] != self.n:
                    raise ValueError(f"bad chunk shape {c.shape}")
                rows.append(c.shape[0])
        self.offsets = np.concatenate([[0], np.cumsum(rows)]).astype(np.int64)
        self.m = int(self.offsets[-1])
        self.stats = {"puts": 0, "max_put_rows": 0, "bcoo_puts": 0,
                      "chunks_streamed": 0, "chunks_skipped": 0,
                      "bytes_put": 0}
        # set by from_store: lazy checksum-verification state over the
        # store's uniform chunk grid (None = not store-backed / no sums)
        self._store = None

    def _bump(self, key: str, n: int = 1):
        """Increment a legacy ``stats`` counter and mirror it into the
        process-wide metrics registry under ``stream.<key>``."""
        self.stats[key] += n
        obs_metrics.counter("stream." + key).inc(n)

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_dense(cls, X, chunk_m: int = 512, **kw) -> "FeatureChunked":
        """Split a dense ``(m, n)`` host matrix into row chunks (no copy of
        the chunk data beyond numpy views)."""
        X = np.asarray(X)
        m, n = X.shape
        chunk_m = max(int(chunk_m), 1)
        chunks = [X[s: s + chunk_m] for s in range(0, m, chunk_m)]
        return cls(chunks, n, dtype=X.dtype, **kw)

    @classmethod
    def from_csr(cls, csr, chunk_m: int = 512, **kw) -> "FeatureChunked":
        """Split a CSR matrix over feature rows into :class:`CsrChunk`s.

        ``csr`` is anything with ``data``/``indices``/``indptr``/``shape``
        (scipy ``csr_matrix``, :class:`repro.data.svm.CsrData`) or a plain
        ``(data, indices, indptr, shape)`` tuple. Slicing CSR row blocks is
        an ``indptr`` slice — no per-element work.
        """
        data, indices, indptr, shape = _as_csr_parts(csr)
        m, n = shape
        chunk_m = max(int(chunk_m), 1)
        chunks = []
        for s in range(0, m, chunk_m):
            e = min(s + chunk_m, m)
            lo, hi = indptr[s], indptr[e]
            chunks.append(CsrChunk(
                data=data[lo:hi],
                indices=np.asarray(indices[lo:hi], np.int32),
                indptr=np.asarray(indptr[s: e + 1] - lo, np.int64),
                n_cols=int(n),
            ))
        return cls(chunks, int(n), dtype=data.dtype, **kw)

    # -- shape / metadata --------------------------------------------------

    @property
    def shape(self) -> tuple[int, int]:
        return (self.m, self.n)

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    def chunk_bounds(self, i: int) -> tuple[int, int]:
        return int(self.offsets[i]), int(self.offsets[i + 1])

    def chunk_density(self, i: int) -> float:
        c = self.chunks[i]
        if isinstance(c, CsrChunk):
            return c.density
        denom = max(c.size, 1)
        return float(np.count_nonzero(c)) / denom

    def density(self) -> float:
        nnz = sum(c.nnz if isinstance(c, CsrChunk) else np.count_nonzero(c)
                  for c in self.chunks)
        return nnz / max(self.m * self.n, 1)

    # -- escape hatch ------------------------------------------------------

    def as_dense(self) -> np.ndarray:
        """Materialize the full host matrix (in-core escape hatch)."""
        return np.concatenate([
            c.to_dense(self.dtype) if isinstance(c, CsrChunk)
            else np.asarray(c, self.dtype)
            for c in self.chunks
        ], axis=0)

    # -- device streaming --------------------------------------------------

    def _verify_rows(self, s: int, e: int) -> None:
        """Checksum-verify the store-grid chunks overlapping rows ``[s, e)``
        before those bytes reach any consumer; each grid chunk is verified
        once per container (the memmap views are read-only thereafter, and
        re-verifying every stream would double the path's disk traffic)."""
        st = self._store
        if st is None:
            return
        cm = st["chunk_m"]
        for j in range(s // cm, -(-e // cm)):
            if st["verified"][j]:
                continue
            gs, ge = j * cm, min((j + 1) * cm, self.m)
            got = _read_with_retry(
                lambda: _grid_chunk_crc(st["format"], st["arrays"], gs, ge),
                f"{st['dir']} rows [{gs}, {ge})")
            if got != st["crcs"][j]:
                raise StoreCorruptError(
                    f"checksum mismatch in store chunk {j} of {st['dir']} "
                    f"(rows [{gs}, {ge})): expected "
                    f"{st['crcs'][j]:#010x}, got {got:#010x}")
            st["verified"][j] = True

    def verify(self) -> None:
        """Eagerly checksum-verify the whole store (no-op when the container
        is not store-backed or the store predates checksums)."""
        self._verify_rows(0, self.m)

    def _device_form(self, i: int):
        """One chunk's device representation: dense ``jax.Array`` or BCOO."""
        from jax.experimental import sparse as jsparse

        self._verify_rows(*self.chunk_bounds(i))
        c = self.chunks[i]
        rows = c.rows if isinstance(c, CsrChunk) else c.shape[0]
        self._bump("puts")
        self._bump("chunks_streamed")
        self.stats["max_put_rows"] = max(self.stats["max_put_rows"], rows)
        obs_metrics.gauge("stream.max_put_rows").set_max(rows)
        if isinstance(c, CsrChunk) and c.density <= self.bcoo_threshold:
            self._bump("bcoo_puts")
            row_idx = np.repeat(np.arange(c.rows, dtype=np.int32),
                                np.diff(c.indptr))
            idx = np.stack([row_idx, c.indices.astype(np.int32)], axis=1)
            data = c.data.astype(self.dtype)
            self._bump("bytes_put", data.nbytes + idx.nbytes)
            return jsparse.BCOO(
                (jax.device_put(data), jax.device_put(idx)),
                shape=(c.rows, self.n),
            )
        dense = np.asarray(c.to_dense(self.dtype) if isinstance(c, CsrChunk)
                           else c, self.dtype)
        self._bump("bytes_put", dense.nbytes)
        return jax.device_put(dense)

    def live_order(self, live_chunks) -> list:
        """Normalize a ``live_chunks`` spec (bool mask over chunks, or index
        list) into an ascending chunk-index list; ``None`` means all live."""
        if live_chunks is None:
            return list(range(self.n_chunks))
        lv = np.asarray(live_chunks)
        if lv.dtype == bool:
            if lv.shape != (self.n_chunks,):
                raise ValueError(
                    f"live_chunks mask shape {lv.shape} != ({self.n_chunks},)")
            return [int(i) for i in np.nonzero(lv)[0]]
        return sorted(int(i) for i in lv)

    def stream(self, live_chunks=None):
        """Yield ``((start, stop), device_chunk)`` with one-chunk prefetch.

        ``jax.device_put`` is asynchronous: dispatching chunk ``i+1``'s
        transfer before yielding chunk ``i`` overlaps the next copy with the
        caller's compute on the current chunk (classic double buffering);
        at most two chunks are in flight on the device at any moment. For
        memmap-backed chunks the host-side read of chunk ``i+1`` (OS page-in
        inside ``_device_form``) also happens before the caller computes on
        chunk ``i``, so disk→host overlaps device compute the same way.

        ``live_chunks`` restricts the stream to the live subset: dead
        chunks are *never* transferred (their ``device_put`` is skipped
        entirely and counted in ``stats["chunks_skipped"]``). The prefetch
        runs over the live subsequence, so skipping keeps the double buffer.
        """
        order = self.live_order(live_chunks)
        self._bump("chunks_skipped", self.n_chunks - len(order))
        if not order:
            return
        nxt = self._device_form(order[0])
        for j, i in enumerate(order):
            cur = nxt
            if j + 1 < len(order):
                nxt = self._device_form(order[j + 1])
            yield self.chunk_bounds(i), cur

    # -- chunk-accumulated GEMV pair (the solver's two sweeps) -------------

    def matvec(self, v, live_chunks=None) -> jax.Array:
        """``X @ v`` — per-chunk rows, concatenated (the gradient sweep).

        Dead chunks contribute exact zero rows without being transferred:
        the screened solver only ever multiplies into weights certified zero
        there, and the zero-fill keeps the output shape ``(m,)``.
        """
        v = jnp.asarray(v, self.dtype)
        if live_chunks is None:
            return jnp.concatenate(
                [_chunk_mv(dev, v) for _, dev in self.stream()])
        live = set(self.live_order(live_chunks))
        it = self.stream(live_chunks=live_chunks)
        parts = []
        for i in range(self.n_chunks):
            s, e = self.chunk_bounds(i)
            if i in live:
                parts.append(_chunk_mv(next(it)[1], v))
            else:
                parts.append(jnp.zeros((e - s,), self.dtype))
        return jnp.concatenate(parts)

    def rmatvec(self, w, live_chunks=None) -> jax.Array:
        """``X^T w`` — per-chunk partials, accumulated (the margin sweep).

        Dead chunks are skipped outright: their ``w`` slice is zero, so
        their partial is an exact zero addend.
        """
        w = jnp.asarray(w, self.dtype)
        acc = jnp.zeros((self.n,), self.dtype)
        for (s, e), dev in self.stream(live_chunks=live_chunks):
            acc = acc + _chunk_rmv(dev, w[s:e])
        return acc

    def col_sq(self) -> jax.Array:
        """``||x_i||^2`` per *sample* (column) — the transposed reduction.

        Chunk-accumulated sum over feature rows of ``X**2``; CSR chunks
        scatter their squared data by column index on the host (no densify,
        no transfer). Theta-independent, so the result is memoized on the
        container — sample rules read it every path step for free. This is
        what lets ``sifs``/``sample_vi`` run out-of-core instead of forcing
        ``as_dense()``.
        """
        cached = getattr(self, "_col_sq_cache", None)
        if cached is not None:
            return cached
        acc = jnp.zeros((self.n,), self.dtype)
        for i, c in enumerate(self.chunks):
            if isinstance(c, CsrChunk):
                part = np.zeros((self.n,), dtype=self.dtype)
                if c.nnz:
                    np.add.at(part, c.indices,
                              (c.data.astype(self.dtype)) ** 2)
                acc = acc + jnp.asarray(part)
            else:
                acc = acc + _chunk_csq(self._device_form(i))
        self._col_sq_cache = acc
        return acc

    def row_sq(self) -> jax.Array:
        """``||f_j||^2`` for every feature row (one stream; CSR chunks from
        their data, no densify)."""
        outs = []
        for i, c in enumerate(self.chunks):
            if isinstance(c, CsrChunk):
                outs.append(jnp.asarray(c.row_sq().astype(self.dtype)))
            else:
                outs.append(_chunk_sq(self._device_form(i)))
        return jnp.concatenate(outs)

    # -- host-side gather (the screened-path reduction) --------------------

    def gather_rows(self, idx: np.ndarray) -> np.ndarray:
        """Dense host gather of the given global feature rows.

        The chunked path driver calls this with the rows that *survived*
        screening (bucket-padded): only chunks containing surviving rows are
        touched, and only those rows are densified — the device then holds a
        ``(kept_padded, n)`` block, never the full matrix.
        """
        idx = np.asarray(idx, np.int64)
        out = np.zeros((len(idx), self.n), dtype=self.dtype)
        which = np.searchsorted(self.offsets[1:], idx, side="right")
        for ci in np.unique(which):
            self._verify_rows(*self.chunk_bounds(int(ci)))
        for ci in np.unique(which):
            sel = np.nonzero(which == ci)[0]
            local = idx[sel] - self.offsets[ci]
            c = self.chunks[ci]
            if isinstance(c, CsrChunk):
                for dst, r in zip(sel, local):
                    lo, hi = c.indptr[r], c.indptr[r + 1]
                    out[dst, c.indices[lo:hi]] = c.data[lo:hi]
            else:
                out[sel] = c[local]
        return out

    # -- disk-resident store (np.memmap-backed chunks) ---------------------

    def save_store(self, store_dir, y=None) -> str:
        """Write this container to an mmap-able on-disk store.

        Layout: ``meta.json`` plus one flat binary per array — ``X.bin``
        (dense, row-major ``(m, n)``) or ``data.bin``/``indices.bin``/
        ``indptr.bin`` (CSR over feature rows). Arrays are written chunk by
        chunk, so saving never needs the full matrix in RAM either.
        ``meta.json`` is written last and doubles as the build-complete
        marker. Pass ``y`` to store labels alongside (``y.bin``).
        """
        os.makedirs(store_dir, exist_ok=True)
        all_csr = all(isinstance(c, CsrChunk) for c in self.chunks)
        if all_csr:
            running = 0
            indptr_parts = [np.zeros((1,), np.int64)]
            with open(os.path.join(store_dir, "data.bin"), "wb") as fd, \
                    open(os.path.join(store_dir, "indices.bin"), "wb") as fi:
                for c in self.chunks:
                    np.asarray(c.data, self.dtype).tofile(fd)
                    np.asarray(c.indices, np.int32).tofile(fi)
                    indptr_parts.append(
                        np.asarray(c.indptr[1:], np.int64) + running)
                    running += c.nnz
            np.concatenate(indptr_parts).tofile(
                os.path.join(store_dir, "indptr.bin"))
            fmt = "csr"
        else:
            with open(os.path.join(store_dir, "X.bin"), "wb") as fx:
                for c in self.chunks:
                    dense = (c.to_dense(self.dtype) if isinstance(c, CsrChunk)
                             else np.asarray(c, self.dtype))
                    dense.tofile(fx)
            fmt = "dense"
        if y is not None:
            np.asarray(y, self.dtype).tofile(os.path.join(store_dir, "y.bin"))
        chunk_m = int(max(self.offsets[i + 1] - self.offsets[i]
                          for i in range(self.n_chunks)))
        meta = {"format": fmt, "m": self.m, "n": self.n,
                "dtype": self.dtype.name, "chunk_m": chunk_m,
                "has_y": y is not None}
        # integrity manifest computed from the bytes that actually landed on
        # disk; meta.json (written last) is still the build-complete marker
        meta["checksums"] = _store_grid_checksums(store_dir, meta)
        with open(os.path.join(store_dir, "meta.json"), "w") as fm:
            json.dump(meta, fm)
        return str(store_dir)

    @classmethod
    def from_store(cls, store_dir, chunk_m: Optional[int] = None,
                   **kw) -> "FeatureChunked":
        """Open an on-disk store with ``np.memmap``-backed chunks.

        Chunks are *views* into the memmaps, so nothing is read from disk
        until a chunk is actually streamed — host RSS tracks the touched
        pages (plus whatever the OS cares to cache), never the matrix.
        ``chunk_m`` overrides the stored chunking (views are free to
        re-slice). Labels saved alongside are exposed as ``.labels`` (or
        ``None``).

        Integrity: raises :class:`StoreMissingError` when the directory or
        a required file is absent, :class:`StoreCorruptError` when meta is
        unparseable or a file is shorter than meta implies. Stores carrying
        a checksum manifest additionally verify each store-grid chunk's
        crc32 lazily, on first touch (see :meth:`verify` to front-load it).
        """
        if not os.path.isdir(store_dir):
            raise StoreMissingError(f"no such store directory: {store_dir}")
        meta_path = _require_store_file(store_dir, "meta.json")
        try:
            with open(meta_path) as fm:
                meta = json.load(fm)
            m, n = int(meta["m"]), int(meta["n"])
            dtype = np.dtype(meta["dtype"])
            fmt = meta["format"]
        except (ValueError, KeyError, TypeError) as e:
            raise StoreCorruptError(
                f"unreadable store meta {meta_path}: {e}") from e
        chunk_m = int(chunk_m or meta["chunk_m"])
        if fmt == "csr":
            _require_store_file(store_dir, "indptr.bin", (m + 1) * 8)
            indptr = np.memmap(os.path.join(store_dir, "indptr.bin"),
                               dtype=np.int64, mode="r", shape=(m + 1,))
            nnz = int(_read_with_retry(lambda: indptr[-1],
                                       f"{store_dir}/indptr.bin"))
            _require_store_file(store_dir, "data.bin", nnz * dtype.itemsize)
            _require_store_file(store_dir, "indices.bin", nnz * 4)
            data = np.memmap(os.path.join(store_dir, "data.bin"),
                             dtype=dtype, mode="r")
            indices = np.memmap(os.path.join(store_dir, "indices.bin"),
                                dtype=np.int32, mode="r")
            fc = cls.from_csr((data, indices, indptr, (m, n)),
                              chunk_m=chunk_m, **kw)
            arrays = (data, indices, indptr)
        else:
            _require_store_file(store_dir, "X.bin", m * n * dtype.itemsize)
            X = np.memmap(os.path.join(store_dir, "X.bin"), dtype=dtype,
                          mode="r", shape=(m, n))
            fc = cls.from_dense(X, chunk_m=chunk_m, **kw)
            arrays = (X,)
        sums = meta.get("checksums")
        if sums and sums.get("algo") == "crc32":
            grid_cm = int(meta["chunk_m"])
            n_grid = -(-m // grid_cm)
            crcs = list(sums["chunks"])
            if len(crcs) != n_grid:
                raise StoreCorruptError(
                    f"store {store_dir}: manifest has {len(crcs)} chunk "
                    f"checksums, grid has {n_grid}")
            fc._store = {"dir": str(store_dir), "format": fmt,
                         "arrays": arrays, "chunk_m": grid_cm,
                         "crcs": crcs,
                         "verified": np.zeros((n_grid,), dtype=bool)}
        y_path = os.path.join(store_dir, "y.bin")
        if meta.get("has_y") and os.path.exists(y_path):
            raw = _read_with_retry(
                lambda: open(y_path, "rb").read(), y_path)
            if sums and "y" in sums and zlib.crc32(raw) != sums["y"]:
                raise StoreCorruptError(
                    f"checksum mismatch in {y_path}: labels are corrupt")
            fc.labels = np.frombuffer(raw, dtype=dtype).copy()
        else:
            fc.labels = None
        return fc

    @classmethod
    def from_libsvm_cached(cls, path, store_dir=None, chunk_m: int = 512,
                           dtype=np.float32, n_features: Optional[int] = None,
                           zero_based: bool = False, rebuild: bool = False,
                           **kw) -> tuple:
        """Libsvm text → on-disk CSR store (built once) → memmap container.

        Returns ``(FeatureChunked, y)``. The store is built in two streaming
        passes over the text (pass 1 counts nnz per feature row, pass 2
        scatters values into preallocated memmaps), transposing the
        sample-major text into the paper's feature-row layout with memory
        O(m + one line) — the dense ``(m, n)`` matrix never exists in host
        RAM. Re-opens the existing store on subsequent calls (it sits next
        to the text as ``<path>.store/`` unless ``store_dir`` is given);
        ``rebuild=True`` forces a rebuild. Gzip input works transparently.

        A store that fails to open (:class:`StoreError` — missing files,
        truncation, checksum mismatch) is rebuilt from the source text once;
        the error propagates only when the rebuild fails too.
        """
        from ..data.svm import iter_libsvm

        store_dir = str(store_dir or f"{path}.store")
        if rebuild or not os.path.exists(os.path.join(store_dir, "meta.json")):
            os.makedirs(store_dir, exist_ok=True)
            # pass 1: samples, labels, nnz per feature row
            counts = np.zeros((1024,), np.int64)
            labels = []
            for label, idx, _ in iter_libsvm(path, zero_based=zero_based):
                labels.append(label)
                if idx:
                    top = max(idx)
                    while top >= len(counts):
                        counts = np.concatenate([counts, np.zeros_like(counts)])
                    np.add.at(counts, idx, 1)
            n = len(labels)
            if n == 0:
                raise ValueError(f"no samples in {path}")
            seen_m = int(np.max(np.nonzero(counts)[0])) + 1 if counts.any() else 0
            m = int(n_features) if n_features else seen_m
            if seen_m > m:
                raise ValueError(
                    f"feature index {seen_m - 1} >= n_features={m}")
            counts = counts[:m]
            indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
            nnz = int(indptr[-1])
            dt = np.dtype(dtype)
            data = np.memmap(os.path.join(store_dir, "data.bin"), dtype=dt,
                             mode="w+", shape=(max(nnz, 1),))
            indices = np.memmap(os.path.join(store_dir, "indices.bin"),
                                dtype=np.int32, mode="w+",
                                shape=(max(nnz, 1),))
            # pass 2: scatter each sample's entries at the rows' fill fronts
            fill = indptr[:-1].copy()
            for col, (_, idx, vals) in enumerate(
                    iter_libsvm(path, zero_based=zero_based)):
                if not idx:
                    continue
                jj = np.asarray(idx, np.int64)
                pos = fill[jj]
                data[pos] = np.asarray(vals, dt)
                indices[pos] = col
                fill[jj] += 1
            data.flush()
            indices.flush()
            indptr.tofile(os.path.join(store_dir, "indptr.bin"))
            y = np.where(np.asarray(labels) > 0, 1.0, -1.0).astype(dt)
            y.tofile(os.path.join(store_dir, "y.bin"))
            meta = {"format": "csr", "m": m, "n": n, "dtype": dt.name,
                    "chunk_m": int(chunk_m), "has_y": True}
            meta["checksums"] = _store_grid_checksums(store_dir, meta)
            with open(os.path.join(store_dir, "meta.json"), "w") as fm:
                json.dump(meta, fm)
        try:
            fc = cls.from_store(store_dir, chunk_m=chunk_m, **kw)
            # eager verify: silent corruption must trigger the rebuild
            # fallback *here*, not a StoreCorruptError mid-path later
            fc.verify()
        except StoreError:
            if rebuild or not os.path.exists(path):
                raise  # fresh build already, or no source to rebuild from
            return cls.from_libsvm_cached(
                path, store_dir=store_dir, chunk_m=chunk_m, dtype=dtype,
                n_features=n_features, zero_based=zero_based, rebuild=True,
                **kw)
        return fc, fc.labels


# --------------------------------------------------------------------------
# per-chunk device kernels (jitted once per chunk shape / sparsity pattern)
# --------------------------------------------------------------------------
# These, plus the screen-sweep kernels in screen_stream.py, are the ONLY
# functions that ever see a chunk on device — the memory-shape property test
# walks exactly these jaxprs.

@jax.jit
def _chunk_mv(Xc, v):
    return Xc @ v


@jax.jit
def _chunk_rmv(Xc, wc):
    # dense (rows, n).T @ (rows,) and BCOO both support this contraction;
    # for BCOO the vector-matrix form avoids materializing the transpose
    if isinstance(Xc, jnp.ndarray):
        return Xc.T @ wc
    return wc @ Xc


@jax.jit
def _chunk_sq(Xc):
    return jnp.sum(Xc * Xc, axis=1)


@jax.jit
def _chunk_csq(Xc):
    # transposed reduction: per-sample (column) partial of ||x_i||^2
    return jnp.sum(Xc * Xc, axis=0)
