"""Out-of-core feature-chunked storage for the ``(m, n)`` design matrix.

The paper's headline regime — high-dimensional text-like data with
``m >> n`` and mostly-zero ``X`` — is exactly where the design matrix stops
fitting on one device while every *working set* (a feature chunk, the
screened active set, every ``(n,)``/``(m,)`` vector) still does. This module
provides the storage container the rest of the pipeline streams over:

* :class:`FeatureChunked` holds ``X`` as a sequence of fixed-size
  **feature-block chunks** (row blocks in the paper's features-major
  layout). Each chunk lives on the *host*, either dense (``np.ndarray``) or
  CSR (:class:`CsrChunk` — indptr/indices/data over the chunk's rows), and
  is shipped to the device only while it is being swept.
* :meth:`FeatureChunked.stream` is the single device-transfer point:
  it double-buffers ``jax.device_put`` (chunk ``i+1`` is dispatched while
  chunk ``i`` computes — transfers are async, so host→device copy overlaps
  device compute), and converts low-density CSR chunks to
  ``jax.experimental.sparse.BCOO`` so the hot sweeps cost FLOPs
  proportional to ``nnz`` rather than ``chunk_m * n``.
* :meth:`matvec` / :meth:`rmatvec` are the chunk-accumulated GEMV pair the
  streamed FISTA solver is built on (``grad = X r`` concatenates per-chunk
  rows; ``u = X^T w`` accumulates per-chunk partials), and
  :meth:`gather_rows` is the host-side gather the chunked
  :class:`~repro.core.path.PathDriver` uses to materialize only the rows
  that *survive screening* — peak device memory is ``O(chunk + kept)``,
  never ``O(m * n)``.

Device-memory contract: no method of this class ever places more than one
chunk (plus ``O(m + n)`` vectors) on the device at a time; the property test
in ``tests/test_sparse_stream.py`` walks the jaxprs of every per-chunk
kernel and asserts no ``(m, n)``-sized intermediate exists. ``as_dense()``
is the explicit escape hatch for in-core use and small tests.

``stats`` counts transfers (``puts``) and the largest row block ever put on
device (``max_put_rows``) so benchmarks and tests can observe the contract
instead of trusting it.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CsrChunk", "FeatureChunked", "BCOO_DENSITY_THRESHOLD"]

#: CSR chunks at or below this density are swept as BCOO on device (FLOPs
#: scale with nnz); denser CSR chunks are densified per transfer (the dense
#: sweep's bandwidth wins once a third of the entries are nonzero anyway).
BCOO_DENSITY_THRESHOLD = 0.05


class CsrChunk(NamedTuple):
    """Host CSR block over a contiguous range of feature rows."""

    data: np.ndarray     # (nnz,)
    indices: np.ndarray  # (nnz,) int32 column (sample) indices
    indptr: np.ndarray   # (rows + 1,) int64
    n_cols: int

    @property
    def rows(self) -> int:
        return len(self.indptr) - 1

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def density(self) -> float:
        denom = max(self.rows * self.n_cols, 1)
        return self.nnz / denom

    def to_dense(self, dtype=None) -> np.ndarray:
        out = np.zeros((self.rows, self.n_cols),
                       dtype=dtype or self.data.dtype)
        rows = np.repeat(np.arange(self.rows), np.diff(self.indptr))
        out[rows, self.indices] = self.data
        return out

    def row_sq(self) -> np.ndarray:
        """``||f_j||^2`` per chunk row, from the CSR data (no densify)."""
        sq = self.data.astype(self.data.dtype) ** 2
        out = np.zeros((self.rows,), dtype=self.data.dtype)
        if len(sq):
            rows = np.repeat(np.arange(self.rows), np.diff(self.indptr))
            np.add.at(out, rows, sq)
        return out


def _as_csr_parts(csr) -> tuple[np.ndarray, np.ndarray, np.ndarray, tuple]:
    """Duck-typed CSR unpack: scipy.sparse.csr_matrix, data/svm.CsrData, or
    a plain ``(data, indices, indptr, shape)`` tuple."""
    if hasattr(csr, "indptr") and hasattr(csr, "shape"):
        return (np.asarray(csr.data), np.asarray(csr.indices),
                np.asarray(csr.indptr), tuple(csr.shape))
    data, indices, indptr, shape = csr
    return np.asarray(data), np.asarray(indices), np.asarray(indptr), tuple(shape)


class FeatureChunked:
    """``X`` as host-resident feature-row chunks, streamed to device on use.

    Build with :meth:`from_dense` or :meth:`from_csr`; the constructor takes
    an explicit chunk list (each ``np.ndarray`` of shape ``(rows_i, n)`` or
    :class:`CsrChunk`) for callers assembling chunks from external storage.
    """

    def __init__(self, chunks: Sequence[Union[np.ndarray, CsrChunk]], n: int,
                 dtype=np.float32,
                 bcoo_threshold: float = BCOO_DENSITY_THRESHOLD):
        if not chunks:
            raise ValueError("FeatureChunked needs at least one chunk")
        self.chunks = list(chunks)
        self.n = int(n)
        self.dtype = np.dtype(dtype)
        self.bcoo_threshold = float(bcoo_threshold)
        rows = []
        for c in self.chunks:
            if isinstance(c, CsrChunk):
                if c.n_cols != self.n:
                    raise ValueError(f"chunk n_cols {c.n_cols} != {self.n}")
                rows.append(c.rows)
            else:
                if c.ndim != 2 or c.shape[1] != self.n:
                    raise ValueError(f"bad chunk shape {c.shape}")
                rows.append(c.shape[0])
        self.offsets = np.concatenate([[0], np.cumsum(rows)]).astype(np.int64)
        self.m = int(self.offsets[-1])
        self.stats = {"puts": 0, "max_put_rows": 0, "bcoo_puts": 0}

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_dense(cls, X, chunk_m: int = 512, **kw) -> "FeatureChunked":
        """Split a dense ``(m, n)`` host matrix into row chunks (no copy of
        the chunk data beyond numpy views)."""
        X = np.asarray(X)
        m, n = X.shape
        chunk_m = max(int(chunk_m), 1)
        chunks = [X[s: s + chunk_m] for s in range(0, m, chunk_m)]
        return cls(chunks, n, dtype=X.dtype, **kw)

    @classmethod
    def from_csr(cls, csr, chunk_m: int = 512, **kw) -> "FeatureChunked":
        """Split a CSR matrix over feature rows into :class:`CsrChunk`s.

        ``csr`` is anything with ``data``/``indices``/``indptr``/``shape``
        (scipy ``csr_matrix``, :class:`repro.data.svm.CsrData`) or a plain
        ``(data, indices, indptr, shape)`` tuple. Slicing CSR row blocks is
        an ``indptr`` slice — no per-element work.
        """
        data, indices, indptr, shape = _as_csr_parts(csr)
        m, n = shape
        chunk_m = max(int(chunk_m), 1)
        chunks = []
        for s in range(0, m, chunk_m):
            e = min(s + chunk_m, m)
            lo, hi = indptr[s], indptr[e]
            chunks.append(CsrChunk(
                data=data[lo:hi],
                indices=np.asarray(indices[lo:hi], np.int32),
                indptr=np.asarray(indptr[s: e + 1] - lo, np.int64),
                n_cols=int(n),
            ))
        return cls(chunks, int(n), dtype=data.dtype, **kw)

    # -- shape / metadata --------------------------------------------------

    @property
    def shape(self) -> tuple[int, int]:
        return (self.m, self.n)

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    def chunk_bounds(self, i: int) -> tuple[int, int]:
        return int(self.offsets[i]), int(self.offsets[i + 1])

    def chunk_density(self, i: int) -> float:
        c = self.chunks[i]
        if isinstance(c, CsrChunk):
            return c.density
        denom = max(c.size, 1)
        return float(np.count_nonzero(c)) / denom

    def density(self) -> float:
        nnz = sum(c.nnz if isinstance(c, CsrChunk) else np.count_nonzero(c)
                  for c in self.chunks)
        return nnz / max(self.m * self.n, 1)

    # -- escape hatch ------------------------------------------------------

    def as_dense(self) -> np.ndarray:
        """Materialize the full host matrix (in-core escape hatch)."""
        return np.concatenate([
            c.to_dense(self.dtype) if isinstance(c, CsrChunk)
            else np.asarray(c, self.dtype)
            for c in self.chunks
        ], axis=0)

    # -- device streaming --------------------------------------------------

    def _device_form(self, i: int):
        """One chunk's device representation: dense ``jax.Array`` or BCOO."""
        from jax.experimental import sparse as jsparse

        c = self.chunks[i]
        rows = c.rows if isinstance(c, CsrChunk) else c.shape[0]
        self.stats["puts"] += 1
        self.stats["max_put_rows"] = max(self.stats["max_put_rows"], rows)
        if isinstance(c, CsrChunk) and c.density <= self.bcoo_threshold:
            self.stats["bcoo_puts"] += 1
            row_idx = np.repeat(np.arange(c.rows, dtype=np.int32),
                                np.diff(c.indptr))
            idx = np.stack([row_idx, c.indices.astype(np.int32)], axis=1)
            return jsparse.BCOO(
                (jax.device_put(c.data.astype(self.dtype)),
                 jax.device_put(idx)),
                shape=(c.rows, self.n),
            )
        dense = c.to_dense(self.dtype) if isinstance(c, CsrChunk) else c
        return jax.device_put(np.asarray(dense, self.dtype))

    def stream(self):
        """Yield ``((start, stop), device_chunk)`` with one-chunk prefetch.

        ``jax.device_put`` is asynchronous: dispatching chunk ``i+1``'s
        transfer before yielding chunk ``i`` overlaps the next copy with the
        caller's compute on the current chunk (classic double buffering);
        at most two chunks are in flight on the device at any moment.
        """
        nxt = self._device_form(0)
        for i in range(self.n_chunks):
            cur = nxt
            if i + 1 < self.n_chunks:
                nxt = self._device_form(i + 1)
            yield self.chunk_bounds(i), cur

    # -- chunk-accumulated GEMV pair (the solver's two sweeps) -------------

    def matvec(self, v) -> jax.Array:
        """``X @ v`` — per-chunk rows, concatenated (the gradient sweep)."""
        v = jnp.asarray(v, self.dtype)
        return jnp.concatenate([_chunk_mv(dev, v) for _, dev in self.stream()])

    def rmatvec(self, w) -> jax.Array:
        """``X^T w`` — per-chunk partials, accumulated (the margin sweep)."""
        w = jnp.asarray(w, self.dtype)
        acc = jnp.zeros((self.n,), self.dtype)
        for (s, e), dev in self.stream():
            acc = acc + _chunk_rmv(dev, w[s:e])
        return acc

    def row_sq(self) -> jax.Array:
        """``||f_j||^2`` for every feature row (one stream; CSR chunks from
        their data, no densify)."""
        outs = []
        for i, c in enumerate(self.chunks):
            if isinstance(c, CsrChunk):
                outs.append(jnp.asarray(c.row_sq().astype(self.dtype)))
            else:
                outs.append(_chunk_sq(self._device_form(i)))
        return jnp.concatenate(outs)

    # -- host-side gather (the screened-path reduction) --------------------

    def gather_rows(self, idx: np.ndarray) -> np.ndarray:
        """Dense host gather of the given global feature rows.

        The chunked path driver calls this with the rows that *survived*
        screening (bucket-padded): only chunks containing surviving rows are
        touched, and only those rows are densified — the device then holds a
        ``(kept_padded, n)`` block, never the full matrix.
        """
        idx = np.asarray(idx, np.int64)
        out = np.zeros((len(idx), self.n), dtype=self.dtype)
        which = np.searchsorted(self.offsets[1:], idx, side="right")
        for ci in np.unique(which):
            sel = np.nonzero(which == ci)[0]
            local = idx[sel] - self.offsets[ci]
            c = self.chunks[ci]
            if isinstance(c, CsrChunk):
                for dst, r in zip(sel, local):
                    lo, hi = c.indptr[r], c.indptr[r + 1]
                    out[dst, c.indices[lo:hi]] = c.data[lo:hi]
            else:
                out[sel] = c[local]
        return out


# --------------------------------------------------------------------------
# per-chunk device kernels (jitted once per chunk shape / sparsity pattern)
# --------------------------------------------------------------------------
# These, plus the screen-sweep kernels in screen_stream.py, are the ONLY
# functions that ever see a chunk on device — the memory-shape property test
# walks exactly these jaxprs.

@jax.jit
def _chunk_mv(Xc, v):
    return Xc @ v


@jax.jit
def _chunk_rmv(Xc, wc):
    # dense (rows, n).T @ (rows,) and BCOO both support this contraction;
    # for BCOO the vector-matrix form avoids materializing the transpose
    if isinstance(Xc, jnp.ndarray):
        return Xc.T @ wc
    return wc @ Xc


@jax.jit
def _chunk_sq(Xc):
    return jnp.sum(Xc * Xc, axis=1)
