"""Streamed FISTA over :class:`FeatureChunked` — the solver's two O(mn)
sweeps as chunk-accumulated GEMVs.

Mirrors the fused in-core body (``core/solver.py``): the iterate carries its
margins ``u = X^T w`` so the momentum point's margins are an axpy, and one
iteration costs exactly two streams of X —

* gradient sweep  ``grad_w = -X (y xi)``: per-chunk rows, concatenated;
* margin sweep    ``u_new = X^T w_new``: per-chunk partials, accumulated —

with the monotone-restart fallback paying its two extra streams only when
it fires. Orchestration is a host loop (each chunk transfer is a host
decision), so per-iteration host sync is inherent to the out-of-core
regime; the chunk transfers themselves are double-buffered by
``FeatureChunked.stream``.

This is the implementation behind ``core/solver.fista_solve(operator=...)``
— the seam that lets every in-core call site run unchanged on data that
does not fit on the device. Objectives match the dense solver to solver
tolerance (chunk accumulation reassociates the ``X^T w`` reduction, so
bitwise equality is *not* claimed here — that contract belongs to the
screening bound sweep, see ``screen_stream.py``).

``gap_theta_delta_stream`` is the streamed twin of
``dual.safe_theta_and_delta`` (same alternating feasibility projection,
same 1-strong-concavity radius), so the chunked path driver can certify
anchors without an in-core X.

Dynamic chunk-level re-screening: ``screen_every`` turns the solve into
segments. Between segments the live duality gap certifies an at-lambda
region, the region's bounds AND into the live *feature* mask (certified
features have ``w* = 0``, so the reduced problem shares the optimum —
the standard dynamic-screening argument), and the live *chunk* set is
whatever chunks still hold a live feature — every subsequent gradient /
margin / certification sweep streams only those. Dead chunks' gradient
rows are exact zeros (their weights are pinned 0 by the mask), so mid-
solve transfer volume tracks the certified support, not ``m``.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace

from repro.core.screening import (
    SAFE_TAU,
    FeatureReductions,
    _finalize_bounds,
    row_dot,
    shared_scalars,
)
from repro.core.solver import (
    HEALTH_SCREEN_REFUSED,
    MAX_GUARD_TRIPS,
    FistaResult,
    _resolve_guards,
    soft_threshold,
)

from .chunked import FeatureChunked

__all__ = [
    "fista_solve_chunked",
    "lipschitz_estimate_stream",
    "gap_theta_delta_stream",
]


@jax.jit
def _slacks(u, b, y, sm):
    xi = jnp.maximum(0.0, 1.0 - y * (u + b))
    return xi * sm


@jax.jit
def _objective(xi, w, lam):
    return 0.5 * jnp.sum(xi * xi) + lam * jnp.sum(jnp.abs(w))


@jax.jit
def _prox(zw, zb, gw, gb, inv_L, lam):
    return soft_threshold(zw - inv_L * gw, lam * inv_L), zb - inv_L * gb


def lipschitz_estimate_stream(fc: FeatureChunked, n_iters: int = 30,
                              key: Optional[jax.Array] = None) -> jax.Array:
    """Power iteration for ``sigma_max([X; 1^T])^2``, two streams per iter.

    Same recurrence (and start vector) as ``solver.lipschitz_estimate``; the
    chunked GEMVs reassociate the reductions, so the estimate agrees to
    float tolerance — still an upper-bound-compatible step size after the
    solver's 1% safety factor, and still monotone under row masking.
    """
    n = fc.shape[1]
    if key is None:
        key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (n,), dtype=fc.dtype)

    def norm(v):
        return jnp.sqrt(jnp.maximum(jnp.sum(v * v), 0.0))

    for _ in range(n_iters):
        v = v / jnp.maximum(norm(v), 1e-30)
        u_w = fc.matvec(v)
        u_b = jnp.sum(v)
        v = fc.rmatvec(u_w) + u_b
    return norm(v)


def _chunks_with_live_features(fc: FeatureChunked, fmask: np.ndarray) -> np.ndarray:
    """Chunk live mask: a chunk stays live while any of its features does."""
    live = np.zeros((fc.n_chunks,), dtype=bool)
    for i in range(fc.n_chunks):
        s, e = fc.chunk_bounds(i)
        live[i] = bool(fmask[s:e].any())
    return live


def fista_solve_chunked(
    fc: FeatureChunked,
    y,
    lam,
    w0=None,
    b0=None,
    max_iters: int = 2000,
    tol: float = 1e-9,
    L: Optional[jax.Array] = None,
    sample_mask=None,
    feature_mask=None,
    screen_every: Optional[int] = None,
    screen_tau: float = SAFE_TAU,
    report: Optional[dict] = None,
    guards: Optional[bool] = None,
    iteration_hook=None,
) -> FistaResult:
    """Solve the primal over chunked storage (see module docstring).

    Same contract as ``solver.fista_solve`` (warm starts, path-shared ``L``,
    0/1 ``sample_mask`` dropping loss columns); device memory stays at one
    chunk plus ``O(m + n)`` vectors.

    ``feature_mask`` (bool ``(m,)``) pins screened features to zero and
    derives the live *chunk* set every sweep streams over — a chunk with no
    live feature is never transferred. ``screen_every`` additionally
    re-certifies from the live duality gap between segments (at-lambda VI
    region), shrinking both masks mid-solve; ``report`` (a dict, mutated)
    receives ``screens`` / ``live_chunks`` / ``kept`` telemetry.

    ``guards`` (None = ``REPRO_SOLVER_GUARDS`` env default) is the host-loop
    twin of the in-core numerical health guard: a non-finite objective after
    a step, or a post-restart increase beyond rounding noise, rolls back to
    the last accepted iterate with a halved step size; trips are bounded by
    ``MAX_GUARD_TRIPS`` and returned in ``FistaResult.health``. Checking the
    *objective* alone suffices — any NaN/inf in ``w``/``u``/``b`` propagates
    into it through ``lam * sum|w|`` and the slacks. ``iteration_hook``
    (fault-injection seam, ``testing/faults.py``) is called as
    ``hook(k, w, b, u, obj) -> None | (w, b, u, obj)`` on each candidate
    iterate before the guard inspects it.
    """
    m, n = fc.shape
    y_key = y
    y = jnp.asarray(y, fc.dtype)
    lam = jnp.asarray(lam, fc.dtype)
    sm = (jnp.ones_like(y) if sample_mask is None
          else jnp.asarray(sample_mask, fc.dtype))
    if L is None:
        L = lipschitz_estimate_stream(fc)
    L = jnp.maximum(jnp.asarray(L, fc.dtype) * 1.01, 1e-12)
    inv_L = 1.0 / L

    dynamic = screen_every is not None and screen_every > 0
    if feature_mask is not None:
        fmask = np.asarray(feature_mask, bool).copy()
    else:
        fmask = np.ones((m,), dtype=bool)
    masked = not fmask.all()
    live = _chunks_with_live_features(fc, fmask) if (masked or dynamic) else None
    live_arg = None if (live is None or live.all()) else live
    fmask_dev = jnp.asarray(fmask, fc.dtype)

    if dynamic:
        from .screen_stream import fixed_reductions

        d_one, d_y, d_sq = fixed_reductions(fc, y_key)

    guards = _resolve_guards(guards)
    health = 0
    backoff = 1.0

    if w0 is None:
        w = jnp.zeros((m,), fc.dtype)
        u = jnp.zeros((n,), fc.dtype)
    else:
        w = jnp.asarray(w0, fc.dtype)
        if guards and not bool(jnp.all(jnp.isfinite(w))):
            # sanitize the warm start (cf. solver._init_state): w = 0 is
            # always feasible, and a poisoned coordinate would otherwise
            # poison every later iterate through the carried margins
            w = jnp.where(jnp.isfinite(w), w, jnp.zeros_like(w))
            health += 1
        if masked:
            w = w * fmask_dev
        u = fc.rmatvec(w, live_chunks=live_arg)
    b = jnp.asarray(jnp.mean(y) if b0 is None else b0, fc.dtype)
    if guards and not bool(jnp.isfinite(b)):
        b = jnp.asarray(0.0, fc.dtype)
        health += 1

    xi = _slacks(u, b, y, sm)
    obj = _objective(xi, w, lam)
    w_prev, b_prev, u_prev = w, b, u
    t = 1.0
    tol = float(tol)
    k = 0
    converged = False
    rel_prev = rel_prev2 = float("inf")
    n_screens = 0

    def prox_from(w_a, b_a, u_a, inv_Le):
        """One proximal step anchored at known margins: 2 streams of X
        (live chunks only — dead rows are pinned zero by the mask)."""
        xi_a = _slacks(u_a, b_a, y, sm)
        gv = y * xi_a
        gw = -fc.matvec(gv, live_chunks=live_arg)
        gb = -jnp.sum(gv)
        w_new, b_new = _prox(w_a, b_a, gw, gb, inv_Le, lam)
        if masked:
            w_new = w_new * fmask_dev
        u_new = fc.rmatvec(w_new, live_chunks=live_arg)
        obj_new = _objective(_slacks(u_new, b_new, y, sm), w_new, lam)
        return w_new, b_new, u_new, obj_new

    eps = float(jnp.finfo(fc.dtype).eps)
    _tt0 = time.perf_counter()
    while k < max_iters:
        inv_Le = inv_L * backoff if guards else inv_L
        t_next = 0.5 * (1.0 + float(jnp.sqrt(1.0 + 4.0 * t * t)))
        beta = (t - 1.0) / t_next
        zw = w + beta * (w - w_prev)
        zb = b + beta * (b - b_prev)
        uz = u + beta * (u - u_prev)

        w_new, b_new, u_new, obj_new = prox_from(zw, zb, uz, inv_Le)
        restarted = float(obj_new) > float(obj)
        if restarted:
            # monotone restart: plain step from (w, b) — margins are carried
            w_new, b_new, u_new, obj_new = prox_from(w, b, u, inv_Le)
            t_next = 1.0

        if iteration_hook is not None:
            hooked = iteration_hook(k, w_new, b_new, u_new, obj_new)
            if hooked is not None:
                w_new, b_new, u_new, obj_new = hooked

        if guards:
            obj_f = float(obj_new)
            # a non-finite objective, or a *plain* (post-restart) step that
            # still increased it beyond rounding noise: the step size is
            # invalid — roll back, halve it, restart momentum (cf. the
            # on-device guard in solver._make_fista_body)
            bad = not np.isfinite(obj_f) or (
                restarted and obj_f > float(obj)
                + 256.0 * eps * max(abs(float(obj)), 1.0))
            if bad:
                health += 1
                backoff *= 0.5
                w_prev, b_prev, u_prev, t = w, b, u, 1.0
                rel_prev = rel_prev2 = float("inf")
                k += 1
                if (health & (HEALTH_SCREEN_REFUSED - 1)) >= MAX_GUARD_TRIPS:
                    break  # unrecoverable: poisoned operands (see solver)
                continue

        # restart iterations are not convergence evidence (cf. the in-core
        # body): force one more plain iteration after every restart
        rel = (float("inf") if restarted
               else abs(float(obj) - float(obj_new)) / max(abs(float(obj)), 1e-30))
        w_prev, b_prev, u_prev = w, b, u
        w, b, u, obj, t = w_new, b_new, u_new, obj_new, t_next
        k += 1
        # three consecutive sub-tol iterations (see solver.FistaState.rel_prev)
        if max(rel, rel_prev, rel_prev2) <= tol:
            converged = True
            break
        rel_prev, rel_prev2 = rel, rel_prev

        if dynamic and k % int(screen_every) == 0 and k < max_iters:
            # segment boundary: certify the reduced problem's gap, screen
            # the at-lambda region, AND into the live masks
            theta, delta = gap_theta_delta_stream(
                fc, y, w, b, lam, u=u,
                live_chunks=live_arg, feature_mask=fmask_dev)
            if not bool(jnp.isfinite(delta)):
                # refused certificate (non-finite gap/theta, sanitized to
                # delta = inf): screening from it could discard a live
                # feature — fail-safe to keep-all for this segment
                health |= HEALTH_SCREEN_REFUSED
                continue
            yt = y * theta
            parts = []
            for i in range(fc.n_chunks):
                s, e = fc.chunk_bounds(i)
                parts.append(jnp.zeros((e - s,), fc.dtype))
            for (s, e), dev in fc.stream(live_chunks=live_arg):
                i = int(np.searchsorted(fc.offsets[1:], s, side="right"))
                parts[i] = (row_dot(dev, yt) if isinstance(dev, jnp.ndarray)
                            else dev @ yt)
            red = FeatureReductions(d_theta=jnp.concatenate(parts),
                                    d_one=d_one, d_y=d_y, d_sq=d_sq)
            sh = shared_scalars(y, lam, lam, theta, delta=delta)
            # NaN-safe keep: a non-finite bound must KEEP its feature
            keep = np.asarray(~(_finalize_bounds(red, sh) < screen_tau))
            new_fmask = fmask & keep
            n_screens += 1
            obs_trace.instant("stream.solve.screen", iter=k,
                              kept=int(new_fmask.sum()))
            if new_fmask.sum() < fmask.sum():
                fmask = new_fmask
                masked = True
                fmask_dev = jnp.asarray(fmask, fc.dtype)
                live = _chunks_with_live_features(fc, fmask)
                live_arg = None if live.all() else live
                w = w * fmask_dev
                u = fc.rmatvec(w, live_chunks=live_arg)
                obj = _objective(_slacks(u, b, y, sm), w, lam)
                # mask change invalidates momentum: restart cleanly
                w_prev, b_prev, u_prev, t = w, b, u, 1.0
                rel_prev = rel_prev2 = float("inf")

    if obs_trace.enabled():
        obs_trace.complete("stream.solve", _tt0, time.perf_counter(),
                           iters=k, converged=bool(converged),
                           screens=n_screens, kept=int(fmask.sum()))
    if report is not None:
        report.update(
            screens=n_screens,
            kept=int(fmask.sum()),
            live_chunks=int(live.sum()) if live is not None else fc.n_chunks,
        )
    return FistaResult(
        w=w, b=b, obj=obj, n_iters=jnp.asarray(k, jnp.int32),
        converged=jnp.asarray(converged), u=u,
        health=jnp.asarray(health, jnp.int32),
    )


def gap_theta_delta_stream(
    fc: FeatureChunked,
    y,
    w,
    b,
    lam,
    n_feas_iters: int = 8,
    u: Optional[jax.Array] = None,
    live_chunks=None,
    feature_mask=None,
    want_corr: bool = False,
):
    """Streamed ``(theta1, delta)`` certificate — twin of
    ``dual.safe_theta_and_delta``.

    Each feasibility iteration needs the correlation sweep ``X (y * alpha)``
    (the rescale is a max over the problem's features), so this costs
    ``n_feas_iters + 1`` streams; ``u`` (margins ``X^T w``, e.g. the
    solver's carried ones) saves the extra margin stream.

    ``live_chunks`` / ``feature_mask`` certify the *reduced* problem
    instead: features already screened out have ``w* = 0``, so the reduced
    problem shares the full optimum and its dual-feasibility max runs over
    live features only — that is what lets both mid-solve certification
    (dynamic chunked solves) and the path driver's between-step anchor
    certification skip dead chunks' transfers.

    ``want_corr=True`` returns ``(theta1, delta, d_theta)`` where
    ``d_theta = X (y * theta1)`` falls out of the *final* rescale's own
    correlation sweep (``theta1 = s * alpha / lam`` implies
    ``X (y * theta1) = s * corr / lam`` — zero extra streams). Entries in
    skipped chunks are zeros: only live chunks' slices are valid, which is
    exactly what the chunk-skip cache refresh consumes
    (``ChunkScreenCache.refresh``). The feature mask applies to the rescale
    *max* only, so live chunks' ``d_theta`` entries are valid for every
    feature in them, screened or not.
    """
    y = jnp.asarray(y, fc.dtype)
    lam = jnp.asarray(lam, fc.dtype)
    if u is None:
        u = fc.rmatvec(jnp.asarray(w, fc.dtype), live_chunks=live_chunks)
    xi = jnp.maximum(0.0, 1.0 - y * (u + jnp.asarray(b, fc.dtype)))
    alpha = xi
    n = y.shape[0]
    fm = None if feature_mask is None else jnp.asarray(feature_mask, fc.dtype)

    def rescale(alpha):
        corr = fc.matvec(y * alpha, live_chunks=live_chunks)
        mx = jnp.max(jnp.abs(corr if fm is None else corr * fm))
        s = jnp.minimum(1.0, lam / jnp.maximum(mx, 1e-30))
        return alpha * s, corr * s

    for _ in range(n_feas_iters):
        alpha, _ = rescale(alpha)
        alpha = jnp.maximum(0.0, alpha - (alpha @ y) / n * y)
    alpha, corr = rescale(alpha)

    gap = (0.5 * jnp.sum(xi * xi)
           + lam * jnp.sum(jnp.abs(jnp.asarray(w, fc.dtype)))
           - (jnp.sum(alpha) - 0.5 * jnp.sum(alpha * alpha)))
    eq_resid = jnp.abs(alpha @ y) / jnp.sqrt(jnp.asarray(float(n), fc.dtype))
    delta = (jnp.sqrt(2.0 * jnp.maximum(gap, 0.0)) + 2.0 * eq_resid) / lam
    theta = alpha / lam
    # certificate sanitize (twin of solver.gap_theta_delta): any non-finite
    # component refuses the certificate — delta = inf is the one downstream
    # signal ("isfinite(delta)") that screening from this anchor is unsafe
    cert_ok = (jnp.isfinite(gap) & jnp.isfinite(delta)
               & jnp.all(jnp.isfinite(theta)))
    delta = jnp.where(cert_ok, delta, jnp.asarray(jnp.inf, fc.dtype))
    if want_corr:
        return theta, delta, corr / lam
    return theta, delta
