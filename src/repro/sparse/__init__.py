"""Out-of-core + sparse-matrix engine: chunked storage and streamed sweeps.

Entry points:

* :class:`FeatureChunked` — ``X`` as host-resident feature-row chunks
  (dense or CSR; low-density chunks sweep as BCOO on device);
* :func:`screen_stream` / :func:`screen_bounds_stream` — the paper's safe
  screen, chunk-accumulated (bitwise vs the in-core sweep on dense chunks);
* :func:`screen_step_stream` / :class:`ChunkScreenCache` — the chunk-skip
  plane: per-chunk stale-anchor bounds certify whole chunks dead *before*
  their ``device_put``, so a path step streams only the live chunks;
* :func:`stream_sample_stats` — the transposed (sample-axis) sweep feeding
  ``sample_vi``/``sifs`` screening out of core;
* :func:`fista_solve_chunked` — streamed FISTA behind the
  ``core/solver.fista_solve(operator=...)`` seam (``screen_every=`` adds
  dynamic chunk-level re-screening between segments);
* the chunked :class:`~repro.core.path.PathDriver` lane: pass a
  ``FeatureChunked`` to ``svm_path`` / ``PathDriver.run`` and the screened
  path gathers only the chunks that survive screening — peak device memory
  ``O(chunk + kept)``. ``FeatureChunked.from_libsvm_cached`` /
  ``from_store`` keep the chunks themselves disk-resident (memmap).
"""

from .chunked import (  # noqa: F401
    BCOO_DENSITY_THRESHOLD,
    CsrChunk,
    FeatureChunked,
    StoreCorruptError,
    StoreError,
    StoreMissingError,
)
from .screen_stream import (  # noqa: F401
    ChunkScreenCache,
    fixed_reductions,
    lambda_max_stream,
    screen_bounds_stream,
    screen_stack_stream,
    screen_step_stream,
    screen_stream,
    stream_anchor_stats,
    stream_feature_reductions,
    stream_sample_stats,
)
from .solver_stream import (  # noqa: F401
    fista_solve_chunked,
    gap_theta_delta_stream,
    lipschitz_estimate_stream,
)
