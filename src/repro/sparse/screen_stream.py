"""Chunk-accumulated safe-screening bound sweep over :class:`FeatureChunked`.

The paper's O(mn) screen reduces each feature row independently (the four
per-feature reductions of ``core/screening.py``), so it streams perfectly:
sweep one feature chunk at a time, concatenate the per-chunk reductions, and
finalize with the same closed-form bound — the device never holds more than
one chunk of ``X``.

Bitwise contract
----------------
For dense chunks the per-chunk reduction is the *same jitted row-stable
kernel* (``core/screening._row_stable_reductions`` / ``row_dot``) the
in-core sweep uses, and row-stable reductions are invariant to the leading
row count — so ``screen_stream`` on any chunking returns **bitwise** the
bounds of ``core/screening.screen_bounds`` on the dense matrix (asserted in
``tests/test_sparse_stream.py``). BCOO chunks (low-density CSR) use sparse
matvecs instead — FLOPs proportional to ``nnz`` — which reassociate the
reduction; they carry a tolerance guarantee, and screening *safety* is
unaffected either way (the tau margin absorbs ulp noise by design).

Per-chunk Pallas route: ``use_pallas=True`` sends each dense chunk through
the fused TPU bound kernel (``kernels/ops.screen_bounds_op``) instead — the
bound finalizer is per-row, so evaluating it per chunk with the globally
shared scalars is exact. fp32 kernel accumulation makes this a tolerance
route too; default policy is Mosaic-on-TPU, XLA elsewhere.

Theta-independent caching (paper Sec. 6.4): ``d_one``, ``d_y``, ``d_sq``
do not depend on the anchor, so a path driver screens T lambdas with
``T + 1`` streams of X, not ``4T`` — :func:`fixed_reductions` computes them
once and memoizes on the container.

Chunk-level screening (the skip plane)
--------------------------------------
:class:`ChunkScreenCache` remembers, per chunk, the anchor (scalars +
that chunk's fresh ``d_theta`` slice) from the step the chunk was last
streamed. A VI region built from *any* certified anchor stays safe for
every smaller target lambda, so evaluating the cached anchor's bounds at
the current ``lam2`` — pure per-feature arithmetic, zero streams — yields
valid safe bounds for the whole chunk. When the chunk's max bound falls
below tau the chunk is certified dead *before* its ``device_put``:
:func:`screen_step_stream` streams only the live chunks (refreshing their
cache entries) and stamps the dead chunks' features with their
(stale-anchor, still-valid) bounds. The full-stream twin (``skip=False``)
runs the *identical* cache policy and arithmetic but transfers every chunk
anyway — which is what makes "skip vs full-stream is bitwise equal" a
testable property rather than a hope.
"""

from __future__ import annotations

import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.core.rules.programs import PROGRAMS, stack_bounds
from repro.core.screening import (
    SAFE_TAU,
    AnchorStats,
    FeatureReductions,
    _finalize_bounds,
    _row_stable_reductions,
    anchor_slice,
    anchor_stats,
    finalize_from_anchor_jit,
    fixed_slice,
    fixed_stats,
    row_dot,
    shared_scalars,
)

from .chunked import FeatureChunked

__all__ = [
    "fixed_reductions",
    "stream_feature_reductions",
    "stream_anchor_stats",
    "stream_sample_stats",
    "screen_bounds_stream",
    "screen_stream",
    "screen_stack_stream",
    "screen_step_stream",
    "ChunkScreenCache",
    "lambda_max_stream",
]

_FIXED_CACHE = "_fixed_reductions"


def fixed_reductions(fc: FeatureChunked, y) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``(d_one, d_y, d_sq)`` for every feature, streamed once and memoized.

    The cache is keyed on the identity of the *caller's* ``y`` object — not
    the dtype-converted copy, which would be fresh every call and silently
    turn the "T + 1 streams per path" contract into 2T+ (one dataset per
    container is the expected usage; a different ``y`` object recomputes).
    """
    cached = getattr(fc, _FIXED_CACHE, None)
    if cached is not None and cached[0] is y:
        return cached[1]
    y_key = y
    y = jnp.asarray(y, fc.dtype)
    d_one, d_y, d_sq = [], [], []
    for (_, _), dev in fc.stream():
        if isinstance(dev, jnp.ndarray):
            _, o, dy, sq = _row_stable_reductions(dev, y, y)
            d_one.append(o), d_y.append(dy), d_sq.append(sq)
        else:  # BCOO: sparse matvecs + data-side row norms
            d_one.append(dev @ y)
            d_y.append(dev @ jnp.ones_like(y))
            d_sq.append(_bcoo_row_sq(dev))
    out = (jnp.concatenate(d_one), jnp.concatenate(d_y), jnp.concatenate(d_sq))
    setattr(fc, _FIXED_CACHE, (y_key, out))
    return out


@functools.partial(jax.jit, static_argnums=(2,))
def _bcoo_row_sq_impl(data, rows, n_rows):
    return jax.ops.segment_sum(data * data, rows, num_segments=n_rows)


def _bcoo_row_sq(dev) -> jax.Array:
    """``||f_j||^2`` of a BCOO chunk from its data (nnz work, no densify)."""
    return _bcoo_row_sq_impl(dev.data, dev.indices[:, 0], int(dev.shape[0]))


def stream_feature_reductions(fc: FeatureChunked, y, theta1) -> FeatureReductions:
    """The four screening reductions for every feature, one stream of X."""
    # cache first, with the caller's y object (see fixed_reductions), then
    # convert for the local arithmetic
    d_one, d_y, d_sq = fixed_reductions(fc, y)
    y = jnp.asarray(y, fc.dtype)
    theta1 = jnp.asarray(theta1, fc.dtype)
    yt = y * theta1
    parts = []
    for (_, _), dev in fc.stream():
        parts.append(row_dot(dev, yt) if isinstance(dev, jnp.ndarray)
                     else dev @ yt)
    return FeatureReductions(d_theta=jnp.concatenate(parts), d_one=d_one,
                             d_y=d_y, d_sq=d_sq)


def screen_bounds_stream(
    fc: FeatureChunked,
    y,
    lam1,
    lam2,
    theta1,
    delta=0.0,
    use_pallas: Optional[bool] = None,
) -> jax.Array:
    """Upper bound on ``|fhat_j^T theta*(lam2)|``, chunk-streamed.

    XLA route (default off-TPU): per-chunk row-stable reductions + the
    shared jitted finalizer — bitwise vs the in-core sweep on dense chunks.
    Pallas route: per-chunk fused bound kernel (TPU hot path).
    """
    from repro.kernels.ops import fista_use_pallas  # lazy: no import cycle

    if fista_use_pallas(use_pallas):
        from repro.kernels.ops import screen_bounds_op

        from .chunked import CsrChunk

        y = jnp.asarray(y, fc.dtype)
        theta1 = jnp.asarray(theta1, fc.dtype)
        parts = []
        # iterate the host chunks directly (densifying CSR ones) rather
        # than fc.stream(): the fused kernel needs dense input, and going
        # through stream() would build-and-discard a BCOO per sparse chunk
        # — a second transfer the stats would record as the one used
        for i, c in enumerate(fc.chunks):
            dense = c.to_dense(fc.dtype) if isinstance(c, CsrChunk) else c
            rows = dense.shape[0]
            fc._bump("puts")
            fc.stats["max_put_rows"] = max(fc.stats["max_put_rows"], rows)
            obs_metrics.gauge("stream.max_put_rows").set_max(rows)
            parts.append(screen_bounds_op(jnp.asarray(dense, fc.dtype), y,
                                          lam1, lam2, theta1, delta=delta))
        return jnp.concatenate(parts)

    red = stream_feature_reductions(fc, y, theta1)
    sh = shared_scalars(jnp.asarray(y, fc.dtype), lam1, lam2,
                        jnp.asarray(theta1, fc.dtype), delta=delta)
    return _finalize_bounds(red, sh)


def screen_stream(
    fc: FeatureChunked,
    y,
    lam1,
    lam2,
    theta1,
    tau: float = SAFE_TAU,
    delta=0.0,
    use_pallas: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Safe screening over chunked storage: ``(keep_mask, bounds)``."""
    bounds = screen_bounds_stream(fc, y, lam1, lam2, theta1, delta=delta,
                                  use_pallas=use_pallas)
    # NaN-safe keep: a non-finite bound certifies nothing — keep the feature
    return ~(bounds < tau), bounds


def stream_anchor_stats(fc: FeatureChunked, y, lam1, theta1, delta=0.0,
                        live_chunks=None, cache: Optional["ChunkScreenCache"] = None,
                        ) -> AnchorStats:
    """:class:`~repro.core.screening.AnchorStats` from ONE stream of X.

    The only chunk-streamed component is the per-feature ``d_theta`` sweep
    (same row-stable kernel as :func:`stream_feature_reductions`); the
    anchor scalars are in-core reductions of ``theta1``/``y``. Callers that
    evaluate multi-anchor stacks (dvi) should hold on to the returned
    pytree — re-using last step's anchor costs zero extra streams.

    ``live_chunks`` restricts the sweep to live chunks; dead chunks fill
    their ``d_theta`` slice from ``cache`` (stale values — only valid to
    read through the cache's own stale-anchor bounds, which is exactly what
    the chunk-skip plane does; live-chunk entries are refreshed in place).
    """
    y = jnp.asarray(y, fc.dtype)
    theta1 = jnp.asarray(theta1, fc.dtype)
    yt = y * theta1
    if live_chunks is None:
        parts = [row_dot(dev, yt) if isinstance(dev, jnp.ndarray) else dev @ yt
                 for (_, _), dev in fc.stream()]
        anchor = anchor_stats(y, lam1, theta1, delta, jnp.concatenate(parts))
        if cache is not None:
            cache.refresh(anchor, live=None)
        return anchor
    if cache is None:
        raise ValueError("live_chunks needs a ChunkScreenCache for the "
                         "dead chunks' d_theta slices")
    live = set(fc.live_order(live_chunks))
    it = fc.stream(live_chunks=live_chunks)
    parts = []
    for i in range(fc.n_chunks):
        if i in live:
            dev = next(it)[1]
            parts.append(row_dot(dev, yt) if isinstance(dev, jnp.ndarray)
                         else dev @ yt)
        else:
            parts.append(cache.d_theta_slice(i))
    anchor = anchor_stats(y, lam1, theta1, delta, jnp.concatenate(parts))
    cache.refresh(anchor, live=live)
    return anchor


def stream_sample_stats(fc: FeatureChunked, y, w1, b1) -> tuple[jax.Array, jax.Array]:
    """The transposed (sample-axis) sweep: ``(u1, x_sq)`` chunk-accumulated.

    ``u1 = X^T w1 + b1`` rides :meth:`FeatureChunked.rmatvec` and
    ``x_sq = ||x_i||^2`` per sample rides the memoized
    :meth:`FeatureChunked.col_sq` — together they are every input
    :func:`~repro.core.rules.sample_vi.margin_surplus_core` needs, so
    ``sifs``/``sample_vi`` screening runs out-of-core without
    ``as_dense()``. Costs one stream for ``u1`` (skippable via the caller's
    live set when ``w1`` is certified zero on dead chunks) and one
    once-per-container stream for ``x_sq``.
    """
    w1 = jnp.asarray(w1, fc.dtype)
    u1 = fc.rmatvec(w1) + jnp.asarray(b1, fc.dtype)
    return u1, fc.col_sq()


class ChunkScreenCache:
    """Per-chunk stale-anchor state for chunk-level safe screening.

    For each chunk: the :class:`AnchorStats` scalars from the step the
    chunk was last streamed, plus the chunk's ``d_theta`` slice from that
    same stream. :meth:`live_mask` evaluates each cached chunk's VI bounds
    at the *current* target ``lam2`` — valid because a certified anchor's
    region is safe for any smaller lambda (see
    ``core/screening.finalize_from_anchor_jit``) — and declares a chunk
    dead when even its loosest surviving feature bound is below tau. Dead
    chunks keep their (stale) cache entries; live chunks are refreshed
    after each stream, so the staleness of any chunk is exactly "how long
    it has been certifiably dead".
    """

    def __init__(self, fc: FeatureChunked):
        self.fc = fc
        self._scalars: list = [None] * fc.n_chunks  # (lam, delta, tdo, tdy, tsq)
        self._d_theta: list = [None] * fc.n_chunks
        self._lam_host: list = [None] * fc.n_chunks  # float lam for the guard

    def d_theta_slice(self, i: int) -> jax.Array:
        part = self._d_theta[i]
        if part is None:
            raise ValueError(f"chunk {i} marked dead but never streamed")
        return part

    def refresh(self, anchor: AnchorStats, live=None) -> None:
        """Record ``anchor`` as the cached region for the streamed chunks
        (``live=None`` = all). ``anchor.d_theta`` must be full-``m``.

        A poisoned anchor (any non-finite scalar or ``d_theta`` entry) must
        never become a cached region — its stale bounds could later certify
        a live chunk dead. Such an anchor *invalidates* the entries it would
        have refreshed instead: ``live_mask`` then treats those chunks as
        never-streamed (+inf stale bounds, always live), i.e. the cache
        fail-safes to streaming everything it can no longer vouch for.
        """
        lam_host = float(anchor.lam)
        bad = not (np.isfinite(lam_host)
                   and np.isfinite(float(anchor.delta))
                   and np.isfinite(float(anchor.theta_dot_one))
                   and np.isfinite(float(anchor.theta_dot_y))
                   and np.isfinite(float(anchor.theta_sq))
                   and bool(jnp.all(jnp.isfinite(anchor.d_theta))))
        scalars = (anchor.lam, anchor.delta, anchor.theta_dot_one,
                   anchor.theta_dot_y, anchor.theta_sq)
        for i in range(self.fc.n_chunks):
            if live is not None and i not in live:
                continue
            if bad:
                self._scalars[i] = None
                self._d_theta[i] = None
                self._lam_host[i] = None
                continue
            s, e = self.fc.chunk_bounds(i)
            self._scalars[i] = scalars
            self._d_theta[i] = anchor.d_theta[s:e]
            self._lam_host[i] = lam_host

    def chunk_anchor(self, i: int) -> Optional[AnchorStats]:
        if self._scalars[i] is None:
            return None
        lam, delta, tdo, tdy, tsq = self._scalars[i]
        return AnchorStats(lam=lam, delta=delta, theta_dot_one=tdo,
                           theta_dot_y=tdy, theta_sq=tsq,
                           d_theta=self._d_theta[i])

    def live_mask(self, lam2, fixed, tau: float = SAFE_TAU,
                  ) -> tuple[np.ndarray, Optional[jax.Array]]:
        """``(live, stale_bounds)`` for the current target ``lam2``.

        ``live[i]`` is True when chunk ``i`` must be streamed (no cache yet,
        or some cached bound survives tau). ``stale_bounds`` is the full
        ``(m,)`` vector of cached-anchor bounds (+inf for never-streamed
        chunks): every finite entry is a *valid* safe bound, and for dead
        chunks every entry is < tau — the caller stamps these over the
        dead features so the returned bounds stay honest without a stream.
        """
        fc = self.fc
        live = np.ones((fc.n_chunks,), dtype=bool)
        parts = []
        lam2_host = float(lam2)
        for i in range(fc.n_chunks):
            s, e = fc.chunk_bounds(i)
            a = self.chunk_anchor(i)
            # the stale region certifies only strictly-smaller targets
            if a is None or not lam2_host < self._lam_host[i]:
                parts.append(jnp.full((e - s,), jnp.inf, fc.dtype))
                continue
            b = finalize_from_anchor_jit(a, lam2, fixed_slice(fixed, s, e))
            parts.append(b)
            # NaN-safe liveness: a non-finite bound must keep its chunk live
            live[i] = not bool(jnp.max(b) < tau)
        return live, jnp.concatenate(parts)


def screen_step_stream(
    fc: FeatureChunked,
    y,
    lam1,
    lam2,
    theta1,
    delta=0.0,
    rules: tuple = ("feature_vi",),
    tau: float = SAFE_TAU,
    cache: Optional[ChunkScreenCache] = None,
    anchor_old: Optional[AnchorStats] = None,
    skip: bool = True,
    use_pallas: Optional[bool] = None,
):
    """One path step's screening with chunk-level skipping.

    Returns ``(keep, bounds, anchor, live)``: the per-feature keep mask and
    bounds, the fresh :class:`AnchorStats` (for multi-anchor stacks), and
    the chunk live mask actually used. Dead chunks — certified by their
    cached stale-anchor bounds — are never transferred when ``skip`` is
    True; with ``skip=False`` the identical decisions are made but every
    chunk is streamed (full-stream twin, for equivalence testing and as the
    no-cache baseline). Their features carry the stale bounds (valid, all
    < tau) so ``keep = bounds >= tau`` needs no side-band mask.

    With ``rules == ("feature_vi",)`` and no ``anchor_old`` the bounds ride
    the same kernels as :func:`screen_stream` (bitwise vs in-core on dense
    chunks, Pallas-eligible); other stacks go through
    :func:`~repro.core.rules.programs.stack_bounds` on the fresh anchors.

    Multi-anchor stacks (dvi) disable the skip: a returned anchor whose
    dead-chunk ``d_theta`` entries are stale would be *invalid* as next
    step's old anchor for features whose chunk comes back alive (a dead
    chunk's bounds grow again as ``lam2`` shrinks) — so history-carrying
    stacks stream every chunk, every step, and chunk skipping stays a
    single-anchor-stack feature. The cache itself already plays the
    old-anchor role there, per chunk.
    """
    from repro.kernels.ops import fista_use_pallas  # lazy: no import cycle

    _tt0 = time.perf_counter()
    y_key = y
    d_one, d_y, d_sq = fixed_reductions(fc, y)
    y = jnp.asarray(y, fc.dtype)
    theta1 = jnp.asarray(theta1, fc.dtype)
    fixed = fixed_stats(y, d_one, d_y, d_sq)

    if cache is None:
        cache = ChunkScreenCache(fc)
    needs_hist = (anchor_old is not None
                  or any(PROGRAMS[nm].n_anchors > 1 for nm in rules))
    if needs_hist:
        live = np.ones((fc.n_chunks,), dtype=bool)
        stale_bounds = None
    else:
        live, stale_bounds = cache.live_mask(lam2, fixed, tau)
    live_arg = None if bool(live.all()) else live

    pure_vi = tuple(rules) == ("feature_vi",) and anchor_old is None
    if pure_vi and fista_use_pallas(use_pallas):
        anchor, bounds = _pallas_step(fc, y_key, y, lam1, lam2, theta1,
                                      delta, cache, live, skip)
    else:
        anchor = stream_anchor_stats(
            fc, y_key, lam1, theta1, delta=delta,
            live_chunks=live_arg if skip else None,
            cache=cache if skip else None)
        if not skip:
            # full-stream twin: the transfer happened for every chunk, but
            # cache entries for dead chunks must NOT advance — identical
            # cache evolution to the skipping run is what makes the two
            # modes bitwise-comparable — so refresh the live set only.
            cache.refresh(anchor,
                          live=set(int(i) for i in np.nonzero(live)[0]))
        if pure_vi:
            red = FeatureReductions(d_theta=anchor.d_theta, d_one=d_one,
                                    d_y=d_y, d_sq=d_sq)
            sh = shared_scalars(y, lam1, lam2, theta1, delta=delta)
            bounds = _finalize_bounds(red, sh)
        else:
            anchors = (anchor,) if anchor_old is None else (anchor_old, anchor)
            progs = tuple(PROGRAMS[nm] for nm in rules)
            bounds = stack_bounds(progs, lam2, anchors, fixed)

    if not bool(live.all()):
        dead_feat = np.repeat(
            ~live, np.diff(fc.offsets).astype(np.int64))
        bounds = jnp.where(jnp.asarray(dead_feat), stale_bounds, bounds)
    # NaN-safe keep: a non-finite bound certifies nothing — keep the feature
    keep = ~(bounds < tau)
    if obs_trace.enabled():
        obs_trace.complete("stream.screen", _tt0, time.perf_counter(),
                           live=int(np.count_nonzero(live)),
                           chunks=int(fc.n_chunks), skip=bool(skip))
    return keep, bounds, anchor, live


def _pallas_step(fc, y_key, y, lam1, lam2, theta1, delta, cache, live, skip):
    """Pure-VI chunk loop through the fused TPU bound kernel, with the same
    live gating as the XLA route. One transfer per live chunk serves both
    the fused bounds and the ``d_theta`` cache refresh."""
    from repro.kernels.ops import screen_bounds_op

    from .chunked import CsrChunk

    yt = y * theta1
    bounds_parts, d_parts = [], []
    for i, c in enumerate(fc.chunks):
        s, e = fc.chunk_bounds(i)
        if not live[i] and skip:
            fc._bump("chunks_skipped")
            bounds_parts.append(jnp.zeros((e - s,), fc.dtype))  # stamped over
            d_parts.append(cache.d_theta_slice(i))
            continue
        dense = c.to_dense(fc.dtype) if isinstance(c, CsrChunk) else c
        dense = np.asarray(dense, fc.dtype)
        fc._bump("puts")
        fc._bump("chunks_streamed")
        fc._bump("bytes_put", dense.nbytes)
        fc.stats["max_put_rows"] = max(fc.stats["max_put_rows"],
                                       dense.shape[0])
        obs_metrics.gauge("stream.max_put_rows").set_max(dense.shape[0])
        dev = jnp.asarray(dense)
        bounds_parts.append(screen_bounds_op(dev, y, lam1, lam2, theta1,
                                             delta=delta))
        d_parts.append(row_dot(dev, yt) if live[i] else cache.d_theta_slice(i))
    anchor = anchor_stats(y, lam1, theta1, delta, jnp.concatenate(d_parts))
    cache.refresh(anchor, live=set(int(i) for i in np.nonzero(live)[0]))
    return anchor, jnp.concatenate(bounds_parts)


def screen_stack_stream(
    fc: FeatureChunked,
    y,
    lam2,
    anchors,
    rules,
    tau: float = SAFE_TAU,
) -> tuple[jax.Array, jax.Array]:
    """Rule-program stack screening over chunked storage.

    Generalizes :func:`screen_stream` from the hard-coded VI bound to any
    stack of scan-lowerable rule programs (``rules`` is a tuple of names in
    :data:`~repro.core.rules.programs.PROGRAMS`): the theta-independent
    reductions come from the memoized :func:`fixed_reductions`, ``anchors``
    are :func:`stream_anchor_stats` pytrees (oldest first — a two-anchor
    program consumes the last two), and the bound finalizers are pure
    per-feature arithmetic, so nothing here streams X again. XLA route
    only; the fused Pallas chunk kernel stays VI-only (``screen_stream``),
    which the host driver uses for the pure-VI fast path anyway.
    """
    progs = tuple(PROGRAMS[nm] for nm in rules)
    d_one, d_y, d_sq = fixed_reductions(fc, y)
    fixed = fixed_stats(jnp.asarray(y, fc.dtype), d_one, d_y, d_sq)
    bounds = stack_bounds(progs, lam2, anchors, fixed)
    # NaN-safe keep: a non-finite bound certifies nothing — keep the feature
    return ~(bounds < tau), bounds


def lambda_max_stream(fc: FeatureChunked, y) -> jax.Array:
    """``|| X (y - mean y) ||_inf`` without an in-core X (cf. dual.lambda_max).

    A max of per-chunk maxima is exact (max is associative), and the
    per-chunk moment rows ride the same row-stable kernel as
    ``dual.lambda_max`` — so on dense chunks this matches the in-core value
    **bitwise**, and both storages walk identical default lambda grids.
    """
    y = jnp.asarray(y, fc.dtype)
    v = y - jnp.mean(y)
    best = jnp.asarray(0.0, fc.dtype)
    for (_, _), dev in fc.stream():
        moment = row_dot(dev, v) if isinstance(dev, jnp.ndarray) else dev @ v
        best = jnp.maximum(best, jnp.max(jnp.abs(moment)))
    return best
