"""Chunk-accumulated safe-screening bound sweep over :class:`FeatureChunked`.

The paper's O(mn) screen reduces each feature row independently (the four
per-feature reductions of ``core/screening.py``), so it streams perfectly:
sweep one feature chunk at a time, concatenate the per-chunk reductions, and
finalize with the same closed-form bound — the device never holds more than
one chunk of ``X``.

Bitwise contract
----------------
For dense chunks the per-chunk reduction is the *same jitted row-stable
kernel* (``core/screening._row_stable_reductions`` / ``row_dot``) the
in-core sweep uses, and row-stable reductions are invariant to the leading
row count — so ``screen_stream`` on any chunking returns **bitwise** the
bounds of ``core/screening.screen_bounds`` on the dense matrix (asserted in
``tests/test_sparse_stream.py``). BCOO chunks (low-density CSR) use sparse
matvecs instead — FLOPs proportional to ``nnz`` — which reassociate the
reduction; they carry a tolerance guarantee, and screening *safety* is
unaffected either way (the tau margin absorbs ulp noise by design).

Per-chunk Pallas route: ``use_pallas=True`` sends each dense chunk through
the fused TPU bound kernel (``kernels/ops.screen_bounds_op``) instead — the
bound finalizer is per-row, so evaluating it per chunk with the globally
shared scalars is exact. fp32 kernel accumulation makes this a tolerance
route too; default policy is Mosaic-on-TPU, XLA elsewhere.

Theta-independent caching (paper Sec. 6.4): ``d_one``, ``d_y``, ``d_sq``
do not depend on the anchor, so a path driver screens T lambdas with
``T + 1`` streams of X, not ``4T`` — :func:`fixed_reductions` computes them
once and memoizes on the container.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.rules.programs import PROGRAMS, stack_bounds
from repro.core.screening import (
    SAFE_TAU,
    AnchorStats,
    FeatureReductions,
    _finalize_bounds,
    _row_stable_reductions,
    anchor_stats,
    fixed_stats,
    row_dot,
    shared_scalars,
)

from .chunked import FeatureChunked

__all__ = [
    "fixed_reductions",
    "stream_feature_reductions",
    "stream_anchor_stats",
    "screen_bounds_stream",
    "screen_stream",
    "screen_stack_stream",
    "lambda_max_stream",
]

_FIXED_CACHE = "_fixed_reductions"


def fixed_reductions(fc: FeatureChunked, y) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``(d_one, d_y, d_sq)`` for every feature, streamed once and memoized.

    The cache is keyed on the identity of the *caller's* ``y`` object — not
    the dtype-converted copy, which would be fresh every call and silently
    turn the "T + 1 streams per path" contract into 2T+ (one dataset per
    container is the expected usage; a different ``y`` object recomputes).
    """
    cached = getattr(fc, _FIXED_CACHE, None)
    if cached is not None and cached[0] is y:
        return cached[1]
    y_key = y
    y = jnp.asarray(y, fc.dtype)
    d_one, d_y, d_sq = [], [], []
    for (_, _), dev in fc.stream():
        if isinstance(dev, jnp.ndarray):
            _, o, dy, sq = _row_stable_reductions(dev, y, y)
            d_one.append(o), d_y.append(dy), d_sq.append(sq)
        else:  # BCOO: sparse matvecs + data-side row norms
            d_one.append(dev @ y)
            d_y.append(dev @ jnp.ones_like(y))
            d_sq.append(_bcoo_row_sq(dev))
    out = (jnp.concatenate(d_one), jnp.concatenate(d_y), jnp.concatenate(d_sq))
    setattr(fc, _FIXED_CACHE, (y_key, out))
    return out


@functools.partial(jax.jit, static_argnums=(2,))
def _bcoo_row_sq_impl(data, rows, n_rows):
    return jax.ops.segment_sum(data * data, rows, num_segments=n_rows)


def _bcoo_row_sq(dev) -> jax.Array:
    """``||f_j||^2`` of a BCOO chunk from its data (nnz work, no densify)."""
    return _bcoo_row_sq_impl(dev.data, dev.indices[:, 0], int(dev.shape[0]))


def stream_feature_reductions(fc: FeatureChunked, y, theta1) -> FeatureReductions:
    """The four screening reductions for every feature, one stream of X."""
    # cache first, with the caller's y object (see fixed_reductions), then
    # convert for the local arithmetic
    d_one, d_y, d_sq = fixed_reductions(fc, y)
    y = jnp.asarray(y, fc.dtype)
    theta1 = jnp.asarray(theta1, fc.dtype)
    yt = y * theta1
    parts = []
    for (_, _), dev in fc.stream():
        parts.append(row_dot(dev, yt) if isinstance(dev, jnp.ndarray)
                     else dev @ yt)
    return FeatureReductions(d_theta=jnp.concatenate(parts), d_one=d_one,
                             d_y=d_y, d_sq=d_sq)


def screen_bounds_stream(
    fc: FeatureChunked,
    y,
    lam1,
    lam2,
    theta1,
    delta=0.0,
    use_pallas: Optional[bool] = None,
) -> jax.Array:
    """Upper bound on ``|fhat_j^T theta*(lam2)|``, chunk-streamed.

    XLA route (default off-TPU): per-chunk row-stable reductions + the
    shared jitted finalizer — bitwise vs the in-core sweep on dense chunks.
    Pallas route: per-chunk fused bound kernel (TPU hot path).
    """
    from repro.kernels.ops import fista_use_pallas  # lazy: no import cycle

    if fista_use_pallas(use_pallas):
        from repro.kernels.ops import screen_bounds_op

        from .chunked import CsrChunk

        y = jnp.asarray(y, fc.dtype)
        theta1 = jnp.asarray(theta1, fc.dtype)
        parts = []
        # iterate the host chunks directly (densifying CSR ones) rather
        # than fc.stream(): the fused kernel needs dense input, and going
        # through stream() would build-and-discard a BCOO per sparse chunk
        # — a second transfer the stats would record as the one used
        for i, c in enumerate(fc.chunks):
            dense = c.to_dense(fc.dtype) if isinstance(c, CsrChunk) else c
            rows = dense.shape[0]
            fc.stats["puts"] += 1
            fc.stats["max_put_rows"] = max(fc.stats["max_put_rows"], rows)
            parts.append(screen_bounds_op(jnp.asarray(dense, fc.dtype), y,
                                          lam1, lam2, theta1, delta=delta))
        return jnp.concatenate(parts)

    red = stream_feature_reductions(fc, y, theta1)
    sh = shared_scalars(jnp.asarray(y, fc.dtype), lam1, lam2,
                        jnp.asarray(theta1, fc.dtype), delta=delta)
    return _finalize_bounds(red, sh)


def screen_stream(
    fc: FeatureChunked,
    y,
    lam1,
    lam2,
    theta1,
    tau: float = SAFE_TAU,
    delta=0.0,
    use_pallas: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Safe screening over chunked storage: ``(keep_mask, bounds)``."""
    bounds = screen_bounds_stream(fc, y, lam1, lam2, theta1, delta=delta,
                                  use_pallas=use_pallas)
    return bounds >= tau, bounds


def stream_anchor_stats(fc: FeatureChunked, y, lam1, theta1,
                        delta=0.0) -> AnchorStats:
    """:class:`~repro.core.screening.AnchorStats` from ONE stream of X.

    The only chunk-streamed component is the per-feature ``d_theta`` sweep
    (same row-stable kernel as :func:`stream_feature_reductions`); the
    anchor scalars are in-core reductions of ``theta1``/``y``. Callers that
    evaluate multi-anchor stacks (dvi) should hold on to the returned
    pytree — re-using last step's anchor costs zero extra streams.
    """
    y = jnp.asarray(y, fc.dtype)
    theta1 = jnp.asarray(theta1, fc.dtype)
    yt = y * theta1
    parts = [row_dot(dev, yt) if isinstance(dev, jnp.ndarray) else dev @ yt
             for (_, _), dev in fc.stream()]
    return anchor_stats(y, lam1, theta1, delta, jnp.concatenate(parts))


def screen_stack_stream(
    fc: FeatureChunked,
    y,
    lam2,
    anchors,
    rules,
    tau: float = SAFE_TAU,
) -> tuple[jax.Array, jax.Array]:
    """Rule-program stack screening over chunked storage.

    Generalizes :func:`screen_stream` from the hard-coded VI bound to any
    stack of scan-lowerable rule programs (``rules`` is a tuple of names in
    :data:`~repro.core.rules.programs.PROGRAMS`): the theta-independent
    reductions come from the memoized :func:`fixed_reductions`, ``anchors``
    are :func:`stream_anchor_stats` pytrees (oldest first — a two-anchor
    program consumes the last two), and the bound finalizers are pure
    per-feature arithmetic, so nothing here streams X again. XLA route
    only; the fused Pallas chunk kernel stays VI-only (``screen_stream``),
    which the host driver uses for the pure-VI fast path anyway.
    """
    progs = tuple(PROGRAMS[nm] for nm in rules)
    d_one, d_y, d_sq = fixed_reductions(fc, y)
    fixed = fixed_stats(jnp.asarray(y, fc.dtype), d_one, d_y, d_sq)
    bounds = stack_bounds(progs, lam2, anchors, fixed)
    return bounds >= tau, bounds


def lambda_max_stream(fc: FeatureChunked, y) -> jax.Array:
    """``|| X (y - mean y) ||_inf`` without an in-core X (cf. dual.lambda_max).

    A max of per-chunk maxima is exact (max is associative), and the
    per-chunk moment rows ride the same row-stable kernel as
    ``dual.lambda_max`` — so on dense chunks this matches the in-core value
    **bitwise**, and both storages walk identical default lambda grids.
    """
    y = jnp.asarray(y, fc.dtype)
    v = y - jnp.mean(y)
    best = jnp.asarray(0.0, fc.dtype)
    for (_, _), dev in fc.stream():
        moment = row_dot(dev, v) if isinstance(dev, jnp.ndarray) else dev @ v
        best = jnp.maximum(best, jnp.max(jnp.abs(moment)))
    return best
