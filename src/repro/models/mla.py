"""Multi-head Latent Attention (DeepSeek-V2): low-rank compressed KV cache.

Prefill materializes per-head K/V from the compressed latent and runs the
blockwise flash path. Decode uses the *absorbed* formulation: the k-up
projection is folded into the query so attention scores are computed directly
against the (B, S, kv_lora) latent cache + the shared rope key — the cache is
``kv_lora + rope_dim`` floats per token instead of ``2*H*hd`` (the paper's
~24x KV memory saving; visible in the roofline memory term).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import NEG_INF, _sdpa_chunked
from .layers import apply_rope, dense_init


def init_mla(key, cfg, dtype):
    D = cfg.d_model
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    L, R = cfg.mla_kv_lora, cfg.mla_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], D, H * (hd + R), dtype),
        "w_dkv": dense_init(ks[1], D, L, dtype),
        "w_krope": dense_init(ks[2], D, R, dtype),
        "k_up": dense_init(ks[3], L, H * hd, dtype),
        "v_up": dense_init(ks[4], L, H * hd, dtype),
        "wo": dense_init(ks[5], H * hd, D, dtype),
    }


def _project_q(params, x, cfg, positions, act_dtype):
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    R = cfg.mla_rope_dim
    q = (x @ params["wq"].astype(act_dtype)).reshape(B, S, H, hd + R)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(params, x, cfg, positions, act_dtype=jnp.bfloat16):
    """Train/prefill path. Returns (out, (c_kv, k_rope)) for the cache."""
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    R = cfg.mla_rope_dim

    q_nope, q_rope = _project_q(params, x, cfg, positions, act_dtype)
    c_kv = x @ params["w_dkv"].astype(act_dtype)                    # (B,S,L)
    k_rope = (x @ params["w_krope"].astype(act_dtype))[:, :, None, :]  # (B,S,1,R)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)

    # materialized path (prefill): per-head K/V from the latent
    k_nope = (c_kv @ params["k_up"].astype(act_dtype)).reshape(B, S, H, hd)
    v = (c_kv @ params["v_up"].astype(act_dtype)).reshape(B, S, H, hd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, R))], axis=-1)
    out = _sdpa_chunked(
        q, k, v, positions, positions, causal=True, window=0,
        q_chunk=cfg.blockwise_q, kv_chunk=cfg.blockwise_kv,
        unroll=cfg.unroll_segments,
    )
    out = out.reshape(B, S, H * hd) @ params["wo"].astype(act_dtype)
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(params, x, cfg, positions, c_cache, r_cache, cache_pos,
               act_dtype=jnp.bfloat16):
    """Absorbed single-token decode against the latent cache.

    c_cache: (B, W, L) latent; r_cache: (B, W, R) shared rope key.
    """
    B = x.shape[0]
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    L, R = cfg.mla_kv_lora, cfg.mla_rope_dim

    q_nope, q_rope = _project_q(params, x, cfg, positions[:, None], act_dtype)
    c_new = x[:, 0] @ params["w_dkv"].astype(act_dtype)             # (B,L)
    r_new = apply_rope(
        (x @ params["w_krope"].astype(act_dtype))[:, :, None, :],
        positions[:, None], cfg.rope_theta)[:, 0, 0]                # (B,R)

    W = c_cache.shape[1]
    oh = jax.nn.one_hot(cache_pos, W, dtype=c_cache.dtype)          # (B,W)
    c_cache = c_cache * (1 - oh[..., None]) + oh[..., None] * c_new[:, None]
    r_cache = r_cache * (1 - oh[..., None]) + oh[..., None] * r_new[:, None]

    # absorb k_up into q: q_lat (B,H,L)
    k_up = params["k_up"].astype(act_dtype).reshape(L, H, hd)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], k_up)
    scale = 1.0 / jnp.sqrt(hd + R)
    s = jnp.einsum("bhl,bwl->bhw", q_lat.astype(jnp.float32),
                   c_cache.astype(jnp.float32))
    s += jnp.einsum("bhr,bwr->bhw", q_rope[:, 0].astype(jnp.float32),
                    r_cache.astype(jnp.float32))
    s *= scale
    valid = jnp.arange(W)[None, :] <= positions[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhw,bwl->bhl", p, c_cache.astype(jnp.float32))  # (B,H,L)
    v_up = params["v_up"].astype(act_dtype).reshape(L, H, hd)
    out = jnp.einsum("bhl,lhd->bhd", ctx.astype(act_dtype), v_up)
    out = out.reshape(B, 1, H * hd) @ params["wo"].astype(act_dtype)
    return out, c_cache, r_cache
