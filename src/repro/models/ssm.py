"""Mamba-2 (SSD — state-space duality) block, TPU-adapted.

Chunked SSD: the sequence is split into chunks of ``ssm_chunk``; intra-chunk
interactions are a masked (decay-weighted) quadratic form computed on the MXU,
inter-chunk interactions flow through a tiny (nh, P, N) state carried by a
lax.scan over chunks — the standard linear-in-S / matmul-rich formulation
from the SSD paper, which is exactly the right shape for a systolic array
(contrast the original CUDA selective-scan kernel: warp-level scans do not
map to TPU; the chunked dual does — see DESIGN.md hardware-adaptation notes).

Single-token decode is the O(1) recurrence on the (B, nh, P, N) state, which
is why this family is eligible for the 500k-token long-context cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm
from .sharding import logical_constraint as _lc


def init_ssm(key, cfg, dtype):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    N = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * N
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], D, 2 * d_in + 2 * N + nh, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32) + jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], d_in, D, dtype),
    }


def _split_proj(params, x, cfg, act_dtype):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    N = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    zxbcdt = x @ params["in_proj"].astype(act_dtype)
    z = _lc(zxbcdt[..., :d_in], "batch", None, "ffn")
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * N]
    dt = zxbcdt[..., -nh:]
    return z, xbc, dt, d_in, N, nh


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv, width K. xbc: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype) for i in range(K))
    new_state = xp[:, -(K - 1):]
    return jax.nn.silu(out + b.astype(xbc.dtype)), new_state


def ssd_chunked(xh, dt, A, Bm, Cm, chunk, init_state=None, unroll=False):
    """Chunked SSD core.

    xh: (B,S,nh,P) inputs; dt: (B,S,nh) softplus'd step; A: (nh,) < 0;
    Bm/Cm: (B,S,N) shared across heads (n_groups=1).
    Returns (y: (B,S,nh,P), final_state: (B,nh,P,N)).
    """
    Bsz, S, nh, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert S % Q == 0, (S, Q)

    la = (dt * A[None, None, :]).reshape(Bsz, nc, Q, nh)      # log a_t (<0)
    xc = xh.reshape(Bsz, nc, Q, nh, P)
    dtc = dt.reshape(Bsz, nc, Q, nh)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    cum = jnp.cumsum(la, axis=2)                               # L_t within chunk
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,Q_t,Q_s,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # clamp BEFORE exp: for s > t the exponent is positive and can overflow
    # fp32, which would poison gradients through the where (NaN trap).
    seg = jnp.where(causal, seg, -60.0)
    decay = jnp.exp(seg) * causal

    # intra-chunk: y[t] = sum_s C_t.B_s decay(t,s) dt_s x_s
    cb = jnp.einsum("bctn,bcsn->bcts", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    m = cb[..., None] * decay * dtc[:, :, None, :, :]          # (B,nc,t,s,nh)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", m, xc.astype(jnp.float32))

    # per-chunk aggregated state contribution: sum_s exp(L_Q - L_s) dt_s B_s x_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dtc              # (B,nc,Q,nh)
    sc = jnp.einsum("bcsh,bcsn,bcshp->bchpn", tail, Bc.astype(jnp.float32),
                    xc.astype(jnp.float32))

    # inter-chunk scan of the (nh,P,N) state
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,nc,nh)
    s0 = jnp.zeros((Bsz, nh, P, N), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def step(state, inp):
        dk, sck = inp                                          # (B,nh), (B,nh,P,N)
        prev = state
        new = state * dk[:, :, None, None] + sck
        return new, prev

    (final_state, prevs) = jax.lax.scan(
        step, s0, (chunk_decay.transpose(1, 0, 2), sc.transpose(1, 0, 2, 3, 4)),
        unroll=unroll)
    prev_states = prevs.transpose(1, 0, 2, 3, 4)               # (B,nc,nh,P,N)

    # inter-chunk output: C_t exp(L_t) S_prev
    inter_w = jnp.exp(cum)                                     # (B,nc,Q,nh)
    y_inter = jnp.einsum("bctn,bchpn,bcth->bcthp", Cc.astype(jnp.float32),
                         prev_states, inter_w)
    y = (y_intra + y_inter).reshape(Bsz, S, nh, P)
    return y, final_state


def ssm_forward(params, x, cfg, conv_state=None, ssd_state=None, act_dtype=jnp.bfloat16):
    """Full-sequence Mamba-2 block. Returns (out, (conv_state, ssd_state))."""
    B, S, D = x.shape
    z, xbc, dt, d_in, N, nh = _split_proj(params, x, cfg, act_dtype)
    P = cfg.ssm_head_dim

    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xh = xbc[..., :d_in].reshape(B, S, nh, P)
    Bm = xbc[..., d_in:d_in + N]
    Cm = xbc[..., d_in + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, new_state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk, ssd_state,
                               unroll=cfg.unroll_segments)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(act_dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    return y @ params["out_proj"].astype(act_dtype), (new_conv, new_state)


def ssm_decode(params, x, cfg, conv_state, ssd_state, act_dtype=jnp.bfloat16):
    """O(1) single-token step. x: (B,1,D)."""
    B = x.shape[0]
    z, xbc, dt, d_in, N, nh = _split_proj(params, x, cfg, act_dtype)
    P = cfg.ssm_head_dim

    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xh = xbc[:, 0, :d_in].reshape(B, nh, P)
    Bm = xbc[:, 0, d_in:d_in + N]
    Cm = xbc[:, 0, d_in + N:]

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,nh)
    a = jnp.exp(dt * (-jnp.exp(params["A_log"]))[None, :])                  # (B,nh)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32),
                     xh.astype(jnp.float32))
    new_state = ssd_state.astype(jnp.float32) * a[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), new_state)
    y = y + params["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, 1, d_in).astype(act_dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    return y @ params["out_proj"].astype(act_dtype), (new_conv, new_state)
