"""Architecture configuration for the assigned model zoo.

One frozen dataclass describes every family (dense / GQA / MLA+MoE / SSM /
hybrid / enc-dec / vlm); per-arch modules in ``repro/configs`` instantiate it
with the exact public hyper-parameters. ``input_specs`` produces
ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# shapes assigned to the LM family (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------
SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False
    gated_mlp: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden
    moe_num_shared: int = 0         # shared (always-on) experts
    moe_dense_ff: int = 0           # parallel dense residual FFN (arctic)
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 2048      # dispatch group along sequence

    # MLA (deepseek)
    mla_kv_lora: int = 0
    mla_rope_dim: int = 64

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # hybrid (recurrentgemma): layer pattern, local-attention window
    hybrid_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    attn_window: int = 0                    # 0 = global attention
    rnn_width: int = 0                      # RG-LRU recurrence width

    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500                     # precomputed audio frames (stub)

    # vlm
    num_prefix_tokens: int = 0              # precomputed patch embeds (stub)

    # numerics / memory policy
    dtype: str = "bfloat16"                 # compute/activation dtype
    param_dtype: str = "float32"            # master params
    remat: str = "full"                     # none | full (per layer)
    unroll_segments: bool = False           # python-loop layers (accurate HLO
                                            # cost analysis: scan bodies are
                                            # counted once by XLA)
    loss_chunk: int = 0                     # >0: compute CE over sequence
                                            # chunks (never materialize the
                                            # full [B,S,V] logits tensor)
    gqa_grouped: bool = False               # baseline-only: grouped (G, rep)
                                            # attention layout (unshardable
                                            # when G < model-axis; kept for
                                            # §Perf before/after runs)
    moe_combine_f32: bool = False           # baseline-only: fp32 combine
                                            # tensor (2x MoE activation bytes)
    attn_probs_bf16: bool = False           # §Perf iter 4: bf16 softmax
                                            # probabilities (fp32 row stats /
                                            # accumulators stay) — halves the
                                            # attention-chain bytes
    blockwise_q: int = 1024                 # flash-style q-chunk for long seq
    blockwise_kv: int = 1024

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded for lane alignment + 16-way TP divisibility."""
        return _round_up(self.vocab_size, 128)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state => eligible for long_500k."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an AR decoder (whisper: dec side)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        H, G = self.num_heads, self.num_kv_heads
        hd = self.resolved_head_dim
        per_layer = 0
        if self.family == "ssm":
            d_in = self.ssm_expand * D
            nh = d_in // self.ssm_head_dim
            # in_proj (z,x,B,C,dt) + conv + out_proj
            per_layer = D * (2 * d_in + 2 * self.ssm_state + nh) \
                + self.ssm_conv * (d_in + 2 * self.ssm_state) \
                + d_in * D + 2 * D
        else:
            if self.mla_kv_lora:
                qd = H * (hd + self.mla_rope_dim)
                per_layer += D * qd
                per_layer += D * (self.mla_kv_lora + self.mla_rope_dim)
                per_layer += self.mla_kv_lora * (2 * H * hd)
                per_layer += H * hd * D
            else:
                per_layer += D * (H + 2 * G) * hd + H * hd * D
                if self.qkv_bias:
                    per_layer += (H + 2 * G) * hd
            if self.moe_num_experts:
                per_layer += D * self.moe_num_experts
                e_ff = self.moe_d_ff
                mult = 3 if self.gated_mlp else 2
                per_layer += self.moe_num_experts * mult * D * e_ff
                per_layer += self.moe_num_shared * mult * D * e_ff
                if self.moe_dense_ff:
                    per_layer += mult * D * self.moe_dense_ff
            elif F:
                per_layer += (3 if self.gated_mlp else 2) * D * F
            per_layer += 2 * D  # norms
        total = self.num_layers * per_layer
        if self.family == "hybrid":
            # recurrent layers replace attention with RG-LRU width-d_rnn
            n_rec = sum(1 for _ in range(self.num_layers)
                        if self.layer_kind(_) == "rec")
            d_rnn = self.rnn_width or D
            attn_cost = D * (H + 2 * G) * hd + H * hd * D
            rec_cost = 2 * D * d_rnn + 2 * d_rnn + d_rnn * D + 2 * d_rnn * self.ssm_conv
            total += n_rec * (rec_cost - attn_cost)
        total += V * D  # embeddings
        if not self.tie_embeddings:
            total += V * D
        if self.enc_layers:
            enc_per = D * 4 * hd * H // H  # rough: qkv+o
            enc_per = 4 * D * H * hd + (2 if not self.gated_mlp else 3) * D * F + 2 * D
            total += self.enc_layers * (enc_per + D * H * hd)  # + cross-kv
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if not self.moe_num_experts:
            return self.param_count()
        full = self.param_count()
        mult = 3 if self.gated_mlp else 2
        routed_all = self.num_layers * self.moe_num_experts * mult * self.d_model * self.moe_d_ff
        routed_act = self.num_layers * self.moe_top_k * mult * self.d_model * self.moe_d_ff
        return int(full - routed_all + routed_act)

    def layer_kind(self, i: int) -> str:
        """Temporal-mixing kind of layer i ('attn' | 'rec' | 'ssm')."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.hybrid_pattern:
            return self.hybrid_pattern[i % len(self.hybrid_pattern)]
        return "attn"

    def shape_skips(self) -> dict[str, str]:
        """Map of shape-name -> reason, for cells this arch does not run."""
        skips = {}
        if not self.supports_long_context:
            skips["long_500k"] = (
                "full quadratic attention; 500k decode needs sub-quadratic "
                "state (see DESIGN.md §Arch-applicability)"
            )
        return skips

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct only — never allocates)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Model inputs for a (config, shape) cell as ShapeDtypeStructs."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    i32 = jnp.int32
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if sh["kind"] == "train":
        spec = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.family == "encdec":
            spec["enc_embeds"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), act)
        if cfg.family == "vlm":
            spec["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_tokens, cfg.d_model), act)
        return spec

    if sh["kind"] == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "encdec":
            spec["enc_embeds"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), act)
        if cfg.family == "vlm":
            spec["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_tokens, cfg.d_model), act)
        return spec

    # decode: one new token against a cache of size S
    from . import cache as cache_lib  # local import to avoid cycles

    spec = {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "positions": jax.ShapeDtypeStruct((B,), i32),
        "cache": cache_lib.cache_specs(cfg, batch=B, max_seq=S),
    }
    return spec
