"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t), with
a_t = exp(c * softplus(Lambda) * (-sigmoid(W_r x_t))) — the real-gated linear
recurrent unit. Train/prefill uses ``jax.lax.associative_scan`` (log-depth,
TPU-friendly; the GPU paper uses a custom linear-scan kernel — the
associative reformulation is the TPU-native equivalent). Decode is the O(1)
per-token update on a (B, d_rnn) state.

The full Griffin block: x -> [gelu gate branch | conv1d -> RG-LRU branch]
-> elementwise merge -> out projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init
from .sharding import logical_constraint as _lc

_C = 8.0  # Griffin's recurrence sharpness constant


def init_rglru(key, cfg, dtype):
    D = cfg.d_model
    R = cfg.rnn_width or D
    ks = jax.random.split(key, 6)
    return {
        "w_gate": dense_init(ks[0], D, R, dtype),
        "w_rec_in": dense_init(ks[1], D, R, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[2], (cfg.ssm_conv, R), jnp.float32).astype(dtype),
        "conv_b": jnp.zeros((R,), dtype),
        "w_r": dense_init(ks[3], R, R, dtype, scale=0.02),
        "w_i": dense_init(ks[4], R, R, dtype, scale=0.02),
        "lam": jnp.full((R,), 2.0, jnp.float32),  # softplus(2) ~ 2.1 -> slow decay
        "out_proj": dense_init(ks[5], R, D, dtype),
    }


def _conv1d(x, w, b, state=None):
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return out + b.astype(x.dtype), xp[:, -(K - 1):]


def _gates(params, u):
    """log a_t (<=0) and scaled input for the recurrence."""
    r = jax.nn.sigmoid((u @ params["w_r"].astype(u.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_i"].astype(u.dtype)).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r        # (.., R)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    x_in = beta * i * u.astype(jnp.float32)
    return log_a, x_in


def rglru_forward(params, x, cfg, conv_state=None, h_state=None,
                  act_dtype=jnp.bfloat16):
    """Full-sequence Griffin recurrent block. Returns (out, (conv_state, h))."""
    B, S, D = x.shape
    gate = _lc(jax.nn.gelu(x @ params["w_gate"].astype(act_dtype)),
               "batch", None, "ffn")
    u = _lc(x @ params["w_rec_in"].astype(act_dtype), "batch", None, "ffn")
    u, new_conv = _conv1d(u, params["conv_w"], params["conv_b"], conv_state)

    log_a, x_in = _gates(params, u)
    a = jnp.exp(log_a)
    if h_state is not None:
        # fold the carried state into step 0's input
        x_in = x_in.at[:, 0].add(a[:, 0] * h_state.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    new_h = h[:, -1]
    y = (gate * h.astype(act_dtype)) @ params["out_proj"].astype(act_dtype)
    return y, (new_conv, new_h)


def rglru_decode(params, x, cfg, conv_state, h_state, act_dtype=jnp.bfloat16):
    """O(1) single-token step. x: (B,1,D)."""
    gate = jax.nn.gelu(x @ params["w_gate"].astype(act_dtype))
    u = x @ params["w_rec_in"].astype(act_dtype)
    u, new_conv = _conv1d(u, params["conv_w"], params["conv_b"], conv_state)
    log_a, x_in = _gates(params, u[:, 0])
    h = jnp.exp(log_a) * h_state.astype(jnp.float32) + x_in
    y = (gate[:, 0] * h.astype(act_dtype)) @ params["out_proj"].astype(act_dtype)
    return y[:, None], (new_conv, h)
