"""Attention: GQA/MQA with RoPE, blockwise (flash-style) prefill, windowed
local attention, and single-token decode against a KV cache.

No S x S score tensor is ever materialized for long sequences: prefill uses a
two-level lax.scan (query chunks x key chunks) with an online-softmax carry,
which is the TPU-friendly reformulation of flash attention in pure JAX (the
XLA scheduler pipelines the chunk loop; VMEM pressure is bounded by the
chunk sizes from the config).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init
from .sharding import logical_constraint as _lc, model_axis_size

NEG_INF = -1e30


def init_attention(key, cfg, dtype):
    D = cfg.d_model
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * hd, dtype),
        "wk": dense_init(ks[1], D, G * hd, dtype),
        "wv": dense_init(ks[2], D, G * hd, dtype),
        "wo": dense_init(ks[3], H * hd, D, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((G * hd,), dtype)
        p["bv"] = jnp.zeros((G * hd,), dtype)
    return p


def _project_qkv(params, x, cfg, positions, act_dtype):
    B, S, D = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"].astype(act_dtype)
    k = x @ params["wk"].astype(act_dtype)
    v = x @ params["wv"].astype(act_dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(act_dtype)
        k = k + params["bk"].astype(act_dtype)
        v = v + params["bv"].astype(act_dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, G, hd)
    v = v.reshape(B, S, G, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = _lc(q, "batch", None, "heads", None)
    k = _lc(k, "batch", None, "heads", None)   # no-op when G % tp != 0
    v = _lc(v, "batch", None, "heads", None)
    return q, k, v


def _sdpa_chunked(q, k, v, q_pos, k_pos, *, causal, window, q_chunk, kv_chunk,
                  unroll=False, grouped=False, probs_bf16=False):
    """Online-softmax attention. q/k: (B,S,{H,G},dk); v: (B,Sk,G,dv).

    dk may differ from dv (MLA concatenates rope dims into q/k only).
    """
    B, Sq, H, hd = q.shape
    _, Sk, G, _ = k.shape
    dv = v.shape[-1]
    rep = H // G
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    # GQA in H-space: repeating K/V to H heads keeps every attention tensor
    # shardable on the head axis — but ONLY pays off when H divides the
    # model axis (the repeat alone is pure extra bytes otherwise, measured on
    # arctic's 56 heads: +1.8x memory term). Adaptive: repeat iff sharding is
    # actually unlocked; explicit score constraints do the placement
    # (EXPERIMENTS.md §Perf iterations 1/1b).
    tp = model_axis_size()
    use_hspace = (rep > 1 and not grouped and tp > 0
                  and H % tp == 0 and G % tp != 0)
    if use_hspace:
        k = _lc(jnp.repeat(k, rep, axis=2), "batch", None, "heads", None)
        v = _lc(jnp.repeat(v, rep, axis=2), "batch", None, "heads", None)
    elif rep > 1:
        return _sdpa_grouped_baseline(q, k, v, q_pos, k_pos, causal=causal,
                                      window=window, q_chunk=q_chunk,
                                      kv_chunk=kv_chunk, unroll=unroll)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = (Sq + q_chunk - 1) // q_chunk
    nk = (Sk + kv_chunk - 1) // kv_chunk
    # pad to chunk multiples
    q = _pad_axis(q, nq * q_chunk, 1)
    k = _pad_axis(k, nk * kv_chunk, 1)
    v = _pad_axis(v, nk * kv_chunk, 1)
    q_pos = _pad_axis(q_pos, nq * q_chunk, 1, fill=-1)       # (B, Sq)
    k_pos = _pad_axis(k_pos, nk * kv_chunk, 1, fill=2**30)   # (B, Sk)

    qc = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 3, 2, 4)   # (nq,B,H,qc,hd)
    kc = k.reshape(B, nk, kv_chunk, H, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nk, kv_chunk, H, dv).transpose(1, 0, 3, 2, 4)
    qpc = q_pos.reshape(B, nq, q_chunk).transpose(1, 0, 2)
    kpc = k_pos.reshape(B, nk, kv_chunk).transpose(1, 0, 2)

    def q_step(_, q_blk):
        qi, qp = q_blk       # (B,H,qc,hd), (B,qc)
        qi32 = qi.astype(jnp.float32) * scale

        def kv_step(carry, kv_blk):
            m_prev, l_prev, acc = carry
            ki, vi, kp = kv_blk
            s = jnp.einsum("bhqd,bhkd->bhqk", qi32, ki.astype(jnp.float32))
            s = _lc(s, "batch", "heads", None, None)
            mask = jnp.ones((B, 1, q_chunk, kv_chunk), bool)
            dq = qp[:, None, :, None]
            dk = kp[:, None, None, :]
            if causal:
                mask &= dk <= dq
            if window:
                mask &= dq - dk < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            if probs_bf16:
                # §Perf iter 4: p is in [0,1] — bf16 storage halves the
                # attention-chain bytes; the PV dot still accumulates fp32.
                pv = jax.lax.dot_general(
                    p.astype(jnp.bfloat16), vi.astype(jnp.bfloat16),
                    ((( 3,), (2,)), ((0, 1), (0, 1))),
                    preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bhqk,bhkd->bhqd", p, vi.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kpc),
                                      unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(qi.dtype)

    _, out = jax.lax.scan(q_step, None, (qc, qpc), unroll=unroll)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_chunk, H, dv)
    return out[:, :Sq]


def _sdpa_grouped_baseline(q, k, v, q_pos, k_pos, *, causal, window, q_chunk,
                           kv_chunk, unroll=False):
    """Baseline grouped-(G, rep) layout — §Perf before/after reference only."""
    B, Sq, H, hd = q.shape
    _, Sk, G, _ = k.shape
    dv = v.shape[-1]
    rep = H // G
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = (Sq + q_chunk - 1) // q_chunk
    nk = (Sk + kv_chunk - 1) // kv_chunk
    q = _pad_axis(q, nq * q_chunk, 1)
    k = _pad_axis(k, nk * kv_chunk, 1)
    v = _pad_axis(v, nk * kv_chunk, 1)
    q_pos = _pad_axis(q_pos, nq * q_chunk, 1, fill=-1)
    k_pos = _pad_axis(k_pos, nk * kv_chunk, 1, fill=2**30)

    qc = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 3, 2, 4)
    kc = k.reshape(B, nk, kv_chunk, G, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, nk, kv_chunk, G, dv).transpose(1, 0, 3, 2, 4)
    qpc = q_pos.reshape(B, nq, q_chunk).transpose(1, 0, 2)
    kpc = k_pos.reshape(B, nk, kv_chunk).transpose(1, 0, 2)

    def q_step(_, q_blk):
        qi, qp = q_blk
        qg = (qi.astype(jnp.float32) * scale).reshape(B, G, rep, q_chunk, hd)

        def kv_step(carry, kv_blk):
            m_prev, l_prev, acc = carry
            ki, vi, kp = kv_blk
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, ki.astype(jnp.float32))
            mask = jnp.ones((B, 1, 1, q_chunk, kv_chunk), bool)
            dq = qp[:, None, None, :, None]
            dk = kp[:, None, None, None, :]
            if causal:
                mask &= dk <= dq
            if window:
                mask &= dq - dk < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, G, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, G, rep, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kpc),
                                      unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.reshape(B, H, q_chunk, dv).astype(qi.dtype)

    _, out = jax.lax.scan(q_step, None, (qc, qpc), unroll=unroll)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_chunk, H, dv)
    return out[:, :Sq]


def _pad_axis(x, size, axis, fill=0):
    if x.shape[axis] == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, size - x.shape[axis])
    return jnp.pad(x, pads, constant_values=fill)


def attention_forward(
    params, x, cfg, positions, *, cache=None, cache_index=None, act_dtype=jnp.bfloat16
):
    """Full-sequence attention (train / prefill).

    Returns (out, new_kv) where new_kv = (k, v) for cache construction.
    """
    q, k, v = _project_qkv(params, x, cfg, positions, act_dtype)
    out = _sdpa_chunked(
        q, k, v, positions, positions,
        causal=True, window=cfg.attn_window,
        q_chunk=cfg.blockwise_q, kv_chunk=cfg.blockwise_kv,
        unroll=cfg.unroll_segments, grouped=cfg.gqa_grouped,
        probs_bf16=cfg.attn_probs_bf16,
    )
    B, S = x.shape[:2]
    out = out.reshape(B, S, -1) @ params["wo"].astype(act_dtype)
    return out, (k, v)


def attention_decode(
    params, x, cfg, positions, k_cache, v_cache, cache_pos, *, act_dtype=jnp.bfloat16
):
    """One-token decode. x: (B,1,D); k/v_cache: (B,W,G,hd) ring buffers.

    ``positions`` (B,) absolute positions; ``cache_pos`` (B,) write slot
    (== positions for full cache, positions % window for ring buffers).
    Returns (out, k_cache, v_cache).
    """
    B = x.shape[0]
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, x, cfg, positions[:, None], act_dtype)

    oh = jax.nn.one_hot(cache_pos, k_cache.shape[1], dtype=k.dtype)  # (B, W)
    k_cache = k_cache * (1.0 - oh[..., None, None]) + oh[..., None, None] * k
    v_cache = v_cache * (1.0 - oh[..., None, None]) + oh[..., None, None] * v

    rep = H // G
    tp = model_axis_size()
    use_hspace = rep > 1 and tp > 0 and H % tp == 0 and G % tp != 0
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    W = k_cache.shape[1]
    slot = jnp.arange(W)[None, :]                      # (1, W)
    if cfg.attn_window:
        written = slot < jnp.minimum(positions[:, None] + 1, W)
    else:
        written = slot <= positions[:, None]

    if use_hspace or rep == 1:  # H-space (see _sdpa_chunked sharding note)
        if rep > 1:
            kf = _lc(jnp.repeat(kf, rep, axis=2), "batch", None, "heads", None)
            vf = _lc(jnp.repeat(vf, rep, axis=2), "batch", None, "heads", None)
        qh = (q.astype(jnp.float32) / jnp.sqrt(hd))[:, 0]  # (B,H,hd)
        s = _lc(jnp.einsum("bhd,bkhd->bhk", qh, kf), "batch", "heads", None)
        s = jnp.where(written[:, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhk,bkhd->bhd", p, vf)
    else:  # grouped decode (H not shardable anyway — skip the repeat bytes)
        qg = (q.astype(jnp.float32) / jnp.sqrt(hd))[:, 0].reshape(B, G, rep, hd)
        s = jnp.einsum("bgrd,bkgd->bgrk", qg, kf)
        s = jnp.where(written[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bgrk,bkgd->bgrd", p, vf).reshape(B, H, hd)
    out = out.reshape(B, 1, H * hd).astype(act_dtype) @ params["wo"].astype(act_dtype)
    return out, k_cache, v_cache
