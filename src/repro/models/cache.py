"""Decode-cache layout per architecture family.

The cache is a pytree mirroring the layer-stack segment structure (see
transformer.py): ``{"segments": [ {"s{i}": stacked-cache-per-slot} ]}`` plus
an optional encoder cross-attention cache for enc-dec models.

Per-slot caches:
  attn : k/v ring buffers  (B, W, G, hd)   W = min(attn_window or S, S)
  mla  : latent + rope key (B, S, L) / (B, S, R)   [the MLA memory win]
  ssm  : conv tail (B, K-1, Cdim) + SSD state (B, nh, P, N)
  rec  : conv tail (B, K-1, R)   + RG-LRU state (B, R)

SSM/rec states are fp32 (recurrences are numerically touchy); K/V and
latents are bf16 (matches production serving).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def segments_of(cfg):
    """[(pattern_tuple, n_units)] decomposition of the layer stack."""
    if cfg.family == "hybrid" and cfg.hybrid_pattern:
        p = len(cfg.hybrid_pattern)
        n_units, rem = divmod(cfg.num_layers, p)
        segs = []
        if n_units:
            segs.append((tuple(cfg.hybrid_pattern), n_units))
        if rem:
            segs.append((tuple(cfg.hybrid_pattern[:rem]), 1))
        return segs
    kind = {"ssm": "ssm"}.get(cfg.family, "attn")
    if cfg.family == "moe" and cfg.mla_kv_lora:
        kind = "mla"
    return [((kind,), cfg.num_layers)]


def _slot_cache_spec(cfg, kind, batch, max_seq, make):
    B = batch
    bf16, f32 = jnp.bfloat16, jnp.float32
    G, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if kind == "attn":
        W = min(cfg.attn_window, max_seq) if cfg.attn_window else max_seq
        c = {"k": make((B, W, G, hd), bf16), "v": make((B, W, G, hd), bf16)}
        if cfg.family == "encdec":
            c["ck"] = make((B, cfg.enc_seq, G, hd), bf16)
            c["cv"] = make((B, cfg.enc_seq, G, hd), bf16)
        return c
    if kind == "mla":
        return {
            "c": make((B, max_seq, cfg.mla_kv_lora), bf16),
            "r": make((B, max_seq, cfg.mla_rope_dim), bf16),
        }
    if kind == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        conv_dim = d_in + 2 * cfg.ssm_state
        return {
            "conv": make((B, cfg.ssm_conv - 1, conv_dim), bf16),
            "state": make((B, nh, cfg.ssm_head_dim, cfg.ssm_state), f32),
        }
    if kind == "rec":
        R = cfg.rnn_width or cfg.d_model
        return {
            "conv": make((B, cfg.ssm_conv - 1, R), bf16),
            "h": make((B, R), f32),
        }
    raise ValueError(kind)


def _build(cfg, batch, max_seq, make):
    segs = []
    for pattern, n_units in segments_of(cfg):
        slots = {}
        for si, kind in enumerate(pattern):
            spec = _slot_cache_spec(cfg, kind, batch, max_seq, make)
            slots[f"s{si}"] = jax.tree_util.tree_map(
                lambda s: _stack(s, n_units, make), spec)
        segs.append(slots)
    return {"segments": segs}


def _stack(leaf, n, make):
    if isinstance(leaf, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct((n, *leaf.shape), leaf.dtype)
    return jnp.broadcast_to(leaf[None], (n, *leaf.shape)).copy()


def cache_specs(cfg, batch: int, max_seq: int):
    """ShapeDtypeStruct cache pytree (dry-run; no allocation)."""
    return _build(cfg, batch, max_seq, _struct)


def init_cache(cfg, batch: int, max_seq: int):
    """Zero-initialized cache (real serving)."""
    def make(shape, dtype):
        return jnp.zeros(shape, dtype)
    return _build(cfg, batch, max_seq, make)
