"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch/combine
einsums (GShard/Switch style), shared experts (DeepSeek) and a parallel dense
residual FFN (Arctic).

Expert-parallel layout: the expert dimension E shards over the "model" mesh
axis (EP); dispatch/combine tensors carry E so all heavy per-expert compute
and the dispatch one-hots stay local to the expert shard — the all-to-all is
expressed implicitly by XLA through the (tokens -> experts -> tokens)
einsum resharding.

Memory discipline: tokens are routed in groups of ``moe_group_size`` along
the sequence so the [T, E, C] combine tensor stays bounded; capacity
C = ceil(group * top_k / E * capacity_factor), rounded up to a multiple of 4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init
from .sharding import logical_constraint as _lc


def init_moe(key, cfg, dtype):
    D = cfg.d_model
    E, Fe = cfg.moe_num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], D, E, dtype, scale=0.02),
        "wi": jax.vmap(lambda k: dense_init(k, D, Fe, dtype))(jax.random.split(ks[1], E)),
        "wg": jax.vmap(lambda k: dense_init(k, D, Fe, dtype))(jax.random.split(ks[2], E)),
        "wo": jax.vmap(lambda k: dense_init(k, Fe, D, dtype))(jax.random.split(ks[3], E)),
    }
    if cfg.moe_num_shared:
        Fs = cfg.moe_num_shared * Fe
        p["shared"] = {
            "wi": dense_init(ks[4], D, Fs, dtype),
            "wg": dense_init(jax.random.fold_in(ks[4], 1), D, Fs, dtype),
            "wo": dense_init(jax.random.fold_in(ks[4], 2), Fs, D, dtype),
        }
    if cfg.moe_dense_ff:
        Fd = cfg.moe_dense_ff
        p["dense"] = {
            "wi": dense_init(ks[5], D, Fd, dtype),
            "wg": dense_init(jax.random.fold_in(ks[5], 1), D, Fd, dtype),
            "wo": dense_init(jax.random.fold_in(ks[5], 2), Fd, D, dtype),
        }
    return p


def _capacity(group: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(group * top_k / n_experts * factor) + 1
    return max(top_k, (c + 3) // 4 * 4)


def moe_forward(params, x, cfg, act_dtype=jnp.bfloat16):
    """x: (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    g_sz = min(cfg.moe_group_size, S)
    n_g = S // g_sz if S % g_sz == 0 else 1
    if S % g_sz != 0:
        g_sz = S
    C = _capacity(g_sz, k, E, cfg.moe_capacity_factor)

    xg = x.reshape(B, n_g, g_sz, D)
    logits = (xg @ params["router"].astype(act_dtype)).astype(jnp.float32)  # (B,n,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                                  # (B,n,T,k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # choice-major positions in each expert queue
    oh = jax.nn.one_hot(top_i, E, dtype=jnp.float32)                        # (B,n,T,k,E)
    ohf = oh.transpose(0, 1, 3, 2, 4).reshape(B, n_g, k * g_sz, E)          # choice-major
    pos = jnp.cumsum(ohf, axis=2) - 1.0                                     # (B,n,kT,E)
    pos = jnp.sum(pos * ohf, axis=-1).reshape(B, n_g, k, g_sz)              # (B,n,k,T)
    pos = pos.transpose(0, 1, 3, 2)                                         # (B,n,T,k)
    fits = pos < C

    # combine tensor (B,n,T,E,C) = sum over choices of gate * onehot(e) * onehot(c).
    # Built directly in bf16 (entries are disjoint gate values <= 1 — no
    # accumulation cancellation); this tensor dominates MoE activation bytes
    # (§Perf iteration 3). ``moe_combine_f32`` restores the fp32 baseline.
    cdt = jnp.float32 if cfg.moe_combine_f32 else act_dtype
    combine = jnp.zeros((B, n_g, g_sz, E, C), cdt)
    for j in range(k):
        sel = (
            jax.nn.one_hot(top_i[..., j], E, dtype=cdt)[..., :, None]
            * jax.nn.one_hot(pos[..., j].astype(jnp.int32), C, dtype=cdt)[..., None, :]
        )
        combine = combine + ((top_p[..., j] * fits[..., j])
                             .astype(cdt))[..., None, None] * sel
    combine = _lc(combine, "batch", None, None, "expert", None)
    dispatch = _lc((combine > 0).astype(act_dtype),
                   "batch", None, None, "expert", None)

    # tokens -> expert buffers (the implicit all-to-all of EP)
    xe = jnp.einsum("bntec,bntd->bnecd", dispatch, xg.astype(act_dtype))
    xe = _lc(xe, "batch", None, "expert", None, None)
    del sel  # keep the per-choice one-hots out of the live set
    wi = params["wi"].astype(act_dtype)
    wg = params["wg"].astype(act_dtype)
    wo = params["wo"].astype(act_dtype)
    h = jnp.einsum("bnecd,edf->bnecf", xe, wi)
    g = jnp.einsum("bnecd,edf->bnecf", xe, wg)
    he = jax.nn.silu(g) * h
    ye = jnp.einsum("bnecf,efd->bnecd", he, wo)
    ye = _lc(ye, "batch", None, "expert", None, None)
    # expert buffers -> tokens
    out = jnp.einsum("bntec,bnecd->bntd", combine.astype(act_dtype), ye)
    out = out.reshape(B, S, D)

    # load-balancing auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(oh.sum(3) / k, axis=(0, 1, 2))
    frac_probs = jnp.mean(probs, axis=(0, 1, 2))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    # shared experts / dense residual run on all tokens
    if "shared" in params:
        sp = params["shared"]
        h = x @ sp["wi"].astype(act_dtype)
        g = x @ sp["wg"].astype(act_dtype)
        out = out + (jax.nn.silu(g) * h) @ sp["wo"].astype(act_dtype)
    if "dense" in params:
        dp = params["dense"]
        h = x @ dp["wi"].astype(act_dtype)
        g = x @ dp["wg"].astype(act_dtype)
        out = out + (jax.nn.silu(g) * h) @ dp["wo"].astype(act_dtype)
    return out, aux
