"""Parameter/activation partitioning rules (TP + FSDP + EP).

Axes: "model" carries tensor/expert parallelism; "data" carries batch DP and
FSDP parameter sharding; "pod" (multi-pod mesh) carries pure DP — parameters
are replicated across pods so all TP collectives stay on intra-pod ICI and
only gradient all-reduce crosses the DCN.

Rules are path-pattern driven over the param pytree; any dimension whose size
is not divisible by its mesh axis falls back to replication (DESIGN.md §4
lists the archs this affects: arctic 56 heads, whisper 8 heads, kv<16).
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# logical activation constraints (MaxText-style named roles)
# ---------------------------------------------------------------------------
# Roles: "batch" -> (pod,)data axes; "heads"/"vocab"/"expert"/"ffn" -> model
# axis; None/"seq"/other -> unconstrained. Constraints apply only under an
# ambient mesh (jax.set_mesh) and only when the dim divides the axis size, so
# the same model code runs unchanged on CPU tests (no-op) and on the
# production mesh (explicit placement — §Perf iteration 1 showed that leaving
# score tensors to propagation silently replicates heavy attention tensors).

_MODEL_ROLES = ("heads", "vocab", "expert", "ffn")


def _ambient_mesh():
    """Compat: jax>=0.5 ``get_abstract_mesh``; older jax has no ambient-mesh
    API, which is indistinguishable from "no mesh set" (the no-op path)."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    return getter() if getter is not None else None


def model_axis_size() -> int:
    """Size of the ambient mesh's "model" axis (0 when no mesh is set)."""
    am = _ambient_mesh()
    if am is None or getattr(am, "empty", True) or "model" not in am.axis_names:
        return 0
    return int(dict(am.shape)["model"])


def logical_constraint(x, *roles):
    am = _ambient_mesh()
    if am is None or getattr(am, "empty", True) or "model" not in am.axis_names:
        return x
    sizes = dict(am.shape)
    ba = tuple(a for a in ("pod", "data") if a in am.axis_names)
    ba_size = int(np.prod([sizes[a] for a in ba])) if ba else 1
    assert len(roles) == x.ndim, (roles, x.shape)
    spec = []
    for role, dim in zip(roles, x.shape):
        if role == "batch" and ba and dim % ba_size == 0:
            spec.append(ba)
        elif role in _MODEL_ROLES and dim % sizes["model"] == 0:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# (path regex, spec builder) — builder returns axis names per *trailing* dim
# (the stacked layer dim, when present, is always None).
# "T" = model/tensor axis, "F" = fsdp (data) axis, "." = replicated.
_RULES: list[tuple[str, str]] = [
    (r"embed/tok$", "TF"),
    (r"head$", "FT"),
    (r"(mix|cross)/w[qkv]$", "FT"),
    (r"(mix|cross)/b[qkv]$", "T"),
    (r"(mix|cross)/wo$", "TF"),
    (r"mix/w_dkv$", "F."),          # MLA latent down-proj (small)
    (r"mix/w_krope$", "F."),
    (r"mix/[kv]_up$", ".T"),
    (r"moe/router$", "F."),
    (r"moe/w[ig]$", "TF."),         # (E, D, Fe): EP on experts
    (r"moe/wo$", "T.F"),
    (r"(shared|dense)/w[ig]$", "FT"),
    (r"(shared|dense)/wo$", "TF"),
    (r"mlp/w[ig]$", "FT"),
    (r"mlp/wo$", "TF"),
    (r"mix/in_proj$", "F."),        # mamba2 fused zxBCdt projection
    (r"mix/out_proj$", "TF"),
    (r"mix/w_(gate|rec_in)$", "FT"),
    (r"mix/w_[ri]$", ".T"),
    (r"mix/(lam|conv_b|norm_scale)$", "T"),
    (r"mix/conv_w$", ".T"),
    (r"mix/(A_log|D|dt_bias)$", "."),
    (r"(ln1|ln2|ln_x|final_norm)/(scale|bias)$", "."),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    tp = mesh.shape["model"]
    fsdp = mesh.shape["data"]
    stacked = bool(re.search(r"segments/\d+/s\d+/|encoder/layers/", path))

    code: Optional[str] = None
    for pat, c in _RULES:
        if re.search(pat, path):
            code = c
            break
    if code is None:
        return P()  # replicate unknowns

    trailing = shape[1:] if stacked else shape
    if len(code) != len(trailing):
        return P()  # rule/shape mismatch -> safe fallback

    axes = []
    for ch, dim in zip(code, trailing):
        if ch == "T" and dim % tp == 0:
            axes.append("model")
        elif ch == "F" and dim % fsdp == 0:
            axes.append("data")
        else:
            axes.append(None)
    if stacked:
        axes = [None] + axes
    return P(*axes)


def param_specs(params_tree, mesh: Mesh):
    """PartitionSpec pytree matching ``params_tree`` (arrays or SDS leaves)."""
    def fn(path, leaf):
        return _spec_for(_path_str(path), tuple(leaf.shape), mesh)
    return jax.tree_util.tree_map_with_path(fn, params_tree)


def param_shardings(params_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params_tree, mesh))


def input_sharding_specs(cfg, specs: dict, mesh: Mesh):
    """PartitionSpecs for model inputs (tokens/targets/embeds/cache)."""
    ba = batch_axes(mesh)
    ba_size = int(np.prod([mesh.shape[a] for a in ba]))

    def bspec(size):
        # shard the batch only when divisible (long_500k has batch 1)
        return ba if size % ba_size == 0 else None

    def leaf_spec(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        if p.startswith("cache/"):
            return _cache_spec(cfg, p, leaf.shape, mesh)
        if p in ("tokens", "targets"):
            return P(bspec(leaf.shape[0]), None)
        if p == "positions":
            return P(bspec(leaf.shape[0]))
        if p.endswith("embeds"):
            return P(bspec(leaf.shape[0]), None, None) if nd == 3 else P(*([None] * nd))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, specs)


def _cache_spec(cfg, path: str, shape, mesh: Mesh) -> P:
    """KV/state caches: batch over data(+pod); heads over model if divisible,
    else the sequence axis over model (distributed-KV decode)."""
    ba = batch_axes(mesh)
    ba_size = int(np.prod([mesh.shape[a] for a in ba]))
    if len(shape) >= 2 and shape[1] % ba_size != 0:
        ba = None  # batch not divisible (long_500k batch=1)
    tp = mesh.shape["model"]
    nd = len(shape)
    # stacked layer dim first, then batch
    if re.search(r"/(k|v|ck|cv)$", path) and nd == 5:   # (L, B, W, G, hd)
        G = shape[3]
        if G % tp == 0:
            return P(None, ba, None, "model", None)
        if shape[2] % tp == 0:
            return P(None, ba, "model", None, None)
        return P(None, ba, None, None, None)
    if re.search(r"/(c|r)$", path) and nd == 4:          # (L, B, S, L_lat)
        if shape[2] % tp == 0:
            return P(None, ba, "model", None)
        return P(None, ba, None, None)
    if re.search(r"/state$", path) and nd == 5:          # (L, B, nh, P, N)
        return P(None, ba, "model" if shape[2] % tp == 0 else None, None, None)
    if re.search(r"/h$", path) and nd == 3:              # (L, B, R)
        return P(None, ba, "model" if shape[2] % tp == 0 else None)
    if re.search(r"/conv$", path) and nd == 4:           # (L, B, K-1, C)
        return P(None, ba, None, "model" if shape[3] % tp == 0 else None)
    return P(*([None] * nd))
