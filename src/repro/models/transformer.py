"""Layer-stack assembly: init, train forward, prefill, decode.

The stack is decomposed into *segments* of repeating layer-pattern *units*
(see cache.segments_of): uniform archs are one segment of a 1-layer pattern;
RecurrentGemma's (rec, rec, attn) pattern scans over 3-layer units with the
2-layer remainder as a second (length-1) segment. Each segment is a
``lax.scan`` over stacked params — compile time stays O(pattern), not O(L) —
with optional per-unit ``jax.checkpoint`` (remat).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import mla as mla_lib
from . import moe as moe_lib
from . import rglru as rglru_lib
from . import ssm as ssm_lib
from .cache import segments_of
from .sharding import logical_constraint as _lc
from .layers import (
    cross_entropy,
    dense_init,
    embed,
    init_embed,
    init_mlp,
    init_rmsnorm,
    lm_logits,
    mlp,
    rmsnorm,
)


def _act_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# per-slot init
# ---------------------------------------------------------------------------

def _init_slot(key, cfg, kind, dtype):
    ks = jax.random.split(key, 8)
    p = {"ln1": init_rmsnorm(cfg.d_model, dtype)}
    if kind == "attn":
        p["mix"] = attn_lib.init_attention(ks[0], cfg, dtype)
        if cfg.family == "encdec":
            p["cross"] = attn_lib.init_attention(ks[1], cfg, dtype)
            p["ln_x"] = init_rmsnorm(cfg.d_model, dtype)
    elif kind == "mla":
        p["mix"] = mla_lib.init_mla(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["mix"] = ssm_lib.init_ssm(ks[0], cfg, dtype)
    elif kind == "rec":
        p["mix"] = rglru_lib.init_rglru(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)

    if kind != "ssm":  # mamba2 blocks have no separate FFN
        p["ln2"] = init_rmsnorm(cfg.d_model, dtype)
        if cfg.moe_num_experts:
            p["moe"] = moe_lib.init_moe(ks[2], cfg, dtype)
        elif cfg.d_ff:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype, cfg.gated_mlp)
    return p


def init_params(cfg, key):
    dtype = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    keys = jax.random.split(key, 8)
    params = {"embed": init_embed(keys[0], cfg.padded_vocab, cfg.d_model, dtype)}

    segs = []
    for gi, (pattern, n_units) in enumerate(segments_of(cfg)):
        slots = {}
        for si, kind in enumerate(pattern):
            def one(k, kind=kind):
                return _init_slot(k, cfg, kind, dtype)
            ks = jax.random.split(jax.random.fold_in(keys[1], gi * 16 + si), n_units)
            slots[f"s{si}"] = jax.vmap(one)(ks)
        segs.append(slots)
    params["segments"] = segs

    params["final_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[2], cfg.d_model, cfg.padded_vocab, dtype)

    if cfg.family == "encdec":
        enc_slots = jax.vmap(
            lambda k: {
                "ln1": init_rmsnorm(cfg.d_model, dtype),
                "mix": attn_lib.init_attention(jax.random.fold_in(k, 0), cfg, dtype),
                "ln2": init_rmsnorm(cfg.d_model, dtype),
                "mlp": init_mlp(jax.random.fold_in(k, 1), cfg.d_model, cfg.d_ff,
                                dtype, cfg.gated_mlp),
            }
        )(jax.random.split(keys[3], cfg.enc_layers))
        params["encoder"] = {"layers": enc_slots,
                             "final_norm": init_rmsnorm(cfg.d_model, dtype)}
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _block_full(p, cfg, kind, x, positions, enc_out, slot_cache):
    """Full-sequence block (train/prefill). Returns (x, new_cache, aux)."""
    act = _act_dtype(cfg)
    aux = jnp.asarray(0.0, jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    new_cache = {}

    if kind == "attn":
        out, (k, v) = attn_lib.attention_forward(p["mix"], h, cfg, positions,
                                                 act_dtype=act)
        if slot_cache is not None:
            W = slot_cache["k"].shape[1]
            S = k.shape[1]
            if S >= W:
                # ring semantics: decode writes slot = pos % W, so the last W
                # keys must land at slots (S-W+i) % W — i.e. roll by S % W.
                kc = jnp.roll(k[:, -W:], S % W, axis=1)
                vc = jnp.roll(v[:, -W:], S % W, axis=1)
            else:       # cache larger than prompt: fill the head, zero-pad
                pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
                kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
            new_cache["k"] = kc.astype(slot_cache["k"].dtype)
            new_cache["v"] = vc.astype(slot_cache["v"].dtype)
    elif kind == "mla":
        out, (c_kv, k_rope) = mla_lib.mla_forward(p["mix"], h, cfg, positions,
                                                  act_dtype=act)
        if slot_cache is not None:
            W = slot_cache["c"].shape[1]
            S = c_kv.shape[1]
            if S < W:
                c_kv = jnp.pad(c_kv, [(0, 0), (0, W - S), (0, 0)])
                k_rope = jnp.pad(k_rope, [(0, 0), (0, W - S), (0, 0)])
            new_cache["c"] = c_kv[:, :W].astype(slot_cache["c"].dtype)
            new_cache["r"] = k_rope[:, :W].astype(slot_cache["r"].dtype)
    elif kind == "ssm":
        out, (conv, state) = ssm_lib.ssm_forward(p["mix"], h, cfg, act_dtype=act)
        if slot_cache is not None:
            new_cache["conv"] = conv.astype(slot_cache["conv"].dtype)
            new_cache["state"] = state
    elif kind == "rec":
        out, (conv, hstate) = rglru_lib.rglru_forward(p["mix"], h, cfg,
                                                      act_dtype=act)
        if slot_cache is not None:
            new_cache["conv"] = conv.astype(slot_cache["conv"].dtype)
            new_cache["h"] = hstate
    else:
        raise ValueError(kind)
    x = x + out

    if "cross" in p and enc_out is not None:
        hx = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        cx, (ck, cv) = _cross_attention(p["cross"], hx, enc_out, cfg, act)
        x = x + cx
        if slot_cache is not None:
            new_cache["ck"] = ck.astype(slot_cache["ck"].dtype)
            new_cache["cv"] = cv.astype(slot_cache["cv"].dtype)

    if "moe" in p:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        out2, aux = moe_lib.moe_forward(p["moe"], h2, cfg, act_dtype=act)
        x = x + out2
    elif "mlp" in p:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, cfg.gated_mlp, act_dtype=act)

    if slot_cache is None:
        new_cache = None
    return x, new_cache, aux


def _block_decode(p, cfg, kind, x, positions, slot_cache):
    """Single-token block. Returns (x, new_cache)."""
    act = _act_dtype(cfg)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    c = dict(slot_cache)

    if kind == "attn":
        W = slot_cache["k"].shape[1]
        cache_pos = positions % W if cfg.attn_window else positions
        out, k_c, v_c = attn_lib.attention_decode(
            p["mix"], h, cfg, positions, slot_cache["k"], slot_cache["v"],
            cache_pos, act_dtype=act)
        c["k"], c["v"] = k_c, v_c
    elif kind == "mla":
        out, c_c, r_c = mla_lib.mla_decode(
            p["mix"], h, cfg, positions, slot_cache["c"], slot_cache["r"],
            positions, act_dtype=act)
        c["c"], c["r"] = c_c, r_c
    elif kind == "ssm":
        out, (conv, state) = ssm_lib.ssm_decode(
            p["mix"], h, cfg, slot_cache["conv"], slot_cache["state"], act_dtype=act)
        c["conv"], c["state"] = conv.astype(slot_cache["conv"].dtype), state
    elif kind == "rec":
        out, (conv, hstate) = rglru_lib.rglru_decode(
            p["mix"], h, cfg, slot_cache["conv"], slot_cache["h"], act_dtype=act)
        c["conv"], c["h"] = conv.astype(slot_cache["conv"].dtype), hstate
    else:
        raise ValueError(kind)
    x = x + out

    if "cross" in p:
        hx = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        x = x + _cross_decode(p["cross"], hx, slot_cache["ck"], slot_cache["cv"],
                              cfg, act)

    if "moe" in p:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        out2, _ = moe_lib.moe_forward(p["moe"], h2, cfg, act_dtype=act)
        x = x + out2
    elif "mlp" in p:
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, cfg.gated_mlp, act_dtype=act)
    return x, c


def _cross_attention(p, x, enc_out, cfg, act):
    """Non-causal cross attention; k/v from encoder output (no rope)."""
    B, S, _ = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    Se = enc_out.shape[1]
    q = (x @ p["wq"].astype(act)).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"].astype(act)).reshape(B, Se, G, hd)
    v = (enc_out @ p["wv"].astype(act)).reshape(B, Se, G, hd)
    qp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kp = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    out = attn_lib._sdpa_chunked(q, k, v, qp, kp, causal=False, window=0,
                                 q_chunk=cfg.blockwise_q, kv_chunk=cfg.blockwise_kv,
                                 unroll=cfg.unroll_segments)
    return out.reshape(B, S, H * hd) @ p["wo"].astype(act), (k, v)


def _cross_decode(p, x, ck, cv, cfg, act):
    B = x.shape[0]
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    rep = H // G
    kf, vf = ck.astype(jnp.float32), cv.astype(jnp.float32)
    if rep > 1:
        kf = jnp.repeat(kf, rep, axis=2)
        vf = jnp.repeat(vf, rep, axis=2)
    q = (x @ p["wq"].astype(act)).reshape(B, H, hd)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32) / jnp.sqrt(hd), kf)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", pr, vf)
    return out.reshape(B, 1, H * hd).astype(act) @ p["wo"].astype(act)


# ---------------------------------------------------------------------------
# stack runner
# ---------------------------------------------------------------------------

def _run_segments(params, cfg, x, positions, cache, enc_out, mode):
    """mode: 'train' | 'prefill' | 'decode'. Returns (x, new_cache, aux)."""
    aux_total = jnp.asarray(0.0, jnp.float32)
    new_segments = []

    for gi, (pattern, n_units) in enumerate(segments_of(cfg)):
        seg_params = params["segments"][gi]
        seg_cache = cache["segments"][gi] if cache is not None else None

        def unit(carry, xs):
            x, aux = carry
            up, uc = xs
            if mode != "decode":
                # decode probes showed the forced residual-stream placement
                # only costs resharding at batch=decode scale (§Perf arctic)
                x = _lc(x, "batch", None, None)
            new_uc = {}
            for si, kind in enumerate(pattern):
                sp = up[f"s{si}"]
                sc = uc[f"s{si}"] if uc is not None else None
                if mode == "decode":
                    x, nc = _block_decode(sp, cfg, kind, x, positions, sc)
                    a = jnp.asarray(0.0, jnp.float32)
                else:
                    x, nc, a = _block_full(sp, cfg, kind, x, positions, enc_out, sc)
                if nc is not None:
                    new_uc[f"s{si}"] = nc
                aux = aux + a
            return (x, aux), (new_uc if new_uc else None)

        body = unit
        if mode == "train" and cfg.remat != "none":
            if cfg.remat == "dots":
                # §Perf iter 5: save matmul outputs, recompute elementwise-
                # only ops in the backward pass — trades a little saved-
                # activation memory for skipping the full-layer recompute.
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                body = jax.checkpoint(unit, prevent_cse=False, policy=policy)
            else:  # "full": recompute everything per unit
                body = jax.checkpoint(unit, prevent_cse=False)

        if cfg.unroll_segments:
            # python loop over units: L x larger HLO, but XLA cost analysis
            # then counts every layer (scan bodies are costed once).
            unit_caches = []
            for u in range(n_units):
                up = jax.tree_util.tree_map(lambda a: a[u], seg_params)
                uc = (jax.tree_util.tree_map(lambda a: a[u], seg_cache)
                      if seg_cache is not None else None)
                (x, aux_total), nc = body((x, aux_total), (up, uc))
                unit_caches.append(nc)
            if seg_cache is None:
                new_segments.append(None)
            else:
                new_segments.append(jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls), *unit_caches))
        elif seg_cache is None:
            (x, aux_total), _ = jax.lax.scan(
                lambda c, p: body(c, (p, None)), (x, aux_total), seg_params)
            new_segments.append(None)
        else:
            xs = (seg_params, seg_cache)
            (x, aux_total), new_sc = jax.lax.scan(body, (x, aux_total), xs)
            new_segments.append(new_sc)

    new_cache = {"segments": new_segments} if cache is not None else None
    return x, new_cache, aux_total


def _encode(params, cfg, enc_embeds):
    """Whisper-style encoder over precomputed frame embeddings (stub frontend)."""
    act = _act_dtype(cfg)
    x = enc_embeds.astype(act)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def enc_block(x, p):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        q, k, v = attn_lib._project_qkv(p["mix"], h, cfg, positions, act)
        out = attn_lib._sdpa_chunked(q, k, v, positions, positions,
                                     causal=False, window=0,
                                     q_chunk=cfg.blockwise_q,
                                     kv_chunk=cfg.blockwise_kv,
                                     unroll=cfg.unroll_segments)
        x = x + out.reshape(B, S, -1) @ p["mix"]["wo"].astype(act)
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        return x + mlp(p["mlp"], h2, cfg.gated_mlp, act_dtype=act), None

    if cfg.unroll_segments:
        for u in range(cfg.enc_layers):
            p_u = jax.tree_util.tree_map(lambda a: a[u], params["encoder"]["layers"])
            x, _ = enc_block(x, p_u)
    else:
        x, _ = jax.lax.scan(enc_block, x, params["encoder"]["layers"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _embed_inputs(params, cfg, tokens, batch):
    act = _act_dtype(cfg)
    x = embed(params["embed"], tokens, act_dtype=act)
    if cfg.family == "vlm" and "prefix_embeds" in batch:
        P = cfg.num_prefix_tokens
        x = jnp.concatenate([batch["prefix_embeds"].astype(act), x[:, P:]], axis=1)
    return x


def _logits(params, cfg, x):
    act = _act_dtype(cfg)
    head = params["head"] if "head" in params else params["embed"]["tok"].T
    out = lm_logits(head, x, act_dtype=act)
    return _lc(out, *(["batch"] + [None] * (out.ndim - 2) + ["vocab"]))


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def loss_fn(params, cfg, batch, aux_coef: float = 0.01):
    """Next-token CE (+ MoE load-balance aux)."""
    tokens, targets = batch["tokens"], batch["targets"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = _embed_inputs(params, cfg, tokens, batch)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["enc_embeds"])
    x, _, aux = _run_segments(params, cfg, x, positions, None, enc_out, "train")
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    if cfg.loss_chunk and S % cfg.loss_chunk == 0 and S > cfg.loss_chunk:
        # chunked CE: the [B, S, V] logits tensor never materializes — each
        # sequence chunk's logits live only inside its (remat'd) scan step.
        # Memory-roofline win: V-sized activations drop from O(S) to O(chunk).
        nc = S // cfg.loss_chunk
        xc = x.reshape(B, nc, cfg.loss_chunk, -1).swapaxes(0, 1)
        tc = targets.reshape(B, nc, cfg.loss_chunk).swapaxes(0, 1)

        @jax.checkpoint
        def chunk_ce(carry, xs):
            xb, tb = xs
            logits = _logits(params, cfg, xb)
            return carry + cross_entropy(logits, tb, cfg.vocab_size), None

        total, _ = jax.lax.scan(chunk_ce, jnp.asarray(0.0, jnp.float32), (xc, tc),
                                unroll=cfg.unroll_segments)
        ce = total / nc
    else:
        logits = _logits(params, cfg, x)
        ce = cross_entropy(logits, targets, cfg.vocab_size)
    return ce + aux_coef * aux, {"ce": ce, "aux": aux}


def prefill(params, cfg, batch, max_seq: Optional[int] = None):
    """Process a full prompt; returns (last-token logits, cache)."""
    from .cache import init_cache

    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cache = init_cache(cfg, batch=B, max_seq=max_seq or S)
    x = _embed_inputs(params, cfg, tokens, batch)
    enc_out = _encode(params, cfg, batch["enc_embeds"]) if cfg.family == "encdec" else None
    x, cache, _ = _run_segments(params, cfg, x, positions, cache, enc_out, "prefill")
    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return _logits(params, cfg, x)[:, 0], cache


def decode_step(params, cfg, tokens, positions, cache):
    """One AR step for a batch. tokens: (B,1); positions: (B,)."""
    x = embed(params["embed"], tokens, act_dtype=_act_dtype(cfg))
    x, cache, _ = _run_segments(params, cfg, x, positions, cache, None, "decode")
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x)[:, 0], cache
