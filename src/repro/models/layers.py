"""Shared building blocks: norms, RoPE, MLPs, embeddings, init helpers.

Parameters are plain pytrees (nested dicts of jax.Arrays). Every ``init_*``
function is pure and usable under ``jax.eval_shape`` so the dry-run can build
parameter ShapeDtypeStructs without allocating.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, scale, dtype):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def dense_init(key, in_dim, out_dim, dtype, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    return truncated_normal(key, (in_dim, out_dim), scale, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated SiLU or plain GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype, gated=True):
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], d_model, d_ff, dtype),
        "wo": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if gated:
        p["wg"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params, x, gated=True, act_dtype=jnp.bfloat16):
    h = x @ params["wi"].astype(act_dtype)
    if gated:
        g = x @ params["wg"].astype(act_dtype)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return h @ params["wo"].astype(act_dtype)


# ---------------------------------------------------------------------------
# embeddings / lm head
# ---------------------------------------------------------------------------

def init_embed(key, vocab, d_model, dtype):
    return {"tok": truncated_normal(key, (vocab, d_model), 1.0, dtype)}


def embed(params, tokens, act_dtype=jnp.bfloat16):
    return jnp.take(params["tok"], tokens, axis=0).astype(act_dtype)


def lm_logits(head, x, act_dtype=jnp.bfloat16):
    return x @ head.astype(act_dtype)


def cross_entropy(logits: jax.Array, targets: jax.Array, vocab_real: int) -> jax.Array:
    """Mean next-token CE; padded vocab columns masked out."""
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab_real:
        neg = jnp.full((logits.shape[-1] - vocab_real,), -1e30, jnp.float32)
        logits = logits.at[..., vocab_real:].set(neg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
