"""Deterministic synthetic token pipeline for LM training.

Pure function of ``(seed, step)`` so a restarted job replays the exact same
batches (fault-tolerance requirement): no pipeline state needs checkpointing
beyond the integer step.

The generator produces packed next-token-prediction batches with a Zipfian
unigram distribution plus a deterministic n-gram-ish structure so losses are
non-trivial (the model can actually learn) without any external corpus.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_batch_specs(batch: int, seq: int, vocab: int):
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }


@dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    batch_size: int           # per-host batch
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Batch for a given global step — stateless, replayable."""
        rng = np.random.default_rng((self.seed, step))
        v = self.vocab_size
        # zipf over a capped support, then mixed with a markov-ish shift so
        # that p(next | current) is learnable.
        raw = rng.zipf(self.zipf_a, size=(self.batch_size, self.seq_len + 1))
        base = (raw - 1) % v
        shift = np.cumsum(base, axis=1) % v
        toks = np.where(rng.random(base.shape) < 0.5, base, shift).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
