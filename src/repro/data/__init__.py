from .svm import make_sparse_classification, SvmDataset  # noqa: F401
from .tokens import TokenPipeline, synthetic_batch_specs  # noqa: F401
