from .svm import (  # noqa: F401
    CsrData,
    SvmDataset,
    csr_from_dense,
    load_libsvm,
    make_sparse_classification,
)
from .tokens import TokenPipeline, synthetic_batch_specs  # noqa: F401
