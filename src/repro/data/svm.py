"""Synthetic datasets for the sparse-SVM workload.

Generates linearly-separable-ish two-class data with a *known* sparse ground
truth ``w_true`` so screening behaviour (rejection rate vs lambda) can be
studied in a controlled way, plus utilities to mimic the paper's
high-dimensional text-like regimes (m >> n, sparse X).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np


class SvmDataset(NamedTuple):
    X: np.ndarray       # (m, n) features x samples (paper layout)
    y: np.ndarray       # (n,) in {-1, +1}
    w_true: np.ndarray  # (m,) ground-truth sparse direction


def make_sparse_classification(
    m: int = 512,
    n: int = 256,
    k_active: int = 16,
    noise: float = 0.25,
    density: float = 1.0,
    seed: int = 0,
    dtype=np.float32,
    correlated: float = 0.0,
) -> SvmDataset:
    """Two-class data: ``y = sign(w_true^T x + eps)`` with k-sparse w_true.

    ``density < 1`` zeroes random entries of X (text-like sparsity);
    ``correlated > 0`` mixes features with an AR(1)-style factor to create
    correlated (harder-to-screen) designs.
    """
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((m, n))
    if correlated > 0.0:
        common = rng.standard_normal((1, n))
        X = np.sqrt(1 - correlated) * X + np.sqrt(correlated) * common
    if density < 1.0:
        X *= rng.random((m, n)) < density

    w_true = np.zeros((m,))
    idx = rng.choice(m, size=k_active, replace=False)
    w_true[idx] = rng.standard_normal(k_active) * 2.0

    scores = w_true @ X + noise * rng.standard_normal(n)
    y = np.where(scores >= np.median(scores), 1.0, -1.0)
    # feature standardization (paper experiments standardize)
    X = (X - X.mean(axis=1, keepdims=True)) / (X.std(axis=1, keepdims=True) + 1e-12)
    return SvmDataset(X.astype(dtype), y.astype(dtype), w_true.astype(dtype))
