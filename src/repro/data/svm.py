"""Synthetic + on-disk datasets for the sparse-SVM workload.

Generates linearly-separable-ish two-class data with a *known* sparse ground
truth ``w_true`` so screening behaviour (rejection rate vs lambda) can be
studied in a controlled way, plus utilities to mimic the paper's
high-dimensional text-like regimes (m >> n, sparse X): a true CSR
representation for sparse designs (feeding ``--storage csr`` /
``repro.sparse.FeatureChunked.from_csr``) and a minimal libsvm-format text
loader for real datasets.
"""

from __future__ import annotations

import gzip
from typing import Iterator, NamedTuple, Optional

import numpy as np

__all__ = ["SvmDataset", "CsrData", "make_sparse_classification",
           "csr_from_dense", "load_libsvm", "iter_libsvm"]


class CsrData(NamedTuple):
    """CSR triple over *feature rows* (the paper's (m, n) layout)."""

    data: np.ndarray     # (nnz,)
    indices: np.ndarray  # (nnz,) int32 sample (column) indices
    indptr: np.ndarray   # (m + 1,) int64
    shape: tuple         # (m, n)

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def density(self) -> float:
        m, n = self.shape
        return self.nnz / max(m * n, 1)

    def to_dense(self, dtype=None) -> np.ndarray:
        m, n = self.shape
        out = np.zeros((m, n), dtype=dtype or self.data.dtype)
        rows = np.repeat(np.arange(m), np.diff(self.indptr))
        out[rows, self.indices] = self.data
        return out


class SvmDataset(NamedTuple):
    X: np.ndarray       # (m, n) features x samples (paper layout)
    y: np.ndarray       # (n,) in {-1, +1}
    w_true: np.ndarray  # (m,) ground-truth sparse direction
    #: true CSR view of X (same values, same dtype) for sparse designs;
    #: ``None`` when the matrix is dense (``density == 1``)
    csr: Optional[CsrData] = None


def csr_from_dense(X: np.ndarray) -> CsrData:
    """Exact CSR triple of a host matrix (row-major, numpy only)."""
    X = np.asarray(X)
    nz = X != 0
    indptr = np.concatenate([[0], np.cumsum(nz.sum(axis=1))]).astype(np.int64)
    return CsrData(
        data=X[nz],
        indices=np.nonzero(nz)[1].astype(np.int32),
        indptr=indptr,
        shape=tuple(X.shape),
    )


def make_sparse_classification(
    m: int = 512,
    n: int = 256,
    k_active: int = 16,
    noise: float = 0.25,
    density: float = 1.0,
    seed: int = 0,
    dtype=np.float32,
    correlated: float = 0.0,
) -> SvmDataset:
    """Two-class data: ``y = sign(w_true^T x + eps)`` with k-sparse w_true.

    ``density < 1`` zeroes random entries of X (text-like sparsity) and the
    returned dataset carries a true CSR triple (``.csr``) of the final
    matrix. To keep that sparsity *real*, sparse designs are standardized by
    feature scale only (no mean-centering — centering would densify every
    row; this matches how sparse text features are used in practice).
    Dense designs keep the paper's full standardization. ``correlated > 0``
    mixes features with an AR(1)-style factor to create correlated
    (harder-to-screen) designs.
    """
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((m, n))
    if correlated > 0.0:
        common = rng.standard_normal((1, n))
        X = np.sqrt(1 - correlated) * X + np.sqrt(correlated) * common
    sparse = density < 1.0
    if sparse:
        X *= rng.random((m, n)) < density

    w_true = np.zeros((m,))
    idx = rng.choice(m, size=k_active, replace=False)
    w_true[idx] = rng.standard_normal(k_active) * 2.0

    scores = w_true @ X + noise * rng.standard_normal(n)
    y = np.where(scores >= np.median(scores), 1.0, -1.0)
    # feature standardization (paper experiments standardize); scale-only
    # for sparse designs so zeros stay zeros
    if sparse:
        X = X / (X.std(axis=1, keepdims=True) + 1e-12)
    else:
        X = (X - X.mean(axis=1, keepdims=True)) / (X.std(axis=1, keepdims=True) + 1e-12)
    X = X.astype(dtype)
    csr = csr_from_dense(X) if sparse else None
    return SvmDataset(X, y.astype(dtype), w_true.astype(dtype), csr)


def _open_maybe_gzip(path):
    """Text handle for a libsvm file, transparently gunzipping.

    Detection is by content (gzip magic ``1f 8b``) rather than extension, so
    ``foo.txt`` that is secretly gzipped and ``foo.gz`` both work.
    """
    with open(path, "rb") as probe:
        magic = probe.read(2)
    if magic == b"\x1f\x8b":
        return gzip.open(path, "rt")
    return open(path, "rt")


def iter_libsvm(path, zero_based: bool = False) -> Iterator[tuple]:
    """Stream ``(label, feature_indices, values)`` per sample from a libsvm
    text file (plain or gzip).

    This is the single parsing point shared by :func:`load_libsvm` (in-core)
    and ``FeatureChunked.from_libsvm_cached`` (two-pass disk-store build):
    memory is O(one line). Comment lines / trailing ``# comments`` are
    stripped, blank lines and trailing whitespace tolerated; indices are
    1-based unless ``zero_based``.

    A malformed line (unparseable label, token without ``:``, non-numeric
    index/value, wrong index base) raises ``ValueError`` naming the file,
    the 1-based line number, and the offending token — not a bare float()
    traceback three frames deep.
    """
    with _open_maybe_gzip(path) as fh:
        for lineno, raw in enumerate(fh, start=1):
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split()
            try:
                label = float(parts[0])
            except ValueError:
                raise ValueError(
                    f"{path}:{lineno}: malformed label {parts[0]!r} "
                    f"(expected a number)") from None
            idx, vals = [], []
            for tok in parts[1:]:
                k, sep, v = tok.partition(":")
                if not sep:
                    raise ValueError(
                        f"{path}:{lineno}: malformed feature token {tok!r} "
                        f"(expected <index>:<value>)")
                try:
                    j = int(k) - (0 if zero_based else 1)
                    val = float(v)
                except ValueError:
                    raise ValueError(
                        f"{path}:{lineno}: malformed feature token {tok!r} "
                        f"(index must be an integer, value a number)"
                    ) from None
                if j < 0:
                    raise ValueError(
                        f"{path}:{lineno}: feature index {k} is not "
                        f"{'0' if zero_based else '1'}-based"
                    )
                idx.append(j)
                vals.append(val)
            yield label, idx, vals


def load_libsvm(
    path,
    n_features: Optional[int] = None,
    dtype=np.float32,
    zero_based: bool = False,
) -> SvmDataset:
    """Minimal libsvm/svmlight text loader, into the paper's (m, n) layout.

    Each line is ``<label> <index>:<value> ...``; indices are 1-based unless
    ``zero_based``. Gzip-compressed files are detected by magic bytes and
    decompressed on the fly; comment lines, trailing ``#`` comments, blank
    lines, and stray whitespace are tolerated. Labels are mapped to {-1, +1}
    by sign (0/1 labels map to -1/+1). Returns an :class:`SvmDataset` whose
    ``X`` is the dense ``(n_features, n_samples)`` matrix (``dtype=``
    selectable) and whose ``.csr`` is the exact CSR triple over feature rows
    — feed the latter to ``FeatureChunked.from_csr`` for out-of-core use
    (this loader materializes the dense host matrix; for data that must stay
    off host RAM use ``FeatureChunked.from_libsvm_cached``; ``w_true`` is
    zeros). Pure numpy — no scipy requirement.
    """
    feats, samples, vals, labels = [], [], [], []
    for label, idx, vv in iter_libsvm(path, zero_based=zero_based):
        labels.append(label)
        i = len(labels) - 1
        feats.extend(idx)
        samples.extend([i] * len(idx))
        vals.extend(vv)
    n = len(labels)
    if n == 0:
        raise ValueError(f"no samples in {path}")
    m = int(n_features) if n_features else (max(feats) + 1 if feats else 0)
    X = np.zeros((m, n), dtype=dtype)
    if feats:
        f = np.asarray(feats)
        if f.max() >= m:
            raise ValueError(f"feature index {f.max()} >= n_features={m}")
        X[f, np.asarray(samples)] = np.asarray(vals, dtype=dtype)
    y = np.where(np.asarray(labels) > 0, 1.0, -1.0).astype(dtype)
    return SvmDataset(X, y, np.zeros((m,), dtype), csr_from_dense(X))
