from .adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm  # noqa: F401
from .schedule import cosine_schedule, linear_warmup  # noqa: F401
from .compression import int8_compress, int8_decompress, compressed_psum  # noqa: F401
