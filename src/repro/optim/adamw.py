"""AdamW with global-norm clipping, decoupled weight decay, and a dtype
policy for the moments (fp32 default; bf16 for memory-bound giants like
arctic-480b — see DESIGN.md memory discipline notes).

Pure pytree functions (no optax dependency): states shard exactly like their
parameters under the pjit partitioner, which is what makes the FSDP layout
(optimizer state sharded over the data axis) fall out for free.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        update = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
