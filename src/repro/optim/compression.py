"""Gradient compression for the scarce cross-pod (DCN) hop.

int8 quantization with per-tensor scale and *stochastic rounding* (unbiased),
plus an error-feedback buffer so the quantization residual re-enters the next
step's gradient — the standard recipe that keeps compressed DP training at
parity. Used by the shard_map data-parallel trainers; under pjit the same
functions wrap the loss gradients before the implicit all-reduce is emitted
(apply on the per-microbatch accumulator).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(x: jax.Array, key: jax.Array):
    """(q, scale): unbiased stochastic-rounded int8 quantization."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    scaled = x32 / scale
    low = jnp.floor(scaled)
    p_up = scaled - low
    up = jax.random.uniform(key, x.shape) < p_up
    q = jnp.clip(low + up.astype(jnp.float32), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, key: jax.Array,
                    error: jax.Array | None = None):
    """psum(x) over ``axis_name`` with int8 payload + error feedback.

    Returns (mean_gradient, new_error). Payload over the wire is 1 byte per
    element (plus one scale); the residual (x - decompress(q)) is carried to
    the next call instead of being dropped.
    """
    if error is not None:
        x = x + error.astype(x.dtype)
    q, scale = int8_compress(x, key)
    new_error = x.astype(jnp.float32) - int8_decompress(q, scale)
    # sum int32 payloads (int8 would overflow across >127 members)
    summed = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale, axis_name)
    n = jax.lax.psum(1.0, axis_name)
    return (summed / n).astype(x.dtype), new_error.astype(x.dtype)
