"""Pallas TPU kernels for the squared-hinge solver hot loop.

Two tiled GEMV-shaped kernels (the FISTA iteration's only O(mn) work):

  * ``hinge_margin``  : u = X^T w, fused with xi = max(0, 1 - y(u + b)) and
                        the per-block loss partials — saves one HBM round
                        trip of u and one of xi vs composing XLA ops. The
                        raw margins ``u`` are emitted alongside ``xi`` so the
                        solver can carry them across iterations (the fused
                        FISTA body extrapolates the momentum point's margins
                        linearly from carried ``u`` instead of re-sweeping X).
  * ``hinge_grad``    : g = -X (y * xi), the transposed sweep.

Both accumulate in fp32 VMEM scratch regardless of input dtype; tiles are
(8k-aligned sublane x 128-aligned lane) blocks.

Row-validity counts (the active-set compaction seam, ``core/path_scan.py``
``reduce="compact"``): both kernels take a dynamic scalar ``valid_m`` — the
number of live leading feature rows. Compacted operands zero-pad the rows
past ``valid_m``, so those blocks contribute nothing; the count lets the
kernel *skip* their MXU work outright (``pl.when`` on the feature-block id)
instead of multiplying zeros. Passing ``valid_m = m`` is the full-matrix
case and leaves the schedule untouched.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _margin_kernel(x_ref, w_ref, y_ref, b_ref, vm_ref, u_ref, xi_ref, loss_ref,
                   acc_ref, *, m_steps):
    j = pl.program_id(1)  # feature-axis reduction step

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks entirely past the live rows of a compacted active set
    # (their x/w are zero padding — no contribution, so no MXU work)
    @pl.when(j * x_ref.shape[0] < vm_ref[0])
    def _acc():
        x = x_ref[...].astype(jnp.float32)   # (bm, bn)
        w = w_ref[...].astype(jnp.float32)   # (bm,)
        acc_ref[...] += w @ x                # (bn,) partial of X^T w

    @pl.when(j == m_steps - 1)
    def _fin():
        y = y_ref[...].astype(jnp.float32)
        b = b_ref[0]
        u = acc_ref[...]
        xi = jnp.maximum(0.0, 1.0 - y * (u + b))
        u_ref[...] = u
        xi_ref[...] = xi
        loss_ref[0] = 0.5 * jnp.sum(xi * xi)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def hinge_margin_pallas(
    X: jax.Array, w: jax.Array, y: jax.Array, b: jax.Array,
    valid_m: jax.Array | None = None,
    block_m: int = 256, block_n: int = 512, interpret: bool = False,
):
    """Returns (u, xi, loss). Shapes must be pre-padded to block multiples.

    ``u = X^T w`` (bias NOT added), ``xi = max(0, 1 - y(u + b))``,
    ``loss = 0.5 * sum(xi^2)`` — all three from one sweep of X. ``valid_m``
    (dynamic scalar, default all rows) skips feature blocks past the live
    rows of a compacted active set.
    """
    m, n = X.shape
    assert m % block_m == 0 and n % block_n == 0
    grid = (n // block_n, m // block_m)
    b_vec = jnp.full((8,), b, jnp.float32)
    vm_vec = jnp.full((8,), m if valid_m is None else valid_m, jnp.int32)

    kernel = functools.partial(_margin_kernel, m_steps=grid[1])
    u, xi, loss_parts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (j, i)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((8,), lambda i, j: (0,)),
            pl.BlockSpec((8,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.float32)],
        interpret=interpret,
    )(X, w, y, b_vec, vm_vec)
    return u, xi, jnp.sum(loss_parts)


def _grad_kernel(x_ref, v_ref, vm_ref, g_ref, acc_ref, *, n_steps):
    i = pl.program_id(0)  # feature-axis output block
    j = pl.program_id(1)  # sample-axis reduction step

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # output blocks past the compacted active set stay zero: skip their MXU
    # work (the final write still runs so every output row is defined)
    @pl.when(i * x_ref.shape[0] < vm_ref[0])
    def _acc():
        x = x_ref[...].astype(jnp.float32)   # (bm, bn)
        v = v_ref[...].astype(jnp.float32)   # (bn,) = y * xi
        acc_ref[...] += x @ v

    @pl.when(j == n_steps - 1)
    def _fin():
        g_ref[...] = -acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def hinge_grad_pallas(
    X: jax.Array, v: jax.Array,
    valid_m: jax.Array | None = None,
    block_m: int = 256, block_n: int = 512, interpret: bool = False,
) -> jax.Array:
    """g = -X v with fp32 accumulation (v = y * xi precomputed).

    ``valid_m`` (dynamic scalar, default all rows) skips output blocks past
    the live rows of a compacted active set — they are written as zeros.
    """
    m, n = X.shape
    assert m % block_m == 0 and n % block_n == 0
    grid = (m // block_m, n // block_n)
    vm_vec = jnp.full((8,), m if valid_m is None else valid_m, jnp.int32)
    kernel = functools.partial(_grad_kernel, n_steps=grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((8,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m,), jnp.float32)],
        interpret=interpret,
    )(X, v, vm_vec)
