"""Fused Pallas TPU kernels for screening bounds, parameterized by axis.

One kernel family, two reduction axes, one shared pattern: sweep X once,
accumulate a (units, 4) reduction block in VMEM across the grid's reduction
axis, and on the final grid step apply a ~30-flop closed-form finalizer
entirely in VMEM. X is read from HBM exactly once; nothing of size
O(units x 4) round-trips to HBM between the reduction and the bound.

``axis="features"`` (paper Alg. 1): per feature row j, reduce over samples

    d_theta = f_j . (y*theta1),  d_one = f_j . y,
    d_y     = f_j . 1,           d_sq  = f_j . f_j

then the three-case VI bound on ``|fhat_j^T theta2|`` (core/screening.py).

``axis="samples"`` (core/rules/sample_vi.py): per sample column i, the
transposed sweep reduces over features

    u_i = x_i . w1,              s_sq_i = ||x_i||^2

then the margin-surplus finalizer: ``y_i (u_i + b1) - 1 - slack_i`` with
``slack_i = min(sqrt(s_sq_i) * dw + db,  shrink * |u_i + b1 - u0_i| +
floor)`` (trust-region and secant slack models; see the rule's docstring).

TPU adaptation notes (vs the paper's per-unit CPU loop):
  * unit tiles ride the VPU sublanes; reduction tiles ride the 128-wide
    lanes (feature axis) or MXU contraction (both axes);
  * the dot-reductions are one (bu, br) x (br, 4) matmul so the MXU does the
    heavy lifting at fp32 accumulation;
  * the grid is (units/bu, reduction/br) with the reduction axis innermost
    ("arbitrary" semantics), accumulating into a VMEM scratch block that
    lives across the sweep — the canonical Pallas reduction pattern.

VMEM budget per program instance (defaults bm=256, bn=512, fp32):
  X tile 512 KiB + side tiles <16 KiB + acc 8 KiB << 16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NUM_SCALARS = 12  # packed per-axis scalars, padded to a common length

_BIG = 1e30  # stands in for inf inside the kernel (avoids 0 * inf = nan)


# --------------------------------------------------------------------------
# scalar packing
# --------------------------------------------------------------------------

def pack_shared(sh) -> jax.Array:
    """Pack ScreenShared scalars into a flat fp32 vector (feature axis)."""
    vals = [
        sh.inv_lam1, sh.inv_lam2, sh.yc, sh.ysq, sh.r_h_sq, sh.g0,
        sh.qa_sq, sh.a_norm, sh.a_dot_y,
        jnp.where(sh.halfspace_valid, 1.0, 0.0),
    ]
    v = jnp.stack([jnp.asarray(x, jnp.float32) for x in vals])
    return jnp.pad(v, (0, NUM_SCALARS - v.shape[0]))


def pack_sample_scalars(b1, dw, db, shrink_factor, margin_floor,
                        has_history) -> jax.Array:
    """Pack the sample-axis finalizer scalars (infs clamped to _BIG)."""
    vals = jnp.stack([
        jnp.asarray(b1, jnp.float32),
        jnp.minimum(jnp.asarray(dw, jnp.float32), _BIG),
        jnp.minimum(jnp.asarray(db, jnp.float32), _BIG),
        jnp.asarray(shrink_factor, jnp.float32),
        jnp.asarray(margin_floor, jnp.float32),
        jnp.where(jnp.asarray(has_history, bool), 1.0, 0.0).astype(jnp.float32),
    ])
    return jnp.pad(vals, (0, NUM_SCALARS - vals.shape[0]))


# --------------------------------------------------------------------------
# closed-form finalizers (vector over the unit tile)
# --------------------------------------------------------------------------

def _t_cases(v_ch, qv_qa, qv_sq, r_h, g0, qa_sq, hv):
    """One-sided ``max_{theta in K} v^T theta`` from hyperplane-projected
    stats of v — the three KKT cases of core/screening._t_max."""
    eps = jnp.float32(1e-30)
    qv_norm = jnp.sqrt(jnp.maximum(qv_sq, 0.0))

    ball = v_ch + r_h * qv_norm
    at_ball = g0 + r_h * qv_qa / jnp.maximum(qv_norm, eps)

    qa_sq_s = jnp.maximum(qa_sq, eps)
    mu = qv_qa / qa_sq_s
    vperp = jnp.sqrt(jnp.maximum(qv_sq - mu * mu * qa_sq_s, 0.0))
    rho = jnp.sqrt(jnp.maximum(r_h * r_h - g0 * g0 / qa_sq_s, 0.0))
    cut = v_ch - mu * g0 + rho * vperp

    use_ball = (at_ball >= 0.0) | (hv < 0.5) | (qv_norm <= eps)
    return jnp.where(use_ball, ball, cut)


def _feature_bound_from_acc(acc, sc):
    """VI bound on |fhat^T theta2| from the 4 reductions (vector bm)."""
    eps = jnp.float32(1e-30)
    d_theta, d_one, d_y, d_sq = acc[:, 0], acc[:, 1], acc[:, 2], acc[:, 3]
    inv1, inv2 = sc[0], sc[1]
    yc, ysq, r_h_sq, g0 = sc[2], sc[3], sc[4], sc[5]
    qa_sq, a_norm, a_dot_y, hv = sc[6], sc[7], sc[8], sc[9]

    v_c = 0.5 * (inv2 * d_one + d_theta)
    v_ch = v_c - (yc / ysq) * d_y
    qv_sq = jnp.maximum(d_sq - d_y * d_y / ysq, 0.0)
    v_a = (d_theta - inv1 * d_one) / jnp.maximum(a_norm, eps)
    qv_qa = v_a - d_y * a_dot_y / ysq

    r_h = jnp.sqrt(jnp.maximum(r_h_sq, 0.0))
    m_pos = _t_cases(v_ch, qv_qa, qv_sq, r_h, g0, qa_sq, hv)
    m_neg = _t_cases(-v_ch, -qv_qa, qv_sq, r_h, g0, qa_sq, hv)
    return jnp.maximum(m_pos, m_neg)


def _sample_surplus_from_acc(acc, aux, sc):
    """Margin surplus y*(u+b1) - 1 - slack from the 2 transposed reductions."""
    u_part, x_sq = acc[:, 0], acc[:, 1]
    y, u_prev = aux[:, 0], aux[:, 1]
    b1, dw, db = sc[0], sc[1], sc[2]
    shrink, floor, has_hist = sc[3], sc[4], sc[5]

    u = u_part + b1
    slack_tr = jnp.sqrt(jnp.maximum(x_sq, 0.0)) * dw + db
    secant = shrink * jnp.abs(u - u_prev) + floor
    slack = jnp.minimum(slack_tr, jnp.where(has_hist > 0.5, secant, _BIG))
    return y * u - 1.0 - jnp.minimum(slack, _BIG)


# --------------------------------------------------------------------------
# kernels
# --------------------------------------------------------------------------

def _feature_kernel(x_ref, rhs_ref, sc_ref, out_ref, acc_ref, *, n_steps: int):
    """Grid = (m_blocks, n_blocks); sample axis (dim 1) is the reduction."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bn)
    rhs = rhs_ref[...].astype(jnp.float32)      # (bn, 4) cols: y*theta, y, 1, 0
    # dots via MXU; the 4th accumulator column is ||f||^2 via elementwise.
    dots = jax.lax.dot_general(
        x, rhs, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (bm, 4); col 3 is zero
    sq = jnp.sum(x * x, axis=1)                  # (bm,)
    upd = dots.at[:, 3].add(sq)
    acc_ref[...] += upd

    @pl.when(j == n_steps - 1)
    def _finalize():
        out_ref[...] = _feature_bound_from_acc(acc_ref[...], sc_ref[...])


def _sample_kernel(x_ref, lhs_ref, aux_ref, sc_ref, out_ref, acc_ref, *,
                   n_steps: int):
    """Grid = (n_blocks, m_blocks); feature axis (dim 1) is the reduction."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bn) — transposed sweep
    lhs = lhs_ref[...].astype(jnp.float32)      # (bm, 4) cols: w1, 0, 0, 0
    dots = jax.lax.dot_general(
        x, lhs, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (bn, 4); col 0 is u partial
    sq = jnp.sum(x * x, axis=0)                  # (bn,) col sums: ||x_i||^2
    upd = dots.at[:, 1].add(sq)
    acc_ref[...] += upd

    @pl.when(j == n_steps - 1)
    def _finalize():
        out_ref[...] = _sample_surplus_from_acc(
            acc_ref[...], aux_ref[...], sc_ref[...]
        )


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("axis", "block_m", "block_n", "interpret")
)
def screen_bounds_pallas(
    X: jax.Array,
    rhs: jax.Array,
    scalars: jax.Array,
    aux: jax.Array | None = None,
    axis: str = "features",
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused per-unit screening bounds; ``axis`` picks the reduction axis.

    ``axis="features"``: ``rhs`` is the (n, 4) stacked
    ``[y*theta1, y, ones, zeros]``, ``scalars`` packs ScreenShared
    (``pack_shared``), ``aux`` unused; returns (m,) VI bounds.

    ``axis="samples"``: ``rhs`` is the (m, 4) stacked ``[w1, 0, 0, 0]``,
    ``aux`` is the (n, 2) stacked ``[y, u_prev]``, ``scalars`` packs the
    slack model (``pack_sample_scalars``); returns (n,) margin surpluses.

    X is (m, n), pre-padded to block multiples (see kernels/ops.py).
    """
    m, n = X.shape
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)

    if axis == "features":
        grid = (m // block_m, n // block_n)
        kernel = functools.partial(_feature_kernel, n_steps=grid[1])
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
                pl.BlockSpec((block_n, 4), lambda i, j: (j, 0)),
                pl.BlockSpec((NUM_SCALARS,), lambda i, j: (0,)),
            ],
            out_specs=pl.BlockSpec((block_m,), lambda i, j: (i,)),
            out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
            scratch_shapes=[pltpu.VMEM((block_m, 4), jnp.float32)],
            interpret=interpret,
        )(X, rhs, scalars)

    if axis == "samples":
        assert aux is not None, "sample axis needs aux = stack([y, u_prev])"
        grid = (n // block_n, m // block_m)
        kernel = functools.partial(_sample_kernel, n_steps=grid[1])
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_n), lambda i, j: (j, i)),
                pl.BlockSpec((block_m, 4), lambda i, j: (j, 0)),
                pl.BlockSpec((block_n, 2), lambda i, j: (i, 0)),
                pl.BlockSpec((NUM_SCALARS,), lambda i, j: (0,)),
            ],
            out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            scratch_shapes=[pltpu.VMEM((block_n, 4), jnp.float32)],
            interpret=interpret,
        )(X, rhs, aux, scalars)

    raise ValueError(f"axis must be 'features' or 'samples', got {axis!r}")
