"""Fused Pallas TPU kernel for the safe-screening bound (paper Alg. 1).

One pass over X computes, per feature row j, the four reductions

    d_theta = f_j . (y*theta1),  d_one = f_j . y,
    d_y     = f_j . 1,           d_sq  = f_j . f_j

and — on the final sample-axis grid step — applies the ~30-flop closed-form
bound (three KKT cases, see core/screening.py) entirely in VMEM. X is read
from HBM exactly once; nothing of size O(m x 4) round-trips to HBM between
the reduction and the bound evaluation.

TPU adaptation notes (vs the paper's per-feature CPU loop):
  * feature tiles of ``block_m`` rows ride the VPU sublanes (multiples of 8);
    sample tiles of ``block_n`` columns ride the 128-wide lanes;
  * the three dot-reductions are expressed as one (bm, bn) x (bn, 4) matmul
    so the MXU does the heavy lifting at fp32 accumulation;
  * the grid is (m/bm, n/bn) with the sample axis innermost ("arbitrary"
    semantics), accumulating into a VMEM scratch block that lives across the
    n-sweep — the canonical Pallas reduction pattern.

VMEM budget per program instance (defaults bm=256, bn=512, fp32):
  X tile 512 KiB + rhs tile 8 KiB + acc 4 KiB << 16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NUM_SCALARS = 12  # packed ScreenShared scalars, padded


def pack_shared(sh) -> jax.Array:
    """Pack ScreenShared scalars into a flat fp32 vector for the kernel."""
    vals = [
        sh.inv_lam1, sh.inv_lam2, sh.yc, sh.ysq, sh.r_h_sq, sh.g0,
        sh.qa_sq, sh.a_norm, sh.a_dot_y,
        jnp.where(sh.halfspace_valid, 1.0, 0.0),
    ]
    v = jnp.stack([jnp.asarray(x, jnp.float32) for x in vals])
    return jnp.pad(v, (0, NUM_SCALARS - v.shape[0]))


def _bound_from_acc(acc, sc):
    """Closed-form bound on |fhat^T theta2| from the 4 reductions (vector bm)."""
    eps = jnp.float32(1e-30)
    d_theta, d_one, d_y, d_sq = acc[:, 0], acc[:, 1], acc[:, 2], acc[:, 3]
    inv1, inv2 = sc[0], sc[1]
    yc, ysq, r_h_sq, g0 = sc[2], sc[3], sc[4], sc[5]
    qa_sq, a_norm, a_dot_y, hv = sc[6], sc[7], sc[8], sc[9]

    v_c = 0.5 * (inv2 * d_one + d_theta)
    v_ch = v_c - (yc / ysq) * d_y
    qv_sq = jnp.maximum(d_sq - d_y * d_y / ysq, 0.0)
    v_a = (d_theta - inv1 * d_one) / jnp.maximum(a_norm, eps)
    qv_qa = v_a - d_y * a_dot_y / ysq

    r_h = jnp.sqrt(jnp.maximum(r_h_sq, 0.0))
    qv_norm = jnp.sqrt(qv_sq)

    ball_pos = v_ch + r_h * qv_norm
    ball_neg = -v_ch + r_h * qv_norm
    at_pos = g0 + r_h * qv_qa / jnp.maximum(qv_norm, eps)
    at_neg = g0 - r_h * qv_qa / jnp.maximum(qv_norm, eps)

    qa_sq_s = jnp.maximum(qa_sq, eps)
    mu = qv_qa / qa_sq_s
    vperp = jnp.sqrt(jnp.maximum(qv_sq - mu * mu * qa_sq_s, 0.0))
    rho = jnp.sqrt(jnp.maximum(r_h_sq - g0 * g0 / qa_sq_s, 0.0))
    cut_pos = v_ch - mu * g0 + rho * vperp
    cut_neg = -v_ch + mu * g0 + rho * vperp

    use_ball_pos = (at_pos >= 0.0) | (hv < 0.5) | (qv_norm <= eps)
    use_ball_neg = (at_neg >= 0.0) | (hv < 0.5) | (qv_norm <= eps)
    m_pos = jnp.where(use_ball_pos, ball_pos, cut_pos)
    m_neg = jnp.where(use_ball_neg, ball_neg, cut_neg)
    return jnp.maximum(m_pos, m_neg)


def _screen_kernel(x_ref, rhs_ref, sc_ref, out_ref, acc_ref, *, n_steps: int):
    """Grid = (m_blocks, n_blocks); sample axis (dim 1) is the reduction."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bn)
    rhs = rhs_ref[...].astype(jnp.float32)      # (bn, 4) cols: y*theta, y, 1, 0
    # dots via MXU; the 4th accumulator column is ||f||^2 via elementwise.
    dots = jax.lax.dot_general(
        x, rhs, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (bm, 4); col 3 is zero
    sq = jnp.sum(x * x, axis=1)                  # (bm,)
    upd = dots.at[:, 3].add(sq)
    acc_ref[...] += upd

    @pl.when(j == n_steps - 1)
    def _finalize():
        sc = sc_ref[...]
        out_ref[...] = _bound_from_acc(acc_ref[...], sc)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def screen_bounds_pallas(
    X: jax.Array,
    rhs: jax.Array,       # (n, 4) stacked [y*theta1, y, ones, zeros]
    scalars: jax.Array,   # (NUM_SCALARS,) packed ScreenShared
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Bounds for all m features; X (m, n) padded to block multiples by ops.py."""
    m, n = X.shape
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (m // block_m, n // block_n)

    kernel = functools.partial(_screen_kernel, n_steps=grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((NUM_SCALARS,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, 4), jnp.float32)],
        interpret=interpret,
    )(X, rhs, scalars)
