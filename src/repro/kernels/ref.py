"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.screening import (
    FeatureReductions,
    screen_bounds_from_reductions,
    shared_scalars,
)


def screen_bounds_ref(
    X: jax.Array, y: jax.Array, lam1, lam2, theta1: jax.Array
) -> jax.Array:
    """Oracle for kernels.screen.screen_bounds_pallas (fp32 accumulation)."""
    Xf = X.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    tf = theta1.astype(jnp.float32)
    rhs = jnp.stack([yf * tf, yf, jnp.ones_like(yf)], axis=1)
    d = Xf @ rhs
    red = FeatureReductions(
        d_theta=d[:, 0], d_one=d[:, 1], d_y=d[:, 2], d_sq=jnp.sum(Xf * Xf, axis=1)
    )
    sh = shared_scalars(yf, lam1, lam2, tf)
    return screen_bounds_from_reductions(red, sh)


def sample_surplus_ref(
    X: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b,
    dw=float("inf"),
    db=float("inf"),
    u_prev: jax.Array | None = None,
    shrink_factor: float = 2.0,
    margin_floor: float = 1e-3,
) -> jax.Array:
    """Oracle for the sample-axis screen kernel (fp32 accumulation).

    Independent restatement of rules/sample_vi.sample_margin_surplus:
    ``y*u - 1 - min(||x_i|| * dw + db, shrink * |u - u_prev| + floor)``.
    """
    big = jnp.float32(1e30)
    Xf = X.astype(jnp.float32)
    u = Xf.T @ w.astype(jnp.float32) + jnp.asarray(b, jnp.float32)
    x_norm = jnp.sqrt(jnp.sum(Xf * Xf, axis=0))
    slack = jnp.minimum(x_norm * jnp.minimum(dw, big) + jnp.minimum(db, big), big)
    if u_prev is not None:
        secant = shrink_factor * jnp.abs(u - u_prev.astype(jnp.float32)) + margin_floor
        slack = jnp.minimum(slack, secant)
    return y.astype(jnp.float32) * u - 1.0 - slack


def hinge_stats_ref(
    X: jax.Array, y: jax.Array, w: jax.Array, b
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for kernels.hinge: margins u, residual xi, loss (fp32 accum)."""
    Xf = X.astype(jnp.float32)
    u = Xf.T @ w.astype(jnp.float32) + jnp.asarray(b, jnp.float32)
    xi = jnp.maximum(0.0, 1.0 - y.astype(jnp.float32) * u)
    loss = 0.5 * jnp.sum(xi * xi)
    return u, xi, loss


def hinge_grad_ref(X: jax.Array, y: jax.Array, xi: jax.Array) -> jax.Array:
    """Oracle for the gradient kernel: g = -X (y * xi)."""
    return -(X.astype(jnp.float32) @ (y.astype(jnp.float32) * xi.astype(jnp.float32)))
