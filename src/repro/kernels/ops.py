"""Public jit'd wrappers for the Pallas kernels.

Handle padding to block multiples, dtype policy (fp32 accumulation), backend
dispatch (Mosaic on TPU, ``interpret=True`` elsewhere / in tests), and
packing of the feature-independent scalars.
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.screening import shared_scalars
from . import hinge as _hinge
from . import screen as _screen


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _default_interpret() -> bool:
    """Interpret-mode policy: forced on by ``REPRO_PALLAS_INTERPRET=1`` (the
    CI kernel lane, scripts/ci.sh), otherwise Mosaic on TPU, interpret
    elsewhere."""
    if os.environ.get("REPRO_PALLAS_INTERPRET", "") not in ("", "0"):
        return True
    return not _on_tpu()


def fista_use_pallas(flag: bool | None = None) -> bool:
    """Resolve the solver's kernel-dispatch toggle to a concrete bool.

    This is the seam ``solver._make_fista_body`` dispatches through: ``True``
    routes the two O(mn) sweeps per FISTA iteration to the fused Pallas
    kernels (:func:`margin_obj_op` + :func:`hinge_grad_op`), ``False`` keeps
    the pure-XLA matmuls. Resolution order:

    1. an explicit ``flag`` (the per-call argument) wins;
    2. ``REPRO_FISTA_PALLAS=1`` / ``=0`` forces it on / off globally;
    3. default: on when running on TPU (Mosaic), off elsewhere — on CPU the
       kernels fall back to Pallas interpret mode (``_default_interpret``),
       which is correct but far slower than XLA, so it is opt-in there
       (tests force it to check solver equivalence).
    """
    if flag is not None:
        return bool(flag)
    env = os.environ.get("REPRO_FISTA_PALLAS", "")
    if env != "":
        return env != "0"
    return _on_tpu()


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def screen_bounds_op(
    X: jax.Array,
    y: jax.Array,
    lam1,
    lam2,
    theta1: jax.Array,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool | None = None,
    delta=0.0,
) -> jax.Array:
    """Fused screening bounds for all m features (kernel-backed).

    ``delta`` is the inexact-theta1 radius bound; it enters the kernel only
    through the packed shared scalars (ball inflation + g0 relaxation happen
    in ``shared_scalars``), so the sweep itself is unchanged — the in-solver
    dynamic refresh and the sequential screen share one kernel.
    """
    if interpret is None:
        interpret = _default_interpret()
    m, n = X.shape
    yf = y.astype(jnp.float32)
    tf = theta1.astype(jnp.float32)
    rhs = jnp.stack([yf * tf, yf, jnp.ones_like(yf), jnp.zeros_like(yf)], axis=1)
    sh = shared_scalars(yf, lam1, lam2, tf, delta=delta)
    scalars = _screen.pack_shared(sh)

    Xp = _pad_to(_pad_to(X, block_m, 0), block_n, 1)
    rhs_p = _pad_to(rhs, block_n, 0)
    out = _screen.screen_bounds_pallas(
        Xp, rhs_p, scalars, block_m=block_m, block_n=block_n, interpret=interpret
    )
    return out[:m]


def sample_surplus_op(
    X: jax.Array,
    w: jax.Array,
    y: jax.Array,
    b,
    dw=float("inf"),
    db=float("inf"),
    u_prev: jax.Array | None = None,
    shrink_factor: float = 2.0,
    margin_floor: float = 1e-3,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused sample-screening margin surpluses (kernel-backed).

    One transposed sweep of X computes ``u = X^T w + b`` and ``||x_i||^2``
    and finalizes ``y*u - 1 - slack`` in VMEM (see rules/sample_vi.py for
    the slack models). ``u_prev=None`` disables the secant model.
    """
    if interpret is None:
        interpret = _default_interpret()
    m, n = X.shape
    wf = w.astype(jnp.float32)
    lhs = jnp.stack(
        [wf, jnp.zeros_like(wf), jnp.zeros_like(wf), jnp.zeros_like(wf)], axis=1
    )
    yf = y.astype(jnp.float32)
    has_history = u_prev is not None
    up = (u_prev.astype(jnp.float32) if has_history else jnp.zeros_like(yf))
    aux = jnp.stack([yf, up], axis=1)
    scalars = _screen.pack_sample_scalars(
        b, dw, db, shrink_factor, margin_floor, has_history
    )

    Xp = _pad_to(_pad_to(X, block_m, 0), block_n, 1)
    lhs_p = _pad_to(lhs, block_m, 0)   # zero rows: no u / ||x||^2 contribution
    aux_p = _pad_to(aux, block_n, 0)   # y=0 columns are sliced off below
    out = _screen.screen_bounds_pallas(
        Xp, lhs_p, scalars, aux=aux_p, axis="samples",
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return out[:n]


def margin_obj_op(
    X: jax.Array, w: jax.Array, y: jax.Array, b,
    block_m: int = 256, block_n: int = 512, interpret: bool | None = None,
    valid_m: jax.Array | None = None,
):
    """(u, xi, loss) = fused margin/residual/loss sweep (kernel-backed).

    One pass over X yields ``u = X^T w`` (bias not added), the hinge slacks
    ``xi = max(0, 1 - y(u + b))``, and the squared-hinge loss
    ``0.5 * sum(xi^2)`` — this is the sweep the fused FISTA body issues at
    each *new* iterate, so the objective costs no extra pass over X (the
    separate ``_objective`` sweep of the pre-fusion solver is gone).

    ``valid_m`` (dynamic scalar): live leading-row count of a compacted
    active set (``core/path_scan.py reduce="compact"``); rows past it must
    be zero padding — the kernel skips their blocks.
    """
    if interpret is None:
        interpret = _default_interpret()
    m, n = X.shape
    Xp = _pad_to(_pad_to(X, block_m, 0), block_n, 1)
    wp = _pad_to(w, block_m, 0)
    # pad y with 0 => padded xi = max(0, 1-0*(u+b)) = 1: inert for u (w rows
    # are zero-padded) but each padded slot adds 0.5 to the loss — mask xi
    # and subtract the padded contribution after the call.
    yp = _pad_to(y, block_n, 0)
    u, xi, loss = _hinge.hinge_margin_pallas(
        Xp, wp, yp, jnp.asarray(b, jnp.float32), valid_m=valid_m,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    if yp.shape[0] != n:
        mask = (jnp.arange(yp.shape[0]) < n).astype(jnp.float32)
        xi = xi * mask
        # padded slots contributed 0.5 * 1^2 each to the loss (y=0 => xi=1)
        loss = loss - 0.5 * jnp.sum(1.0 - mask)
    return u[:n], xi[:n], loss


def hinge_margin_op(
    X: jax.Array, w: jax.Array, y: jax.Array, b,
    block_m: int = 256, block_n: int = 512, interpret: bool | None = None,
):
    """(xi, loss) = fused margin/residual sweep (kernel-backed)."""
    _, xi, loss = margin_obj_op(X, w, y, b, block_m=block_m, block_n=block_n,
                                interpret=interpret)
    return xi, loss


def hinge_grad_op(
    X: jax.Array, y: jax.Array, xi: jax.Array,
    block_m: int = 256, block_n: int = 512, interpret: bool | None = None,
    valid_m: jax.Array | None = None,
) -> jax.Array:
    """g = -X (y*xi) (kernel-backed). ``valid_m`` as in :func:`margin_obj_op`
    (output rows past the live count are written as zeros, not computed)."""
    if interpret is None:
        interpret = _default_interpret()
    m, n = X.shape
    Xp = _pad_to(_pad_to(X, block_m, 0), block_n, 1)
    v = _pad_to(y.astype(jnp.float32) * xi.astype(jnp.float32), block_n, 0)
    g = _hinge.hinge_grad_pallas(Xp, v, valid_m=valid_m, block_m=block_m,
                                 block_n=block_n, interpret=interpret)
    return g[:m]
