"""Fault-tolerant checkpointing.

Design goals (large-scale runnability):
  * ATOMIC: write to ``<dir>.tmp`` then ``os.replace`` — a preemption mid-save
    never corrupts the latest checkpoint.
  * ELASTIC: arrays are stored *unsharded-logical* (npz of flattened pytree
    paths), so a restart may use a different mesh shape / device count; the
    restore path re-shards via the caller's current NamedShardings. At real
    1000-node scale the same manager writes one npz per host-shard with the
    identical manifest format (hook left in ``shard_suffix``).
  * SELF-DESCRIBING: a JSON manifest carries step, config name, data cursor,
    and PRNG key so the data pipeline replays exactly (pipeline is a pure
    function of (seed, step)).
  * KEEP-K + corruption fallback: ``latest()`` validates the manifest and
    falls back to older checkpoints if the newest is unreadable.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_SEP = "||"


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_pytree(tree, path: Path):
    np.savez(path, **_flatten(tree))


def load_pytree(template, path: Path, strict: bool = True):
    """Restore into the structure of ``template`` (values replaced).

    ``strict=False`` lets state schemas evolve: template leaves missing from
    the checkpoint keep their template (initial) value instead of raising —
    use when restoring checkpoints written before a new state field existed.
    """
    data = np.load(path, allow_pickle=False)
    flat = dict(data.items())

    def fn(p, leaf):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        if not strict and key not in flat:
            return leaf
        arr = flat[key]
        return arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr

    return jax.tree_util.tree_map_with_path(fn, template)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- write ----------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        tmp = self.dir / f"step_{step:012d}.tmp"
        final = self.dir / f"step_{step:012d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        save_pytree(state, tmp / "state.npz")
        manifest = {
            "step": step,
            "time": time.time(),
            "format": 1,
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.replace(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        ckpts = self.all_steps()
        for step in ckpts[: -self.keep] if len(ckpts) > self.keep else []:
            shutil.rmtree(self.dir / f"step_{step:012d}", ignore_errors=True)

    # -- read -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(steps)

    def latest(self) -> Optional[int]:
        for step in reversed(self.all_steps()):
            if self._valid(step):
                return step
        return None

    def _valid(self, step: int) -> bool:
        d = self.dir / f"step_{step:012d}"
        try:
            m = json.loads((d / "manifest.json").read_text())
            return m.get("step") == step and (d / "state.npz").exists()
        except Exception:
            return False

    def restore(self, step: int, template: Any, strict: bool = True):
        d = self.dir / f"step_{step:012d}"
        state = load_pytree(template, d / "state.npz", strict=strict)
        manifest = json.loads((d / "manifest.json").read_text())
        return state, manifest

    def restore_raw(self, step: int) -> tuple[dict, dict]:
        """Template-free restore: the checkpoint's flattened
        ``{path: array}`` dict plus its manifest. For callers whose state
        is naturally a flat dict of arrays (e.g. the path server's serve
        snapshots) — no pytree template to thread around."""
        d = self.dir / f"step_{step:012d}"
        with np.load(d / "state.npz", allow_pickle=False) as data:
            flat = {k: np.array(v) for k, v in data.items()}
        manifest = json.loads((d / "manifest.json").read_text())
        return flat, manifest
