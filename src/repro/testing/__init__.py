"""Test-support utilities (fault injection, chaos harnesses)."""

from .faults import (
    ServerKilled,
    corrupt_store_bytes,
    dead_reads,
    flaky_reads,
    kill_server_after,
    poison_path_step,
    poison_stream_iterate,
    truncate_store_file,
)

__all__ = [
    "ServerKilled",
    "corrupt_store_bytes",
    "dead_reads",
    "flaky_reads",
    "kill_server_after",
    "poison_path_step",
    "poison_stream_iterate",
    "truncate_store_file",
]
