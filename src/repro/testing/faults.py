"""Fault-injection harness for the robustness test suite.

Each injector targets one seam the production code exposes on purpose:

* :func:`poison_path_step` — ``PathDriver._fault_injector``: corrupt the
  accepted solution of path step ``k`` *before* it is recorded and
  certified, so the poison flows into the step's stored weights, the next
  anchor's certificate, and the next warm start — the full recovery chain
  (refused certificate → keep-all screen → sanitized warm start) is what
  the chaos tests then assert on.
* :func:`poison_stream_iterate` — ``fista_solve_chunked(iteration_hook=)``:
  corrupt the streamed solver's candidate iterate at host-loop iteration
  ``k``, exercising the host-side guard (rollback + step backoff).
* :func:`corrupt_store_bytes` / :func:`truncate_store_file` — flip payload
  bytes in (or truncate) an on-disk store file, for checksum/truncation
  detection tests.
* :func:`flaky_reads` / :func:`dead_reads` — context managers installing
  ``repro.sparse.chunked._read_fault_hook`` so guarded store reads fail
  transiently (absorbed by retry) or persistently (typed ``StoreError``).
* :func:`kill_server_after` — ``PathServer._step_hook``: raise
  :class:`ServerKilled` after N serve-loop steps, simulating a crash
  mid-drain (snapshots taken before the kill stay valid — atomic publish).

Nothing here is imported by production code paths; the seams themselves
default to "off" (``None`` hooks).
"""

from __future__ import annotations

import contextlib

import numpy as np

from repro.sparse import chunked as _chunked


class ServerKilled(RuntimeError):
    """Raised by :func:`kill_server_after` to simulate a server crash."""


# -- solver / path poison ----------------------------------------------------

def poison_path_step(k: int, value: float = np.nan, coord: int = 0):
    """A ``PathDriver._fault_injector`` that corrupts step ``k``'s accepted
    weight vector (``w[coord] = value``) and bias, exactly once."""
    state = {"fired": False}

    def injector(step, w_full, b_new):
        if step == k and not state["fired"]:
            state["fired"] = True
            w_full = np.array(w_full, copy=True)
            w_full[coord] = value
            return w_full, float(value)
        return w_full, b_new

    injector.state = state
    return injector


def poison_stream_iterate(k: int, value: float = np.nan):
    """An ``iteration_hook`` for ``fista_solve_chunked`` that replaces the
    candidate objective at host iteration ``k`` with ``value``, once."""
    import jax.numpy as jnp

    state = {"fired": False}

    def hook(step, w, b, u, obj):
        if step == k and not state["fired"]:
            state["fired"] = True
            return w, b, u, jnp.asarray(value, w.dtype)
        return None

    hook.state = state
    return hook


# -- storage faults ----------------------------------------------------------

def corrupt_store_bytes(path, offset: int = 0, nbytes: int = 4):
    """Flip ``nbytes`` payload bytes of a store file in place (XOR 0xFF —
    guaranteed to change the bytes, hence the chunk's crc32)."""
    with open(path, "r+b") as f:
        f.seek(offset)
        raw = f.read(nbytes)
        f.seek(offset)
        f.write(bytes(b ^ 0xFF for b in raw))


def truncate_store_file(path, nbytes: int = 0):
    """Truncate a store file to ``nbytes`` (simulates an interrupted write
    that escaped the meta-last build protocol, or filesystem damage)."""
    with open(path, "r+b") as f:
        f.truncate(nbytes)


@contextlib.contextmanager
def flaky_reads(n_failures: int = 1):
    """Guarded store reads raise transient ``OSError`` for their first
    ``n_failures`` attempts, then succeed — must be absorbed by the retry
    loop (asserted via the yielded counter dict)."""
    counts: dict = {}

    def hook(tag, attempt):
        seen = counts.setdefault(tag, 0)
        if seen < n_failures:
            counts[tag] = seen + 1
            raise OSError(f"injected transient fault on {tag}")

    prev = _chunked._read_fault_hook
    _chunked._read_fault_hook = hook
    try:
        yield counts
    finally:
        _chunked._read_fault_hook = prev


@contextlib.contextmanager
def dead_reads():
    """Every guarded store read fails persistently — retries must exhaust
    and surface a typed ``StoreError``."""

    def hook(tag, attempt):
        raise OSError(f"injected persistent fault on {tag}")

    prev = _chunked._read_fault_hook
    _chunked._read_fault_hook = hook
    try:
        yield
    finally:
        _chunked._read_fault_hook = prev


# -- server crash ------------------------------------------------------------

def kill_server_after(n_steps: int):
    """A ``PathServer._step_hook`` raising :class:`ServerKilled` once the
    serve loop has executed ``n_steps`` batched steps."""

    def hook(step_count):
        if step_count >= n_steps:
            raise ServerKilled(f"injected crash after {step_count} steps")

    return hook
