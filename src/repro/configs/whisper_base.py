"""whisper-base — enc-dec audio model [arXiv:2212.04356].

6L encoder + 6L decoder, d_model=512, 8H, d_ff=2048, vocab=51865.
The conv frame frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, 1500, d_model). Adaptations (DESIGN.md §7): RMSNorm instead
of LayerNorm, RoPE decoder positions instead of learned embeddings — the
backbone compute/communication shape is preserved.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    gated_mlp=False,       # whisper uses plain GELU MLPs
    enc_layers=6,
    enc_seq=1500,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, enc_layers=2, enc_seq=32,
)
