"""internvl2-26b — InternViT frontend (stub) + InternLM2 backbone
[arXiv:2404.16821].

48L, d_model=6144, 48H (GQA kv=8), d_ff=16384, vocab=92553 (padded to 92672
for 16-way TP; logical vocab preserved, padded logits masked in the loss).
The ViT is a STUB per the brief: input_specs() provides precomputed patch
embeddings for the first 256 positions.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    num_prefix_tokens=256,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=256, num_prefix_tokens=8,
)
