"""deepseek-v2-236b — MLA (kv_lora=512) + MoE 160e top-6, 2 shared experts
[arXiv:2405.04434].

60L, d_model=5120, 128H, per-expert d_ff=1536, vocab=102400.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,      # MLA: per-head K/V from the shared latent
    head_dim=128,
    d_ff=0,
    vocab_size=102400,
    moe_num_experts=160,
    moe_top_k=6,
    moe_d_ff=1536,
    moe_num_shared=2,
    mla_kv_lora=512,
    mla_rope_dim=64,
    moe_group_size=1024,   # §Perf iter 3: dispatch GEMM flops/token ∝ group
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    vocab_size=256, moe_num_experts=8, moe_top_k=2, moe_d_ff=32,
    moe_num_shared=1, mla_kv_lora=32, mla_rope_dim=16, moe_group_size=64,
)
