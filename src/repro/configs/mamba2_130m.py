"""mamba2-130m — SSD (state-space duality) [arXiv:2405.21060].

24L, d_model=768, attention-free, vocab=50280, ssm_state=128.
Screening applicability: backbone is not an L1-penalized linear model; the
paper's rule attaches as a sparse-probe head only (DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,          # SSD heads = d_inner / ssm_head_dim
    num_kv_heads=24,       # unused (attention-free)
    d_ff=0,                # no separate FFN in mamba2 blocks
    vocab_size=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, vocab_size=256,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=32,
)
